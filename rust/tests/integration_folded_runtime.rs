//! Integration: folded configurations *execute* end-to-end on simcomm.
//!
//! The tentpole of ISSUE 2: `ParallelMapping::{folded,legacy}` — via the
//! runtime topology layer (`mapping::runtime`) — is the single source of
//! truth for every group the simulator runs, so configurations with
//! `tp·cp != etp·ep` (inexpressible before MoE Parallel Folding) actually
//! *run*, not just price analytically:
//!
//! 1. a folded config and its legacy-expressible counterpart produce
//!    **bit-identical** losses on the same token stream;
//! 2. gradient synchronization splits per parameter class (attention-DP vs
//!    EDP groups), which a flat all-reduce gets wrong whenever `dp != edp`;
//! 3. the Table-3 folded optima and the autotuner's analytic winners are
//!    executable on simcomm at full world size without panics.

use moe_folding::autotune;
use moe_folding::config::{DropPolicy, ModelConfig, ParallelConfig, TrainConfig};
use moe_folding::dispatcher::{
    reference_moe_forward, Balancer, DistributedMoeLayer, Router, RouterConfig,
};
use moe_folding::mapping::RuntimeTopology;
use moe_folding::perfmodel::{PerfModel, Strategy};
use moe_folding::pipeline::execute_1f1b_mapped;
use moe_folding::simcomm::{run_ranks, Payload};
use moe_folding::train::math::SwigluExpert;
use moe_folding::train::{GradSync, ParamClass};
use moe_folding::util::Rng;

const H: usize = 16;
const FF: usize = 32;

fn build_router(num_experts: usize, top_k: usize, policy: DropPolicy, seed: u64) -> Router {
    let mut rng = Rng::seed_from_u64(seed);
    Router::init(
        RouterConfig {
            hidden: H,
            num_experts,
            top_k,
            capacity_factor: 1.0,
            drop_policy: policy,
            capacity_override: None,
            pad_to_capacity: false,
            node_limit: None,
            balancer: Balancer::AuxLoss,
        },
        &mut rng,
    )
}

fn build_experts(num_experts: usize, seed: u64) -> Vec<SwigluExpert> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..num_experts).map(|_| SwigluExpert::init(H, FF, &mut rng)).collect()
}

/// Run `steps` MoE forwards over `topo`, one token chunk per rank per step
/// drawn from the shared `stream`, and return per-rank (outputs, losses).
/// The "loss" is the full-world mean of the per-rank output sums — a
/// deterministic rank-order fold, so layouts that compute the same math
/// produce the same bits.
fn run_stream(
    topo: &RuntimeTopology,
    router: &Router,
    experts: &[SwigluExpert],
    stream: &[Vec<f32>],
    n_per_rank: usize,
) -> Vec<(Vec<Vec<f32>>, Vec<f32>)> {
    let world = topo.world();
    run_ranks(world, |rank, comm| {
        let layer =
            DistributedMoeLayer::from_topology(topo.view(rank), router.clone(), experts);
        let all: Vec<usize> = (0..world).collect();
        let mut outs = Vec::new();
        let mut losses = Vec::new();
        for step_tokens in stream {
            let mine =
                step_tokens[rank * n_per_rank * H..(rank + 1) * n_per_rank * H].to_vec();
            let (out, _) = layer.forward(&comm, &mine);
            let local: f32 = out.iter().sum();
            let l = comm.all_reduce_sum(&all, &[local]);
            losses.push(l[0] / world as f32);
            outs.push(out);
        }
        (outs, losses)
    })
}

/// Tentpole differential: a folded config with `tp·cp != etp·ep`
/// (TP2·CP1 attention vs ETP1·EP4 MoE on 8 ranks — inexpressible in the
/// coupled legacy scheme) must produce bit-identical per-rank outputs and
/// losses to a legacy-expressible counterpart (TP1·ETP1·EP2) on the same
/// token stream: the MoE math is layout-invariant, only the groups differ.
#[test]
fn folded_config_matches_legacy_counterpart_bit_for_bit() {
    let folded_cfg = ParallelConfig::new(8, 2, 1, 4, 1, 1);
    assert_ne!(folded_cfg.attn_inner(), folded_cfg.moe_inner());
    assert!(!folded_cfg.is_legacy_expressible());
    let legacy_cfg = ParallelConfig::new(8, 1, 1, 2, 1, 1);
    assert!(legacy_cfg.is_legacy_expressible());

    let folded = RuntimeTopology::folded(folded_cfg).unwrap();
    let legacy = RuntimeTopology::legacy(legacy_cfg).unwrap();
    // The two layouts really do execute different EP groups.
    assert_eq!(folded.view(0).ep_group.len(), 4);
    assert_eq!(legacy.view(0).ep_group.len(), 2);

    for policy in [DropPolicy::Dropless, DropPolicy::SubSequence] {
        let router = build_router(8, 2, policy, 100);
        let experts = build_experts(8, 200);
        let n_per_rank = 12;
        let mut rng = Rng::seed_from_u64(300);
        let stream: Vec<Vec<f32>> = (0..3)
            .map(|_| {
                let mut t = vec![0.0f32; 8 * n_per_rank * H];
                rng.fill_normal(&mut t, 1.0);
                t
            })
            .collect();

        let f = run_stream(&folded, &router, &experts, &stream, n_per_rank);
        let l = run_stream(&legacy, &router, &experts, &stream, n_per_rank);
        for rank in 0..8 {
            for step in 0..stream.len() {
                assert_eq!(
                    f[rank].1[step].to_bits(),
                    l[rank].1[step].to_bits(),
                    "{policy:?} rank {rank} step {step}: loss {} vs {}",
                    f[rank].1[step],
                    l[rank].1[step]
                );
                let (fo, lo) = (&f[rank].0[step], &l[rank].0[step]);
                assert_eq!(fo.len(), lo.len());
                for (i, (a, b)) in fo.iter().zip(lo).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{policy:?} rank {rank} step {step} idx {i}: {a} vs {b}"
                    );
                }
            }
        }
    }
}

/// The trainer's per-class gradient reduction under folding: attention
/// gradients average over the attention-DP group (4 ranks here), expert
/// gradients over the EDP group (2 ranks) — with param classes resolved the
/// same way `train::trainer` resolves `expert_param_indices`.
#[test]
fn gradient_sync_splits_attention_dp_from_edp() {
    let topo = RuntimeTopology::folded(ParallelConfig::new(8, 2, 1, 4, 1, 1)).unwrap();
    assert_eq!(topo.config().dp(), 4);
    assert_eq!(topo.config().edp(), 2);
    let expert_param_indices = [2usize];

    let outs = run_ranks(8, |rank, comm| {
        let sync = GradSync::from_topology(&topo, rank);
        // Three "parameter tensors": 0/1 attention-class, 2 expert-class.
        let mut grads: Vec<Vec<f32>> = vec![
            vec![rank as f32; 4],
            vec![10.0 + rank as f32; 4],
            vec![100.0 + rank as f32; 4],
        ];
        for (i, g) in grads.iter_mut().enumerate() {
            let class = if expert_param_indices.contains(&i) {
                ParamClass::Expert
            } else {
                ParamClass::Attention
            };
            sync.reduce_mean(&comm, class, g);
        }
        (grads[0][0], grads[1][0], grads[2][0])
    });

    for (r, &(a0, a1, e)) in outs.iter().enumerate() {
        // Attention DP group {r%2, r%2+2, r%2+4, r%2+6} -> mean r%2 + 3.
        assert_eq!(a0, (r % 2) as f32 + 3.0, "rank {r}");
        assert_eq!(a1, 10.0 + (r % 2) as f32 + 3.0, "rank {r}");
        // Expert EDP group {r%4, r%4+4} -> mean 100 + r%4 + 2.
        assert_eq!(e, 100.0 + (r % 4) as f32 + 2.0, "rank {r}");
        // A flat world all-reduce would have produced 3.5 / 13.5 / 103.5.
        assert_ne!(a0, 3.5);
        assert_ne!(e, 103.5);
    }
}

/// Execute one full simulated step of `topo` at full world size: MoE
/// dispatch from topology groups, 1F1B over the mapping's PP partition,
/// and a closing world-wide reduction. Asserts finite outputs and agreeing
/// global losses — the "runs without panics" bar for analytic winners.
fn execute_end_to_end(topo: &RuntimeTopology, num_experts: usize) {
    let world = topo.world();
    let top_k = 2.min(num_experts);
    let router = build_router(num_experts, top_k, DropPolicy::Dropless, 4242);
    let experts = build_experts(num_experts, 4243);
    let n_per_rank = 2;
    let mut rng = Rng::seed_from_u64(4244);
    let mut tokens = vec![0.0f32; world * n_per_rank * H];
    rng.fill_normal(&mut tokens, 1.0);
    let m = 2;
    let width = 4;
    let inputs: Vec<Vec<f32>> = (0..m).map(|mb| vec![mb as f32; width]).collect();

    let losses = run_ranks(world, |rank, comm| {
        let view = topo.view(rank);
        let layer =
            DistributedMoeLayer::from_topology(view, router.clone(), &experts);
        let mine = tokens[rank * n_per_rank * H..(rank + 1) * n_per_rank * H].to_vec();
        let (out, stats) = layer.forward(&comm, &mine);
        assert_eq!(out.len(), n_per_rank * H);
        assert!(out.iter().all(|v| v.is_finite()), "rank {rank} non-finite output");
        assert_eq!(stats.tokens_routed, n_per_rank * top_k);

        // Pipeline hand-off over the mapping's PP partition.
        let pipe = execute_1f1b_mapped(
            &comm,
            topo,
            m,
            &inputs,
            |_mb, x| x.iter().map(|v| v + 1.0).collect(),
            |_mb, g| g.to_vec(),
        );
        let pp = view.pp_group.len();
        if view.pp_stage == pp - 1 {
            for (mb, o) in pipe.outputs.iter().enumerate() {
                assert_eq!(o, &vec![mb as f32 + pp as f32; width], "rank {rank} mb {mb}");
            }
        }

        let all: Vec<usize> = (0..world).collect();
        let local: f32 = out.iter().sum();
        comm.all_reduce_sum(&all, &[local])[0]
    });
    for w in losses.windows(2) {
        assert_eq!(w[0].to_bits(), w[1].to_bits(), "global loss must agree on all ranks");
    }
}

/// Every Table-3 folded optimum executes end-to-end on simcomm at its full
/// world size (128/64/128/256 ranks).
#[test]
fn table3_folded_optima_execute_on_simcomm() {
    for (w, tp, cp, ep, etp, pp) in [
        (128, 2, 1, 8, 1, 8),  // Mixtral-8x22B
        (64, 2, 1, 4, 1, 4),   // Qwen2-57B-A14B
        (128, 4, 1, 8, 1, 8),  // Mixtral-8x22B-G8T8
        (256, 8, 1, 8, 1, 16), // Llama3-8x70B
    ] {
        let cfg = ParallelConfig::new(w, tp, cp, ep, etp, pp);
        let topo = RuntimeTopology::folded(cfg)
            .unwrap_or_else(|e| panic!("{}: {e}", cfg.tag()));
        execute_end_to_end(&topo, 8);
    }
}

/// The autotuner's analytic winner for every Table-3 (model, GPUs) case is
/// executable: the mapping the performance model priced is the mapping the
/// simulator runs.
#[test]
fn autotune_winners_execute_on_simcomm() {
    let pm = PerfModel::default();
    let train = TrainConfig::paper_default(4096, 256);
    let mut executed = 0usize;
    for (model, gpus) in [
        (ModelConfig::mixtral_8x22b(), 128),
        (ModelConfig::qwen2_57b_a14b(), 64),
        (ModelConfig::mixtral_8x22b_g8t8(), 128),
        (ModelConfig::llama3_8x70b(), 256),
    ] {
        let r = autotune::tune(&pm, &model, gpus, &train, Strategy::MCoreFolding);
        let Some(best) = r.best else {
            // No feasible (non-OOM) estimate -> nothing to execute. Only
            // Mixtral's feasibility is pinned by the perf-model tests.
            assert_ne!(
                model.name, "Mixtral-8x22B",
                "Mixtral@128 must have a feasible folded winner"
            );
            eprintln!("{} @ {gpus}: all folded candidates OOM, skipping", model.name);
            continue;
        };
        let topo = RuntimeTopology::folded(best.config)
            .unwrap_or_else(|e| panic!("{} winner {}: {e}", model.name, best.config.tag()));
        execute_end_to_end(&topo, model.num_experts);
        executed += 1;
    }
    assert!(executed >= 1, "no analytic winner was executable");
}

/// Full-sequence dropping with a *non-divisible* sequence split (5 + 3
/// tokens): slice offsets must come from the gathered per-rank counts, and
/// the result must match the single-rank full-scope reference bit-for-bit.
/// Regression for the `my_idx * n_local` misalignment (ISSUE 2).
#[test]
fn full_sequence_drop_handles_uneven_splits() {
    let router = build_router(8, 2, DropPolicy::FullSequence, 7);
    let experts = build_experts(8, 8);
    let n_total = 8;
    let split = [5usize, 3];
    let mut rng = Rng::seed_from_u64(9);
    let mut all_tokens = vec![0.0f32; n_total * H];
    rng.fill_normal(&mut all_tokens, 1.0);

    let reference = reference_moe_forward(&router, &experts, &all_tokens, None);
    let expect_aux = router.route(&all_tokens).aux_loss;

    let outs = run_ranks(2, |rank, comm| {
        let epr = 8 / 2;
        let layer = DistributedMoeLayer {
            router: router.clone(),
            local_experts: experts[rank * epr..(rank + 1) * epr].to_vec(),
            ep_group: vec![0, 1],
            etp_group: vec![rank],
            ep_index: rank,
            num_experts: 8,
            seq_group: Some(vec![0, 1]),
            phase_cost: None,
            overlap_a2a: false,
            payload: Payload::F32,
        };
        let offset: usize = split[..rank].iter().sum();
        let mine = all_tokens[offset * H..(offset + split[rank]) * H].to_vec();
        layer.forward(&comm, &mine)
    });

    let distributed: Vec<f32> = outs.iter().flat_map(|(o, _)| o.clone()).collect();
    assert_eq!(distributed.len(), reference.len());
    for (i, (a, b)) in distributed.iter().zip(&reference).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "idx {i}: {a} vs {b} (uneven full-sequence split must be exact)"
        );
    }
    // The aux loss is computed from full-sequence statistics: bit-identical
    // across ranks and to the single-rank reference (ISSUE 2 satellite).
    for (rank, (_, stats)) in outs.iter().enumerate() {
        assert_eq!(
            stats.aux_loss.to_bits(),
            expect_aux.to_bits(),
            "rank {rank}: aux {} vs reference {expect_aux}",
            stats.aux_loss
        );
    }
}
