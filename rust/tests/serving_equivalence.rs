//! Differential suite for the serving path (ISSUE 10): autoregressive
//! decode microsteps are bit-identical to the single-rank reference and
//! invariant to how prefill is chunked; the replay engine's output digest
//! is pinned across the `microstep_tokens` knob; and MoETuner-style expert
//! placement provably cuts the fabric's metered InfiniBand dispatch bytes
//! on pinned skewed traffic while staying a strict identity on uniform
//! traffic. ETP sharding, which reorders the FFN reduction, keeps the same
//! tolerance tier as the training differential (`skew_equivalence`).

use moe_folding::cluster::ClusterSpec;
use moe_folding::config::{DropPolicy, ParallelConfig};
use moe_folding::dispatcher::{
    reference_moe_forward, Balancer, DistributedMoeLayer, Router, RouterConfig, SkewGen,
    SkewProfile,
};
use moe_folding::mapping::RuntimeTopology;
use moe_folding::serving::{
    measure_ib_bytes, optimize_placement, replay, rotate_gate_features, ExpertPlacement,
    PlacementHistogram, ReplaySpec,
};
use moe_folding::simcomm::{run_ranks, Payload};
use moe_folding::train::math::SwigluExpert;
use moe_folding::util::Rng;

const H: usize = 16;
const FF: usize = 32;
const E: usize = 8;
const K: usize = 2;
const PREFILL: usize = 8;
const DECODE: usize = 4;

fn dropless_cfg(hidden: usize, e: usize, k: usize) -> RouterConfig {
    RouterConfig {
        hidden,
        num_experts: e,
        top_k: k,
        capacity_factor: 1.0,
        drop_policy: DropPolicy::Dropless,
        capacity_override: None,
        pad_to_capacity: false,
        node_limit: None,
        balancer: Balancer::AuxLoss,
    }
}

fn build_experts(e: usize, hidden: usize, ff: usize, seed: u64) -> Vec<SwigluExpert> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..e).map(|_| SwigluExpert::init(hidden, ff, &mut rng)).collect()
}

/// One Zipf "sequence" per rank: PREFILL prompt rows plus DECODE generated
/// rows, seeded independently per rank.
fn per_rank_sequences(world: usize, e: usize, hidden: usize, seed: u64) -> Vec<Vec<f32>> {
    (0..world)
        .map(|r| {
            let mut gen = SkewGen::new(
                SkewProfile::Zipf { exponent: 1.2 },
                e,
                hidden,
                seed + r as u64,
            );
            gen.next_tokens(PREFILL + DECODE)
        })
        .collect()
}

/// The decode microstep schedule: one training-shaped prefill round, then
/// one single-token round per generated token.
fn decode_schedule() -> Vec<usize> {
    let mut schedule = vec![PREFILL];
    schedule.extend(std::iter::repeat(1).take(DECODE));
    schedule
}

/// Serving's microstep structure changes nothing about the math: running
/// each sequence as prefill + single-token decode rounds produces outputs
/// bit-identical to one whole-sequence distributed forward AND to the
/// single-rank reference, on a plain EP grid and on a folded
/// `tp·cp ≠ etp·ep` grid.
#[test]
fn decode_microsteps_match_oneshot_and_reference_bitwise() {
    for (world, pcfg) in [
        (4, ParallelConfig::new(4, 1, 1, 4, 1, 1)),
        (8, ParallelConfig::new(8, 2, 1, 4, 1, 1)),
    ] {
        let topo = RuntimeTopology::folded(pcfg).unwrap();
        let experts = build_experts(E, H, FF, 13);
        let router = Router::new(dropless_cfg(H, E, K), SkewGen::gate_weight(H, E));
        let seqs = per_rank_sequences(world, E, H, 100);

        let mut micro: Vec<Vec<f32>> = vec![Vec::new(); world];
        let mut off = 0usize;
        for rows in decode_schedule() {
            let step = run_ranks(world, |rank, comm| {
                let layer =
                    DistributedMoeLayer::from_topology(topo.view(rank), router.clone(), &experts);
                let mine = seqs[rank][off * H..(off + rows) * H].to_vec();
                layer.forward(&comm, &mine).0
            });
            for (acc, out) in micro.iter_mut().zip(step) {
                acc.extend(out);
            }
            off += rows;
        }

        let oneshot = run_ranks(world, |rank, comm| {
            let layer =
                DistributedMoeLayer::from_topology(topo.view(rank), router.clone(), &experts);
            layer.forward(&comm, &seqs[rank]).0
        });
        for (rank, (m, o)) in micro.iter().zip(&oneshot).enumerate() {
            assert_eq!(m.len(), o.len());
            for (i, (a, b)) in m.iter().zip(o).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{} rank {rank} idx {i}: microstepped {a} vs one-shot {b}",
                    pcfg.tag()
                );
            }
        }

        let all_tokens: Vec<f32> = seqs.concat();
        let reference =
            reference_moe_forward(&router, &experts, &all_tokens, Some(PREFILL + DECODE));
        let distributed: Vec<f32> = micro.concat();
        assert_eq!(distributed.len(), reference.len());
        for (i, (a, b)) in distributed.iter().zip(&reference).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{} idx {i}: distributed {a} vs reference {b}",
                pcfg.tag()
            );
        }
    }
}

/// ETP sharding splits each expert's FFN reduction across ranks, so the
/// decode microsteps match the reference within the same tolerance tier
/// the training differential uses — not bitwise.
#[test]
fn etp_sharded_decode_microsteps_match_reference_within_tolerance() {
    let (ep, etp) = (2, 2);
    let world = ep * etp;
    let experts = build_experts(E, H, FF, 11);
    let router = Router::new(dropless_cfg(H, E, K), SkewGen::gate_weight(H, E));
    let seqs = per_rank_sequences(world, E, H, 300);

    let mut micro: Vec<Vec<f32>> = vec![Vec::new(); world];
    let mut off = 0usize;
    for rows in decode_schedule() {
        let step = run_ranks(world, |rank, comm| {
            let ep_idx = rank / etp;
            let etp_idx = rank % etp;
            let epr = E / ep;
            let layer = DistributedMoeLayer {
                router: router.clone(),
                local_experts: (0..epr)
                    .map(|le| experts[ep_idx * epr + le].shard(etp, etp_idx))
                    .collect(),
                ep_group: (0..ep).map(|i| i * etp + etp_idx).collect(),
                etp_group: (0..etp).map(|i| ep_idx * etp + i).collect(),
                ep_index: ep_idx,
                num_experts: E,
                seq_group: None,
                phase_cost: None,
                overlap_a2a: false,
                payload: Payload::F32,
            };
            let mine = seqs[rank][off * H..(off + rows) * H].to_vec();
            layer.forward(&comm, &mine).0
        });
        for (acc, out) in micro.iter_mut().zip(step) {
            acc.extend(out);
        }
        off += rows;
    }

    let all_tokens: Vec<f32> = seqs.concat();
    let reference = reference_moe_forward(&router, &experts, &all_tokens, Some(PREFILL + DECODE));
    let distributed: Vec<f32> = micro.concat();
    assert_eq!(distributed.len(), reference.len());
    for (i, (a, b)) in distributed.iter().zip(&reference).enumerate() {
        assert!(
            (a - b).abs() < 2e-4 * (1.0 + b.abs()),
            "etp decode idx {i}: {a} vs {b}"
        );
    }
}

/// The replay fingerprint is pinned across the `microstep_tokens` knob:
/// chunking prefill differently changes step counts and latencies, never
/// the per-(sequence, position) outputs or the routing histogram. A
/// different seed changes the fingerprint.
#[test]
fn replay_digest_invariant_to_microstep_chunking() {
    let base = ReplaySpec::small(8, 10, 7);
    let packed = ExpertPlacement::packed(base.num_experts);
    let a = replay(&base, &packed);
    assert_eq!(a.completed, 10);
    assert_eq!(a.generated_tokens, 10 * (1 + base.decode_tokens));
    for chunk in [3usize, 1] {
        let spec = ReplaySpec { microstep_tokens: chunk, ..base.clone() };
        let b = replay(&spec, &packed);
        assert_eq!(a.digest, b.digest, "chunk {chunk} changed the output digest");
        assert_eq!(a.histogram, b.histogram, "chunk {chunk} changed routed traffic");
        assert_eq!(a.generated_tokens, b.generated_tokens);
        assert_eq!(a.completed, b.completed);
        assert!(
            b.steps >= a.steps,
            "finer prefill chunks cannot take fewer rounds: {} vs {}",
            b.steps,
            a.steps
        );
    }
    let c = replay(&ReplaySpec { seed: 8, ..base.clone() }, &packed);
    assert_ne!(a.digest, c.digest, "different seed must change the fingerprint");
}

/// The pinned-Zipf placement win, measured on the fabric's own meter:
/// per-node domain rotation makes each node's hot experts live on the
/// *other* node under the packed layout; the histogram-driven optimizer
/// must move them and strictly cut metered InfiniBand bytes.
#[test]
fn optimized_placement_cuts_measured_ib_on_pinned_zipf_traffic() {
    let (world, e, h, k) = (16, 16, 64, 2);
    let n_per_rank = 64;
    let cluster = ClusterSpec::eos(world);
    let router = Router::new(dropless_cfg(h, e, k), SkewGen::gate_weight(h, e));
    let experts = build_experts(e, h, h, 3);
    let per_rank: Vec<Vec<f32>> = (0..world)
        .map(|r| {
            let mut gen =
                SkewGen::new(SkewProfile::Zipf { exponent: 1.2 }, e, h, 1000 + r as u64);
            let mut toks = gen.next_tokens(n_per_rank);
            let rot = ((cluster.node_of(r) + 1) % 2) * (e / 2);
            rotate_gate_features(&mut toks, e, h, rot);
            toks
        })
        .collect();

    let mut hist = PlacementHistogram::new(2, e);
    for (r, toks) in per_rank.iter().enumerate() {
        hist.record(cluster.node_of(r), &router.route(toks).expert_load);
    }
    let opt = optimize_placement(&hist, &cluster, world, e);
    assert!(!opt.is_identity(), "rotated Zipf traffic must move experts");

    let packed = ExpertPlacement::packed(e);
    let ib_packed = measure_ib_bytes(&router, &experts, &packed, &per_rank);
    let ib_opt = measure_ib_bytes(&router, &experts, &opt, &per_rank);
    assert!(ib_packed > 0.0, "cross-node dispatch must meter IB traffic");
    assert!(
        ib_opt < 0.98 * ib_packed,
        "placement must cut metered IB dispatch bytes: {ib_opt} vs {ib_packed}"
    );
}

/// On exactly-uniform traffic the optimizer is a strict identity: the
/// histogram built from the router's own decisions on a round-robin
/// one-hot stream (top-1) is perfectly flat, so every expert stays on its
/// packed home node.
#[test]
fn optimizer_is_identity_on_exactly_uniform_traffic() {
    let (world, e, h) = (16, 16, 64);
    let n_per_rank = 32;
    let cluster = ClusterSpec::eos(world);
    let router = Router::new(dropless_cfg(h, e, 1), SkewGen::gate_weight(h, e));
    let mut hist = PlacementHistogram::new(2, e);
    for r in 0..world {
        let mut toks = vec![0.0f32; n_per_rank * h];
        for j in 0..n_per_rank {
            toks[j * h + (j % e)] = 4.0;
        }
        let dec = router.route(&toks);
        assert!(
            dec.expert_load.iter().all(|&c| c == n_per_rank / e),
            "round-robin one-hot stream must load experts exactly evenly"
        );
        hist.record(cluster.node_of(r), &dec.expert_load);
    }
    let p = optimize_placement(&hist, &cluster, world, e);
    assert!(p.is_identity(), "uniform traffic moved experts: {:?}", p.slot_to_expert);
}

/// End-to-end: a packed replay's own histogram drives a placement that
/// makes a second, identical replay strictly cheaper on the IB meter —
/// with the same completions and token counts.
#[test]
fn replayed_histogram_drives_placement_that_cuts_replay_ib() {
    let spec = ReplaySpec::small(16, 32, 42);
    let packed = ExpertPlacement::packed(spec.num_experts);
    let base = replay(&spec, &packed);
    assert_eq!(base.completed, 32);
    assert!(base.p50_us > 0.0 && base.p99_us >= base.p50_us);
    assert!(base.tokens_per_sec_per_gpu > 0.0);

    let cluster = ClusterSpec::eos(spec.world);
    let p = optimize_placement(&base.histogram, &cluster, spec.world, spec.num_experts);
    assert!(!p.is_identity(), "domain-rotated replay traffic must move experts");
    let opt = replay(&spec, &p);
    assert_eq!(opt.completed, base.completed);
    assert_eq!(opt.generated_tokens, base.generated_tokens);
    assert!(
        opt.ib_bytes < base.ib_bytes,
        "optimized placement must cut replay IB bytes: {} vs {}",
        opt.ib_bytes,
        base.ib_bytes
    );
}

/// Weekly-tier scale differential: a 128-rank (16-node) replay, one
/// request per rank, still completes, and the histogram-driven placement
/// still cuts the IB meter. Picked up by
/// `cargo test --release -- --ignored`.
#[test]
#[ignore = "128-rank serving replay; runs in the weekly --ignored tier"]
fn large_world_replay_placement_cuts_ib() {
    let spec = ReplaySpec::small(128, 128, 5);
    let packed = ExpertPlacement::packed(spec.num_experts);
    let base = replay(&spec, &packed);
    assert_eq!(base.completed, 128);
    let cluster = ClusterSpec::eos(spec.world);
    let p = optimize_placement(&base.histogram, &cluster, spec.world, spec.num_experts);
    assert!(!p.is_identity());
    let opt = replay(&spec, &p);
    assert_eq!(opt.completed, base.completed);
    assert!(
        opt.ib_bytes < base.ib_bytes,
        "128-rank optimized placement must cut replay IB bytes: {} vs {}",
        opt.ib_bytes,
        base.ib_bytes
    );
}
