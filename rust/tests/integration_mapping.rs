//! Integration: parallel-group generation across realistic configurations —
//! the paper's Table-3 optima, legacy/folded divergence, appendix Listing 1.
use moe_folding::cluster::ClusterSpec;
use moe_folding::config::ParallelConfig;
use moe_folding::mapping::{generate_mappings_listing1, ParallelMapping};

/// All Table-3 optimal configurations must produce valid folded mappings.
#[test]
fn table3_optima_are_valid_mappings() {
    // (world, tp, cp, ep, etp, pp) from Table 3, folding rows.
    let cases = [
        (128, 2, 1, 8, 1, 8),   // Mixtral-8x22B
        (64, 2, 1, 4, 1, 4),    // Qwen2-57B-A14B
        (128, 4, 1, 8, 1, 8),   // Mixtral-8x22B-G8T8
        (256, 8, 1, 8, 1, 16),  // Llama3-8x70B (ETP blank in the table => 1)
    ];
    for (w, tp, cp, ep, etp, pp) in cases {
        let cfg = ParallelConfig::new(w, tp, cp, ep, etp, pp);
        let m = ParallelMapping::folded(cfg)
            .unwrap_or_else(|e| panic!("{cfg:?}: {e}"));
        m.check_invariants().unwrap();
        m.validate_pp_consistency().unwrap();
    }
}

/// Folding keeps the MoE EP group inside a node for every Table-3 optimum
/// with ep <= 8 and etp = 1.
#[test]
fn folded_ep_groups_are_intra_node() {
    for (w, tp, ep, pp) in [(128, 2, 8, 8), (64, 2, 4, 4), (128, 4, 8, 8)] {
        let cfg = ParallelConfig::new(w, tp, 1, ep, 1, pp);
        let m = ParallelMapping::folded(cfg).unwrap();
        let cluster = ClusterSpec::eos(w);
        let rep = m.fold_report(&cluster);
        assert_eq!(rep.ep_nodes, 1, "cfg {} -> {rep:?}", cfg.tag());
    }
}

/// The legacy mapping's EP groups stride over cp*tp: once cp*tp >= 8 they
/// span nodes while the folded equivalent stays NVLink-resident (Figure 6).
#[test]
fn legacy_vs_folded_node_span() {
    let cluster = ClusterSpec::eos(64);
    for (tp, cp) in [(2usize, 4usize), (8, 1), (4, 2)] {
        let legacy = ParallelMapping::legacy(ParallelConfig::new(64, tp, cp, 8, tp, 1)).unwrap();
        let folded = ParallelMapping::folded(ParallelConfig::new(64, tp, cp, 8, 1, 1)).unwrap();
        let l = legacy.fold_report(&cluster);
        let f = folded.fold_report(&cluster);
        assert!(l.ep_nodes > 1, "tp{tp}cp{cp} legacy should span nodes: {l:?}");
        assert_eq!(f.ep_nodes, 1, "tp{tp}cp{cp} folded should fit: {f:?}");
    }
}

/// Listing 1 (appendix) agrees with the production layout on the appendix
/// example where both are defined.
#[test]
fn listing1_appendix_example_consistent() {
    let (a, m) = generate_mappings_listing1(64, 2, 2, 2, 2, 2).unwrap();
    // Every axis partitions the world.
    for set in [&a, &m] {
        for groups in set.groups.values() {
            let mut all: Vec<usize> = groups.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..64).collect::<Vec<_>>());
        }
    }
    // PP partitions agree between attention and MoE (inner blocks match).
    let mut ap = a.groups["PP"].clone();
    let mut mp = m.groups["PP"].clone();
    ap.sort();
    mp.sort();
    assert_eq!(ap, mp);
}

/// Every rank sees a consistent pair of (attention, moe) groups: the EP
/// group of a rank is always inside its PP stage's rank set.
#[test]
fn ep_groups_respect_pipeline_stages() {
    let cfg = ParallelConfig::new(64, 2, 1, 4, 2, 4);
    let m = ParallelMapping::folded(cfg).unwrap();
    for rank in 0..64 {
        let pp_stage_peers: Vec<usize> = (0..64)
            .filter(|&r| {
                m.moe.index_in_group("PP", r)
                    == m.moe.index_in_group("PP", rank)
                    && m.moe.group_of("PP", r) == m.moe.group_of("PP", rank)
            })
            .collect();
        let _ = pp_stage_peers;
        let ep = m.moe.group_of("EP", rank).unwrap();
        // All EP members share the rank's PP coordinate.
        let my_pp_idx = m.moe.index_in_group("PP", rank).unwrap();
        for &peer in ep {
            assert_eq!(m.moe.index_in_group("PP", peer).unwrap(), my_pp_idx);
        }
    }
}
