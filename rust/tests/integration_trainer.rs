//! Integration: the end-to-end trainer over PJRT artifacts (requires
//! `make artifacts`; skips when absent).
use moe_folding::config::ParallelConfig;
use moe_folding::train::{train, TrainerConfig};

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.txt").exists()
}

#[test]
fn loss_decreases_on_test_preset() {
    if !have_artifacts() { return; }
    let cfg = TrainerConfig { preset: "test".into(), steps: 15, ..Default::default() };
    let r = train(&cfg).unwrap();
    assert!(r.final_loss < r.initial_loss, "{} -> {}", r.initial_loss, r.final_loss);
    assert!(r.losses.iter().all(|(_, l)| l.is_finite()));
    assert!(r.num_params > 100_000);
}

#[test]
fn training_is_deterministic() {
    if !have_artifacts() { return; }
    let cfg = TrainerConfig { preset: "test".into(), steps: 5, ..Default::default() };
    let a = train(&cfg).unwrap();
    let b = train(&cfg).unwrap();
    assert_eq!(a.losses, b.losses);
}

#[test]
fn dp2_matches_dp2_and_learns() {
    if !have_artifacts() { return; }
    let cfg = TrainerConfig { preset: "test".into(), steps: 8, dp: 2, ..Default::default() };
    let a = train(&cfg).unwrap();
    let b = train(&cfg).unwrap();
    assert_eq!(a.losses, b.losses, "DP training must be deterministic");
    assert!(a.final_loss < a.initial_loss);
}

/// A degenerate folded topology (tp=cp=ep=pp=1, world = dp) must reproduce
/// the flat-DP trainer bit-for-bit: its DP and EDP groups are both the full
/// world, and data replicas coincide with ranks.
#[test]
fn degenerate_parallel_topology_matches_flat_dp() {
    if !have_artifacts() { return; }
    let flat = TrainerConfig { preset: "test".into(), steps: 6, dp: 2, ..Default::default() };
    let folded = TrainerConfig {
        parallel: Some(ParallelConfig::new(2, 1, 1, 1, 1, 1)),
        ..flat.clone()
    };
    let a = train(&flat).unwrap();
    let b = train(&folded).unwrap();
    assert_eq!(a.losses, b.losses, "degenerate topology must equal flat DP");
}

/// A genuinely folded topology (TP2 attention vs ETP1·EP2 MoE on 4 ranks,
/// dp = edp = 2) trains deterministically with per-class gradient reduction
/// over the topology's DP/EDP groups.
#[test]
fn folded_parallel_trainer_is_deterministic() {
    if !have_artifacts() { return; }
    let cfg = TrainerConfig {
        preset: "test".into(),
        steps: 6,
        parallel: Some(ParallelConfig::new(4, 2, 1, 2, 1, 1)),
        expert_param_indices: vec![1],
        ..Default::default()
    };
    let a = train(&cfg).unwrap();
    let b = train(&cfg).unwrap();
    assert_eq!(a.losses, b.losses);
    assert!(a.losses.iter().all(|(_, l)| l.is_finite()));
}

/// The virtual clock must not perturb training: a clocked run is loss-
/// bitwise-identical to the plain run, while reporting a measured-in-sim
/// step time.
#[test]
fn clocked_trainer_is_bit_identical_and_reports_sim_time() {
    if !have_artifacts() { return; }
    let plain = TrainerConfig { preset: "test".into(), steps: 5, dp: 2, ..Default::default() };
    let clocked = TrainerConfig {
        clocked: true,
        compute_us_per_step: 1234.0,
        ..plain.clone()
    };
    let a = train(&plain).unwrap();
    let b = train(&clocked).unwrap();
    assert_eq!(a.losses, b.losses, "the clock must not perturb payloads");
    assert!(a.sim_step_us.is_none());
    let us = b.sim_step_us.expect("clocked run reports sim step time");
    assert!(us >= 1234.0, "at least the charged compute: {us}");
}

/// Overlapped grad-reduce (nonblocking reduces issued under the backward
/// compute charge) must be loss-bitwise-identical to both the plain and
/// the serialized-clocked trainer, never slower on the virtual clock, and
/// report the measured hidden/exposed comm split.
#[test]
fn overlapped_grad_reduce_is_loss_bitwise_and_never_slower() {
    if !have_artifacts() { return; }
    let plain = TrainerConfig { preset: "test".into(), steps: 5, dp: 2, ..Default::default() };
    let overlapped = TrainerConfig {
        clocked: true,
        compute_us_per_step: 5000.0,
        overlap_grad_reduce: true,
        ..plain.clone()
    };
    let serial = TrainerConfig { overlap_grad_reduce: false, ..overlapped.clone() };
    let a = train(&plain).unwrap();
    let b = train(&overlapped).unwrap();
    let c = train(&serial).unwrap();
    assert_eq!(a.losses, b.losses, "overlap must not perturb payloads");
    assert_eq!(a.losses, c.losses, "the clock must not perturb payloads");
    let t_overlap = b.sim_step_us.unwrap();
    let t_serial = c.sim_step_us.unwrap();
    assert!(
        t_overlap <= t_serial + 1e-6,
        "overlap {t_overlap} µs/step > serialized {t_serial} µs/step"
    );
    assert!(b.sim_hidden_comm_us.unwrap() >= 0.0);
    assert!(c.sim_hidden_comm_us.unwrap() < 1e-3, "serialized path hid comm");
}

#[test]
fn different_seeds_different_curves() {
    if !have_artifacts() { return; }
    let a = train(&TrainerConfig { preset: "test".into(), steps: 4, seed: 1, ..Default::default() }).unwrap();
    let b = train(&TrainerConfig { preset: "test".into(), steps: 4, seed: 2, ..Default::default() }).unwrap();
    assert_ne!(a.losses, b.losses);
}
