//! Integration: the end-to-end trainer over PJRT artifacts (requires
//! `make artifacts`; skips when absent).
use moe_folding::train::{train, TrainerConfig};

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.txt").exists()
}

#[test]
fn loss_decreases_on_test_preset() {
    if !have_artifacts() { return; }
    let cfg = TrainerConfig { preset: "test".into(), steps: 15, ..Default::default() };
    let r = train(&cfg).unwrap();
    assert!(r.final_loss < r.initial_loss, "{} -> {}", r.initial_loss, r.final_loss);
    assert!(r.losses.iter().all(|(_, l)| l.is_finite()));
    assert!(r.num_params > 100_000);
}

#[test]
fn training_is_deterministic() {
    if !have_artifacts() { return; }
    let cfg = TrainerConfig { preset: "test".into(), steps: 5, ..Default::default() };
    let a = train(&cfg).unwrap();
    let b = train(&cfg).unwrap();
    assert_eq!(a.losses, b.losses);
}

#[test]
fn dp2_matches_dp2_and_learns() {
    if !have_artifacts() { return; }
    let cfg = TrainerConfig { preset: "test".into(), steps: 8, dp: 2, ..Default::default() };
    let a = train(&cfg).unwrap();
    let b = train(&cfg).unwrap();
    assert_eq!(a.losses, b.losses, "DP training must be deterministic");
    assert!(a.final_loss < a.initial_loss);
}

#[test]
fn different_seeds_different_curves() {
    if !have_artifacts() { return; }
    let a = train(&TrainerConfig { preset: "test".into(), steps: 4, seed: 1, ..Default::default() }).unwrap();
    let b = train(&TrainerConfig { preset: "test".into(), steps: 4, seed: 2, ..Default::default() }).unwrap();
    assert_ne!(a.losses, b.losses);
}
