//! Integration: the performance model reproduces the *shape* of every
//! headline result in the paper's evaluation (who wins, OOM pattern,
//! crossovers, scaling decay).
use moe_folding::autotune::{tune, tune_all};
use moe_folding::config::{ModelConfig, ParallelConfig, Precision, TrainConfig};
use moe_folding::perfmodel::{PerfModel, Strategy};

fn best_mfu(pm: &PerfModel, m: &ModelConfig, gpus: usize, t: &TrainConfig, s: Strategy) -> Option<f64> {
    tune(pm, m, gpus, t, s).best.map(|e| e.mfu)
}

/// Table 1 strategy ordering holds for every model:
/// FSDP < FSDP+EP and TP+EP+DP < MCore < Folding.
#[test]
fn table1_strategy_ordering() {
    let pm = PerfModel::default();
    let t = TrainConfig::paper_default(4096, 256);
    for (m, gpus) in [
        (ModelConfig::mixtral_8x22b(), 128),
        (ModelConfig::qwen2_57b_a14b(), 64),
        (ModelConfig::mixtral_8x22b_g8t8(), 128),
    ] {
        let fsdp = best_mfu(&pm, &m, gpus, &t, Strategy::Fsdp).unwrap_or(0.0);
        let fsdp_ep = best_mfu(&pm, &m, gpus, &t, Strategy::FsdpEp).unwrap_or(0.0);
        let mcore = best_mfu(&pm, &m, gpus, &t, Strategy::MCore).unwrap_or(0.0);
        let folded = best_mfu(&pm, &m, gpus, &t, Strategy::MCoreFolding).unwrap_or(0.0);
        assert!(fsdp < fsdp_ep, "{}: fsdp {fsdp} !< fsdp_ep {fsdp_ep}", m.name);
        assert!(fsdp_ep < mcore, "{}: fsdp_ep {fsdp_ep} !< mcore {mcore}", m.name);
        assert!(mcore < folded, "{}: mcore {mcore} !< folded {folded}", m.name);
    }
}

/// Table 1 OOM pattern: FSDP and TP+EP+DP cannot fit Llama3-8x70B.
#[test]
fn table1_oom_pattern() {
    let pm = PerfModel::default();
    let t = TrainConfig::paper_default(4096, 256);
    let m = ModelConfig::llama3_8x70b();
    assert!(tune(&pm, &m, 256, &t, Strategy::Fsdp).best.is_none(), "FSDP must OOM");
    assert!(tune(&pm, &m, 256, &t, Strategy::TpEpDp).best.is_none(), "TP+EP+DP must OOM");
    assert!(tune(&pm, &m, 256, &t, Strategy::MCore).best.is_some());
    assert!(tune(&pm, &m, 256, &t, Strategy::MCoreFolding).best.is_some());
}

/// Fine-grained MoE (G8T8) trains far less efficiently than coarse-grained
/// Mixtral under every strategy (paper §4.2's second finding).
#[test]
fn fine_grained_is_slower_everywhere() {
    let pm = PerfModel::default();
    let t = TrainConfig::paper_default(4096, 256);
    let coarse = ModelConfig::mixtral_8x22b();
    let fine = ModelConfig::mixtral_8x22b_g8t8();
    for s in [Strategy::FsdpEp, Strategy::TpEpDp, Strategy::MCore, Strategy::MCoreFolding] {
        let c = best_mfu(&pm, &coarse, 128, &t, s).unwrap_or(0.0);
        let f = best_mfu(&pm, &fine, 128, &t, s).unwrap_or(0.0);
        assert!(f < 0.8 * c, "{}: fine {f:.3} not << coarse {c:.3}", s.name());
    }
}

/// Folding uplift magnitudes are in the paper's ballpark: biggest for the
/// fine-grained model (paper: +11.7 pts), small-but-positive elsewhere.
#[test]
fn folding_uplift_shape() {
    let pm = PerfModel::default();
    let t = TrainConfig::paper_default(4096, 256);
    let uplift = |m: &ModelConfig, gpus| {
        best_mfu(&pm, m, gpus, &t, Strategy::MCoreFolding).unwrap()
            - best_mfu(&pm, m, gpus, &t, Strategy::MCore).unwrap()
    };
    let mixtral = uplift(&ModelConfig::mixtral_8x22b(), 128);
    let g8t8 = uplift(&ModelConfig::mixtral_8x22b_g8t8(), 128);
    assert!(mixtral > 0.0 && mixtral < 0.10, "mixtral uplift {mixtral}");
    assert!(g8t8 > 0.05, "g8t8 uplift {g8t8} should be the largest");
    assert!(g8t8 > mixtral);
}

/// Figure 3 shape: MFU decays mildly as GPUs scale 128 -> 1024 at fixed
/// GBS 1024 (paper Llama3 folded: 43.7 -> 41.5).
#[test]
fn strong_scaling_mild_decay() {
    let pm = PerfModel::default();
    let t = TrainConfig::paper_default(4096, 1024);
    let m = ModelConfig::mixtral_8x22b();
    let small = best_mfu(&pm, &m, 128, &t, Strategy::MCoreFolding).unwrap();
    let large = best_mfu(&pm, &m, 1024, &t, Strategy::MCoreFolding).unwrap();
    assert!(large < small, "MFU should decay with scale");
    assert!(large > 0.6 * small, "decay too steep: {small:.3} -> {large:.3}");
}

/// Figure 4 shape: at 128K context the folded MFU only drops moderately
/// from its 16K value (paper Mixtral: 47.6 -> 42.9, i.e. ~10%).
#[test]
fn context_scaling_moderate_drop() {
    let pm = PerfModel::default();
    let m = ModelConfig::mixtral_8x22b();
    let short = tune(&pm, &m, 128, &TrainConfig::paper_default(16384, 1024), Strategy::MCoreFolding)
        .best.map(|e| e.mfu).unwrap();
    let long = tune(&pm, &m, 1024, &TrainConfig::paper_default(131072, 128), Strategy::MCoreFolding)
        .best.map(|e| e.mfu).unwrap();
    assert!(long > 0.55 * short, "128K {long:.3} vs 16K {short:.3}");
    // And folding beats coupled MCore at long context (the CP-folding win).
    // An infeasible MCore tune is a pass of this claim in itself, not a
    // fake 0.0-MFU baseline (ISSUE 10: infeasible != 0.0).
    if let Some(long_mcore) = tune(
        &pm, &m, 1024, &TrainConfig::paper_default(131072, 128), Strategy::MCore,
    )
    .best
    .map(|e| e.mfu)
    {
        assert!(long >= long_mcore, "folded {long:.3} < mcore {long_mcore:.3}");
    }
}

/// Table 2 shape: FP8 gives 1.15-1.45x over BF16, and folding still helps
/// within FP8.
#[test]
fn fp8_speedup_band() {
    let pm = PerfModel::default();
    let m = ModelConfig::mixtral_8x22b();
    let mut t = TrainConfig::paper_default(4096, 256);
    let bf = tune(&pm, &m, 128, &t, Strategy::MCoreFolding).best.unwrap().tflops_per_gpu;
    t.precision = Precision::Fp8;
    let f8_fold = tune(&pm, &m, 128, &t, Strategy::MCoreFolding).best.unwrap().tflops_per_gpu;
    let f8_mcore = tune(&pm, &m, 128, &t, Strategy::MCore).best.unwrap().tflops_per_gpu;
    let speedup = f8_fold / bf;
    assert!((1.10..1.50).contains(&speedup), "fp8 speedup {speedup:.2}");
    assert!(f8_fold > f8_mcore, "folding must help in FP8 too");
}

/// Figure 5 shape: at EPxETP=16 (inter-node) the fine-grained model's MoE
/// layer is communication-dominated (paper: >70% of latency).
#[test]
fn fig5_comm_dominates_fine_grained_internode() {
    let pm = PerfModel::default();
    let t = TrainConfig::paper_default(4096, 256);
    let m = ModelConfig::mixtral_8x22b_g8t8();
    // EP16 x ETP1 folded: spans 2 nodes.
    let b = pm
        .moe_layer_breakdown(&m, ParallelConfig::new(128, 4, 1, 16, 1, 1), &t, true)
        .unwrap();
    let frac = b.comm() / b.total();
    assert!(frac > 0.5, "comm fraction {frac:.2} (want > 0.5 inter-node)");
    // ETP is far more expensive than EP at the same product (finding 2).
    let b_etp = pm
        .moe_layer_breakdown(&m, ParallelConfig::new(128, 4, 1, 2, 8, 1), &t, true)
        .unwrap();
    assert!(b_etp.comm() > b.comm() * 0.8);
}

/// tune_all returns one result per strategy, in canonical order.
#[test]
fn tune_all_complete() {
    let pm = PerfModel::default();
    let t = TrainConfig::paper_default(4096, 256);
    let rs = tune_all(&pm, &ModelConfig::mixtral_8x22b(), 128, &t);
    assert_eq!(rs.len(), 5);
    assert_eq!(rs[0].strategy, Strategy::Fsdp);
    assert_eq!(rs[4].strategy, Strategy::MCoreFolding);
}
