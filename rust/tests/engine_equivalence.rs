//! Differential suite for the discrete-event executed engine (ISSUE 6):
//! the single-threaded event interpreter must be **bit-identical** to the
//! reference thread-per-rank engine — every `ExecutedEstimate` field and
//! every trace event, compared through `f64::to_bits` — and it must make
//! 1024-rank executed steps cheap enough for tier-1 CI.
//!
//! Why bit-identity is achievable at all: both engines bill the same
//! virtual clock (`simcomm::SimClock`) with the same `CommCost` prices,
//! and the event engine replays the exact leader/peer f32-rounding of the
//! thread engine's clock-sync rendezvous. Any divergence — reordered
//! rendezvous arrivals, a dropped wait, a different latency fold — shows
//! up here as a failed bit comparison, not a tolerance drift.

use moe_folding::config::{ModelConfig, ParallelConfig, TrainConfig};
use moe_folding::perfmodel::{execute_step_traced_on, ExecEngine, PerfModel, Strategy};

/// Run one step on both engines and require bitwise-equal outputs.
fn assert_engines_bit_identical(model: &ModelConfig, cfg: ParallelConfig, train: &TrainConfig) {
    let pm = PerfModel::default();
    let (thr, thr_trace) =
        execute_step_traced_on(ExecEngine::Threads, &pm, model, cfg, train, Strategy::MCoreFolding)
            .unwrap_or_else(|e| panic!("{} threads: {e}", cfg.tag()));
    let (evt, evt_trace) =
        execute_step_traced_on(ExecEngine::Events, &pm, model, cfg, train, Strategy::MCoreFolding)
            .unwrap_or_else(|e| panic!("{} events: {e}", cfg.tag()));

    assert_eq!(thr.config, evt.config);
    assert_eq!(thr.oom, evt.oom);
    let fields = [
        ("step_ms", thr.step_ms, evt.step_ms),
        ("pipeline_ms", thr.pipeline_ms, evt.pipeline_ms),
        ("bubble_fraction", thr.bubble_fraction, evt.bubble_fraction),
        ("hidden_comm_us", thr.hidden_comm_us, evt.hidden_comm_us),
        ("exposed_comm_us", thr.exposed_comm_us, evt.exposed_comm_us),
        ("cp_hidden_us", thr.cp_hidden_us, evt.cp_hidden_us),
        ("cp_exposed_us", thr.cp_exposed_us, evt.cp_exposed_us),
        ("tflops_per_gpu", thr.tflops_per_gpu, evt.tflops_per_gpu),
        ("mfu", thr.mfu, evt.mfu),
    ];
    for (name, a, b) in fields {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{}: {name} differs: threads {a} vs events {b}",
            cfg.tag()
        );
    }

    assert_eq!(
        thr_trace.len(),
        evt_trace.len(),
        "{}: trace lengths differ: threads {} vs events {}",
        cfg.tag(),
        thr_trace.len(),
        evt_trace.len()
    );
    for (i, (a, b)) in thr_trace.iter().zip(&evt_trace).enumerate() {
        assert_eq!(a.rank, b.rank, "{}: trace[{i}] rank", cfg.tag());
        assert_eq!(a.name, b.name, "{}: trace[{i}] name (rank {})", cfg.tag(), a.rank);
        assert_eq!(a.cat, b.cat, "{}: trace[{i}] cat ({})", cfg.tag(), a.name);
        assert_eq!(a.lane, b.lane, "{}: trace[{i}] lane ({})", cfg.tag(), a.name);
        assert_eq!(
            a.ts_us.to_bits(),
            b.ts_us.to_bits(),
            "{}: trace[{i}] ts ({}): threads {} vs events {}",
            cfg.tag(),
            a.name,
            a.ts_us,
            b.ts_us
        );
        assert_eq!(
            a.dur_us.to_bits(),
            b.dur_us.to_bits(),
            "{}: trace[{i}] dur ({}): threads {} vs events {}",
            cfg.tag(),
            a.name,
            a.dur_us,
            b.dur_us
        );
    }
}

/// Thread vs event engine on a Table-3 folded optimum (Qwen2-57B-A14B at
/// 64 ranks, `tp·cp != etp·ep`) with interleaving: every estimate field
/// and every trace span bit-identical.
#[test]
fn engines_bit_identical_on_table3_folded_optimum() {
    let cfg = ParallelConfig::new(64, 2, 1, 4, 1, 4).with_vpp(7);
    assert_ne!(cfg.attn_inner(), cfg.moe_inner(), "must be a folded config");
    assert_engines_bit_identical(
        &ModelConfig::qwen2_57b_a14b(),
        cfg,
        &TrainConfig::paper_default(4096, 256),
    );
}

/// Same differential with context parallelism in the fold (ring-attention
/// chunks on the clock): cp = 2 at 16K sequence exercises the CP
/// hidden/exposed accounting through both engines.
#[test]
fn engines_bit_identical_with_context_parallel_fold() {
    let cfg = ParallelConfig::new(16, 2, 2, 4, 1, 1);
    assert_engines_bit_identical(
        &ModelConfig::mixtral_8x22b(),
        cfg,
        &TrainConfig::paper_default(16384, 64),
    );
}

/// The ISSUE 6 acceptance differential: a 1024-rank folded step
/// (Mixtral-8x22B scaled out, interleaved vpp = 7) runs on both engines in
/// tier-1 and stays bit-identical. This is the world size the thread
/// engine relegated to weekly CI; the event engine runs it single-threaded.
#[test]
fn engines_bit_identical_at_1024_ranks() {
    let cfg = ParallelConfig::new(1024, 2, 1, 8, 1, 8).with_vpp(7);
    assert_ne!(cfg.attn_inner(), cfg.moe_inner(), "must be a folded config");
    assert_engines_bit_identical(
        &ModelConfig::mixtral_8x22b(),
        cfg,
        &TrainConfig::paper_default(4096, 1024),
    );
}

/// 1024-rank smoke on the default (event) engine alone: the executed step
/// agrees with the analytic estimate within 5% — same tolerance as the
/// large-world sweep in `tests/schedule_equivalence.rs` — and comm overlap
/// is actually measured.
#[test]
fn event_engine_1024_rank_step_agrees_with_analytic() {
    let pm = PerfModel::default();
    let model = ModelConfig::mixtral_8x22b();
    let mut train = TrainConfig::paper_default(4096, 1024);
    train.overlap_a2a = true;
    let cfg = ParallelConfig::new(1024, 2, 1, 8, 1, 8).with_vpp(7);
    let (executed, trace) =
        execute_step_traced_on(ExecEngine::Events, &pm, &model, cfg, &train, Strategy::MCoreFolding)
            .unwrap_or_else(|e| panic!("{}: {e}", cfg.tag()));
    let analytic = pm.estimate(&model, cfg, &train, Strategy::MCoreFolding).unwrap();
    let rel = (executed.step_ms - analytic.step_ms).abs() / analytic.step_ms;
    assert!(
        rel < 0.05,
        "{}: executed {:.1} ms vs analytic {:.1} ms (rel {rel:.4})",
        cfg.tag(),
        executed.step_ms,
        analytic.step_ms
    );
    assert!(executed.hidden_comm_us > 0.0, "overlap must be measured");
    // Every one of the 1024 ranks contributed spans to the trace.
    let mut seen = vec![false; 1024];
    for e in &trace {
        seen[e.rank] = true;
    }
    assert!(seen.iter().all(|&s| s), "every rank must appear in the trace");
}

/// Placement is a priced axis (ISSUE 7): at 1024 ranks the packed EP
/// groups sit whole inside NVLink domains while the strided twin's EP
/// peers sit `edp·etp = 16` ranks apart — every dispatch a2a crosses IB —
/// so the two executed step times must differ, packed strictly faster.
#[test]
fn executed_step_prices_ep_placement_at_1024_ranks() {
    use moe_folding::config::EpPlacement;
    use moe_folding::perfmodel::execute_step;

    let pm = PerfModel::default();
    let model = ModelConfig::mixtral_8x22b();
    let train = TrainConfig::paper_default(4096, 1024);
    let packed_cfg = ParallelConfig::new(1024, 2, 1, 8, 1, 8).with_vpp(7);
    let strided_cfg = packed_cfg.with_placement(EpPlacement::Strided);
    let packed = execute_step(&pm, &model, packed_cfg, &train, Strategy::MCoreFolding)
        .unwrap_or_else(|e| panic!("{}: {e}", packed_cfg.tag()));
    let strided = execute_step(&pm, &model, strided_cfg, &train, Strategy::MCoreFolding)
        .unwrap_or_else(|e| panic!("{}: {e}", strided_cfg.tag()));
    assert!(
        packed.step_ms < strided.step_ms,
        "packed EP must beat strided across nodes: {:.2} ms vs {:.2} ms",
        packed.step_ms,
        strided.step_ms
    );
}

/// Weekly stress tier (ISSUE 7): a 4096-rank folded step, events engine
/// only — thread-per-rank would need 4096 OS threads, the event
/// interpreter needs one. Same 5% analytic agreement and full-trace
/// coverage as the 1024-rank tier-1 smoke. `cargo test --release -- --ignored`.
#[test]
#[ignore = "weekly stress tier: 4096-rank world"]
fn event_engine_4096_rank_step_agrees_with_analytic() {
    let pm = PerfModel::default();
    let model = ModelConfig::mixtral_8x22b();
    let mut train = TrainConfig::paper_default(4096, 4096);
    train.overlap_a2a = true;
    let cfg = ParallelConfig::new(4096, 2, 1, 8, 1, 8).with_vpp(7);
    let (executed, trace) =
        execute_step_traced_on(ExecEngine::Events, &pm, &model, cfg, &train, Strategy::MCoreFolding)
            .unwrap_or_else(|e| panic!("{}: {e}", cfg.tag()));
    let analytic = pm.estimate(&model, cfg, &train, Strategy::MCoreFolding).unwrap();
    let rel = (executed.step_ms - analytic.step_ms).abs() / analytic.step_ms;
    assert!(
        rel < 0.05,
        "{}: executed {:.1} ms vs analytic {:.1} ms (rel {rel:.4})",
        cfg.tag(),
        executed.step_ms,
        analytic.step_ms
    );
    assert!(executed.hidden_comm_us > 0.0, "overlap must be measured");
    let mut seen = vec![false; 4096];
    for e in &trace {
        seen[e.rank] = true;
    }
    assert!(seen.iter().all(|&s| s), "every rank must appear in the trace");
}
