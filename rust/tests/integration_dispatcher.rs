//! Integration: the distributed token dispatcher against the single-rank
//! reference across the full (EP, ETP, drop-policy) matrix, plus stats and
//! conservation invariants. (Unit-level equivalence lives in the module
//! tests; these runs use larger shapes and all policies.)
use moe_folding::config::DropPolicy;
use moe_folding::dispatcher::{
    reference_moe_forward, Balancer, DistributedMoeLayer, Router, RouterConfig,
};
use moe_folding::simcomm::{run_ranks, Payload};
use moe_folding::train::math::SwigluExpert;
use moe_folding::util::Rng;

const H: usize = 32;
const F: usize = 64;
const E: usize = 8;

fn setup(top_k: usize, policy: DropPolicy, cf: f64) -> (Router, Vec<SwigluExpert>) {
    let mut rng = Rng::seed_from_u64(77);
    let router = Router::init(
        RouterConfig {
            hidden: H,
            num_experts: E,
            top_k,
            capacity_factor: cf,
            drop_policy: policy,
            capacity_override: None,
            pad_to_capacity: false,
            node_limit: None,
            balancer: Balancer::AuxLoss,
        },
        &mut rng,
    );
    let experts = (0..E).map(|_| SwigluExpert::init(H, F, &mut rng)).collect();
    (router, experts)
}

fn run_matrix(ep: usize, etp: usize, top_k: usize, policy: DropPolicy, cf: f64) {
    let world = ep * etp;
    let n_per_rank = 48;
    let (router, experts) = setup(top_k, policy, cf);
    let mut rng = Rng::seed_from_u64(99);
    let mut tokens = vec![0.0f32; world * n_per_rank * H];
    rng.fill_normal(&mut tokens, 1.0);

    let outs = run_ranks(world, |rank, comm| {
        let ep_idx = rank / etp;
        let etp_idx = rank % etp;
        let epr = E / ep;
        let layer = DistributedMoeLayer {
            router: router.clone(),
            local_experts: (0..epr)
                .map(|le| {
                    let g = ep_idx * epr + le;
                    if etp > 1 { experts[g].shard(etp, etp_idx) } else { experts[g].clone() }
                })
                .collect(),
            ep_group: (0..ep).map(|i| i * etp + etp_idx).collect(),
            etp_group: (0..etp).map(|i| ep_idx * etp + i).collect(),
            ep_index: ep_idx,
            num_experts: E,
            seq_group: None,
            phase_cost: None,
            overlap_a2a: false,
            payload: Payload::F32,
        };
        let mine = tokens[rank * n_per_rank * H..(rank + 1) * n_per_rank * H].to_vec();
        layer.forward(&comm, &mine)
    });

    let reference = reference_moe_forward(&router, &experts, &tokens, Some(n_per_rank));
    let distributed: Vec<f32> = outs.iter().flat_map(|(o, _)| o.clone()).collect();
    for (i, (a, b)) in distributed.iter().zip(&reference).enumerate() {
        assert!(
            (a - b).abs() < 3e-4 * (1.0 + b.abs()),
            "ep{ep} etp{etp} k{top_k} {policy:?} cf{cf}: idx {i}: {a} vs {b}"
        );
    }
    // Conservation: per-rank routed+dropped == n*k.
    for (_, s) in &outs {
        assert_eq!(s.tokens_routed + s.tokens_dropped, n_per_rank * top_k);
    }
}

#[test]
fn matrix_dropless() {
    for (ep, etp) in [(2, 1), (4, 1), (8, 1), (2, 2), (4, 2), (2, 4)] {
        run_matrix(ep, etp, 2, DropPolicy::Dropless, 1.0);
    }
}

#[test]
fn matrix_subsequence_drop_cf1() {
    for (ep, etp) in [(2, 1), (4, 2), (8, 1)] {
        run_matrix(ep, etp, 2, DropPolicy::SubSequence, 1.0);
    }
}

#[test]
fn matrix_subsequence_drop_higher_cf() {
    run_matrix(4, 1, 2, DropPolicy::SubSequence, 2.0);
}

#[test]
fn matrix_topk_variants() {
    run_matrix(4, 1, 1, DropPolicy::Dropless, 1.0);
    run_matrix(4, 1, 4, DropPolicy::Dropless, 1.0);
    run_matrix(8, 1, 8, DropPolicy::Dropless, 1.0);
}

/// Sub-sequence drop drops *more or equal* tokens than full-sequence drop in
/// aggregate never holds in general, but both respect the capacity bound.
#[test]
fn capacity_bound_respected_in_both_scopes() {
    let n_per_rank = 64;
    for policy in [DropPolicy::SubSequence, DropPolicy::FullSequence] {
        let (router, experts) = setup(2, policy, 1.0);
        let mut rng = Rng::seed_from_u64(5);
        let mut tokens = vec![0.0f32; 2 * n_per_rank * H];
        rng.fill_normal(&mut tokens, 1.0);
        let outs = run_ranks(2, |rank, comm| {
            let layer = DistributedMoeLayer {
                router: router.clone(),
                local_experts: experts[rank * 4..(rank + 1) * 4].to_vec(),
                ep_group: vec![0, 1],
                etp_group: vec![rank],
                ep_index: rank,
                num_experts: E,
                seq_group: Some(vec![0, 1]),
                phase_cost: None,
                overlap_a2a: false,
            payload: Payload::F32,
            };
            let mine = tokens[rank * n_per_rank * H..(rank + 1) * n_per_rank * H].to_vec();
            layer.forward(&comm, &mine).1
        });
        let total_routed: usize = outs.iter().map(|s| s.tokens_routed).sum();
        // Global capacity = CF * total_tokens * k = 256 copies.
        assert!(total_routed <= 2 * n_per_rank * 2, "{policy:?}: {total_routed}");
    }
}
