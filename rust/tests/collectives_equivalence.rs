//! Differential suite: every collective algorithm vs the `NaiveLeader`
//! oracle, **bit-for-bit**. The engine's documented invariant is that every
//! algorithm reduces in ascending group-index order, so outputs must match
//! the oracle exactly — not within a tolerance. Inputs are seeded via
//! `util::rng` with per-rank magnitude skew (1e-2 … 1e2) so that any
//! reordering of f32 additions would change the bits and fail loudly.
use moe_folding::cluster::{ClusterSpec, LinkKind};
use moe_folding::collectives::CommCost;
use moe_folding::simcomm::{
    run_ranks_on, run_ranks_with, AlgoSelection, CollectiveAlgo, Communicator, Fabric,
};
use moe_folding::util::Rng;

/// Group sizes exercised everywhere: singleton, pair, odd (recursive
/// halving must fall back), small power of two, larger power of two.
const SIZES: [usize; 5] = [1, 2, 3, 4, 8];

fn assert_bits_eq(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: idx {i}: {x} vs {y}");
    }
}

/// Run the same per-rank program under the oracle and under `algos`;
/// returns both outputs in rank order. Inputs must be derived
/// deterministically from `rank` inside `f` so both runs see identical
/// data.
fn differential<T, F>(world: usize, algos: AlgoSelection, f: F) -> (Vec<T>, Vec<T>)
where
    T: Send,
    F: Fn(usize, &Communicator) -> T + Sync,
{
    let naive = run_ranks_with(world, AlgoSelection::naive(), |r, c| f(r, &c));
    let fast = run_ranks_with(world, algos, |r, c| f(r, &c));
    (naive, fast)
}

/// Per-rank data with deliberately skewed magnitudes: rank r draws from
/// N(0, 10^(r mod 5 − 2)), so summation order is observable in the bits.
fn skewed(rank: usize, seed: u64, len: usize) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(seed.wrapping_add(rank as u64 * 7919));
    let mut v = vec![0.0f32; len];
    rng.fill_normal(&mut v, 10.0f32.powi((rank % 5) as i32 - 2));
    v
}

#[test]
fn all_reduce_matches_oracle_bitwise() {
    for &n in &SIZES {
        for len in [1usize, 5, 64, 257] {
            let group: Vec<usize> = (0..n).collect();
            let (naive, fast) = differential(n, AlgoSelection::fast(), |rank, comm| {
                let local = skewed(rank, 11, len);
                comm.all_reduce_sum(&group, &local)
            });
            for (a, b) in naive.iter().zip(&fast) {
                assert_bits_eq(a, b, &format!("allreduce n={n} len={len}"));
            }
        }
    }
}

#[test]
fn all_gather_v_matches_oracle_bitwise() {
    for &n in &SIZES {
        let group: Vec<usize> = (0..n).collect();
        let (naive, fast) = differential(n, AlgoSelection::fast(), |rank, comm| {
            // Variable lengths, including an empty contribution at rank 2.
            let len = if rank == 2 { 0 } else { 17 * (rank + 1) };
            let local = skewed(rank, 23, len);
            comm.all_gather_v(&group, &local)
        });
        for (a, b) in naive.iter().zip(&fast) {
            assert_bits_eq(a, b, &format!("allgatherv n={n}"));
        }
    }
}

#[test]
fn reduce_scatter_matches_oracle_bitwise() {
    // Fast suite (recursive halving on powers of two, pairwise otherwise)
    // and explicitly-forced pairwise both against the oracle.
    let pairwise = AlgoSelection {
        reduce_scatter: CollectiveAlgo::PairwiseExchange,
        ..AlgoSelection::fast()
    };
    for algos in [AlgoSelection::fast(), pairwise] {
        for &n in &SIZES {
            let group: Vec<usize> = (0..n).collect();
            let (naive, fast) = differential(n, algos, |rank, comm| {
                let local = skewed(rank, 37, n * 29);
                comm.reduce_scatter_sum(&group, &local)
            });
            for (me, (a, b)) in naive.iter().zip(&fast).enumerate() {
                assert_bits_eq(a, b, &format!("reducescatter n={n} rank={me}"));
            }
        }
    }
}

#[test]
fn reduce_scatter_v_matches_oracle_bitwise() {
    for &n in &SIZES {
        let group: Vec<usize> = (0..n).collect();
        // Uneven segments, one of them empty when the group is big enough.
        let counts: Vec<usize> = (0..n).map(|i| if i == 1 { 0 } else { 3 * i + 2 }).collect();
        let total: usize = counts.iter().sum();
        let (naive, fast) = differential(n, AlgoSelection::fast(), |rank, comm| {
            let local = skewed(rank, 41, total);
            comm.reduce_scatter_v(&group, &local, &counts)
        });
        for (me, (a, b)) in naive.iter().zip(&fast).enumerate() {
            assert_eq!(a.len(), counts[me], "rsv n={n} rank={me} segment length");
            assert_bits_eq(a, b, &format!("rsv n={n} rank={me}"));
        }
    }
}

#[test]
fn all_to_all_v_matches_oracle_bitwise() {
    for &n in &SIZES {
        let group: Vec<usize> = (0..n).collect();
        let (naive, fast) = differential(n, AlgoSelection::fast(), |rank, comm| {
            // Uneven splits: length depends on (src, dst), with empties.
            let mut rng = Rng::seed_from_u64(5000 + rank as u64);
            let sends: Vec<Vec<f32>> = (0..n)
                .map(|dst| {
                    let len = (rank * 3 + dst * 5) % 7; // 0..6, some empty
                    let mut v = vec![0.0f32; len];
                    rng.fill_normal(&mut v, 1.0);
                    v
                })
                .collect();
            comm.all_to_all_v(&group, sends)
        });
        for (me, (a, b)) in naive.iter().zip(&fast).enumerate() {
            assert_eq!(a.len(), n);
            for (src, (x, y)) in a.iter().zip(b).enumerate() {
                assert_bits_eq(x, y, &format!("a2av n={n} rank={me} from={src}"));
            }
        }
    }
}

#[test]
fn broadcast_matches_oracle_bitwise() {
    for &n in &SIZES {
        let group: Vec<usize> = (0..n).collect();
        let root = group[n / 2];
        let (naive, fast) = differential(n, AlgoSelection::fast(), |rank, comm| {
            let payload = skewed(root, 53, 201); // every rank derives the same
            if rank == root {
                comm.broadcast(&group, root, &payload)
            } else {
                comm.broadcast(&group, root, &[])
            }
        });
        for (a, b) in naive.iter().zip(&fast) {
            assert_bits_eq(a, b, &format!("broadcast n={n}"));
        }
    }
}

/// Non-contiguous, interleaved groups (a folded EP layout): evens and odds
/// of an 8-rank world run independent collectives concurrently; both
/// suites must match the oracle bitwise.
#[test]
fn non_contiguous_groups_match_oracle_bitwise() {
    let (naive, fast) = differential(8, AlgoSelection::fast(), |rank, comm| {
        let group: Vec<usize> = if rank % 2 == 0 {
            vec![0, 2, 4, 6]
        } else {
            vec![1, 3, 5, 7]
        };
        let local = skewed(rank, 67, 4 * 31);
        let summed = comm.all_reduce_sum(&group, &local);
        let shard = comm.reduce_scatter_sum(&group, &local);
        let sends: Vec<Vec<f32>> = (0..4).map(|i| skewed(rank, 71 + i as u64, i + 1)).collect();
        let exchanged = comm.all_to_all_v(&group, sends);
        (summed, shard, exchanged)
    });
    for (me, (a, b)) in naive.iter().zip(&fast).enumerate() {
        assert_bits_eq(&a.0, &b.0, &format!("nc allreduce rank={me}"));
        assert_bits_eq(&a.1, &b.1, &format!("nc reducescatter rank={me}"));
        for (src, (x, y)) in a.2.iter().zip(&b.2).enumerate() {
            assert_bits_eq(x, y, &format!("nc a2av rank={me} from={src}"));
        }
    }
}

/// Hierarchical algorithms on awkward node shapes (ISSUE 7): full
/// two-node world (16 = 2×8), partial last node (12 = 8+4), and a
/// non-power-of-two node count (24 = 3×8) — every collective must stay
/// bit-identical to the oracle despite the intra-node / inter-node phase
/// split, because the leader chain folds in ascending group-index order.
#[test]
fn hierarchical_matches_oracle_on_awkward_worlds() {
    for world in [12usize, 16, 24] {
        let group: Vec<usize> = (0..world).collect();
        let counts: Vec<usize> = (0..world).map(|i| if i == 1 { 0 } else { 2 * i + 1 }).collect();
        let total: usize = counts.iter().sum();
        let root = group[world / 2];
        let (naive, hier) = differential(world, AlgoSelection::hierarchical(), |rank, comm| {
            let local = skewed(rank, 97, 4 * world);
            let ar = comm.all_reduce_sum(&group, &local);
            let rs = comm.reduce_scatter_sum(&group, &local);
            let wide = skewed(rank, 101, total);
            let rsv = comm.reduce_scatter_v(&group, &wide, &counts);
            let ag = comm.all_gather_v(&group, &skewed(rank, 103, (rank % 5) * 3));
            let bc = if rank == root {
                comm.broadcast(&group, root, &skewed(root, 107, 33))
            } else {
                comm.broadcast(&group, root, &[])
            };
            let sends: Vec<Vec<f32>> = (0..world)
                .map(|dst| skewed(rank, 109 + dst as u64, (rank * 5 + dst * 3) % 6))
                .collect();
            let a2a = comm.all_to_all_v(&group, sends);
            (ar, rs, rsv, ag, bc, a2a)
        });
        for (me, (a, b)) in naive.iter().zip(&hier).enumerate() {
            let ctx = format!("hier world={world} rank={me}");
            assert_bits_eq(&a.0, &b.0, &format!("{ctx} allreduce"));
            assert_bits_eq(&a.1, &b.1, &format!("{ctx} reducescatter"));
            assert_bits_eq(&a.2, &b.2, &format!("{ctx} rsv"));
            assert_bits_eq(&a.3, &b.3, &format!("{ctx} allgatherv"));
            assert_bits_eq(&a.4, &b.4, &format!("{ctx} broadcast"));
            for (src, (x, y)) in a.5.iter().zip(&b.5).enumerate() {
                assert_bits_eq(x, y, &format!("{ctx} a2av from={src}"));
            }
        }
    }
}

/// The hierarchical suite on the small single-node worlds of `SIZES`: the
/// node-grouped algorithms must degrade cleanly to a single intra-node run
/// (and a singleton group to a no-op), still bit-identical to the oracle.
#[test]
fn hierarchical_matches_oracle_on_single_node_worlds() {
    for &n in &SIZES {
        let group: Vec<usize> = (0..n).collect();
        let (naive, hier) = differential(n, AlgoSelection::hierarchical(), |rank, comm| {
            let local = skewed(rank, 113, n * 13);
            let ar = comm.all_reduce_sum(&group, &local);
            let ag = comm.all_gather_v(&group, &skewed(rank, 127, 5 * rank));
            let sends: Vec<Vec<f32>> =
                (0..n).map(|dst| skewed(rank, 131 + dst as u64, (rank + 2 * dst) % 5)).collect();
            let a2a = comm.all_to_all_v(&group, sends);
            (ar, ag, a2a)
        });
        for (me, (a, b)) in naive.iter().zip(&hier).enumerate() {
            assert_bits_eq(&a.0, &b.0, &format!("hier1 n={n} rank={me} allreduce"));
            assert_bits_eq(&a.1, &b.1, &format!("hier1 n={n} rank={me} allgatherv"));
            for (src, (x, y)) in a.2.iter().zip(&b.2).enumerate() {
                assert_bits_eq(x, y, &format!("hier1 n={n} rank={me} a2av from={src}"));
            }
        }
    }
}

/// Non-contiguous groups that straddle a node boundary: evens and odds of
/// a 16-rank (two-node) world run concurrent hierarchical collectives —
/// each group folds into two node runs of four — bit-identical to the
/// oracle.
#[test]
fn hierarchical_non_contiguous_groups_across_nodes() {
    let (naive, hier) = differential(16, AlgoSelection::hierarchical(), |rank, comm| {
        let group: Vec<usize> = ((rank % 2)..16).step_by(2).collect();
        let local = skewed(rank, 137, 8 * 9);
        let summed = comm.all_reduce_sum(&group, &local);
        let shard = comm.reduce_scatter_sum(&group, &local);
        let sends: Vec<Vec<f32>> = (0..8).map(|i| skewed(rank, 139 + i as u64, i + 1)).collect();
        let exchanged = comm.all_to_all_v(&group, sends);
        (summed, shard, exchanged)
    });
    for (me, (a, b)) in naive.iter().zip(&hier).enumerate() {
        assert_bits_eq(&a.0, &b.0, &format!("ncx allreduce rank={me}"));
        assert_bits_eq(&a.1, &b.1, &format!("ncx reducescatter rank={me}"));
        for (src, (x, y)) in a.2.iter().zip(&b.2).enumerate() {
            assert_bits_eq(x, y, &format!("ncx a2av rank={me} from={src}"));
        }
    }
}

/// Hierarchical collectives on a *clocked* partial-last-node fabric
/// (eos(12) = one full node of eight + one node of four): per-phase
/// billing by link class must never touch payload math — outputs stay
/// bit-identical to the unclocked oracle, and the run demonstrably
/// crossed InfiniBand.
#[test]
fn clocked_hierarchical_partial_node_is_bit_exact() {
    let world = 12usize;
    let group: Vec<usize> = (0..world).collect();
    let program = |rank: usize, comm: &Communicator| {
        let local = skewed(rank, 149, 3 * world);
        let ar = comm.all_reduce_sum(&group, &local);
        let ag = comm.all_gather_v(&group, &skewed(rank, 151, rank % 4));
        let sends: Vec<Vec<f32>> =
            (0..world).map(|dst| skewed(rank, 157 + dst as u64, (rank + dst) % 4)).collect();
        let a2a = comm.all_to_all_v(&group, sends);
        (ar, ag, a2a)
    };
    let naive = run_ranks_with(world, AlgoSelection::naive(), |r, c| program(r, &c));
    let clocked = Fabric::new_clocked(
        world,
        AlgoSelection::hierarchical(),
        CommCost::new(ClusterSpec::eos(world)),
    );
    let hier = run_ranks_on(&clocked, |r, c| program(r, &c));
    for (me, (a, b)) in naive.iter().zip(&hier).enumerate() {
        assert_bits_eq(&a.0, &b.0, &format!("clocked hier allreduce rank={me}"));
        assert_bits_eq(&a.1, &b.1, &format!("clocked hier allgatherv rank={me}"));
        for (src, (x, y)) in a.2.iter().zip(&b.2).enumerate() {
            assert_bits_eq(x, y, &format!("clocked hier a2av rank={me} from={src}"));
        }
    }
    assert!(
        clocked.link_traffic(LinkKind::InfiniBand).messages > 0,
        "a 12-rank world spans two nodes, so the leader chain must cross IB"
    );
}

/// The two-level a2a crosses IB once per ordered node pair instead of once
/// per cross-node rank pair: on a 16-rank / two-node world with every
/// split non-empty it posts exactly two InfiniBand messages (one
/// mega-bundle each way) where the flat exchange posts one per crossing
/// (src, dst) pair — while staying bit-identical to it.
#[test]
fn two_level_a2a_sends_fewer_ib_messages() {
    let world = 16usize;
    let group: Vec<usize> = (0..world).collect();
    let program = |rank: usize, comm: &Communicator| {
        let sends: Vec<Vec<f32>> =
            (0..world).map(|dst| skewed(rank, 163 + dst as u64, dst + 1)).collect();
        comm.all_to_all_v(&group, sends)
    };
    let flat = Fabric::new_with(world, AlgoSelection::fast());
    let flat_out = run_ranks_on(&flat, |r, c| program(r, &c));
    let hier = Fabric::new_with(world, AlgoSelection::hierarchical());
    let hier_out = run_ranks_on(&hier, |r, c| program(r, &c));
    for (me, (a, b)) in flat_out.iter().zip(&hier_out).enumerate() {
        for (src, (x, y)) in a.iter().zip(b).enumerate() {
            assert_bits_eq(x, y, &format!("two-level a2a rank={me} from={src}"));
        }
    }
    let flat_ib = flat.link_traffic(LinkKind::InfiniBand).messages;
    let hier_ib = hier.link_traffic(LinkKind::InfiniBand).messages;
    assert!(
        hier_ib < flat_ib,
        "two-level a2a must cross IB less often: {hier_ib} vs flat {flat_ib}"
    );
    assert_eq!(hier_ib, 2, "one mega-bundle per ordered node pair");
}

/// Catastrophic-cancellation stress: ranks contribute alternating ±1e8
/// plus small residues; only the oracle's exact fold order reproduces the
/// result, so this pins the rank-order invariant hard.
#[test]
fn cancellation_stress_is_bit_exact() {
    let n = 8;
    let group: Vec<usize> = (0..n).collect();
    let (naive, fast) = differential(n, AlgoSelection::fast(), |rank, comm| {
        let sign = if rank % 2 == 0 { 1.0f32 } else { -1.0 };
        let mut local = skewed(rank, 83, 512);
        for (i, v) in local.iter_mut().enumerate() {
            *v += sign * 1e8 + (i % 3) as f32;
        }
        let ar = comm.all_reduce_sum(&group, &local);
        let rs = comm.reduce_scatter_sum(&group, &local);
        (ar, rs)
    });
    for (me, (a, b)) in naive.iter().zip(&fast).enumerate() {
        assert_bits_eq(&a.0, &b.0, &format!("cancel allreduce rank={me}"));
        assert_bits_eq(&a.1, &b.1, &format!("cancel reducescatter rank={me}"));
    }
}
