//! Differential suite: every collective algorithm vs the `NaiveLeader`
//! oracle, **bit-for-bit**. The engine's documented invariant is that every
//! algorithm reduces in ascending group-index order, so outputs must match
//! the oracle exactly — not within a tolerance. Inputs are seeded via
//! `util::rng` with per-rank magnitude skew (1e-2 … 1e2) so that any
//! reordering of f32 additions would change the bits and fail loudly.
use moe_folding::simcomm::{run_ranks_with, AlgoSelection, CollectiveAlgo, Communicator};
use moe_folding::util::Rng;

/// Group sizes exercised everywhere: singleton, pair, odd (recursive
/// halving must fall back), small power of two, larger power of two.
const SIZES: [usize; 5] = [1, 2, 3, 4, 8];

fn assert_bits_eq(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: idx {i}: {x} vs {y}");
    }
}

/// Run the same per-rank program under the oracle and under `algos`;
/// returns both outputs in rank order. Inputs must be derived
/// deterministically from `rank` inside `f` so both runs see identical
/// data.
fn differential<T, F>(world: usize, algos: AlgoSelection, f: F) -> (Vec<T>, Vec<T>)
where
    T: Send,
    F: Fn(usize, &Communicator) -> T + Sync,
{
    let naive = run_ranks_with(world, AlgoSelection::naive(), |r, c| f(r, &c));
    let fast = run_ranks_with(world, algos, |r, c| f(r, &c));
    (naive, fast)
}

/// Per-rank data with deliberately skewed magnitudes: rank r draws from
/// N(0, 10^(r mod 5 − 2)), so summation order is observable in the bits.
fn skewed(rank: usize, seed: u64, len: usize) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(seed.wrapping_add(rank as u64 * 7919));
    let mut v = vec![0.0f32; len];
    rng.fill_normal(&mut v, 10.0f32.powi((rank % 5) as i32 - 2));
    v
}

#[test]
fn all_reduce_matches_oracle_bitwise() {
    for &n in &SIZES {
        for len in [1usize, 5, 64, 257] {
            let group: Vec<usize> = (0..n).collect();
            let (naive, fast) = differential(n, AlgoSelection::fast(), |rank, comm| {
                let local = skewed(rank, 11, len);
                comm.all_reduce_sum(&group, &local)
            });
            for (a, b) in naive.iter().zip(&fast) {
                assert_bits_eq(a, b, &format!("allreduce n={n} len={len}"));
            }
        }
    }
}

#[test]
fn all_gather_v_matches_oracle_bitwise() {
    for &n in &SIZES {
        let group: Vec<usize> = (0..n).collect();
        let (naive, fast) = differential(n, AlgoSelection::fast(), |rank, comm| {
            // Variable lengths, including an empty contribution at rank 2.
            let len = if rank == 2 { 0 } else { 17 * (rank + 1) };
            let local = skewed(rank, 23, len);
            comm.all_gather_v(&group, &local)
        });
        for (a, b) in naive.iter().zip(&fast) {
            assert_bits_eq(a, b, &format!("allgatherv n={n}"));
        }
    }
}

#[test]
fn reduce_scatter_matches_oracle_bitwise() {
    // Fast suite (recursive halving on powers of two, pairwise otherwise)
    // and explicitly-forced pairwise both against the oracle.
    let pairwise = AlgoSelection {
        reduce_scatter: CollectiveAlgo::PairwiseExchange,
        ..AlgoSelection::fast()
    };
    for algos in [AlgoSelection::fast(), pairwise] {
        for &n in &SIZES {
            let group: Vec<usize> = (0..n).collect();
            let (naive, fast) = differential(n, algos, |rank, comm| {
                let local = skewed(rank, 37, n * 29);
                comm.reduce_scatter_sum(&group, &local)
            });
            for (me, (a, b)) in naive.iter().zip(&fast).enumerate() {
                assert_bits_eq(a, b, &format!("reducescatter n={n} rank={me}"));
            }
        }
    }
}

#[test]
fn reduce_scatter_v_matches_oracle_bitwise() {
    for &n in &SIZES {
        let group: Vec<usize> = (0..n).collect();
        // Uneven segments, one of them empty when the group is big enough.
        let counts: Vec<usize> = (0..n).map(|i| if i == 1 { 0 } else { 3 * i + 2 }).collect();
        let total: usize = counts.iter().sum();
        let (naive, fast) = differential(n, AlgoSelection::fast(), |rank, comm| {
            let local = skewed(rank, 41, total);
            comm.reduce_scatter_v(&group, &local, &counts)
        });
        for (me, (a, b)) in naive.iter().zip(&fast).enumerate() {
            assert_eq!(a.len(), counts[me], "rsv n={n} rank={me} segment length");
            assert_bits_eq(a, b, &format!("rsv n={n} rank={me}"));
        }
    }
}

#[test]
fn all_to_all_v_matches_oracle_bitwise() {
    for &n in &SIZES {
        let group: Vec<usize> = (0..n).collect();
        let (naive, fast) = differential(n, AlgoSelection::fast(), |rank, comm| {
            // Uneven splits: length depends on (src, dst), with empties.
            let mut rng = Rng::seed_from_u64(5000 + rank as u64);
            let sends: Vec<Vec<f32>> = (0..n)
                .map(|dst| {
                    let len = (rank * 3 + dst * 5) % 7; // 0..6, some empty
                    let mut v = vec![0.0f32; len];
                    rng.fill_normal(&mut v, 1.0);
                    v
                })
                .collect();
            comm.all_to_all_v(&group, sends)
        });
        for (me, (a, b)) in naive.iter().zip(&fast).enumerate() {
            assert_eq!(a.len(), n);
            for (src, (x, y)) in a.iter().zip(b).enumerate() {
                assert_bits_eq(x, y, &format!("a2av n={n} rank={me} from={src}"));
            }
        }
    }
}

#[test]
fn broadcast_matches_oracle_bitwise() {
    for &n in &SIZES {
        let group: Vec<usize> = (0..n).collect();
        let root = group[n / 2];
        let (naive, fast) = differential(n, AlgoSelection::fast(), |rank, comm| {
            let payload = skewed(root, 53, 201); // every rank derives the same
            if rank == root {
                comm.broadcast(&group, root, &payload)
            } else {
                comm.broadcast(&group, root, &[])
            }
        });
        for (a, b) in naive.iter().zip(&fast) {
            assert_bits_eq(a, b, &format!("broadcast n={n}"));
        }
    }
}

/// Non-contiguous, interleaved groups (a folded EP layout): evens and odds
/// of an 8-rank world run independent collectives concurrently; both
/// suites must match the oracle bitwise.
#[test]
fn non_contiguous_groups_match_oracle_bitwise() {
    let (naive, fast) = differential(8, AlgoSelection::fast(), |rank, comm| {
        let group: Vec<usize> = if rank % 2 == 0 {
            vec![0, 2, 4, 6]
        } else {
            vec![1, 3, 5, 7]
        };
        let local = skewed(rank, 67, 4 * 31);
        let summed = comm.all_reduce_sum(&group, &local);
        let shard = comm.reduce_scatter_sum(&group, &local);
        let sends: Vec<Vec<f32>> = (0..4).map(|i| skewed(rank, 71 + i as u64, i + 1)).collect();
        let exchanged = comm.all_to_all_v(&group, sends);
        (summed, shard, exchanged)
    });
    for (me, (a, b)) in naive.iter().zip(&fast).enumerate() {
        assert_bits_eq(&a.0, &b.0, &format!("nc allreduce rank={me}"));
        assert_bits_eq(&a.1, &b.1, &format!("nc reducescatter rank={me}"));
        for (src, (x, y)) in a.2.iter().zip(&b.2).enumerate() {
            assert_bits_eq(x, y, &format!("nc a2av rank={me} from={src}"));
        }
    }
}

/// Catastrophic-cancellation stress: ranks contribute alternating ±1e8
/// plus small residues; only the oracle's exact fold order reproduces the
/// result, so this pins the rank-order invariant hard.
#[test]
fn cancellation_stress_is_bit_exact() {
    let n = 8;
    let group: Vec<usize> = (0..n).collect();
    let (naive, fast) = differential(n, AlgoSelection::fast(), |rank, comm| {
        let sign = if rank % 2 == 0 { 1.0f32 } else { -1.0 };
        let mut local = skewed(rank, 83, 512);
        for (i, v) in local.iter_mut().enumerate() {
            *v += sign * 1e8 + (i % 3) as f32;
        }
        let ar = comm.all_reduce_sum(&group, &local);
        let rs = comm.reduce_scatter_sum(&group, &local);
        (ar, rs)
    });
    for (me, (a, b)) in naive.iter().zip(&fast).enumerate() {
        assert_bits_eq(&a.0, &b.0, &format!("cancel allreduce rank={me}"));
        assert_bits_eq(&a.1, &b.1, &format!("cancel reducescatter rank={me}"));
    }
}
