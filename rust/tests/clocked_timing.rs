//! Differential suite for the event-clocked simulator (ISSUE 3):
//!
//! 1. **Clock neutrality** — executing on a clocked fabric is bit-identical
//!    to the plain fabric (the clock rides control messages, never payload
//!    math), across a folded `tp·cp != etp·ep` dispatch + pipeline run.
//! 2. **Analytic ↔ executed step agreement** — `PerfModel::estimate` and
//!    the clocked `execute_step` agree within a pinned tolerance on all
//!    four Table-3 folded optima at full world size (128/64/128/256
//!    ranks): the two share per-phase prices (`CommCost`,
//!    `StepComponents`), so residual differences are schedule composition
//!    only.
//! 3. **Chrome trace validity** — the `timeline` path emits syntactically
//!    valid chrome-trace JSON for a folded mapping, checked by an actual
//!    JSON parser (below), with one timeline row per rank.

use moe_folding::cluster::{ClusterSpec, GpuSpec};
use moe_folding::collectives::CommCost;
use moe_folding::config::{DropPolicy, ModelConfig, ParallelConfig, TrainConfig};
use moe_folding::dispatcher::{Balancer, DistributedMoeLayer, MoePhaseCost, Router, RouterConfig};
use moe_folding::mapping::RuntimeTopology;
use moe_folding::perfmodel::{execute_step, execute_step_traced, PerfModel, Strategy};
use moe_folding::pipeline::execute_1f1b_mapped;
use moe_folding::simcomm::{chrome_trace_json, run_ranks_on, AlgoSelection, Fabric, Lane};
use moe_folding::train::math::SwigluExpert;
use moe_folding::util::Rng;

const H: usize = 16;
const FF: usize = 32;
const E: usize = 8;

fn build_router(policy: DropPolicy, seed: u64) -> Router {
    let mut rng = Rng::seed_from_u64(seed);
    Router::init(
        RouterConfig {
            hidden: H,
            num_experts: E,
            top_k: 2,
            capacity_factor: 1.0,
            drop_policy: policy,
            capacity_override: None,
            pad_to_capacity: false,
            node_limit: None,
            balancer: Balancer::AuxLoss,
        },
        &mut rng,
    )
}

/// One folded step's worth of per-rank work: MoE dispatch + 1F1B over the
/// mapping's PP partition + a closing world reduction.
fn run_program(clocked: bool) -> (Vec<(Vec<f32>, f32)>, f64) {
    let cfg = ParallelConfig::new(8, 2, 1, 4, 1, 2);
    assert_ne!(cfg.attn_inner(), cfg.moe_inner(), "must be a folded config");
    let topo = RuntimeTopology::folded(cfg).unwrap();
    let router = build_router(DropPolicy::SubSequence, 11);
    let mut rng = Rng::seed_from_u64(12);
    let experts: Vec<SwigluExpert> =
        (0..E).map(|_| SwigluExpert::init(H, FF, &mut rng)).collect();
    let n_per_rank = 10;
    let mut tokens = vec![0.0f32; 8 * n_per_rank * H];
    rng.fill_normal(&mut tokens, 1.0);
    let m = 4;
    let inputs: Vec<Vec<f32>> = (0..m).map(|mb| vec![mb as f32; 5]).collect();
    let pc = MoePhaseCost::from_model(&ModelConfig::mixtral_8x22b(), 1, &GpuSpec::h100());

    let fabric = if clocked {
        Fabric::new_clocked(8, AlgoSelection::fast(), CommCost::new(ClusterSpec::eos(8)))
    } else {
        Fabric::new_with(8, AlgoSelection::fast())
    };
    let outs = run_ranks_on(&fabric, |rank, comm| {
        let layer =
            DistributedMoeLayer::from_topology(topo.view(rank), router.clone(), &experts)
                .with_phase_cost(pc);
        let mine = tokens[rank * n_per_rank * H..(rank + 1) * n_per_rank * H].to_vec();
        let (out, _) = layer.forward(&comm, &mine);
        let pipe = execute_1f1b_mapped(
            &comm,
            &topo,
            m,
            &inputs,
            |_mb, x| x.iter().map(|v| v * 1.5).collect(),
            |_mb, g| g.to_vec(),
        );
        let mut acc: f32 = out.iter().sum();
        if let Some(o) = pipe.outputs.first() {
            acc += o.iter().sum::<f32>();
        }
        let all: Vec<usize> = (0..8).collect();
        let loss = comm.all_reduce_sum(&all, &[acc])[0];
        (out, loss)
    });
    let makespan = fabric.max_sim_time_us();
    (outs, makespan)
}

/// Satellite 3a: the clock must not perturb payloads — clocked and
/// unclocked runs of the same folded program are bit-identical, while the
/// clocked run actually accumulates simulated time.
#[test]
fn clocked_execution_bit_identical_to_unclocked() {
    let (plain, t_plain) = run_program(false);
    let (clocked, t_clocked) = run_program(true);
    assert_eq!(t_plain, 0.0);
    assert!(t_clocked > 0.0, "clocked run must accumulate simulated time");
    for rank in 0..8 {
        assert_eq!(
            plain[rank].1.to_bits(),
            clocked[rank].1.to_bits(),
            "rank {rank} loss differs under the clock"
        );
        assert_eq!(plain[rank].0.len(), clocked[rank].0.len());
        for (i, (a, b)) in plain[rank].0.iter().zip(&clocked[rank].0).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "rank {rank} idx {i}: {a} vs {b}");
        }
    }
}

/// Satellite 3b: analytic and measured-in-sim step time agree within a
/// pinned tolerance on every Table-3 folded optimum at full world size.
#[test]
fn analytic_and_executed_agree_on_table3_folded_optima() {
    let pm = PerfModel::default();
    let train = TrainConfig::paper_default(4096, 256);
    for (model, w, tp, cp, ep, etp, pp) in [
        (ModelConfig::mixtral_8x22b(), 128, 2, 1, 8, 1, 8),
        (ModelConfig::qwen2_57b_a14b(), 64, 2, 1, 4, 1, 4),
        (ModelConfig::mixtral_8x22b_g8t8(), 128, 4, 1, 8, 1, 8),
        (ModelConfig::llama3_8x70b(), 256, 8, 1, 8, 1, 16),
    ] {
        let cfg = ParallelConfig::new(w, tp, cp, ep, etp, pp);
        let analytic = pm
            .estimate(&model, cfg, &train, Strategy::MCoreFolding)
            .unwrap_or_else(|e| panic!("{}: {e}", cfg.tag()));
        let executed = execute_step(&pm, &model, cfg, &train, Strategy::MCoreFolding)
            .unwrap_or_else(|e| panic!("{}: {e}", cfg.tag()));
        let rel = (executed.step_ms - analytic.step_ms).abs() / analytic.step_ms;
        assert!(
            rel < 0.02,
            "{} ({}): executed {:.1} ms vs analytic {:.1} ms (rel {rel:.4})",
            model.name,
            cfg.tag(),
            executed.step_ms,
            analytic.step_ms
        );
        // The measured bubble is in the analytic 1F1B ballpark (p2p and
        // f≠b shift it slightly off the uniform closed form).
        let m_micro = train.num_microbatches(cfg.dp());
        let analytic_bubble = moe_folding::pipeline::bubble_fraction(pp, m_micro);
        assert!(
            (executed.bubble_fraction - analytic_bubble).abs() < 0.05,
            "{}: bubble {:.3} vs analytic {:.3}",
            cfg.tag(),
            executed.bubble_fraction,
            analytic_bubble
        );
    }
}

/// Acceptance: the timeline path produces **valid** chrome-trace JSON for
/// a folded (`tp·cp != etp·ep`) mapping, with a timeline row per rank.
#[test]
fn timeline_trace_is_valid_chrome_json_for_folded_mapping() {
    let pm = PerfModel::default();
    let model = ModelConfig::qwen2_57b_a14b();
    let train = TrainConfig::paper_default(4096, 32);
    let cfg = ParallelConfig::new(8, 2, 1, 4, 1, 2);
    assert_ne!(cfg.attn_inner(), cfg.moe_inner(), "must be folded");
    assert!(!cfg.is_legacy_expressible());
    let (est, trace) =
        execute_step_traced(&pm, &model, cfg, &train, Strategy::MCoreFolding).unwrap();
    assert!(est.step_ms > 0.0);
    assert!(!trace.is_empty());
    // Every rank shows up in the trace.
    for rank in 0..8 {
        assert!(trace.iter().any(|e| e.rank == rank), "rank {rank} missing");
    }
    let json = chrome_trace_json(&trace);
    let value_count = json_validate(&json).expect("trace must be valid JSON");
    assert!(value_count > trace.len(), "one value per event at minimum");
    assert!(json.contains("\"traceEvents\""));
    assert!(json.contains("\"ph\":\"X\""));
}

/// Trace-integrity satellite (ISSUE 4): every `TraceEvent` stream from an
/// **overlapped** executed step (grad-reduce under backward, a2a under
/// expert GEMM, interleaved vpp) is well-formed — non-negative durations,
/// per-lane spans non-overlapping within a rank, all three lanes present,
/// and the chrome JSON round-trips through the strict in-test parser.
#[test]
fn overlapped_executed_trace_is_wellformed() {
    let pm = PerfModel::default();
    let model = ModelConfig::qwen2_57b_a14b(); // 28 layers: pp2·vpp2 tiles
    let mut train = TrainConfig::paper_default(4096, 32);
    train.overlap_a2a = true;
    assert!(train.overlap_grad_reduce);
    let cfg = ParallelConfig::new(8, 2, 1, 4, 1, 2).with_vpp(2);
    let (est, trace) =
        execute_step_traced(&pm, &model, cfg, &train, Strategy::MCoreFolding).unwrap();
    assert!(est.step_ms > 0.0);
    assert!(est.hidden_comm_us > 0.0, "overlap must hide something");
    assert!(!trace.is_empty());
    // 1. Durations are finite and non-negative; timestamps finite.
    for e in &trace {
        assert!(e.dur_us.is_finite() && e.dur_us >= 0.0, "{e:?}");
        assert!(e.ts_us.is_finite() && e.ts_us >= 0.0, "{e:?}");
    }
    // 2. Per (rank, lane) spans never overlap.
    for rank in 0..8 {
        for lane in [Lane::Main, Lane::Comm, Lane::Bg] {
            let mut spans: Vec<(f64, f64)> = trace
                .iter()
                .filter(|e| e.rank == rank && e.lane == lane)
                .map(|e| (e.ts_us, e.ts_us + e.dur_us))
                .collect();
            spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for w in spans.windows(2) {
                assert!(
                    w[0].1 <= w[1].0 + 1e-6,
                    "rank {rank} {lane:?}: span ending {:.3} overlaps next starting {:.3}",
                    w[0].1,
                    w[1].0
                );
            }
        }
        // Every rank drove all three lanes (compute ops, a2a charges, grad
        // buckets).
        for lane in [Lane::Main, Lane::Comm, Lane::Bg] {
            assert!(
                trace.iter().any(|e| e.rank == rank && e.lane == lane),
                "rank {rank}: lane {lane:?} missing"
            );
        }
    }
    // 3. The overlapped grad buckets and a2a charges are visible.
    assert!(trace.iter().any(|e| e.lane == Lane::Bg && e.name.contains("grad")));
    assert!(trace.iter().any(|e| e.name == "moe/a2a_ovl"));
    // 4. Chrome JSON round-trips the strict parser.
    let json = chrome_trace_json(&trace);
    let values = json_validate(&json).expect("overlapped trace must be valid JSON");
    assert!(values > trace.len());
    // Lane metadata rows are emitted.
    assert!(json.contains("grad-sync"));
    assert!(json.contains("comm"));
}

// ---------------------------------------------------------------------
// Minimal strict JSON syntax checker (returns the number of values
// parsed). No external crates in this repo — see Cargo.toml header.
// ---------------------------------------------------------------------

fn json_validate(s: &str) -> Result<usize, String> {
    let b = s.as_bytes();
    let mut pos = 0usize;
    let mut count = 0usize;
    skip_ws(b, &mut pos);
    parse_value(b, &mut pos, &mut count)?;
    skip_ws(b, &mut pos);
    if pos != b.len() {
        return Err(format!("trailing garbage at byte {pos}"));
    }
    Ok(count)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<(), String> {
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected {:?} at byte {}", c as char, *pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize, count: &mut usize) -> Result<(), String> {
    *count += 1;
    skip_ws(b, pos);
    match b.get(*pos) {
        Some(b'{') => {
            *pos += 1;
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(());
            }
            loop {
                skip_ws(b, pos);
                parse_string(b, pos)?;
                skip_ws(b, pos);
                expect(b, pos, b':')?;
                parse_value(b, pos, count)?;
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(());
                    }
                    _ => return Err(format!("bad object at byte {}", *pos)),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(());
            }
            loop {
                parse_value(b, pos, count)?;
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(());
                    }
                    _ => return Err(format!("bad array at byte {}", *pos)),
                }
            }
        }
        Some(b'"') => parse_string(b, pos),
        Some(b't') => parse_lit(b, pos, "true"),
        Some(b'f') => parse_lit(b, pos, "false"),
        Some(b'n') => parse_lit(b, pos, "null"),
        Some(_) => parse_number(b, pos),
        None => Err("unexpected end of input".into()),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str) -> Result<(), String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("bad literal at byte {}", *pos))
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<(), String> {
    expect(b, pos, b'"')?;
    while *pos < b.len() {
        match b[*pos] {
            b'\\' => {
                *pos += 2;
            }
            b'"' => {
                *pos += 1;
                return Ok(());
            }
            0x00..=0x1f => return Err(format!("raw control char at byte {}", *pos)),
            _ => *pos += 1,
        }
    }
    Err("unterminated string".into())
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<(), String> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    let mut digits = 0;
    while *pos < b.len() && b[*pos].is_ascii_digit() {
        *pos += 1;
        digits += 1;
    }
    if digits == 0 {
        return Err(format!("bad number at byte {start}"));
    }
    if b.get(*pos) == Some(&b'.') {
        *pos += 1;
        let mut frac = 0;
        while *pos < b.len() && b[*pos].is_ascii_digit() {
            *pos += 1;
            frac += 1;
        }
        if frac == 0 {
            return Err(format!("bad fraction at byte {start}"));
        }
    }
    if matches!(b.get(*pos), Some(&b'e') | Some(&b'E')) {
        *pos += 1;
        if matches!(b.get(*pos), Some(&b'+') | Some(&b'-')) {
            *pos += 1;
        }
        let mut exp = 0;
        while *pos < b.len() && b[*pos].is_ascii_digit() {
            *pos += 1;
            exp += 1;
        }
        if exp == 0 {
            return Err(format!("bad exponent at byte {start}"));
        }
    }
    Ok(())
}
