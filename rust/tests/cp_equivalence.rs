//! CP differential suite (ISSUE 5): executed context parallelism is
//! **measured and bit-exact**, not credited.
//!
//! 1. **Attention equivalence** — CP=2/4 folded ring attention (zig-zag
//!    and contiguous/"even" shardings) produces outputs bit-identical to
//!    the CP=1 single-process reference on the same token stream; with TP
//!    fixed, outputs are bit-identical across CP degrees.
//! 2. **End-to-end folded config** — a `tp·cp != etp·ep` mapping (not
//!    legacy-expressible) runs ring attention + the MoE dispatcher in one
//!    step; per-rank outputs and the global loss equal the single-process
//!    reference construction bit-for-bit. A Table-3-style 128-rank variant
//!    runs in the `--ignored` tier (scheduled weekly CI).
//! 3. **Overlap bound** — the nonblocking zig-zag ring's clocked makespan
//!    never exceeds the serialized (blocking-p2p) twin, with bit-identical
//!    payloads.
//! 4. **Analytic ↔ executed agreement** — on the fig6 CP sweep the
//!    executed step time agrees with `PerfModel::estimate` within 2%
//!    (the recalibrated `cp_exposed_us` closed form cannot drift from the
//!    measured ring again).
//! 5. **Trainer** — with the CP-sharded attention forward on, trainer
//!    losses and the step-0 attention digest are bit-identical across
//!    cp ∈ {1, 2, 4} (artifact-gated, like the other trainer suites).

use moe_folding::attention::{
    reference_forward, zigzag, AttnConfig, AttnPhaseCost, AttnWeights, DistributedAttentionLayer,
};
use moe_folding::cluster::{ClusterSpec, GpuSpec};
use moe_folding::collectives::CommCost;
use moe_folding::config::{DropPolicy, ModelConfig, ParallelConfig, TrainConfig};
use moe_folding::dispatcher::{
    reference_moe_forward, Balancer, DistributedMoeLayer, Router, RouterConfig,
};
use moe_folding::mapping::RuntimeTopology;
use moe_folding::perfmodel::{execute_step, PerfModel, Strategy};
use moe_folding::simcomm::{run_ranks_on, AlgoSelection, Fabric};
use moe_folding::train::math::SwigluExpert;
use moe_folding::train::{train, CpAttnProbe, TrainerConfig};
use moe_folding::util::Rng;

const H: usize = 16;
const HEADS: usize = 2;
const KV_CHUNKS: usize = 8;
const SEQ: usize = 32;

fn attn_cfg(zigzag: bool) -> AttnConfig {
    AttnConfig { hidden: H, num_heads: HEADS, kv_chunks: KV_CHUNKS, zigzag }
}

fn make_tokens(seed: u64, n: usize, h: usize) -> Vec<f32> {
    let mut rng = Rng::seed_from_u64(seed);
    let mut t = vec![0.0f32; n * h];
    rng.fill_normal(&mut t, 1.0);
    t
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: idx {i}: {x} vs {y}");
    }
}

/// Run the attention layer over a folded topology's full world and
/// reassemble each sequence block's output (gather TP slices, then undo
/// the CP sharding). Every block consumed the same `tokens`, so all
/// reassembled outputs must agree.
fn run_attention_world(
    cfg: ParallelConfig,
    acfg: AttnConfig,
    weights: &AttnWeights,
    tokens: &[f32],
) -> Vec<Vec<f32>> {
    let topo = RuntimeTopology::folded(cfg).unwrap();
    let fabric = Fabric::new_with(cfg.world_size, AlgoSelection::fast());
    let outs = run_ranks_on(&fabric, |rank, comm| {
        let layer = DistributedAttentionLayer::from_topology(topo.view(rank), acfg, weights);
        let (out, _) = layer.forward(&comm, &layer.input_slice(tokens), tokens.len() / acfg.hidden);
        out
    });
    // Reassemble per sequence block: shards[cp_index] = concat of the TP
    // slices in tp-index order.
    let mut blocks: Vec<Vec<f32>> = Vec::new();
    for r in 0..cfg.world_size {
        let v = topo.view(r);
        if v.tp_index != 0 || v.cp_index != 0 {
            continue;
        }
        let mut shards: Vec<Vec<f32>> = vec![Vec::new(); cfg.cp];
        for c in 0..cfg.cp {
            for t in 0..cfg.tp {
                let peer = *v
                    .seq_group
                    .iter()
                    .find(|&&p| topo.view(p).cp_index == c && topo.view(p).tp_index == t)
                    .unwrap();
                shards[c].extend_from_slice(&outs[peer]);
            }
        }
        blocks.push(zigzag::unshard(&shards, acfg.hidden, acfg.zigzag));
    }
    blocks
}

/// CP = 2 / 4 folded attention output is bit-identical to the CP = 1
/// single-process reference — for both the zig-zag and the contiguous
/// ("even") sharding.
#[test]
fn cp_attention_bit_identical_to_reference() {
    let tokens = make_tokens(11, SEQ, H);
    let mut rng = Rng::seed_from_u64(21);
    let weights = AttnWeights::init(H, &mut rng);
    for zz in [true, false] {
        let acfg = attn_cfg(zz);
        let want = reference_forward(&acfg, &weights, &tokens);
        for cp in [1usize, 2, 4] {
            let cfg = ParallelConfig::new(cp, 1, cp, 1, 1, 1);
            let blocks = run_attention_world(cfg, acfg, &weights, &tokens);
            assert_eq!(blocks.len(), 1);
            assert_bits_eq(&blocks[0], &want, &format!("cp {cp} zigzag {zz}"));
        }
    }
}

/// With TP fixed (the output-projection sum association pinned), outputs
/// are bit-identical across CP degrees — the canonical-chunk LSE combine
/// is CP-invariant even through the sequence-parallel AG/RS pair.
#[test]
fn cp_attention_bit_identical_across_cp_at_fixed_tp() {
    let tokens = make_tokens(13, SEQ, H);
    let mut rng = Rng::seed_from_u64(23);
    let weights = AttnWeights::init(H, &mut rng);
    let acfg = attn_cfg(true);
    let reference = run_attention_world(
        ParallelConfig::new(2, 2, 1, 1, 1, 1), // tp2 · cp1
        acfg,
        &weights,
        &tokens,
    );
    for cp in [2usize, 4] {
        let cfg = ParallelConfig::new(2 * cp, 2, cp, 1, 1, 1);
        let blocks = run_attention_world(cfg, acfg, &weights, &tokens);
        assert_eq!(blocks.len(), 1);
        assert_bits_eq(&blocks[0], &reference[0], &format!("tp2 cp{cp}"));
    }
}

const E: usize = 4;
const FF: usize = 32;

fn moe_parts(seed: u64) -> (Router, Vec<SwigluExpert>) {
    let mut rng = Rng::seed_from_u64(seed);
    let router = Router::init(
        RouterConfig {
            hidden: H,
            num_experts: E,
            top_k: 2,
            capacity_factor: 1.0,
            drop_policy: DropPolicy::SubSequence,
            capacity_override: None,
            pad_to_capacity: false,
            node_limit: None,
            balancer: Balancer::AuxLoss,
        },
        &mut rng,
    );
    let experts: Vec<SwigluExpert> = (0..E).map(|_| SwigluExpert::init(H, FF, &mut rng)).collect();
    (router, experts)
}

/// End-to-end folded step on a `tp·cp != etp·ep` mapping (8 ranks,
/// CP2 attention vs ETP1·EP4 MoE — not legacy-expressible): ring attention
/// feeds the token dispatcher, and per-rank outputs plus the global loss
/// equal the single-process reference construction bit-for-bit.
#[test]
fn folded_config_attention_feeds_moe_end_to_end() {
    let cfg = ParallelConfig::new(8, 1, 2, 4, 1, 1);
    assert_ne!(cfg.attn_inner(), cfg.moe_inner(), "must be a folded config");
    assert!(!cfg.is_legacy_expressible());
    let topo = RuntimeTopology::folded(cfg).unwrap();
    let acfg = attn_cfg(true);
    let mut rng = Rng::seed_from_u64(31);
    let weights = AttnWeights::init(H, &mut rng);
    let (router, experts) = moe_parts(33);
    // Every sequence block (= CP pair) consumes the same token stream.
    let tokens = make_tokens(35, SEQ, H);

    let fabric = Fabric::new_with(8, AlgoSelection::fast());
    let outs = run_ranks_on(&fabric, |rank, comm| {
        let attn = DistributedAttentionLayer::from_topology(topo.view(rank), acfg, &weights);
        let (attn_out, stats) = attn.forward(&comm, &attn.input_slice(&tokens), SEQ);
        assert_eq!(stats.ring_steps, 1);
        let moe = DistributedMoeLayer::from_topology(topo.view(rank), router.clone(), &experts);
        let (moe_out, _) = moe.forward(&comm, &attn_out);
        let acc: f32 = moe_out.iter().sum();
        let all: Vec<usize> = (0..8).collect();
        let loss = comm.all_reduce_sum(&all, &[acc])[0];
        (attn_out, moe_out, loss)
    });

    // Reference: full-sequence attention, zig-zag shard, then the chunked
    // single-process MoE (sub-sequence routing = one chunk per rank shard).
    let attn_full = reference_forward(&acfg, &weights, &tokens);
    let n_shard = SEQ / 2;
    for rank in 0..8 {
        let v = topo.view(rank);
        let want_attn = zigzag::shard(&attn_full, H, 2, v.cp_index, true);
        assert_bits_eq(&outs[rank].0, &want_attn, &format!("rank {rank} attention"));
        let want_moe = reference_moe_forward(&router, &experts, &want_attn, Some(n_shard));
        assert_bits_eq(&outs[rank].1, &want_moe, &format!("rank {rank} moe"));
    }
    // The engine's all-reduce folds in ascending rank order — recompute
    // the same fold from the verified per-rank outputs.
    let mut want_loss = 0.0f32;
    for o in &outs {
        want_loss += o.1.iter().sum::<f32>();
    }
    for (rank, o) in outs.iter().enumerate() {
        assert_eq!(o.2.to_bits(), want_loss.to_bits(), "rank {rank} loss");
    }
}

/// The nonblocking zig-zag ring never loses to the serialized
/// (blocking-p2p) twin on the clock, with bit-identical payloads; the
/// measured hidden share is positive when the core window covers the
/// transfer.
#[test]
fn zigzag_ring_makespan_never_exceeds_serialized() {
    let cp = 4usize;
    let cfg = ParallelConfig::new(cp, 1, cp, 1, 1, 1);
    let topo = RuntimeTopology::folded(cfg).unwrap();
    let acfg = attn_cfg(true);
    let mut rng = Rng::seed_from_u64(41);
    let weights = AttnWeights::init(H, &mut rng);
    let tokens = make_tokens(43, SEQ, H);
    // Model-scale core charge (the stand-in payload is tiny): the Mixtral
    // attention core priced per (q, kv) pair, exactly what a clocked
    // skeleton would attach.
    let pc = AttnPhaseCost::from_model(&ModelConfig::mixtral_8x22b(), 1, &GpuSpec::h100());
    assert!(pc.core_us_per_pair > 0.0);
    let mut results: Vec<(Vec<Vec<f32>>, f64, f64, f64)> = Vec::new();
    for overlap in [true, false] {
        let fabric = Fabric::new_clocked(
            cp,
            AlgoSelection::fast(),
            CommCost::new(ClusterSpec::eos(cp)),
        );
        let outs = run_ranks_on(&fabric, |rank, comm| {
            let layer = DistributedAttentionLayer::from_topology(topo.view(rank), acfg, &weights)
                .with_phase_cost(pc)
                .with_kv_bill_scale(1e3)
                .with_overlap(overlap);
            let (out, stats) = layer.forward(&comm, &layer.input_slice(&tokens), SEQ);
            (out, stats)
        });
        let makespan = fabric.max_sim_time_us();
        let hidden: f64 = outs.iter().map(|(_, s)| s.cp_hidden_us).sum();
        let exposed: f64 = outs.iter().map(|(_, s)| s.cp_exposed_us).sum();
        results.push((outs.into_iter().map(|(o, _)| o).collect(), makespan, hidden, exposed));
    }
    let (ovl_outs, t_ovl, hid_ovl, _) = &results[0];
    let (ser_outs, t_ser, _, exp_ser) = &results[1];
    for (rank, (a, b)) in ovl_outs.iter().zip(ser_outs).enumerate() {
        assert_bits_eq(a, b, &format!("rank {rank} overlap vs serialized"));
    }
    assert!(
        t_ovl <= &(t_ser + 1e-9),
        "overlapped ring {t_ovl} µs > serialized {t_ser} µs"
    );
    assert!(*hid_ovl > 0.0, "the core window must hide some KV transfer");
    assert!(*exp_ser > 0.0, "the serialized twin pays its transfers exposed");
}

/// Fig6 CP sweep: the executed step (structural ring charges, measured
/// exposure) agrees with the analytic estimate within 2% — the regression
/// pin that keeps the recalibrated `cp_exposed_us` credit honest.
#[test]
fn fig6_executed_step_agrees_with_analytic_within_2pct() {
    let pm = PerfModel::default();
    let model = ModelConfig::mixtral_8x22b();
    for (cp, seq) in [(2usize, 16384usize), (4, 32768)] {
        let cfg = ParallelConfig::new(32, 2, cp, 8, 1, 1);
        let train_cfg = TrainConfig::paper_default(seq, 256);
        let analytic = pm.estimate(&model, cfg, &train_cfg, Strategy::MCoreFolding).unwrap();
        let executed = execute_step(&pm, &model, cfg, &train_cfg, Strategy::MCoreFolding).unwrap();
        let rel = (executed.step_ms - analytic.step_ms).abs() / analytic.step_ms;
        assert!(
            rel < 0.02,
            "cp {cp}: executed {:.1} ms vs analytic {:.1} ms (rel {rel:.4})",
            executed.step_ms,
            analytic.step_ms
        );
        assert!(
            executed.cp_hidden_us + executed.cp_exposed_us > 0.0,
            "cp {cp}: the ring must be measured"
        );
    }
    // The coordinator table carries the same numbers (|Δ| < 2 %).
    let t = moe_folding::coordinator::fig6_cp_folding_executed(&pm, &model, 32);
    assert!(t.rows.len() >= 3, "{} rows", t.rows.len());
    for row in &t.rows {
        let delta: f64 = row[4].parse().unwrap();
        assert!(delta.abs() < 2.0, "CP {}: Δ {delta}%", row[0]);
    }
}

/// Trainer: the CP-sharded attention forward leaves losses bit-identical
/// across cp ∈ {1, 2, 4} (same data per DP replica), the step-0 attention
/// digest is bit-identical too, and the clocked runs measure CP ring comm
/// for cp > 1. Artifact-gated like the other trainer suites.
#[test]
fn trainer_losses_and_attention_digest_bit_identical_across_cp() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        return;
    }
    let probe = CpAttnProbe { seq_len: 48, kv_chunks: 8, ..Default::default() };
    let mut reports = Vec::new();
    for cp in [1usize, 2, 4] {
        let cfg = TrainerConfig {
            preset: "test".into(),
            steps: 4,
            parallel: Some(ParallelConfig::new(2 * cp, 1, cp, 1, 1, 1)), // dp 2 fixed
            clocked: true,
            compute_us_per_step: 500.0,
            cp_attention: Some(probe.clone()),
            ..Default::default()
        };
        reports.push((cp, train(&cfg).unwrap()));
    }
    let (_, r1) = &reports[0];
    for (cp, r) in &reports[1..] {
        assert_eq!(r1.losses, r.losses, "cp {cp}: losses must be bit-identical");
        let d1 = r1.cp_attn_digest.as_ref().unwrap();
        let d = r.cp_attn_digest.as_ref().unwrap();
        assert_bits_eq(d, d1, &format!("cp {cp} attention digest"));
        let ring = r.sim_cp_hidden_us.unwrap() + r.sim_cp_exposed_us.unwrap();
        assert!(ring > 0.0, "cp {cp}: ring comm must be measured");
    }
    assert_eq!(
        r1.sim_cp_hidden_us.unwrap() + r1.sim_cp_exposed_us.unwrap(),
        0.0,
        "cp = 1 has no ring"
    );
}

/// `--ignored` tier (scheduled weekly CI): a Table-3-style folded config
/// with `tp·cp != etp·ep` executed end-to-end at full world size —
/// 128 rank threads run the functional ring attention (bit-identical to
/// the single-process reference in every CP group) and the full executed
/// step agrees with the analytic estimate within 2%.
#[test]
#[ignore]
fn table3_style_folded_cp_config_at_full_world_size() {
    let cfg = ParallelConfig::new(128, 1, 2, 8, 1, 8);
    assert_ne!(cfg.attn_inner(), cfg.moe_inner());
    let topo = RuntimeTopology::folded(cfg).unwrap();
    let acfg = attn_cfg(true);
    let mut rng = Rng::seed_from_u64(51);
    let weights = AttnWeights::init(H, &mut rng);
    let tokens = make_tokens(53, SEQ, H);
    let want = reference_forward(&acfg, &weights, &tokens);
    let fabric = Fabric::new_with(128, AlgoSelection::fast());
    let outs = run_ranks_on(&fabric, |rank, comm| {
        let layer = DistributedAttentionLayer::from_topology(topo.view(rank), acfg, &weights);
        let (out, _) = layer.forward(&comm, &layer.input_slice(&tokens), SEQ);
        out
    });
    // Every CP pair reassembles to the reference bit-for-bit.
    for rank in 0..128 {
        let v = topo.view(rank);
        if v.cp_index != 0 {
            continue;
        }
        let shards: Vec<Vec<f32>> = v.cp_group.iter().map(|&p| outs[p].clone()).collect();
        let full = zigzag::unshard(&shards, H, true);
        assert_bits_eq(&full, &want, &format!("cp group of rank {rank}"));
    }
    // Full executed step on the clocked simulator.
    let pm = PerfModel::default();
    let model = ModelConfig::mixtral_8x22b();
    let train_cfg = TrainConfig::paper_default(16384, 256);
    let analytic = pm.estimate(&model, cfg, &train_cfg, Strategy::MCoreFolding).unwrap();
    let executed = execute_step(&pm, &model, cfg, &train_cfg, Strategy::MCoreFolding).unwrap();
    let rel = (executed.step_ms - analytic.step_ms).abs() / analytic.step_ms;
    assert!(
        rel < 0.02,
        "executed {:.1} ms vs analytic {:.1} ms (rel {rel:.4})",
        executed.step_ms,
        analytic.step_ms
    );
    assert!(executed.cp_hidden_us + executed.cp_exposed_us > 0.0);
}
