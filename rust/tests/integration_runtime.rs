//! Integration: the PJRT runtime against the AOT artifacts, including the
//! cross-layer equivalence check — the Rust dispatcher's math must match
//! the JAX/Pallas `moe_block` artifact given identical weights.
//!
//! These tests require `make artifacts`; they skip (pass vacuously) when
//! the artifacts directory is absent so `cargo test` works pre-build.
use moe_folding::config::DropPolicy;
use moe_folding::dispatcher::{reference_moe_forward, Balancer, Router, RouterConfig};
use moe_folding::runtime::{InputBuf, Runtime};
use moe_folding::train::math::SwigluExpert;
use moe_folding::util::Rng;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::cpu("artifacts").expect("pjrt cpu client"))
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(rt) = runtime() else { return };
    for name in ["test_train_step", "test_eval_loss", "test_moe_block",
                 "test_moe_block_ref", "test_router"] {
        assert!(rt.manifest().unwrap().get(name).is_some(), "{name} missing");
    }
}

#[test]
fn router_artifact_matches_rust_softmax() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("test_router").unwrap();
    let spec = exe.spec.clone().unwrap();
    let (n, h) = (spec.inputs[0].dims[0], spec.inputs[0].dims[1]);
    let e = spec.inputs[1].dims[1];
    let mut rng = Rng::seed_from_u64(11);
    let mut tokens = vec![0.0f32; n * h];
    let mut w = vec![0.0f32; h * e];
    rng.fill_normal(&mut tokens, 1.0);
    rng.fill_normal(&mut w, 0.3);
    let out = exe
        .run_f32(&[InputBuf::f32(tokens.clone(), &[n, h]), InputBuf::f32(w.clone(), &[h, e])])
        .unwrap();
    let router = Router::new(
        RouterConfig {
            hidden: h,
            num_experts: e,
            top_k: 2,
            capacity_factor: 1.0,
            drop_policy: DropPolicy::Dropless,
            capacity_override: None,
            pad_to_capacity: false,
            node_limit: None,
            balancer: Balancer::AuxLoss,
        },
        w,
    );
    let probs = router.gate_probs(&tokens);
    for (a, b) in out[0].iter().zip(&probs) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

/// THE cross-layer check: Rust dispatcher math == JAX/Pallas MoE block.
/// Same weights, same tokens; the artifact uses capacity-bin dispatch with
/// the manifest's static capacity; the Rust reference uses the same
/// capacity via `capacity_override` and full-batch scope.
#[test]
fn rust_dispatcher_matches_pallas_moe_block() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("test_moe_block").unwrap();
    let spec = exe.spec.clone().unwrap();
    let (n, h) = (spec.inputs[0].dims[0], spec.inputs[0].dims[1]);
    let e = spec.inputs[1].dims[1];
    let f = spec.inputs[2].dims[2];
    let cap = rt.meta_usize("test.moe_capacity").unwrap();
    let top_k = rt.meta_usize("test.top_k").unwrap();

    let mut rng = Rng::seed_from_u64(21);
    let mut tokens = vec![0.0f32; n * h];
    rng.fill_normal(&mut tokens, 1.0);
    let mut wr = vec![0.0f32; h * e];
    rng.fill_normal(&mut wr, 0.3);
    // Expert weights: build rust experts, serialize into [E,H,F]/[E,F,H].
    let experts: Vec<SwigluExpert> = (0..e)
        .map(|_| SwigluExpert::init(h, f, &mut rng))
        .collect();
    let mut wg = Vec::with_capacity(e * h * f);
    let mut wu = Vec::with_capacity(e * h * f);
    let mut wd = Vec::with_capacity(e * f * h);
    for ex in &experts {
        wg.extend_from_slice(&ex.w_gate);
        wu.extend_from_slice(&ex.w_up);
        wd.extend_from_slice(&ex.w_down);
    }

    let out = exe
        .run_f32(&[
            InputBuf::f32(tokens.clone(), &[n, h]),
            InputBuf::f32(wr.clone(), &[h, e]),
            InputBuf::f32(wg, &[e, h, f]),
            InputBuf::f32(wu, &[e, h, f]),
            InputBuf::f32(wd, &[e, f, h]),
        ])
        .unwrap();

    let router = Router::new(
        RouterConfig {
            hidden: h,
            num_experts: e,
            top_k,
            capacity_factor: 1.0,
            drop_policy: DropPolicy::SubSequence,
            capacity_override: Some(cap),
            pad_to_capacity: false,
            node_limit: None,
            balancer: Balancer::AuxLoss,
        },
        wr,
    );
    let reference = reference_moe_forward(&router, &experts, &tokens, None);
    let mut max_err = 0.0f32;
    for (a, b) in out[0].iter().zip(&reference) {
        max_err = max_err.max((a - b).abs() / (1.0 + b.abs()));
    }
    assert!(max_err < 5e-4, "max rel err {max_err}");
}

/// Pallas kernel path and pure-jnp reference artifact agree when executed
/// from Rust (kernel correctness survives the AOT round-trip).
#[test]
fn pallas_and_ref_artifacts_agree_via_pjrt() {
    let Some(rt) = runtime() else { return };
    let a = rt.load("test_moe_block").unwrap();
    let b = rt.load("test_moe_block_ref").unwrap();
    let spec = a.spec.clone().unwrap();
    let mut rng = Rng::seed_from_u64(31);
    let bufs: Vec<InputBuf> = spec
        .inputs
        .iter()
        .map(|ts| {
            let mut v = vec![0.0f32; ts.elements()];
            rng.fill_normal(&mut v, 0.5);
            InputBuf::f32(v, &ts.dims)
        })
        .collect();
    let oa = a.run_f32(&bufs).unwrap();
    let ob = b.run_f32(&bufs).unwrap();
    for (x, y) in oa[0].iter().zip(&ob[0]) {
        assert!((x - y).abs() < 1e-4, "{x} vs {y}");
    }
}

#[test]
fn grouped_ffn_artifact_runs() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("test_grouped_ffn_ep2").unwrap();
    let spec = exe.spec.clone().unwrap();
    let mut rng = Rng::seed_from_u64(41);
    let bufs: Vec<InputBuf> = spec
        .inputs
        .iter()
        .map(|ts| {
            let mut v = vec![0.0f32; ts.elements()];
            rng.fill_normal(&mut v, 0.5);
            InputBuf::f32(v, &ts.dims)
        })
        .collect();
    let out = exe.run_f32(&bufs).unwrap();
    assert_eq!(out[0].len(), spec.outputs[0].elements());
    assert!(out[0].iter().all(|x| x.is_finite()));
}
