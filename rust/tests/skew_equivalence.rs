//! Differential suite for skewed gate streams (ISSUE 9): under Zipf and
//! domain-shifted gates from [`SkewGen`], every drop scope × capacity
//! policy × balancer produces bit-identical outputs between the
//! distributed dispatcher and the single-rank reference (ETP sharding,
//! which reorders the FFN reduction, gets a tolerance tier instead) —
//! plus the cost-triangle regressions that pin what each capacity policy
//! trades: dropped tokens vs dispatch bytes vs static shapes.

use moe_folding::config::{DropPolicy, ParallelConfig};
use moe_folding::dispatcher::{
    reference_moe_forward, Balancer, DispatchStats, DistributedMoeLayer, LoadStats, Router,
    RouterConfig, SkewGen, SkewProfile,
};
use moe_folding::mapping::RuntimeTopology;
use moe_folding::simcomm::{run_ranks, Payload};
use moe_folding::train::math::SwigluExpert;
use moe_folding::util::Rng;

const H: usize = 16;
const FF: usize = 32;
const E: usize = 8;
const K: usize = 2;

fn cfg(policy: DropPolicy, pad: bool, balancer: Balancer) -> RouterConfig {
    RouterConfig {
        hidden: H,
        num_experts: E,
        top_k: K,
        capacity_factor: 1.0,
        drop_policy: policy,
        capacity_override: None,
        pad_to_capacity: pad,
        node_limit: None,
        balancer,
    }
}

fn build_experts(seed: u64) -> Vec<SwigluExpert> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..E).map(|_| SwigluExpert::init(H, FF, &mut rng)).collect()
}

/// Warm an aux-loss-free router's bias on a disjoint stream of the same
/// profile, then return the frozen bias — so the differential runs route
/// with a realistic non-zero bias on both sides of the comparison.
fn warmed_bias(profile: SkewProfile, update_rate: f32) -> Vec<f32> {
    let mut gen = SkewGen::new(profile, E, H, 777);
    let aux = Balancer::AuxFree { update_rate };
    let mut router = gen.router(cfg(DropPolicy::Dropless, false, aux));
    for _ in 0..16 {
        let d = router.route(&gen.next_tokens(64));
        router.update_bias(&d.expert_load);
    }
    router.bias.clone()
}

/// Route a world-rank-major token batch through a direct EP layer (ETP=1)
/// and return per-rank (output, stats). `full_seq` puts every rank in one
/// full-sequence drop scope.
fn run_ep_layer(
    router: &Router,
    experts: &[SwigluExpert],
    tokens: &[f32],
    ep: usize,
    n_per_rank: usize,
    full_seq: bool,
) -> Vec<(Vec<f32>, DispatchStats)> {
    run_ranks(ep, |rank, comm| {
        let epr = E / ep;
        let layer = DistributedMoeLayer {
            router: router.clone(),
            local_experts: experts[rank * epr..(rank + 1) * epr].to_vec(),
            ep_group: (0..ep).collect(),
            etp_group: vec![rank],
            ep_index: rank,
            num_experts: E,
            seq_group: full_seq.then(|| (0..ep).collect()),
            phase_cost: None,
            overlap_a2a: false,
            payload: Payload::F32,
        };
        let mine = tokens[rank * n_per_rank * H..(rank + 1) * n_per_rank * H].to_vec();
        layer.forward(&comm, &mine)
    })
}

/// Tentpole differential: Zipf and domain-shifted gate streams route
/// bit-identically to the single-rank reference across every drop scope,
/// capacity policy, and balancer (ETP=1: same reduction order). Sinkhorn
/// is excluded from the full-sequence cell only — its transport plan
/// couples the tokens routed together, so its selection scope *is* the
/// local chunk and no single-rank whole-scope reference exists.
#[test]
fn skewed_streams_match_reference_across_policies_and_balancers() {
    let ep = 4;
    let n_per_rank = 16;
    let experts = build_experts(42);
    let profiles = [
        SkewProfile::Zipf { exponent: 1.2 },
        SkewProfile::DomainShift { exponent: 1.2, period: 32 },
    ];
    let balancers = [
        Balancer::AuxLoss,
        Balancer::AuxFree { update_rate: 0.05 },
        Balancer::Sinkhorn { iters: 16 },
    ];
    for profile in profiles {
        for balancer in balancers {
            let mut cells = vec![
                (DropPolicy::Dropless, false, false),
                (DropPolicy::SubSequence, false, false),
                (DropPolicy::SubSequence, true, false),
            ];
            if !matches!(balancer, Balancer::Sinkhorn { .. }) {
                cells.push((DropPolicy::FullSequence, false, true));
            }
            for (policy, pad, full_seq) in cells {
                let mut gen = SkewGen::new(profile, E, H, 1234);
                let mut router = gen.router(cfg(policy, pad, balancer));
                if let Balancer::AuxFree { update_rate } = balancer {
                    router = router.with_bias(warmed_bias(profile, update_rate));
                }
                let tokens = gen.next_tokens(ep * n_per_rank);
                let outs = run_ep_layer(&router, &experts, &tokens, ep, n_per_rank, full_seq);
                let chunk = if full_seq { None } else { Some(n_per_rank) };
                let reference = reference_moe_forward(&router, &experts, &tokens, chunk);
                let distributed: Vec<f32> = outs.iter().flat_map(|(o, _)| o.clone()).collect();
                assert_eq!(distributed.len(), reference.len());
                for (i, (a, b)) in distributed.iter().zip(&reference).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{} {policy:?} pad={pad} {balancer:?} idx {i}: {a} vs {b}",
                        profile.name()
                    );
                }
            }
        }
    }
}

/// A folded `tp·cp ≠ etp·ep` topology (TP2·CP1 attention vs ETP1·EP4 MoE
/// on 8 ranks) routes the same skewed stream bit-identically to the
/// single-rank reference. The seq-drop scope is the TP×CP block of 2
/// consecutive ranks, so the full-sequence reference routes 2-rank chunks.
#[test]
fn folded_topology_skewed_stream_matches_reference() {
    let cfg_p = ParallelConfig::new(8, 2, 1, 4, 1, 1);
    assert_ne!(cfg_p.attn_inner(), cfg_p.moe_inner());
    let topo = RuntimeTopology::folded(cfg_p).unwrap();
    let world = 8;
    let n_per_rank = 12;
    let profile = SkewProfile::Zipf { exponent: 1.2 };
    let experts = build_experts(7);
    for (policy, chunk) in [
        (DropPolicy::Dropless, Some(n_per_rank)),
        (DropPolicy::SubSequence, Some(n_per_rank)),
        (DropPolicy::FullSequence, Some(2 * n_per_rank)),
    ] {
        for balancer in [
            Balancer::AuxLoss,
            Balancer::AuxFree { update_rate: 0.05 },
            Balancer::Sinkhorn { iters: 16 },
        ] {
            let full_seq = matches!(policy, DropPolicy::FullSequence);
            if full_seq && matches!(balancer, Balancer::Sinkhorn { .. }) {
                continue; // batch-coupled plan: no whole-scope reference
            }
            let mut gen = SkewGen::new(profile, E, H, 99);
            let mut router = gen.router(cfg(policy, false, balancer));
            if let Balancer::AuxFree { update_rate } = balancer {
                router = router.with_bias(warmed_bias(profile, update_rate));
            }
            let tokens = gen.next_tokens(world * n_per_rank);
            let outs = run_ranks(world, |rank, comm| {
                let layer =
                    DistributedMoeLayer::from_topology(topo.view(rank), router.clone(), &experts);
                let mine = tokens[rank * n_per_rank * H..(rank + 1) * n_per_rank * H].to_vec();
                layer.forward(&comm, &mine).0
            });
            let reference = reference_moe_forward(&router, &experts, &tokens, chunk);
            let distributed: Vec<f32> = outs.concat();
            assert_eq!(distributed.len(), reference.len());
            for (i, (a, b)) in distributed.iter().zip(&reference).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{policy:?} {balancer:?} idx {i}: {a} vs {b}"
                );
            }
        }
    }
}

/// ETP sharding splits each expert's FFN reduction across ranks, which
/// reorders the f32 accumulation — so the skewed stream matches the
/// reference within tolerance rather than bitwise.
#[test]
fn etp_sharded_skewed_stream_matches_reference_within_tolerance() {
    let (ep, etp) = (2, 2);
    let world = ep * etp;
    let n_per_rank = 16;
    let experts = build_experts(11);
    for balancer in [Balancer::AuxLoss, Balancer::Sinkhorn { iters: 16 }] {
        let mut gen = SkewGen::new(SkewProfile::Zipf { exponent: 1.2 }, E, H, 3);
        let router = gen.router(cfg(DropPolicy::SubSequence, false, balancer));
        let tokens = gen.next_tokens(world * n_per_rank);
        let outs = run_ranks(world, |rank, comm| {
            let ep_idx = rank / etp;
            let etp_idx = rank % etp;
            let epr = E / ep;
            let layer = DistributedMoeLayer {
                router: router.clone(),
                local_experts: (0..epr)
                    .map(|le| experts[ep_idx * epr + le].shard(etp, etp_idx))
                    .collect(),
                ep_group: (0..ep).map(|i| i * etp + etp_idx).collect(),
                etp_group: (0..etp).map(|i| ep_idx * etp + i).collect(),
                ep_index: ep_idx,
                num_experts: E,
                seq_group: None,
                phase_cost: None,
                overlap_a2a: false,
                payload: Payload::F32,
            };
            let mine = tokens[rank * n_per_rank * H..(rank + 1) * n_per_rank * H].to_vec();
            layer.forward(&comm, &mine).0
        });
        let reference = reference_moe_forward(&router, &experts, &tokens, Some(n_per_rank));
        let distributed: Vec<f32> = outs.concat();
        for (i, (a, b)) in distributed.iter().zip(&reference).enumerate() {
            assert!(
                (a - b).abs() < 2e-4 * (1.0 + b.abs()),
                "{balancer:?} idx {i}: {a} vs {b}"
            );
        }
    }
}

/// Weekly-tier scale differential: 128 ranks (TP2·CP1 attention folded
/// over ETP1·EP16), 16 experts, Zipf gates — still bit-identical to the
/// single-rank reference. Picked up by `cargo test --release -- --ignored`.
#[test]
#[ignore = "128-rank differential; runs in the weekly --ignored tier"]
fn large_world_skewed_stream_matches_reference() {
    let e = 16;
    let h = 16;
    let world = 128;
    let n_per_rank = 4;
    let topo = RuntimeTopology::folded(ParallelConfig::new(world, 2, 1, 16, 1, 1)).unwrap();
    let mut rng = Rng::seed_from_u64(21);
    let experts: Vec<SwigluExpert> = (0..e).map(|_| SwigluExpert::init(h, FF, &mut rng)).collect();
    for policy in [DropPolicy::Dropless, DropPolicy::SubSequence] {
        let mut gen = SkewGen::new(SkewProfile::Zipf { exponent: 1.2 }, e, h, 31);
        let router = gen.router(RouterConfig {
            hidden: h,
            num_experts: e,
            top_k: 2,
            capacity_factor: 1.0,
            drop_policy: policy,
            capacity_override: None,
            pad_to_capacity: false,
            node_limit: None,
            balancer: Balancer::AuxLoss,
        });
        let tokens = gen.next_tokens(world * n_per_rank);
        let outs = run_ranks(world, |rank, comm| {
            let layer =
                DistributedMoeLayer::from_topology(topo.view(rank), router.clone(), &experts);
            let mine = tokens[rank * n_per_rank * h..(rank + 1) * n_per_rank * h].to_vec();
            layer.forward(&comm, &mine).0
        });
        let reference = reference_moe_forward(&router, &experts, &tokens, Some(n_per_rank));
        let distributed: Vec<f32> = outs.concat();
        assert_eq!(distributed.len(), reference.len());
        for (i, (a, b)) in distributed.iter().zip(&reference).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{policy:?} idx {i}: {a} vs {b}");
        }
    }
}

/// Cost-triangle regression at CF=1 under Zipf skew: drop mode strictly
/// cuts dispatch a2a bytes vs dropless (dropped copies never travel), and
/// dropless by definition drops nothing.
#[test]
fn drop_mode_cuts_dispatch_bytes_and_dropless_drops_nothing() {
    let ep = 4;
    let n_per_rank = 32;
    let experts = build_experts(17);
    let run = |policy: DropPolicy| {
        let mut gen = SkewGen::new(SkewProfile::Zipf { exponent: 1.2 }, E, H, 23);
        let router = gen.router(cfg(policy, false, Balancer::AuxLoss));
        let tokens = gen.next_tokens(ep * n_per_rank);
        let outs = run_ep_layer(&router, &experts, &tokens, ep, n_per_rank, false);
        let send: usize = outs.iter().map(|(_, s)| s.a2a_send_bytes).sum();
        let dropped: usize = outs.iter().map(|(_, s)| s.tokens_dropped).sum();
        (send, dropped)
    };
    let (dropless_bytes, dropless_dropped) = run(DropPolicy::Dropless);
    let (drop_bytes, drop_dropped) = run(DropPolicy::SubSequence);
    assert_eq!(dropless_dropped, 0, "dropless must not drop");
    assert!(drop_dropped > 0, "zipf at CF=1 must overflow some expert bin");
    assert!(
        drop_bytes < dropless_bytes,
        "dropping must cut dispatch a2a bytes: {drop_bytes} vs {dropless_bytes}"
    );
}

/// Cost-triangle regression for pad mode: the dispatch a2a ships the same
/// closed-form byte count whether the gate stream is Zipf-skewed or
/// uniform — static shapes are what the padding bytes buy.
#[test]
fn pad_mode_a2a_volume_is_skew_invariant() {
    let ep = 4;
    let n_per_rank = 32;
    let experts = build_experts(19);
    let per_rank_bytes = |profile: SkewProfile| {
        let mut gen = SkewGen::new(profile, E, H, 29);
        let router = gen.router(cfg(DropPolicy::SubSequence, true, Balancer::AuxLoss));
        let tokens = gen.next_tokens(ep * n_per_rank);
        let outs = run_ep_layer(&router, &experts, &tokens, ep, n_per_rank, false);
        outs.iter().map(|(_, s)| s.a2a_send_bytes).collect::<Vec<_>>()
    };
    let zipf = per_rank_bytes(SkewProfile::Zipf { exponent: 1.2 });
    let uniform = per_rank_bytes(SkewProfile::Uniform);
    assert_eq!(zipf, uniform, "padded dispatch volume must not depend on skew");
    let router = SkewGen::new(SkewProfile::Uniform, E, H, 29)
        .router(cfg(DropPolicy::SubSequence, true, Balancer::AuxLoss));
    let cap = router.capacity_for(n_per_rank);
    let epr = E / ep;
    // ep peers × (epr counts + epr·capacity·H rows) × 4 bytes.
    for b in &zipf {
        assert_eq!(*b, ep * (epr + epr * cap * H) * 4);
    }
}

/// Tier-1 acceptance pin: on one identical Zipf gate stream, both new
/// balancers beat the plain aux-loss router's max/mean expert-load
/// imbalance — aux-loss-free via bias feedback between chunks, Sinkhorn
/// by re-planning each chunk. Load is measured after a warmup prefix so
/// the aux-free bias has converged.
#[test]
fn balancers_reduce_zipf_load_imbalance() {
    let chunks = 48;
    let chunk_tokens = 64;
    let warmup = 32;
    let profile = SkewProfile::Zipf { exponent: 1.2 };
    let stream: Vec<Vec<f32>> = {
        let mut gen = SkewGen::new(profile, E, H, 4242);
        (0..chunks).map(|_| gen.next_tokens(chunk_tokens)).collect()
    };
    let run = |balancer: Balancer| {
        let gen = SkewGen::new(profile, E, H, 0);
        let mut router = gen.router(cfg(DropPolicy::Dropless, false, balancer));
        let mut load = vec![0usize; E];
        for (i, chunk) in stream.iter().enumerate() {
            let d = router.route(chunk);
            if i >= warmup {
                for (l, &c) in load.iter_mut().zip(&d.expert_load) {
                    *l += c;
                }
            }
            router.update_bias(&d.expert_load);
        }
        LoadStats::from_load(&load).imbalance
    };
    let plain = run(Balancer::AuxLoss);
    let aux_free = run(Balancer::AuxFree { update_rate: 0.05 });
    let sinkhorn = run(Balancer::Sinkhorn { iters: 32 });
    assert!(plain > 1.5, "plain router must stay skewed under zipf, got {plain}");
    assert!(aux_free < plain, "aux-free {aux_free} must beat plain {plain}");
    assert!(sinkhorn < plain, "sinkhorn {sinkhorn} must beat plain {plain}");
}
