//! Integration: 1F1B schedules compose with the perf model at paper scale.
use moe_folding::pipeline::{bubble_fraction, schedule_1f1b, simulate_1f1b, PipeOp};

/// The paper's configurations: PP8 with 32 microbatches (Mixtral) and PP16
/// with 16 (Llama3 at GBS 256 / DP 16... representative values).
#[test]
fn paper_scale_bubbles() {
    // Mixtral MCore: pp=8, m=32 -> bubble 18%.
    let b = bubble_fraction(8, 32);
    assert!((b - 7.0 / 39.0).abs() < 1e-12);
    // Simulation agrees within 5%.
    let t = simulate_1f1b(8, 32, 1000.0, 2000.0, 10.0);
    let ideal = 32.0 * 3000.0;
    let sim_bubble = (t - ideal) / t;
    assert!((sim_bubble - b).abs() < 0.05, "sim {sim_bubble} analytic {b}");
}

/// Dependency correctness: no stage runs a microbatch's bwd before its fwd
/// completed on the last stage.
#[test]
fn schedule_respects_dependencies() {
    for pp in [2, 4, 8] {
        for m in [pp, 2 * pp, 4 * pp] {
            for stage in 0..pp {
                let ops = schedule_1f1b(stage, pp, m);
                let mut seen_fwd = vec![false; m];
                for op in ops {
                    match op {
                        PipeOp::Fwd { mb, .. } => seen_fwd[mb] = true,
                        PipeOp::Bwd { mb, .. } => {
                            assert!(seen_fwd[mb], "pp{pp} m{m} stage{stage}: bwd {mb} before fwd")
                        }
                    }
                }
            }
        }
    }
}

/// More microbatches always reduce the simulated bubble fraction.
#[test]
fn bubble_shrinks_with_microbatches() {
    let mut last = f64::INFINITY;
    for m in [8, 16, 32, 64] {
        let t = simulate_1f1b(8, m, 500.0, 1000.0, 5.0);
        let frac = (t - m as f64 * 1500.0) / t;
        assert!(frac < last);
        last = frac;
    }
}

/// Makespan is monotone in compute times and p2p latency.
#[test]
fn makespan_monotonicity() {
    let base = simulate_1f1b(4, 16, 100.0, 200.0, 1.0);
    assert!(simulate_1f1b(4, 16, 110.0, 200.0, 1.0) > base);
    assert!(simulate_1f1b(4, 16, 100.0, 220.0, 1.0) > base);
    assert!(simulate_1f1b(4, 16, 100.0, 200.0, 50.0) > base);
}
