//! Scheduling differential suite (ISSUE 4): the executed interleaved-1F1B
//! schedule against its closed forms, the plain-1F1B degenerate case, and
//! the overlap acceptance criteria on the paper's Table-3 folded optima.
//!
//! 1. **Closed form** — the executed interleaved makespan with zero-cost
//!    hand-offs equals `(m·vpp + pp − 1)(f + b)` (the form implied by
//!    `bubble_fraction_interleaved`) to float precision across a
//!    (pp, m, vpp) sweep.
//! 2. **Degenerate case** — `vpp = 1` is bitwise-identical in outputs,
//!    input gradients and losses to the existing `execute_1f1b_mapped`,
//!    and equal in clocked makespan.
//! 3. **Acceptance (Table-3)** — for all four folded optima: overlap-on
//!    executed step ≤ serialized executed step, within 2% of the analytic
//!    estimate (which keeps its overlap credit), and `vpp > 1` shrinks the
//!    measured bubble toward `bubble_fraction_interleaved`.
//! 4. **Loss invariance** — one folded program's losses are bit-identical
//!    across clocked/unclocked, dispatcher overlapped/serialized, and vpp
//!    settings (layer blocks placed by global block index, so the composed
//!    function is literally the same f32 program).

use moe_folding::cluster::ClusterSpec;
use moe_folding::collectives::CommCost;
use moe_folding::config::{DropPolicy, ModelConfig, ParallelConfig, TrainConfig};
use moe_folding::dispatcher::{Balancer, DistributedMoeLayer, Router, RouterConfig};
use moe_folding::mapping::RuntimeTopology;
use moe_folding::perfmodel::{execute_step, PerfModel, Strategy};
use moe_folding::pipeline::{
    bubble_fraction_interleaved, execute_1f1b_mapped, execute_1f1b_timed,
    execute_interleaved_mapped, execute_interleaved_timed,
};
use moe_folding::simcomm::{run_ranks, run_ranks_on, AlgoSelection, Fabric};
use moe_folding::train::math::SwigluExpert;
use moe_folding::util::Rng;

fn zero_latency_cost(world: usize) -> CommCost {
    let mut cluster = ClusterSpec::eos(world);
    cluster.nvlink_latency_us = 0.0;
    cluster.ib_latency_us = 0.0;
    CommCost::new(cluster)
}

/// Satellite 1: executed interleaved makespan with free hand-offs equals
/// the closed form implied by `bubble_fraction_interleaved` to float
/// precision, across a (pp, m, vpp) sweep.
#[test]
fn executed_interleaved_matches_interleaved_closed_form() {
    let (f, b) = (120.0, 260.0);
    for pp in [2usize, 4, 8] {
        for m in [pp, 2 * pp, 4 * pp] {
            for vpp in [1usize, 2, 4] {
                let fabric = Fabric::new_clocked(
                    pp,
                    AlgoSelection::fast(),
                    zero_latency_cost(pp),
                );
                let group: Vec<usize> = (0..pp).collect();
                let outs = run_ranks_on(&fabric, |_, comm| {
                    execute_interleaved_timed(&comm, &group, m, vpp, f, b, 0.0)
                });
                let executed = outs.iter().map(|r| r.finish_us).fold(0.0, f64::max);
                let closed = (m * vpp + pp - 1) as f64 * (f + b);
                assert!(
                    (executed - closed).abs() < 1e-9 * closed,
                    "pp={pp} m={m} vpp={vpp}: executed {executed} vs closed {closed}"
                );
                // Consistency with the bubble-fraction form: makespan =
                // ideal / (1 − bubble).
                let ideal = (m * vpp) as f64 * (f + b);
                let bubble = bubble_fraction_interleaved(pp, m, vpp);
                let from_bubble = ideal / (1.0 - bubble);
                assert!(
                    (executed - from_bubble).abs() < 1e-9 * from_bubble,
                    "pp={pp} m={m} vpp={vpp}: {executed} vs bubble-form {from_bubble}"
                );
            }
        }
    }
}

/// Satellite 2a: `vpp = 1` interleaved execution is bitwise-identical to
/// the existing `execute_1f1b_mapped` on real payloads.
#[test]
fn vpp1_bitwise_identical_to_plain_1f1b() {
    let cfg = ParallelConfig::new(8, 2, 1, 2, 1, 2);
    let topo = RuntimeTopology::folded(cfg).unwrap();
    let m = 6;
    let width = 7;
    let inputs: Vec<Vec<f32>> =
        (0..m).map(|mb| vec![0.37 * (mb as f32 + 1.0); width]).collect();
    let run_plain = || {
        run_ranks(8, |rank, comm| {
            let a = 1.0 + 0.25 * (rank % 4) as f32;
            execute_1f1b_mapped(
                &comm,
                &topo,
                m,
                &inputs,
                |_mb, x| x.iter().map(|v| a * v + 0.125).collect(),
                |_mb, g| g.iter().map(|v| a * v).collect(),
            )
        })
    };
    let run_inter = || {
        run_ranks(8, |rank, comm| {
            let a = 1.0 + 0.25 * (rank % 4) as f32;
            execute_interleaved_mapped(
                &comm,
                &topo,
                m,
                1,
                &inputs,
                |_chunk, _mb, x| x.iter().map(|v| a * v + 0.125).collect(),
                |_chunk, _mb, g| g.iter().map(|v| a * v).collect(),
            )
        })
    };
    let plain = run_plain();
    let inter = run_inter();
    for rank in 0..8 {
        assert_eq!(plain[rank].outputs.len(), inter[rank].outputs.len());
        for (mb, (p, i)) in plain[rank].outputs.iter().zip(&inter[rank].outputs).enumerate() {
            assert_eq!(p.len(), i.len());
            for (x, y) in p.iter().zip(i) {
                assert_eq!(x.to_bits(), y.to_bits(), "rank {rank} mb {mb} output");
            }
        }
        for (mb, (p, i)) in
            plain[rank].input_grads.iter().zip(&inter[rank].input_grads).enumerate()
        {
            for (x, y) in p.iter().zip(i) {
                assert_eq!(x.to_bits(), y.to_bits(), "rank {rank} mb {mb} grad");
            }
        }
    }
}

/// Satellite 2b: `vpp = 1` interleaved execution is equal in clocked
/// makespan to the plain executor (same ops, same billing — only the
/// message tags differ, and tags are clock-free).
#[test]
fn vpp1_equal_makespan_to_plain_1f1b() {
    for (pp, m, f, b, p2p_bytes) in
        [(2usize, 4usize, 100.0, 200.0, 0.0), (4, 8, 120.0, 240.0, 2.0e6)]
    {
        let group: Vec<usize> = (0..pp).collect();
        let run = |interleaved: bool| {
            let fabric =
                Fabric::new_clocked(pp, AlgoSelection::fast(), zero_latency_cost(pp));
            let outs = run_ranks_on(&fabric, |_, comm| {
                if interleaved {
                    execute_interleaved_timed(&comm, &group, m, 1, f, b, p2p_bytes)
                } else {
                    execute_1f1b_timed(&comm, &group, m, f, b, p2p_bytes)
                }
            });
            outs.iter().map(|r| r.finish_us).fold(0.0, f64::max)
        };
        let plain = run(false);
        let inter = run(true);
        assert!(
            (plain - inter).abs() < 1e-9,
            "pp={pp} m={m}: plain {plain} vs interleaved-vpp1 {inter}"
        );
    }
}

/// The Table-3 folded optima with their maximal interleave (one layer per
/// virtual chunk): `(model, gpus, tp, cp, ep, etp, pp, vpp)`.
fn table3_optima() -> Vec<(ModelConfig, usize, usize, usize, usize, usize, usize, usize)> {
    vec![
        (ModelConfig::mixtral_8x22b(), 128, 2, 1, 8, 1, 8, 7),
        (ModelConfig::qwen2_57b_a14b(), 64, 2, 1, 4, 1, 4, 7),
        (ModelConfig::mixtral_8x22b_g8t8(), 128, 4, 1, 8, 1, 8, 4),
        (ModelConfig::llama3_8x70b(), 256, 8, 1, 8, 1, 16, 5),
    ]
}

/// Acceptance: for all four Table-3 folded optima, the executed step with
/// overlap enabled is ≤ the serialized executed step and within 2% of the
/// analytic estimate (which keeps its overlap credit); `vpp > 1` shrinks
/// the measured bubble fraction toward `bubble_fraction_interleaved`.
#[test]
fn table3_overlap_and_vpp_acceptance() {
    let pm = PerfModel::default();
    let mut overlap_train = TrainConfig::paper_default(4096, 256);
    overlap_train.overlap_a2a = true;
    assert!(overlap_train.overlap_grad_reduce);
    let mut serial_train = overlap_train.clone();
    serial_train.overlap_grad_reduce = false;
    serial_train.overlap_param_gather = false;
    serial_train.overlap_a2a = false;
    for (model, w, tp, cp, ep, etp, pp, vpp) in table3_optima() {
        let cfg = ParallelConfig::new(w, tp, cp, ep, etp, pp);
        let analytic = pm
            .estimate(&model, cfg, &overlap_train, Strategy::MCoreFolding)
            .unwrap_or_else(|e| panic!("{}: {e}", cfg.tag()));
        let overlapped = execute_step(&pm, &model, cfg, &overlap_train, Strategy::MCoreFolding)
            .unwrap_or_else(|e| panic!("{}: {e}", cfg.tag()));
        let serialized = execute_step(&pm, &model, cfg, &serial_train, Strategy::MCoreFolding)
            .unwrap_or_else(|e| panic!("{}: {e}", cfg.tag()));
        assert!(
            overlapped.step_ms <= serialized.step_ms + 1e-9,
            "{} ({}): overlap {:.1} ms > serialized {:.1} ms",
            model.name,
            cfg.tag(),
            overlapped.step_ms,
            serialized.step_ms
        );
        let rel = (overlapped.step_ms - analytic.step_ms).abs() / analytic.step_ms;
        assert!(
            rel < 0.02,
            "{} ({}): executed-overlap {:.1} ms vs analytic {:.1} ms (rel {rel:.4})",
            model.name,
            cfg.tag(),
            overlapped.step_ms,
            analytic.step_ms
        );
        assert!(
            overlapped.hidden_comm_us > 0.0,
            "{}: overlap hid nothing",
            cfg.tag()
        );

        // vpp > 1: interleaving measurably shrinks the bubble toward the
        // interleaved closed form.
        let inter_cfg = cfg.with_vpp(vpp);
        let inter =
            execute_step(&pm, &model, inter_cfg, &overlap_train, Strategy::MCoreFolding)
                .unwrap_or_else(|e| panic!("{}: {e}", inter_cfg.tag()));
        let m_micro = overlap_train.num_microbatches(cfg.dp());
        let bf_inter = bubble_fraction_interleaved(pp, m_micro, vpp);
        assert!(
            inter.bubble_fraction < overlapped.bubble_fraction,
            "{}: vpp{} bubble {:.4} !< vpp1 bubble {:.4}",
            cfg.tag(),
            vpp,
            inter.bubble_fraction,
            overlapped.bubble_fraction
        );
        assert!(
            (inter.bubble_fraction - bf_inter).abs() < 0.05,
            "{}: measured vpp bubble {:.4} vs closed form {:.4}",
            inter_cfg.tag(),
            inter.bubble_fraction,
            bf_inter
        );
        // Interleaving shortens the step itself (the bubble is real time).
        assert!(
            inter.step_ms < serialized.step_ms,
            "{}: vpp step {:.1} ms !< serialized vpp1 {:.1} ms",
            inter_cfg.tag(),
            inter.step_ms,
            serialized.step_ms
        );
    }
}

// ---------------------------------------------------------------------
// Loss invariance across clock / dispatcher-overlap / vpp.
// ---------------------------------------------------------------------

const H: usize = 16;
const FF: usize = 32;
const E: usize = 8;
/// Total layer blocks of the toy pipeline model (pp·vpp_max).
const BLOCKS: usize = 4;

/// One folded program: dispatcher forward + interleaved pipeline + world
/// reduction. Layer block `b` applies the same affine map regardless of
/// the (pp, vpp) placement, and blocks compose in global index order on
/// every vpp setting — so the result is one fixed f32 program and must be
/// bit-identical across every execution mode.
fn folded_program(clocked: bool, vpp: usize, overlap_dispatch: bool) -> (Vec<f32>, f64) {
    assert!(BLOCKS % vpp == 0);
    let cfg = ParallelConfig::new(8, 2, 1, 4, 1, 2);
    let topo = RuntimeTopology::folded(cfg).unwrap();
    let mut rng = Rng::seed_from_u64(77);
    let router = Router::init(
        RouterConfig {
            hidden: H,
            num_experts: E,
            top_k: 2,
            capacity_factor: 1.1,
            drop_policy: DropPolicy::SubSequence,
            capacity_override: None,
            pad_to_capacity: false,
            node_limit: None,
            balancer: Balancer::AuxLoss,
        },
        &mut rng,
    );
    let experts: Vec<SwigluExpert> =
        (0..E).map(|_| SwigluExpert::init(H, FF, &mut rng)).collect();
    let n_per_rank = 12;
    let mut tokens = vec![0.0f32; 8 * n_per_rank * H];
    rng.fill_normal(&mut tokens, 1.0);
    let m = 4;
    let inputs: Vec<Vec<f32>> = (0..m).map(|mb| vec![0.5 + mb as f32; 6]).collect();
    let pp = 2usize;
    let blocks_per_chunk = BLOCKS / (pp * vpp);
    let block_coef = |b: usize| 0.9 + 0.05 * b as f32;

    let fabric = if clocked {
        Fabric::new_clocked(8, AlgoSelection::fast(), CommCost::new(ClusterSpec::eos(8)))
    } else {
        Fabric::new_with(8, AlgoSelection::fast())
    };
    let outs = run_ranks_on(&fabric, |rank, comm| {
        let layer = DistributedMoeLayer::from_topology(topo.view(rank), router.clone(), &experts)
            .with_overlap(overlap_dispatch);
        let mine = tokens[rank * n_per_rank * H..(rank + 1) * n_per_rank * H].to_vec();
        let (moe_out, _) = layer.forward(&comm, &mine);
        let stage = topo.view(rank).pp_stage;
        let apply_blocks = |first: usize, x: &[f32]| -> Vec<f32> {
            let mut y = x.to_vec();
            for b in first..first + blocks_per_chunk {
                let a = block_coef(b);
                for v in y.iter_mut() {
                    *v = a * *v + 0.0625;
                }
            }
            y
        };
        let apply_blocks_bwd = |first: usize, g: &[f32]| -> Vec<f32> {
            let mut y = g.to_vec();
            for b in (first..first + blocks_per_chunk).rev() {
                let a = block_coef(b);
                for v in y.iter_mut() {
                    *v *= a;
                }
            }
            y
        };
        let pipe = execute_interleaved_mapped(
            &comm,
            &topo,
            m,
            vpp,
            &inputs,
            |chunk, _mb, x| apply_blocks((chunk * pp + stage) * blocks_per_chunk, x),
            |chunk, _mb, g| apply_blocks_bwd((chunk * pp + stage) * blocks_per_chunk, g),
        );
        let mut acc: f32 = moe_out.iter().sum();
        for o in &pipe.outputs {
            acc += o.iter().sum::<f32>();
        }
        for g in &pipe.input_grads {
            acc += g.iter().sum::<f32>();
        }
        let all: Vec<usize> = (0..8).collect();
        comm.all_reduce_sum(&all, &[acc])[0]
    });
    let makespan = fabric.max_sim_time_us();
    (outs, makespan)
}

/// Acceptance: losses are bit-identical across clocked/unclocked,
/// dispatcher overlapped/serialized, and vpp settings; the clock
/// accumulates time only when enabled.
#[test]
fn losses_bitwise_invariant_across_clock_overlap_vpp() {
    let (reference, t0) = folded_program(false, 1, false);
    assert_eq!(t0, 0.0);
    for clocked in [false, true] {
        for vpp in [1usize, 2] {
            for overlap in [false, true] {
                let (losses, t) = folded_program(clocked, vpp, overlap);
                if clocked {
                    assert!(t > 0.0, "clocked run must accumulate time");
                }
                for (rank, (a, b)) in reference.iter().zip(&losses).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "rank {rank}: clocked={clocked} vpp={vpp} overlap={overlap}: \
                         {a} vs {b}"
                    );
                }
            }
        }
    }
}

/// Large-world executed suite (≥ 128 ranks with interleaving + overlap).
/// Formerly `--ignored` (weekly CI) when each rank was an OS thread; the
/// event engine (ISSUE 6) runs these worlds single-threaded, so the sweep
/// is tier-1 now.
#[test]
fn large_world_interleaved_overlap_sweep() {
    let pm = PerfModel::default();
    let mut train = TrainConfig::paper_default(4096, 256);
    train.overlap_a2a = true;
    for (model, w, tp, cp, ep, etp, pp, vpp) in table3_optima() {
        if w < 128 {
            continue;
        }
        let cfg = ParallelConfig::new(w, tp, cp, ep, etp, pp).with_vpp(vpp);
        let executed = execute_step(&pm, &model, cfg, &train, Strategy::MCoreFolding)
            .unwrap_or_else(|e| panic!("{}: {e}", cfg.tag()));
        let analytic = pm
            .estimate(&model, cfg, &train, Strategy::MCoreFolding)
            .unwrap_or_else(|e| panic!("{}: {e}", cfg.tag()));
        let rel = (executed.step_ms - analytic.step_ms).abs() / analytic.step_ms;
        assert!(
            rel < 0.05,
            "{} ({}): executed {:.1} ms vs analytic {:.1} ms (rel {rel:.4})",
            model.name,
            cfg.tag(),
            executed.step_ms,
            analytic.step_ms
        );
        assert!(executed.hidden_comm_us > 0.0);
    }
}
