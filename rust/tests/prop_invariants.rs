//! Property tests (in-crate `util::prop` scaffold — no proptest offline):
//! invariants of the mapping, dispatcher, collectives, and pipeline.
use moe_folding::cluster::ClusterSpec;
use moe_folding::collectives::CommModel;
use moe_folding::config::{DropPolicy, ParallelConfig};
use moe_folding::dispatcher::{Assignment, Balancer, Permutation, Router, RouterConfig};
use moe_folding::mapping::{ParallelMapping, RuntimeTopology};
use moe_folding::pipeline::{bubble_fraction, simulate_1f1b};
use moe_folding::util::prop::{draw, forall};
use moe_folding::util::Rng;

/// Random legal folded configs: every axis partitions the world exactly and
/// PP stays consistent between attention and MoE grids.
#[test]
fn prop_folded_mapping_partitions() {
    forall(
        "folded mapping invariants",
        60,
        |rng: &mut Rng| {
            let tp = draw::pow2_upto(rng, 8);
            let cp = draw::pow2_upto(rng, 4);
            let pp = draw::pow2_upto(rng, 4);
            let ep = draw::pow2_upto(rng, 8);
            let etp = draw::pow2_upto(rng, 4);
            let dp = draw::pow2_upto(rng, 4);
            // world must be divisible by both inner products.
            let attn = tp * cp * pp * dp;
            let moe = etp * ep * pp;
            let world = attn * moe / gcd(attn, moe);
            let world = world.min(1 << 12);
            (world, tp, cp, ep, etp, pp)
        },
        |&(world, tp, cp, ep, etp, pp)| {
            let cfg = ParallelConfig::new(world, tp, cp, ep, etp, pp);
            if cfg.validate_ok() {
                let m = ParallelMapping::folded(cfg)?;
                m.check_invariants()?;
                m.validate_pp_consistency()?;
            }
            Ok(())
        },
    );
}

/// Exhaustive (not sampled): for **every** legal `(tp, cp, etp, ep, pp)`
/// combination at worlds 8/16/32, the folded mapping's axis partitions each
/// tile `0..world` exactly — disjoint, covering, equal-sized, including the
/// MoE-side ETP/EDP axes — and the attention and MoE PP partitions
/// coincide. This is the invariant the runtime topology layer
/// (`mapping::runtime`) builds per-rank views on, so the same sweep also
/// materializes a `RuntimeTopology` for each combination (its constructor
/// re-validates group membership, stage ordering, and sequence blocks).
#[test]
fn prop_folded_tiles_every_legal_combo_at_worlds_8_16_32() {
    for world in [8usize, 16, 32] {
        let divisors: Vec<usize> = (1..=world).filter(|d| world % d == 0).collect();
        let mut checked = 0usize;
        for &tp in &divisors {
            for &cp in &divisors {
                for &pp in &divisors {
                    if world % (tp * cp * pp) != 0 {
                        continue;
                    }
                    for &ep in &divisors {
                        for &etp in &divisors {
                            if world % (etp * ep * pp) != 0 {
                                continue;
                            }
                            let cfg = ParallelConfig::new(world, tp, cp, ep, etp, pp);
                            let m = ParallelMapping::folded(cfg)
                                .unwrap_or_else(|e| panic!("{}: {e}", cfg.tag()));
                            m.check_invariants()
                                .unwrap_or_else(|e| panic!("{}: {e}", cfg.tag()));
                            m.validate_pp_consistency()
                                .unwrap_or_else(|e| panic!("{}: {e}", cfg.tag()));
                            let topo = RuntimeTopology::from_mapping(m)
                                .unwrap_or_else(|e| panic!("{}: {e}", cfg.tag()));
                            // Spot-check view coherence on every rank.
                            for v in topo.views() {
                                assert_eq!(v.ep_group[v.ep_index], v.rank);
                                assert_eq!(v.dp_group[v.dp_index], v.rank);
                                assert_eq!(v.edp_group[v.edp_index], v.rank);
                                assert_eq!(v.pp_group[v.pp_stage], v.rank);
                                assert!(v.seq_group.contains(&v.rank));
                            }
                            checked += 1;
                        }
                    }
                }
            }
        }
        assert!(checked > 50, "world {world}: only {checked} legal combos swept");
    }
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 { a } else { gcd(b, a % b) }
}

trait ValidateOk {
    fn validate_ok(&self) -> bool;
}
impl ValidateOk for ParallelConfig {
    fn validate_ok(&self) -> bool {
        self.world_size % (self.tp * self.cp * self.pp) == 0
            && self.world_size % (self.etp * self.ep * self.pp) == 0
    }
}

/// Permute/unpermute roundtrip: with probs summing to 1 per token and an
/// identity expert, output == input for every random routing.
#[test]
fn prop_permutation_roundtrip() {
    forall(
        "permutation roundtrip",
        100,
        |rng: &mut Rng| {
            let n = draw::in_range(rng, 1, 64);
            let e = draw::in_range(rng, 1, 16);
            let h = draw::in_range(rng, 1, 8);
            let mut assignments = Vec::new();
            for t in 0..n {
                // two copies with probs 0.4/0.6
                assignments.push(Assignment {
                    token: t,
                    expert: rng.next_below(e),
                    prob: 0.4,
                    kept: true,
                });
                assignments.push(Assignment {
                    token: t,
                    expert: rng.next_below(e),
                    prob: 0.6,
                    kept: true,
                });
            }
            let mut tokens = vec![0.0f32; n * h];
            rng.fill_normal(&mut tokens, 1.0);
            (n, e, h, assignments, tokens)
        },
        |(n, e, h, assignments, tokens)| {
            let p = Permutation::from_assignments(assignments, *e);
            if p.total() != assignments.len() {
                return Err(format!("lost copies: {} vs {}", p.total(), assignments.len()));
            }
            let permuted = p.permute(tokens, *h, assignments);
            let restored = p.unpermute_accumulate(&permuted, *h, assignments, *n);
            for (a, b) in tokens.iter().zip(&restored) {
                if (a - b).abs() > 1e-5 {
                    return Err(format!("{a} vs {b}"));
                }
            }
            Ok(())
        },
    );
}

/// Permutation round-trip in the presence of dropped copies: exactly one
/// kept copy per token with prob 1.0 (plus random dropped extras) makes
/// permute∘unpermute the identity **bit-for-bit**, and the plan must cover
/// exactly the kept assignment indices.
#[test]
fn prop_permutation_roundtrip_with_drops() {
    forall(
        "permutation roundtrip with drops",
        80,
        |rng: &mut Rng| {
            let n = draw::in_range(rng, 1, 48);
            let e = draw::in_range(rng, 1, 12);
            let h = draw::in_range(rng, 1, 6);
            let mut assignments = Vec::new();
            for t in 0..n {
                assignments.push(Assignment {
                    token: t,
                    expert: rng.next_below(e),
                    prob: 1.0,
                    kept: true,
                });
                if rng.next_below(2) == 0 {
                    // Dropped copies must not contribute to the plan.
                    assignments.push(Assignment {
                        token: t,
                        expert: rng.next_below(e),
                        prob: 0.7,
                        kept: false,
                    });
                }
            }
            let mut tokens = vec![0.0f32; n * h];
            rng.fill_normal(&mut tokens, 1.0);
            (n, e, h, assignments, tokens)
        },
        |(n, e, h, assignments, tokens)| {
            let p = Permutation::from_assignments(assignments, *e);
            let kept: Vec<usize> = assignments
                .iter()
                .enumerate()
                .filter(|(_, a)| a.kept)
                .map(|(i, _)| i)
                .collect();
            if p.total() != kept.len() {
                return Err(format!("plan covers {} copies, kept {}", p.total(), kept.len()));
            }
            let mut order = p.order.clone();
            order.sort_unstable();
            if order != kept {
                return Err("order is not a permutation of the kept copies".into());
            }
            let permuted = p.permute(tokens, *h, assignments);
            let restored = p.unpermute_accumulate(&permuted, *h, assignments, *n);
            for (i, (a, b)) in tokens.iter().zip(&restored).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("idx {i}: {a} vs {b} (not bit-identical)"));
                }
            }
            Ok(())
        },
    );
}

/// Router capacity invariants under both dropping scopes:
/// `tokens_routed + tokens_dropped == n·top_k`, per-expert load ≤ derived
/// capacity, and `expert_load` sums to the kept count.
#[test]
fn prop_router_capacity_invariants() {
    forall(
        "router capacity invariants",
        60,
        |rng: &mut Rng| {
            let e = draw::pow2_upto(rng, 16).max(2);
            let k = draw::in_range(rng, 1, e.min(4));
            let n = draw::in_range(rng, 1, 96);
            let cf = 0.5 + rng.next_f64() * 2.0;
            let policy = if rng.next_below(2) == 0 {
                DropPolicy::SubSequence
            } else {
                DropPolicy::FullSequence
            };
            let seed = rng.next_u64();
            (e, k, n, cf, policy, seed)
        },
        |&(e, k, n, cf, policy, seed)| {
            let mut rng = Rng::seed_from_u64(seed);
            let router = Router::init(
                RouterConfig {
                    hidden: 16,
                    num_experts: e,
                    top_k: k,
                    capacity_factor: cf,
                    drop_policy: policy,
                    capacity_override: None,
                    pad_to_capacity: false,
                    node_limit: None,
                    balancer: Balancer::AuxLoss,
                },
                &mut rng,
            );
            let mut tokens = vec![0.0f32; n * 16];
            rng.fill_normal(&mut tokens, 1.0);
            let d = router.route(&tokens);
            if d.assignments.len() != n * k {
                return Err(format!("{} assignments, expected {}", d.assignments.len(), n * k));
            }
            let kept = d.assignments.iter().filter(|a| a.kept).count();
            let dropped = d.assignments.len() - kept;
            if kept + dropped != n * k {
                return Err(format!("conservation: {kept} + {dropped} != {}", n * k));
            }
            let capacity = ((cf * n as f64 * k as f64 / e as f64).ceil() as usize).max(1);
            if router.capacity_for(n) != capacity {
                return Err(format!(
                    "capacity_for {} != derived {capacity}",
                    router.capacity_for(n)
                ));
            }
            if d.capacity != capacity {
                return Err(format!("decision capacity {} != {capacity}", d.capacity));
            }
            for (ex, &load) in d.expert_load.iter().enumerate() {
                if load > capacity {
                    return Err(format!("expert {ex}: load {load} > capacity {capacity}"));
                }
            }
            if d.expert_load.iter().sum::<usize>() != kept {
                return Err("expert_load sum != kept copies".into());
            }
            Ok(())
        },
    );
}

/// Pad-to-capacity dispatch invariants (paper: drop **with** padding): for
/// random (experts, top-k, CF, tokens) over a 2-rank EP group, the padded
/// dispatch volume is *static* — exactly `ep · (epr + epr·capacity·h)`
/// f32s per rank — padding conservation holds
/// (`routed + padded == E·capacity` per rank), and outputs stay
/// bit-identical to the unpadded drop mode.
#[test]
fn prop_padded_dispatch_static_volume_and_bit_equality() {
    use moe_folding::dispatcher::DistributedMoeLayer;
    use moe_folding::simcomm::run_ranks;
    use moe_folding::train::math::SwigluExpert;

    forall(
        "padded dispatch invariants",
        16,
        |rng: &mut Rng| {
            let e = draw::pow2_upto(rng, 8).max(2);
            let k = draw::in_range(rng, 1, e.min(3));
            let n = draw::in_range(rng, 4, 24);
            let cf = 0.5 + rng.next_f64() * 1.5;
            let seed = rng.next_u64();
            (e, k, n, cf, seed)
        },
        |&(e, k, n, cf, seed)| {
            let h = 8usize;
            let mut rng = Rng::seed_from_u64(seed);
            let experts: Vec<SwigluExpert> =
                (0..e).map(|_| SwigluExpert::init(h, 16, &mut rng)).collect();
            let mut tokens = vec![0.0f32; 2 * n * h];
            rng.fill_normal(&mut tokens, 1.0);
            let topo = RuntimeTopology::folded(ParallelConfig::new(2, 1, 1, 2, 1, 1))?;
            let run = |pad: bool| {
                run_ranks(2, |rank, comm| {
                    let mut r2 = Rng::seed_from_u64(seed ^ 0x5ca1ab1e);
                    let router = Router::init(
                        RouterConfig {
                            hidden: h,
                            num_experts: e,
                            top_k: k,
                            capacity_factor: cf,
                            drop_policy: DropPolicy::SubSequence,
                            capacity_override: None,
                            pad_to_capacity: pad,
                            node_limit: None,
                            balancer: Balancer::AuxLoss,
                        },
                        &mut r2,
                    );
                    let layer = DistributedMoeLayer::from_topology(
                        topo.view(rank),
                        router,
                        &experts,
                    );
                    let mine = tokens[rank * n * h..(rank + 1) * n * h].to_vec();
                    layer.forward(&comm, &mine)
                })
            };
            let plain = run(false);
            let padded = run(true);
            let mut r3 = Rng::seed_from_u64(seed ^ 0x5ca1ab1e);
            let router = Router::init(
                RouterConfig {
                    hidden: h,
                    num_experts: e,
                    top_k: k,
                    capacity_factor: cf,
                    drop_policy: DropPolicy::SubSequence,
                    capacity_override: None,
                    pad_to_capacity: true,
                    node_limit: None,
                    balancer: Balancer::AuxLoss,
                },
                &mut r3,
            );
            let capacity = router.capacity_for(n);
            let epr = e / 2;
            for rank in 0..2 {
                let (po, ps) = &padded[rank];
                let (uo, _) = &plain[rank];
                for (i, (a, b)) in po.iter().zip(uo).enumerate() {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("rank {rank} idx {i}: {a} vs {b}"));
                    }
                }
                let want = 2 * (epr + epr * capacity * h) * 4;
                if ps.a2a_send_bytes != want {
                    return Err(format!(
                        "rank {rank}: send bytes {} != static {want}",
                        ps.a2a_send_bytes
                    ));
                }
                if ps.tokens_routed + ps.tokens_padded != e * capacity {
                    return Err(format!(
                        "rank {rank}: routed {} + padded {} != E·cap {}",
                        ps.tokens_routed,
                        ps.tokens_padded,
                        e * capacity
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Satellite (ISSUE 4a): a nonblocking collective with an **immediate
/// wait** is bit-identical in payload and equal in clock price to its
/// blocking counterpart — for every `CollectiveAlgo`, across all five
/// primitives, on a pow-2 group (recursive halving's native shape) with
/// uneven-v payloads.
#[test]
fn prop_nonblocking_immediate_wait_equals_blocking_every_algo() {
    use moe_folding::cluster::ClusterSpec;
    use moe_folding::collectives::CommCost;
    use moe_folding::simcomm::{run_ranks_on, AlgoSelection, CollectiveAlgo, Fabric};

    let algos_all = [
        CollectiveAlgo::NaiveLeader,
        CollectiveAlgo::Ring,
        CollectiveAlgo::RecursiveHalving,
        CollectiveAlgo::PairwiseExchange,
        CollectiveAlgo::Hierarchical,
        CollectiveAlgo::HierarchicalA2A,
    ];
    forall(
        "nonblocking == blocking per algo",
        12,
        |rng: &mut Rng| {
            // 12 = a partial-last-node two-node world (8 + 4), so the
            // hierarchical algorithms cross a real IB boundary here.
            let world = [2usize, 4, 8, 12][rng.next_below(4)];
            let n = draw::in_range(rng, 1, 40);
            let seed = rng.next_u64();
            (world, n, seed)
        },
        |&(world, n, seed)| {
            let group: Vec<usize> = (0..world).collect();
            for algo in algos_all {
                let sel = AlgoSelection {
                    all_reduce: algo,
                    all_gather: algo,
                    reduce_scatter: algo,
                    all_to_all: algo,
                    broadcast: algo,
                };
                // Same program twice: blocking vs i-variant + wait.
                let run = |nonblocking: bool| {
                    let fabric = Fabric::new_clocked(
                        world,
                        sel,
                        CommCost::new(ClusterSpec::eos(world)),
                    );
                    let outs = run_ranks_on(&fabric, |rank, comm| {
                        let mut r = Rng::seed_from_u64(seed ^ (rank as u64) << 3);
                        let mut local = vec![0.0f32; n * world];
                        r.fill_normal(&mut local, 1.0);
                        comm.advance("skew", 3.0 * rank as f64);
                        let counts: Vec<usize> = (0..world).map(|_| n / world + 1).collect();
                        let take: usize = counts.iter().sum();
                        let a2a_len = |p: usize| ((n + p) % 7 + 1).min(n);
                        let mut sink = Vec::new();
                        if nonblocking {
                            let (a, h) = comm.all_reduce_sum_i(&group, &local);
                            comm.wait(h);
                            sink.extend(a);
                            let (b, h) = comm.all_gather_v_i(&group, &local[..n + rank]);
                            comm.wait(h);
                            sink.extend(b);
                            let (c, h) = comm.reduce_scatter_v_i(&group, &local[..take], &counts);
                            comm.wait(h);
                            sink.extend(c);
                            let sends: Vec<Vec<f32>> =
                                (0..world).map(|p| local[..a2a_len(p)].to_vec()).collect();
                            let (d, h) = comm.all_to_all_v_i(&group, sends);
                            comm.wait(h);
                            sink.extend(d.into_iter().flatten());
                            let (e, h) = comm.broadcast_i(&group, world - 1, &local[..n]);
                            comm.wait(h);
                            sink.extend(e);
                        } else {
                            sink.extend(comm.all_reduce_sum(&group, &local));
                            sink.extend(comm.all_gather_v(&group, &local[..n + rank]));
                            sink.extend(comm.reduce_scatter_v(&group, &local[..take], &counts));
                            let sends: Vec<Vec<f32>> =
                                (0..world).map(|p| local[..a2a_len(p)].to_vec()).collect();
                            sink.extend(comm.all_to_all_v(&group, sends).into_iter().flatten());
                            sink.extend(comm.broadcast(&group, world - 1, &local[..n]));
                        }
                        (sink, comm.now_us())
                    });
                    outs
                };
                let blocking = run(false);
                let immediate = run(true);
                for rank in 0..world {
                    let (bp, bt) = &blocking[rank];
                    let (ip, it) = &immediate[rank];
                    if bp.len() != ip.len() {
                        return Err(format!("{algo:?} rank {rank}: payload lengths differ"));
                    }
                    for (k, (x, y)) in bp.iter().zip(ip).enumerate() {
                        if x.to_bits() != y.to_bits() {
                            return Err(format!(
                                "{algo:?} rank {rank} idx {k}: {x} vs {y} (not bit-identical)"
                            ));
                        }
                    }
                    if (bt - it).abs() > 1e-9 {
                        return Err(format!(
                            "{algo:?} rank {rank}: clock {bt} vs {it} (price differs)"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Satellite (ISSUE 4b): enabling the chunk-pipelined (overlapped)
/// dispatcher never changes outputs (bitwise) and never changes the
/// byte-accounting, across random (experts, top-k, capacity, tokens,
/// padding) on a 4-rank EP group; and with **static volumes**
/// (pad-to-capacity, the chunked β is exactly additive) on a zero-latency
/// fabric the overlapped makespan never exceeds the serialized one. (With
/// dynamic volumes, chunking adds per-chunk launch latency and per-chunk
/// imbalance — the at-scale win is pinned separately in
/// `schedule_equivalence.rs` / `clocked_timing.rs`.)
#[test]
fn prop_dispatch_overlap_bitwise_and_never_slower() {
    use moe_folding::cluster::{ClusterSpec, GpuSpec};
    use moe_folding::collectives::CommCost;
    use moe_folding::config::ModelConfig;
    use moe_folding::dispatcher::{DistributedMoeLayer, MoePhaseCost};
    use moe_folding::simcomm::{run_ranks_on, AlgoSelection, Fabric};
    use moe_folding::train::math::SwigluExpert;

    forall(
        "overlapped dispatch invariants",
        10,
        |rng: &mut Rng| {
            let e = [4usize, 8, 16][rng.next_below(3)];
            let k = draw::in_range(rng, 1, 3);
            let n = draw::in_range(rng, 4, 32);
            let pad = rng.next_below(2) == 0;
            let seed = rng.next_u64();
            (e, k, n, pad, seed)
        },
        |&(e, k, n, pad, seed)| {
            let h = 8usize;
            let world = 4usize;
            let mut rng = Rng::seed_from_u64(seed);
            let experts: Vec<SwigluExpert> =
                (0..e).map(|_| SwigluExpert::init(h, 16, &mut rng)).collect();
            let mut tokens = vec![0.0f32; world * n * h];
            rng.fill_normal(&mut tokens, 1.0);
            let topo = RuntimeTopology::folded(ParallelConfig::new(world, 1, 1, 4, 1, 1))?;
            let pc = MoePhaseCost::from_model(&ModelConfig::mixtral_8x22b(), 1, &GpuSpec::h100());
            let run = |overlap: bool| {
                let mut cluster = ClusterSpec::eos(world);
                cluster.nvlink_latency_us = 0.0;
                cluster.ib_latency_us = 0.0;
                let fabric =
                    Fabric::new_clocked(world, AlgoSelection::fast(), CommCost::new(cluster));
                let outs = run_ranks_on(&fabric, |rank, comm| {
                    let mut r2 = Rng::seed_from_u64(seed ^ 0xfeed);
                    let router = Router::init(
                        RouterConfig {
                            hidden: h,
                            num_experts: e,
                            top_k: k,
                            capacity_factor: 1.2,
                            drop_policy: DropPolicy::SubSequence,
                            capacity_override: None,
                            pad_to_capacity: pad,
                            node_limit: None,
                            balancer: Balancer::AuxLoss,
                        },
                        &mut r2,
                    );
                    let layer = DistributedMoeLayer::from_topology(
                        topo.view(rank),
                        router,
                        &experts,
                    )
                    .with_phase_cost(pc)
                    .with_overlap(overlap);
                    let mine = tokens[rank * n * h..(rank + 1) * n * h].to_vec();
                    layer.forward(&comm, &mine)
                });
                (outs, fabric.max_sim_time_us())
            };
            let (serial, t_serial) = run(false);
            let (overlapped, t_overlap) = run(true);
            for rank in 0..world {
                let (so, ss) = &serial[rank];
                let (oo, os) = &overlapped[rank];
                for (i, (a, b)) in so.iter().zip(oo).enumerate() {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("rank {rank} idx {i}: {a} vs {b}"));
                    }
                }
                if (ss.a2a_send_bytes, ss.a2a_recv_bytes, ss.tokens_padded)
                    != (os.a2a_send_bytes, os.a2a_recv_bytes, os.tokens_padded)
                {
                    return Err(format!(
                        "rank {rank}: byte accounting differs ({ss:?} vs {os:?})"
                    ));
                }
                if e / world > 1 && os.a2a_hidden_us + os.a2a_exposed_us <= 0.0 {
                    return Err(format!("rank {rank}: overlapped path measured no a2a"));
                }
            }
            if pad && t_overlap > t_serial + 1e-6 {
                return Err(format!(
                    "overlap makespan {t_overlap} > serialized {t_serial} (static volumes)"
                ));
            }
            Ok(())
        },
    );
}

/// Collective cost model: monotone in bytes and never cheaper across nodes
/// than within a node for the same shape.
#[test]
fn prop_collective_monotonicity() {
    let comm = CommModel::new(ClusterSpec::eos(64));
    forall(
        "collective monotonicity",
        80,
        |rng: &mut Rng| {
            let n = draw::pow2_upto(rng, 8).max(2);
            let bytes = 1e4 * (1 << rng.next_below(12)) as f64;
            (n, bytes)
        },
        |&(n, bytes)| {
            let intra: Vec<usize> = (0..n).collect();
            let inter: Vec<usize> = (0..n).map(|i| i * 8).collect();
            for f in [CommModel::all_reduce, CommModel::all_gather, CommModel::all_to_all] {
                let t1 = f(&comm, &intra, bytes);
                let t2 = f(&comm, &intra, 2.0 * bytes);
                if t2 < t1 {
                    return Err(format!("not monotone in bytes: {t1} {t2}"));
                }
                let t3 = f(&comm, &inter, bytes);
                if t3 < t1 {
                    return Err(format!("inter {t3} cheaper than intra {t1}"));
                }
            }
            Ok(())
        },
    );
}

/// 1F1B simulation: bubble fraction within [analytic, analytic + 10%] for
/// random (pp, m, f, b).
#[test]
fn prop_pipeline_bubble_bounds() {
    forall(
        "1f1b bubble bounds",
        60,
        |rng: &mut Rng| {
            let pp = draw::pow2_upto(rng, 16).max(2);
            let m = pp * draw::in_range(rng, 1, 8);
            let f = 50.0 + rng.next_f64() * 500.0;
            (pp, m, f, 2.0 * f)
        },
        |&(pp, m, f, b)| {
            let t = simulate_1f1b(pp, m, f, b, 0.0);
            let ideal = m as f64 * (f + b);
            if t < ideal {
                return Err(format!("makespan {t} below ideal {ideal}"));
            }
            let frac = (t - ideal) / t;
            let analytic = bubble_fraction(pp, m);
            if frac > analytic + 0.10 {
                return Err(format!("bubble {frac:.3} far above analytic {analytic:.3}"));
            }
            Ok(())
        },
    );
}

/// Zig-zag (and contiguous) shard → unshard round-trips bit-exactly for
/// arbitrary `seq % (2·cp) == 0` lengths — sharding is pure row movement.
#[test]
fn prop_zigzag_shard_roundtrip_bit_exact() {
    use moe_folding::attention::zigzag;
    forall(
        "zigzag shard/unshard round trip",
        60,
        |rng: &mut Rng| {
            let cp = draw::pow2_upto(rng, 8);
            let seq = 2 * cp * draw::in_range(rng, 1, 12);
            let h = draw::in_range(rng, 1, 9);
            (cp, seq, h, rng.next_u64())
        },
        |&(cp, seq, h, seed)| {
            let mut rng = Rng::seed_from_u64(seed);
            let mut tokens = vec![0.0f32; seq * h];
            rng.fill_normal(&mut tokens, 1.0);
            for zz in [true, false] {
                let shards: Vec<Vec<f32>> =
                    (0..cp).map(|i| zigzag::shard(&tokens, h, cp, i, zz)).collect();
                let back = zigzag::unshard(&shards, h, zz);
                if back.len() != tokens.len() {
                    return Err(format!("zigzag {zz}: length {} vs {}", back.len(), tokens.len()));
                }
                for (i, (a, b)) in tokens.iter().zip(&back).enumerate() {
                    if a.to_bits() != b.to_bits() {
                        return Err(format!("zigzag {zz}: idx {i}: {a} vs {b}"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Per-rank causal-FLOPs imbalance is **exactly zero** under zig-zag
/// sharding, while the naive contiguous split's imbalance grows with cp.
#[test]
fn prop_zigzag_causal_workload_exactly_balanced() {
    use moe_folding::attention::zigzag;
    forall(
        "zig-zag causal balance",
        40,
        |rng: &mut Rng| {
            let cp = draw::pow2_upto(rng, 8).max(2);
            let seq = 2 * cp * draw::in_range(rng, 1, 16);
            (cp, seq)
        },
        |&(cp, seq)| {
            let zz: Vec<u64> =
                (0..cp).map(|i| zigzag::causal_workload(seq, cp, i, true)).collect();
            if zz.iter().any(|&w| w != zz[0]) {
                return Err(format!("zig-zag imbalance: {zz:?}"));
            }
            let ct: Vec<u64> =
                (0..cp).map(|i| zigzag::causal_workload(seq, cp, i, false)).collect();
            let (min, max) = (*ct.iter().min().unwrap(), *ct.iter().max().unwrap());
            if max <= min {
                return Err(format!("contiguous should be imbalanced: {ct:?}"));
            }
            // Total work is conserved either way.
            let want: u64 = (1..=seq as u64).sum();
            if zz.iter().sum::<u64>() != want || ct.iter().sum::<u64>() != want {
                return Err("workload not conserved".into());
            }
            // Contiguous imbalance grows with cp: exactly
            // 1 + 2(cp−1)·c/(c+1) for c tokens per rank, which is ≥ cp for
            // every c ≥ 2 and approaches 2cp−1 as c grows.
            let ratio = max as f64 / min as f64;
            if ratio < cp as f64 {
                return Err(format!("contiguous ratio {ratio:.2} below cp {cp}"));
            }
            Ok(())
        },
    );
}

/// The executed ring's KV p2p volume equals the analytic `kv_bytes`
/// formula of the layer coster per step and in total:
/// `2 · tokens_local · kv_dim · 4 B · (cp − 1)` for f32 payloads.
#[test]
fn prop_ring_kv_bytes_match_analytic_formula() {
    use moe_folding::attention::{AttnConfig, AttnWeights, DistributedAttentionLayer};
    use moe_folding::simcomm::{run_ranks_on, AlgoSelection, Fabric};
    forall(
        "ring KV bytes vs analytic formula",
        12,
        |rng: &mut Rng| {
            let cp = [2usize, 4][rng.next_below(2)];
            let chunks_per_piece = draw::in_range(rng, 1, 3);
            (cp, 2 * cp * chunks_per_piece, rng.next_u64())
        },
        |&(cp, kv_chunks, seed)| {
            let h = 8usize;
            let seq = kv_chunks * 4; // 4 rows per canonical chunk
            let cfg = AttnConfig { hidden: h, num_heads: 2, kv_chunks, zigzag: true };
            let mut rng = Rng::seed_from_u64(seed);
            let weights = AttnWeights::init(h, &mut rng);
            let mut tokens = vec![0.0f32; seq * h];
            rng.fill_normal(&mut tokens, 1.0);
            let topo = RuntimeTopology::folded(ParallelConfig::new(cp, 1, cp, 1, 1, 1))
                .map_err(|e| e.to_string())?;
            let fabric = Fabric::new_with(cp, AlgoSelection::fast());
            let stats = run_ranks_on(&fabric, |rank, comm| {
                let layer =
                    DistributedAttentionLayer::from_topology(topo.view(rank), cfg, &weights);
                let (_, s) = layer.forward(&comm, &layer.input_slice(&tokens), seq);
                s
            });
            // tokens_local = seq/cp (tp = 1), kv_dim = h, 4-byte payloads.
            let want = 2 * (seq / cp) * h * 4 * (cp - 1);
            for (rank, s) in stats.iter().enumerate() {
                if s.kv_send_bytes != want || s.kv_recv_bytes != want {
                    return Err(format!(
                        "rank {rank}: sent {} recv {} vs analytic {want}",
                        s.kv_send_bytes, s.kv_recv_bytes
                    ));
                }
                if s.ring_steps != cp - 1 {
                    return Err(format!("rank {rank}: {} steps", s.ring_steps));
                }
                // Per-step volume is uniform.
                if s.kv_send_bytes % s.ring_steps.max(1) != 0 {
                    return Err("per-step volume must be uniform".into());
                }
            }
            Ok(())
        },
    );
}

/// Satellite (ISSUE 7): node-limited routing (DeepSeek-V3 style) caps the
/// number of node groups a token's copies span. With gate affinities that
/// are locally concentrated plus one weak remote straggler, the
/// unrestricted top-4 ships one copy per token across InfiniBand while
/// the 1-node-limited top-4 keeps every copy inside the token's preferred
/// group — strictly fewer IB bytes through the EP dispatch a2a for the
/// same token load.
#[test]
fn node_limited_routing_saves_ib_bytes_on_correlated_gates() {
    use moe_folding::cluster::LinkKind;
    use moe_folding::dispatcher::NodeLimit;
    use moe_folding::simcomm::{run_ranks_on, AlgoSelection, Fabric};

    // eos(16): two nodes of eight, one expert per rank.
    let world = 16usize;
    let (h, e, k) = (16usize, 16usize, 4usize);
    // Identity gating weight: a token's features are its expert logits.
    let mut weight = vec![0.0f32; h * e];
    for i in 0..e {
        weight[i * e + i] = 1.0;
    }
    // Rank r's token prefers its own node's expert block (logits 5, 4, 3,
    // 2) with a weak remote straggler at 3.5 that outranks the 4th local
    // choice — so unrestricted top-4 always crosses IB once per token.
    let features = |rank: usize| {
        let base = (rank / 8) * 8;
        let mut f = vec![0.0f32; h];
        f[base] = 5.0;
        f[base + 1] = 4.0;
        f[base + 2] = 3.0;
        f[base + 3] = 2.0;
        f[(base + 8) % e] = 3.5;
        f
    };
    let cfg = |node_limit| RouterConfig {
        hidden: h,
        num_experts: e,
        top_k: k,
        capacity_factor: 1.0,
        drop_policy: DropPolicy::Dropless,
        capacity_override: None,
        pad_to_capacity: false,
        node_limit,
        balancer: Balancer::AuxLoss,
    };
    let limit = NodeLimit { max_nodes: 1, experts_per_node: 8 };
    // Sanity: the crafted gates do what the comment above claims.
    let unres: Vec<usize> = Router::new(cfg(None), weight.clone())
        .route(&features(0))
        .assignments
        .iter()
        .map(|a| a.expert)
        .collect();
    assert!(unres.contains(&8), "unrestricted top-4 must take the remote straggler: {unres:?}");
    let lim: Vec<usize> = Router::new(cfg(Some(limit)), weight.clone())
        .route(&features(0))
        .assignments
        .iter()
        .map(|a| a.expert)
        .collect();
    assert!(lim.iter().all(|&x| x < 8), "node-limited top-4 must stay local: {lim:?}");
    // Route every rank's token, dispatch the copies through the two-level
    // a2a, and meter what actually crossed IB.
    let ib_bytes = |node_limit: Option<NodeLimit>| {
        let router = Router::new(cfg(node_limit), weight.clone());
        let fabric = Fabric::new_with(world, AlgoSelection::hierarchical());
        run_ranks_on(&fabric, |rank, comm| {
            let group: Vec<usize> = (0..world).collect();
            let d = router.route(&features(rank));
            let mut sends: Vec<Vec<f32>> = (0..world).map(|_| Vec::new()).collect();
            for a in &d.assignments {
                if a.kept {
                    sends[a.expert].extend_from_slice(&[a.prob; 16]);
                }
            }
            comm.all_to_all_v(&group, sends)
        });
        fabric.link_traffic(LinkKind::InfiniBand).bytes
    };
    let unrestricted = ib_bytes(None);
    let limited = ib_bytes(Some(limit));
    assert!(unrestricted > 0.0, "unrestricted dispatch must cross IB");
    assert!(
        limited < unrestricted,
        "node-limited dispatch must move fewer IB bytes: {limited} vs {unrestricted}"
    );
}

/// Satellite (ISSUE 8): quantized-payload dispatch. On identical routes the
/// [`Payload::Quantized`] twin's measured `link_traffic` bytes are
/// **exactly** `bytes_per_el(Fp8) / bytes_per_el(Bf16) = 1/2` of the
/// [`Payload::Bf16`] twin's (uniform per-element billing; per-chunk scales
/// ride out of band, unbilled), and `Payload::Bf16` is bit-identical in
/// output to the f32 reference (width is billing-only). The quantized
/// twin's layer outputs stay inside a generous relative-L2 envelope of the
/// dequantized f32 reference while being measurably lossy — the
/// bounded-epsilon half of the twin pin (the per-chunk `max|x|/254` bound
/// itself is pinned in `simcomm::quant`).
#[test]
fn prop_quantized_dispatch_halves_link_bytes_and_bounds_error() {
    use moe_folding::cluster::LinkKind;
    use moe_folding::dispatcher::DistributedMoeLayer;
    use moe_folding::simcomm::{run_ranks_on, AlgoSelection, Fabric, Payload};
    use moe_folding::train::math::SwigluExpert;

    forall(
        "quantized a2a bytes and error envelope",
        8,
        |rng: &mut Rng| {
            let e = [4usize, 8][rng.next_below(2)];
            let k = draw::in_range(rng, 1, 3);
            let n = draw::in_range(rng, 4, 24);
            let overlap = rng.next_below(2) == 0;
            (e, k, n, overlap, rng.next_u64())
        },
        |&(e, k, n, overlap, seed)| {
            let h = 8usize;
            let world = 4usize;
            let mut rng = Rng::seed_from_u64(seed);
            let experts: Vec<SwigluExpert> =
                (0..e).map(|_| SwigluExpert::init(h, 16, &mut rng)).collect();
            let mut tokens = vec![0.0f32; world * n * h];
            rng.fill_normal(&mut tokens, 1.0);
            let topo = RuntimeTopology::folded(ParallelConfig::new(world, 1, 1, 4, 1, 1))?;
            let run = |payload: Payload| {
                let fabric = Fabric::new_with(world, AlgoSelection::fast());
                let outs = run_ranks_on(&fabric, |rank, comm| {
                    let mut r2 = Rng::seed_from_u64(seed ^ 0x0ddba11);
                    let router = Router::init(
                        RouterConfig {
                            hidden: h,
                            num_experts: e,
                            top_k: k,
                            capacity_factor: 1.0,
                            drop_policy: DropPolicy::Dropless,
                            capacity_override: None,
                            pad_to_capacity: false,
                            node_limit: None,
                            balancer: Balancer::AuxLoss,
                        },
                        &mut r2,
                    );
                    let layer =
                        DistributedMoeLayer::from_topology(topo.view(rank), router, &experts)
                            .with_overlap(overlap)
                            .with_payload(payload);
                    let mine = tokens[rank * n * h..(rank + 1) * n * h].to_vec();
                    layer.forward(&comm, &mine).0
                });
                let bytes: f64 = [LinkKind::Loopback, LinkKind::NvLink, LinkKind::InfiniBand]
                    .iter()
                    .map(|&kind| fabric.link_traffic(kind).bytes)
                    .sum();
                (outs, bytes)
            };
            let (ref_out, f32_bytes) = run(Payload::F32);
            let (bf16_out, bf16_bytes) = run(Payload::Bf16);
            let (q_out, q_bytes) = run(Payload::Quantized);
            if bf16_bytes <= 0.0 {
                return Err("no a2a traffic measured".into());
            }
            // Identical element counts on identical routes × uniform widths
            // ⇒ the ratios are exact, not approximate.
            if q_bytes * 2.0 != bf16_bytes {
                return Err(format!(
                    "quantized bytes {q_bytes} must be exactly half of bf16 {bf16_bytes}"
                ));
            }
            if bf16_bytes * 2.0 != f32_bytes {
                return Err(format!(
                    "bf16 bytes {bf16_bytes} must be exactly half of f32 {f32_bytes}"
                ));
            }
            let (mut num, mut den, mut lossy) = (0.0f64, 0.0f64, false);
            for rank in 0..world {
                for (i, (b, r)) in bf16_out[rank].iter().zip(&ref_out[rank]).enumerate() {
                    if b.to_bits() != r.to_bits() {
                        return Err(format!(
                            "rank {rank} idx {i}: bf16 billing twin changed the payload"
                        ));
                    }
                }
                for (q, r) in q_out[rank].iter().zip(&ref_out[rank]) {
                    num += (*q as f64 - *r as f64).powi(2);
                    den += (*r as f64).powi(2);
                    lossy |= q.to_bits() != r.to_bits();
                }
            }
            let rel_l2 = (num / den.max(1e-30)).sqrt();
            if rel_l2 > 0.05 {
                return Err(format!("quantized rel-L2 {rel_l2:.4} outside the 5% envelope"));
            }
            if !lossy {
                return Err("quantized twin must be measurably lossy".into());
            }
            Ok(())
        },
    );
}

/// Satellite (ISSUE 9): the Zipf skew generator's empirical expert
/// popularity peaks strictly on expert 0 with a head that dominates the
/// tail, and the stream is exactly reproducible from its seed.
#[test]
fn prop_zipf_skewgen_ranking_and_determinism() {
    use moe_folding::dispatcher::{SkewGen, SkewProfile};

    forall(
        "zipf skew ranking + determinism",
        12,
        |rng: &mut Rng| {
            let e = [4usize, 8, 16][rng.next_below(3)];
            let exponent = 1.0 + rng.next_f64();
            (e, exponent, rng.next_u64())
        },
        |&(e, exponent, seed)| {
            let profile = SkewProfile::Zipf { exponent };
            let h = e.max(16);
            let n = 4096usize;
            let mut a = SkewGen::new(profile, e, h, seed);
            let mut b = SkewGen::new(profile, e, h, seed);
            let ta = a.next_tokens(n);
            if ta != b.next_tokens(n) {
                return Err("same seed must reproduce the same stream".into());
            }
            // Preferred expert per token = argmax gate feature (the
            // identity gate's top-1 choice); count empirical popularity.
            let mut counts = vec![0usize; e];
            for t in 0..n {
                let row = &ta[t * h..t * h + e];
                let mut best = 0;
                for j in 1..e {
                    if row[j] > row[best] {
                        best = j;
                    }
                }
                counts[best] += 1;
            }
            if *counts.iter().max().unwrap() != counts[0] {
                return Err(format!("expert 0 must be most popular: {counts:?}"));
            }
            if counts[0] <= counts[1] {
                return Err(format!("zipf head must decrease strictly: {counts:?}"));
            }
            if counts[0] <= counts[e - 1] * 2 {
                return Err(format!("head must dominate tail: {counts:?}"));
            }
            Ok(())
        },
    );
}

/// Satellite (ISSUE 9): the aux-loss-free balancer preserves the routing
/// conservation law (`routed + dropped == n·top_k` — bias steers *which*
/// experts are selected, never how many copies exist), and once its bias
/// has adapted it routes the same Zipf stream with strictly lower load
/// imbalance than the unbiased aux-loss router.
#[test]
fn prop_aux_free_conserves_copies_and_converges() {
    use moe_folding::dispatcher::{LoadStats, SkewGen, SkewProfile};

    forall(
        "aux-free conservation + convergence",
        8,
        |rng: &mut Rng| {
            let e = [4usize, 8][rng.next_below(2)];
            let k = draw::in_range(rng, 1, 2);
            let exponent = 1.1 + rng.next_f64() * 0.6;
            (e, k, exponent, rng.next_u64())
        },
        |&(e, k, exponent, seed)| {
            let h = 16usize;
            let profile = SkewProfile::Zipf { exponent };
            let (chunk, chunks, warmup) = (128usize, 40usize, 24usize);
            let cfg = |balancer| RouterConfig {
                hidden: h,
                num_experts: e,
                top_k: k,
                capacity_factor: 1.0,
                drop_policy: DropPolicy::Dropless,
                capacity_override: None,
                pad_to_capacity: false,
                node_limit: None,
                balancer,
            };
            let stream: Vec<Vec<f32>> = {
                let mut gen = SkewGen::new(profile, e, h, seed);
                (0..chunks).map(|_| gen.next_tokens(chunk)).collect()
            };
            let gen = SkewGen::new(profile, e, h, seed);
            let mut biased = gen.router(cfg(Balancer::AuxFree { update_rate: 0.05 }));
            let plain = gen.router(cfg(Balancer::AuxLoss));
            let (mut load_b, mut load_p) = (vec![0usize; e], vec![0usize; e]);
            for (i, tokens) in stream.iter().enumerate() {
                let db = biased.route(tokens);
                let kept = db.assignments.iter().filter(|a| a.kept).count();
                let dropped = db.assignments.len() - kept;
                if kept + dropped != chunk * k {
                    return Err(format!("conservation: {kept}+{dropped} != {}", chunk * k));
                }
                let dp = plain.route(tokens);
                if i >= warmup {
                    for x in 0..e {
                        load_b[x] += db.expert_load[x];
                        load_p[x] += dp.expert_load[x];
                    }
                }
                biased.update_bias(&db.expert_load);
            }
            // The conservation law is also non-trivial under dropping:
            // a capacity-limited aux-free router still accounts for every
            // n·k copy as either routed or dropped.
            let mut dropping = SkewGen::new(profile, e, h, seed ^ 1)
                .router(cfg(Balancer::AuxFree { update_rate: 0.05 }));
            dropping.config.drop_policy = DropPolicy::SubSequence;
            let d = dropping.route(&stream[0]);
            let kept = d.assignments.iter().filter(|a| a.kept).count();
            if kept + (d.assignments.len() - kept) != chunk * k {
                return Err("dropping conservation violated".into());
            }
            let ib = LoadStats::from_load(&load_b).imbalance;
            let ip = LoadStats::from_load(&load_p).imbalance;
            if ib >= ip {
                return Err(format!("aux-free imbalance {ib:.3} must beat plain {ip:.3}"));
            }
            Ok(())
        },
    );
}

/// Satellite (ISSUE 9): [`moe_folding::dispatcher::sinkhorn_plan`] yields a
/// row-stochastic transport plan (each token's row sums to 1 within f32
/// rounding) whose column sums land within a small ε of the balanced
/// target `n/E` after enough iterations, for arbitrary positive gates.
#[test]
fn prop_sinkhorn_plan_row_stochastic_and_column_balanced() {
    use moe_folding::dispatcher::sinkhorn_plan;

    forall(
        "sinkhorn plan invariants",
        20,
        |rng: &mut Rng| {
            let n = draw::in_range(rng, 1, 48);
            let e = draw::in_range(rng, 2, 12);
            (n, e, rng.next_u64())
        },
        |&(n, e, seed)| {
            let mut rng = Rng::seed_from_u64(seed);
            let mut probs = vec![0.0f32; n * e];
            for row in probs.chunks_mut(e) {
                let mut sum = 0.0f32;
                for x in row.iter_mut() {
                    *x = (rng.next_normal_f32() * 1.5).exp();
                    sum += *x;
                }
                for x in row.iter_mut() {
                    *x /= sum;
                }
            }
            let plan = sinkhorn_plan(&probs, n, e, 128);
            for (t, row) in plan.chunks(e).enumerate() {
                let s: f64 = row.iter().map(|&x| x as f64).sum();
                if (s - 1.0).abs() > 1e-3 {
                    return Err(format!("token {t}: row sum {s} not stochastic"));
                }
            }
            let target = n as f64 / e as f64;
            for j in 0..e {
                let col: f64 = (0..n).map(|t| plan[t * e + j] as f64).sum();
                if (col - target).abs() > 0.15 * target {
                    return Err(format!(
                        "column {j}: mass {col:.3} vs target {target:.3} outside ε"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Satellite (ISSUE 9): node-limited routing composes with both new
/// balancers — every copy stays inside the token's `max_nodes` allowed
/// node groups no matter how the bias or the Sinkhorn plan reshuffles the
/// selection, and the copy count stays `n·top_k`.
#[test]
fn prop_node_limit_composes_with_balancers() {
    use moe_folding::dispatcher::{NodeLimit, SkewGen, SkewProfile};

    forall(
        "node limit × balancer composition",
        12,
        |rng: &mut Rng| {
            let balancer = match rng.next_below(2) {
                0 => Balancer::AuxFree { update_rate: 0.1 },
                _ => Balancer::Sinkhorn { iters: 16 },
            };
            let exponent = 1.0 + rng.next_f64();
            (balancer, exponent, rng.next_u64())
        },
        |&(balancer, exponent, seed)| {
            let (e, h, k, n) = (16usize, 16usize, 4usize, 64usize);
            let limit = NodeLimit { max_nodes: 2, experts_per_node: 4 };
            let mut gen = SkewGen::new(SkewProfile::Zipf { exponent }, e, h, seed);
            let mut router = gen.router(RouterConfig {
                hidden: h,
                num_experts: e,
                top_k: k,
                capacity_factor: 1.0,
                drop_policy: DropPolicy::Dropless,
                capacity_override: None,
                pad_to_capacity: false,
                node_limit: Some(limit),
                balancer,
            });
            for _ in 0..4 {
                let tokens = gen.next_tokens(n);
                let d = router.route(&tokens);
                if d.assignments.len() != n * k {
                    return Err(format!("{} copies, want {}", d.assignments.len(), n * k));
                }
                for t in 0..n {
                    let mut nodes: Vec<usize> = d.assignments[t * k..(t + 1) * k]
                        .iter()
                        .map(|a| a.expert / limit.experts_per_node)
                        .collect();
                    nodes.sort_unstable();
                    nodes.dedup();
                    if nodes.len() > limit.max_nodes {
                        return Err(format!(
                            "token {t}: copies span {} nodes > {} ({balancer:?})",
                            nodes.len(),
                            limit.max_nodes
                        ));
                    }
                }
                router.update_bias(&d.expert_load);
            }
            Ok(())
        },
    );
}
