//! Model zoo: the four MoE architectures evaluated in the paper plus small
//! test/e2e configurations.
//!
//! Architectural numbers are taken from the public model cards:
//! - Mixtral 8x22B (coarse-grained, 8 experts, top-2)
//! - Llama3-8x70B (coarse-grained upcycle of Llama3-70B, 8 experts, top-2)
//! - Qwen2-57B-A14B (fine-grained, 64 experts, top-8)
//! - Mixtral-8x22B-G8T8 (fine-grained re-parameterization of 8x22B:
//!   64 experts, top-8, expert FFN 1/8 of the original)



/// Architecture description of a (MoE) transformer.
///
/// All MoE models in the paper replace every dense FFN with an MoE FFN; the
/// `moe_layer_freq` field allows hybrid dense/MoE stacks for ablations.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    /// Transformer hidden size (d_model).
    pub hidden_size: usize,
    /// Number of transformer layers.
    pub num_layers: usize,
    /// Number of attention (query) heads.
    pub num_heads: usize,
    /// Number of KV heads (GQA groups). Equal to `num_heads` for MHA.
    pub num_query_groups: usize,
    /// FFN hidden size of a *single expert* (SwiGLU intermediate size).
    pub moe_ffn_hidden_size: usize,
    /// FFN hidden size used by dense layers (if any) and by the optional
    /// shared expert.
    pub ffn_hidden_size: usize,
    /// Number of routed experts (E). 0 => dense model.
    pub num_experts: usize,
    /// Active experts per token (K of top-K routing).
    pub top_k: usize,
    /// Shared-expert intermediate size (Qwen2-style). 0 => none.
    pub shared_expert_ffn_hidden_size: usize,
    /// 1 => every layer is MoE; 2 => every other layer, etc.
    pub moe_layer_freq: usize,
    pub vocab_size: usize,
    /// Default training sequence length.
    pub seq_len: usize,
    /// Untie input/output embeddings (true for all paper models).
    pub untie_embeddings: bool,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.hidden_size / self.num_heads
    }

    /// Number of MoE layers in the stack.
    pub fn num_moe_layers(&self) -> usize {
        if self.num_experts == 0 {
            0
        } else {
            self.num_layers / self.moe_layer_freq
        }
    }

    /// Number of dense-FFN layers in the stack.
    pub fn num_dense_layers(&self) -> usize {
        self.num_layers - self.num_moe_layers()
    }

    /// Attention parameters per layer: QKV + output projection (GQA-aware).
    pub fn attn_params_per_layer(&self) -> u64 {
        let h = self.hidden_size as u64;
        let hd = self.head_dim() as u64;
        let q = h * h;
        let kv = 2 * h * (self.num_query_groups as u64 * hd);
        let o = h * h;
        // 2 RMSNorm weight vectors per layer (attn + mlp input norms).
        q + kv + o + 2 * h
    }

    /// Parameters of a single routed expert (SwiGLU: gate, up, down).
    pub fn params_per_expert(&self) -> u64 {
        3 * self.hidden_size as u64 * self.moe_ffn_hidden_size as u64
    }

    /// Dense-FFN parameters per layer (SwiGLU).
    pub fn dense_ffn_params_per_layer(&self) -> u64 {
        3 * self.hidden_size as u64 * self.ffn_hidden_size as u64
    }

    /// Shared-expert parameters per MoE layer (0 if the model has none).
    pub fn shared_expert_params_per_layer(&self) -> u64 {
        3 * self.hidden_size as u64 * self.shared_expert_ffn_hidden_size as u64
    }

    /// Router (gating) parameters per MoE layer.
    pub fn router_params_per_layer(&self) -> u64 {
        self.hidden_size as u64 * self.num_experts as u64
    }

    /// Total parameter count.
    pub fn total_params(&self) -> u64 {
        let embeds = (if self.untie_embeddings { 2 } else { 1 })
            * self.vocab_size as u64
            * self.hidden_size as u64;
        let attn = self.num_layers as u64 * self.attn_params_per_layer();
        let moe = self.num_moe_layers() as u64
            * (self.num_experts as u64 * self.params_per_expert()
                + self.shared_expert_params_per_layer()
                + self.router_params_per_layer());
        let dense = self.num_dense_layers() as u64 * self.dense_ffn_params_per_layer();
        let final_norm = self.hidden_size as u64;
        embeds + attn + moe + dense + final_norm
    }

    /// Parameters activated per token (top-K experts instead of all E).
    pub fn active_params(&self) -> u64 {
        let embeds = (if self.untie_embeddings { 2 } else { 1 })
            * self.vocab_size as u64
            * self.hidden_size as u64;
        let attn = self.num_layers as u64 * self.attn_params_per_layer();
        let moe = self.num_moe_layers() as u64
            * (self.top_k as u64 * self.params_per_expert()
                + self.shared_expert_params_per_layer()
                + self.router_params_per_layer());
        let dense = self.num_dense_layers() as u64 * self.dense_ffn_params_per_layer();
        embeds + attn + dense + moe + self.hidden_size as u64
    }

    /// True for "fine-grained" MoE in the paper's sense: many small experts,
    /// several active per token.
    pub fn is_fine_grained(&self) -> bool {
        self.num_experts >= 16 && self.top_k >= 4
    }

    // ----- model zoo ------------------------------------------------------

    /// Mixtral 8x22B: 56 layers, hidden 6144, 8 experts, top-2 (~141B total).
    pub fn mixtral_8x22b() -> Self {
        Self {
            name: "Mixtral-8x22B".into(),
            hidden_size: 6144,
            num_layers: 56,
            num_heads: 48,
            num_query_groups: 8,
            moe_ffn_hidden_size: 16384,
            ffn_hidden_size: 16384,
            num_experts: 8,
            top_k: 2,
            shared_expert_ffn_hidden_size: 0,
            moe_layer_freq: 1,
            vocab_size: 32768,
            seq_len: 4096,
            untie_embeddings: true,
        }
    }

    /// Llama3-8x70B: Llama3-70B upcycled to 8 experts, top-2 (~465B total).
    pub fn llama3_8x70b() -> Self {
        Self {
            name: "Llama3-8x70B".into(),
            hidden_size: 8192,
            num_layers: 80,
            num_heads: 64,
            num_query_groups: 8,
            moe_ffn_hidden_size: 28672,
            ffn_hidden_size: 28672,
            num_experts: 8,
            top_k: 2,
            shared_expert_ffn_hidden_size: 0,
            moe_layer_freq: 1,
            vocab_size: 128256,
            seq_len: 4096,
            untie_embeddings: true,
        }
    }

    /// Qwen2-57B-A14B: 28 layers, hidden 3584, 64 experts top-8 + shared
    /// expert (57B total / 14B active).
    pub fn qwen2_57b_a14b() -> Self {
        Self {
            name: "Qwen2-57B-A14B".into(),
            hidden_size: 3584,
            num_layers: 28,
            num_heads: 28,
            num_query_groups: 4,
            moe_ffn_hidden_size: 2560,
            ffn_hidden_size: 18944,
            num_experts: 64,
            top_k: 8,
            shared_expert_ffn_hidden_size: 20480,
            moe_layer_freq: 1,
            vocab_size: 151936,
            seq_len: 4096,
            untie_embeddings: true,
        }
    }

    /// Mixtral-8x22B-G8T8: fine-grained re-parameterization of Mixtral 8x22B
    /// (64 experts, top-8, expert FFN = 16384/8 = 2048). Same total params.
    pub fn mixtral_8x22b_g8t8() -> Self {
        Self {
            name: "Mixtral-8x22B-G8T8".into(),
            moe_ffn_hidden_size: 2048,
            num_experts: 64,
            top_k: 8,
            ..Self::mixtral_8x22b()
        }
    }

    /// Mixtral 8x7B — used in the paper's appendix accuracy validation.
    pub fn mixtral_8x7b() -> Self {
        Self {
            name: "Mixtral-8x7B".into(),
            hidden_size: 4096,
            num_layers: 32,
            num_heads: 32,
            num_query_groups: 8,
            moe_ffn_hidden_size: 14336,
            ffn_hidden_size: 14336,
            num_experts: 8,
            top_k: 2,
            shared_expert_ffn_hidden_size: 0,
            moe_layer_freq: 1,
            vocab_size: 32768,
            seq_len: 4096,
            untie_embeddings: true,
        }
    }

    /// Tiny MoE used by the end-to-end training example (~tens of millions
    /// of params; exact count depends on `scale`).
    pub fn tiny_moe(scale: TinyScale) -> Self {
        let (hidden, layers, ffn, vocab) = match scale {
            TinyScale::Test => (64, 2, 128, 256),
            TinyScale::Small => (256, 4, 512, 2048),
            TinyScale::E2e => (512, 8, 1408, 8192),
            TinyScale::Hundred => (768, 12, 2048, 16384),
        };
        Self {
            name: format!("tiny-moe-{scale:?}").to_lowercase(),
            hidden_size: hidden,
            num_layers: layers,
            num_heads: (hidden / 64).max(1),
            num_query_groups: (hidden / 64).max(1),
            moe_ffn_hidden_size: ffn,
            ffn_hidden_size: ffn,
            num_experts: 8,
            top_k: 2,
            shared_expert_ffn_hidden_size: 0,
            moe_layer_freq: 1,
            vocab_size: vocab,
            seq_len: 512,
            untie_embeddings: false,
        }
    }

    /// Look up a zoo model by (case-insensitive) name.
    pub fn by_name(name: &str) -> Option<Self> {
        let n = name.to_lowercase().replace('_', "-");
        Some(match n.as_str() {
            "mixtral-8x22b" | "mixtral8x22b" => Self::mixtral_8x22b(),
            "llama3-8x70b" | "llama38x70b" => Self::llama3_8x70b(),
            "qwen2-57b-a14b" | "qwen2-57b" => Self::qwen2_57b_a14b(),
            "mixtral-8x22b-g8t8" | "g8t8" => Self::mixtral_8x22b_g8t8(),
            "mixtral-8x7b" => Self::mixtral_8x7b(),
            "tiny" | "tiny-moe" => Self::tiny_moe(TinyScale::Small),
            "tiny-e2e" => Self::tiny_moe(TinyScale::E2e),
            "tiny-100m" => Self::tiny_moe(TinyScale::Hundred),
            _ => return None,
        })
    }

    /// The four models of the paper's evaluation, in Table 1 order.
    pub fn paper_models() -> Vec<Self> {
        vec![
            Self::mixtral_8x22b(),
            Self::llama3_8x70b(),
            Self::qwen2_57b_a14b(),
            Self::mixtral_8x22b_g8t8(),
        ]
    }
}

/// Size presets for the tiny model family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TinyScale {
    /// Unit-test scale (sub-second).
    Test,
    /// Small: quick integration tests.
    Small,
    /// E2E driver default (~50M params).
    E2e,
    /// ~100M params for the recorded end-to-end run.
    Hundred,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixtral_total_params_plausible() {
        let m = ModelConfig::mixtral_8x22b();
        let p = m.total_params() as f64 / 1e9;
        // Public number: ~141B total.
        assert!(p > 120.0 && p < 160.0, "got {p}B");
    }

    #[test]
    fn mixtral_active_params_plausible() {
        let m = ModelConfig::mixtral_8x22b();
        let p = m.active_params() as f64 / 1e9;
        // Public number: ~39B active.
        assert!(p > 32.0 && p < 46.0, "got {p}B");
    }

    #[test]
    fn qwen2_totals() {
        let m = ModelConfig::qwen2_57b_a14b();
        let total = m.total_params() as f64 / 1e9;
        let active = m.active_params() as f64 / 1e9;
        assert!(total > 48.0 && total < 66.0, "total {total}B");
        assert!(active > 11.0 && active < 18.0, "active {active}B");
    }

    #[test]
    fn llama3_8x70b_is_large() {
        let m = ModelConfig::llama3_8x70b();
        let p = m.total_params() as f64 / 1e9;
        // 8x the 70B FFN stack: > 400B total.
        assert!(p > 380.0, "got {p}B");
    }

    #[test]
    fn g8t8_preserves_total_expert_params() {
        let base = ModelConfig::mixtral_8x22b();
        let g = ModelConfig::mixtral_8x22b_g8t8();
        assert_eq!(
            base.num_experts as u64 * base.params_per_expert(),
            g.num_experts as u64 * g.params_per_expert()
        );
        assert!(g.is_fine_grained());
        assert!(!base.is_fine_grained());
    }

    #[test]
    fn zoo_lookup() {
        for name in [
            "Mixtral-8x22B",
            "llama3-8x70b",
            "qwen2-57b-a14b",
            "g8t8",
            "tiny",
        ] {
            assert!(ModelConfig::by_name(name).is_some(), "{name}");
        }
        assert!(ModelConfig::by_name("gpt-5").is_none());
    }

    #[test]
    fn head_dim_divides() {
        for m in ModelConfig::paper_models() {
            assert_eq!(m.hidden_size % m.num_heads, 0, "{}", m.name);
            assert_eq!(m.num_heads % m.num_query_groups, 0, "{}", m.name);
        }
    }
}
