//! Parallelism configuration: the 5-D hybrid space of the paper.
//!
//! Attention layers are mapped over `TP × CP × DP × PP`; MoE layers over
//! `ETP × EP × EDP × PP` (paper §3.2). With MoE Parallel Folding the two
//! mappings are independent except that the PP decomposition must agree.



/// Numeric precision of the training run (affects peak flops + memory).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    Bf16,
    /// FP8 delayed scaling (Transformer-Engine style): GEMMs run at 2x the
    /// BF16 peak; non-GEMM work and cast/amax overheads stay in BF16.
    Fp8,
}

impl Precision {
    /// Human label for table rows ("BF16" / "FP8").
    pub fn name(&self) -> &'static str {
        match self {
            Precision::Bf16 => "BF16",
            Precision::Fp8 => "FP8",
        }
    }

    /// The other precision — the twin the executed tuner ranks against,
    /// the same way [`EpPlacement::Strided`] twins [`EpPlacement::Packed`].
    pub fn twin(&self) -> Precision {
        match self {
            Precision::Bf16 => Precision::Fp8,
            Precision::Fp8 => Precision::Bf16,
        }
    }
}

/// ZeRO / distributed-optimizer sharding level along the DP axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ZeroStage {
    /// Plain DDP: full optimizer state replicated.
    None,
    /// ZeRO-1 / Megatron distributed optimizer: optimizer states sharded.
    Zero1,
    /// ZeRO-3 / FSDP: parameters, gradients and optimizer states sharded.
    Zero3,
}

/// Token-dropping policy of the MoE router (paper §3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DropPolicy {
    /// Dropless (MegaBlocks-style): every token is processed.
    Dropless,
    /// Capacity-factor dropping where top-k selection consistency is enforced
    /// across the full sequence (gather of logits across CP/TP ranks).
    FullSequence,
    /// Capacity-factor dropping decided per local sub-sequence (the paper's
    /// default: no logit gather, less communication, balanced a2a).
    SubSequence,
}

/// Where EP groups land relative to node boundaries (MoETuner's placement
/// axis). The analytic and executed estimators price a collective by the
/// link classes its group spans, so placement changes step time without
/// changing any group size.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EpPlacement {
    /// EP is the fastest-varying MoE grid axis after ETP: an EP group is a
    /// contiguous rank range, packed inside NVLink domains when
    /// `ep · etp` fits in a node. The default (and the paper's choice).
    Packed,
    /// EP varies slower than EDP: EP peers sit `edp · etp` ranks apart, so
    /// an EP group strides across nodes and its dispatch a2a crosses IB.
    /// The deliberately-bad twin the autotuner ranks against packed.
    Strided,
}

/// The 5-D hybrid parallel mapping.
///
/// `dp` and `edp` are derived from the world size; they are not free knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ParallelConfig {
    /// Total number of GPUs.
    pub world_size: usize,
    /// Attention tensor parallelism.
    pub tp: usize,
    /// Context parallelism (sequence split for attention).
    pub cp: usize,
    /// Pipeline parallelism (shared by attention and MoE).
    pub pp: usize,
    /// Expert parallelism (MoE).
    pub ep: usize,
    /// Expert tensor parallelism (MoE). With folding this is independent of
    /// `tp`; the coupled (legacy MCore) mapping forces `etp == tp`.
    pub etp: usize,
    /// Virtual pipeline stages per rank (interleaved 1F1B). 1 = plain 1F1B.
    pub vpp: usize,
    /// EP-group placement relative to node boundaries (MoE grid only).
    pub placement: EpPlacement,
}

impl ParallelConfig {
    pub fn new(world_size: usize, tp: usize, cp: usize, ep: usize, etp: usize, pp: usize) -> Self {
        Self { world_size, tp, cp, pp, ep, etp, vpp: 1, placement: EpPlacement::Packed }
    }

    /// Same mapping with `vpp` virtual chunks per pipeline stage
    /// (interleaved 1F1B when `vpp > 1`).
    pub fn with_vpp(mut self, vpp: usize) -> Self {
        self.vpp = vpp;
        self
    }

    /// Same mapping with a different EP placement.
    pub fn with_placement(mut self, placement: EpPlacement) -> Self {
        self.placement = placement;
        self
    }

    /// Attention-side data parallelism.
    pub fn dp(&self) -> usize {
        self.world_size / (self.tp * self.cp * self.pp)
    }

    /// MoE-side data parallelism (Expert-DP).
    pub fn edp(&self) -> usize {
        self.world_size / (self.etp * self.ep * self.pp)
    }

    /// Size of the attention model-parallel block (ranks sharing one replica
    /// of one pipeline stage's attention weights).
    pub fn attn_inner(&self) -> usize {
        self.tp * self.cp
    }

    /// Size of the MoE model-parallel block.
    pub fn moe_inner(&self) -> usize {
        self.etp * self.ep
    }

    /// Whether this mapping is expressible without MoE Parallel Folding,
    /// i.e. in the coupled legacy MCore scheme: `etp == tp` and the EP group
    /// is a sub-group of attention DP (`ep` divides `dp`), and no folding of
    /// EP across CP.
    pub fn is_legacy_expressible(&self) -> bool {
        self.etp == self.tp && self.cp == 1 && self.dp() % self.ep == 0
    }

    /// Validate divisibility and group-consistency constraints.
    pub fn validate(&self, num_experts: usize, num_layers: usize) -> Result<(), String> {
        let need = |cond: bool, msg: &str| if cond { Ok(()) } else { Err(msg.to_string()) };
        need(self.world_size > 0, "world_size must be > 0")?;
        for (v, n) in [
            (self.tp, "tp"),
            (self.cp, "cp"),
            (self.pp, "pp"),
            (self.ep, "ep"),
            (self.etp, "etp"),
            (self.vpp, "vpp"),
        ] {
            need(v > 0, &format!("{n} must be > 0"))?;
        }
        need(
            self.world_size % (self.tp * self.cp * self.pp) == 0,
            &format!(
                "world_size {} not divisible by tp*cp*pp = {}",
                self.world_size,
                self.tp * self.cp * self.pp
            ),
        )?;
        need(
            self.world_size % (self.etp * self.ep * self.pp) == 0,
            &format!(
                "world_size {} not divisible by etp*ep*pp = {}",
                self.world_size,
                self.etp * self.ep * self.pp
            ),
        )?;
        if num_experts > 0 {
            need(
                num_experts % self.ep == 0,
                &format!("num_experts {num_experts} not divisible by ep {}", self.ep),
            )?;
        }
        need(
            num_layers % (self.pp * self.vpp) == 0,
            &format!("num_layers {num_layers} not divisible by pp*vpp"),
        )?;
        Ok(())
    }

    /// Short "tpXcpYepZ..." string used in reports. `VPP` appears only when
    /// interleaving is on (`vpp > 1`), keeping the plain-1F1B tags stable.
    pub fn tag(&self) -> String {
        let mut t = format!(
            "TP{}CP{}EP{}ETP{}PP{}DP{}EDP{}",
            self.tp,
            self.cp,
            self.ep,
            self.etp,
            self.pp,
            self.dp(),
            self.edp()
        );
        if self.vpp > 1 {
            t.push_str(&format!("VPP{}", self.vpp));
        }
        if self.placement == EpPlacement::Strided {
            t.push_str("+strided");
        }
        t
    }
}

/// Training hyper-parameters relevant to the performance model and trainer.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    /// Global batch size in sequences.
    pub global_batch_size: usize,
    /// Micro-batch size in sequences (per model replica per pipeline slot).
    pub micro_batch_size: usize,
    /// Sequence length (overrides the model default when set).
    pub seq_len: usize,
    pub precision: Precision,
    /// MoE capacity factor (>= 1.0). Ignored in dropless mode.
    pub capacity_factor: f64,
    pub drop_policy: DropPolicy,
    /// Recompute granularity: fraction of activation memory retained
    /// (1.0 = no recompute, ~0.35 = selective recompute of attention).
    pub activation_retained_frac: f64,
    /// Overlap DP gradient communication with the backward pass.
    pub overlap_grad_reduce: bool,
    /// Overlap ZeRO-3 parameter all-gather with compute (FSDP prefetch).
    pub overlap_param_gather: bool,
    /// Overlap the MoE token-dispatch All-to-All with expert GEMM
    /// (chunk-pipelined dispatcher). Off by default: the analytic estimate
    /// then matches the serialized dispatcher exactly; turning it on
    /// credits `PerfModel::a2a_overlap_frac` of the hideable a2a
    /// analytically and the executed estimator measures the same overlap
    /// on the virtual clock's comm lane.
    pub overlap_a2a: bool,
}

impl TrainConfig {
    pub fn paper_default(seq_len: usize, global_batch_size: usize) -> Self {
        Self {
            global_batch_size,
            micro_batch_size: 1,
            seq_len,
            precision: Precision::Bf16,
            capacity_factor: 1.0,
            drop_policy: DropPolicy::SubSequence,
            activation_retained_frac: 0.4,
            overlap_grad_reduce: true,
            overlap_param_gather: true,
            overlap_a2a: false,
        }
    }

    /// Number of microbatches per pipeline (per data-parallel replica).
    pub fn num_microbatches(&self, dp: usize) -> usize {
        (self.global_batch_size / (self.micro_batch_size * dp)).max(1)
    }

    pub fn tokens_per_global_batch(&self) -> usize {
        self.global_batch_size * self.seq_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dp_derivation() {
        let p = ParallelConfig::new(128, 2, 1, 8, 1, 8);
        assert_eq!(p.dp(), 8);
        assert_eq!(p.edp(), 2);
        assert!(p.validate(8, 56).is_ok());
    }

    #[test]
    fn folded_config_not_legacy_expressible() {
        // Mixtral folded optimum from Table 3: TP2 EP8 PP8 ETP1 on 128 GPUs.
        let p = ParallelConfig::new(128, 2, 1, 8, 1, 8);
        assert!(!p.is_legacy_expressible()); // etp(1) != tp(2)
        // MCore coupled optimum: TP2 EP4 PP8.
        let q = ParallelConfig::new(128, 2, 1, 4, 2, 8);
        assert!(q.is_legacy_expressible());
    }

    #[test]
    fn validate_rejects_bad_divisibility() {
        let p = ParallelConfig::new(100, 3, 1, 8, 1, 8);
        assert!(p.validate(8, 56).is_err());
        let q = ParallelConfig::new(128, 2, 1, 3, 1, 8);
        assert!(q.validate(8, 56).is_err()); // 8 experts % ep 3
    }

    #[test]
    fn microbatch_count() {
        let t = TrainConfig::paper_default(4096, 256);
        assert_eq!(t.num_microbatches(8), 32);
        assert_eq!(t.tokens_per_global_batch(), 256 * 4096);
    }

    #[test]
    fn tag_roundtrip() {
        let p = ParallelConfig::new(64, 2, 2, 2, 2, 2);
        assert_eq!(p.dp(), 8);
        assert_eq!(p.edp(), 8);
        assert!(p.tag().contains("TP2CP2EP2ETP2PP2"));
    }

    #[test]
    fn strided_placement_tags_and_defaults() {
        let p = ParallelConfig::new(64, 2, 1, 4, 1, 2);
        assert_eq!(p.placement, EpPlacement::Packed);
        assert!(!p.tag().contains("strided"));
        let s = p.with_placement(EpPlacement::Strided);
        assert!(s.tag().ends_with("+strided"));
        assert_eq!(s.with_placement(EpPlacement::Packed), p);
    }
}
