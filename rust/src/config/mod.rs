//! Configuration layer: model zoo, parallel mappings, training knobs.

pub mod models;
pub mod parallel;

pub use models::{ModelConfig, TinyScale};
pub use parallel::{DropPolicy, EpPlacement, ParallelConfig, Precision, TrainConfig, ZeroStage};
