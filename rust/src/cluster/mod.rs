//! Cluster model: the simulated testbed standing in for the Eos DGX-H100
//! cluster of the paper (§4.1).
//!
//! Every performance number in the reproduction flows through this model:
//! per-GPU peak flops, HBM capacity, and — crucially for MoE Parallel
//! Folding — the two-tier interconnect (NVLink inside a node, InfiniBand
//! across nodes). The paper's technique is precisely about placing
//! communication-heavy parallel groups inside the NVLink domain, so the
//! fidelity that matters here is the intra/inter-node bandwidth gap
//! (450 GB/s vs 50 GB/s per GPU), not absolute silicon details.



use crate::config::Precision;

/// A single accelerator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuSpec {
    /// Peak dense BF16 throughput in TFLOP/s.
    pub peak_bf16_tflops: f64,
    /// Peak dense FP8 throughput in TFLOP/s.
    pub peak_fp8_tflops: f64,
    /// HBM capacity in GiB.
    pub hbm_gib: f64,
    /// HBM bandwidth in GB/s (used for memory-bound op estimates).
    pub hbm_bw_gbs: f64,
}

impl GpuSpec {
    /// NVIDIA H100 SXM (the paper's GPU).
    pub fn h100() -> Self {
        Self {
            peak_bf16_tflops: 989.5,
            peak_fp8_tflops: 1979.0,
            hbm_gib: 80.0,
            hbm_bw_gbs: 3350.0,
        }
    }

    pub fn peak_tflops(&self, p: Precision) -> f64 {
        match p {
            Precision::Bf16 => self.peak_bf16_tflops,
            Precision::Fp8 => self.peak_fp8_tflops,
        }
    }
}

/// Link class between two ranks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkKind {
    /// Same device (no transfer).
    Loopback,
    /// Same node: NVLink / NVSwitch.
    NvLink,
    /// Cross-node: InfiniBand.
    InfiniBand,
}

/// The cluster: `num_nodes` nodes of `gpus_per_node` GPUs each.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub gpu: GpuSpec,
    pub gpus_per_node: usize,
    pub num_nodes: usize,
    /// Exact world size. A world that does not fill its last node (e.g. 12
    /// GPUs on 8-GPU nodes) keeps its true size here; `gpus_per_node *
    /// num_nodes` would silently round it up to the full-node capacity.
    pub total_gpus: usize,
    /// Uni-directional NVLink bandwidth per GPU, GB/s.
    pub nvlink_bw_gbs: f64,
    /// Uni-directional InfiniBand bandwidth per GPU, GB/s (400 Gb/s NIC).
    pub ib_bw_gbs: f64,
    /// Per-message launch latency on NVLink, microseconds.
    pub nvlink_latency_us: f64,
    /// Per-message latency across IB, microseconds.
    pub ib_latency_us: f64,
}

impl ClusterSpec {
    /// The Eos testbed of the paper: DGX H100, NVLink4 450 GB/s, 400 Gbps IB.
    pub fn eos(num_gpus: usize) -> Self {
        assert!(num_gpus >= 1);
        let gpus_per_node = 8usize.min(num_gpus);
        Self {
            gpu: GpuSpec::h100(),
            gpus_per_node,
            num_nodes: num_gpus.div_ceil(gpus_per_node),
            total_gpus: num_gpus,
            nvlink_bw_gbs: 450.0,
            ib_bw_gbs: 50.0,
            nvlink_latency_us: 3.0,
            ib_latency_us: 8.0,
        }
    }

    pub fn num_gpus(&self) -> usize {
        self.total_gpus
    }

    /// Node index hosting a global rank.
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.gpus_per_node
    }

    /// Classify the link between two global ranks. This is the fabric's
    /// source of truth: the executed hierarchical collectives, the
    /// per-link traffic counters, and the two-tier cost model all route
    /// their "which wire does this cross?" question here.
    pub fn link_of(&self, a: usize, b: usize) -> LinkKind {
        if a == b {
            LinkKind::Loopback
        } else if self.node_of(a) == self.node_of(b) {
            LinkKind::NvLink
        } else {
            LinkKind::InfiniBand
        }
    }

    /// Alias for [`Self::link_of`] (the original name).
    pub fn link(&self, a: usize, b: usize) -> LinkKind {
        self.link_of(a, b)
    }

    /// Number of distinct nodes spanned by a rank group.
    pub fn nodes_spanned(&self, group: &[usize]) -> usize {
        let mut nodes: Vec<usize> = group.iter().map(|&r| self.node_of(r)).collect();
        nodes.sort_unstable();
        nodes.dedup();
        nodes.len()
    }

    /// True if the whole group sits inside one NVLink domain.
    pub fn fits_in_node(&self, group: &[usize]) -> bool {
        self.nodes_spanned(group) <= 1
    }

    /// Bandwidth (GB/s per GPU) of the slowest link class used by the group.
    pub fn group_bottleneck_bw(&self, group: &[usize]) -> f64 {
        if self.fits_in_node(group) {
            self.nvlink_bw_gbs
        } else {
            self.ib_bw_gbs
        }
    }

    /// Latency (us) of the slowest link class used by the group.
    pub fn group_latency_us(&self, group: &[usize]) -> f64 {
        if self.fits_in_node(group) {
            self.nvlink_latency_us
        } else {
            self.ib_latency_us
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eos_shapes() {
        let c = ClusterSpec::eos(128);
        assert_eq!(c.num_nodes, 16);
        assert_eq!(c.num_gpus(), 128);
        assert_eq!(c.node_of(0), 0);
        assert_eq!(c.node_of(7), 0);
        assert_eq!(c.node_of(8), 1);
    }

    /// Regression (ISSUE 6 satellite): a world that only partly fills its
    /// last node must keep its exact size — `eos(12)` used to report
    /// `num_gpus() == 16`.
    #[test]
    fn partial_last_node_world_is_exact() {
        let c = ClusterSpec::eos(12);
        assert_eq!(c.num_gpus(), 12);
        assert_eq!(c.num_nodes, 2);
        assert_eq!(c.gpus_per_node, 8);
        assert_eq!(c.node_of(7), 0);
        assert_eq!(c.node_of(11), 1);
    }

    #[test]
    fn small_cluster_is_single_node() {
        let c = ClusterSpec::eos(4);
        assert_eq!(c.num_nodes, 1);
        assert_eq!(c.gpus_per_node, 4);
    }

    #[test]
    fn link_classes() {
        let c = ClusterSpec::eos(16);
        assert_eq!(c.link(0, 0), LinkKind::Loopback);
        assert_eq!(c.link(0, 7), LinkKind::NvLink);
        assert_eq!(c.link(0, 8), LinkKind::InfiniBand);
        assert_eq!(c.link_of(7, 8), LinkKind::InfiniBand);
        assert_eq!(c.link_of(8, 9), LinkKind::NvLink);
    }

    #[test]
    fn group_span() {
        let c = ClusterSpec::eos(32);
        assert!(c.fits_in_node(&[0, 1, 2, 3, 4, 5, 6, 7]));
        assert!(!c.fits_in_node(&[0, 8]));
        assert_eq!(c.nodes_spanned(&[0, 8, 16, 24]), 4);
        assert_eq!(c.group_bottleneck_bw(&[0, 1]), 450.0);
        assert_eq!(c.group_bottleneck_bw(&[0, 8]), 50.0);
    }

    #[test]
    fn peak_flops_by_precision() {
        let g = GpuSpec::h100();
        assert_eq!(g.peak_tflops(Precision::Bf16), 989.5);
        assert_eq!(g.peak_tflops(Precision::Fp8), 1979.0);
    }
}
