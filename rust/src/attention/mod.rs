//! **Executed context-parallel attention**: ring attention over the clocked
//! fabric (paper §3.2's CP axis, previously only an analytic lump in
//! [`crate::perfmodel::layers`]).
//!
//! One [`DistributedAttentionLayer`] is one rank's slice of a causal
//! multi-head attention block mapped over the attention grid's TP × CP
//! axes (groups from [`crate::mapping::RuntimeTopology`], never hand-rolled):
//!
//! 1. **TP sequence parallelism** — the rank holds `seq / (cp·tp)` input
//!    rows; an AllGather-V over the TP group assembles the CP shard before
//!    the block and a ReduceScatter-V splits (and sums) the output after.
//! 2. **Zig-zag CP sharding** ([`zigzag`]) — the sequence splits into
//!    `2·cp` chunks, rank `i` holding chunks `i` and `2cp−1−i`, so causal
//!    work is exactly balanced.
//! 3. **Ring KV exchange** — `cp − 1` steps of tagged nonblocking p2p
//!    ([`crate::simcomm::Communicator::send_tagged_billed`] +
//!    [`crate::simcomm::Communicator::irecv_tagged`]): the transfer of
//!    step `s+1`'s KV block rides under the attention-core compute of step
//!    `s`'s block, and the clock *measures* the hidden vs exposed split
//!    ([`AttnStats`]) — mirroring the chunk-pipelined MoE dispatcher.
//!
//! # Bit-exactness (the load-bearing invariant)
//!
//! Softmax over a distributed KV axis needs partial results combined with
//! the log-sum-exp trick, and floating-point LSE merges depend on the merge
//! tree. This layer pins a **canonical combine grid**: the KV axis is cut
//! into [`AttnConfig::kv_chunks`] fixed chunks, each rank computes the
//! chunk-local partials `(max, Σexp, Σexp·V)` with an identical fold
//! (ascending key position), and every rank merges partials in ascending
//! canonical-chunk order — a fixed, rank-independent order. Any two runs
//! with the same `kv_chunks` and the same TP degree are **bit-identical**
//! regardless of `cp` or sharding layout (zig-zag or contiguous), and the
//! `cp = 1 = tp` run equals the pure single-process
//! [`reference_forward`] — enforced by `tests/cp_equivalence.rs`.
//! (Different TP degrees re-associate the output-projection sum and are
//! *not* bit-comparable; differential tests always fix TP.)
//!
//! The virtual clock only ever adds charges ([`AttnPhaseCost`]) and billed
//! p2p volume — payload math is untouched, so clocked runs are bit-identical
//! to unclocked ones, like everything else on the fabric.

pub mod zigzag;

use crate::cluster::GpuSpec;
use crate::config::ModelConfig;
use crate::mapping::RankView;
use crate::simcomm::Communicator;
use crate::train::math::matmul;
use crate::util::Rng;

/// Tag base of the ring KV hand-off (`tag = base + step`); far outside the
/// pipeline executor's small `chunk_tag` space so streams can never cross
/// even if a rank pair carried both.
const RING_TAG_BASE: u64 = 0x5247_0000;

/// Shape of the attention block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttnConfig {
    pub hidden: usize,
    pub num_heads: usize,
    /// Canonical LSE-combine chunk count over the KV axis. Must divide the
    /// sequence length and be a multiple of `2·cp` (zig-zag) / `cp`
    /// (contiguous), so every shard piece is whole canonical chunks. Runs
    /// sharing this value are bit-comparable across `cp`.
    pub kv_chunks: usize,
    /// Zig-zag (balanced) vs contiguous ("even" split) CP sharding.
    pub zigzag: bool,
}

impl AttnConfig {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.num_heads
    }
}

/// Full (un-sharded) projection weights, replicated across CP; TP shards
/// are cut per rank with [`AttnWeights::tp_shard`]. Row-major `[h × h]`.
#[derive(Debug, Clone)]
pub struct AttnWeights {
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
}

impl AttnWeights {
    /// Deterministic init (identical on every rank for a given seed).
    pub fn init(h: usize, rng: &mut Rng) -> Self {
        let std = (1.0 / h as f32).sqrt();
        let mut mk = || {
            let mut w = vec![0.0f32; h * h];
            rng.fill_normal(&mut w, std);
            w
        };
        Self { wq: mk(), wk: mk(), wv: mk(), wo: mk() }
    }

    /// TP shard `idx` of `tp`: Q/K/V keep the column block of this rank's
    /// heads (`[h × h/tp]`), the output projection keeps the matching row
    /// block (`[h/tp × h]`) — summing the shard outputs over TP reproduces
    /// the full projection.
    pub fn tp_shard(&self, h: usize, tp: usize, idx: usize) -> AttnWeights {
        assert_eq!(h % tp, 0);
        let hq = h / tp;
        let cols = |w: &[f32]| -> Vec<f32> {
            let mut out = vec![0.0f32; h * hq];
            for r in 0..h {
                out[r * hq..(r + 1) * hq]
                    .copy_from_slice(&w[r * h + idx * hq..r * h + (idx + 1) * hq]);
            }
            out
        };
        AttnWeights {
            wq: cols(&self.wq),
            wk: cols(&self.wk),
            wv: cols(&self.wv),
            wo: self.wo[idx * hq * h..(idx + 1) * hq * h].to_vec(),
        }
    }
}

/// Per-forward accounting: real KV ring volume plus the measured
/// hidden/exposed split of the ring transfers on a clocked fabric.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AttnStats {
    /// KV payload bytes this rank pushed into the ring (f32 payloads).
    pub kv_send_bytes: usize,
    /// KV payload bytes received off the ring.
    pub kv_recv_bytes: usize,
    /// Ring steps executed (`cp − 1`).
    pub ring_steps: usize,
    /// Ring transfer time hidden under attention-core compute, µs
    /// (clocked fabrics with a phase cost; 0 otherwise).
    pub cp_hidden_us: f64,
    /// Ring transfer time the compute lane waited for, µs.
    pub cp_exposed_us: f64,
}

/// Per-unit compute charge for the virtual clock's attention-core spans,
/// so clocked skeleton runs measure a realistic hidden fraction even with
/// tiny stand-in payloads (the MoE twin is
/// [`crate::dispatcher::MoePhaseCost`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttnPhaseCost {
    /// µs per allowed (query, key) position pair, covering all local heads.
    pub core_us_per_pair: f64,
}

impl AttnPhaseCost {
    /// Charge for `model`'s attention core with heads sharded `tp` ways on
    /// `gpu` (BF16; flash-core operating point mirrors the layer coster).
    pub fn from_model(model: &ModelConfig, tp: usize, gpu: &GpuSpec) -> Self {
        // One (q, kv) pair costs 2·h flops for QKᵀ + 2·h for PV across the
        // full head set; a TP shard carries 1/tp of the heads.
        let flops_per_pair = 4.0 * model.hidden_size as f64 / tp.max(1) as f64;
        Self { core_us_per_pair: flops_per_pair / (gpu.peak_bf16_tflops * 1e12 * 0.4) * 1e6 }
    }
}

/// Chunk-keyed partial-softmax state: `(m, l, o)` per
/// `(canonical chunk, query row, head)`, merged in ascending chunk order.
struct Partials {
    n: usize,
    heads: usize,
    hd: usize,
    /// Row-max per (chunk, row, head); −inf = chunk fully masked for row.
    m: Vec<f32>,
    /// Σ exp(s − m) per (chunk, row, head).
    l: Vec<f32>,
    /// Σ exp(s − m) · V per (chunk, row, head, dim).
    o: Vec<f32>,
}

impl Partials {
    fn new(cpk: usize, n: usize, heads: usize, hd: usize) -> Self {
        Self {
            n,
            heads,
            hd,
            m: vec![f32::NEG_INFINITY; cpk * n * heads],
            l: vec![0.0; cpk * n * heads],
            o: vec![0.0; cpk * n * heads * hd],
        }
    }

    #[inline]
    fn ml_idx(&self, chunk: usize, row: usize, head: usize) -> usize {
        (chunk * self.n + row) * self.heads + head
    }
}

/// Accumulate one canonical chunk's partials: `k_rows`/`v_rows` are the
/// chunk's `rows` KV rows (ascending global position from `kpos0`),
/// `qpos[i]` the global position of query row `i`. The fold order inside a
/// chunk (ascending key position) never depends on which rank runs it.
/// Returns the allowed (query, key) pair count for the clock charge.
fn accumulate_chunk(
    p: &mut Partials,
    chunk: usize,
    q: &[f32],
    qpos: &[usize],
    k_rows: &[f32],
    v_rows: &[f32],
    kpos0: usize,
    rows: usize,
    scale: f32,
) -> usize {
    let (heads, hd) = (p.heads, p.hd);
    let hq = heads * hd;
    let mut pairs = 0usize;
    let mut scores = vec![0.0f32; rows];
    for (i, &qp) in qpos.iter().enumerate() {
        // Causal prefix: keys at positions kpos0..kpos0+rows, allowed while
        // position ≤ qp (ascending, so a contiguous prefix).
        let allowed = (qp + 1).saturating_sub(kpos0).min(rows);
        if allowed == 0 {
            continue;
        }
        pairs += allowed;
        for head in 0..heads {
            let qseg = &q[i * hq + head * hd..i * hq + head * hd + hd];
            let mut m = f32::NEG_INFINITY;
            for (j, s) in scores.iter_mut().enumerate().take(allowed) {
                let kseg = &k_rows[j * hq + head * hd..j * hq + head * hd + hd];
                let mut acc = 0.0f32;
                for (a, b) in qseg.iter().zip(kseg) {
                    acc += a * b;
                }
                *s = acc * scale;
                m = m.max(*s);
            }
            let mi = p.ml_idx(chunk, i, head);
            let mut l = 0.0f32;
            let obase = mi * hd;
            for (j, s) in scores.iter().enumerate().take(allowed) {
                let w = (s - m).exp();
                l += w;
                let vseg = &v_rows[j * hq + head * hd..j * hq + head * hd + hd];
                for (od, vd) in p.o[obase..obase + hd].iter_mut().zip(vseg) {
                    *od += w * vd;
                }
            }
            p.m[mi] = m;
            p.l[mi] = l;
        }
    }
    pairs
}

/// Merge the per-chunk partials in ascending canonical-chunk order — the
/// fixed, rank-independent LSE combine — and normalize. Output
/// `[n × heads·hd]`.
fn merge_output(p: &Partials, cpk: usize) -> Vec<f32> {
    let (n, heads, hd) = (p.n, p.heads, p.hd);
    let hq = heads * hd;
    let mut out = vec![0.0f32; n * hq];
    let mut acc_o = vec![0.0f32; hd];
    for i in 0..n {
        for head in 0..heads {
            let mut m = f32::NEG_INFINITY;
            let mut l = 0.0f32;
            acc_o.fill(0.0);
            for c in 0..cpk {
                let mi = p.ml_idx(c, i, head);
                if p.l[mi] == 0.0 {
                    continue; // chunk fully masked for this query
                }
                let (mc, lc) = (p.m[mi], p.l[mi]);
                let m_new = m.max(mc);
                let sa = (m - m_new).exp(); // exp(−inf) = 0 seeds cleanly
                let sb = (mc - m_new).exp();
                l = l * sa + lc * sb;
                let cb = mi * hd;
                for (d, od) in acc_o.iter_mut().enumerate() {
                    *od = *od * sa + p.o[cb + d] * sb;
                }
                m = m_new;
            }
            let inv = if l > 0.0 { 1.0 / l } else { 0.0 };
            for (d, &od) in acc_o.iter().enumerate() {
                out[i * hq + head * hd + d] = od * inv;
            }
        }
    }
    out
}

/// One rank's slice of the distributed attention block.
pub struct DistributedAttentionLayer {
    pub cfg: AttnConfig,
    /// This rank's TP weight shard.
    local: AttnWeights,
    /// Global ranks of this rank's CP group (sorted) and its index.
    pub cp_group: Vec<usize>,
    pub cp_index: usize,
    /// Global ranks of this rank's TP group (sorted) and its index.
    pub tp_group: Vec<usize>,
    pub tp_index: usize,
    /// Optional per-pair compute charge for clocked runs.
    pub phase_cost: Option<AttnPhaseCost>,
    /// Multiplier on the billed KV ring volume (skeleton runs billing
    /// model scale); payload bytes are unaffected.
    pub kv_bill_scale: f64,
    /// Nonblocking ring (default): step `s+1`'s KV transfer hides under
    /// step `s`'s core compute. `false` = blocking p2p before each block's
    /// compute — the serialized twin the differential suite bounds against.
    pub overlap_ring: bool,
}

impl DistributedAttentionLayer {
    /// Build this rank's slice from a runtime-topology view: CP ring group
    /// and TP sequence-parallel group come from the mapping, the weight
    /// shard from the rank's TP coordinate.
    pub fn from_topology(view: &RankView, cfg: AttnConfig, weights: &AttnWeights) -> Self {
        let tp = view.tp_group.len();
        assert_eq!(cfg.hidden % cfg.num_heads, 0, "head_dim must divide hidden");
        assert_eq!(cfg.num_heads % tp, 0, "heads must divide over TP");
        let local = weights.tp_shard(cfg.hidden, tp, view.tp_index);
        Self {
            cfg,
            local,
            cp_group: view.cp_group.clone(),
            cp_index: view.cp_index,
            tp_group: view.tp_group.clone(),
            tp_index: view.tp_index,
            phase_cost: None,
            kv_bill_scale: 1.0,
            overlap_ring: true,
        }
    }

    /// Attach the per-pair compute charge for clocked execution.
    pub fn with_phase_cost(mut self, pc: AttnPhaseCost) -> Self {
        self.phase_cost = Some(pc);
        self
    }

    /// Bill ring KV transfers at `scale ×` their payload bytes.
    pub fn with_kv_bill_scale(mut self, scale: f64) -> Self {
        self.kv_bill_scale = scale.max(0.0);
        self
    }

    /// Toggle the nonblocking ring (see field docs).
    pub fn with_overlap(mut self, on: bool) -> Self {
        self.overlap_ring = on;
        self
    }

    /// This rank's input slice of a full sequence: zig-zag CP shard, then
    /// the contiguous 1/tp sequence-parallel sub-slice.
    pub fn input_slice(&self, tokens: &[f32]) -> Vec<f32> {
        let h = self.cfg.hidden;
        let shard = zigzag::shard(tokens, h, self.cp_group.len(), self.cp_index, self.cfg.zigzag);
        let rows = shard.len() / h / self.tp_group.len();
        shard[self.tp_index * rows * h..(self.tp_index + 1) * rows * h].to_vec()
    }

    /// Forward of this rank's sequence-parallel slice (`seq/(cp·tp)` rows ×
    /// `hidden`) of a `seq`-token causal sequence. Must be entered by every
    /// rank of the TP × CP block. Returns the rank's output slice (same
    /// shape as the input) and the ring accounting.
    pub fn forward(
        &self,
        comm: &Communicator,
        my_rows: &[f32],
        seq: usize,
    ) -> (Vec<f32>, AttnStats) {
        let h = self.cfg.hidden;
        let cp = self.cp_group.len();
        let tp = self.tp_group.len();
        let cpk = self.cfg.kv_chunks;
        assert_eq!(seq % cpk, 0, "seq must divide into kv_chunks");
        if self.cfg.zigzag {
            assert_eq!(cpk % (2 * cp), 0, "kv_chunks must be a multiple of 2·cp");
        } else {
            assert_eq!(cpk % cp, 0, "kv_chunks must be a multiple of cp");
        }
        let n_shard = seq / cp;
        assert_eq!(my_rows.len(), n_shard / tp * h, "input must be the SP slice");
        let mut stats = AttnStats::default();

        // 1. Sequence-parallel AllGather: assemble the CP shard over TP.
        comm.set_phase("attn/sp_ag");
        let shard_tokens = if tp > 1 {
            comm.all_gather_v(&self.tp_group, my_rows)
        } else {
            my_rows.to_vec()
        };

        // 2. Project Q/K/V with the local head shard.
        let hq = h / tp;
        let q = matmul(&shard_tokens, &self.local.wq, n_shard, h, hq);
        let k = matmul(&shard_tokens, &self.local.wk, n_shard, h, hq);
        let v = matmul(&shard_tokens, &self.local.wv, n_shard, h, hq);

        // 3. Ring over CP: process the held KV block while the next one's
        //    transfer is in flight; partials land on the canonical chunk
        //    grid keyed by the block owner's global positions.
        let heads_local = self.cfg.num_heads / tp;
        let hd = self.cfg.head_dim();
        let scale = 1.0 / (hd as f32).sqrt();
        let qpos = zigzag::shard_positions(seq, cp, self.cp_index, self.cfg.zigzag);
        let mut partials = Partials::new(cpk, n_shard, heads_local, hd);
        let mut core_pairs = 0usize;
        let process_block = |partials: &mut Partials, owner: usize, kv: &[f32]| -> usize {
            let (k_rows, v_rows) = kv.split_at(n_shard * hq);
            let kpos = zigzag::shard_positions(seq, cp, owner, self.cfg.zigzag);
            let chunk_rows = seq / cpk;
            // The owner's shard is a run of whole canonical chunks per
            // piece; walk them in shard-row order.
            let mut pairs = 0usize;
            let mut row = 0usize;
            while row < n_shard {
                let pos0 = kpos[row];
                debug_assert_eq!(pos0 % chunk_rows, 0, "piece must align to the chunk grid");
                let chunk = pos0 / chunk_rows;
                pairs += accumulate_chunk(
                    partials,
                    chunk,
                    &q,
                    &qpos,
                    &k_rows[row * hq..(row + chunk_rows) * hq],
                    &v_rows[row * hq..(row + chunk_rows) * hq],
                    pos0,
                    chunk_rows,
                    scale,
                );
                row += chunk_rows;
            }
            pairs
        };

        let mut cur_kv: Vec<f32> = Vec::with_capacity(2 * n_shard * hq);
        cur_kv.extend_from_slice(&k);
        cur_kv.extend_from_slice(&v);
        let mut cur_owner = self.cp_index;
        stats.ring_steps = cp.saturating_sub(1);
        for step in 1..cp {
            let dst = self.cp_group[(self.cp_index + 1) % cp];
            let src = self.cp_group[(self.cp_index + cp - 1) % cp];
            let billed = cur_kv.len() as f64 * 4.0 * self.kv_bill_scale;
            comm.send_tagged_billed(dst, RING_TAG_BASE + step as u64, &cur_kv, billed);
            stats.kv_send_bytes += cur_kv.len() * 4;
            if self.overlap_ring {
                // Take the incoming block (payloads move eagerly; the clock
                // charge rides the handle), compute the held block under the
                // transfer, then settle the exposed remainder.
                let (buf, handle) = comm.irecv_tagged(src, RING_TAG_BASE + step as u64);
                let pairs = process_block(&mut partials, cur_owner, &cur_kv);
                core_pairs += pairs;
                if let Some(pc) = self.phase_cost {
                    comm.advance("attn/core", pc.core_us_per_pair * pairs as f64);
                }
                let (hid, exp) = comm.wait_split(handle);
                stats.cp_hidden_us += hid;
                stats.cp_exposed_us += exp;
                stats.kv_recv_bytes += buf.len() * 4;
                cur_kv = buf;
            } else {
                // Serialized twin: settle the transfer before computing —
                // the wait lands fully exposed on the main lane.
                let (buf, handle) = comm.irecv_tagged(src, RING_TAG_BASE + step as u64);
                let (hid, exp) = comm.wait_split(handle);
                stats.cp_hidden_us += hid;
                stats.cp_exposed_us += exp;
                let pairs = process_block(&mut partials, cur_owner, &cur_kv);
                core_pairs += pairs;
                if let Some(pc) = self.phase_cost {
                    comm.advance("attn/core", pc.core_us_per_pair * pairs as f64);
                }
                stats.kv_recv_bytes += buf.len() * 4;
                cur_kv = buf;
            }
            cur_owner = (cur_owner + cp - 1) % cp;
        }
        // Final block: no transfer rides under it.
        let pairs = process_block(&mut partials, cur_owner, &cur_kv);
        core_pairs += pairs;
        if let Some(pc) = self.phase_cost {
            comm.advance("attn/core", pc.core_us_per_pair * pairs as f64);
        }
        debug_assert_eq!(
            core_pairs,
            qpos.iter().map(|&p| p + 1).sum::<usize>(),
            "every causal pair computed exactly once"
        );

        // 4. Canonical-order LSE merge + output projection.
        let attn_out = merge_output(&partials, cpk);
        let y_part = matmul(&attn_out, &self.local.wo, n_shard, hq, h);

        // 5. Sequence-parallel ReduceScatter: sum TP partials, split rows.
        comm.set_phase("attn/sp_rs");
        let out = if tp > 1 {
            let counts = vec![n_shard / tp * h; tp];
            comm.reduce_scatter_v(&self.tp_group, &y_part, &counts)
        } else {
            y_part
        };
        comm.clear_phase();
        (out, stats)
    }
}

/// Single-process reference: the same canonical-chunk attention with no
/// parallelism (`tp = cp = 1`). Bit-identical to any `tp = 1` distributed
/// run sharing `kv_chunks`, for every `cp` and both sharding layouts.
pub fn reference_forward(cfg: &AttnConfig, weights: &AttnWeights, tokens: &[f32]) -> Vec<f32> {
    let h = cfg.hidden;
    let n = tokens.len() / h;
    let cpk = cfg.kv_chunks;
    assert_eq!(n % cpk, 0, "seq must divide into kv_chunks");
    let q = matmul(tokens, &weights.wq, n, h, h);
    let k = matmul(tokens, &weights.wk, n, h, h);
    let v = matmul(tokens, &weights.wv, n, h, h);
    let hd = cfg.hidden / cfg.num_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let qpos: Vec<usize> = (0..n).collect();
    let mut partials = Partials::new(cpk, n, cfg.num_heads, hd);
    let chunk_rows = n / cpk;
    for c in 0..cpk {
        accumulate_chunk(
            &mut partials,
            c,
            &q,
            &qpos,
            &k[c * chunk_rows * h..(c + 1) * chunk_rows * h],
            &v[c * chunk_rows * h..(c + 1) * chunk_rows * h],
            c * chunk_rows,
            chunk_rows,
            scale,
        );
    }
    let attn_out = merge_output(&partials, cpk);
    matmul(&attn_out, &weights.wo, n, h, h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ParallelConfig;
    use crate::mapping::RuntimeTopology;
    use crate::simcomm::run_ranks;

    fn cfg(zigzag: bool) -> AttnConfig {
        AttnConfig { hidden: 16, num_heads: 2, kv_chunks: 8, zigzag }
    }

    fn tokens(seq: usize, h: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed);
        let mut t = vec![0.0f32; seq * h];
        rng.fill_normal(&mut t, 1.0);
        t
    }

    /// Reference softmax probabilities sum to 1: the canonical-chunk LSE
    /// path is a real softmax, cross-checked against a direct O(n²) causal
    /// softmax within tolerance.
    #[test]
    fn reference_matches_direct_softmax() {
        let c = cfg(true);
        let mut rng = Rng::seed_from_u64(3);
        let w = AttnWeights::init(c.hidden, &mut rng);
        let toks = tokens(16, c.hidden, 4);
        let got = reference_forward(&c, &w, &toks);
        // Direct: per head, full score row softmax.
        let h = c.hidden;
        let n = 16usize;
        let q = matmul(&toks, &w.wq, n, h, h);
        let k = matmul(&toks, &w.wk, n, h, h);
        let v = matmul(&toks, &w.wv, n, h, h);
        let hd = c.head_dim();
        let scale = 1.0 / (hd as f32).sqrt();
        let mut attn = vec![0.0f32; n * h];
        for i in 0..n {
            for head in 0..c.num_heads {
                let qs = &q[i * h + head * hd..i * h + head * hd + hd];
                let mut s: Vec<f32> = (0..=i)
                    .map(|j| {
                        let ks = &k[j * h + head * hd..j * h + head * hd + hd];
                        qs.iter().zip(ks).map(|(a, b)| a * b).sum::<f32>() * scale
                    })
                    .collect();
                let m = s.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut l = 0.0f32;
                for x in s.iter_mut() {
                    *x = (*x - m).exp();
                    l += *x;
                }
                for (j, w_j) in s.iter().enumerate() {
                    let vs = &v[j * h + head * hd..j * h + head * hd + hd];
                    for d in 0..hd {
                        attn[i * h + head * hd + d] += w_j / l * vs[d];
                    }
                }
            }
        }
        let want = matmul(&attn, &w.wo, n, h, h);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    /// The executed cp=1 layer equals the pure reference bit-for-bit.
    #[test]
    fn single_rank_layer_equals_reference() {
        for zigzag in [true, false] {
            let c = cfg(zigzag);
            let mut rng = Rng::seed_from_u64(7);
            let w = AttnWeights::init(c.hidden, &mut rng);
            let toks = tokens(32, c.hidden, 8);
            let want = reference_forward(&c, &w, &toks);
            let topo = RuntimeTopology::folded(ParallelConfig::new(1, 1, 1, 1, 1, 1)).unwrap();
            let outs = run_ranks(1, |rank, comm| {
                let layer = DistributedAttentionLayer::from_topology(topo.view(rank), c, &w);
                let (out, stats) = layer.forward(&comm, &layer.input_slice(&toks), 32);
                assert_eq!(stats.ring_steps, 0);
                out
            });
            assert_eq!(outs[0].len(), want.len());
            for (a, b) in outs[0].iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "zigzag {zigzag}");
            }
        }
    }
}
