//! Zig-zag causal sequence sharding for context parallelism.
//!
//! Plain contiguous sharding of a causal sequence over `cp` ranks is
//! maximally imbalanced: the rank holding the head of the sequence attends
//! to almost nothing while the rank holding the tail attends to everything.
//! The zig-zag layout (Megatron-Core's CP sharding) splits the sequence
//! into `2·cp` equal chunks and gives rank `i` chunks `i` and
//! `2·cp − 1 − i`:
//!
//! ```text
//! chunks:   0   1   2   3   4   5   6   7        (cp = 4)
//! rank:     0   1   2   3   3   2   1   0
//! ```
//!
//! Every rank then owns one early and one late chunk, and the causal
//! attention work (each query position `p` attends to `p + 1` keys) sums to
//! *exactly* the same count on every rank — pinned by
//! `tests/prop_invariants.rs` via [`causal_workload`].
//!
//! Sharding is pure row movement (no arithmetic), so a shard → unshard
//! round trip is bit-exact by construction; the property suite pins it for
//! arbitrary `seq % (2·cp) == 0` lengths.

/// The two chunk ids (of the `2·cp` grid) owned by CP rank `idx`, in the
/// order their rows are stored in the rank's shard.
pub fn zigzag_chunks(cp: usize, idx: usize) -> [usize; 2] {
    assert!(idx < cp);
    [idx, 2 * cp - 1 - idx]
}

/// CP rank owning chunk `chunk` of the `2·cp` zig-zag grid.
pub fn zigzag_owner(cp: usize, chunk: usize) -> usize {
    assert!(chunk < 2 * cp);
    if chunk < cp {
        chunk
    } else {
        2 * cp - 1 - chunk
    }
}

/// Global token positions held by CP rank `idx` (ascending within each
/// chunk, chunks in [`zigzag_chunks`] order) under zig-zag sharding of a
/// `seq`-token sequence. `contiguous` = the naive split for comparison.
pub fn shard_positions(seq: usize, cp: usize, idx: usize, zigzag: bool) -> Vec<usize> {
    assert!(idx < cp);
    if zigzag {
        assert_eq!(seq % (2 * cp), 0, "seq must divide over 2·cp chunks");
        let c = seq / (2 * cp);
        zigzag_chunks(cp, idx)
            .iter()
            .flat_map(|&ch| ch * c..(ch + 1) * c)
            .collect()
    } else {
        assert_eq!(seq % cp, 0, "seq must divide over cp ranks");
        let c = seq / cp;
        (idx * c..(idx + 1) * c).collect()
    }
}

/// Cut CP rank `idx`'s shard out of `tokens` (`n × h` row-major).
pub fn shard(tokens: &[f32], h: usize, cp: usize, idx: usize, zigzag: bool) -> Vec<f32> {
    let n = tokens.len() / h;
    let pos = shard_positions(n, cp, idx, zigzag);
    let mut out = Vec::with_capacity(pos.len() * h);
    for p in pos {
        out.extend_from_slice(&tokens[p * h..(p + 1) * h]);
    }
    out
}

/// Reassemble the full sequence from all `cp` rank shards (inverse of
/// [`shard`]; bit-exact — rows only move, no arithmetic).
pub fn unshard(shards: &[Vec<f32>], h: usize, zigzag: bool) -> Vec<f32> {
    let cp = shards.len();
    let n: usize = shards.iter().map(|s| s.len() / h).sum();
    let mut out = vec![0.0f32; n * h];
    for (idx, s) in shards.iter().enumerate() {
        for (row, p) in shard_positions(n, cp, idx, zigzag).into_iter().enumerate() {
            out[p * h..(p + 1) * h].copy_from_slice(&s[row * h..(row + 1) * h]);
        }
    }
    out
}

/// Causal attention work units on CP rank `idx`: `Σ (p + 1)` over the
/// rank's query positions `p` (each position attends to `p + 1` keys).
/// Under zig-zag this is identical on every rank; under contiguous
/// sharding the spread grows linearly with `cp`.
pub fn causal_workload(seq: usize, cp: usize, idx: usize, zigzag: bool) -> u64 {
    shard_positions(seq, cp, idx, zigzag)
        .into_iter()
        .map(|p| p as u64 + 1)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zigzag_chunk_ownership() {
        assert_eq!(zigzag_chunks(4, 0), [0, 7]);
        assert_eq!(zigzag_chunks(4, 3), [3, 4]);
        for cp in [1usize, 2, 4, 8] {
            for ch in 0..2 * cp {
                let owner = zigzag_owner(cp, ch);
                assert!(zigzag_chunks(cp, owner).contains(&ch));
            }
        }
    }

    #[test]
    fn shard_unshard_roundtrip() {
        let h = 3;
        let n = 16;
        let tokens: Vec<f32> = (0..n * h).map(|i| i as f32).collect();
        for zigzag in [true, false] {
            for cp in [1usize, 2, 4] {
                let shards: Vec<Vec<f32>> =
                    (0..cp).map(|i| shard(&tokens, h, cp, i, zigzag)).collect();
                assert_eq!(unshard(&shards, h, zigzag), tokens, "cp {cp} zigzag {zigzag}");
            }
        }
    }

    #[test]
    fn zigzag_workload_is_exactly_balanced() {
        for cp in [2usize, 4, 8] {
            let seq = 16 * cp;
            let w0 = causal_workload(seq, cp, 0, true);
            for idx in 1..cp {
                assert_eq!(causal_workload(seq, cp, idx, true), w0, "cp {cp} idx {idx}");
            }
            // Contiguous: the last rank does strictly more than the first.
            let first = causal_workload(seq, cp, 0, false);
            let last = causal_workload(seq, cp, cp - 1, false);
            assert!(last > first);
        }
    }
}
