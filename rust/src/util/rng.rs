//! Deterministic PRNG (xoshiro256++) — the offline environment has no `rand`
//! crate, and the trainer/dispatcher need reproducible streams anyway.

/// xoshiro256++ by Blackman & Vigna (public domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 seed (including 0) works.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        (self.next_u64() % n.max(1) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn next_normal_f32(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fill a slice with N(0, std^2) values.
    pub fn fill_normal(&mut self, buf: &mut [f32], std: f32) {
        for v in buf.iter_mut() {
            *v = self.next_normal_f32() * std;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.next_below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(3);
        let n = 50_000;
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for _ in 0..n {
            let x = r.next_normal_f32() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn next_below_bounds() {
        let mut r = Rng::seed_from_u64(9);
        for _ in 0..1000 {
            assert!(r.next_below(17) < 17);
        }
        assert_eq!(r.next_below(1), 0);
    }
}
