//! Mini property-testing scaffold (no `proptest` offline).
//!
//! `forall` draws `cases` random inputs from a generator closure, runs the
//! property, and on failure re-runs a simple shrink loop (halving numeric
//! fields is the caller's job via `Shrink`); failures report the seed so the
//! case can be replayed deterministically.

use super::rng::Rng;

/// Run `prop` on `cases` inputs drawn by `gen`. Panics with the failing seed
/// on the first violated case.
pub fn forall<T: std::fmt::Debug, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let base_seed = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Rng::seed_from_u64(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed (case {case}, PROP_SEED={seed}):\n  input: {input:?}\n  error: {msg}"
            );
        }
    }
}

/// Draw helpers for common generator shapes.
pub mod draw {
    use super::Rng;

    /// Power of two in [1, max] (inclusive), where max need not be a power.
    pub fn pow2_upto(rng: &mut Rng, max: usize) -> usize {
        let max_log = (usize::BITS - 1 - max.max(1).leading_zeros()) as usize;
        1 << rng.next_below(max_log + 1)
    }

    /// Uniform usize in [lo, hi].
    pub fn in_range(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.next_below(hi - lo + 1)
    }

    /// Random divisor of n.
    pub fn divisor_of(rng: &mut Rng, n: usize) -> usize {
        let divs: Vec<usize> = (1..=n).filter(|d| n % d == 0).collect();
        divs[rng.next_below(divs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("trivial", 50, |r| r.next_below(100), |x| {
            count += 1;
            if *x < 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_panics_with_seed() {
        forall("fails", 10, |r| r.next_below(10), |_| Err("always".into()));
    }

    #[test]
    fn draw_pow2() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..100 {
            let p = draw::pow2_upto(&mut r, 64);
            assert!(p.is_power_of_two() && p <= 64);
        }
    }

    #[test]
    fn draw_divisor() {
        let mut r = Rng::seed_from_u64(2);
        for _ in 0..50 {
            let d = draw::divisor_of(&mut r, 24);
            assert_eq!(24 % d, 0);
        }
    }
}
