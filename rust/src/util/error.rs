//! Minimal `anyhow`-compatible error shim.
//!
//! The offline build cannot resolve the `anyhow` crate, so the handful of
//! fallible subsystems (runtime, trainer, CLI) use this instead. The
//! call-site surface matches the subset of `anyhow` the crate used:
//! `Result<T>`, the [`crate::anyhow!`] macro (format-string or expression
//! forms), and `?`-conversion from any `std::error::Error`.

use std::fmt;

/// A boxed, message-carrying error. Context chains are flattened into the
/// message at construction time (no backtrace support offline).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build from anything printable (mirrors `anyhow::Error::msg`).
    pub fn msg(m: impl fmt::Display) -> Self {
        Self { msg: m.to_string() }
    }

    /// The flattened message.
    pub fn to_string_lossy(&self) -> &str {
        &self.msg
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `?`-conversion from any standard error (io, parse, …). `Error` itself
// deliberately does not implement `std::error::Error`, exactly like
// `anyhow::Error`, so this blanket impl cannot overlap `From<Error>`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Self::msg(e.to_string())
    }
}

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Drop-in for `anyhow::anyhow!`: a format string with args, or any single
/// displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(, $arg:expr)* $(,)?) => {
        $crate::util::error::Error::msg(format!($msg $(, $arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_and_expr_forms() {
        let a = anyhow!("bad dim {} in {}", 3, "spec");
        assert_eq!(format!("{a}"), "bad dim 3 in spec");
        let b = anyhow!("plain");
        assert_eq!(format!("{b:?}"), "plain");
        let msg = String::from("owned");
        let c = anyhow!(msg);
        assert_eq!(format!("{c}"), "owned");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(read().is_err());

        fn parse() -> Result<usize> {
            Ok("12x".parse::<usize>()?)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn collect_into_result() {
        let ok: Result<Vec<usize>> = ["1", "2"].iter().map(|s| Ok(s.len())).collect();
        assert_eq!(ok.unwrap(), vec![1, 1]);
    }
}
