//! Minimal criterion-style benchmark harness.
//!
//! The offline environment cannot resolve the `criterion` crate, so the
//! `cargo bench` targets (one per paper table/figure) use this in-crate
//! harness instead: warmup, timed iterations, median / mean / MAD / p95
//! reporting, and a CSV sink for EXPERIMENTS.md. Interface is deliberately
//! criterion-like (`Bencher::iter`).

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

/// Re-export of `std::hint::black_box` for benchmark bodies.
pub fn black_box<T>(x: T) -> T {
    bb(x)
}

/// Statistics over one benchmark's samples (nanoseconds).
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub samples: Vec<f64>,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub mad_ns: f64,
    pub p95_ns: f64,
    pub iters_per_sample: u64,
}

impl Stats {
    fn from_samples(name: &str, mut s: Vec<f64>, iters: u64) -> Self {
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len().max(1);
        let mean = s.iter().sum::<f64>() / n as f64;
        let median = s[n / 2];
        let mut dev: Vec<f64> = s.iter().map(|x| (x - median).abs()).collect();
        dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = dev[n / 2];
        let p95 = s[(n as f64 * 0.95) as usize % n];
        Self {
            name: name.to_string(),
            samples: s,
            mean_ns: mean,
            median_ns: median,
            mad_ns: mad,
            p95_ns: p95,
            iters_per_sample: iters,
        }
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<48} time: [{} ± {}]  p95: {}  ({} iters/sample)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mad_ns),
            fmt_ns(self.p95_ns),
            self.iters_per_sample
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.1} ns")
    }
}

/// Benchmark runner: collects samples until `target_time` is spent, after a
/// short warmup.
pub struct Harness {
    pub warmup: Duration,
    pub target_time: Duration,
    pub min_samples: usize,
    pub results: Vec<Stats>,
}

impl Default for Harness {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            target_time: Duration::from_secs(2),
            min_samples: 10,
            results: Vec::new(),
        }
    }
}

impl Harness {
    pub fn new() -> Self {
        // CLI conventions: `cargo bench -- --quick` shortens runs.
        let quick = std::env::args().any(|a| a == "--quick");
        let mut h = Self::default();
        if quick {
            h.warmup = Duration::from_millis(50);
            h.target_time = Duration::from_millis(300);
            h.min_samples = 5;
        }
        h
    }

    /// Benchmark `f`, auto-calibrating iterations per sample.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Stats {
        // Warmup + calibration.
        let w0 = Instant::now();
        let mut calib_iters: u64 = 0;
        while w0.elapsed() < self.warmup {
            f();
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_nanos() as f64 / calib_iters.max(1) as f64;
        // Aim for ~50 samples within target_time.
        let iters = ((self.target_time.as_nanos() as f64 / 50.0 / per_iter).floor() as u64).max(1);

        let mut samples = Vec::new();
        let t0 = Instant::now();
        while t0.elapsed() < self.target_time || samples.len() < self.min_samples {
            let s = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(s.elapsed().as_nanos() as f64 / iters as f64);
            if samples.len() >= 500 {
                break;
            }
        }
        let stats = Stats::from_samples(name, samples, iters);
        println!("{}", stats.report_line());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Write all results as CSV (name, median_ns, mean_ns, mad_ns, p95_ns).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut out = String::from("name,median_ns,mean_ns,mad_ns,p95_ns\n");
        for r in &self.results {
            out.push_str(&format!(
                "{},{:.1},{:.1},{:.1},{:.1}\n",
                r.name, r.median_ns, r.mean_ns, r.mad_ns, r.p95_ns
            ));
        }
        std::fs::write(path, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_stats() {
        let mut h = Harness {
            warmup: Duration::from_millis(10),
            target_time: Duration::from_millis(50),
            min_samples: 3,
            results: vec![],
        };
        let mut acc = 0u64;
        h.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        let s = &h.results[0];
        assert!(s.median_ns >= 0.0);
        assert!(s.samples.len() >= 3);
    }

    #[test]
    fn format_ns_ranges() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains(" s"));
    }
}
