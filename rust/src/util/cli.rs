//! Tiny argument parser (the offline environment has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn parse() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v}")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse_from(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["train", "--model", "mixtral-8x22b", "--steps=100", "--verbose"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("model"), Some("mixtral-8x22b"));
        assert_eq!(a.get_usize("steps", 0), 100);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--a", "--b"]);
        assert!(a.flag("a") && a.flag("b"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_usize("gpus", 128), 128);
        assert_eq!(a.get_or("model", "tiny"), "tiny");
        assert_eq!(a.get_f64("cf", 1.0), 1.0);
    }
}
