//! In-crate replacements for crates unavailable in the offline environment:
//! PRNG ([`rng`]), benchmark harness ([`benchkit`]), CLI parsing ([`cli`]),
//! property-test scaffolding ([`prop`]), error handling ([`error`]).

pub mod benchkit;
pub mod cli;
pub mod error;
pub mod prop;
pub mod rng;

pub use rng::Rng;
