//! Per-GPU memory model under a parallel mapping.
//!
//! Reproduces the OOM pattern of Table 1/3 (FSDP and TP+EP+DP fail on
//! Llama3-8x70B) and drives the auto-tuner's feasibility filter. Numbers are
//! bytes per GPU at the training steady state (peak of fwd/bwd).

use crate::config::{ModelConfig, ParallelConfig, Precision, TrainConfig, ZeroStage};

/// Tunable constants of the memory model (calibrated once, documented in
/// EXPERIMENTS.md).
#[derive(Debug, Clone, Copy)]
pub struct MemoryKnobs {
    /// Bytes per parameter for gradients (fp32 main grads).
    pub grad_bytes: f64,
    /// Bytes per parameter for optimizer state (fp32 master + Adam m, v).
    pub optim_bytes: f64,
    /// Activation bytes per token per layer, in units of hidden_size, for
    /// the attention block (post-flash-attention era: no s^2 term).
    pub attn_act_factor: f64,
    /// Additional activation units per routed token (dispatch buffers,
    /// expert intermediates) per active expert.
    pub moe_act_factor: f64,
    /// CUDA/NCCL context + fragmentation overhead (GiB).
    pub framework_overhead_gib: f64,
    /// FSDP transient: number of layer-units gathered simultaneously
    /// (current + prefetch).
    pub fsdp_prefetch_units: f64,
    /// Usable fraction of HBM before the allocator thrashes.
    pub usable_frac: f64,
}

impl Default for MemoryKnobs {
    fn default() -> Self {
        Self {
            grad_bytes: 4.0,
            optim_bytes: 12.0,
            attn_act_factor: 22.0,
            moe_act_factor: 10.0,
            framework_overhead_gib: 6.0,
            fsdp_prefetch_units: 2.0,
            usable_frac: 0.94,
        }
    }
}

/// Memory estimate per GPU (bytes).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryEstimate {
    pub param_bytes: f64,
    pub grad_bytes: f64,
    pub optim_bytes: f64,
    pub activation_bytes: f64,
    pub transient_bytes: f64,
    pub overhead_bytes: f64,
    /// KV-cache bytes (serving only — [`MemoryModel::estimate_serving`]);
    /// 0.0 at training steady state, where no autoregressive cache exists.
    pub kv_cache_bytes: f64,
}

impl MemoryEstimate {
    pub fn total(&self) -> f64 {
        self.param_bytes
            + self.grad_bytes
            + self.optim_bytes
            + self.activation_bytes
            + self.transient_bytes
            + self.overhead_bytes
            + self.kv_cache_bytes
    }

    pub fn total_gib(&self) -> f64 {
        self.total() / (1u64 << 30) as f64
    }

    pub fn fits(&self, hbm_gib: f64, knobs: &MemoryKnobs) -> bool {
        self.total_gib() <= hbm_gib * knobs.usable_frac
    }
}

/// Memory model evaluator.
#[derive(Debug, Clone)]
pub struct MemoryModel {
    pub knobs: MemoryKnobs,
}

impl Default for MemoryModel {
    fn default() -> Self {
        Self { knobs: MemoryKnobs::default() }
    }
}

impl MemoryModel {
    /// Estimate per-GPU memory for `model` trained under `parallel`/`train`
    /// with the given ZeRO stage on the DP (and EDP, for experts) axis.
    pub fn estimate(
        &self,
        model: &ModelConfig,
        parallel: &ParallelConfig,
        train: &TrainConfig,
        zero: ZeroStage,
    ) -> MemoryEstimate {
        let k = &self.knobs;
        let pp = parallel.pp as f64;
        let tp = parallel.tp as f64;
        let cp = parallel.cp as f64;
        let dp = parallel.dp() as f64;
        let edp = parallel.edp() as f64;

        // --- parameter placement ------------------------------------------
        let expert_params_total = model.num_moe_layers() as u64
            * model.num_experts as u64
            * model.params_per_expert();
        let non_expert_params_total = model.total_params() - expert_params_total;

        // Non-expert params shard over TP and PP (CP replicates weights).
        let non_expert_local = non_expert_params_total as f64 / (tp * pp);
        // Expert params shard over EP, ETP and PP.
        let expert_local = expert_params_total as f64
            / (parallel.ep as f64 * parallel.etp as f64 * pp);

        let (param_mult, transient) = match zero {
            // ZeRO-3: persistent copy is 1/dp; transient working copy is
            // `fsdp_prefetch_units` full layers (all experts of the layer).
            ZeroStage::Zero3 => {
                // PyTorch FSDP gathers whole flat layer units: the attention
                // block plus *all locally-hosted experts* of the layer,
                // un-sharded. Without EP that is every expert — the
                // mechanism behind the FSDP OOM on Llama3-8x70B.
                let layer_params = non_expert_params_total as f64 / model.num_layers as f64
                    + (model.num_experts / parallel.ep).max(1) as f64
                        * model.params_per_expert() as f64;
                (
                    1.0 / dp,
                    k.fsdp_prefetch_units * layer_params * 2.0, // bf16 bytes
                )
            }
            _ => (1.0, 0.0),
        };
        let param_bytes =
            2.0 * (non_expert_local * param_mult + expert_local * param_mult_expert(zero, edp))
                + 0.0;

        // --- gradients + optimizer ----------------------------------------
        // Gradients: ZeRO >= 2 shards them; Megatron distopt (ZeRO-1) keeps
        // full main grads during accumulation.
        let grad_shard = match zero {
            ZeroStage::Zero3 => dp,
            _ => 1.0,
        };
        // FSDP keeps sharded bf16 grads (2 B); Megatron keeps fp32 mains.
        let grad_width = if zero == ZeroStage::Zero3 { 2.0 } else { k.grad_bytes };
        let grad_bytes = grad_width
            * (non_expert_local / grad_shard + expert_local / grad_shard_expert(zero, edp));

        // Optimizer states shard over DP for ZeRO-1 and ZeRO-3.
        let opt_shard = match zero {
            ZeroStage::None => 1.0,
            _ => dp,
        };
        let opt_shard_e = match zero {
            ZeroStage::None => 1.0,
            _ => edp,
        };
        let optim_bytes =
            k.optim_bytes * (non_expert_local / opt_shard + expert_local / opt_shard_e)
                // fp32 master weights accompany mixed-precision training.
                + 4.0 * (non_expert_local / opt_shard + expert_local / opt_shard_e);

        // --- activations ---------------------------------------------------
        let h = model.hidden_size as f64;
        let layers_local = model.num_layers as f64 / pp;
        // 1F1B keeps up to `pp` microbatches alive on the first stage.
        let inflight = if parallel.pp > 1 {
            (parallel.pp as f64).min(train.num_microbatches(parallel.dp()) as f64)
        } else {
            1.0
        };
        let cf = match train.drop_policy {
            crate::config::DropPolicy::Dropless => 1.3,
            _ => train.capacity_factor,
        };
        let block_units = k.attn_act_factor + k.moe_act_factor * model.top_k as f64 * cf;
        // Retained activations (incl. KV) are stored at the training
        // precision: fp8 halves this term while weights stay bf16 and the
        // optimizer keeps fp32 masters (Megatron convention) — this is what
        // lets the autotuner's `hbm_gib` gate admit configs under fp8 that
        // bf16 prunes.
        let act_width = match train.precision {
            Precision::Bf16 => 2.0,
            Precision::Fp8 => 1.0,
        };
        let activation_bytes = match zero {
            // FSDP baseline (PyTorch FSDP + TP): no Megatron sequence
            // parallelism — norms/residual/input activations (~12 units) are
            // replicated across TP; only the block intermediates shard.
            // This is what kills FSDP on Llama3-8x70B (Table 1 OOM).
            ZeroStage::Zero3 => {
                let tokens_cp = train.micro_batch_size as f64 * train.seq_len as f64 / cp;
                tokens_cp
                    * layers_local
                    * act_width
                    * h
                    * (8.0 + block_units / tp)
                    * train.activation_retained_frac
                    * inflight
            }
            // Megatron path: sequence parallelism shards everything by TP×CP.
            _ => {
                let tokens_local =
                    train.micro_batch_size as f64 * train.seq_len as f64 / (tp * cp);
                tokens_local
                    * layers_local
                    * act_width
                    * h
                    * block_units
                    * train.activation_retained_frac
                    * inflight
            }
        };

        MemoryEstimate {
            param_bytes,
            grad_bytes,
            optim_bytes,
            activation_bytes,
            transient_bytes: transient,
            overhead_bytes: k.framework_overhead_gib * (1u64 << 30) as f64,
            kv_cache_bytes: 0.0,
        }
    }

    /// Per-GPU memory at inference steady state (ISSUE 10 serving):
    /// weights without gradients or optimizer state, a one-microstep
    /// activation working set, and the **KV cache** — the class training
    /// never has. The cache grows linearly with context (prompt + decoded
    /// length), shards over TP (GQA KV heads) × CP (sequence dimension),
    /// and is precision-aware like retained activations, so FP8 serving
    /// doubles the contexts the same `hbm_gib` gate admits.
    /// `concurrent_seqs` is the number of sequences resident on one model
    /// replica (one DP group); `context_len` is the per-sequence context
    /// the gate must provision for (prompt + max decode).
    pub fn estimate_serving(
        &self,
        model: &ModelConfig,
        parallel: &ParallelConfig,
        precision: Precision,
        concurrent_seqs: usize,
        context_len: usize,
    ) -> MemoryEstimate {
        let k = &self.knobs;
        let pp = parallel.pp as f64;
        let tp = parallel.tp as f64;
        let cp = parallel.cp as f64;

        let expert_params_total = model.num_moe_layers() as u64
            * model.num_experts as u64
            * model.params_per_expert();
        let non_expert_params_total = model.total_params() - expert_params_total;
        let non_expert_local = non_expert_params_total as f64 / (tp * pp);
        let expert_local =
            expert_params_total as f64 / (parallel.ep as f64 * parallel.etp as f64 * pp);
        // Serving stores weights at the serving width (no bf16 masters to
        // keep — fp8 deployments quantize the checkpoint).
        let width = match precision {
            Precision::Bf16 => 2.0,
            Precision::Fp8 => 1.0,
        };
        let param_bytes = width * (non_expert_local + expert_local);

        let h = model.hidden_size as f64;
        let layers_local = model.num_layers as f64 / pp;

        // KV cache: 2 (K+V) · kv_heads · head_dim per token per layer,
        // sharded over TP (heads) × CP (sequence), one entry per resident
        // sequence token.
        let kv_per_token_layer = 2.0 * model.num_query_groups as f64 * model.head_dim() as f64;
        let kv_cache_bytes = concurrent_seqs as f64 * context_len as f64 * layers_local
            * kv_per_token_layer
            * width
            / (tp * cp);

        // Working set of one decode microstep: one token per resident
        // sequence through attention + routed experts (no 1F1B in-flight
        // multiplier, nothing retained for a backward pass).
        // Only one layer's buffers are alive at a time without a backward
        // pass, so no `layers_local` factor here.
        let cf = 1.3; // dropless serving provisioning, as in training
        let block_units = k.attn_act_factor + k.moe_act_factor * model.top_k as f64 * cf;
        let activation_bytes =
            concurrent_seqs as f64 * h * block_units * width / (tp * cp);

        MemoryEstimate {
            param_bytes,
            grad_bytes: 0.0,
            optim_bytes: 0.0,
            activation_bytes,
            transient_bytes: 0.0,
            overhead_bytes: k.framework_overhead_gib * (1u64 << 30) as f64,
            kv_cache_bytes,
        }
    }
}

fn param_mult_expert(zero: ZeroStage, edp: f64) -> f64 {
    match zero {
        ZeroStage::Zero3 => 1.0 / edp,
        _ => 1.0,
    }
}

fn grad_shard_expert(zero: ZeroStage, edp: f64) -> f64 {
    match zero {
        ZeroStage::Zero3 => edp,
        _ => 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn cfg(world: usize, tp: usize, cp: usize, ep: usize, etp: usize, pp: usize) -> ParallelConfig {
        ParallelConfig::new(world, tp, cp, ep, etp, pp)
    }

    #[test]
    fn mcore_mixtral_fits() {
        // Table 3: MCore Mixtral 8x22B on 128 GPUs TP2 EP4 PP8 fits in 80G.
        let m = ModelConfig::mixtral_8x22b();
        let mm = MemoryModel::default();
        let t = TrainConfig::paper_default(4096, 256);
        let est = mm.estimate(&m, &cfg(128, 2, 1, 4, 2, 8), &t, ZeroStage::Zero1);
        assert!(est.fits(80.0, &mm.knobs), "total {:.1} GiB", est.total_gib());
    }

    #[test]
    fn tp_ep_dp_llama3_ooms() {
        // Table 1/3: TP8 EP8 (no PP) on 256 GPUs OOMs for Llama3-8x70B.
        let m = ModelConfig::llama3_8x70b();
        let mm = MemoryModel::default();
        let t = TrainConfig::paper_default(4096, 256);
        let est = mm.estimate(&m, &cfg(256, 8, 1, 8, 8, 1), &t, ZeroStage::Zero1);
        assert!(!est.fits(80.0, &mm.knobs), "total {:.1} GiB", est.total_gib());
    }

    #[test]
    fn zero3_shards_optimizer() {
        let m = ModelConfig::mixtral_8x22b();
        let mm = MemoryModel::default();
        let t = TrainConfig::paper_default(4096, 256);
        let z1 = mm.estimate(&m, &cfg(128, 8, 1, 1, 8, 1), &t, ZeroStage::Zero1);
        let z3 = mm.estimate(&m, &cfg(128, 8, 1, 1, 8, 1), &t, ZeroStage::Zero3);
        assert!(z3.param_bytes < z1.param_bytes);
        assert!(z3.grad_bytes < z1.grad_bytes);
    }

    #[test]
    fn more_pp_less_memory() {
        let m = ModelConfig::mixtral_8x22b();
        let mm = MemoryModel::default();
        let t = TrainConfig::paper_default(4096, 256);
        let p1 = mm.estimate(&m, &cfg(128, 2, 1, 4, 2, 1), &t, ZeroStage::Zero1);
        let p8 = mm.estimate(&m, &cfg(128, 2, 1, 4, 2, 8), &t, ZeroStage::Zero1);
        assert!(p8.param_bytes < p1.param_bytes);
    }

    /// FP8 halves the retained-activation term exactly while weights stay
    /// bf16 and the optimizer keeps fp32 masters — so only activations move
    /// (ISSUE 8: precision-aware memory behind the autotuner's hbm gate).
    #[test]
    fn fp8_halves_activations_only() {
        let m = ModelConfig::mixtral_8x22b();
        let mm = MemoryModel::default();
        let mut t = TrainConfig::paper_default(4096, 256);
        let bf16 = mm.estimate(&m, &cfg(128, 2, 1, 8, 1, 8), &t, ZeroStage::Zero1);
        t.precision = Precision::Fp8;
        let fp8 = mm.estimate(&m, &cfg(128, 2, 1, 8, 1, 8), &t, ZeroStage::Zero1);
        assert_eq!(fp8.activation_bytes, bf16.activation_bytes / 2.0);
        assert_eq!(fp8.param_bytes, bf16.param_bytes, "bf16 master weights");
        assert_eq!(fp8.grad_bytes, bf16.grad_bytes, "fp32 main grads");
        assert_eq!(fp8.optim_bytes, bf16.optim_bytes, "fp32 optimizer masters");
        assert!(fp8.total_gib() < bf16.total_gib());
    }

    /// Serving memory (ISSUE 10): training has no KV class; the serving
    /// estimate's cache grows linearly with context, shards over TP×CP,
    /// halves under FP8, and drops grads/optimizer entirely.
    #[test]
    fn serving_kv_cache_class() {
        let m = ModelConfig::mixtral_8x22b();
        let mm = MemoryModel::default();
        let t = TrainConfig::paper_default(4096, 256);
        let train = mm.estimate(&m, &cfg(128, 2, 1, 4, 2, 8), &t, ZeroStage::Zero1);
        assert_eq!(train.kv_cache_bytes, 0.0, "training has no KV cache");

        let p = cfg(128, 2, 1, 8, 1, 8);
        let short = mm.estimate_serving(&m, &p, Precision::Bf16, 64, 4096);
        let long = mm.estimate_serving(&m, &p, Precision::Bf16, 64, 16384);
        assert_eq!(short.grad_bytes, 0.0);
        assert_eq!(short.optim_bytes, 0.0);
        assert_eq!(long.kv_cache_bytes, 4.0 * short.kv_cache_bytes, "linear in context");
        // Exact pin: 64 seqs · 16384 ctx · (56/8 layers) · 2·8·128 · 2 B / (2·1).
        let expected = 64.0 * 16384.0 * 7.0 * (2.0 * 8.0 * 128.0) * 2.0 / 2.0;
        assert_eq!(long.kv_cache_bytes, expected);

        let tp4 = cfg(128, 4, 1, 8, 1, 4);
        let sharded = mm.estimate_serving(&m, &tp4, Precision::Bf16, 64, 16384);
        // tp 2→4 and pp 8→4: layers_local doubles, tp halves — KV per GPU
        // is unchanged; the tp·cp shard is what moved.
        assert_eq!(sharded.kv_cache_bytes, long.kv_cache_bytes);

        let fp8 = mm.estimate_serving(&m, &p, Precision::Fp8, 64, 16384);
        assert_eq!(fp8.kv_cache_bytes, long.kv_cache_bytes / 2.0, "precision-aware");
        assert!(fp8.param_bytes < long.param_bytes, "serving weights at serving width");
    }

    /// The serving gate prunes what training admits: at heavy concurrency
    /// and long context the KV cache pushes a training-feasible mapping
    /// past `hbm_gib`, while a wider-TP mapping that shards the cache
    /// harder still fits.
    #[test]
    fn serving_kv_gate_prunes_training_feasible_config() {
        let m = ModelConfig::mixtral_8x22b();
        let mm = MemoryModel::default();
        let t = TrainConfig::paper_default(4096, 256);
        let p = cfg(128, 2, 1, 4, 2, 8);
        let train = mm.estimate(&m, &p, &t, ZeroStage::Zero1);
        assert!(train.fits(80.0, &mm.knobs), "training admits TP2 EP4 PP8");
        let serve = mm.estimate_serving(&m, &p, Precision::Bf16, 512, 16384);
        assert!(
            !serve.fits(80.0, &mm.knobs),
            "512×16K KV ({:.1} GiB cache) must blow the same gate",
            serve.kv_cache_bytes / (1u64 << 30) as f64
        );
        // KV per GPU scales as num_layers / (pp·tp·cp): TP8 at the same
        // PP8 quarters the cache.
        let wide = cfg(128, 8, 1, 8, 1, 8);
        let serve_wide = mm.estimate_serving(&m, &wide, Precision::Bf16, 512, 16384);
        assert!(
            serve_wide.fits(80.0, &mm.knobs),
            "TP8 shards the cache back under the gate, {:.1} GiB",
            serve_wide.total_gib()
        );
    }

    #[test]
    fn dropless_needs_more_activation_memory() {
        let m = ModelConfig::mixtral_8x22b_g8t8();
        let mm = MemoryModel::default();
        let mut t = TrainConfig::paper_default(4096, 256);
        let drop = mm.estimate(&m, &cfg(128, 4, 1, 8, 1, 8), &t, ZeroStage::Zero1);
        t.drop_policy = crate::config::DropPolicy::Dropless;
        let dropless = mm.estimate(&m, &cfg(128, 4, 1, 8, 1, 8), &t, ZeroStage::Zero1);
        assert!(dropless.activation_bytes > drop.activation_bytes);
    }
}
