//! Transformer/MoE arithmetic: FLOP counts, parameter placement under a
//! parallel mapping, and activation-memory estimates.
//!
//! These are the quantities the performance model (and the MFU definition)
//! are built on. Conventions follow Megatron-LM's reporting: "model FLOPs"
//! per token = forward FLOPs × 3 (backward ≈ 2× forward), counting the
//! attention quadratic term and only the *activated* experts.

pub mod flops;
pub mod memory;

pub use flops::ModelFlops;
pub use memory::MemoryModel;
