//! FLOP accounting for MoE transformers.

use crate::config::ModelConfig;

/// Per-token forward-FLOP breakdown at a given sequence length.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelFlops {
    /// Attention GEMMs (QKV + output projection), all layers.
    pub attn_gemm: f64,
    /// Attention score/context FLOPs (the seq-dependent quadratic term).
    pub attn_core: f64,
    /// Routed-expert FFN FLOPs (top-k experts only), all MoE layers.
    pub moe_ffn: f64,
    /// Shared-expert + dense-layer FFN FLOPs.
    pub dense_ffn: f64,
    /// Router gating GEMMs.
    pub router: f64,
    /// Output-logit GEMM.
    pub logits: f64,
}

impl ModelFlops {
    /// Forward FLOPs per token.
    pub fn per_token(model: &ModelConfig, seq_len: usize) -> Self {
        let h = model.hidden_size as f64;
        let l = model.num_layers as f64;
        let lm = model.num_moe_layers() as f64;
        let ld = model.num_dense_layers() as f64;
        let kv_dim = (model.num_query_groups * model.head_dim()) as f64;
        let s = seq_len as f64;

        // GEMM flops = 2 * m * n * k; per token m=1.
        let attn_gemm = l * 2.0 * h * (h + 2.0 * kv_dim + h);
        // Causal attention: each token attends to ~s/2 keys on average; score
        // (QK^T) + context (PV) each cost 2*h per key.
        let attn_core = l * 2.0 * 2.0 * h * (s / 2.0);
        let moe_ffn = lm * model.top_k as f64 * 3.0 * 2.0 * h * model.moe_ffn_hidden_size as f64;
        let dense_ffn = ld * 3.0 * 2.0 * h * model.ffn_hidden_size as f64
            + lm * 3.0 * 2.0 * h * model.shared_expert_ffn_hidden_size as f64;
        let router = lm * 2.0 * h * model.num_experts as f64;
        let logits = 2.0 * h * model.vocab_size as f64;
        Self { attn_gemm, attn_core, moe_ffn, dense_ffn, router, logits }
    }

    /// Total forward FLOPs per token.
    pub fn fwd_total(&self) -> f64 {
        self.attn_gemm + self.attn_core + self.moe_ffn + self.dense_ffn + self.router + self.logits
    }

    /// "Model FLOPs" per token for MFU accounting (fwd + bwd = 3 × fwd).
    pub fn model_flops_per_token(&self) -> f64 {
        3.0 * self.fwd_total()
    }

    /// MFU given an achieved per-GPU throughput in tokens/s.
    pub fn mfu(&self, tokens_per_sec_per_gpu: f64, peak_tflops: f64) -> f64 {
        self.model_flops_per_token() * tokens_per_sec_per_gpu / (peak_tflops * 1e12)
    }

    /// Achieved model TFLOPS per GPU given step time and token count.
    pub fn achieved_tflops(&self, tokens: usize, step_time_s: f64, num_gpus: usize) -> f64 {
        self.model_flops_per_token() * tokens as f64 / step_time_s / num_gpus as f64 / 1e12
    }

    /// Router gating FLOPs for one token of one MoE layer (the per-layer
    /// share of `router`). Used by the executed dispatcher's phase charges.
    pub fn router_flops_per_token(model: &ModelConfig) -> f64 {
        2.0 * model.hidden_size as f64 * model.num_experts as f64
    }

    /// Expert FFN FLOPs for one routed token **copy** through one expert's
    /// full width (divide by ETP for a width shard): the three SwiGLU GEMMs.
    pub fn expert_flops_per_copy(model: &ModelConfig) -> f64 {
        3.0 * 2.0 * model.hidden_size as f64 * model.moe_ffn_hidden_size as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn mixtral_flops_match_active_params() {
        let m = ModelConfig::mixtral_8x22b();
        let f = ModelFlops::per_token(&m, 4096);
        // At short-ish seq the GEMM terms should be ≈ 2 × active params.
        let gemm_only = f.attn_gemm + f.moe_ffn + f.dense_ffn + f.router + f.logits;
        let two_p = 2.0 * m.active_params() as f64;
        let ratio = gemm_only / two_p;
        assert!((0.9..1.1).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn quadratic_term_grows_with_seq() {
        let m = ModelConfig::mixtral_8x22b();
        let f4k = ModelFlops::per_token(&m, 4096);
        let f128k = ModelFlops::per_token(&m, 131072);
        assert!((f128k.attn_core / f4k.attn_core - 32.0).abs() < 1e-6);
        assert_eq!(f4k.moe_ffn, f128k.moe_ffn);
    }

    #[test]
    fn fine_grained_same_order_flops() {
        // G8T8 activates 8 experts of 1/8 size: same expert FLOPs as top-2
        // of full size would be 2*16384 vs 8*2048 = times... top_k*ffn:
        // 2*16384 = 32768 vs 8*2048 = 16384 -> G8T8 has *half* the MoE flops.
        let base = ModelFlops::per_token(&ModelConfig::mixtral_8x22b(), 4096);
        let g = ModelFlops::per_token(&ModelConfig::mixtral_8x22b_g8t8(), 4096);
        assert!((g.moe_ffn / base.moe_ffn - 0.5).abs() < 1e-9);
    }

    #[test]
    fn mfu_sanity() {
        let m = ModelConfig::mixtral_8x22b();
        let f = ModelFlops::per_token(&m, 4096);
        // 49.3% MFU on H100 => tokens/s/GPU such that mfu() returns 0.493.
        let flops_tok = f.model_flops_per_token();
        let tps = 0.493 * 989.5e12 / flops_tok;
        let mfu = f.mfu(tps, 989.5);
        assert!((mfu - 0.493).abs() < 1e-9);
    }
}
