//! Pipeline-parallel scheduling: 1F1B and interleaved-1F1B.
//!
//! Three roles:
//! 1. **Schedule generation** — the exact (microbatch, fwd/bwd) order each
//!    stage executes, used by the distributed trainer/coordinator.
//! 2. **Timeline simulation** — given per-microbatch forward/backward stage
//!    times and P2P costs, compute the step makespan and bubble fraction,
//!    which feeds the performance model.
//! 3. **Functional execution** ([`execute_1f1b`]) — run the schedule for
//!    real over the in-process communicator ([`crate::simcomm`]), stages
//!    exchanging activation/gradient buffers point-to-point; used to test
//!    that the schedule's send/recv pattern is deadlock-free and delivers
//!    the right microbatch to the right stage.

use crate::mapping::RuntimeTopology;
use crate::simcomm::Communicator;

/// One unit of pipeline work on a stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipeOp {
    /// Forward of microbatch `mb` for virtual chunk `chunk`.
    Fwd { mb: usize, chunk: usize },
    /// Backward of microbatch `mb` for virtual chunk `chunk`.
    Bwd { mb: usize, chunk: usize },
}

/// Generate the classic 1F1B schedule for `stage` of `pp` stages over `m`
/// microbatches (single model chunk).
///
/// Warmup: `pp - 1 - stage` forwards; steady state: alternating 1F1B;
/// cooldown: remaining backwards.
pub fn schedule_1f1b(stage: usize, pp: usize, m: usize) -> Vec<PipeOp> {
    assert!(stage < pp);
    let warmup = (pp - 1 - stage).min(m);
    let mut ops = Vec::with_capacity(2 * m);
    let mut next_fwd = 0usize;
    let mut next_bwd = 0usize;
    for _ in 0..warmup {
        ops.push(PipeOp::Fwd { mb: next_fwd, chunk: 0 });
        next_fwd += 1;
    }
    // steady 1F1B
    while next_fwd < m {
        ops.push(PipeOp::Fwd { mb: next_fwd, chunk: 0 });
        next_fwd += 1;
        ops.push(PipeOp::Bwd { mb: next_bwd, chunk: 0 });
        next_bwd += 1;
    }
    while next_bwd < m {
        ops.push(PipeOp::Bwd { mb: next_bwd, chunk: 0 });
        next_bwd += 1;
    }
    ops
}

/// Analytic 1F1B bubble fraction: `(pp-1) / (m + pp - 1)`.
pub fn bubble_fraction(pp: usize, m: usize) -> f64 {
    if pp <= 1 {
        0.0
    } else {
        (pp - 1) as f64 / (m + pp - 1) as f64
    }
}

/// Interleaved 1F1B bubble fraction with `vpp` virtual chunks per stage.
pub fn bubble_fraction_interleaved(pp: usize, m: usize, vpp: usize) -> f64 {
    if pp <= 1 {
        0.0
    } else {
        (pp - 1) as f64 / (vpp as f64 * m as f64 + (pp - 1) as f64)
    }
}

/// Generate the **interleaved** 1F1B schedule (Megatron-Core's virtual
/// pipeline) for `stage` of `pp` stages over `m` microbatches with `vpp`
/// model chunks per stage. Model chunk `c` of stage `s` is layer block
/// `c·pp + s`, so one microbatch's forward visits
/// `(0,c0) (1,c0) … (pp−1,c0) (0,c1) …`.
///
/// The schedule is the standard one: the forward stream enumerates virtual
/// microbatches in groups of `pp·vpp` slots — within a group, chunk 0 runs
/// microbatches `g·pp … g·pp+pp−1`, then chunk 1 the same microbatches, and
/// so on; the backward stream mirrors it with the chunk order reversed.
/// Rank `stage` runs `min(total, 2·(pp−stage−1) + (vpp−1)·pp)` warmup
/// forwards, then alternates 1F1B, then drains the backwards. With uniform
/// per-chunk times and free hand-offs the makespan is exactly
/// `(m·vpp + pp − 1)(f + b)` — the closed form behind
/// [`bubble_fraction_interleaved`], pinned by
/// `tests/schedule_equivalence.rs`.
///
/// `vpp == 1` returns the plain [`schedule_1f1b`] (the interleaved warmup
/// formula over-counts by `pp−stage−1` in that degenerate case, exactly as
/// in Megatron, which only takes this path for `vpp > 1`). `vpp > 1`
/// requires `m % pp == 0` (the schedule's microbatch groups span `pp`).
pub fn schedule_interleaved(stage: usize, pp: usize, m: usize, vpp: usize) -> Vec<PipeOp> {
    assert!(stage < pp);
    assert!(vpp >= 1, "vpp must be >= 1");
    if vpp == 1 {
        return schedule_1f1b(stage, pp, m);
    }
    assert!(
        m % pp == 0,
        "interleaved 1F1B requires microbatches ({m}) divisible by pp ({pp})"
    );
    let total = m * vpp;
    let chunk_of = |vid: usize, fwd: bool| -> usize {
        let c = (vid % (pp * vpp)) / pp;
        if fwd {
            c
        } else {
            vpp - 1 - c
        }
    };
    let mb_of = |vid: usize| -> usize { (vid / (pp * vpp)) * pp + vid % pp };
    let warmup = (2 * (pp - stage - 1) + (vpp - 1) * pp).min(total);
    let mut ops = Vec::with_capacity(2 * total);
    let mut next_fwd = 0usize;
    let mut next_bwd = 0usize;
    for _ in 0..warmup {
        ops.push(PipeOp::Fwd { mb: mb_of(next_fwd), chunk: chunk_of(next_fwd, true) });
        next_fwd += 1;
    }
    while next_fwd < total {
        ops.push(PipeOp::Fwd { mb: mb_of(next_fwd), chunk: chunk_of(next_fwd, true) });
        next_fwd += 1;
        ops.push(PipeOp::Bwd { mb: mb_of(next_bwd), chunk: chunk_of(next_bwd, false) });
        next_bwd += 1;
    }
    while next_bwd < total {
        ops.push(PipeOp::Bwd { mb: mb_of(next_bwd), chunk: chunk_of(next_bwd, false) });
        next_bwd += 1;
    }
    ops
}

/// Timeline simulation of 1F1B.
///
/// `fwd_us`/`bwd_us` are per-microbatch per-stage compute times;
/// `p2p_us` is the boundary activation send time. Returns the makespan of
/// the whole pipeline step in microseconds.
pub fn simulate_1f1b(pp: usize, m: usize, fwd_us: f64, bwd_us: f64, p2p_us: f64) -> f64 {
    if pp == 1 {
        return m as f64 * (fwd_us + bwd_us);
    }
    // Event-driven simulation over (stage, op) dependencies.
    // fwd(s, i) needs fwd(s-1, i) done + stage s free.
    // bwd(s, i) needs bwd(s+1, i) done + stage s free.
    let mut fwd_done = vec![vec![f64::INFINITY; m]; pp];
    let mut bwd_done = vec![vec![f64::INFINITY; m]; pp];
    let mut free_at = vec![0.0f64; pp];
    // Execute ops in schedule order per stage, with cross-stage waits.
    // Iterate until fixpoint (schedules are acyclic; two passes suffice if
    // processed in dependency order — we process ops in global topological
    // rounds instead).
    let schedules: Vec<Vec<PipeOp>> = (0..pp).map(|s| schedule_1f1b(s, pp, m)).collect();
    let mut idx = vec![0usize; pp];
    let total_ops: usize = schedules.iter().map(|s| s.len()).sum();
    let mut executed = 0usize;
    while executed < total_ops {
        let mut progressed = false;
        for s in 0..pp {
            while idx[s] < schedules[s].len() {
                let op = schedules[s][idx[s]];
                let ready = match op {
                    PipeOp::Fwd { mb, .. } => {
                        if s == 0 {
                            Some(free_at[s])
                        } else if fwd_done[s - 1][mb].is_finite() {
                            Some(free_at[s].max(fwd_done[s - 1][mb] + p2p_us))
                        } else {
                            None
                        }
                    }
                    PipeOp::Bwd { mb, .. } => {
                        if s == pp - 1 {
                            if fwd_done[s][mb].is_finite() {
                                Some(free_at[s].max(fwd_done[s][mb]))
                            } else {
                                None
                            }
                        } else if bwd_done[s + 1][mb].is_finite() {
                            Some(free_at[s].max(bwd_done[s + 1][mb] + p2p_us))
                        } else {
                            None
                        }
                    }
                };
                let Some(start) = ready else { break };
                match op {
                    PipeOp::Fwd { mb, .. } => {
                        fwd_done[s][mb] = start + fwd_us;
                        free_at[s] = fwd_done[s][mb];
                    }
                    PipeOp::Bwd { mb, .. } => {
                        bwd_done[s][mb] = start + bwd_us;
                        free_at[s] = bwd_done[s][mb];
                    }
                }
                idx[s] += 1;
                executed += 1;
                progressed = true;
            }
        }
        assert!(progressed, "pipeline deadlock: schedule inconsistent");
    }
    free_at.iter().cloned().fold(0.0, f64::max)
}

/// Timeline simulation of **interleaved** 1F1B ([`schedule_interleaved`]).
///
/// `fwd_us`/`bwd_us` are **per-chunk** per-microbatch stage times (a stage
/// holding `vpp` chunks of `L/(pp·vpp)` layers each spends `fwd_us` per
/// chunk visit); `p2p_us` is the per-hop boundary transfer time, paid on
/// every chunk hop including the `stage pp−1 → stage 0` wrap-around.
/// Returns the step makespan in microseconds. `vpp == 1` matches
/// [`simulate_1f1b`] exactly (same schedule, same dependency rules).
pub fn simulate_interleaved(
    pp: usize,
    m: usize,
    vpp: usize,
    fwd_us: f64,
    bwd_us: f64,
    p2p_us: f64,
) -> f64 {
    if pp == 1 && vpp == 1 {
        return m as f64 * (fwd_us + bwd_us);
    }
    // Single-stage "hand-offs" are self-sends — free, like the executed
    // path's `cost.p2p(r, r, …) == 0`.
    let p2p_us = if pp == 1 { 0.0 } else { p2p_us };
    let schedules: Vec<Vec<PipeOp>> =
        (0..pp).map(|s| schedule_interleaved(s, pp, m, vpp)).collect();
    // done[(stage, chunk, mb)] completion times, forward and backward.
    let mut fdone = vec![vec![vec![f64::INFINITY; m]; vpp]; pp];
    let mut bdone = vec![vec![vec![f64::INFINITY; m]; vpp]; pp];
    let mut free_at = vec![0.0f64; pp];
    let mut idx = vec![0usize; pp];
    let total_ops: usize = schedules.iter().map(|s| s.len()).sum();
    let mut executed = 0usize;
    let last = pp - 1;
    while executed < total_ops {
        let mut progressed = false;
        for s in 0..pp {
            while idx[s] < schedules[s].len() {
                let op = schedules[s][idx[s]];
                let ready = match op {
                    PipeOp::Fwd { mb, chunk } => {
                        if s == 0 && chunk == 0 {
                            Some(free_at[s])
                        } else {
                            let (ps, pc) = if s > 0 { (s - 1, chunk) } else { (last, chunk - 1) };
                            if fdone[ps][pc][mb].is_finite() {
                                Some(free_at[s].max(fdone[ps][pc][mb] + p2p_us))
                            } else {
                                None
                            }
                        }
                    }
                    PipeOp::Bwd { mb, chunk } => {
                        if s == last && chunk == vpp - 1 {
                            if fdone[s][chunk][mb].is_finite() {
                                Some(free_at[s].max(fdone[s][chunk][mb]))
                            } else {
                                None
                            }
                        } else {
                            let (ns, nc) = if s < last { (s + 1, chunk) } else { (0, chunk + 1) };
                            if bdone[ns][nc][mb].is_finite() {
                                Some(free_at[s].max(bdone[ns][nc][mb] + p2p_us))
                            } else {
                                None
                            }
                        }
                    }
                };
                let Some(start) = ready else { break };
                match op {
                    PipeOp::Fwd { mb, chunk } => {
                        fdone[s][chunk][mb] = start + fwd_us;
                        free_at[s] = fdone[s][chunk][mb];
                    }
                    PipeOp::Bwd { mb, chunk } => {
                        bdone[s][chunk][mb] = start + bwd_us;
                        free_at[s] = bdone[s][chunk][mb];
                    }
                }
                idx[s] += 1;
                executed += 1;
                progressed = true;
            }
        }
        assert!(progressed, "interleaved pipeline deadlock: schedule inconsistent");
    }
    free_at.iter().cloned().fold(0.0, f64::max)
}

/// Outcome of one stage's [`execute_1f1b`] run.
#[derive(Debug, Clone, Default)]
pub struct PipelineRunResult {
    /// Per-microbatch forward outputs — populated on the **last** stage.
    pub outputs: Vec<Vec<f32>>,
    /// Per-microbatch input gradients — populated on stage **0**.
    pub input_grads: Vec<Vec<f32>>,
    /// On a clocked fabric: one `(op, start_us, end_us)` span per executed
    /// op, covering the op's compute only (recv waits appear as gaps —
    /// that's the bubble, visible in the chrome trace). Empty unclocked.
    pub op_spans: Vec<(PipeOp, f64, f64)>,
    /// This rank's simulated time when its schedule finished (0 unclocked).
    pub finish_us: f64,
}

impl PipelineRunResult {
    /// Total busy (compute) time of this rank's timeline, µs.
    pub fn busy_us(&self) -> f64 {
        self.op_spans.iter().map(|(_, s, e)| e - s).sum()
    }
}

/// Bubble fraction measured from an executed, clocked timeline: the share
/// of the `ranks × makespan` area not covered by op spans. For uniform
/// per-op costs and zero p2p this equals the analytic
/// [`bubble_fraction`] exactly (pinned by `tests/clocked_timing.rs`).
pub fn measured_bubble_fraction(per_rank_busy_us: &[f64], makespan_us: f64) -> f64 {
    if makespan_us <= 0.0 || per_rank_busy_us.is_empty() {
        return 0.0;
    }
    let busy: f64 = per_rank_busy_us.iter().sum();
    (1.0 - busy / (per_rank_busy_us.len() as f64 * makespan_us)).max(0.0)
}

/// Execute the 1F1B schedule functionally over [`crate::simcomm`].
///
/// `stage_group[s]` is the global rank of stage `s` (must contain
/// `comm.rank()`; every member must call this collectively). `inputs` holds
/// stage-0's `m` microbatch activations (ignored on other stages).
/// `fwd(mb, act)` runs this stage's forward; `bwd(mb, grad_in)` its
/// backward. On the last stage the backward is seeded with that stage's own
/// forward output (the caller's `bwd` closure is the loss head).
///
/// Activation/gradient hand-off is point-to-point in schedule order; since
/// 1F1B executes both forwards and backwards in ascending microbatch order
/// on every stage, the per-source FIFO of the fabric delivers each buffer
/// to the op that expects it.
pub fn execute_1f1b<Fw, Bw>(
    comm: &Communicator,
    stage_group: &[usize],
    m: usize,
    inputs: &[Vec<f32>],
    fwd: Fw,
    bwd: Bw,
) -> PipelineRunResult
where
    Fw: FnMut(usize, &[f32]) -> Vec<f32>,
    Bw: FnMut(usize, &[f32]) -> Vec<f32>,
{
    execute_1f1b_with(comm, stage_group, m, inputs, fwd, bwd, None)
}

/// [`execute_1f1b`] with an explicit clock-billed volume for the boundary
/// p2p transfers: when `p2p_billed_bytes` is `Some(b)`, activation and
/// gradient hand-offs are billed as `b` bytes regardless of the real
/// payload size. This is how the executed step estimator
/// ([`crate::perfmodel::executed`]) runs model-scale schedules over tiny
/// stand-in activations.
pub fn execute_1f1b_with<Fw, Bw>(
    comm: &Communicator,
    stage_group: &[usize],
    m: usize,
    inputs: &[Vec<f32>],
    mut fwd: Fw,
    mut bwd: Bw,
    p2p_billed_bytes: Option<f64>,
) -> PipelineRunResult
where
    Fw: FnMut(usize, &[f32]) -> Vec<f32>,
    Bw: FnMut(usize, &[f32]) -> Vec<f32>,
{
    let pp = stage_group.len();
    let stage = stage_group
        .iter()
        .position(|&r| r == comm.rank())
        .expect("rank must be a member of stage_group");
    if stage == 0 {
        assert_eq!(inputs.len(), m, "stage 0 needs one input per microbatch");
    }
    let last = pp - 1;
    let clocked = comm.clocked();
    let send = |dst: usize, data: &[f32]| match p2p_billed_bytes {
        Some(b) => comm.send_billed(dst, data, b),
        None => comm.send(dst, data),
    };
    let mut outputs: Vec<Vec<f32>> = vec![Vec::new(); m];
    let mut input_grads: Vec<Vec<f32>> = vec![Vec::new(); m];
    let mut op_spans = Vec::new();

    for op in schedule_1f1b(stage, pp, m) {
        match op {
            PipeOp::Fwd { mb, .. } => {
                let act = if stage == 0 {
                    let t0 = comm.now_us();
                    let a = fwd(mb, &inputs[mb]);
                    if clocked {
                        op_spans.push((op, t0, comm.now_us()));
                    }
                    a
                } else {
                    let x = comm.recv(stage_group[stage - 1]);
                    let t0 = comm.now_us();
                    let a = fwd(mb, &x);
                    if clocked {
                        op_spans.push((op, t0, comm.now_us()));
                    }
                    a
                };
                if stage < last {
                    send(stage_group[stage + 1], &act);
                } else {
                    outputs[mb] = act;
                }
            }
            PipeOp::Bwd { mb, .. } => {
                let grad_in = if stage == last {
                    outputs[mb].clone()
                } else {
                    comm.recv(stage_group[stage + 1])
                };
                let t0 = comm.now_us();
                let g = bwd(mb, &grad_in);
                if clocked {
                    op_spans.push((op, t0, comm.now_us()));
                }
                if stage > 0 {
                    send(stage_group[stage - 1], &g);
                } else {
                    input_grads[mb] = g;
                }
            }
        }
    }

    PipelineRunResult {
        outputs: if stage == last { outputs } else { Vec::new() },
        input_grads: if stage == 0 { input_grads } else { Vec::new() },
        op_spans,
        finish_us: comm.now_us(),
    }
}

/// Executed, clocked 1F1B **skeleton**: runs the real schedule over the
/// communicator with uniform per-op compute charges (`fwd_us` / `bwd_us`)
/// and boundary p2p transfers billed at `p2p_bytes` — tiny stand-in
/// payloads, model-scale clock. The returned timeline's `finish_us` is the
/// executed counterpart of [`simulate_1f1b`]'s closed-form makespan; the
/// differential suite pins the two against each other.
pub fn execute_1f1b_timed(
    comm: &Communicator,
    stage_group: &[usize],
    m: usize,
    fwd_us: f64,
    bwd_us: f64,
    p2p_bytes: f64,
) -> PipelineRunResult {
    let inputs: Vec<Vec<f32>> = (0..m).map(|mb| vec![mb as f32]).collect();
    execute_1f1b_with(
        comm,
        stage_group,
        m,
        &inputs,
        |_mb, x| {
            comm.advance("fwd", fwd_us);
            x.to_vec()
        },
        |_mb, g| {
            comm.advance("bwd", bwd_us);
            g.to_vec()
        },
        Some(p2p_bytes),
    )
}

/// [`execute_1f1b`] with the stage group taken from a runtime topology:
/// the calling rank's PP group (attention and MoE PP partitions are
/// validated identical), in stage order. This is how folded configurations
/// run the pipeline — the stage group is *never* re-derived from rank
/// arithmetic.
pub fn execute_1f1b_mapped<Fw, Bw>(
    comm: &Communicator,
    topo: &RuntimeTopology,
    m: usize,
    inputs: &[Vec<f32>],
    fwd: Fw,
    bwd: Bw,
) -> PipelineRunResult
where
    Fw: FnMut(usize, &[f32]) -> Vec<f32>,
    Bw: FnMut(usize, &[f32]) -> Vec<f32>,
{
    let view = topo.view(comm.rank());
    execute_1f1b(comm, &view.pp_group, m, inputs, fwd, bwd)
}

/// Message tag of an interleaved-1F1B hand-off, named by the **receiver's**
/// `(direction, chunk, microbatch)`. Interleaved schedules cross forward
/// activations and backward gradients of different chunks on the same rank
/// pair (for `pp == 2` the next and previous ring neighbours coincide), so
/// the executor matches payloads by tag instead of arrival order.
pub(crate) fn chunk_tag(bwd: bool, chunk: usize, mb: usize, vpp: usize) -> u64 {
    1 + (((mb * vpp + chunk) * 2) + bwd as usize) as u64
}

/// Execute the **interleaved** 1F1B schedule functionally over
/// [`crate::simcomm`], with `vpp` model chunks per stage.
///
/// `stage_group[s]` is the global rank of stage `s` (must contain
/// `comm.rank()`; every member must call this collectively). `inputs`
/// holds stage-0's `m` microbatch activations (ignored elsewhere).
/// `fwd(chunk, mb, act)` runs model chunk `chunk` (layer block
/// `chunk·pp + stage`) of this stage; `bwd(chunk, mb, grad)` its backward.
/// The backward of the *last chunk on the last stage* is seeded with that
/// chunk's own forward output (the caller's `bwd` closure is the loss
/// head). Hand-offs are tagged point-to-point messages: stage `s` forwards
/// chunk `c` to stage `s+1`, and the last stage forwards chunk `c` to
/// stage 0 as chunk `c+1` input (the wrap-around hop); gradients flow the
/// reverse ring. `vpp == 1` degenerates to the plain 1F1B dataflow and is
/// bit-identical to [`execute_1f1b`] (pinned by
/// `tests/schedule_equivalence.rs`).
pub fn execute_interleaved<Fw, Bw>(
    comm: &Communicator,
    stage_group: &[usize],
    m: usize,
    vpp: usize,
    inputs: &[Vec<f32>],
    fwd: Fw,
    bwd: Bw,
) -> PipelineRunResult
where
    Fw: FnMut(usize, usize, &[f32]) -> Vec<f32>,
    Bw: FnMut(usize, usize, &[f32]) -> Vec<f32>,
{
    execute_interleaved_with(comm, stage_group, m, vpp, inputs, fwd, bwd, None)
}

/// [`execute_interleaved`] with an explicit clock-billed volume for the
/// boundary p2p transfers (see [`execute_1f1b_with`]).
#[allow(clippy::too_many_arguments)]
pub fn execute_interleaved_with<Fw, Bw>(
    comm: &Communicator,
    stage_group: &[usize],
    m: usize,
    vpp: usize,
    inputs: &[Vec<f32>],
    mut fwd: Fw,
    mut bwd: Bw,
    p2p_billed_bytes: Option<f64>,
) -> PipelineRunResult
where
    Fw: FnMut(usize, usize, &[f32]) -> Vec<f32>,
    Bw: FnMut(usize, usize, &[f32]) -> Vec<f32>,
{
    let pp = stage_group.len();
    let stage = stage_group
        .iter()
        .position(|&r| r == comm.rank())
        .expect("rank must be a member of stage_group");
    if stage == 0 {
        assert_eq!(inputs.len(), m, "stage 0 needs one input per microbatch");
    }
    let last = pp - 1;
    let clocked = comm.clocked();
    let send = |dst: usize, tag: u64, data: &[f32]| match p2p_billed_bytes {
        Some(b) => comm.send_tagged_billed(dst, tag, data, b),
        None => comm.send_tagged(dst, tag, data),
    };
    // Forward outputs of the last chunk on the last stage (the pipeline
    // outputs, and the seeds of its own backward).
    let mut outputs: Vec<Vec<f32>> = vec![Vec::new(); m];
    let mut input_grads: Vec<Vec<f32>> = vec![Vec::new(); m];
    let mut op_spans = Vec::new();

    for op in schedule_interleaved(stage, pp, m, vpp) {
        match op {
            PipeOp::Fwd { mb, chunk } => {
                let act = if stage == 0 && chunk == 0 {
                    let t0 = comm.now_us();
                    let a = fwd(chunk, mb, &inputs[mb]);
                    if clocked {
                        op_spans.push((op, t0, comm.now_us()));
                    }
                    a
                } else {
                    let src = if stage > 0 { stage_group[stage - 1] } else { stage_group[last] };
                    let x = comm.recv_tagged(src, chunk_tag(false, chunk, mb, vpp));
                    let t0 = comm.now_us();
                    let a = fwd(chunk, mb, &x);
                    if clocked {
                        op_spans.push((op, t0, comm.now_us()));
                    }
                    a
                };
                if stage < last {
                    send(stage_group[stage + 1], chunk_tag(false, chunk, mb, vpp), &act);
                } else if chunk < vpp - 1 {
                    send(stage_group[0], chunk_tag(false, chunk + 1, mb, vpp), &act);
                } else {
                    outputs[mb] = act;
                }
            }
            PipeOp::Bwd { mb, chunk } => {
                let grad_in = if stage == last && chunk == vpp - 1 {
                    outputs[mb].clone()
                } else {
                    let src = if stage < last { stage_group[stage + 1] } else { stage_group[0] };
                    comm.recv_tagged(src, chunk_tag(true, chunk, mb, vpp))
                };
                let t0 = comm.now_us();
                let g = bwd(chunk, mb, &grad_in);
                if clocked {
                    op_spans.push((op, t0, comm.now_us()));
                }
                if stage > 0 {
                    send(stage_group[stage - 1], chunk_tag(true, chunk, mb, vpp), &g);
                } else if chunk > 0 {
                    send(stage_group[last], chunk_tag(true, chunk - 1, mb, vpp), &g);
                } else {
                    input_grads[mb] = g;
                }
            }
        }
    }

    PipelineRunResult {
        outputs: if stage == last { outputs } else { Vec::new() },
        input_grads: if stage == 0 { input_grads } else { Vec::new() },
        op_spans,
        finish_us: comm.now_us(),
    }
}

/// Executed, clocked interleaved-1F1B **skeleton**: the real schedule with
/// uniform per-chunk compute charges and boundary p2p billed at
/// `p2p_bytes`. The executed counterpart of [`simulate_interleaved`]; with
/// zero-cost p2p the makespan equals `(m·vpp + pp − 1)(f + b)` to float
/// precision (`tests/schedule_equivalence.rs`).
pub fn execute_interleaved_timed(
    comm: &Communicator,
    stage_group: &[usize],
    m: usize,
    vpp: usize,
    fwd_us: f64,
    bwd_us: f64,
    p2p_bytes: f64,
) -> PipelineRunResult {
    let inputs: Vec<Vec<f32>> = (0..m).map(|mb| vec![mb as f32]).collect();
    execute_interleaved_with(
        comm,
        stage_group,
        m,
        vpp,
        &inputs,
        |_chunk, _mb, x| {
            comm.advance("fwd", fwd_us);
            x.to_vec()
        },
        |_chunk, _mb, g| {
            comm.advance("bwd", bwd_us);
            g.to_vec()
        },
        Some(p2p_bytes),
    )
}

/// [`execute_interleaved`] with the stage group taken from a runtime
/// topology (the mapped counterpart of [`execute_1f1b_mapped`]).
pub fn execute_interleaved_mapped<Fw, Bw>(
    comm: &Communicator,
    topo: &RuntimeTopology,
    m: usize,
    vpp: usize,
    inputs: &[Vec<f32>],
    fwd: Fw,
    bwd: Bw,
) -> PipelineRunResult
where
    Fw: FnMut(usize, usize, &[f32]) -> Vec<f32>,
    Bw: FnMut(usize, usize, &[f32]) -> Vec<f32>,
{
    let view = topo.view(comm.rank());
    execute_interleaved(comm, &view.pp_group, m, vpp, inputs, fwd, bwd)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ParallelConfig;
    use crate::simcomm::run_ranks;

    #[test]
    fn schedule_counts() {
        for pp in [1, 2, 4, 8] {
            for m in [1, 4, 32] {
                for s in 0..pp {
                    let ops = schedule_1f1b(s, pp, m);
                    let f = ops.iter().filter(|o| matches!(o, PipeOp::Fwd { .. })).count();
                    let b = ops.iter().filter(|o| matches!(o, PipeOp::Bwd { .. })).count();
                    assert_eq!(f, m);
                    assert_eq!(b, m);
                }
            }
        }
    }

    #[test]
    fn one_f_one_b_in_steady_state() {
        let ops = schedule_1f1b(0, 4, 8);
        // stage 0 warmup = 3 forwards.
        assert!(matches!(ops[0], PipeOp::Fwd { mb: 0, .. }));
        assert!(matches!(ops[3], PipeOp::Fwd { mb: 3, .. }));
        assert!(matches!(ops[4], PipeOp::Bwd { mb: 0, .. }));
    }

    #[test]
    fn backward_order_matches_forward() {
        let ops = schedule_1f1b(2, 4, 6);
        let bwds: Vec<usize> = ops
            .iter()
            .filter_map(|o| match o {
                PipeOp::Bwd { mb, .. } => Some(*mb),
                _ => None,
            })
            .collect();
        assert_eq!(bwds, (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn simulated_makespan_matches_analytic_bubble() {
        let (pp, m) = (8, 32);
        let f = 100.0;
        let b = 200.0;
        let t = simulate_1f1b(pp, m, f, b, 0.0);
        let ideal = m as f64 * (f + b);
        let analytic = ideal * (1.0 + (pp - 1) as f64 / m as f64);
        // Simulation should be within a few % of the analytic 1F1B bound.
        let rel = (t - analytic).abs() / analytic;
        assert!(rel < 0.05, "sim {t} vs analytic {analytic} rel {rel}");
    }

    #[test]
    fn pp1_has_no_bubble() {
        assert_eq!(bubble_fraction(1, 8), 0.0);
        let t = simulate_1f1b(1, 8, 10.0, 20.0, 5.0);
        assert_eq!(t, 8.0 * 30.0);
    }

    #[test]
    fn interleaving_shrinks_bubble() {
        let plain = bubble_fraction(8, 16);
        let inter = bubble_fraction_interleaved(8, 16, 4);
        assert!(inter < plain);
    }

    /// Regression (m < pp corner audit): with fewer microbatches than
    /// stages the schedule degenerates to all-forwards-then-all-backwards
    /// on the early stages; every stage still runs exactly `m` forwards and
    /// `m` backwards, warmup is clamped at `m`, and both the event-driven
    /// simulation and the executed skeleton still equal the closed form
    /// `(m + pp − 1)(f + b)` for free hand-offs.
    #[test]
    fn m_less_than_pp_corner() {
        for (pp, m) in [(4usize, 1usize), (4, 2), (4, 3), (8, 3), (16, 5)] {
            for s in 0..pp {
                let ops = schedule_1f1b(s, pp, m);
                let f = ops.iter().filter(|o| matches!(o, PipeOp::Fwd { .. })).count();
                let b = ops.iter().filter(|o| matches!(o, PipeOp::Bwd { .. })).count();
                assert_eq!(f, m, "pp={pp} m={m} stage {s} fwd count");
                assert_eq!(b, m, "pp={pp} m={m} stage {s} bwd count");
                // Warmup never exceeds the microbatch count.
                let leading_f = ops
                    .iter()
                    .take_while(|o| matches!(o, PipeOp::Fwd { .. }))
                    .count();
                assert!(leading_f <= m, "pp={pp} m={m} stage {s}: warmup {leading_f} > m");
            }
            let (f, b) = (110.0, 230.0);
            let sim = simulate_1f1b(pp, m, f, b, 0.0);
            let closed = (m + pp - 1) as f64 * (f + b);
            assert!(
                (sim - closed).abs() < 1e-9 * closed,
                "pp={pp} m={m}: sim {sim} vs closed {closed}"
            );
        }
    }

    /// Regression: the degenerate `makespan_us == 0` input (a pipeline that
    /// never ran) reports a 0 bubble instead of NaN/negative garbage; so
    /// does an empty rank list.
    #[test]
    fn measured_bubble_fraction_degenerate_inputs() {
        assert_eq!(measured_bubble_fraction(&[10.0, 20.0], 0.0), 0.0);
        assert_eq!(measured_bubble_fraction(&[], 100.0), 0.0);
        assert_eq!(measured_bubble_fraction(&[0.0, 0.0], 0.0), 0.0);
        // Busy exceeding the area clamps at 0, never negative.
        assert_eq!(measured_bubble_fraction(&[200.0], 100.0), 0.0);
    }

    /// Interleaved schedule: vpp = 1 is byte-for-byte the plain 1F1B
    /// schedule; vpp > 1 runs every (chunk, microbatch) exactly once per
    /// direction with the Megatron warmup count.
    #[test]
    fn interleaved_schedule_counts_and_degenerate() {
        for pp in [2usize, 4, 8] {
            for m in [pp, 2 * pp, 4 * pp] {
                for s in 0..pp {
                    assert_eq!(
                        schedule_interleaved(s, pp, m, 1),
                        schedule_1f1b(s, pp, m),
                        "vpp=1 must degenerate to plain 1F1B (pp={pp} m={m} s={s})"
                    );
                }
                for vpp in [2usize, 3, 4] {
                    for s in 0..pp {
                        let ops = schedule_interleaved(s, pp, m, vpp);
                        assert_eq!(ops.len(), 2 * m * vpp);
                        let mut fseen = vec![vec![false; m]; vpp];
                        let mut bseen = vec![vec![false; m]; vpp];
                        for op in &ops {
                            match *op {
                                PipeOp::Fwd { mb, chunk } => {
                                    assert!(!fseen[chunk][mb], "dup fwd {chunk}/{mb}");
                                    fseen[chunk][mb] = true;
                                }
                                PipeOp::Bwd { mb, chunk } => {
                                    assert!(!bseen[chunk][mb], "dup bwd {chunk}/{mb}");
                                    bseen[chunk][mb] = true;
                                }
                            }
                        }
                        assert!(fseen.iter().flatten().all(|&x| x));
                        assert!(bseen.iter().flatten().all(|&x| x));
                        let warm = ops
                            .iter()
                            .take_while(|o| matches!(o, PipeOp::Fwd { .. }))
                            .count();
                        let expect = (2 * (pp - s - 1) + (vpp - 1) * pp).min(m * vpp);
                        assert!(
                            warm >= expect,
                            "pp={pp} m={m} vpp={vpp} s={s}: {warm} warmup fwds < {expect}"
                        );
                    }
                }
            }
        }
    }

    /// The interleaved event simulation hits the closed form
    /// `(m·vpp + pp − 1)(f + b)` exactly for free hand-offs, matches
    /// [`simulate_1f1b`] at vpp = 1, and p2p only ever adds time.
    #[test]
    fn simulate_interleaved_closed_form_and_degenerate() {
        for pp in [1usize, 2, 4, 8] {
            for mult in [1usize, 2, 4] {
                let m = pp * mult;
                for vpp in [1usize, 2, 3, 4] {
                    let (f, b) = (120.0, 275.5);
                    let sim = simulate_interleaved(pp, m, vpp, f, b, 0.0);
                    let closed = (m * vpp + pp - 1) as f64 * (f + b);
                    assert!(
                        (sim - closed).abs() < 1e-9 * closed,
                        "pp={pp} m={m} vpp={vpp}: sim {sim} vs closed {closed}"
                    );
                    if vpp == 1 {
                        let plain = simulate_1f1b(pp, m, f, b, 7.5);
                        let inter = simulate_interleaved(pp, m, 1, f, b, 7.5);
                        assert!(
                            (plain - inter).abs() < 1e-9,
                            "pp={pp} m={m}: {plain} vs {inter}"
                        );
                    }
                    let with_p2p = simulate_interleaved(pp, m, vpp, f, b, 9.0);
                    assert!(with_p2p >= sim - 1e-9);
                }
            }
        }
    }

    /// Functional interleaved execution composes the virtual chunks in
    /// layer-block order: chunk c of stage s is block c·pp + s, so the
    /// composed forward applies blocks 0, 1, …, pp·vpp − 1 in order (and
    /// the backward reverses it). Affine per-block maps make the
    /// composition exactly checkable.
    #[test]
    fn execute_interleaved_composes_chunked_stages() {
        let pp = 2;
        let vpp = 3;
        let m = 4;
        let width = 5;
        let blocks = pp * vpp;
        let coef = |blk: usize| (blk + 2) as f32;
        let inputs: Vec<Vec<f32>> =
            (0..m).map(|mb| vec![mb as f32 - 1.5; width]).collect();
        let outs = run_ranks(pp, |rank, comm| {
            let group: Vec<usize> = (0..pp).collect();
            execute_interleaved(
                &comm,
                &group,
                m,
                vpp,
                &inputs,
                |chunk, _mb, x| {
                    let a = coef(chunk * pp + rank);
                    x.iter().map(|v| a * v + 1.0).collect()
                },
                |chunk, _mb, g| {
                    let a = coef(chunk * pp + rank);
                    g.iter().map(|v| a * v).collect()
                },
            )
        });
        for mb in 0..m {
            let mut y = inputs[mb].clone();
            for blk in 0..blocks {
                for v in y.iter_mut() {
                    *v = coef(blk) * *v + 1.0;
                }
            }
            assert_eq!(outs[pp - 1].outputs[mb], y, "mb {mb} forward");
            let mut g = y.clone();
            for blk in (0..blocks).rev() {
                for v in g.iter_mut() {
                    *v *= coef(blk);
                }
            }
            assert_eq!(outs[0].input_grads[mb], g, "mb {mb} backward");
        }
    }

    /// Executed interleaved skeleton on the clocked fabric equals the
    /// event-driven simulation for nonzero p2p as well (same dependency
    /// structure, same receiver-pays billing).
    #[test]
    fn executed_interleaved_matches_simulation_with_p2p() {
        use crate::cluster::ClusterSpec;
        use crate::collectives::CommCost;
        use crate::simcomm::{run_ranks_on, AlgoSelection, Fabric};
        for (pp, m, vpp) in [(2usize, 4usize, 2usize), (4, 4, 2), (4, 8, 3)] {
            let mut cluster = ClusterSpec::eos(pp);
            cluster.nvlink_latency_us = 0.0;
            cluster.ib_latency_us = 0.0;
            let cost = CommCost::new(cluster);
            let p2p_bytes = 1.5e6;
            let p2p_us = cost.p2p(0, 1, p2p_bytes);
            let fabric = Fabric::new_clocked(pp, AlgoSelection::fast(), cost);
            let group: Vec<usize> = (0..pp).collect();
            let (f, b) = (100.0, 180.0);
            let outs = run_ranks_on(&fabric, |_, comm| {
                execute_interleaved_timed(&comm, &group, m, vpp, f, b, p2p_bytes)
            });
            let executed = outs.iter().map(|r| r.finish_us).fold(0.0, f64::max);
            let simulated = simulate_interleaved(pp, m, vpp, f, b, p2p_us);
            assert!(
                (executed - simulated).abs() < 1e-6 * simulated,
                "pp={pp} m={m} vpp={vpp}: executed {executed} vs simulated {simulated}"
            );
        }
    }

    #[test]
    fn p2p_adds_latency() {
        let t0 = simulate_1f1b(4, 8, 100.0, 200.0, 0.0);
        let t1 = simulate_1f1b(4, 8, 100.0, 200.0, 10.0);
        assert!(t1 > t0);
    }

    /// The timing satellite of ISSUE 3: the **executed** clocked 1F1B
    /// timeline (real schedule, real p2p messages, virtual clock) must
    /// match the event-driven [`simulate_1f1b`] recurrence exactly, with
    /// p2p send/recv accounted in both. For zero p2p both equal the
    /// textbook uniform makespan `(m+pp−1)(f+b)` and the measured bubble
    /// fraction equals the analytic [`bubble_fraction`]; with p2p the
    /// naive `m(f+b)+(pp−1)(f+b+2·p2p)` is only a **lower bound** (each
    /// steady-state microbatch pays part of the cross-stage round trip
    /// too — a threaded model-check of this schedule confirms the
    /// event-driven number, which is why the closed form is not used for
    /// p2p > 0 anywhere in the estimator).
    #[test]
    fn executed_clocked_1f1b_matches_simulation_and_closed_form() {
        use crate::cluster::ClusterSpec;
        use crate::collectives::CommCost;
        use crate::simcomm::{run_ranks_on, AlgoSelection, Fabric};
        for (pp, m) in [(1usize, 4usize), (2, 4), (4, 2), (4, 8), (8, 16)] {
            for (f, b, p2p_bytes) in [(100.0, 200.0, 0.0), (120.0, 240.0, 2.0e6)] {
                // Zero link latency so `p2p_bytes == 0` really means free
                // hand-offs (the latency term would otherwise smear the
                // exact bubble identity below).
                let mut cluster = ClusterSpec::eos(pp);
                cluster.nvlink_latency_us = 0.0;
                cluster.ib_latency_us = 0.0;
                let cost = CommCost::new(cluster);
                let p2p_us = if pp > 1 { cost.p2p(0, 1, p2p_bytes) } else { 0.0 };
                let fabric = Fabric::new_clocked(pp, AlgoSelection::fast(), cost);
                let group: Vec<usize> = (0..pp).collect();
                let outs = run_ranks_on(&fabric, |_, comm| {
                    execute_1f1b_timed(&comm, &group, m, f, b, p2p_bytes)
                });
                let executed = outs.iter().map(|r| r.finish_us).fold(0.0, f64::max);
                let simulated = simulate_1f1b(pp, m, f, b, p2p_us);
                let closed =
                    m as f64 * (f + b) + (pp - 1) as f64 * (f + b + 2.0 * p2p_us);
                assert!(
                    (executed - simulated).abs() < 1e-6 * simulated,
                    "pp={pp} m={m} p2p={p2p_us:.2}: executed {executed} vs simulated {simulated}"
                );
                if p2p_bytes == 0.0 {
                    assert!(
                        (simulated - closed).abs() < 1e-6 * closed,
                        "pp={pp} m={m}: simulated {simulated} vs closed {closed}"
                    );
                } else {
                    assert!(
                        simulated >= closed - 1e-6 * closed,
                        "pp={pp} m={m} p2p={p2p_us:.2}: closed form must lower-bound \
                         the executed makespan ({simulated} vs {closed})"
                    );
                }
                if p2p_bytes == 0.0 {
                    let busy: Vec<f64> = outs.iter().map(|r| r.busy_us()).collect();
                    let measured = measured_bubble_fraction(&busy, executed);
                    let analytic = bubble_fraction(pp, m);
                    assert!(
                        (measured - analytic).abs() < 1e-9,
                        "pp={pp} m={m}: measured bubble {measured} vs analytic {analytic}"
                    );
                }
            }
        }
    }

    /// Functional 1F1B over simcomm: affine stages compose exactly, and
    /// each microbatch reaches every stage in order (m > pp exercises the
    /// steady-state interleave).
    #[test]
    fn execute_1f1b_composes_affine_stages() {
        let pp = 4;
        let m = 8;
        let width = 6;
        let inputs: Vec<Vec<f32>> = (0..m).map(|mb| vec![mb as f32 + 0.5; width]).collect();
        let outs = run_ranks(pp, |rank, comm| {
            let group: Vec<usize> = (0..pp).collect();
            let a = (rank + 2) as f32;
            let b = rank as f32;
            execute_1f1b(
                &comm,
                &group,
                m,
                &inputs,
                |_mb, x| x.iter().map(|v| a * v + b).collect(),
                |_mb, g| g.iter().map(|v| a * v).collect(),
            )
        });
        for mb in 0..m {
            // Reference forward/backward, same op order as the pipeline.
            let mut y = inputs[mb].clone();
            for s in 0..pp {
                let a = (s + 2) as f32;
                let b = s as f32;
                for v in y.iter_mut() {
                    *v = a * *v + b;
                }
            }
            assert_eq!(outs[pp - 1].outputs[mb], y, "mb {mb} forward");
            let mut g = y.clone();
            for s in (0..pp).rev() {
                let a = (s + 2) as f32;
                for v in g.iter_mut() {
                    *v *= a;
                }
            }
            assert_eq!(outs[0].input_grads[mb], g, "mb {mb} backward");
        }
        // Non-terminal stages report nothing.
        assert!(outs[1].outputs.is_empty() && outs[1].input_grads.is_empty());
    }

    /// Stage groups from a folded mapping: TP2·PP2 on 8 ranks puts pipeline
    /// neighbours 4 ranks apart ({r, r+4}), and every rank's stage index is
    /// its position in the mapping's PP group — not its rank id.
    #[test]
    fn execute_1f1b_stage_groups_from_folded_mapping() {
        let topo = RuntimeTopology::folded(ParallelConfig::new(8, 2, 1, 2, 1, 2)).unwrap();
        let m = 4;
        let width = 3;
        let inputs: Vec<Vec<f32>> = (0..m).map(|mb| vec![mb as f32; width]).collect();
        let outs = run_ranks(8, |_rank, comm| {
            execute_1f1b_mapped(
                &comm,
                &topo,
                m,
                &inputs,
                |_mb, x| x.iter().map(|v| v + 1.0).collect(),
                |_mb, g| g.to_vec(),
            )
        });
        for r in 0..8 {
            let view = topo.view(r);
            assert_eq!(view.pp_group, vec![r % 4, r % 4 + 4]);
            if view.pp_stage == 1 {
                // Last stage: two stages each add 1.0.
                for mb in 0..m {
                    assert_eq!(outs[r].outputs[mb], vec![mb as f32 + 2.0; width]);
                }
                assert!(outs[r].input_grads.is_empty());
            } else {
                assert!(outs[r].outputs.is_empty());
                for mb in 0..m {
                    assert_eq!(outs[r].input_grads[mb], vec![mb as f32 + 2.0; width]);
                }
            }
        }
    }

    /// Single-stage degenerate case: outputs and input grads both come back.
    #[test]
    fn execute_1f1b_single_stage() {
        let inputs = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        let outs = run_ranks(1, |_, comm| {
            execute_1f1b(
                &comm,
                &[0],
                2,
                &inputs,
                |_mb, x| x.iter().map(|v| v * 2.0).collect(),
                |_mb, g| g.iter().map(|v| v + 1.0).collect(),
            )
        });
        assert_eq!(outs[0].outputs, vec![vec![2.0, 4.0], vec![6.0, 8.0]]);
        assert_eq!(outs[0].input_grads, vec![vec![3.0, 5.0], vec![7.0, 9.0]]);
    }

    /// Stages on non-contiguous global ranks (a folded layout): the stage
    /// index comes from the group position, not the rank id.
    #[test]
    fn execute_1f1b_non_contiguous_stage_group() {
        let inputs = vec![vec![2.0f32; 3]; 4];
        let outs = run_ranks(3, |rank, comm| {
            let group = [0usize, 2]; // rank 1 sits out
            if group.contains(&rank) {
                Some(execute_1f1b(
                    &comm,
                    &group,
                    4,
                    &inputs,
                    |_mb, x| x.iter().map(|v| v + 10.0).collect(),
                    |_mb, g| g.to_vec(),
                ))
            } else {
                None
            }
        });
        let last = outs[2].as_ref().unwrap();
        assert_eq!(last.outputs, vec![vec![22.0f32; 3]; 4]);
        let first = outs[0].as_ref().unwrap();
        assert_eq!(first.input_grads, vec![vec![22.0f32; 3]; 4]);
    }
}
