//! Pipeline-parallel scheduling: 1F1B and interleaved-1F1B.
//!
//! Three roles:
//! 1. **Schedule generation** — the exact (microbatch, fwd/bwd) order each
//!    stage executes, used by the distributed trainer/coordinator.
//! 2. **Timeline simulation** — given per-microbatch forward/backward stage
//!    times and P2P costs, compute the step makespan and bubble fraction,
//!    which feeds the performance model.
//! 3. **Functional execution** ([`execute_1f1b`]) — run the schedule for
//!    real over the in-process communicator ([`crate::simcomm`]), stages
//!    exchanging activation/gradient buffers point-to-point; used to test
//!    that the schedule's send/recv pattern is deadlock-free and delivers
//!    the right microbatch to the right stage.

use crate::mapping::RuntimeTopology;
use crate::simcomm::Communicator;

/// One unit of pipeline work on a stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipeOp {
    /// Forward of microbatch `mb` for virtual chunk `chunk`.
    Fwd { mb: usize, chunk: usize },
    /// Backward of microbatch `mb` for virtual chunk `chunk`.
    Bwd { mb: usize, chunk: usize },
}

/// Generate the classic 1F1B schedule for `stage` of `pp` stages over `m`
/// microbatches (single model chunk).
///
/// Warmup: `pp - 1 - stage` forwards; steady state: alternating 1F1B;
/// cooldown: remaining backwards.
pub fn schedule_1f1b(stage: usize, pp: usize, m: usize) -> Vec<PipeOp> {
    assert!(stage < pp);
    let warmup = (pp - 1 - stage).min(m);
    let mut ops = Vec::with_capacity(2 * m);
    let mut next_fwd = 0usize;
    let mut next_bwd = 0usize;
    for _ in 0..warmup {
        ops.push(PipeOp::Fwd { mb: next_fwd, chunk: 0 });
        next_fwd += 1;
    }
    // steady 1F1B
    while next_fwd < m {
        ops.push(PipeOp::Fwd { mb: next_fwd, chunk: 0 });
        next_fwd += 1;
        ops.push(PipeOp::Bwd { mb: next_bwd, chunk: 0 });
        next_bwd += 1;
    }
    while next_bwd < m {
        ops.push(PipeOp::Bwd { mb: next_bwd, chunk: 0 });
        next_bwd += 1;
    }
    ops
}

/// Analytic 1F1B bubble fraction: `(pp-1) / (m + pp - 1)`.
pub fn bubble_fraction(pp: usize, m: usize) -> f64 {
    if pp <= 1 {
        0.0
    } else {
        (pp - 1) as f64 / (m + pp - 1) as f64
    }
}

/// Interleaved 1F1B bubble fraction with `vpp` virtual chunks per stage.
pub fn bubble_fraction_interleaved(pp: usize, m: usize, vpp: usize) -> f64 {
    if pp <= 1 {
        0.0
    } else {
        (pp - 1) as f64 / (vpp as f64 * m as f64 + (pp - 1) as f64)
    }
}

/// Timeline simulation of 1F1B.
///
/// `fwd_us`/`bwd_us` are per-microbatch per-stage compute times;
/// `p2p_us` is the boundary activation send time. Returns the makespan of
/// the whole pipeline step in microseconds.
pub fn simulate_1f1b(pp: usize, m: usize, fwd_us: f64, bwd_us: f64, p2p_us: f64) -> f64 {
    if pp == 1 {
        return m as f64 * (fwd_us + bwd_us);
    }
    // Event-driven simulation over (stage, op) dependencies.
    // fwd(s, i) needs fwd(s-1, i) done + stage s free.
    // bwd(s, i) needs bwd(s+1, i) done + stage s free.
    let mut fwd_done = vec![vec![f64::INFINITY; m]; pp];
    let mut bwd_done = vec![vec![f64::INFINITY; m]; pp];
    let mut free_at = vec![0.0f64; pp];
    // Execute ops in schedule order per stage, with cross-stage waits.
    // Iterate until fixpoint (schedules are acyclic; two passes suffice if
    // processed in dependency order — we process ops in global topological
    // rounds instead).
    let schedules: Vec<Vec<PipeOp>> = (0..pp).map(|s| schedule_1f1b(s, pp, m)).collect();
    let mut idx = vec![0usize; pp];
    let total_ops: usize = schedules.iter().map(|s| s.len()).sum();
    let mut executed = 0usize;
    while executed < total_ops {
        let mut progressed = false;
        for s in 0..pp {
            while idx[s] < schedules[s].len() {
                let op = schedules[s][idx[s]];
                let ready = match op {
                    PipeOp::Fwd { mb, .. } => {
                        if s == 0 {
                            Some(free_at[s])
                        } else if fwd_done[s - 1][mb].is_finite() {
                            Some(free_at[s].max(fwd_done[s - 1][mb] + p2p_us))
                        } else {
                            None
                        }
                    }
                    PipeOp::Bwd { mb, .. } => {
                        if s == pp - 1 {
                            if fwd_done[s][mb].is_finite() {
                                Some(free_at[s].max(fwd_done[s][mb]))
                            } else {
                                None
                            }
                        } else if bwd_done[s + 1][mb].is_finite() {
                            Some(free_at[s].max(bwd_done[s + 1][mb] + p2p_us))
                        } else {
                            None
                        }
                    }
                };
                let Some(start) = ready else { break };
                match op {
                    PipeOp::Fwd { mb, .. } => {
                        fwd_done[s][mb] = start + fwd_us;
                        free_at[s] = fwd_done[s][mb];
                    }
                    PipeOp::Bwd { mb, .. } => {
                        bwd_done[s][mb] = start + bwd_us;
                        free_at[s] = bwd_done[s][mb];
                    }
                }
                idx[s] += 1;
                executed += 1;
                progressed = true;
            }
        }
        assert!(progressed, "pipeline deadlock: schedule inconsistent");
    }
    free_at.iter().cloned().fold(0.0, f64::max)
}

/// Outcome of one stage's [`execute_1f1b`] run.
#[derive(Debug, Clone, Default)]
pub struct PipelineRunResult {
    /// Per-microbatch forward outputs — populated on the **last** stage.
    pub outputs: Vec<Vec<f32>>,
    /// Per-microbatch input gradients — populated on stage **0**.
    pub input_grads: Vec<Vec<f32>>,
    /// On a clocked fabric: one `(op, start_us, end_us)` span per executed
    /// op, covering the op's compute only (recv waits appear as gaps —
    /// that's the bubble, visible in the chrome trace). Empty unclocked.
    pub op_spans: Vec<(PipeOp, f64, f64)>,
    /// This rank's simulated time when its schedule finished (0 unclocked).
    pub finish_us: f64,
}

impl PipelineRunResult {
    /// Total busy (compute) time of this rank's timeline, µs.
    pub fn busy_us(&self) -> f64 {
        self.op_spans.iter().map(|(_, s, e)| e - s).sum()
    }
}

/// Bubble fraction measured from an executed, clocked timeline: the share
/// of the `ranks × makespan` area not covered by op spans. For uniform
/// per-op costs and zero p2p this equals the analytic
/// [`bubble_fraction`] exactly (pinned by `tests/clocked_timing.rs`).
pub fn measured_bubble_fraction(per_rank_busy_us: &[f64], makespan_us: f64) -> f64 {
    if makespan_us <= 0.0 || per_rank_busy_us.is_empty() {
        return 0.0;
    }
    let busy: f64 = per_rank_busy_us.iter().sum();
    (1.0 - busy / (per_rank_busy_us.len() as f64 * makespan_us)).max(0.0)
}

/// Execute the 1F1B schedule functionally over [`crate::simcomm`].
///
/// `stage_group[s]` is the global rank of stage `s` (must contain
/// `comm.rank()`; every member must call this collectively). `inputs` holds
/// stage-0's `m` microbatch activations (ignored on other stages).
/// `fwd(mb, act)` runs this stage's forward; `bwd(mb, grad_in)` its
/// backward. On the last stage the backward is seeded with that stage's own
/// forward output (the caller's `bwd` closure is the loss head).
///
/// Activation/gradient hand-off is point-to-point in schedule order; since
/// 1F1B executes both forwards and backwards in ascending microbatch order
/// on every stage, the per-source FIFO of the fabric delivers each buffer
/// to the op that expects it.
pub fn execute_1f1b<Fw, Bw>(
    comm: &Communicator,
    stage_group: &[usize],
    m: usize,
    inputs: &[Vec<f32>],
    fwd: Fw,
    bwd: Bw,
) -> PipelineRunResult
where
    Fw: FnMut(usize, &[f32]) -> Vec<f32>,
    Bw: FnMut(usize, &[f32]) -> Vec<f32>,
{
    execute_1f1b_with(comm, stage_group, m, inputs, fwd, bwd, None)
}

/// [`execute_1f1b`] with an explicit clock-billed volume for the boundary
/// p2p transfers: when `p2p_billed_bytes` is `Some(b)`, activation and
/// gradient hand-offs are billed as `b` bytes regardless of the real
/// payload size. This is how the executed step estimator
/// ([`crate::perfmodel::executed`]) runs model-scale schedules over tiny
/// stand-in activations.
pub fn execute_1f1b_with<Fw, Bw>(
    comm: &Communicator,
    stage_group: &[usize],
    m: usize,
    inputs: &[Vec<f32>],
    mut fwd: Fw,
    mut bwd: Bw,
    p2p_billed_bytes: Option<f64>,
) -> PipelineRunResult
where
    Fw: FnMut(usize, &[f32]) -> Vec<f32>,
    Bw: FnMut(usize, &[f32]) -> Vec<f32>,
{
    let pp = stage_group.len();
    let stage = stage_group
        .iter()
        .position(|&r| r == comm.rank())
        .expect("rank must be a member of stage_group");
    if stage == 0 {
        assert_eq!(inputs.len(), m, "stage 0 needs one input per microbatch");
    }
    let last = pp - 1;
    let clocked = comm.clocked();
    let send = |dst: usize, data: &[f32]| match p2p_billed_bytes {
        Some(b) => comm.send_billed(dst, data, b),
        None => comm.send(dst, data),
    };
    let mut outputs: Vec<Vec<f32>> = vec![Vec::new(); m];
    let mut input_grads: Vec<Vec<f32>> = vec![Vec::new(); m];
    let mut op_spans = Vec::new();

    for op in schedule_1f1b(stage, pp, m) {
        match op {
            PipeOp::Fwd { mb, .. } => {
                let act = if stage == 0 {
                    let t0 = comm.now_us();
                    let a = fwd(mb, &inputs[mb]);
                    if clocked {
                        op_spans.push((op, t0, comm.now_us()));
                    }
                    a
                } else {
                    let x = comm.recv(stage_group[stage - 1]);
                    let t0 = comm.now_us();
                    let a = fwd(mb, &x);
                    if clocked {
                        op_spans.push((op, t0, comm.now_us()));
                    }
                    a
                };
                if stage < last {
                    send(stage_group[stage + 1], &act);
                } else {
                    outputs[mb] = act;
                }
            }
            PipeOp::Bwd { mb, .. } => {
                let grad_in = if stage == last {
                    outputs[mb].clone()
                } else {
                    comm.recv(stage_group[stage + 1])
                };
                let t0 = comm.now_us();
                let g = bwd(mb, &grad_in);
                if clocked {
                    op_spans.push((op, t0, comm.now_us()));
                }
                if stage > 0 {
                    send(stage_group[stage - 1], &g);
                } else {
                    input_grads[mb] = g;
                }
            }
        }
    }

    PipelineRunResult {
        outputs: if stage == last { outputs } else { Vec::new() },
        input_grads: if stage == 0 { input_grads } else { Vec::new() },
        op_spans,
        finish_us: comm.now_us(),
    }
}

/// Executed, clocked 1F1B **skeleton**: runs the real schedule over the
/// communicator with uniform per-op compute charges (`fwd_us` / `bwd_us`)
/// and boundary p2p transfers billed at `p2p_bytes` — tiny stand-in
/// payloads, model-scale clock. The returned timeline's `finish_us` is the
/// executed counterpart of [`simulate_1f1b`]'s closed-form makespan; the
/// differential suite pins the two against each other.
pub fn execute_1f1b_timed(
    comm: &Communicator,
    stage_group: &[usize],
    m: usize,
    fwd_us: f64,
    bwd_us: f64,
    p2p_bytes: f64,
) -> PipelineRunResult {
    let inputs: Vec<Vec<f32>> = (0..m).map(|mb| vec![mb as f32]).collect();
    execute_1f1b_with(
        comm,
        stage_group,
        m,
        &inputs,
        |_mb, x| {
            comm.advance("fwd", fwd_us);
            x.to_vec()
        },
        |_mb, g| {
            comm.advance("bwd", bwd_us);
            g.to_vec()
        },
        Some(p2p_bytes),
    )
}

/// [`execute_1f1b`] with the stage group taken from a runtime topology:
/// the calling rank's PP group (attention and MoE PP partitions are
/// validated identical), in stage order. This is how folded configurations
/// run the pipeline — the stage group is *never* re-derived from rank
/// arithmetic.
pub fn execute_1f1b_mapped<Fw, Bw>(
    comm: &Communicator,
    topo: &RuntimeTopology,
    m: usize,
    inputs: &[Vec<f32>],
    fwd: Fw,
    bwd: Bw,
) -> PipelineRunResult
where
    Fw: FnMut(usize, &[f32]) -> Vec<f32>,
    Bw: FnMut(usize, &[f32]) -> Vec<f32>,
{
    let view = topo.view(comm.rank());
    execute_1f1b(comm, &view.pp_group, m, inputs, fwd, bwd)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ParallelConfig;
    use crate::simcomm::run_ranks;

    #[test]
    fn schedule_counts() {
        for pp in [1, 2, 4, 8] {
            for m in [1, 4, 32] {
                for s in 0..pp {
                    let ops = schedule_1f1b(s, pp, m);
                    let f = ops.iter().filter(|o| matches!(o, PipeOp::Fwd { .. })).count();
                    let b = ops.iter().filter(|o| matches!(o, PipeOp::Bwd { .. })).count();
                    assert_eq!(f, m);
                    assert_eq!(b, m);
                }
            }
        }
    }

    #[test]
    fn one_f_one_b_in_steady_state() {
        let ops = schedule_1f1b(0, 4, 8);
        // stage 0 warmup = 3 forwards.
        assert!(matches!(ops[0], PipeOp::Fwd { mb: 0, .. }));
        assert!(matches!(ops[3], PipeOp::Fwd { mb: 3, .. }));
        assert!(matches!(ops[4], PipeOp::Bwd { mb: 0, .. }));
    }

    #[test]
    fn backward_order_matches_forward() {
        let ops = schedule_1f1b(2, 4, 6);
        let bwds: Vec<usize> = ops
            .iter()
            .filter_map(|o| match o {
                PipeOp::Bwd { mb, .. } => Some(*mb),
                _ => None,
            })
            .collect();
        assert_eq!(bwds, (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn simulated_makespan_matches_analytic_bubble() {
        let (pp, m) = (8, 32);
        let f = 100.0;
        let b = 200.0;
        let t = simulate_1f1b(pp, m, f, b, 0.0);
        let ideal = m as f64 * (f + b);
        let analytic = ideal * (1.0 + (pp - 1) as f64 / m as f64);
        // Simulation should be within a few % of the analytic 1F1B bound.
        let rel = (t - analytic).abs() / analytic;
        assert!(rel < 0.05, "sim {t} vs analytic {analytic} rel {rel}");
    }

    #[test]
    fn pp1_has_no_bubble() {
        assert_eq!(bubble_fraction(1, 8), 0.0);
        let t = simulate_1f1b(1, 8, 10.0, 20.0, 5.0);
        assert_eq!(t, 8.0 * 30.0);
    }

    #[test]
    fn interleaving_shrinks_bubble() {
        let plain = bubble_fraction(8, 16);
        let inter = bubble_fraction_interleaved(8, 16, 4);
        assert!(inter < plain);
    }

    #[test]
    fn p2p_adds_latency() {
        let t0 = simulate_1f1b(4, 8, 100.0, 200.0, 0.0);
        let t1 = simulate_1f1b(4, 8, 100.0, 200.0, 10.0);
        assert!(t1 > t0);
    }

    /// The timing satellite of ISSUE 3: the **executed** clocked 1F1B
    /// timeline (real schedule, real p2p messages, virtual clock) must
    /// match the event-driven [`simulate_1f1b`] recurrence exactly, with
    /// p2p send/recv accounted in both. For zero p2p both equal the
    /// textbook uniform makespan `(m+pp−1)(f+b)` and the measured bubble
    /// fraction equals the analytic [`bubble_fraction`]; with p2p the
    /// naive `m(f+b)+(pp−1)(f+b+2·p2p)` is only a **lower bound** (each
    /// steady-state microbatch pays part of the cross-stage round trip
    /// too — a threaded model-check of this schedule confirms the
    /// event-driven number, which is why the closed form is not used for
    /// p2p > 0 anywhere in the estimator).
    #[test]
    fn executed_clocked_1f1b_matches_simulation_and_closed_form() {
        use crate::cluster::ClusterSpec;
        use crate::collectives::CommCost;
        use crate::simcomm::{run_ranks_on, AlgoSelection, Fabric};
        for (pp, m) in [(1usize, 4usize), (2, 4), (4, 2), (4, 8), (8, 16)] {
            for (f, b, p2p_bytes) in [(100.0, 200.0, 0.0), (120.0, 240.0, 2.0e6)] {
                // Zero link latency so `p2p_bytes == 0` really means free
                // hand-offs (the latency term would otherwise smear the
                // exact bubble identity below).
                let mut cluster = ClusterSpec::eos(pp);
                cluster.nvlink_latency_us = 0.0;
                cluster.ib_latency_us = 0.0;
                let cost = CommCost::new(cluster);
                let p2p_us = if pp > 1 { cost.p2p(0, 1, p2p_bytes) } else { 0.0 };
                let fabric = Fabric::new_clocked(pp, AlgoSelection::fast(), cost);
                let group: Vec<usize> = (0..pp).collect();
                let outs = run_ranks_on(&fabric, |_, comm| {
                    execute_1f1b_timed(&comm, &group, m, f, b, p2p_bytes)
                });
                let executed = outs.iter().map(|r| r.finish_us).fold(0.0, f64::max);
                let simulated = simulate_1f1b(pp, m, f, b, p2p_us);
                let closed =
                    m as f64 * (f + b) + (pp - 1) as f64 * (f + b + 2.0 * p2p_us);
                assert!(
                    (executed - simulated).abs() < 1e-6 * simulated,
                    "pp={pp} m={m} p2p={p2p_us:.2}: executed {executed} vs simulated {simulated}"
                );
                if p2p_bytes == 0.0 {
                    assert!(
                        (simulated - closed).abs() < 1e-6 * closed,
                        "pp={pp} m={m}: simulated {simulated} vs closed {closed}"
                    );
                } else {
                    assert!(
                        simulated >= closed - 1e-6 * closed,
                        "pp={pp} m={m} p2p={p2p_us:.2}: closed form must lower-bound \
                         the executed makespan ({simulated} vs {closed})"
                    );
                }
                if p2p_bytes == 0.0 {
                    let busy: Vec<f64> = outs.iter().map(|r| r.busy_us()).collect();
                    let measured = measured_bubble_fraction(&busy, executed);
                    let analytic = bubble_fraction(pp, m);
                    assert!(
                        (measured - analytic).abs() < 1e-9,
                        "pp={pp} m={m}: measured bubble {measured} vs analytic {analytic}"
                    );
                }
            }
        }
    }

    /// Functional 1F1B over simcomm: affine stages compose exactly, and
    /// each microbatch reaches every stage in order (m > pp exercises the
    /// steady-state interleave).
    #[test]
    fn execute_1f1b_composes_affine_stages() {
        let pp = 4;
        let m = 8;
        let width = 6;
        let inputs: Vec<Vec<f32>> = (0..m).map(|mb| vec![mb as f32 + 0.5; width]).collect();
        let outs = run_ranks(pp, |rank, comm| {
            let group: Vec<usize> = (0..pp).collect();
            let a = (rank + 2) as f32;
            let b = rank as f32;
            execute_1f1b(
                &comm,
                &group,
                m,
                &inputs,
                |_mb, x| x.iter().map(|v| a * v + b).collect(),
                |_mb, g| g.iter().map(|v| a * v).collect(),
            )
        });
        for mb in 0..m {
            // Reference forward/backward, same op order as the pipeline.
            let mut y = inputs[mb].clone();
            for s in 0..pp {
                let a = (s + 2) as f32;
                let b = s as f32;
                for v in y.iter_mut() {
                    *v = a * *v + b;
                }
            }
            assert_eq!(outs[pp - 1].outputs[mb], y, "mb {mb} forward");
            let mut g = y.clone();
            for s in (0..pp).rev() {
                let a = (s + 2) as f32;
                for v in g.iter_mut() {
                    *v *= a;
                }
            }
            assert_eq!(outs[0].input_grads[mb], g, "mb {mb} backward");
        }
        // Non-terminal stages report nothing.
        assert!(outs[1].outputs.is_empty() && outs[1].input_grads.is_empty());
    }

    /// Stage groups from a folded mapping: TP2·PP2 on 8 ranks puts pipeline
    /// neighbours 4 ranks apart ({r, r+4}), and every rank's stage index is
    /// its position in the mapping's PP group — not its rank id.
    #[test]
    fn execute_1f1b_stage_groups_from_folded_mapping() {
        let topo = RuntimeTopology::folded(ParallelConfig::new(8, 2, 1, 2, 1, 2)).unwrap();
        let m = 4;
        let width = 3;
        let inputs: Vec<Vec<f32>> = (0..m).map(|mb| vec![mb as f32; width]).collect();
        let outs = run_ranks(8, |_rank, comm| {
            execute_1f1b_mapped(
                &comm,
                &topo,
                m,
                &inputs,
                |_mb, x| x.iter().map(|v| v + 1.0).collect(),
                |_mb, g| g.to_vec(),
            )
        });
        for r in 0..8 {
            let view = topo.view(r);
            assert_eq!(view.pp_group, vec![r % 4, r % 4 + 4]);
            if view.pp_stage == 1 {
                // Last stage: two stages each add 1.0.
                for mb in 0..m {
                    assert_eq!(outs[r].outputs[mb], vec![mb as f32 + 2.0; width]);
                }
                assert!(outs[r].input_grads.is_empty());
            } else {
                assert!(outs[r].outputs.is_empty());
                for mb in 0..m {
                    assert_eq!(outs[r].input_grads[mb], vec![mb as f32 + 2.0; width]);
                }
            }
        }
    }

    /// Single-stage degenerate case: outputs and input grads both come back.
    #[test]
    fn execute_1f1b_single_stage() {
        let inputs = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        let outs = run_ranks(1, |_, comm| {
            execute_1f1b(
                &comm,
                &[0],
                2,
                &inputs,
                |_mb, x| x.iter().map(|v| v * 2.0).collect(),
                |_mb, g| g.iter().map(|v| v + 1.0).collect(),
            )
        });
        assert_eq!(outs[0].outputs, vec![vec![2.0, 4.0], vec![6.0, 8.0]]);
        assert_eq!(outs[0].input_grads, vec![vec![3.0, 5.0], vec![7.0, 9.0]]);
    }

    /// Stages on non-contiguous global ranks (a folded layout): the stage
    /// index comes from the group position, not the rank id.
    #[test]
    fn execute_1f1b_non_contiguous_stage_group() {
        let inputs = vec![vec![2.0f32; 3]; 4];
        let outs = run_ranks(3, |rank, comm| {
            let group = [0usize, 2]; // rank 1 sits out
            if group.contains(&rank) {
                Some(execute_1f1b(
                    &comm,
                    &group,
                    4,
                    &inputs,
                    |_mb, x| x.iter().map(|v| v + 10.0).collect(),
                    |_mb, g| g.to_vec(),
                ))
            } else {
                None
            }
        });
        let last = outs[2].as_ref().unwrap();
        assert_eq!(last.outputs, vec![vec![22.0f32; 3]; 4]);
        let first = outs[0].as_ref().unwrap();
        assert_eq!(first.input_grads, vec![vec![22.0f32; 3]; 4]);
    }
}
