//! The five parallelism strategies compared in Table 1, and the candidate
//! configuration space each one may legally search (used by `autotune`).

use crate::config::{ModelConfig, ParallelConfig, ZeroStage};
use crate::mapping::ParallelMapping;

/// The strategies of the paper's evaluation (§4.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// PyTorch-FSDP-style ZeRO-3 data parallelism (+ optional TP).
    Fsdp,
    /// FSDP with expert parallelism for the MoE weights.
    FsdpEp,
    /// Tensor + expert + data parallelism with ZeRO-1 (Singh et al.).
    TpEpDp,
    /// Megatron-Core 5-D parallelism, coupled mappings (ETP = TP, EP ⊂ DP).
    MCore,
    /// Megatron-Core with MoE Parallel Folding (this paper).
    MCoreFolding,
}

impl Strategy {
    pub const ALL: [Strategy; 5] = [
        Strategy::Fsdp,
        Strategy::FsdpEp,
        Strategy::TpEpDp,
        Strategy::MCore,
        Strategy::MCoreFolding,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Fsdp => "FSDP",
            Strategy::FsdpEp => "FSDP + EP",
            Strategy::TpEpDp => "TP+EP+DP",
            Strategy::MCore => "MCore",
            Strategy::MCoreFolding => "MCore w/ Folding",
        }
    }

    /// ZeRO stage the strategy runs on the DP/EDP axes.
    pub fn zero_stage(&self) -> ZeroStage {
        match self {
            Strategy::Fsdp | Strategy::FsdpEp => ZeroStage::Zero3,
            _ => ZeroStage::Zero1,
        }
    }

    /// Whether MoE mapping is decoupled from attention (folding).
    pub fn folded(&self) -> bool {
        matches!(self, Strategy::MCoreFolding)
    }

    /// Is `cfg` a legal configuration for this strategy?
    pub fn admits(&self, cfg: &ParallelConfig, model: &ModelConfig) -> bool {
        if cfg.validate(model.num_experts, model.num_layers).is_err() {
            return false;
        }
        match self {
            // FSDP: pure ZeRO-3 (+TP to fit); no EP, no PP, no CP.
            Strategy::Fsdp => {
                cfg.ep == 1 && cfg.etp == cfg.tp && cfg.pp == 1 && cfg.cp == 1
            }
            // FSDP+EP: adds expert parallelism; still no PP.
            Strategy::FsdpEp => {
                cfg.etp == cfg.tp && cfg.pp == 1 && cfg.cp == 1 && cfg.dp() % cfg.ep == 0
            }
            // TP+EP+DP: no PP/CP; EP within DP; ETP coupled.
            Strategy::TpEpDp => {
                cfg.etp == cfg.tp && cfg.pp == 1 && cfg.cp == 1 && cfg.dp() % cfg.ep == 0
            }
            // MCore: full 5-D but coupled: ETP = TP and EP ⊂ DP.
            Strategy::MCore => cfg.etp == cfg.tp && cfg.dp() % cfg.ep == 0,
            // Folding: any PP-consistent combination.
            Strategy::MCoreFolding => true,
        }
    }

    /// Build the rank mapping this strategy uses for `cfg`.
    pub fn mapping(&self, cfg: ParallelConfig) -> Result<ParallelMapping, String> {
        if self.folded() {
            ParallelMapping::folded(cfg)
        } else {
            // Coupled strategies use the legacy placement (EP strides over
            // the fused DP×CP axis with step = tp).
            ParallelMapping::legacy(cfg)
        }
    }

    /// Candidate configurations for `model` on `gpus` GPUs (power-of-two
    /// sweep, filtered by `admits`).
    pub fn candidates(&self, model: &ModelConfig, gpus: usize) -> Vec<ParallelConfig> {
        let mut out = Vec::new();
        let pow2 = |max: usize| -> Vec<usize> {
            let mut v = vec![1usize];
            while *v.last().unwrap() < max {
                v.push(v.last().unwrap() * 2);
            }
            v
        };
        let tps = pow2(8);
        let cps = pow2(16);
        let pps = pow2(16);
        let eps: Vec<usize> = pow2(model.num_experts.max(1))
            .into_iter()
            .filter(|e| *e <= model.num_experts.max(1))
            .collect();
        let etps = pow2(8);
        for &tp in &tps {
            for &cp in &cps {
                for &pp in &pps {
                    if tp * cp * pp > gpus {
                        continue;
                    }
                    if model.num_layers % pp != 0 {
                        continue;
                    }
                    for &ep in &eps {
                        for &etp in &etps {
                            if etp * ep * pp > gpus {
                                continue;
                            }
                            let cfg = ParallelConfig::new(gpus, tp, cp, ep, etp, pp);
                            if self.admits(&cfg, model) && self.mapping(cfg).is_ok() {
                                out.push(cfg);
                                // Interleaved-1F1B variants: every vpp > 1
                                // dividing the layers-per-stage count (so
                                // pp·vpp tiles num_layers; e.g. 56 layers
                                // at pp=8 admits exactly vpp=7), capped at
                                // one-layer chunks / vpp ≤ 8. Microbatch
                                // divisibility is train-config dependent;
                                // the estimator rejects infeasible points
                                // at tune time.
                                if pp > 1 {
                                    let lps = model.num_layers / pp;
                                    for vpp in 2..=lps.min(8) {
                                        if lps % vpp == 0 {
                                            out.push(cfg.with_vpp(vpp));
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out.sort_by_key(|c| (c.tp, c.cp, c.pp, c.ep, c.etp, c.vpp));
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_names() {
        assert_eq!(Strategy::MCoreFolding.name(), "MCore w/ Folding");
        assert_eq!(Strategy::ALL.len(), 5);
    }

    #[test]
    fn fsdp_admits_only_dp_tp() {
        let m = ModelConfig::mixtral_8x22b();
        let ok = ParallelConfig::new(128, 8, 1, 1, 8, 1);
        let bad = ParallelConfig::new(128, 2, 1, 8, 2, 1);
        assert!(Strategy::Fsdp.admits(&ok, &m));
        assert!(!Strategy::Fsdp.admits(&bad, &m));
    }

    #[test]
    fn folding_admits_decoupled() {
        let m = ModelConfig::mixtral_8x22b();
        let cfg = ParallelConfig::new(128, 2, 1, 8, 1, 8); // etp != tp
        assert!(Strategy::MCoreFolding.admits(&cfg, &m));
        assert!(!Strategy::MCore.admits(&cfg, &m));
    }

    #[test]
    fn candidate_spaces_nonempty_and_strictly_larger_with_folding() {
        let m = ModelConfig::mixtral_8x22b();
        let mcore = Strategy::MCore.candidates(&m, 128);
        let folded = Strategy::MCoreFolding.candidates(&m, 128);
        assert!(!mcore.is_empty());
        assert!(
            folded.len() > mcore.len(),
            "folding should expand the space: {} vs {}",
            folded.len(),
            mcore.len()
        );
        // every candidate validates
        for c in folded.iter().chain(mcore.iter()) {
            assert!(c.validate(m.num_experts, m.num_layers).is_ok(), "{c:?}");
        }
    }

    #[test]
    fn ep_bounded_by_num_experts() {
        let m = ModelConfig::mixtral_8x22b(); // 8 experts
        for c in Strategy::MCoreFolding.candidates(&m, 256) {
            assert!(c.ep <= 8);
        }
    }
}
