//! GEMM efficiency curve: fraction of peak achieved as a function of the
//! problem shape.
//!
//! The paper's fine-grained-MoE findings (§4.2: "smaller hidden sizes
//! decrease GEMM efficiency") enter the model here: expert GEMMs with small
//! N (= expert FFN width / ETP) or small M (= tokens per expert) run far
//! below peak on tensor cores. The curve is a saturating product form
//! `eff_max · m/(m+m₀) · n/(n+n₀) · k/(k+k₀)` — the standard roofline-ish
//! approximation used by analytic LLM cost models, calibrated so that large
//! dense GEMMs reach ~85% of peak and 2048-wide expert GEMMs land near 50%.

use crate::config::Precision;

/// Efficiency model constants.
#[derive(Debug, Clone, Copy)]
pub struct EffKnobs {
    pub eff_max: f64,
    pub m_half: f64,
    pub n_half: f64,
    pub k_half: f64,
    /// Flash-attention core efficiency relative to BF16 peak.
    pub attn_core_eff: f64,
    /// Extra time multiplier for FP8 GEMMs (cast + amax bookkeeping).
    pub fp8_overhead: f64,
    /// FP8 efficiency derate: FP8 tensor cores are harder to saturate.
    pub fp8_derate: f64,
    /// HBM passes over the layer activations (at bf16 width) charged only
    /// under FP8, for the Transformer-Engine-style cast/transpose/amax
    /// bookkeeping that surrounds every fp8 GEMM: quantize inputs, keep a
    /// transposed copy for the backward, track amax history. Calibrated so
    /// the Table-2 Mixtral 8x22B @128-GPU step speedup lands inside the
    /// paper's 1.26–1.30× window (the pure-GEMM fp8 speedup stays ~1.36,
    /// pinned separately by `fp8_faster_despite_derate`).
    pub fp8_cast_passes: f64,
    /// Fixed per-layer per-microbatch overhead (kernel launches, small ops),
    /// microseconds. Penalizes very small shards (large CP/TP at short seq).
    pub fixed_layer_us: f64,
    /// Memory passes over activations per layer for norms/residual/
    /// activation functions (elementwise, HBM-bound).
    pub elementwise_passes: f64,
}

impl Default for EffKnobs {
    fn default() -> Self {
        Self {
            eff_max: 0.92,
            m_half: 96.0,
            n_half: 640.0,
            k_half: 384.0,
            attn_core_eff: 0.52,
            fp8_overhead: 0.15,
            fp8_derate: 0.78,
            fp8_cast_passes: 8.0,
            fixed_layer_us: 14.0,
            elementwise_passes: 14.0,
        }
    }
}

/// GEMM efficiency (fraction of the precision's peak) for an `m×k · k×n`
/// problem.
pub fn gemm_eff(knobs: &EffKnobs, m: f64, n: f64, k: f64, precision: Precision) -> f64 {
    let base = knobs.eff_max
        * (m / (m + knobs.m_half))
        * (n / (n + knobs.n_half))
        * (k / (k + knobs.k_half));
    match precision {
        Precision::Bf16 => base,
        Precision::Fp8 => base * knobs.fp8_derate,
    }
}

/// Time (µs) for `flops` of GEMM work with shape `(m, n, k)` on a GPU with
/// `peak_tflops` at `precision`.
pub fn gemm_time_us(
    knobs: &EffKnobs,
    flops: f64,
    m: f64,
    n: f64,
    k: f64,
    peak_tflops: f64,
    precision: Precision,
) -> f64 {
    let eff = gemm_eff(knobs, m, n, k, precision).max(1e-3);
    let t = flops / (peak_tflops * 1e12 * eff) * 1e6;
    match precision {
        Precision::Bf16 => t,
        Precision::Fp8 => t * (1.0 + knobs.fp8_overhead),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn big_gemm_near_eff_max() {
        let k = EffKnobs::default();
        let e = gemm_eff(&k, 4096.0, 16384.0, 6144.0, Precision::Bf16);
        assert!(e > 0.80, "{e}");
    }

    #[test]
    fn small_n_hurts() {
        let k = EffKnobs::default();
        let wide = gemm_eff(&k, 1024.0, 16384.0, 6144.0, Precision::Bf16);
        let narrow = gemm_eff(&k, 1024.0, 2048.0, 6144.0, Precision::Bf16);
        assert!(narrow < 0.85 * wide, "narrow {narrow} wide {wide}");
    }

    #[test]
    fn small_m_hurts() {
        let k = EffKnobs::default();
        let big = gemm_eff(&k, 4096.0, 4096.0, 4096.0, Precision::Bf16);
        let tiny = gemm_eff(&k, 32.0, 4096.0, 4096.0, Precision::Bf16);
        assert!(tiny < 0.4 * big);
    }

    #[test]
    fn fp8_faster_despite_derate() {
        let k = EffKnobs::default();
        let flops = 1e12;
        let bf = gemm_time_us(&k, flops, 4096.0, 8192.0, 8192.0, 989.5, Precision::Bf16);
        let f8 = gemm_time_us(&k, flops, 4096.0, 8192.0, 8192.0, 1979.0, Precision::Fp8);
        let speedup = bf / f8;
        assert!(speedup > 1.3 && speedup < 2.0, "speedup {speedup}");
    }

    #[test]
    fn monotone_in_all_dims() {
        let k = EffKnobs::default();
        let mut last = 0.0;
        for m in [32.0, 128.0, 512.0, 4096.0] {
            let e = gemm_eff(&k, m, 4096.0, 4096.0, Precision::Bf16);
            assert!(e > last);
            last = e;
        }
    }
}
