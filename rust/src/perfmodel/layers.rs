//! Per-layer timing: attention and MoE layer costs for one microbatch on
//! one rank, including the communication placed by the parallel mapping.
//!
//! All times in microseconds, forward pass; backward is derived in
//! `perfmodel::estimate` (2× GEMM compute, mirrored collectives).

use crate::cluster::ClusterSpec;
use crate::collectives::CommModel;
use crate::config::{DropPolicy, ModelConfig, ParallelConfig, Precision, TrainConfig};
use crate::mapping::ParallelMapping;

use super::efficiency::{gemm_time_us, EffKnobs};

/// Forward-pass time breakdown of one attention block (one layer, one
/// microbatch, one rank).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AttnLayerTime {
    pub gemm_us: f64,
    pub core_us: f64,
    /// Exposed TP (sequence-parallel) collective time.
    pub tp_comm_us: f64,
    /// Exposed CP (ring KV-exchange) time after overlap with the core —
    /// the closed form [`cp_exposed_us`] of the executed zig-zag ring.
    pub cp_comm_us: f64,
    /// Raw CP ring KV volume time before overlap (all `cp − 1` steps), µs.
    /// Not part of [`Self::total`]; the executed estimator re-runs the ring
    /// structurally from it.
    pub cp_ring_us: f64,
    /// Norms, residuals, rotary embedding, kernel-launch overhead.
    pub other_us: f64,
}

impl AttnLayerTime {
    pub fn total(&self) -> f64 {
        self.gemm_us + self.core_us + self.tp_comm_us + self.cp_comm_us + self.other_us
    }
}

/// Forward-pass time breakdown of one MoE block (layer, microbatch, rank).
/// Mirrors the paper's Figure 5/6 latency breakdown categories.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MoeLayerTime {
    /// Router gating + aux loss (+ full-sequence logit gather if enabled).
    pub router_us: f64,
    /// Token permute/unpermute (memory-bound reshuffles).
    pub permute_us: f64,
    /// All-to-All(-V) dispatch + combine over the EP group.
    pub a2a_us: f64,
    /// AllGather-V + ReduceScatter-V over the ETP group.
    pub etp_comm_us: f64,
    /// Expert FFN GEMMs (+ shared expert).
    pub expert_gemm_us: f64,
}

impl MoeLayerTime {
    pub fn total(&self) -> f64 {
        self.router_us + self.permute_us + self.a2a_us + self.etp_comm_us + self.expert_gemm_us
    }

    pub fn comm(&self) -> f64 {
        self.a2a_us + self.etp_comm_us
    }
}

/// Everything needed to cost layers under one mapping.
pub struct LayerCoster<'a> {
    pub model: &'a ModelConfig,
    pub parallel: &'a ParallelConfig,
    pub train: &'a TrainConfig,
    pub mapping: &'a ParallelMapping,
    pub comm: &'a CommModel,
    pub eff: EffKnobs,
}

impl<'a> LayerCoster<'a> {
    pub fn cluster(&self) -> &ClusterSpec {
        &self.comm.cluster
    }

    fn peak(&self) -> f64 {
        self.cluster().gpu.peak_tflops(self.train.precision)
    }

    fn bf16_peak(&self) -> f64 {
        self.cluster().gpu.peak_bf16_tflops
    }

    /// Local tokens per microbatch after the attention-side split (sequence
    /// parallelism over TP plus CP sequence split).
    pub fn tokens_local(&self) -> f64 {
        self.train.micro_batch_size as f64 * self.train.seq_len as f64
            / (self.parallel.tp as f64 * self.parallel.cp as f64)
    }

    /// Effective per-token expert multiplicity: top-k scaled by capacity
    /// factor (drop) or the dropless imbalance allowance.
    pub fn dispatch_multiplier(&self) -> f64 {
        let k = self.model.top_k as f64;
        match self.train.drop_policy {
            DropPolicy::Dropless => k, // mean volume; imbalance handled in a2a_v
            _ => k * self.train.capacity_factor,
        }
    }

    fn dropless_imbalance(&self) -> f64 {
        match self.train.drop_policy {
            DropPolicy::Dropless => 1.30,
            _ => 1.0,
        }
    }

    /// Representative rank-0 group on an axis of the attention grid.
    fn attn_group(&self, axis: &str) -> &[usize] {
        self.mapping.attention.group_of(axis, 0).expect("axis")
    }

    fn moe_group(&self, axis: &str) -> &[usize] {
        self.mapping.moe.group_of(axis, 0).expect("axis")
    }

    /// Cost of one attention block's forward.
    pub fn attention_layer(&self) -> AttnLayerTime {
        let m = self.model;
        let t = self.train;
        let h = m.hidden_size as f64;
        let kv_dim = (m.num_query_groups * m.head_dim()) as f64;
        let tokens = self.tokens_local();
        let tp = self.parallel.tp as f64;
        let cp = self.parallel.cp as f64;
        let bytes = bytes_per_el(t.precision);

        // QKV + O projection GEMMs. Sequence parallelism all-gathers the
        // TP-split sequence before the block, so each rank runs GEMMs with
        // M = tokens_mb / cp rows and 1/tp of the output columns:
        // per-rank flops = tokens_local * full-layer per-token flops.
        let gemm_flops = tokens * 2.0 * h * (h + 2.0 * kv_dim + h);
        let mut gemm_us = gemm_time_us(
            &self.eff,
            gemm_flops,
            tokens * tp,                    // M: CP-local sequence rows
            (2.0 * h + 2.0 * kv_dim) / tp,  // N: TP-split columns
            h,
            self.peak(),
            t.precision,
        );
        // FP8 cast/transpose/amax traffic around the block's GEMMs: extra
        // HBM passes over the bf16-width activations (Transformer-Engine
        // keeps the master activations in bf16 and quantizes per GEMM).
        if t.precision == Precision::Fp8 {
            gemm_us += self.eff.fp8_cast_passes * tokens * h * 2.0
                / (self.comm.cluster.gpu.hbm_bw_gbs * 1e9)
                * 1e6;
        }

        // Attention core (flash): quadratic term, causal, split over heads
        // (TP) and sequence (CP).
        let s = t.seq_len as f64;
        let core_flops =
            t.micro_batch_size as f64 * s * 2.0 * 2.0 * h * (s / 2.0) / (tp * cp);
        // Flash-attention efficiency degrades with the KV chunk each ring
        // step sees (s/cp): small chunks can't keep the tensor cores busy.
        let chunk = s / cp;
        let core_eff = self.eff.attn_core_eff * chunk / (chunk + 1024.0);
        let core_us = core_flops / (self.bf16_peak() * 1e12 * core_eff) * 1e6;

        // TP sequence-parallel collectives: AllGather activations before the
        // block + ReduceScatter after (one pair per block).
        let tp_group = self.attn_group("TP");
        let act_bytes = tokens * h * bytes;
        let tp_comm_us = if self.parallel.tp > 1 {
            self.comm.all_gather(tp_group, act_bytes)
                + self.comm.reduce_scatter(tp_group, act_bytes * tp)
        } else {
            0.0
        };

        // CP ring KV exchange, overlapped with the attention core. The
        // exposed share is the closed form of the executed zig-zag ring
        // (`cp_exposed_us`), which the executed estimator *measures* — the
        // old `(ring − 0.85·core).max(0.05·ring)` guess is gone (see the
        // function docs for why it was wrong in both directions).
        let (cp_ring_us, cp_comm_us) = if self.parallel.cp > 1 {
            let cp_group = self.attn_group("CP");
            let kv_bytes = 2.0 * tokens * kv_dim * bytes * (cp - 1.0);
            let ring_us = kv_bytes / (self.comm.cluster.group_bottleneck_bw(cp_group) * 1e9 * 0.8)
                * 1e6
                + (cp - 1.0) * self.comm.cluster.group_latency_us(cp_group);
            (ring_us, cp_exposed_us(ring_us, core_us, cp))
        } else {
            (0.0, 0.0)
        };

        // Elementwise work (norms, residual, rotary) + launch overhead.
        let other_us = self.eff.elementwise_passes * tokens * h * bytes
            / (self.comm.cluster.gpu.hbm_bw_gbs * 1e9)
            * 1e6
            + self.eff.fixed_layer_us;

        AttnLayerTime { gemm_us, core_us, tp_comm_us, cp_comm_us, cp_ring_us, other_us }
    }

    /// Cost of one MoE block's forward. This is the Figure-5/6 breakdown.
    pub fn moe_layer(&self) -> MoeLayerTime {
        let m = self.model;
        let t = self.train;
        let h = m.hidden_size as f64;
        let tokens = self.tokens_local();
        let bytes = bytes_per_el(t.precision);
        let disp = self.dispatch_multiplier(); // tokens*disp routed copies
        let routed = tokens * disp;
        let etp = self.parallel.etp as f64;
        let ep_group = self.moe_group("EP");
        let etp_group = self.moe_group("ETP");

        // Router: gating GEMM + softmax/topk, memory-bound-ish; plus the
        // full-sequence logit gather when that drop mode is selected.
        let router_flops = tokens * 2.0 * h * m.num_experts as f64;
        let mut router_us = router_flops / (self.bf16_peak() * 1e12 * 0.2) * 1e6
            + self.eff.fixed_layer_us;
        if t.drop_policy == DropPolicy::FullSequence {
            // Gather logits over the TP×CP sub-sequence ranks.
            let seq_group_len = self.parallel.tp * self.parallel.cp;
            if seq_group_len > 1 {
                let grp: Vec<usize> = (0..seq_group_len).collect();
                router_us += self.comm.all_gather(&grp, tokens * m.num_experts as f64 * bytes);
            }
        }

        // Permute + unpermute: 2 gather passes over routed activations.
        let permute_bytes = 2.0 * routed * h * bytes * 2.0; // read+write
        let mut permute_us = permute_bytes / (self.comm.cluster.gpu.hbm_bw_gbs * 1e9) * 1e6 + 2.0;
        // FP8 cast/transpose/amax traffic around the expert GEMMs, charged
        // on the routed copies at bf16 width (see `attention_layer`).
        if t.precision == Precision::Fp8 {
            permute_us += self.eff.fp8_cast_passes * routed * h * 2.0
                / (self.comm.cluster.gpu.hbm_bw_gbs * 1e9)
                * 1e6;
        }

        // All-to-All-V dispatch + combine across the EP group.
        let a2a_bytes = routed * h * bytes;
        let a2a_us = if ep_group.len() > 1 {
            2.0 * self.comm.all_to_all_v(ep_group, a2a_bytes, self.dropless_imbalance())
        } else {
            0.0
        };

        // ETP AllGather-V before expert GEMMs + ReduceScatter-V after.
        let etp_comm_us = if etp_group.len() > 1 {
            self.comm.all_gather(etp_group, a2a_bytes)
                + self.comm.reduce_scatter(etp_group, a2a_bytes * etp)
        } else {
            0.0
        };

        // Expert FFN GEMMs. Per rank: `routed × etp` tokens (post-AG) through
        // FFN width `moe_ffn / etp`; grouped by local experts so the GEMM M
        // is tokens-per-expert.
        let local_experts = (m.num_experts / self.parallel.ep).max(1) as f64;
        let tokens_per_expert = routed * etp * self.parallel.ep as f64 / m.num_experts as f64;
        let ffn_local = m.moe_ffn_hidden_size as f64 / etp;
        let expert_flops = routed * etp * 3.0 * 2.0 * h * ffn_local;
        let mut expert_gemm_us = gemm_time_us(
            &self.eff,
            expert_flops,
            tokens_per_expert,
            ffn_local,
            h,
            self.peak(),
            t.precision,
        );
        // Grouped-GEMM launch overhead per local expert.
        expert_gemm_us += local_experts * 1.5;

        // Shared expert (dense path), computed on the attention shard.
        if m.shared_expert_ffn_hidden_size > 0 {
            let sh = m.shared_expert_ffn_hidden_size as f64 / self.parallel.tp as f64;
            let flops = tokens * 3.0 * 2.0 * h * sh * self.parallel.tp as f64;
            expert_gemm_us +=
                gemm_time_us(&self.eff, flops, tokens, sh, h, self.peak(), t.precision);
        }

        MoeLayerTime { router_us, permute_us, a2a_us, etp_comm_us, expert_gemm_us }
    }
}

/// Exposed CP ring time: the closed form of the **executed** zig-zag ring
/// attention ([`crate::attention::DistributedAttentionLayer`]). The ring
/// runs `cp − 1` KV transfer steps; step `s`'s transfer hides under the
/// attention-core compute of block `s` (one of `cp` equal chunks of
/// `core_us`), and the **final** chunk has no transfer behind it — so the
/// overlap window is `(cp−1)/cp · core_us`, never the whole core.
///
/// This replaced the hand-tuned `(ring − 0.85·core).max(0.05·ring)` guess
/// (ISSUE 5 satellite bugfix), which nothing validated and which was wrong
/// in both directions: the `0.85·core` credit over-counted the window (the
/// last chunk cannot hide a transfer that does not exist — the honest
/// window fraction is `(cp−1)/cp ≤ 0.75` for `cp ≤ 4`), and the
/// `0.05·ring` floor kept charging exposed time even when the core fully
/// covers the ring. The executed estimator measures the same structure on
/// the clock; `tests/cp_equivalence.rs` pins analytic-vs-executed
/// agreement within 2% on the fig6 sweep so the formula cannot silently
/// drift again.
pub fn cp_exposed_us(ring_us: f64, core_window_us: f64, cp: f64) -> f64 {
    if cp <= 1.0 {
        return 0.0;
    }
    (ring_us - core_window_us * (cp - 1.0) / cp).max(0.0)
}

pub fn bytes_per_el(p: Precision) -> f64 {
    match p {
        Precision::Bf16 => 2.0,
        Precision::Fp8 => 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::ModelConfig;

    fn coster_parts(
        model: ModelConfig,
        cfg: ParallelConfig,
        gpus: usize,
    ) -> (ModelConfig, ParallelConfig, TrainConfig, ParallelMapping, CommModel) {
        let train = TrainConfig::paper_default(4096, 256);
        let mapping = ParallelMapping::folded(cfg).unwrap();
        let comm = CommModel::new(ClusterSpec::eos(gpus));
        (model, cfg, train, mapping, comm)
    }

    #[test]
    fn moe_layer_ep_vs_etp_comm() {
        // Figure 5 key finding: ETP introduces far more comm than EP at the
        // same model-parallel product.
        let model = ModelConfig::mixtral_8x22b();
        let (m1, c1, t1, map1, comm1) =
            coster_parts(model.clone(), ParallelConfig::new(64, 4, 1, 8, 1, 1), 64);
        let ep8 = LayerCoster {
            model: &m1,
            parallel: &c1,
            train: &t1,
            mapping: &map1,
            comm: &comm1,
            eff: EffKnobs::default(),
        }
        .moe_layer();

        let (m2, c2, t2, map2, comm2) =
            coster_parts(model, ParallelConfig::new(64, 4, 1, 1, 8, 1), 64);
        let etp8 = LayerCoster {
            model: &m2,
            parallel: &c2,
            train: &t2,
            mapping: &map2,
            comm: &comm2,
            eff: EffKnobs::default(),
        }
        .moe_layer();

        assert!(
            etp8.comm() > 1.5 * ep8.comm(),
            "ETP comm {:.0}us should exceed EP comm {:.0}us",
            etp8.comm(),
            ep8.comm()
        );
    }

    #[test]
    fn fine_grained_more_comm_dominated() {
        let coarse = ModelConfig::mixtral_8x22b();
        let fine = ModelConfig::mixtral_8x22b_g8t8();
        let cfg = ParallelConfig::new(128, 4, 1, 8, 1, 1);
        let (m_c, c_c, t_c, map_c, comm_c) = coster_parts(coarse, cfg, 128);
        let coarse_frac = LayerCoster {
            model: &m_c, parallel: &c_c, train: &t_c, mapping: &map_c, comm: &comm_c,
            eff: EffKnobs::default(),
        }
        .moe_layer();
        let coarse_frac = coarse_frac.comm() / coarse_frac.total();
        for (model, expect_comm_frac) in [(fine, (coarse_frac * 1.5).min(0.3))] {
            let (m, c, t, map, comm) = coster_parts(model, cfg, 128);
            let lt = LayerCoster {
                model: &m,
                parallel: &c,
                train: &t,
                mapping: &map,
                comm: &comm,
                eff: EffKnobs::default(),
            }
            .moe_layer();
            let frac = lt.comm() / lt.total();
            assert!(
                frac >= expect_comm_frac,
                "{}: comm frac {frac:.2} (expected >= {expect_comm_frac})",
                m.name
            );
        }
    }

    #[test]
    fn attention_tp_comm_nonzero() {
        let model = ModelConfig::mixtral_8x22b();
        let (m, c, t, map, comm) =
            coster_parts(model, ParallelConfig::new(64, 4, 1, 8, 1, 1), 64);
        let at = LayerCoster {
            model: &m,
            parallel: &c,
            train: &t,
            mapping: &map,
            comm: &comm,
            eff: EffKnobs::default(),
        }
        .attention_layer();
        assert!(at.tp_comm_us > 0.0);
        assert!(at.gemm_us > 0.0 && at.core_us > 0.0);
        assert_eq!(at.cp_comm_us, 0.0);
    }

    /// Regression pin for the recalibrated CP overlap credit: the exposed
    /// time is exactly the executed ring's closed form — window =
    /// `(cp−1)/cp` of the core (the final chunk hides nothing), no floor —
    /// and a comm-bound ring stays positive while a compute-bound one is
    /// fully hidden. The old `0.85·core` / `0.05·ring` constants must not
    /// creep back.
    #[test]
    fn cp_exposed_matches_executed_ring_closed_form() {
        // Compute-bound: ring fits under the honest window → zero exposed
        // (the old formula would still charge its 5% floor here).
        assert_eq!(cp_exposed_us(100.0, 400.0, 4.0), 0.0);
        // Comm-bound: exposed = ring − (cp−1)/cp·core exactly (the old
        // 0.85·core credit would claim 640 − 340 = 300 instead).
        let e = cp_exposed_us(640.0, 400.0, 2.0);
        assert!((e - (640.0 - 200.0)).abs() < 1e-9, "{e}");
        // cp = 1 has no ring.
        assert_eq!(cp_exposed_us(640.0, 400.0, 1.0), 0.0);
        // The layer coster wires the formula in: a cp > 1 attention layer's
        // exposed time equals the closed form of its own ring/core parts.
        let model = ModelConfig::mixtral_8x22b();
        let (m, c, t, map, comm) =
            coster_parts(model, ParallelConfig::new(64, 2, 4, 8, 1, 1), 64);
        let at = LayerCoster {
            model: &m, parallel: &c, train: &t, mapping: &map, comm: &comm,
            eff: EffKnobs::default(),
        }
        .attention_layer();
        assert!(at.cp_ring_us > 0.0);
        let want = cp_exposed_us(at.cp_ring_us, at.core_us, 4.0);
        assert_eq!(at.cp_comm_us, want);
    }

    #[test]
    fn full_sequence_drop_costs_more_router() {
        let model = ModelConfig::qwen2_57b_a14b();
        let cfg = ParallelConfig::new(64, 4, 2, 8, 1, 1);
        let (m, c, mut t, map, comm) = coster_parts(model, cfg, 64);
        let sub = LayerCoster {
            model: &m, parallel: &c, train: &t, mapping: &map, comm: &comm,
            eff: EffKnobs::default(),
        }
        .moe_layer();
        t.drop_policy = DropPolicy::FullSequence;
        let full = LayerCoster {
            model: &m, parallel: &c, train: &t, mapping: &map, comm: &comm,
            eff: EffKnobs::default(),
        }
        .moe_layer();
        assert!(full.router_us > sub.router_us);
    }
}
