//! **Measured-in-sim** step time: run the step's actual schedule over the
//! clocked functional simulator at full world size, instead of closing it
//! with an analytic formula.
//!
//! [`execute_step`] shares its per-phase inputs ([`super::StepComponents`])
//! with the analytic [`super::PerfModel::estimate`]: per-stage fwd/bwd
//! charges, stage-boundary p2p volumes, and the gradient-sync collective
//! list. The difference is *structural* — here `world_size` rank threads
//! really execute the (interleaved-)1F1B schedule over [`crate::simcomm`]
//! (real sends, real recvs, real blocking), grad-sync collectives run over
//! each rank's mapped DP/EDP groups from the runtime topology, and the
//! step time is read off the virtual clock. Warmup/steady/cooldown
//! interleaving, cross-stage waits and bubbles *emerge* from the executed
//! schedule; nothing is assumed about them.
//!
//! # Overlap is measured, not credited
//!
//! When `TrainConfig::overlap_grad_reduce` is on, the overlappable
//! bucketed share of each DP/EDP grad collective
//! (`PerfModel::dp_overlap_frac` of its bytes, capped by the half-backward
//! window the analytic model assumes) is issued **nonblocking** on the
//! background grad-sync lane once half the pipeline compute has run —
//! buckets drain one per schedule op, the NCCL-style dedicated stream —
//! and waited after the pipeline. The clock *measures* what the backward
//! window actually hid ([`ExecutedEstimate::hidden_comm_us`] /
//! [`ExecutedEstimate::exposed_comm_us`]); nothing subtracts the analytic
//! `hidden_us` credit anymore. Likewise `TrainConfig::overlap_a2a` issues
//! the per-op hideable a2a share on the comm lane under the expert-GEMM
//! window. With both knobs off every collective runs blocking and fully
//! exposed — the serialized twin the differential suite compares against.
//!
//! The differential suite (`tests/clocked_timing.rs`,
//! `tests/schedule_equivalence.rs`) pins analytic vs executed agreement on
//! the paper's Table-3 folded optima with and without overlap; the
//! `timeline` CLI subcommand dumps [`execute_step_traced`]'s chrome trace
//! (main + comm + grad-sync lanes) for any mapping.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;

use crate::config::{ModelConfig, ParallelConfig, TrainConfig};
use crate::mapping::RuntimeTopology;
use crate::model::flops::ModelFlops;
use crate::pipeline::{
    chunk_tag, execute_interleaved_with, measured_bubble_fraction, schedule_interleaved, PipeOp,
};
use crate::simcomm::engine::{self, EngineOp, RankProgram, WaitAcc};
use crate::simcomm::{run_ranks_on, AlgoSelection, CommHandle, Communicator, Fabric, TraceEvent};

use super::{GradScope, PerfModel, StepComponents, Strategy};

/// Which execution engine runs the clocked step schedule. Both engines
/// bill the same [`crate::simcomm`] virtual clock and are bit-identical
/// on every output (differentially pinned in
/// `tests/engine_equivalence.rs`); they differ only in how rank programs
/// are driven.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecEngine {
    /// One OS thread per rank over the message fabric
    /// ([`run_ranks_on`]). The reference engine — also the only one that
    /// can run payload-real programs — but O(world) threads make
    /// 1024-rank steps painful.
    Threads,
    /// Single-threaded discrete-event interpreter
    /// ([`crate::simcomm::engine`]): rank programs compile to payload-free
    /// op lists, ranks park at rendezvous/receive points and resume on
    /// completion. No threads, no per-event allocation — 1024+-rank steps
    /// run in tier-1 CI.
    #[default]
    Events,
}

/// Result of executing one step on the clocked simulator.
#[derive(Debug, Clone)]
pub struct ExecutedEstimate {
    pub config: ParallelConfig,
    /// Measured-in-sim step time (pipeline + measured exposed grad sync +
    /// optimizer), ms. Overlap is measured on the clock's comm lanes, not
    /// granted as a credit.
    pub step_ms: f64,
    /// Measured pipeline makespan (max rank finish of the schedule), ms.
    pub pipeline_ms: f64,
    /// Bubble fraction measured from the executed per-rank timelines:
    /// `1 − busy / (ranks × makespan)`.
    pub bubble_fraction: f64,
    /// Communication genuinely hidden under compute (mean per rank), µs:
    /// comm-lane span time whose `wait` exposed nothing.
    pub hidden_comm_us: f64,
    /// Communication the main lane had to wait for (mean per rank), µs.
    pub exposed_comm_us: f64,
    /// CP ring KV transfer time hidden under the attention-core chunks
    /// (mean per rank, whole step), µs — measured per ring step on the
    /// comm lane; 0 without CP.
    pub cp_hidden_us: f64,
    /// CP ring time the core chunks failed to hide (mean per rank), µs.
    /// The analytic estimate's `layers::cp_exposed_us` closed form must
    /// agree with this within 2% (`tests/cp_equivalence.rs`).
    pub cp_exposed_us: f64,
    /// Achieved model TFLOPS per GPU at the measured step time.
    pub tflops_per_gpu: f64,
    /// Measured-in-sim MFU.
    pub mfu: f64,
    pub oom: bool,
}

impl ExecutedEstimate {
    /// Pretty single-line summary (mirrors `StepEstimate::summary`).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{:<28} sim-step {:8.1} ms   {:6.1} TFLOPS/GPU   MFU {:5.1}%   bubble {:4.1}%   hidden-comm {:4.1}%",
            self.config.tag(),
            self.step_ms,
            self.tflops_per_gpu,
            self.mfu * 100.0,
            self.bubble_fraction * 100.0,
            100.0 * self.hidden_comm_us / (self.hidden_comm_us + self.exposed_comm_us).max(1e-9)
        );
        if self.cp_hidden_us + self.cp_exposed_us > 0.0 {
            s.push_str(&format!(
                "   cp-ring {:.0}/{:.0} µs hidden/exposed",
                self.cp_hidden_us, self.cp_exposed_us
            ));
        }
        s
    }
}

/// One grad-sync charge of the executed step: the overlappable bucket list
/// plus the exposed tail, all priced by the clock when they run.
struct GradPlan {
    label: &'static str,
    prim: crate::collectives::CommPrimitive,
    scope: GradScope,
    /// Bytes of each nonblocking bucket issued under backward.
    bucket_bytes: Vec<f64>,
    /// Bytes of the blocking tail after the pipeline (0 = fully bucketed).
    tail_bytes: f64,
}

/// Number of nonblocking buckets the overlappable share splits into.
const GRAD_BUCKETS: usize = 4;

/// Build the per-collective overlap plan: `overlap_frac` of each
/// collective's bytes is bucketed for nonblocking issue, scaled down if the
/// priced total would exceed the half-compute window `cap_us` (mirroring
/// the analytic `hidden_us` cap), the rest is the exposed tail.
fn plan_grad_overlap(
    comps: &StepComponents,
    cost: &crate::collectives::CommCost,
    cap_us: f64,
) -> Vec<GradPlan> {
    let frac = comps.grad_overlap_frac.clamp(0.0, 1.0);
    let fast = AlgoSelection::fast();
    let dp_group = comps.mapping.attention.group_of("DP", 0).unwrap();
    let edp_group = comps.mapping.moe.group_of("EDP", 0).unwrap();
    // Price the full overlappable share to derive the cap scale.
    let mut ovl_price = 0.0;
    for gc in &comps.grad_comm {
        if frac <= 0.0 {
            continue;
        }
        let group = match gc.scope {
            GradScope::Dp => dp_group,
            GradScope::Edp => edp_group,
        };
        if group.len() > 1 {
            let algo = match gc.prim {
                crate::collectives::CommPrimitive::AllGather => fast.all_gather,
                _ => fast.reduce_scatter,
            };
            ovl_price += cost.price(gc.prim, algo, group, gc.bytes * frac);
        }
    }
    let scale = if ovl_price > cap_us && ovl_price > 0.0 {
        cap_us / ovl_price
    } else {
        1.0
    };
    comps
        .grad_comm
        .iter()
        .map(|gc| {
            let ovl = gc.bytes * frac * scale;
            let bucket_bytes = if ovl > 0.0 {
                vec![ovl / GRAD_BUCKETS as f64; GRAD_BUCKETS]
            } else {
                Vec::new()
            };
            GradPlan {
                label: gc.label,
                prim: gc.prim,
                scope: gc.scope,
                bucket_bytes,
                tail_bytes: gc.bytes - ovl,
            }
        })
        .collect()
}

/// Execute one training step on the clocked simulator at full world size.
pub fn execute_step(
    pm: &PerfModel,
    model: &ModelConfig,
    cfg: ParallelConfig,
    train: &TrainConfig,
    strategy: Strategy,
) -> Result<ExecutedEstimate, String> {
    execute_step_traced(pm, model, cfg, train, strategy).map(|(e, _)| e)
}

/// Per-rank outcome of the executed schedule.
struct RankOutcome {
    pipeline_us: f64,
    finish_us: f64,
    busy_us: f64,
    hidden_us: f64,
    exposed_us: f64,
    cp_hidden_us: f64,
    cp_exposed_us: f64,
}

/// [`execute_step`] returning the full per-rank trace (serialize with
/// [`crate::simcomm::chrome_trace_json`]). Runs on the default engine
/// ([`ExecEngine::Events`]).
pub fn execute_step_traced(
    pm: &PerfModel,
    model: &ModelConfig,
    cfg: ParallelConfig,
    train: &TrainConfig,
    strategy: Strategy,
) -> Result<(ExecutedEstimate, Vec<TraceEvent>), String> {
    execute_step_traced_on(ExecEngine::default(), pm, model, cfg, train, strategy)
}

/// [`execute_step_traced`] on an explicit [`ExecEngine`].
pub fn execute_step_traced_on(
    engine: ExecEngine,
    pm: &PerfModel,
    model: &ModelConfig,
    cfg: ParallelConfig,
    train: &TrainConfig,
    strategy: Strategy,
) -> Result<(ExecutedEstimate, Vec<TraceEvent>), String> {
    let comps = pm.components(model, cfg, train, strategy)?;
    let topo = RuntimeTopology::from_mapping(comps.mapping.clone())?;
    let world = cfg.world_size;
    let cost = crate::collectives::CommCost::new(comps.cluster.clone());

    let m = comps.m_micro;
    let vpp = comps.vpp.max(1);
    let v = vpp as f64;
    // Per-chunk charges: a stage's vpp chunks split its per-microbatch
    // time evenly (layers_per_stage / vpp layers per chunk).
    let f_c = comps.f_us / v;
    let b_c = comps.b_us / v;
    let fh_c = comps.f_hidden_us / v;
    let bh_c = comps.b_hidden_us / v;
    let f_win_c = (comps.f_expert_us / v).min(f_c - fh_c).max(0.0);
    let b_win_c = (comps.b_expert_us / v).min(b_c - bh_c).max(0.0);
    // Executed CP ring: per-chunk ring-step comm, core windows, and the
    // analytic exposed share already inside f_c/b_c (the charge loop
    // re-runs the same structure and *measures* its own exposure).
    let cp_steps = comps.cp_steps;
    let cp_comm_c = comps.cp_step_comm_us / v;
    let cp_fwin_c = comps.cp_f_window_us / v;
    let cp_bwin_c = comps.cp_b_window_us / v;
    let cp_fexp_c = comps.cp_f_exposed_us / v;
    let cp_bexp_c = comps.cp_b_exposed_us / v;
    let p2p_bytes = comps.p2p_bytes;
    let optimizer_us = comps.optimizer_us;
    // Grad overlap plan: the same half-compute cap the analytic credit
    // uses, so the two estimators stay structurally comparable.
    let compute_total_us = m as f64 * (comps.f_eff_us() + comps.b_eff_us());
    let grad_plan = plan_grad_overlap(&comps, &cost, compute_total_us * 0.5);
    let total_ops = 2 * m * vpp;
    // Issue buckets once half the per-rank compute has run (grads of the
    // early buckets are complete by then), one bucket per op boundary.
    let issue_threshold_us = compute_total_us * 0.5;
    // Flattened bucket issue order: collective-major, so DP and EDP
    // buckets interleave the way Megatron's bucketed DDP drains them.
    let bucket_seq: Vec<(usize, usize)> = grad_plan
        .iter()
        .enumerate()
        .flat_map(|(ci, gp)| (0..gp.bucket_bytes.len()).map(move |bi| (ci, bi)))
        .collect();

    if engine == ExecEngine::Events {
        // Compile each rank's schedule to a payload-free op program and
        // interpret them all on the single-threaded event engine.
        let mut table = GroupTable::default();
        let programs: Vec<RankProgram> = (0..world)
            .map(|rank| {
                record_rank_program(
                    rank,
                    topo.view(rank),
                    &comps,
                    &grad_plan,
                    &bucket_seq,
                    issue_threshold_us,
                    &mut table,
                )
            })
            .collect();
        let (stats, trace) =
            engine::run_programs(cost, AlgoSelection::fast(), &table.groups, &programs);
        let results: Vec<RankOutcome> = stats
            .into_iter()
            .map(|s| RankOutcome {
                pipeline_us: s.pipeline_us,
                finish_us: s.finish_us,
                busy_us: s.busy_us,
                hidden_us: s.hidden_us,
                exposed_us: s.exposed_us,
                cp_hidden_us: s.cp_hidden_us,
                cp_exposed_us: s.cp_exposed_us,
            })
            .collect();
        return Ok(aggregate_step(&comps, model, cfg, train, results, trace));
    }

    let fabric = Fabric::new_clocked(world, AlgoSelection::fast(), cost);
    let results: Vec<RankOutcome> = run_ranks_on(&fabric, |rank, comm| {
        let view = topo.view(rank);
        let hidden = Cell::new(0.0f64);
        let exposed = Cell::new(0.0f64);
        let cp_hidden = Cell::new(0.0f64);
        let cp_exposed = Cell::new(0.0f64);
        let cum_compute = Cell::new(0.0f64);
        let ops_done = Cell::new(0usize);
        let next_bucket = Cell::new(0usize);
        let pending: RefCell<Vec<CommHandle>> = RefCell::new(Vec::new());

        let issue_buckets = |comm: &Communicator, force: bool| {
            while next_bucket.get() < bucket_seq.len()
                && (force || cum_compute.get() + 1e-9 >= issue_threshold_us)
            {
                let (ci, bi) = bucket_seq[next_bucket.get()];
                let gp = &grad_plan[ci];
                let group = match gp.scope {
                    GradScope::Dp => &view.dp_group,
                    GradScope::Edp => &view.edp_group,
                };
                let h = comm.charge_collective_bg(gp.label, gp.prim, group, gp.bucket_bytes[bi]);
                pending.borrow_mut().push(h);
                next_bucket.set(next_bucket.get() + 1);
                if !force {
                    // One bucket per op boundary: buckets become ready
                    // progressively through the backward phase.
                    break;
                }
            }
        };
        // One schedule op: overlap-aware charge structure. The attention
        // lump is gone for cp > 1 — the CP ring runs structurally (one
        // nonblocking ring-step charge on the comm lane per core chunk,
        // exactly the executed `attention::DistributedAttentionLayer`
        // pattern) and its exposure is *measured*, not credited. The rest
        // of the op keeps the a2a-under-expert-GEMM structure; net
        // main-lane time is (total − hidden) when everything fits its
        // window, and the clock verifies it per op.
        let run_op = |comm: &Communicator,
                      label: &'static str,
                      total_us: f64,
                      window_us: f64,
                      a2a_hidden_us: f64,
                      cp_chunk_us: f64,
                      cp_exp_us: f64| {
            let mut rest = total_us;
            if cp_steps > 0 {
                for _ in 0..cp_steps {
                    let h = comm.charge_comm_i("attn/cp_ring", &view.cp_group, cp_comm_c);
                    comm.advance("attn/core", cp_chunk_us);
                    let (hid, exp) = comm.wait_split(h);
                    cp_hidden.set(cp_hidden.get() + hid);
                    cp_exposed.set(cp_exposed.get() + exp);
                }
                // Final core chunk: no ring step rides under it.
                comm.advance("attn/core", cp_chunk_us);
                // Main-lane budget the ring block consumed under the
                // analytic closed form (the measurement equals it — same
                // prices, same structure).
                rest = (total_us - (cp_steps as f64 + 1.0) * cp_chunk_us - cp_exp_us).max(0.0);
            }
            if a2a_hidden_us > 0.0 {
                let win = window_us.min((rest - a2a_hidden_us).max(0.0));
                let h = comm.charge_comm_i("moe/a2a_ovl", &view.ep_group, a2a_hidden_us);
                comm.advance(label, win);
                let (hid, exp) = comm.wait_split(h);
                hidden.set(hidden.get() + hid);
                exposed.set(exposed.get() + exp);
                comm.advance(label, (rest - win - a2a_hidden_us).max(0.0));
            } else {
                comm.advance(label, rest);
            }
            let cp_block = if cp_steps > 0 { cp_exp_us } else { 0.0 };
            cum_compute.set(cum_compute.get() + total_us - a2a_hidden_us - cp_block);
            ops_done.set(ops_done.get() + 1);
            issue_buckets(comm, false);
        };

        let inputs: Vec<Vec<f32>> = (0..m).map(|mb| vec![mb as f32]).collect();
        let pipe = execute_interleaved_with(
            &comm,
            &view.pp_group,
            m,
            vpp,
            &inputs,
            |_chunk, _mb, x| {
                run_op(&comm, "fwd", f_c, f_win_c, fh_c, cp_fwin_c, cp_fexp_c);
                x.to_vec()
            },
            |_chunk, _mb, g| {
                run_op(&comm, "bwd", b_c, b_win_c, bh_c, cp_bwin_c, cp_bexp_c);
                g.to_vec()
            },
            Some(p2p_bytes),
        );
        let t_pipeline = comm.now_us();
        debug_assert_eq!(ops_done.get(), total_ops);
        // Any buckets the schedule never reached (tiny m) issue now.
        issue_buckets(&comm, true);
        // Settle the overlapped grad buckets: exposed time = what the
        // backward window failed to hide.
        for h in pending.borrow_mut().drain(..) {
            let (hid, exp) = comm.wait_split(h);
            hidden.set(hidden.get() + hid);
            exposed.set(exposed.get() + exp);
        }
        // Exposed tails: the non-overlappable share runs blocking on the
        // same grad-sync lane (measured + traced like everything else).
        for gp in &grad_plan {
            if gp.tail_bytes <= 0.0 {
                continue;
            }
            let group = match gp.scope {
                GradScope::Dp => &view.dp_group,
                GradScope::Edp => &view.edp_group,
            };
            let h = comm.charge_collective_bg(gp.label, gp.prim, group, gp.tail_bytes);
            let (hid, exp) = comm.wait_split(h);
            hidden.set(hidden.get() + hid);
            exposed.set(exposed.get() + exp);
        }
        comm.advance("optimizer", optimizer_us);
        RankOutcome {
            pipeline_us: t_pipeline,
            finish_us: comm.now_us(),
            busy_us: pipe.busy_us(),
            hidden_us: hidden.get(),
            exposed_us: exposed.get(),
            cp_hidden_us: cp_hidden.get(),
            cp_exposed_us: cp_exposed.get(),
        }
    });

    let trace = fabric.take_trace();
    Ok(aggregate_step(&comps, model, cfg, train, results, trace))
}

/// Fold per-rank outcomes and the drained trace into the estimate —
/// shared by both engines, so the aggregation arithmetic (and therefore
/// every derived field) is one implementation.
fn aggregate_step(
    comps: &StepComponents,
    model: &ModelConfig,
    cfg: ParallelConfig,
    train: &TrainConfig,
    results: Vec<RankOutcome>,
    trace: Vec<TraceEvent>,
) -> (ExecutedEstimate, Vec<TraceEvent>) {
    let world = cfg.world_size;
    let pipeline_us = results.iter().map(|r| r.pipeline_us).fold(0.0, f64::max);
    let step_us = results.iter().map(|r| r.finish_us).fold(0.0, f64::max);
    let busy: Vec<f64> = results.iter().map(|r| r.busy_us).collect();
    let bubble = measured_bubble_fraction(&busy, pipeline_us);
    let hidden_comm_us = results.iter().map(|r| r.hidden_us).sum::<f64>() / world as f64;
    let exposed_comm_us = results.iter().map(|r| r.exposed_us).sum::<f64>() / world as f64;
    let cp_hidden_us = results.iter().map(|r| r.cp_hidden_us).sum::<f64>() / world as f64;
    let cp_exposed_us = results.iter().map(|r| r.cp_exposed_us).sum::<f64>() / world as f64;

    let tokens = train.tokens_per_global_batch();
    let flops = ModelFlops::per_token(model, train.seq_len);
    let tflops = flops.achieved_tflops(tokens, step_us / 1e6, world);
    let mfu = tflops / comps.cluster.gpu.peak_tflops(train.precision);

    (
        ExecutedEstimate {
            config: cfg,
            step_ms: step_us / 1e3,
            pipeline_ms: pipeline_us / 1e3,
            bubble_fraction: bubble,
            hidden_comm_us,
            exposed_comm_us,
            cp_hidden_us,
            cp_exposed_us,
            tflops_per_gpu: if comps.oom { 0.0 } else { tflops },
            mfu: if comps.oom { 0.0 } else { mfu },
            oom: comps.oom,
        },
        trace,
    )
}

/// Interned collective-group table for one compiled step: the event
/// engine's rendezvous keys by group id, and identical member lists share
/// one id (collective instances pair up by arrival count, exactly like
/// the thread fabric's FIFO control messages — sound because every member
/// of a group runs the same charge sequence on it).
#[derive(Default)]
struct GroupTable {
    ids: HashMap<Vec<usize>, usize>,
    groups: Vec<Vec<usize>>,
}

impl GroupTable {
    /// Intern `group`, returning `(group id, this rank's member index,
    /// member count)`.
    fn of(&mut self, group: &[usize], rank: usize) -> (usize, usize, usize) {
        let gid = match self.ids.get(group) {
            Some(&gid) => gid,
            None => {
                let gid = self.groups.len();
                self.groups.push(group.to_vec());
                self.ids.insert(group.to_vec(), gid);
                gid
            }
        };
        let midx = group.iter().position(|&r| r == rank).expect("rank must be a group member");
        (gid, midx, group.len())
    }
}

/// Program-recorder state: the compile-time twin of the thread closure's
/// accumulator cells. `cum_compute`/`next_bucket` replay the same bucket
/// issue decisions; zero-duration charges and their waits are elided,
/// which is bit-safe because they add exactly `+0.0` to accumulators that
/// are never `-0.0` (they start at `+0.0` and only non-negative values
/// are added).
#[derive(Default)]
struct Recorder {
    ops: Vec<EngineOp>,
    handles: usize,
    cum_compute: f64,
    ops_done: usize,
    next_bucket: usize,
    pending: Vec<usize>,
}

impl Recorder {
    /// [`Communicator::advance`] twin (elides `us <= 0`, as advance
    /// does).
    fn advance(&mut self, label: &'static str, us: f64) {
        if us > 0.0 {
            self.ops.push(EngineOp::Advance { label, us });
        }
    }

    /// [`Communicator::charge_comm_i`] twin; `None` is the
    /// already-completed handle (`us <= 0`).
    fn charge_comm(
        &mut self,
        label: &'static str,
        (group, midx, _len): (usize, usize, usize),
        us: f64,
    ) -> Option<usize> {
        if us <= 0.0 {
            return None;
        }
        let handle = self.handles;
        self.handles += 1;
        self.ops.push(EngineOp::CommCharge { label, group, midx, us, handle });
        Some(handle)
    }

    /// [`Communicator::charge_collective_bg`] twin; `None` for singleton
    /// groups (the live call returns a completed handle without billing).
    fn charge_bg(
        &mut self,
        label: &'static str,
        prim: crate::collectives::CommPrimitive,
        (group, midx, len): (usize, usize, usize),
        bytes: f64,
    ) -> Option<usize> {
        if len <= 1 {
            return None;
        }
        let handle = self.handles;
        self.handles += 1;
        self.ops.push(EngineOp::BgCharge { label, prim, group, midx, bytes, handle });
        Some(handle)
    }

    /// [`Communicator::wait_split`] twin: elided handles split exactly
    /// `(0.0, 0.0)`.
    fn wait(&mut self, handle: Option<usize>, acc: WaitAcc) {
        if let Some(handle) = handle {
            self.ops.push(EngineOp::Wait { handle, acc });
        }
    }

    fn send(&mut self, dst: usize, tag: u64, bytes: f64) {
        self.ops.push(EngineOp::Send { dst, tag, bytes });
    }

    fn recv(&mut self, src: usize, tag: u64) {
        self.ops.push(EngineOp::Recv { src, tag });
    }
}

/// Compile one rank's step schedule into an [`EngineOp`] program — the
/// op-for-op twin of the thread closure in [`execute_step_traced_on`]:
/// the same charge order, the same bucket-issue decisions, and the p2p
/// dataflow of [`execute_interleaved_with`] (walked directly from
/// [`schedule_interleaved`] — the pipeline's dataflow has no dependence
/// on payload values, only on the schedule). Differentially pinned
/// bit-identical in `tests/engine_equivalence.rs`.
fn record_rank_program(
    rank: usize,
    view: &crate::mapping::RankView,
    comps: &StepComponents,
    grad_plan: &[GradPlan],
    bucket_seq: &[(usize, usize)],
    issue_threshold_us: f64,
    table: &mut GroupTable,
) -> RankProgram {
    let m = comps.m_micro;
    let vpp = comps.vpp.max(1);
    let v = vpp as f64;
    let f_c = comps.f_us / v;
    let b_c = comps.b_us / v;
    let fh_c = comps.f_hidden_us / v;
    let bh_c = comps.b_hidden_us / v;
    let f_win_c = (comps.f_expert_us / v).min(f_c - fh_c).max(0.0);
    let b_win_c = (comps.b_expert_us / v).min(b_c - bh_c).max(0.0);
    let cp_steps = comps.cp_steps;
    let cp_comm_c = comps.cp_step_comm_us / v;
    let cp_fwin_c = comps.cp_f_window_us / v;
    let cp_bwin_c = comps.cp_b_window_us / v;
    let cp_fexp_c = comps.cp_f_exposed_us / v;
    let cp_bexp_c = comps.cp_b_exposed_us / v;
    let p2p_bytes = comps.p2p_bytes;
    let cp_g = table.of(&view.cp_group, rank);
    let ep_g = table.of(&view.ep_group, rank);
    let dp_g = table.of(&view.dp_group, rank);
    let edp_g = table.of(&view.edp_group, rank);

    let mut rec = Recorder::default();

    let issue_buckets = |rec: &mut Recorder, force: bool| {
        while rec.next_bucket < bucket_seq.len()
            && (force || rec.cum_compute + 1e-9 >= issue_threshold_us)
        {
            let (ci, bi) = bucket_seq[rec.next_bucket];
            let gp = &grad_plan[ci];
            let g = match gp.scope {
                GradScope::Dp => dp_g,
                GradScope::Edp => edp_g,
            };
            if let Some(h) = rec.charge_bg(gp.label, gp.prim, g, gp.bucket_bytes[bi]) {
                rec.pending.push(h);
            }
            rec.next_bucket += 1;
            if !force {
                break;
            }
        }
    };
    let run_op = |rec: &mut Recorder,
                  label: &'static str,
                  total_us: f64,
                  window_us: f64,
                  a2a_hidden_us: f64,
                  cp_chunk_us: f64,
                  cp_exp_us: f64| {
        let mut rest = total_us;
        if cp_steps > 0 {
            for _ in 0..cp_steps {
                let h = rec.charge_comm("attn/cp_ring", cp_g, cp_comm_c);
                rec.advance("attn/core", cp_chunk_us);
                rec.wait(h, WaitAcc::Cp);
            }
            rec.advance("attn/core", cp_chunk_us);
            rest = (total_us - (cp_steps as f64 + 1.0) * cp_chunk_us - cp_exp_us).max(0.0);
        }
        if a2a_hidden_us > 0.0 {
            let win = window_us.min((rest - a2a_hidden_us).max(0.0));
            let h = rec.charge_comm("moe/a2a_ovl", ep_g, a2a_hidden_us);
            rec.advance(label, win);
            rec.wait(h, WaitAcc::Comm);
            rec.advance(label, (rest - win - a2a_hidden_us).max(0.0));
        } else {
            rec.advance(label, rest);
        }
        let cp_block = if cp_steps > 0 { cp_exp_us } else { 0.0 };
        rec.cum_compute += total_us - a2a_hidden_us - cp_block;
        rec.ops_done += 1;
        issue_buckets(rec, false);
    };

    let pp = view.pp_group.len();
    let stage = view.pp_stage;
    let last = pp - 1;
    for op in schedule_interleaved(stage, pp, m, vpp) {
        match op {
            PipeOp::Fwd { mb, chunk } => {
                if !(stage == 0 && chunk == 0) {
                    let src =
                        if stage > 0 { view.pp_group[stage - 1] } else { view.pp_group[last] };
                    rec.recv(src, chunk_tag(false, chunk, mb, vpp));
                }
                rec.ops.push(EngineOp::SpanOpen);
                run_op(&mut rec, "fwd", f_c, f_win_c, fh_c, cp_fwin_c, cp_fexp_c);
                rec.ops.push(EngineOp::SpanClose);
                if stage < last {
                    rec.send(view.pp_group[stage + 1], chunk_tag(false, chunk, mb, vpp), p2p_bytes);
                } else if chunk < vpp - 1 {
                    rec.send(view.pp_group[0], chunk_tag(false, chunk + 1, mb, vpp), p2p_bytes);
                }
            }
            PipeOp::Bwd { mb, chunk } => {
                if !(stage == last && chunk == vpp - 1) {
                    let src =
                        if stage < last { view.pp_group[stage + 1] } else { view.pp_group[0] };
                    rec.recv(src, chunk_tag(true, chunk, mb, vpp));
                }
                rec.ops.push(EngineOp::SpanOpen);
                run_op(&mut rec, "bwd", b_c, b_win_c, bh_c, cp_bwin_c, cp_bexp_c);
                rec.ops.push(EngineOp::SpanClose);
                if stage > 0 {
                    rec.send(view.pp_group[stage - 1], chunk_tag(true, chunk, mb, vpp), p2p_bytes);
                } else if chunk > 0 {
                    rec.send(view.pp_group[last], chunk_tag(true, chunk - 1, mb, vpp), p2p_bytes);
                }
            }
        }
    }
    rec.ops.push(EngineOp::MarkPipeline);
    debug_assert_eq!(rec.ops_done, 2 * m * vpp);
    issue_buckets(&mut rec, true);
    for handle in std::mem::take(&mut rec.pending) {
        rec.ops.push(EngineOp::Wait { handle, acc: WaitAcc::Comm });
    }
    for gp in grad_plan {
        if gp.tail_bytes <= 0.0 {
            continue;
        }
        let g = match gp.scope {
            GradScope::Dp => dp_g,
            GradScope::Edp => edp_g,
        };
        let h = rec.charge_bg(gp.label, gp.prim, g, gp.tail_bytes);
        rec.wait(h, WaitAcc::Comm);
    }
    rec.advance("optimizer", comps.optimizer_us);
    RankProgram { ops: rec.ops, handles: rec.handles }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn executed_step_close_to_analytic_on_small_config() {
        let pm = PerfModel::default();
        let model = ModelConfig::qwen2_57b_a14b();
        let train = TrainConfig::paper_default(4096, 64);
        let cfg = ParallelConfig::new(16, 2, 1, 4, 1, 2);
        let analytic = pm.estimate(&model, cfg, &train, Strategy::MCoreFolding).unwrap();
        let (executed, trace) =
            execute_step_traced(&pm, &model, cfg, &train, Strategy::MCoreFolding).unwrap();
        let rel = (executed.step_ms - analytic.step_ms).abs() / analytic.step_ms;
        assert!(
            rel < 0.02,
            "executed {:.1} ms vs analytic {:.1} ms (rel {rel:.4})",
            executed.step_ms,
            analytic.step_ms
        );
        assert!(executed.bubble_fraction > 0.0 && executed.bubble_fraction < 0.5);
        // Overlap is on by default: the bucketed grad-reduce must be
        // genuinely hidden under the backward window.
        assert!(train.overlap_grad_reduce);
        assert!(executed.hidden_comm_us > 0.0, "no comm hidden");
        assert!(!trace.is_empty());
        // Every rank contributed compute spans and the grad sync ran.
        assert!(trace.iter().any(|e| e.name == "dp/grad_reduce_scatter"));
        assert!(trace.iter().any(|e| e.name == "optimizer"));
    }

    /// The serialized twin (all overlap off) is never faster, and its
    /// hidden-comm measurement is exactly zero.
    #[test]
    fn serialized_twin_never_faster() {
        let pm = PerfModel::default();
        let model = ModelConfig::qwen2_57b_a14b();
        let mut train = TrainConfig::paper_default(4096, 64);
        let cfg = ParallelConfig::new(16, 2, 1, 4, 1, 2);
        let overlapped = execute_step(&pm, &model, cfg, &train, Strategy::MCoreFolding).unwrap();
        train.overlap_grad_reduce = false;
        train.overlap_param_gather = false;
        train.overlap_a2a = false;
        let serial = execute_step(&pm, &model, cfg, &train, Strategy::MCoreFolding).unwrap();
        // Exactly zero up to float residue of `end − now` round-trips.
        assert!(serial.hidden_comm_us < 1e-3, "serialized run hid {} µs", serial.hidden_comm_us);
        assert!(
            overlapped.step_ms <= serial.step_ms + 1e-9,
            "overlap {:.2} ms vs serialized {:.2} ms",
            overlapped.step_ms,
            serial.step_ms
        );
        assert!(overlapped.hidden_comm_us > 0.0);
    }

    /// cp > 1 replaces the attention lump with the executed ring: the
    /// ring-step charges land on the comm lane (measured hidden/exposed),
    /// and the step still agrees with the analytic estimate within 2% —
    /// the closed form and the charge loop share structure and prices.
    #[test]
    fn executed_cp_ring_is_measured_and_agrees_with_analytic() {
        let pm = PerfModel::default();
        let model = ModelConfig::qwen2_57b_a14b();
        let train = TrainConfig::paper_default(16384, 64);
        let cfg = ParallelConfig::new(16, 2, 2, 4, 1, 1);
        let analytic = pm.estimate(&model, cfg, &train, Strategy::MCoreFolding).unwrap();
        let (executed, trace) =
            execute_step_traced(&pm, &model, cfg, &train, Strategy::MCoreFolding).unwrap();
        let rel = (executed.step_ms - analytic.step_ms).abs() / analytic.step_ms;
        assert!(
            rel < 0.02,
            "executed {:.1} ms vs analytic {:.1} ms (rel {rel:.4})",
            executed.step_ms,
            analytic.step_ms
        );
        let total = executed.cp_hidden_us + executed.cp_exposed_us;
        assert!(total > 0.0, "cp ring must be measured");
        // The ring-step spans are visible in the trace on the comm lane.
        assert!(trace.iter().any(|e| e.name == "attn/cp_ring"));
        assert!(trace.iter().any(|e| e.name == "attn/core"));
        // cp = 1 twin measures nothing on the ring.
        let cfg1 = ParallelConfig::new(16, 2, 1, 4, 1, 1);
        let e1 = execute_step(&pm, &model, cfg1, &train, Strategy::MCoreFolding).unwrap();
        assert_eq!(e1.cp_hidden_us + e1.cp_exposed_us, 0.0);
    }

    /// ISSUE 8 pin: on the Table-2/3 folded Mixtral mapping the
    /// **measured** fp8-vs-bf16 step speedup lands in the paper's
    /// 1.26–1.30x window (Table 2 reports 1.255x/1.295x for
    /// MCore/folding). The same fixed config executes under both
    /// precisions — fp8 GEMMs at the derated fp8 peak, activation-class
    /// payloads at 1 byte/element, cast/amax HBM passes charged, grad
    /// sync at bf16 master-weight widths — and each precision's executed
    /// step agrees with its analytic twin within the existing 2% pin.
    #[test]
    fn fp8_executed_speedup_in_paper_window() {
        let pm = PerfModel::default();
        let model = ModelConfig::mixtral_8x22b();
        let cfg = ParallelConfig::new(128, 2, 1, 8, 1, 8);
        let bf16 = TrainConfig::paper_default(4096, 256);
        let mut fp8 = bf16.clone();
        fp8.precision = crate::config::Precision::Fp8;
        let mut steps = Vec::new();
        for train in [&bf16, &fp8] {
            let analytic = pm.estimate(&model, cfg, train, Strategy::MCoreFolding).unwrap();
            let executed = execute_step(&pm, &model, cfg, train, Strategy::MCoreFolding).unwrap();
            let rel = (executed.step_ms - analytic.step_ms).abs() / analytic.step_ms;
            assert!(
                rel < 0.02,
                "{:?}: executed {:.1} ms vs analytic {:.1} ms (rel {rel:.4})",
                train.precision,
                executed.step_ms,
                analytic.step_ms
            );
            steps.push(executed.step_ms);
        }
        let speedup = steps[0] / steps[1];
        assert!(
            (1.26..=1.30).contains(&speedup),
            "measured fp8 speedup {speedup:.4} outside the paper's 1.26–1.30x window \
             (bf16 {:.1} ms, fp8 {:.1} ms)",
            steps[0],
            steps[1]
        );
    }

    /// vpp > 1 executes the interleaved schedule and shrinks the measured
    /// bubble toward the interleaved closed form.
    #[test]
    fn interleaved_vpp_shrinks_executed_bubble() {
        let pm = PerfModel::default();
        let model = ModelConfig::qwen2_57b_a14b(); // 28 layers
        let train = TrainConfig::paper_default(4096, 64);
        let plain = ParallelConfig::new(16, 2, 1, 4, 1, 2);
        let inter = plain.with_vpp(2);
        let e1 = execute_step(&pm, &model, plain, &train, Strategy::MCoreFolding).unwrap();
        let e2 = execute_step(&pm, &model, inter, &train, Strategy::MCoreFolding).unwrap();
        assert!(
            e2.bubble_fraction < e1.bubble_fraction,
            "vpp2 bubble {:.4} !< vpp1 bubble {:.4}",
            e2.bubble_fraction,
            e1.bubble_fraction
        );
        assert!(e2.step_ms < e1.step_ms);
    }
}
