//! **Measured-in-sim** step time: run the step's actual schedule over the
//! clocked functional simulator at full world size, instead of closing it
//! with an analytic formula.
//!
//! [`execute_step`] shares its per-phase inputs ([`super::StepComponents`])
//! with the analytic [`super::PerfModel::estimate`]: per-stage fwd/bwd
//! charges, stage-boundary p2p volumes, and the gradient-sync collective
//! list. The difference is *structural* — here `world_size` rank threads
//! really execute the 1F1B schedule over [`crate::simcomm`] (real sends,
//! real recvs, real blocking), grad-sync collectives run over each rank's
//! mapped DP/EDP groups from the runtime topology, and the step time is
//! read off the virtual clock. Warmup/steady/cooldown interleaving, cross-
//! stage waits and bubbles *emerge* from the executed schedule; nothing is
//! assumed about them.
//!
//! The differential suite (`tests/clocked_timing.rs`) pins analytic vs
//! executed agreement on the paper's Table-3 folded optima; the `timeline`
//! CLI subcommand dumps [`execute_step_traced`]'s chrome trace for any
//! mapping.

use crate::config::{ModelConfig, ParallelConfig, TrainConfig};
use crate::mapping::RuntimeTopology;
use crate::model::flops::ModelFlops;
use crate::pipeline::{execute_1f1b_timed, measured_bubble_fraction};
use crate::simcomm::{run_ranks_on, AlgoSelection, Fabric, TraceEvent};

use super::{GradScope, PerfModel, Strategy};

/// Result of executing one step on the clocked simulator.
#[derive(Debug, Clone)]
pub struct ExecutedEstimate {
    pub config: ParallelConfig,
    /// Measured-in-sim step time (pipeline + exposed grad sync +
    /// optimizer), ms. The same overlap credit the analytic model grants
    /// (`StepComponents::hidden_us`) is subtracted, so the two numbers are
    /// directly comparable.
    pub step_ms: f64,
    /// Measured pipeline makespan (max rank finish of the 1F1B schedule),
    /// ms.
    pub pipeline_ms: f64,
    /// Bubble fraction measured from the executed per-rank timelines:
    /// `1 − busy / (ranks × makespan)`.
    pub bubble_fraction: f64,
    /// Achieved model TFLOPS per GPU at the measured step time.
    pub tflops_per_gpu: f64,
    /// Measured-in-sim MFU.
    pub mfu: f64,
    pub oom: bool,
}

impl ExecutedEstimate {
    /// Pretty single-line summary (mirrors `StepEstimate::summary`).
    pub fn summary(&self) -> String {
        format!(
            "{:<28} sim-step {:8.1} ms   {:6.1} TFLOPS/GPU   MFU {:5.1}%   bubble {:4.1}%",
            self.config.tag(),
            self.step_ms,
            self.tflops_per_gpu,
            self.mfu * 100.0,
            self.bubble_fraction * 100.0
        )
    }
}

/// Execute one training step on the clocked simulator at full world size.
pub fn execute_step(
    pm: &PerfModel,
    model: &ModelConfig,
    cfg: ParallelConfig,
    train: &TrainConfig,
    strategy: Strategy,
) -> Result<ExecutedEstimate, String> {
    execute_step_traced(pm, model, cfg, train, strategy).map(|(e, _)| e)
}

/// [`execute_step`] returning the full per-rank trace (serialize with
/// [`crate::simcomm::chrome_trace_json`]).
pub fn execute_step_traced(
    pm: &PerfModel,
    model: &ModelConfig,
    cfg: ParallelConfig,
    train: &TrainConfig,
    strategy: Strategy,
) -> Result<(ExecutedEstimate, Vec<TraceEvent>), String> {
    let comps = pm.components(model, cfg, train, strategy)?;
    let topo = RuntimeTopology::from_mapping(comps.mapping.clone())?;
    let world = cfg.world_size;
    let cost = crate::collectives::CommCost::new(comps.cluster.clone());
    let fabric = Fabric::new_clocked(world, AlgoSelection::fast(), cost);

    let m = comps.m_micro;
    let (f_us, b_us, p2p_bytes) = (comps.f_us, comps.b_us, comps.p2p_bytes);
    let grad_comm = &comps.grad_comm;
    let optimizer_us = comps.optimizer_us;
    let results = run_ranks_on(&fabric, |rank, comm| {
        let view = topo.view(rank);
        // The pipeline: real 1F1B over this rank's mapped stage group.
        let pipe = execute_1f1b_timed(&comm, &view.pp_group, m, f_us, b_us, p2p_bytes);
        let t_pipeline = comm.now_us();
        // Gradient/param sync over the rank's actual DP / EDP groups.
        for gc in grad_comm {
            let group = match gc.scope {
                GradScope::Dp => &view.dp_group,
                GradScope::Edp => &view.edp_group,
            };
            comm.charge_collective(gc.label, gc.prim, group, gc.bytes);
        }
        comm.advance("optimizer", optimizer_us);
        (t_pipeline, comm.now_us(), pipe.busy_us())
    });

    let pipeline_us = results.iter().map(|r| r.0).fold(0.0, f64::max);
    let raw_us = results.iter().map(|r| r.1).fold(0.0, f64::max);
    // Grant the same overlap credit the analytic model applies, so the two
    // step times differ only where their structure does.
    let step_us = raw_us - comps.hidden_us;
    let busy: Vec<f64> = results.iter().map(|r| r.2).collect();
    let bubble = measured_bubble_fraction(&busy, pipeline_us);

    let tokens = train.tokens_per_global_batch();
    let flops = ModelFlops::per_token(model, train.seq_len);
    let tflops = flops.achieved_tflops(tokens, step_us / 1e6, world);
    let mfu = tflops / comps.cluster.gpu.peak_tflops(train.precision);

    let trace = fabric.take_trace();
    Ok((
        ExecutedEstimate {
            config: cfg,
            step_ms: step_us / 1e3,
            pipeline_ms: pipeline_us / 1e3,
            bubble_fraction: bubble,
            tflops_per_gpu: if comps.oom { 0.0 } else { tflops },
            mfu: if comps.oom { 0.0 } else { mfu },
            oom: comps.oom,
        },
        trace,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn executed_step_close_to_analytic_on_small_config() {
        let pm = PerfModel::default();
        let model = ModelConfig::qwen2_57b_a14b();
        let train = TrainConfig::paper_default(4096, 64);
        let cfg = ParallelConfig::new(16, 2, 1, 4, 1, 2);
        let analytic = pm.estimate(&model, cfg, &train, Strategy::MCoreFolding).unwrap();
        let (executed, trace) =
            execute_step_traced(&pm, &model, cfg, &train, Strategy::MCoreFolding).unwrap();
        let rel = (executed.step_ms - analytic.step_ms).abs() / analytic.step_ms;
        assert!(
            rel < 0.02,
            "executed {:.1} ms vs analytic {:.1} ms (rel {rel:.4})",
            executed.step_ms,
            analytic.step_ms
        );
        assert!(executed.bubble_fraction > 0.0 && executed.bubble_fraction < 0.5);
        assert!(!trace.is_empty());
        // Every rank contributed compute spans and the grad sync ran.
        assert!(trace.iter().any(|e| e.name == "dp/grad_reduce_scatter"));
        assert!(trace.iter().any(|e| e.name == "optimizer"));
    }
}
