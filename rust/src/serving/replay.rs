//! Traffic replay: autoregressive decode microsteps on the clocked fabric.
//!
//! Training steps are huge, rectangular, and latency-oblivious; serving is
//! the opposite — a trickle of requests arrives over wall-clock time, each
//! does one training-shaped *prefill* step and then `decode_tokens` single
//! token microsteps, and the number that matters is token latency, not MFU.
//! This engine replays a seeded arrival process (Poisson or diurnal) through
//! continuous batching on one long-lived clocked [`Fabric`]: every microstep
//! is a real collective round through the existing
//! [`DistributedMoeLayer::forward`] path, step durations are deltas of
//! [`Fabric::max_sim_time_us`], and KV-read attention time is charged on the
//! compute lane in proportion to resident context.
//!
//! Everything is deterministic in the spec seed: arrivals, per-sequence
//! token streams (seeded independently per request id so outputs are
//! invariant to how prefill is chunked across microsteps), and domain
//! rotations. The per-(sequence, position) output digest in the report is
//! therefore a replay fingerprint the differential suite pins across
//! batching choices.

use crate::cluster::{ClusterSpec, LinkKind};
use crate::collectives::CommCost;
use crate::config::{DropPolicy, ParallelConfig};
use crate::dispatcher::{
    Balancer, DistributedMoeLayer, Router, RouterConfig, SkewGen, SkewProfile,
};
use crate::mapping::RuntimeTopology;
use crate::simcomm::{run_ranks_on, AlgoSelection, Fabric};
use crate::train::math::SwigluExpert;
use crate::util::Rng;

use super::placement::{ExpertPlacement, PlacementHistogram};

/// Request arrival process, in simulated microseconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Poisson arrivals: i.i.d. exponential inter-arrival gaps with the
    /// given mean.
    Poisson { mean_gap_us: f64 },
    /// Diurnal tide: Poisson whose mean gap sweeps between `quiet_gap_us`
    /// (edges of each period) and `busy_gap_us` (middle of each period) on
    /// a triangle wave — a deterministic stand-in for day/night load.
    Diurnal { quiet_gap_us: f64, busy_gap_us: f64, period_us: f64 },
}

impl ArrivalProcess {
    /// The first `n` arrival times, nondecreasing, deterministic in `rng`.
    pub fn times(&self, n: usize, rng: &mut Rng) -> Vec<f64> {
        let mut out = Vec::with_capacity(n);
        let mut t = 0.0f64;
        for _ in 0..n {
            let mean = match *self {
                ArrivalProcess::Poisson { mean_gap_us } => mean_gap_us,
                ArrivalProcess::Diurnal { quiet_gap_us, busy_gap_us, period_us } => {
                    let phase = (t / period_us).fract();
                    let tri = 1.0 - (2.0 * phase - 1.0).abs();
                    quiet_gap_us + (busy_gap_us - quiet_gap_us) * tri
                }
            };
            let u = rng.next_f64();
            t += -mean * (1.0 - u).ln();
            out.push(t);
        }
        out
    }
}

/// One replay scenario. All fields are simulation-scale: `hidden` is the
/// sim width (`>= num_experts`; bill-scaled to the real model via
/// `bill_scale`), not the model's.
#[derive(Debug, Clone)]
pub struct ReplaySpec {
    pub world: usize,
    pub num_experts: usize,
    pub hidden: usize,
    pub top_k: usize,
    /// Requests to replay to completion.
    pub requests: usize,
    /// Prompt length per request (the training-shaped prefill step).
    pub prefill_tokens: usize,
    /// Tokens generated after the first (one microstep each).
    pub decode_tokens: usize,
    pub arrivals: ArrivalProcess,
    pub profile: SkewProfile,
    /// Rotate each sequence's gate preference by a per-node offset — the
    /// domain-sharded front door that gives expert placement its leverage.
    /// Off, every node sees the same mix and placement is a no-op.
    pub rotate_domains: bool,
    /// Continuous-batching admission cap per rank (the sim-scale stand-in
    /// for the KV-cache memory gate; `tune_serving` computes the
    /// model-scale equivalent from [`crate::model::MemoryModel`]).
    pub max_concurrent_per_rank: usize,
    /// Max prefill rows a sequence contributes to one microstep; prompts
    /// longer than this are chunked across steps. Outputs are invariant to
    /// this knob (pinned by the differential suite); latency is not.
    pub microstep_tokens: usize,
    /// KV-read attention charge per resident context token per microstep,
    /// µs (compute-lane `advance`, the decode-side analogue of
    /// [`crate::dispatcher::MoePhaseCost`]).
    pub attn_us_per_ctx_token: f64,
    /// Fabric billing scale (real hidden / sim hidden).
    pub bill_scale: f64,
    pub seed: u64,
}

impl ReplaySpec {
    /// A small deterministic scenario: one expert per rank, Zipf traffic,
    /// Poisson arrivals. The differential suite's workhorse.
    pub fn small(world: usize, requests: usize, seed: u64) -> Self {
        let num_experts = world.max(4);
        ReplaySpec {
            world,
            num_experts,
            hidden: 64usize.max(num_experts),
            top_k: 2,
            requests,
            prefill_tokens: 8,
            decode_tokens: 8,
            arrivals: ArrivalProcess::Poisson { mean_gap_us: 50.0 },
            profile: SkewProfile::Zipf { exponent: 1.2 },
            rotate_domains: true,
            max_concurrent_per_rank: 4,
            microstep_tokens: 8,
            attn_us_per_ctx_token: 0.02,
            bill_scale: 1.0,
            seed,
        }
    }
}

/// What a replay measured.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    pub completed: usize,
    /// Tokens generated (first token + decode tokens, all requests).
    pub generated_tokens: usize,
    /// Collective rounds executed.
    pub steps: usize,
    /// Nearest-rank percentiles over all per-token latencies (first-token
    /// latency includes queue wait; decode latencies are inter-token).
    pub p50_us: f64,
    pub p99_us: f64,
    pub tokens_per_sec_per_gpu: f64,
    /// Metered bytes over the IB link class — the placement ground truth.
    pub ib_bytes: f64,
    pub nvlink_bytes: f64,
    pub total_us: f64,
    /// Order-invariant digest over every (sequence, position) output row.
    pub digest: u64,
    pub token_latencies: Vec<f64>,
    /// Per-source-node routing traffic in logical expert space — feed to
    /// [`super::placement::optimize_placement`].
    pub histogram: PlacementHistogram,
}

/// Nearest-rank percentile (`p` in (0, 1]): the ceil(p·n)-th smallest.
pub fn percentile_nearest_rank(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty sample");
    assert!(p > 0.0 && p <= 1.0);
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let idx = ((p * v.len() as f64).ceil() as usize).clamp(1, v.len()) - 1;
    v[idx]
}

/// Rotate the gate-logit features (the first `e` of each `h`-wide row) by
/// `rot` positions: a token preferring expert `p` now prefers
/// `(p + rot) % e`. This is the domain operator — same popularity shape,
/// shifted support.
pub fn rotate_gate_features(tokens: &mut [f32], e: usize, h: usize, rot: usize) {
    if rot == 0 {
        return;
    }
    let n = tokens.len() / h;
    let mut buf = vec![0.0f32; e];
    for t in 0..n {
        let row = &mut tokens[t * h..t * h + e];
        for (j, &x) in row.iter().enumerate() {
            buf[(j + rot) % e] = x;
        }
        row.copy_from_slice(&buf);
    }
}

fn seq_seed(seed: u64, id: usize) -> u64 {
    seed ^ (id as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

fn row_digest(id: usize, pos: usize, row: &[f32]) -> u64 {
    let mut x = 0x9E37_79B9_7F4A_7C15u64 ^ ((id as u64) << 32) ^ pos as u64;
    for &v in row {
        x = x.wrapping_mul(0x0000_0100_0000_01B3).wrapping_add(u64::from(v.to_bits()));
    }
    x
}

struct SeqState {
    id: usize,
    gen: SkewGen,
    rotation: usize,
    prefill_left: usize,
    decode_left: usize,
    context: usize,
    emitted: usize,
    arrival_us: f64,
    last_token_us: f64,
}

/// Replay `spec` under `placement` and measure it. Every call builds its
/// own fabric, router, and experts from `spec.seed`, so two calls with
/// different placements but the same spec compare exactly the same traffic
/// — the only degree of freedom is where the experts live.
pub fn replay(spec: &ReplaySpec, placement: &ExpertPlacement) -> ReplayReport {
    let (world, e, h) = (spec.world, spec.num_experts, spec.hidden);
    assert!(h >= e, "gate logits embed in the first num_experts features");
    assert_eq!(e % world, 0, "experts must divide evenly over EP ranks");
    assert_eq!(placement.num_experts(), e);
    assert!(spec.requests > 0 && spec.prefill_tokens > 0);

    let cluster = ClusterSpec::eos(world);
    let num_nodes = cluster.node_of(world - 1) + 1;
    let cfg = RouterConfig {
        hidden: h,
        num_experts: e,
        top_k: spec.top_k,
        capacity_factor: 1.0,
        // Dropless is load-bearing: it keeps per-token outputs independent
        // of batch composition, which is what makes the replay digest
        // invariant to chunking and admission order.
        drop_policy: DropPolicy::Dropless,
        capacity_override: None,
        pad_to_capacity: false,
        node_limit: None,
        balancer: Balancer::AuxLoss,
    };
    let base_router = Router::new(cfg, SkewGen::gate_weight(h, e));
    let router = placement.apply_to_router(&base_router);
    let mut wrng = Rng::seed_from_u64(spec.seed ^ 0x00C0_FFEE);
    let base_experts: Vec<SwigluExpert> =
        (0..e).map(|_| SwigluExpert::init(h, h, &mut wrng)).collect();
    let experts = placement.apply_to_experts(&base_experts);
    let expert_of_slot = placement.slot_to_expert.clone();

    let topo = RuntimeTopology::folded(ParallelConfig::new(world, 1, 1, world, 1, 1))
        .expect("EP-only serving grid");
    let fabric =
        Fabric::new_clocked(world, AlgoSelection::fast(), CommCost::new(cluster.clone()));

    let mut arr_rng = Rng::seed_from_u64(spec.seed ^ 0x0A22_17A1);
    let mut pending: std::collections::VecDeque<(f64, usize)> = spec
        .arrivals
        .times(spec.requests, &mut arr_rng)
        .into_iter()
        .enumerate()
        .map(|(id, t)| (t, id))
        .collect();
    let mut active: Vec<Vec<SeqState>> = (0..world).map(|_| Vec::new()).collect();

    let mut idle_us = 0.0f64;
    let mut latencies: Vec<f64> = Vec::new();
    let mut digest = 0u64;
    let mut hist = PlacementHistogram::new(num_nodes, e);
    let mut generated = 0usize;
    let mut completed = 0usize;
    let mut steps = 0usize;

    while completed < spec.requests {
        assert!(steps < 1_000_000, "replay failed to converge");
        let now = fabric.max_sim_time_us() + idle_us;
        // Admit arrived requests. Sharding is static (`id % world`, the
        // hash-sharded front door): the sequence->rank map — and with it
        // every domain rotation and token stream — is independent of step
        // timing, which is what makes the replay digest invariant to the
        // microstep chunking knob. A full rank blocks its queue head.
        while let Some(&(t, id)) = pending.front() {
            if t > now {
                break;
            }
            let rank = id % world;
            if active[rank].len() >= spec.max_concurrent_per_rank {
                break;
            }
            pending.pop_front();
            let rotation = if spec.rotate_domains && num_nodes > 1 {
                ((cluster.node_of(rank) + 1) % num_nodes) * (e / num_nodes).max(1)
            } else {
                0
            };
            active[rank].push(SeqState {
                id,
                gen: SkewGen::new(spec.profile, e, h, seq_seed(spec.seed, id)),
                rotation,
                prefill_left: spec.prefill_tokens,
                decode_left: spec.decode_tokens,
                context: 0,
                emitted: 0,
                arrival_us: t,
                last_token_us: t,
            });
        }
        if active.iter().all(|a| a.is_empty()) {
            // Fleet idle: jump the engine clock to the next arrival.
            let (t, _) = *pending.front().expect("idle with nothing pending");
            idle_us += (t - now).max(0.0);
            continue;
        }

        // Build this microstep's per-rank batches.
        let mut batch: Vec<Vec<f32>> = (0..world).map(|_| Vec::new()).collect();
        let mut rows_of: Vec<Vec<usize>> = (0..world).map(|_| Vec::new()).collect();
        let mut attn_ctx = vec![0.0f64; world];
        for r in 0..world {
            for s in active[r].iter_mut() {
                let rows = if s.prefill_left > 0 {
                    s.prefill_left.min(spec.microstep_tokens.max(1))
                } else {
                    1
                };
                let mut toks = s.gen.next_tokens(rows);
                rotate_gate_features(&mut toks, e, h, s.rotation);
                batch[r].extend_from_slice(&toks);
                rows_of[r].push(rows);
                attn_ctx[r] += (s.context + rows) as f64;
            }
        }

        // One collective round: every rank participates even when empty.
        let outs: Vec<Vec<f32>> = run_ranks_on(&fabric, |rank, comm| {
            comm.set_bill_scale(spec.bill_scale);
            comm.advance("serve/attn", spec.attn_us_per_ctx_token * attn_ctx[rank]);
            let layer =
                DistributedMoeLayer::from_topology(topo.view(rank), router.clone(), &experts);
            layer.forward(&comm, &batch[rank]).0
        });
        steps += 1;
        let step_end = fabric.max_sim_time_us() + idle_us;

        // Source-node routing histogram, folded back to logical experts.
        for r in 0..world {
            if batch[r].is_empty() {
                continue;
            }
            let dec = router.route(&batch[r]);
            let mut logical = vec![0usize; e];
            for (slot, &cnt) in dec.expert_load.iter().enumerate() {
                logical[expert_of_slot[slot]] += cnt;
            }
            hist.record(cluster.node_of(r), &logical);
        }

        // Token accounting.
        for r in 0..world {
            let mut off = 0usize;
            for (k, s) in active[r].iter_mut().enumerate() {
                let rows = rows_of[r][k];
                let out_rows = &outs[r][off * h..(off + rows) * h];
                off += rows;
                s.context += rows;
                if s.prefill_left > 0 {
                    s.prefill_left -= rows;
                    if s.prefill_left == 0 {
                        // Prefill completion emits the first token.
                        latencies.push(step_end - s.arrival_us);
                        s.last_token_us = step_end;
                        digest = digest
                            .wrapping_add(row_digest(s.id, s.emitted, &out_rows[(rows - 1) * h..]));
                        s.emitted += 1;
                        generated += 1;
                        if s.decode_left == 0 {
                            completed += 1;
                        }
                    }
                } else {
                    latencies.push(step_end - s.last_token_us);
                    s.last_token_us = step_end;
                    digest = digest.wrapping_add(row_digest(s.id, s.emitted, out_rows));
                    s.emitted += 1;
                    generated += 1;
                    s.decode_left -= 1;
                    if s.decode_left == 0 {
                        completed += 1;
                    }
                }
            }
            active[r].retain(|s| s.prefill_left > 0 || s.decode_left > 0);
        }
    }

    let total_us = fabric.max_sim_time_us() + idle_us;
    ReplayReport {
        completed,
        generated_tokens: generated,
        steps,
        p50_us: percentile_nearest_rank(&latencies, 0.50),
        p99_us: percentile_nearest_rank(&latencies, 0.99),
        tokens_per_sec_per_gpu: generated as f64 / (total_us / 1e6) / world as f64,
        ib_bytes: fabric.link_traffic(LinkKind::InfiniBand).bytes,
        nvlink_bytes: fabric.link_traffic(LinkKind::NvLink).bytes,
        total_us,
        digest,
        token_latencies: latencies,
        histogram: hist,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank_pinned() {
        let xs: Vec<f64> = (1..=10).map(|i| (i * 10) as f64).collect();
        assert_eq!(percentile_nearest_rank(&xs, 0.50), 50.0);
        assert_eq!(percentile_nearest_rank(&xs, 0.90), 90.0);
        assert_eq!(percentile_nearest_rank(&xs, 0.99), 100.0);
        assert_eq!(percentile_nearest_rank(&xs, 1.0), 100.0);
        assert_eq!(percentile_nearest_rank(&[7.0], 0.5), 7.0);
        // Unsorted input sorts internally.
        assert_eq!(percentile_nearest_rank(&[3.0, 1.0, 2.0], 0.5), 2.0);
    }

    #[test]
    fn arrivals_deterministic_and_monotone() {
        let p = ArrivalProcess::Poisson { mean_gap_us: 40.0 };
        let a = p.times(200, &mut Rng::seed_from_u64(5));
        let b = p.times(200, &mut Rng::seed_from_u64(5));
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        let mean = a.last().unwrap() / 200.0;
        assert!(mean > 10.0 && mean < 160.0, "poisson mean gap {mean}");

        let d = ArrivalProcess::Diurnal {
            quiet_gap_us: 200.0,
            busy_gap_us: 20.0,
            period_us: 4000.0,
        };
        let t = d.times(100, &mut Rng::seed_from_u64(5));
        assert!(t.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn rotate_gate_features_shifts_preference() {
        let (e, h) = (4, 8);
        let mut row = vec![0.0f32; h];
        row[1] = 5.0; // prefers expert 1
        row[6] = 3.3; // non-gate feature untouched
        rotate_gate_features(&mut row, e, h, 3);
        assert_eq!(row[(1 + 3) % e], 5.0);
        assert_eq!(row[6], 3.3);
        // rot == 0 is a strict no-op.
        let before = row.clone();
        rotate_gate_features(&mut row, e, h, 0);
        assert_eq!(row, before);
    }

    #[test]
    fn replay_smoke_and_determinism() {
        let spec = ReplaySpec::small(4, 6, 99);
        let packed = ExpertPlacement::packed(spec.num_experts);
        let a = replay(&spec, &packed);
        assert_eq!(a.completed, 6);
        assert_eq!(a.generated_tokens, 6 * (1 + spec.decode_tokens));
        assert!(a.steps > 0 && a.total_us > 0.0);
        assert!(a.p50_us > 0.0 && a.p99_us >= a.p50_us);
        assert!(a.tokens_per_sec_per_gpu > 0.0);
        // Same spec, same placement => bit-identical report.
        let b = replay(&spec, &packed);
        assert_eq!(a.digest, b.digest);
        assert_eq!(a.p50_us.to_bits(), b.p50_us.to_bits());
        assert_eq!(a.ib_bytes.to_bits(), b.ib_bytes.to_bits());
        assert_eq!(a.histogram, b.histogram);
    }
}
