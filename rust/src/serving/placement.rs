//! MoETuner-style expert placement for the serving fabric.
//!
//! Training shards experts packed over EP ranks (expert `e` on rank
//! `e / experts_per_rank`) and never revisits the assignment: the balancers
//! keep training traffic near-uniform, so no placement beats any other.
//! Serving traffic is different — request streams carry domain affinity,
//! hot experts stay hot for minutes, and the front door shards sequences
//! over nodes without consulting the gate. The optimizer here aggregates
//! per-*source-node* routing histograms
//! ([`crate::dispatcher::RouteDecision::expert_load`] summed over the steps
//! of a replay) and re-assigns logical experts to physical slots so the
//! heaviest (node, expert) traffic stays on-node. Ground truth is never the
//! histogram itself: it is the clocked fabric's own meter,
//! [`crate::simcomm::Fabric::link_traffic`] on the InfiniBand class.
//!
//! A placement is an expert-id permutation, nothing more: physical slot `s`
//! (owned by EP rank `s / experts_per_rank`) hosts logical expert
//! `slot_to_expert[s]`. Applying it permutes the gate columns and the
//! expert table *consistently*, so routing probabilities — and therefore
//! model outputs — are unchanged; only the wire destinations move.

use crate::cluster::{ClusterSpec, LinkKind};
use crate::collectives::CommCost;
use crate::config::ParallelConfig;
use crate::dispatcher::{DistributedMoeLayer, Router};
use crate::mapping::RuntimeTopology;
use crate::simcomm::{run_ranks_on, AlgoSelection, Fabric};
use crate::train::math::SwigluExpert;

/// How much hotter (relative) a foreign node's traffic for an expert must be
/// before the optimizer moves it off its packed home node. Keeps the
/// optimizer a provable identity on uniform traffic, where per-node counts
/// differ only by sampling noise.
pub const HOME_STICKINESS: f64 = 0.10;

/// An assignment of logical experts to physical expert slots.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExpertPlacement {
    /// `slot_to_expert[s]` = logical expert hosted in physical slot `s`.
    /// Slot `s` lives on EP rank `s / experts_per_rank`. Always a
    /// permutation of `0..num_experts`.
    pub slot_to_expert: Vec<usize>,
}

impl ExpertPlacement {
    /// The packed (training) placement: slot `s` hosts expert `s`.
    pub fn packed(num_experts: usize) -> Self {
        Self { slot_to_expert: (0..num_experts).collect() }
    }

    pub fn num_experts(&self) -> usize {
        self.slot_to_expert.len()
    }

    pub fn is_identity(&self) -> bool {
        self.slot_to_expert.iter().enumerate().all(|(s, &e)| s == e)
    }

    /// Inverse map: `expert_to_slot[e]` = physical slot hosting expert `e`.
    pub fn expert_to_slot(&self) -> Vec<usize> {
        let mut inv = vec![0usize; self.slot_to_expert.len()];
        for (s, &e) in self.slot_to_expert.iter().enumerate() {
            inv[e] = s;
        }
        inv
    }

    /// Reorder a global expert table so `out[s]` holds the weights of the
    /// logical expert placed in slot `s`.
    pub fn apply_to_experts(&self, experts: &[SwigluExpert]) -> Vec<SwigluExpert> {
        assert_eq!(experts.len(), self.slot_to_expert.len());
        self.slot_to_expert.iter().map(|&e| experts[e].clone()).collect()
    }

    /// Permute a router's gate columns (and bias) into slot space: column
    /// `s` of the placed gate scores the expert hosted in slot `s`. The
    /// placed router selects the *same* logical experts with the same
    /// probabilities; only the slot ids on the wire change.
    pub fn apply_to_router(&self, router: &Router) -> Router {
        let e = router.config.num_experts;
        assert_eq!(e, self.slot_to_expert.len());
        let h = router.config.hidden;
        let mut w = vec![0.0f32; h * e];
        for r in 0..h {
            for (s, &le) in self.slot_to_expert.iter().enumerate() {
                w[r * e + s] = router.weight[r * e + le];
            }
        }
        let bias: Vec<f32> =
            self.slot_to_expert.iter().map(|&le| router.bias[le]).collect();
        Router::new(router.config, w).with_bias(bias)
    }
}

/// Per-source-node routing traffic, in *logical* expert space.
/// `per_node[m][e]` = tokens sourced on node `m` that routed to expert `e`.
#[derive(Debug, Clone, PartialEq)]
pub struct PlacementHistogram {
    pub per_node: Vec<Vec<f64>>,
}

impl PlacementHistogram {
    pub fn new(num_nodes: usize, num_experts: usize) -> Self {
        Self { per_node: vec![vec![0.0; num_experts]; num_nodes] }
    }

    /// Fold one step's per-expert load from a rank on `node` into the
    /// histogram. `load` is in logical expert space (un-permute a placed
    /// run's slot loads first; see [`ExpertPlacement::expert_to_slot`]).
    /// An all-zero step (idle rank) contributes nothing — the serving path
    /// hits these constantly, which is exactly why
    /// [`crate::dispatcher::LoadStats::from_load`] treats them as a NaN
    /// sentinel rather than "perfectly balanced".
    pub fn record(&mut self, node: usize, load: &[usize]) {
        let row = &mut self.per_node[node];
        assert_eq!(row.len(), load.len());
        for (acc, &l) in row.iter_mut().zip(load) {
            *acc += l as f64;
        }
    }

    /// Total traffic to one logical expert across all source nodes.
    pub fn expert_total(&self, expert: usize) -> f64 {
        self.per_node.iter().map(|row| row[expert]).sum()
    }

    pub fn num_nodes(&self) -> usize {
        self.per_node.len()
    }
}

/// Greedy MoETuner-style node assignment. Experts are visited in descending
/// total-traffic order; each is pinned to the node sourcing most of its
/// traffic, unless its packed home node is within [`HOME_STICKINESS`] of
/// that maximum (then it stays home — identity on uniform traffic). Node
/// capacities are the slot counts the EP sharding dictates. Within a node,
/// experts fill slots in ascending id order, so "every expert stays home"
/// reproduces the packed placement bit-for-bit.
pub fn optimize_placement(
    hist: &PlacementHistogram,
    cluster: &ClusterSpec,
    ep: usize,
    num_experts: usize,
) -> ExpertPlacement {
    assert!(num_experts % ep == 0, "experts must divide evenly over EP ranks");
    let epr = num_experts / ep;
    // Node of each physical slot under the serving layout (EP ranks are
    // global ranks 0..ep, in order).
    let node_of_slot = |s: usize| cluster.node_of(s / epr);
    let num_nodes = node_of_slot(num_experts - 1) + 1;
    if num_nodes <= 1 {
        // Single node: no IB to optimize, keep packed.
        return ExpertPlacement::packed(num_experts);
    }
    assert!(
        hist.num_nodes() >= num_nodes,
        "histogram covers {} nodes, layout needs {}",
        hist.num_nodes(),
        num_nodes
    );
    let mut capacity = vec![0usize; num_nodes];
    for s in 0..num_experts {
        capacity[node_of_slot(s)] += 1;
    }

    // Hot experts first; ties broken by ascending id for determinism.
    let mut order: Vec<usize> = (0..num_experts).collect();
    order.sort_by(|&a, &b| {
        hist.expert_total(b)
            .total_cmp(&hist.expert_total(a))
            .then(a.cmp(&b))
    });

    let mut assigned: Vec<Vec<usize>> = vec![Vec::new(); num_nodes];
    for &e in &order {
        let home = node_of_slot(e);
        // Node sourcing the most traffic for this expert, among those with
        // free slots; ties go to the lowest node id.
        let mut best: Option<(usize, f64)> = None;
        for m in 0..num_nodes {
            if assigned[m].len() >= capacity[m] {
                continue;
            }
            let t = hist.per_node[m][e];
            let better = match best {
                None => true,
                Some((_, bt)) => t > bt,
            };
            if better {
                best = Some((m, t));
            }
        }
        let (mut pick, best_t) = best.expect("capacities sum to num_experts");
        if assigned[home].len() < capacity[home] {
            let home_t = hist.per_node[home][e];
            if best_t <= home_t * (1.0 + HOME_STICKINESS) {
                pick = home;
            }
        }
        assigned[pick].push(e);
    }

    // Fill each node's slots in ascending expert order.
    let mut slot_to_expert = vec![usize::MAX; num_experts];
    let mut cursor = vec![0usize; num_nodes];
    let mut node_slots: Vec<Vec<usize>> = vec![Vec::new(); num_nodes];
    for s in 0..num_experts {
        node_slots[node_of_slot(s)].push(s);
    }
    for m in 0..num_nodes {
        assigned[m].sort_unstable();
        for &e in &assigned[m] {
            slot_to_expert[node_slots[m][cursor[m]]] = e;
            cursor[m] += 1;
        }
    }
    debug_assert!(slot_to_expert.iter().all(|&e| e != usize::MAX));
    ExpertPlacement { slot_to_expert }
}

/// Run one dispatch step per rank under `placement` on a clocked EP-only
/// fabric and return the metered InfiniBand bytes. This is the ground-truth
/// harness the placement tests and the `serve` CLI use to prove (or refute)
/// a placement: same router, same experts, same per-rank token batches —
/// only the slot permutation differs between candidates.
pub fn measure_ib_bytes(
    router: &Router,
    experts: &[SwigluExpert],
    placement: &ExpertPlacement,
    per_rank_tokens: &[Vec<f32>],
) -> f64 {
    let world = per_rank_tokens.len();
    let placed_router = placement.apply_to_router(router);
    let placed_experts = placement.apply_to_experts(experts);
    let topo = RuntimeTopology::folded(ParallelConfig::new(world, 1, 1, world, 1, 1))
        .expect("EP-only serving grid");
    let cluster = ClusterSpec::eos(world);
    let fabric = Fabric::new_clocked(world, AlgoSelection::fast(), CommCost::new(cluster));
    run_ranks_on(&fabric, |rank, comm| {
        let layer =
            DistributedMoeLayer::from_topology(topo.view(rank), placed_router.clone(), &placed_experts);
        layer.forward(&comm, &per_rank_tokens[rank]).0
    });
    fabric.link_traffic(LinkKind::InfiniBand).bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DropPolicy;
    use crate::dispatcher::{Balancer, RouterConfig, SkewGen};

    fn dropless(hidden: usize, e: usize, k: usize) -> RouterConfig {
        RouterConfig {
            hidden,
            num_experts: e,
            top_k: k,
            capacity_factor: 1.0,
            drop_policy: DropPolicy::Dropless,
            capacity_override: None,
            pad_to_capacity: false,
            node_limit: None,
            balancer: Balancer::AuxLoss,
        }
    }

    #[test]
    fn placement_permutes_router_and_experts_consistently() {
        let (h, e) = (16, 8);
        let router = Router::new(dropless(h, e, 2), SkewGen::gate_weight(h, e));
        let mut rng = crate::util::Rng::seed_from_u64(7);
        let experts: Vec<SwigluExpert> =
            (0..e).map(|_| SwigluExpert::init(h, 4, &mut rng)).collect();

        // Packed placement is a strict no-op on both artifacts.
        let packed = ExpertPlacement::packed(e);
        assert!(packed.is_identity());
        let same = packed.apply_to_router(&router);
        assert_eq!(same.weight, router.weight);

        // A rotation: slot s hosts expert (s + 3) % e.
        let rot = ExpertPlacement {
            slot_to_expert: (0..e).map(|s| (s + 3) % e).collect(),
        };
        let placed_router = rot.apply_to_router(&router);
        let placed_experts = rot.apply_to_experts(&experts);
        // Gate column s of the placed router is gate column perm[s] of the
        // original — with the identity-embedding gate, that means feature
        // perm[s] scores slot s.
        for r in 0..h {
            for s in 0..e {
                assert_eq!(
                    placed_router.weight[r * e + s],
                    router.weight[r * e + rot.slot_to_expert[s]]
                );
            }
        }
        // Slot s's expert weights are the logical expert's, bit-for-bit.
        for s in 0..e {
            assert_eq!(placed_experts[s].w_gate, experts[rot.slot_to_expert[s]].w_gate);
        }
        // Inverse really inverts.
        let inv = rot.expert_to_slot();
        for s in 0..e {
            assert_eq!(inv[rot.slot_to_expert[s]], s);
        }
    }

    #[test]
    fn placement_preserves_routed_expert_identity() {
        // The placed (router, experts) pair routes every token to the same
        // logical expert weights as the unplaced pair — only slot ids move.
        let (h, e, n) = (16, 8, 64);
        let router = Router::new(dropless(h, e, 2), SkewGen::gate_weight(h, e));
        let mut gen = SkewGen::new(
            crate::dispatcher::SkewProfile::Zipf { exponent: 1.2 },
            e,
            h,
            42,
        );
        let tokens = gen.next_tokens(n);
        let rot = ExpertPlacement {
            slot_to_expert: (0..e).map(|s| (s + 5) % e).collect(),
        };
        let placed = rot.apply_to_router(&router);
        let base_dec = router.route(&tokens);
        let placed_dec = placed.route(&tokens);
        // The softmax denominator sums in permuted order, so probs can move
        // by an ulp — compare per-token logical expert sets with a
        // tolerance on the gate weight, not bit equality.
        let per_token = |dec: &crate::dispatcher::RouteDecision, to_logical: bool| {
            let mut by_tok: Vec<Vec<(usize, f32)>> = vec![Vec::new(); n];
            for a in &dec.assignments {
                let le = if to_logical { rot.slot_to_expert[a.expert] } else { a.expert };
                by_tok[a.token].push((le, a.prob));
            }
            for row in &mut by_tok {
                row.sort_by_key(|&(le, _)| le);
            }
            by_tok
        };
        let base = per_token(&base_dec, false);
        let plcd = per_token(&placed_dec, true);
        for (bt, pt) in base.iter().zip(&plcd) {
            let be: Vec<usize> = bt.iter().map(|&(le, _)| le).collect();
            let pe: Vec<usize> = pt.iter().map(|&(le, _)| le).collect();
            assert_eq!(be, pe, "placement changed the selected logical experts");
            for (&(_, wa), &(_, wb)) in bt.iter().zip(pt) {
                assert!((wa - wb).abs() < 1e-5, "gate weight moved: {wa} vs {wb}");
            }
        }
    }

    #[test]
    fn optimizer_swaps_cross_node_hotspots() {
        // 16 ranks = 2 EOS nodes, 16 experts, 1 per rank. Node 0's traffic
        // all targets experts 8..16 (homed on node 1) and vice versa — the
        // optimizer must swap the two halves.
        let world = 16;
        let e = 16;
        let cluster = ClusterSpec::eos(world);
        let mut hist = PlacementHistogram::new(2, e);
        for x in 8..16 {
            hist.per_node[0][x] = 100.0;
        }
        for x in 0..8 {
            hist.per_node[1][x] = 100.0;
        }
        let p = optimize_placement(&hist, &cluster, world, e);
        let want: Vec<usize> = (8..16).chain(0..8).collect();
        assert_eq!(p.slot_to_expert, want);
    }

    #[test]
    fn optimizer_is_identity_on_uniform_traffic() {
        // Near-uniform counts (small noise below the stickiness threshold)
        // must leave the packed placement untouched.
        let world = 16;
        let e = 16;
        let cluster = ClusterSpec::eos(world);
        let mut hist = PlacementHistogram::new(2, e);
        for m in 0..2 {
            for x in 0..e {
                hist.per_node[m][x] = 100.0 + ((m * 31 + x * 7) % 5) as f64;
            }
        }
        let p = optimize_placement(&hist, &cluster, world, e);
        assert!(p.is_identity(), "uniform traffic moved experts: {:?}", p.slot_to_expert);
    }

    #[test]
    fn single_node_layout_stays_packed() {
        let cluster = ClusterSpec::eos(8);
        let mut hist = PlacementHistogram::new(1, 16);
        hist.per_node[0][3] = 1e6;
        let p = optimize_placement(&hist, &cluster, 8, 16);
        assert!(p.is_identity());
    }
}
