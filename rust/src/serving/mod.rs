//! Serving on the folded fabric: decode microsteps, expert placement, and
//! traffic replay.
//!
//! The paper tunes parallelism for training throughput. Serving the same
//! checkpoint flips every assumption: steps shrink from millions of tokens
//! to one per sequence, the objective moves from MFU to token latency, the
//! memory budget is dominated by a KV cache that grows with every decoded
//! token, and traffic stops being balancer-flattened — request streams have
//! domain affinity, so per-node routing histograms diverge. This module is
//! the serving half of that split, built entirely on the training
//! machinery:
//!
//! * [`replay`] — seeded Poisson/diurnal arrivals, continuous batching,
//!   prefill as one training-shaped step followed by single-token decode
//!   microsteps, all as real collective rounds on a clocked
//!   [`crate::simcomm::Fabric`]. Reports nearest-rank p50/p99 token latency
//!   and tokens/sec/GPU.
//! * [`placement`] — MoETuner-style histogram-driven expert placement: a
//!   pure expert-id permutation that provably cuts metered InfiniBand
//!   dispatch bytes on skewed traffic and is the identity on uniform
//!   traffic.
//! * [`tune_serving`] — the serving autotuner: same candidate grids as
//!   training, but gated by [`crate::model::MemoryModel::estimate_serving`]
//!   (weights + KV cache, no optimizer states) and ranked by an analytic
//!   decode-microstep latency. Prefill wants the training optima; decode
//!   wants shallow pipelines and KV-friendly TP — the tuner exposes
//!   exactly that disagreement.

pub mod placement;
pub mod replay;

pub use placement::{
    measure_ib_bytes, optimize_placement, ExpertPlacement, PlacementHistogram,
};
pub use replay::{
    percentile_nearest_rank, replay, rotate_gate_features, ArrivalProcess, ReplayReport,
    ReplaySpec,
};

use crate::cluster::ClusterSpec;
use crate::config::{ModelConfig, ParallelConfig, Precision};
use crate::model::memory::MemoryEstimate;
use crate::perfmodel::{PerfModel, Strategy};

/// The serving-side counterpart of [`crate::config::TrainConfig`].
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Resident sequences per model replica (one DP group), i.e. the
    /// continuous-batching depth the KV budget must carry.
    pub concurrent_seqs: usize,
    /// KV context length budgeted per sequence (prompt + generation).
    pub context_len: usize,
    pub precision: Precision,
    /// Per-GPU HBM budget in GiB.
    pub hbm_gib: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            concurrent_seqs: 64,
            context_len: 8192,
            precision: Precision::Bf16,
            hbm_gib: crate::cluster::GpuSpec::h100().hbm_gib,
        }
    }
}

/// One serving-feasible parallel configuration, ranked by decode latency.
#[derive(Debug, Clone)]
pub struct ServingCandidate {
    pub config: ParallelConfig,
    /// Analytic per-token decode latency, µs (see [`decode_microstep_us`]).
    pub decode_us: f64,
    pub memory: MemoryEstimate,
}

/// Result of [`tune_serving`] for one strategy.
#[derive(Debug, Clone)]
pub struct ServingTuneResult {
    pub strategy: Strategy,
    /// Serving-feasible candidates, sorted by ascending decode latency.
    pub candidates: Vec<ServingCandidate>,
    pub best: Option<ServingCandidate>,
    pub evaluated: usize,
    /// Candidates the KV-aware memory gate pruned.
    pub oom_count: usize,
}

/// Analytic decode-microstep latency, µs. Decode GEMMs at microstep batch
/// sizes are HBM-bound, so the model is bandwidth-first:
///
/// * weight streaming — every resident weight byte is read once per token;
///   a token passes all `num_layers` serially, so PP does **not** shrink
///   the weight bytes on its critical path (it only splits them across
///   stages and adds hops);
/// * KV streaming — the resident cache (`concurrent_seqs · context_len`)
///   is read once per microstep, sharded over TP·CP;
/// * dispatch/combine all-to-all per MoE layer, priced NVLink while
///   `ep·etp` fits in a node (folding packs EP innermost) and IB beyond;
/// * TP sync latencies and one cross-stage hop per extra PP stage.
pub fn decode_microstep_us(
    model: &ModelConfig,
    cfg: &ParallelConfig,
    cluster: &ClusterSpec,
    serve: &ServeConfig,
) -> f64 {
    let gpu = &cluster.gpu;
    let hbm = gpu.hbm_bw_gbs * 1e9;
    let width = match serve.precision {
        Precision::Bf16 => 2.0,
        Precision::Fp8 => 1.0,
    };
    let b = serve.concurrent_seqs as f64;
    let layers = model.num_layers as f64;
    let moe_layers = model.num_moe_layers() as f64;

    let attn_w_us =
        model.attn_params_per_layer() as f64 / cfg.tp as f64 * width / hbm * 1e6;
    let e = model.num_experts.max(1) as f64;
    let local_expert_bytes =
        e * model.params_per_expert() as f64 / (cfg.ep * cfg.etp) as f64 * width;
    // With b·k active tokens over e experts, the expected touched fraction
    // of the local expert table saturates at 1.
    let active_frac = (b * model.top_k as f64 / e).min(1.0);
    let expert_w_us = local_expert_bytes * active_frac / hbm * 1e6;

    let kv_row = 2.0 * model.num_query_groups as f64 * model.head_dim() as f64 * width;
    let kv_us = b * serve.context_len as f64 * kv_row / (cfg.tp * cfg.cp) as f64
        / hbm
        * 1e6;

    let (lat, bw_gbs) = if cfg.ep * cfg.etp <= cluster.gpus_per_node {
        (cluster.nvlink_latency_us, cluster.nvlink_bw_gbs)
    } else {
        (cluster.ib_latency_us, cluster.ib_bw_gbs)
    };
    let a2a_bytes = b * model.top_k as f64 * model.hidden_size as f64 * width;
    let a2a_us = if cfg.ep > 1 {
        2.0 * (lat + a2a_bytes / (bw_gbs * 1e9) * 1e6)
    } else {
        0.0
    };
    let tp_us = if cfg.tp > 1 { 4.0 * cluster.nvlink_latency_us } else { 0.0 };
    let pp_hop_us = (cfg.pp - 1) as f64 * cluster.ib_latency_us;

    layers * (attn_w_us + kv_us + tp_us) + moe_layers * (expert_w_us + a2a_us) + pp_hop_us
}

/// The serving autotuner: the training candidate grid, re-gated and
/// re-ranked for decode. Configurations the training tuner admits are
/// pruned here whenever weights + KV cache blow the HBM budget, and the
/// survivors are ordered by [`decode_microstep_us`] — latency, not MFU.
pub fn tune_serving(
    pm: &PerfModel,
    model: &ModelConfig,
    gpus: usize,
    serve: &ServeConfig,
    strategy: Strategy,
) -> ServingTuneResult {
    let cluster = ClusterSpec::eos(gpus);
    let mut evaluated = 0usize;
    let mut oom_count = 0usize;
    let mut candidates = Vec::new();
    for cfg in strategy.candidates(model, gpus) {
        if cfg.validate(model.num_experts, model.num_layers).is_err() {
            continue;
        }
        evaluated += 1;
        let memory = pm.memory.estimate_serving(
            model,
            &cfg,
            serve.precision,
            serve.concurrent_seqs,
            serve.context_len,
        );
        if !memory.fits(serve.hbm_gib, &pm.memory.knobs) {
            oom_count += 1;
            continue;
        }
        let decode_us = decode_microstep_us(model, &cfg, &cluster, serve);
        candidates.push(ServingCandidate { config: cfg, decode_us, memory });
    }
    candidates.sort_by(|a, b| a.decode_us.total_cmp(&b.decode_us));
    let best = candidates.first().cloned();
    ServingTuneResult { strategy, candidates, best, evaluated, oom_count }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::tune;
    use crate::config::TrainConfig;

    #[test]
    fn decode_latency_shape() {
        // The analytic decode model has the shapes the tuner relies on:
        // deeper pipelines and longer contexts are strictly slower, wider
        // TP is faster on the KV term.
        let m = ModelConfig::mixtral_8x22b();
        let cluster = ClusterSpec::eos(128);
        let serve = ServeConfig::default();
        let shallow = ParallelConfig::new(128, 2, 1, 8, 1, 1);
        let deep = ParallelConfig::new(128, 2, 1, 8, 1, 8);
        assert!(
            decode_microstep_us(&m, &deep, &cluster, &serve)
                > decode_microstep_us(&m, &shallow, &cluster, &serve),
            "PP must cost decode latency"
        );
        let long = ServeConfig { context_len: 4 * serve.context_len, ..serve };
        assert!(
            decode_microstep_us(&m, &shallow, &cluster, &long)
                > decode_microstep_us(&m, &shallow, &cluster, &serve),
            "longer context must cost decode latency"
        );
        let wide_tp = ParallelConfig::new(128, 8, 1, 8, 1, 1);
        let narrow_tp = ParallelConfig::new(128, 1, 1, 8, 1, 1);
        assert!(
            decode_microstep_us(&m, &wide_tp, &cluster, &serve)
                < decode_microstep_us(&m, &narrow_tp, &cluster, &serve),
            "TP must shard the KV/weight stream"
        );
    }

    #[test]
    fn prefill_wants_training_optima_decode_does_not() {
        // The headline split: the training tuner's winner is not the
        // serving tuner's winner, and the disagreement is the pipeline
        // depth (throughput loves PP, per-token latency does not).
        let pm = PerfModel::default();
        let m = ModelConfig::mixtral_8x22b();
        let t = TrainConfig::paper_default(4096, 256);
        let train_best = tune(&pm, &m, 128, &t, Strategy::MCoreFolding)
            .best
            .expect("training fixture must be feasible");
        let serve = ServeConfig::default();
        let r = tune_serving(&pm, &m, 128, &serve, Strategy::MCoreFolding);
        let best = r.best.as_ref().expect("serving must find a config");
        assert!(best.config.pp <= train_best.config.pp);
        if train_best.config.pp > 1 {
            assert!(
                best.config.pp < train_best.config.pp,
                "serving kept training's deep pipeline: serve {} vs train {}",
                best.config.tag(),
                train_best.config.tag()
            );
            let cluster = ClusterSpec::eos(128);
            let train_decode = decode_microstep_us(&m, &train_best.config, &cluster, &serve);
            assert!(
                best.decode_us < train_decode,
                "serving winner must beat the training winner on decode latency"
            );
        }
        // Candidates come back latency-sorted.
        assert!(r.candidates.windows(2).all(|w| w[0].decode_us <= w[1].decode_us));
    }

    #[test]
    fn kv_gate_prunes_configs_training_admits() {
        // A config the training memory model happily admits (pinned in
        // model::memory) must vanish from the serving-feasible set once the
        // KV budget (512 seqs x 16K context) enters the estimate.
        let pm = PerfModel::default();
        let m = ModelConfig::mixtral_8x22b();
        let heavy = ParallelConfig::new(128, 2, 1, 4, 2, 8);
        let light = ServeConfig::default();
        let r_light = tune_serving(&pm, &m, 128, &light, Strategy::MCoreFolding);
        assert!(
            r_light.candidates.iter().any(|c| c.config == heavy),
            "fixture config must be serving-feasible at the light working set"
        );
        let heavy_serve =
            ServeConfig { concurrent_seqs: 512, context_len: 16384, ..ServeConfig::default() };
        let r_heavy = tune_serving(&pm, &m, 128, &heavy_serve, Strategy::MCoreFolding);
        assert!(
            r_heavy.candidates.iter().all(|c| c.config != heavy),
            "KV gate failed to prune the training-admitted config"
        );
        assert!(r_heavy.oom_count > r_light.oom_count);
        // The gate prunes, it does not nuke: something still serves.
        let best = r_heavy.best.as_ref().expect("a KV-friendly config must survive");
        assert!(best.memory.kv_cache_bytes > 0.0);
    }
}
