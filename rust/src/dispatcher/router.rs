//! Top-K router with capacity-factor dropping (full-sequence and
//! sub-sequence variants) and dropless mode — paper §3.3.

use crate::config::DropPolicy;
use crate::train::math::softmax_rows;

/// Router configuration.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    pub hidden: usize,
    pub num_experts: usize,
    pub top_k: usize,
    pub capacity_factor: f64,
    pub drop_policy: DropPolicy,
    /// Absolute per-expert capacity override (e.g. to match an AOT
    /// artifact's static bin size exactly). `None` derives from CF.
    pub capacity_override: Option<usize>,
}

/// One routed token-copy: which expert, with what gate weight, and whether
/// it survived the capacity check.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Assignment {
    pub token: usize,
    pub expert: usize,
    pub prob: f32,
    pub kept: bool,
}

/// The routing decision for a batch of tokens.
#[derive(Debug, Clone)]
pub struct RouteDecision {
    /// `n_tokens * top_k` assignments, token-major then k-major.
    pub assignments: Vec<Assignment>,
    pub num_tokens: usize,
    /// Tokens kept per expert (post-drop).
    pub expert_load: Vec<usize>,
    /// Switch-style auxiliary load-balancing loss.
    pub aux_loss: f32,
}

impl RouteDecision {
    pub fn dropped_fraction(&self) -> f64 {
        if self.assignments.is_empty() {
            return 0.0;
        }
        let dropped = self.assignments.iter().filter(|a| !a.kept).count();
        dropped as f64 / self.assignments.len() as f64
    }
}

/// The router: a gating GEMM plus top-k selection and capacity enforcement.
#[derive(Debug, Clone)]
pub struct Router {
    pub config: RouterConfig,
    /// Gating weight, row-major [hidden × num_experts].
    pub weight: Vec<f32>,
    /// Transposed gating weight [num_experts × hidden] — kept alongside so
    /// the gating GEMM runs as contiguous dot products (perf pass §Perf:
    /// 14.2 ms → ~4 ms on the 4096×256 routing benchmark).
    weight_t: Vec<f32>,
}

impl Router {
    pub fn new(config: RouterConfig, weight: Vec<f32>) -> Self {
        assert_eq!(weight.len(), config.hidden * config.num_experts);
        let (h, e) = (config.hidden, config.num_experts);
        let mut weight_t = vec![0.0f32; e * h];
        for r in 0..h {
            for c in 0..e {
                weight_t[c * h + r] = weight[r * e + c];
            }
        }
        Self { config, weight, weight_t }
    }

    pub fn init(config: RouterConfig, rng: &mut crate::util::Rng) -> Self {
        let mut w = vec![0.0; config.hidden * config.num_experts];
        rng.fill_normal(&mut w, (1.0 / config.hidden as f32).sqrt());
        Self::new(config, w)
    }

    /// Softmax gate probabilities for `tokens` [n × hidden] → [n × E].
    /// Uses the cached transposed weight: one contiguous dot product per
    /// (token, expert) pair, which LLVM auto-vectorizes.
    pub fn gate_probs(&self, tokens: &[f32]) -> Vec<f32> {
        let h = self.config.hidden;
        let e = self.config.num_experts;
        let n = tokens.len() / h;
        let mut logits = vec![0.0f32; n * e];
        for t in 0..n {
            let row = &tokens[t * h..(t + 1) * h];
            let out = &mut logits[t * e..(t + 1) * e];
            for (j, o) in out.iter_mut().enumerate() {
                let w = &self.weight_t[j * h..(j + 1) * h];
                // 4 independent accumulator lanes so LLVM can vectorize the
                // reduction (a single f32 chain is order-constrained).
                let mut acc = [0.0f32; 4];
                let chunks = h / 4;
                for c in 0..chunks {
                    let i = c * 4;
                    acc[0] += row[i] * w[i];
                    acc[1] += row[i + 1] * w[i + 1];
                    acc[2] += row[i + 2] * w[i + 2];
                    acc[3] += row[i + 3] * w[i + 3];
                }
                let mut tail = 0.0f32;
                for i in chunks * 4..h {
                    tail += row[i] * w[i];
                }
                *o = acc[0] + acc[1] + acc[2] + acc[3] + tail;
            }
        }
        softmax_rows(&mut logits, n, e);
        logits
    }

    /// Top-k selection with deterministic tie-break (lower expert id wins).
    /// K rounds of (argmax, mask) — no allocation, no sort; k is 1-8 in
    /// every MoE of interest, so this beats sorting E entries per token.
    pub fn topk(&self, probs: &[f32], n: usize) -> Vec<Assignment> {
        let e = self.config.num_experts;
        let k = self.config.top_k.min(e);
        let mut out = Vec::with_capacity(n * k);
        let mut taken = vec![false; e];
        for t in 0..n {
            let row = &probs[t * e..(t + 1) * e];
            taken.iter_mut().for_each(|x| *x = false);
            for _ in 0..k {
                let mut best = usize::MAX;
                let mut best_p = f32::NEG_INFINITY;
                for (j, (&p, &tk)) in row.iter().zip(taken.iter()).enumerate() {
                    if !tk && p > best_p {
                        best = j;
                        best_p = p;
                    }
                }
                taken[best] = true;
                out.push(Assignment { token: t, expert: best, prob: best_p, kept: true });
            }
        }
        out
    }

    /// Apply capacity-factor dropping in place. `scope_tokens` is the number
    /// of tokens over which capacity is computed (the local sub-sequence for
    /// SubSequence mode; the full sequence for FullSequence mode — in that
    /// case assignments from all ranks must be passed jointly).
    pub fn apply_capacity(&self, assignments: &mut [Assignment], scope_tokens: usize) {
        if self.config.drop_policy == DropPolicy::Dropless {
            return;
        }
        let e = self.config.num_experts;
        let k = self.config.top_k.min(e);
        let capacity = self.config.capacity_override.unwrap_or_else(|| {
            ((self.config.capacity_factor * scope_tokens as f64 * k as f64 / e as f64)
                .ceil() as usize)
                .max(1)
        });
        let mut load = vec![0usize; e];
        // Position-based dropping: earlier tokens win (Switch-style).
        for a in assignments.iter_mut() {
            if load[a.expert] < capacity {
                load[a.expert] += 1;
                a.kept = true;
            } else {
                a.kept = false;
            }
        }
    }

    /// Full routing pipeline on a local chunk of tokens.
    pub fn route(&self, tokens: &[f32]) -> RouteDecision {
        let n = tokens.len() / self.config.hidden;
        let probs = self.gate_probs(tokens);
        let mut assignments = self.topk(&probs, n);
        self.apply_capacity(&mut assignments, n);
        let e = self.config.num_experts;
        let mut expert_load = vec![0usize; e];
        for a in &assignments {
            if a.kept {
                expert_load[a.expert] += 1;
            }
        }
        // Switch aux loss: E * Σ_e f_e · P_e, with f_e the fraction of
        // tokens whose top-1 is e and P_e the mean gate prob of e.
        let mut p_mean = vec![0.0f32; e];
        for t in 0..n {
            for (i, pm) in p_mean.iter_mut().enumerate() {
                *pm += probs[t * e + i] / n.max(1) as f32;
            }
        }
        let mut f_top1 = vec![0.0f32; e];
        for t in 0..n {
            let row = &probs[t * e..(t + 1) * e];
            let top = (0..e)
                .max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap().then(b.cmp(&a)))
                .unwrap();
            f_top1[top] += 1.0 / n.max(1) as f32;
        }
        let aux_loss =
            e as f32 * f_top1.iter().zip(&p_mean).map(|(f, p)| f * p).sum::<f32>();
        RouteDecision { assignments, num_tokens: n, expert_load, aux_loss }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn cfg(e: usize, k: usize, cf: f64, policy: DropPolicy) -> RouterConfig {
        RouterConfig {
            hidden: 16,
            num_experts: e,
            top_k: k,
            capacity_factor: cf,
            drop_policy: policy,
            capacity_override: None,
        }
    }

    fn tokens(n: usize, h: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed);
        let mut t = vec![0.0; n * h];
        rng.fill_normal(&mut t, 1.0);
        t
    }

    #[test]
    fn topk_selects_k_distinct() {
        let mut rng = Rng::seed_from_u64(3);
        let r = Router::init(cfg(8, 2, 1.0, DropPolicy::Dropless), &mut rng);
        let t = tokens(32, 16, 5);
        let d = r.route(&t);
        assert_eq!(d.assignments.len(), 64);
        for t_idx in 0..32 {
            let a = &d.assignments[t_idx * 2];
            let b = &d.assignments[t_idx * 2 + 1];
            assert_ne!(a.expert, b.expert);
            assert!(a.prob >= b.prob);
            assert_eq!(a.token, t_idx);
        }
    }

    #[test]
    fn dropless_keeps_everything() {
        let mut rng = Rng::seed_from_u64(4);
        let r = Router::init(cfg(4, 2, 1.0, DropPolicy::Dropless), &mut rng);
        let d = r.route(&tokens(64, 16, 6));
        assert!(d.assignments.iter().all(|a| a.kept));
        assert_eq!(d.dropped_fraction(), 0.0);
        // Load conservation: total kept = n * k.
        assert_eq!(d.expert_load.iter().sum::<usize>(), 128);
    }

    #[test]
    fn capacity_limits_expert_load() {
        let mut rng = Rng::seed_from_u64(5);
        let r = Router::init(cfg(4, 1, 1.0, DropPolicy::SubSequence), &mut rng);
        let d = r.route(&tokens(64, 16, 7));
        let capacity = (1.0 * 64.0 * 1.0 / 4.0_f64).ceil() as usize;
        for (e, &load) in d.expert_load.iter().enumerate() {
            assert!(load <= capacity, "expert {e} load {load} > cap {capacity}");
        }
        // With a skewed router some tokens must drop at CF=1 (near-certain
        // with random gates).
        assert!(d.dropped_fraction() >= 0.0);
    }

    #[test]
    fn higher_cf_drops_less() {
        let mut rng = Rng::seed_from_u64(8);
        let r1 = Router::init(cfg(8, 2, 1.0, DropPolicy::SubSequence), &mut rng);
        let mut r2 = r1.clone();
        r2.config.capacity_factor = 4.0;
        let t = tokens(128, 16, 9);
        let d1 = r1.route(&t);
        let d2 = r2.route(&t);
        assert!(d2.dropped_fraction() <= d1.dropped_fraction());
    }

    #[test]
    fn aux_loss_near_one_for_balanced() {
        // Uniform gates => aux loss ≈ E * Σ (1/E)·(1/E) · ... = 1.
        let config = cfg(4, 1, 1.0, DropPolicy::Dropless);
        let r = Router::new(config, vec![0.0; 16 * 4]); // zero weight => uniform
        let d = r.route(&tokens(256, 16, 10));
        assert!((d.aux_loss - 1.0).abs() < 0.05, "aux {}", d.aux_loss);
    }

    #[test]
    fn deterministic_across_calls() {
        let mut rng = Rng::seed_from_u64(11);
        let r = Router::init(cfg(8, 2, 1.0, DropPolicy::SubSequence), &mut rng);
        let t = tokens(32, 16, 12);
        let d1 = r.route(&t);
        let d2 = r.route(&t);
        assert_eq!(d1.assignments, d2.assignments);
    }
}
