//! Top-K router with capacity-factor dropping (full-sequence and
//! sub-sequence variants), dropless mode, and pluggable load balancing
//! (aux-loss, DeepSeek-V3 aux-loss-free, Sinkhorn) — paper §3.3.

use crate::config::DropPolicy;
use crate::train::math::softmax_rows;

/// Load-balancing strategy. All three share [`argmax_untaken`] for
/// selection, so tied and NaN gates break identically regardless of the
/// balancer, and all three record the **raw** softmax probability as the
/// gate weight — a balancer steers *which* experts are picked, never *how
/// much* each copy contributes to the combine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Balancer {
    /// Plain softmax top-k plus the Switch-style auxiliary loss — the
    /// pre-existing router behaviour and the default.
    AuxLoss,
    /// DeepSeek-V3 aux-loss-free balancing: a per-expert bias
    /// ([`Router::bias`]) is added to the gate score for *selection only*;
    /// [`Router::update_bias`] nudges each bias against the observed load
    /// error by `update_rate` per step. Routing itself stays pure
    /// (`&self`), so distributed replicas and single-rank references see
    /// the same bias and stay bit-identical.
    AuxFree { update_rate: f32 },
    /// Sinkhorn (S-BASE) balancing: `iters` rounds of column/row
    /// normalization turn the gate matrix into a row-stochastic,
    /// approximately column-balanced transport plan
    /// ([`sinkhorn_plan`]); selection runs on the plan.
    Sinkhorn { iters: usize },
}

/// Node-limited routing à la DeepSeek-V3: expert ids are grouped into
/// contiguous blocks of `experts_per_node` (the experts co-located on one
/// node under packed EP placement), and each token may only route to
/// experts inside its `max_nodes` highest-affinity blocks. Bounding the
/// nodes a token's copies span bounds the cross-IB legs of the dispatch
/// all-to-all.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeLimit {
    /// Maximum expert-node groups a token's k copies may span (M).
    pub max_nodes: usize,
    /// Experts per node group (contiguous expert-id blocks).
    pub experts_per_node: usize,
}

/// Router configuration.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    pub hidden: usize,
    pub num_experts: usize,
    pub top_k: usize,
    pub capacity_factor: f64,
    pub drop_policy: DropPolicy,
    /// Absolute per-expert capacity override (e.g. to match an AOT
    /// artifact's static bin size exactly). `None` derives from CF.
    pub capacity_override: Option<usize>,
    /// Pad every expert's dispatched bin with zero rows up to the capacity
    /// (the paper's "drop **with** padding" mode: static shapes, constant
    /// All-to-All volume). Ignored in dropless mode. The padded forward is
    /// bit-identical to the unpadded drop mode — only communication volume
    /// changes ([`crate::dispatcher::DispatchStats::tokens_padded`]).
    pub pad_to_capacity: bool,
    /// Optional node-limited routing ([`NodeLimit`]). `None` routes over
    /// all experts (the default, and the behaviour of every pre-existing
    /// config).
    pub node_limit: Option<NodeLimit>,
    /// Load-balancing strategy ([`Balancer`]). `Balancer::AuxLoss` is the
    /// pre-existing behaviour.
    pub balancer: Balancer,
}

/// One routed token-copy: which expert, with what gate weight, and whether
/// it survived the capacity check.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Assignment {
    pub token: usize,
    pub expert: usize,
    pub prob: f32,
    pub kept: bool,
}

/// The routing decision for a batch of tokens.
#[derive(Debug, Clone)]
pub struct RouteDecision {
    /// `n_tokens * top_k` assignments, token-major then k-major.
    pub assignments: Vec<Assignment>,
    pub num_tokens: usize,
    /// Tokens kept per expert (post-drop).
    pub expert_load: Vec<usize>,
    /// Switch-style auxiliary load-balancing loss.
    pub aux_loss: f32,
    /// Per-expert capacity this decision was dropped against (0 in
    /// dropless mode — no capacity applied). The dispatcher's
    /// pad-to-capacity mode pads every expert bin to exactly this many
    /// rows.
    pub capacity: usize,
}

impl RouteDecision {
    pub fn dropped_fraction(&self) -> f64 {
        if self.assignments.is_empty() {
            return 0.0;
        }
        let dropped = self.assignments.iter().filter(|a| !a.kept).count();
        dropped as f64 / self.assignments.len() as f64
    }
}

/// The router: a gating GEMM plus top-k selection and capacity enforcement.
#[derive(Debug, Clone)]
pub struct Router {
    pub config: RouterConfig,
    /// Gating weight, row-major [hidden × num_experts].
    pub weight: Vec<f32>,
    /// Transposed gating weight [num_experts × hidden] — kept alongside so
    /// the gating GEMM runs as contiguous dot products (perf pass §Perf:
    /// 14.2 ms → ~4 ms on the 4096×256 routing benchmark).
    weight_t: Vec<f32>,
    /// Per-expert selection bias for [`Balancer::AuxFree`] (zeros for the
    /// other balancers, where it is ignored). Mutated only by
    /// [`Self::update_bias`], never inside `route` — so a `Router` clone
    /// shipped to every rank routes bit-identically to the original.
    pub bias: Vec<f32>,
}

impl Router {
    pub fn new(config: RouterConfig, weight: Vec<f32>) -> Self {
        assert_eq!(weight.len(), config.hidden * config.num_experts);
        let (h, e) = (config.hidden, config.num_experts);
        let mut weight_t = vec![0.0f32; e * h];
        for r in 0..h {
            for c in 0..e {
                weight_t[c * h + r] = weight[r * e + c];
            }
        }
        Self { config, weight, weight_t, bias: vec![0.0; e] }
    }

    /// Replace the aux-loss-free selection bias (e.g. with a warmed-up
    /// state); builder-style for test and sweep setup.
    pub fn with_bias(mut self, bias: Vec<f32>) -> Self {
        assert_eq!(bias.len(), self.config.num_experts);
        self.bias = bias;
        self
    }

    /// DeepSeek-V3 aux-loss-free bias step: nudge each expert's selection
    /// bias *against* its observed load error — overloaded experts
    /// (`load > mean`) lose `update_rate`, underloaded ones gain it.
    /// `load` is kept-token counts per expert over whatever scope the
    /// caller balances (a local chunk, or an all-reduced global load —
    /// replicated routers must all be fed the same reduced load to stay
    /// identical). No-op for the other balancers.
    pub fn update_bias(&mut self, load: &[usize]) {
        let Balancer::AuxFree { update_rate } = self.config.balancer else {
            return;
        };
        let e = self.config.num_experts;
        assert_eq!(load.len(), e);
        let mean = load.iter().sum::<usize>() as f64 / e as f64;
        for (b, &l) in self.bias.iter_mut().zip(load) {
            let err = l as f64 - mean;
            if err > 0.0 {
                *b -= update_rate;
            } else if err < 0.0 {
                *b += update_rate;
            }
        }
    }

    pub fn init(config: RouterConfig, rng: &mut crate::util::Rng) -> Self {
        let mut w = vec![0.0; config.hidden * config.num_experts];
        rng.fill_normal(&mut w, (1.0 / config.hidden as f32).sqrt());
        Self::new(config, w)
    }

    /// Softmax gate probabilities for `tokens` [n × hidden] → [n × E].
    /// Uses the cached transposed weight: one contiguous dot product per
    /// (token, expert) pair, which LLVM auto-vectorizes.
    pub fn gate_probs(&self, tokens: &[f32]) -> Vec<f32> {
        let h = self.config.hidden;
        let e = self.config.num_experts;
        let n = tokens.len() / h;
        let mut logits = vec![0.0f32; n * e];
        for t in 0..n {
            let row = &tokens[t * h..(t + 1) * h];
            let out = &mut logits[t * e..(t + 1) * e];
            for (j, o) in out.iter_mut().enumerate() {
                let w = &self.weight_t[j * h..(j + 1) * h];
                // 4 independent accumulator lanes so LLVM can vectorize the
                // reduction (a single f32 chain is order-constrained).
                let mut acc = [0.0f32; 4];
                let chunks = h / 4;
                for c in 0..chunks {
                    let i = c * 4;
                    acc[0] += row[i] * w[i];
                    acc[1] += row[i + 1] * w[i + 1];
                    acc[2] += row[i + 2] * w[i + 2];
                    acc[3] += row[i + 3] * w[i + 3];
                }
                let mut tail = 0.0f32;
                for i in chunks * 4..h {
                    tail += row[i] * w[i];
                }
                *o = acc[0] + acc[1] + acc[2] + acc[3] + tail;
            }
        }
        softmax_rows(&mut logits, n, e);
        logits
    }

    /// Top-k selection with deterministic, NaN-safe tie-break (lower expert
    /// id wins; see [`argmax_untaken`]). K rounds of (argmax, mask) — no
    /// allocation, no sort; k is 1-8 in every MoE of interest, so this beats
    /// sorting E entries per token.
    ///
    /// The configured [`Balancer`] only changes the *selection scores*
    /// (raw probs, bias-shifted probs, or the Sinkhorn plan); the gate
    /// weight recorded in each [`Assignment`] is always the raw softmax
    /// probability of the chosen expert.
    pub fn topk(&self, probs: &[f32], n: usize) -> Vec<Assignment> {
        let e = self.config.num_experts;
        let k = self.config.top_k.min(e);
        let scores: Option<Vec<f32>> = match self.config.balancer {
            Balancer::AuxLoss => None,
            Balancer::AuxFree { .. } => {
                let mut s = probs.to_vec();
                for t in 0..n {
                    for (j, x) in s[t * e..(t + 1) * e].iter_mut().enumerate() {
                        *x += self.bias[j];
                    }
                }
                Some(s)
            }
            Balancer::Sinkhorn { iters } => Some(sinkhorn_plan(probs, n, e, iters)),
        };
        let mut out = Vec::with_capacity(n * k);
        let mut taken = vec![false; e];
        for t in 0..n {
            let row = &probs[t * e..(t + 1) * e];
            let srow = match &scores {
                Some(s) => &s[t * e..(t + 1) * e],
                None => row,
            };
            taken.iter_mut().for_each(|x| *x = false);
            self.ban_out_of_node_experts(row, &mut taken);
            for _ in 0..k {
                let best = argmax_untaken(srow, &taken);
                let p = row[best];
                taken[best] = true;
                out.push(Assignment {
                    token: t,
                    expert: best,
                    // A non-finite gate (all-NaN row fallback) contributes
                    // nothing to the combine instead of poisoning it.
                    prob: if p.is_finite() { p } else { 0.0 },
                    kept: true,
                });
            }
        }
        out
    }

    /// Node-limited pre-selection (DeepSeek-V3 style): rank the contiguous
    /// `experts_per_node` expert groups by summed finite gate affinity,
    /// keep the token's top `max_nodes` groups, and mask every expert
    /// outside them before top-k runs. NaN gates contribute nothing to a
    /// group's affinity, so an all-NaN row degenerates to the lowest-id
    /// groups — matching the argmax fallback top-k already uses. If the
    /// config under-provisions (`max_nodes · experts_per_node < top_k`)
    /// the group budget is widened just enough that selection stays
    /// total. No-op without a `node_limit`.
    fn ban_out_of_node_experts(&self, row: &[f32], taken: &mut [bool]) {
        let Some(nl) = self.config.node_limit else { return };
        let e = self.config.num_experts;
        let k = self.config.top_k.min(e);
        let per = nl.experts_per_node.clamp(1, e);
        let groups = e.div_ceil(per);
        let m = nl.max_nodes.max(1).max(k.div_ceil(per));
        if m >= groups {
            return;
        }
        let mut affinity = vec![0.0f32; groups];
        for (j, &p) in row.iter().enumerate() {
            if p.is_finite() {
                affinity[j / per] += p;
            }
        }
        // M rounds of the shared argmax, so tied and NaN group affinities
        // break exactly like tied expert gates (lower id wins).
        let mut group_taken = vec![false; groups];
        for _ in 0..m {
            let best = argmax_untaken(&affinity, &group_taken);
            group_taken[best] = true;
        }
        for (j, t) in taken.iter_mut().enumerate() {
            if !group_taken[j / per] {
                *t = true;
            }
        }
    }

    /// The per-expert capacity for a `scope_tokens`-token drop scope:
    /// `ceil(cf · scope · k / E)`, or the absolute override.
    pub fn capacity_for(&self, scope_tokens: usize) -> usize {
        let e = self.config.num_experts;
        let k = self.config.top_k.min(e);
        self.config.capacity_override.unwrap_or_else(|| {
            ((self.config.capacity_factor * scope_tokens as f64 * k as f64 / e as f64).ceil()
                as usize)
                .max(1)
        })
    }

    /// Apply capacity-factor dropping in place. `scope_tokens` is the number
    /// of tokens over which capacity is computed (the local sub-sequence for
    /// SubSequence mode; the full sequence for FullSequence mode — in that
    /// case assignments from all ranks must be passed jointly). Returns the
    /// capacity applied (0 in dropless mode).
    pub fn apply_capacity(&self, assignments: &mut [Assignment], scope_tokens: usize) -> usize {
        if self.config.drop_policy == DropPolicy::Dropless {
            return 0;
        }
        let e = self.config.num_experts;
        let capacity = self.capacity_for(scope_tokens);
        let mut load = vec![0usize; e];
        // Position-based dropping: earlier tokens win (Switch-style).
        for a in assignments.iter_mut() {
            if load[a.expert] < capacity {
                load[a.expert] += 1;
                a.kept = true;
            } else {
                a.kept = false;
            }
        }
        capacity
    }

    /// Switch-style auxiliary load-balancing loss over gate `probs`
    /// (`[n × E]`): `E · Σ_e f_e · P_e`, with `f_e` the fraction of tokens
    /// whose top-1 expert is `e` and `P_e` the mean gate probability of `e`.
    ///
    /// The top-1 statistic shares [`argmax_untaken`] with [`Self::topk`], so
    /// `f_top1` counts exactly the expert dispatch would pick — identical
    /// tie-breaks, no panic on NaN gates. Callers with a gathered
    /// full-sequence tensor (full-sequence drop scope) get bit-identical
    /// values on every rank, since the fold order depends only on `probs`.
    pub fn aux_loss(&self, probs: &[f32], n: usize) -> f32 {
        let e = self.config.num_experts;
        let mut p_mean = vec![0.0f32; e];
        for t in 0..n {
            for (i, pm) in p_mean.iter_mut().enumerate() {
                *pm += probs[t * e + i] / n.max(1) as f32;
            }
        }
        let mut f_top1 = vec![0.0f32; e];
        let unmasked = vec![false; e];
        for t in 0..n {
            let row = &probs[t * e..(t + 1) * e];
            let top = argmax_untaken(row, &unmasked);
            f_top1[top] += 1.0 / n.max(1) as f32;
        }
        e as f32 * f_top1.iter().zip(&p_mean).map(|(f, p)| f * p).sum::<f32>()
    }

    /// Full routing pipeline on a local chunk of tokens.
    pub fn route(&self, tokens: &[f32]) -> RouteDecision {
        let n = tokens.len() / self.config.hidden;
        let probs = self.gate_probs(tokens);
        let mut assignments = self.topk(&probs, n);
        let capacity = self.apply_capacity(&mut assignments, n);
        let e = self.config.num_experts;
        let mut expert_load = vec![0usize; e];
        for a in &assignments {
            if a.kept {
                expert_load[a.expert] += 1;
            }
        }
        let aux_loss = self.aux_loss(&probs, n);
        RouteDecision { assignments, num_tokens: n, expert_load, aux_loss, capacity }
    }
}

/// Deterministic, NaN-safe argmax shared by [`Router::topk`] and the aux
/// loss's top-1 statistic ([`Router::aux_loss`]): the highest comparable
/// (non-NaN) probability wins, exact ties break to the **lower** expert id,
/// and a row whose remaining entries are all NaN falls back to the lowest
/// unmasked index, so selection is total and never panics. A single helper
/// guarantees the two call sites can never disagree on tied or NaN gates
/// (which would skew `f_top1` against the actually-dispatched expert).
fn argmax_untaken(row: &[f32], taken: &[bool]) -> usize {
    let mut best = usize::MAX;
    let mut best_p = f32::NEG_INFINITY;
    for (j, (&p, &tk)) in row.iter().zip(taken.iter()).enumerate() {
        if tk || p.is_nan() {
            continue;
        }
        if best == usize::MAX || p > best_p {
            best = j;
            best_p = p;
        }
    }
    if best != usize::MAX {
        best
    } else {
        taken
            .iter()
            .position(|&t| !t)
            .expect("argmax_untaken: no unmasked entry (k > num_experts?)")
    }
}

/// Sinkhorn (S-BASE) normalization of a gate matrix `probs` [n × E]:
/// `iters` rounds of (column-normalize to `n/E`, row-normalize to 1)
/// drive the matrix toward the balanced transport polytope. The sweep
/// always **ends on a row pass**, so every output row sums to exactly 1
/// (up to f32 rounding) while columns converge toward `n/E` as `iters`
/// grows. NaN-safe and deterministic: non-finite or non-positive inputs
/// are zeroed up front, a row that zeroes out entirely (an all-NaN gate
/// row) renormalizes to uniform `1/E` — which selection then breaks to
/// the lowest expert ids, matching [`argmax_untaken`]'s NaN fallback.
/// Column sums accumulate in f64 so large-n scopes don't lose mass.
pub fn sinkhorn_plan(probs: &[f32], n: usize, e: usize, iters: usize) -> Vec<f32> {
    assert_eq!(probs.len(), n * e);
    let mut m: Vec<f32> =
        probs.iter().map(|&p| if p.is_finite() && p > 0.0 { p } else { 0.0 }).collect();
    if n == 0 || e == 0 {
        return m;
    }
    let col_target = n as f64 / e as f64;
    for _ in 0..iters.max(1) {
        for j in 0..e {
            let mut s = 0.0f64;
            for t in 0..n {
                s += m[t * e + j] as f64;
            }
            if s > 0.0 {
                let scale = col_target / s;
                for t in 0..n {
                    m[t * e + j] = (m[t * e + j] as f64 * scale) as f32;
                }
            }
        }
        for t in 0..n {
            let row = &mut m[t * e..(t + 1) * e];
            let s: f64 = row.iter().map(|&x| x as f64).sum();
            if s > 0.0 {
                for x in row.iter_mut() {
                    *x = (*x as f64 / s) as f32;
                }
            } else {
                for x in row.iter_mut() {
                    *x = 1.0 / e as f32;
                }
            }
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn cfg(e: usize, k: usize, cf: f64, policy: DropPolicy) -> RouterConfig {
        RouterConfig {
            hidden: 16,
            num_experts: e,
            top_k: k,
            capacity_factor: cf,
            drop_policy: policy,
            capacity_override: None,
            pad_to_capacity: false,
            node_limit: None,
            balancer: Balancer::AuxLoss,
        }
    }

    fn tokens(n: usize, h: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed);
        let mut t = vec![0.0; n * h];
        rng.fill_normal(&mut t, 1.0);
        t
    }

    #[test]
    fn topk_selects_k_distinct() {
        let mut rng = Rng::seed_from_u64(3);
        let r = Router::init(cfg(8, 2, 1.0, DropPolicy::Dropless), &mut rng);
        let t = tokens(32, 16, 5);
        let d = r.route(&t);
        assert_eq!(d.assignments.len(), 64);
        for t_idx in 0..32 {
            let a = &d.assignments[t_idx * 2];
            let b = &d.assignments[t_idx * 2 + 1];
            assert_ne!(a.expert, b.expert);
            assert!(a.prob >= b.prob);
            assert_eq!(a.token, t_idx);
        }
    }

    #[test]
    fn dropless_keeps_everything() {
        let mut rng = Rng::seed_from_u64(4);
        let r = Router::init(cfg(4, 2, 1.0, DropPolicy::Dropless), &mut rng);
        let d = r.route(&tokens(64, 16, 6));
        assert!(d.assignments.iter().all(|a| a.kept));
        assert_eq!(d.dropped_fraction(), 0.0);
        // Load conservation: total kept = n * k.
        assert_eq!(d.expert_load.iter().sum::<usize>(), 128);
    }

    #[test]
    fn capacity_limits_expert_load() {
        let mut rng = Rng::seed_from_u64(5);
        let r = Router::init(cfg(4, 1, 1.0, DropPolicy::SubSequence), &mut rng);
        let d = r.route(&tokens(64, 16, 7));
        let capacity = (1.0 * 64.0 * 1.0 / 4.0_f64).ceil() as usize;
        for (e, &load) in d.expert_load.iter().enumerate() {
            assert!(load <= capacity, "expert {e} load {load} > cap {capacity}");
        }
        // With a skewed router some tokens must drop at CF=1 (near-certain
        // with random gates).
        assert!(d.dropped_fraction() >= 0.0);
    }

    #[test]
    fn higher_cf_drops_less() {
        let mut rng = Rng::seed_from_u64(8);
        let r1 = Router::init(cfg(8, 2, 1.0, DropPolicy::SubSequence), &mut rng);
        let mut r2 = r1.clone();
        r2.config.capacity_factor = 4.0;
        let t = tokens(128, 16, 9);
        let d1 = r1.route(&t);
        let d2 = r2.route(&t);
        assert!(d2.dropped_fraction() <= d1.dropped_fraction());
    }

    #[test]
    fn aux_loss_near_one_for_balanced() {
        // Uniform gates => aux loss ≈ E * Σ (1/E)·(1/E) · ... = 1.
        let config = cfg(4, 1, 1.0, DropPolicy::Dropless);
        let r = Router::new(config, vec![0.0; 16 * 4]); // zero weight => uniform
        let d = r.route(&tokens(256, 16, 10));
        assert!((d.aux_loss - 1.0).abs() < 0.05, "aux {}", d.aux_loss);
    }

    /// Regression (ISSUE 2): exactly-tied gate probabilities must break to
    /// the lower expert id in *both* top-k dispatch and the aux-loss top-1
    /// statistic — they share one helper, so `f_top1` counts the expert
    /// that was actually dispatched.
    #[test]
    fn tied_gates_break_to_lower_expert_in_topk_and_aux() {
        // Zero gating weight => every expert exactly tied at 1/E.
        let r = Router::new(cfg(8, 2, 1.0, DropPolicy::Dropless), vec![0.0; 16 * 8]);
        let d = r.route(&tokens(16, 16, 3));
        for t in 0..16 {
            assert_eq!(d.assignments[t * 2].expert, 0, "token {t} top-1");
            assert_eq!(d.assignments[t * 2 + 1].expert, 1, "token {t} top-2");
        }
        // With ties resolved consistently, f_top1 = [1, 0, ...] and
        // P_e = 1/8, so aux = 8 * 1 * (1/8) = 1 exactly (up to the mean's
        // accumulation rounding).
        assert!((d.aux_loss - 1.0).abs() < 1e-5, "aux {}", d.aux_loss);
    }

    /// Regression (ISSUE 2): NaN gate logits used to panic in the aux-loss
    /// argmax (`partial_cmp().unwrap()`) and to index out of bounds in
    /// `topk`. Selection must be total and deterministic instead.
    #[test]
    fn nan_gates_select_deterministically_without_panic() {
        let mut rng = Rng::seed_from_u64(21);
        let r = Router::init(cfg(8, 2, 1.0, DropPolicy::SubSequence), &mut rng);
        let mut t = tokens(8, 16, 22);
        // Token 0's features are NaN -> its whole gate row is NaN.
        for x in t[0..16].iter_mut() {
            *x = f32::NAN;
        }
        let d = r.route(&t);
        assert_eq!(d.assignments.len(), 16);
        // All-NaN row: fallback picks the lowest expert ids, zero weight.
        assert_eq!(d.assignments[0].expert, 0);
        assert_eq!(d.assignments[1].expert, 1);
        assert_eq!(d.assignments[0].prob, 0.0);
        assert_eq!(d.assignments[1].prob, 0.0);
        // Healthy tokens are routed normally with finite gates.
        assert!(d.assignments[2..].iter().all(|a| a.prob.is_finite()));
        // The aux statistics still *contain* the NaN probabilities (real
        // training would surface the NaN loss), but selection never panics.
        assert!(d.aux_loss.is_nan());
    }

    /// A partially-NaN row skips the NaN entries rather than letting them
    /// win or aborting the scan.
    #[test]
    fn partial_nan_row_selects_best_finite_gate() {
        let r = Router::new(cfg(4, 1, 1.0, DropPolicy::Dropless), vec![0.0; 16 * 4]);
        let probs = [0.3f32, f32::NAN, 0.5, 0.1];
        let a = r.topk(&probs, 1);
        assert_eq!(a[0].expert, 2);
        assert_eq!(a[0].prob, 0.5);
    }

    /// The decision carries the capacity it was dropped against (the
    /// dispatcher's pad-to-capacity mode pads bins to exactly this).
    #[test]
    fn route_reports_capacity_applied() {
        let mut rng = Rng::seed_from_u64(30);
        let r = Router::init(cfg(4, 2, 1.5, DropPolicy::SubSequence), &mut rng);
        let d = r.route(&tokens(32, 16, 31));
        assert_eq!(d.capacity, r.capacity_for(32));
        assert_eq!(d.capacity, (1.5f64 * 32.0 * 2.0 / 4.0).ceil() as usize);
        let r2 = Router::init(cfg(4, 2, 1.5, DropPolicy::Dropless), &mut rng);
        assert_eq!(r2.route(&tokens(8, 16, 32)).capacity, 0);
    }

    #[test]
    fn deterministic_across_calls() {
        let mut rng = Rng::seed_from_u64(11);
        let r = Router::init(cfg(8, 2, 1.0, DropPolicy::SubSequence), &mut rng);
        let t = tokens(32, 16, 12);
        let d1 = r.route(&t);
        let d2 = r.route(&t);
        assert_eq!(d1.assignments, d2.assignments);
    }

    /// A node limit spanning every group is the unrestricted router,
    /// bit-for-bit.
    #[test]
    fn node_limit_spanning_all_nodes_is_identity() {
        let mut rng = Rng::seed_from_u64(40);
        let mut c = cfg(8, 2, 1.0, DropPolicy::SubSequence);
        let r = Router::init(c, &mut rng);
        c.node_limit = Some(NodeLimit { max_nodes: 4, experts_per_node: 2 });
        let limited = Router::new(c, r.weight.clone());
        let t = tokens(64, 16, 41);
        assert_eq!(r.route(&t).assignments, limited.route(&t).assignments);
    }

    /// With `max_nodes = 1` every token's k copies land inside one
    /// contiguous expert group.
    #[test]
    fn node_limit_confines_copies_to_top_groups() {
        let mut rng = Rng::seed_from_u64(42);
        let mut c = cfg(16, 4, 1.0, DropPolicy::Dropless);
        c.node_limit = Some(NodeLimit { max_nodes: 1, experts_per_node: 4 });
        let r = Router::init(c, &mut rng);
        let d = r.route(&tokens(64, 16, 43));
        for t in 0..64 {
            let group = d.assignments[t * 4].expert / 4;
            for j in 1..4 {
                assert_eq!(d.assignments[t * 4 + j].expert / 4, group, "token {t}");
            }
        }
    }

    /// Group affinity is *summed* gate probability, so a group of several
    /// good experts beats a group holding the single best expert.
    #[test]
    fn node_limit_ranks_groups_by_summed_affinity() {
        let mut c = cfg(4, 1, 1.0, DropPolicy::Dropless);
        c.node_limit = Some(NodeLimit { max_nodes: 1, experts_per_node: 2 });
        let r = Router::new(c, vec![0.0; 16 * 4]);
        // Group 0 = {0.40, 0.05} -> 0.45; group 1 = {0.30, 0.25} -> 0.55.
        // Unrestricted top-1 is expert 0; node-limited picks group 1's
        // best, expert 2.
        let probs = [0.40f32, 0.05, 0.30, 0.25];
        let a = r.topk(&probs, 1);
        assert_eq!(a[0].expert, 2);
        assert_eq!(a[0].prob, 0.30);
    }

    /// An all-NaN gate row under a node limit falls back to the lowest-id
    /// groups and experts without panicking, like the unrestricted router.
    #[test]
    fn node_limit_nan_row_degenerates_to_lowest_groups() {
        let mut c = cfg(8, 2, 1.0, DropPolicy::Dropless);
        c.node_limit = Some(NodeLimit { max_nodes: 1, experts_per_node: 4 });
        let r = Router::new(c, vec![0.0; 16 * 8]);
        let probs = [f32::NAN; 8];
        let a = r.topk(&probs, 1);
        assert_eq!(a[0].expert, 0);
        assert_eq!(a[1].expert, 1);
        assert_eq!(a[0].prob, 0.0);
    }

    /// A zero bias under the aux-loss-free balancer is the plain router,
    /// bit-for-bit — bias only matters once `update_bias` has moved it.
    #[test]
    fn aux_free_zero_bias_matches_plain_router() {
        let mut rng = Rng::seed_from_u64(50);
        let plain = Router::init(cfg(8, 2, 1.0, DropPolicy::SubSequence), &mut rng);
        let mut c = plain.config;
        c.balancer = Balancer::AuxFree { update_rate: 0.1 };
        let free = Router::new(c, plain.weight.clone());
        let t = tokens(64, 16, 51);
        assert_eq!(plain.route(&t).assignments, free.route(&t).assignments);
    }

    /// Bias steers selection but never the gate weight: a bias large
    /// enough to flip the pick still records the flipped expert's *raw*
    /// softmax probability.
    #[test]
    fn aux_free_bias_changes_selection_not_gate_weight() {
        let mut c = cfg(4, 1, 1.0, DropPolicy::Dropless);
        c.balancer = Balancer::AuxFree { update_rate: 0.1 };
        let r = Router::new(c, vec![0.0; 16 * 4]).with_bias(vec![-1.0, 0.0, 2.0, 0.0]);
        // Raw probs favour expert 0; bias +2 on expert 2 flips selection.
        let probs = [0.5f32, 0.2, 0.2, 0.1];
        let a = r.topk(&probs, 1);
        assert_eq!(a[0].expert, 2);
        assert_eq!(a[0].prob, 0.2, "gate weight must stay the raw prob");
    }

    /// `update_bias` lowers overloaded experts' bias and raises
    /// underloaded ones by exactly the update rate, and is a no-op for
    /// the other balancers.
    #[test]
    fn update_bias_moves_against_load_error() {
        let mut c = cfg(4, 1, 1.0, DropPolicy::Dropless);
        c.balancer = Balancer::AuxFree { update_rate: 0.25 };
        let mut r = Router::new(c, vec![0.0; 16 * 4]);
        r.update_bias(&[10, 2, 4, 4]); // mean 5
        assert_eq!(r.bias, vec![-0.25, 0.25, 0.25, 0.25]);
        let mut plain = Router::new(cfg(4, 1, 1.0, DropPolicy::Dropless), vec![0.0; 16 * 4]);
        plain.update_bias(&[10, 2, 4, 4]);
        assert_eq!(plain.bias, vec![0.0; 4], "non-AuxFree balancers ignore updates");
    }

    /// Sinkhorn on an already-balanced (uniform) gate matrix is a fixed
    /// point: selection matches the plain router bit-for-bit.
    #[test]
    fn sinkhorn_uniform_gates_match_plain_selection() {
        let mut c = cfg(8, 2, 1.0, DropPolicy::Dropless);
        c.balancer = Balancer::Sinkhorn { iters: 16 };
        let s = Router::new(c, vec![0.0; 16 * 8]);
        let plain = Router::new(cfg(8, 2, 1.0, DropPolicy::Dropless), vec![0.0; 16 * 8]);
        let t = tokens(32, 16, 52);
        assert_eq!(plain.route(&t).assignments, s.route(&t).assignments);
    }

    /// Sinkhorn selection survives NaN gate rows without panicking: the
    /// sanitized row renormalizes (column passes may steer it toward
    /// underloaded experts — that's the balancer working), selection stays
    /// total and distinct, and the recorded gate weight for the NaN token
    /// is 0 so it contributes nothing to the combine.
    #[test]
    fn sinkhorn_nan_row_selects_without_panic() {
        let mut c = cfg(8, 2, 1.0, DropPolicy::SubSequence);
        c.balancer = Balancer::Sinkhorn { iters: 8 };
        let mut rng = Rng::seed_from_u64(53);
        let r = Router::init(c, &mut rng);
        let mut t = tokens(8, 16, 54);
        for x in t[0..16].iter_mut() {
            *x = f32::NAN;
        }
        let d = r.route(&t);
        assert_eq!(d.assignments.len(), 16);
        assert_ne!(d.assignments[0].expert, d.assignments[1].expert);
        assert_eq!(d.assignments[0].prob, 0.0);
        assert_eq!(d.assignments[1].prob, 0.0);
        assert!(d.assignments[2..].iter().all(|a| a.prob.is_finite()));
    }

    /// Under-provisioned limits (`max_nodes · experts_per_node < top_k`)
    /// widen the group budget instead of running out of experts.
    #[test]
    fn node_limit_widens_when_under_provisioned() {
        let mut c = cfg(8, 4, 1.0, DropPolicy::Dropless);
        c.node_limit = Some(NodeLimit { max_nodes: 1, experts_per_node: 2 });
        let r = Router::new(c, vec![0.0; 16 * 8]);
        let d = r.route(&tokens(16, 16, 44));
        assert_eq!(d.assignments.len(), 64);
        // k=4 over 2-expert groups needs 2 groups; copies span exactly 2.
        for t in 0..16 {
            let mut groups: Vec<usize> =
                (0..4).map(|j| d.assignments[t * 4 + j].expert / 2).collect();
            groups.sort_unstable();
            groups.dedup();
            assert_eq!(groups.len(), 2, "token {t}");
        }
    }
}
