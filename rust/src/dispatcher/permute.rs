//! Token permutation: reorder routed token-copies so that copies bound for
//! the same expert are contiguous (paper §3.1.2 "Token Dispatching"), plus
//! the inverse operation for the combine phase.

use super::router::Assignment;

/// The permutation plan derived from a routing decision: for each kept
/// assignment, where its copy sits in the expert-sorted buffer.
#[derive(Debug, Clone)]
pub struct Permutation {
    /// Sorted order: indices into `assignments` (kept only), grouped by
    /// expert ascending, stable within an expert (token order preserved).
    pub order: Vec<usize>,
    /// Number of kept copies per expert.
    pub counts: Vec<usize>,
    /// Start offset of each expert's segment in the permuted buffer.
    pub offsets: Vec<usize>,
}

impl Permutation {
    /// Build from assignments (only `kept` copies participate).
    pub fn from_assignments(assignments: &[Assignment], num_experts: usize) -> Self {
        let mut counts = vec![0usize; num_experts];
        for a in assignments.iter().filter(|a| a.kept) {
            counts[a.expert] += 1;
        }
        let mut offsets = vec![0usize; num_experts + 1];
        for e in 0..num_experts {
            offsets[e + 1] = offsets[e] + counts[e];
        }
        let mut cursor = offsets.clone();
        let mut order = vec![usize::MAX; offsets[num_experts]];
        for (i, a) in assignments.iter().enumerate() {
            if a.kept {
                order[cursor[a.expert]] = i;
                cursor[a.expert] += 1;
            }
        }
        Self { order, counts, offsets: offsets[..num_experts].to_vec() }
    }

    pub fn total(&self) -> usize {
        self.order.len()
    }

    /// Gather token rows into expert-sorted order.
    /// `tokens` is [n × h]; assignments map copies to source tokens.
    pub fn permute(&self, tokens: &[f32], h: usize, assignments: &[Assignment]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.total() * h];
        for (slot, &ai) in self.order.iter().enumerate() {
            let src = assignments[ai].token;
            out[slot * h..(slot + 1) * h].copy_from_slice(&tokens[src * h..(src + 1) * h]);
        }
        out
    }

    /// Scatter expert outputs back: accumulate `prob`-weighted copies into
    /// each source token's row (the combine/un-permute step).
    pub fn unpermute_accumulate(
        &self,
        expert_out: &[f32],
        h: usize,
        assignments: &[Assignment],
        num_tokens: usize,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; num_tokens * h];
        for (slot, &ai) in self.order.iter().enumerate() {
            let a = assignments[ai];
            let dst = &mut out[a.token * h..(a.token + 1) * h];
            let src = &expert_out[slot * h..(slot + 1) * h];
            for (d, s) in dst.iter_mut().zip(src) {
                *d += a.prob * s;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn asg(token: usize, expert: usize, prob: f32, kept: bool) -> Assignment {
        Assignment { token, expert, prob, kept }
    }

    #[test]
    fn groups_by_expert_stably() {
        let assignments = vec![
            asg(0, 1, 0.5, true),
            asg(0, 0, 0.5, true),
            asg(1, 1, 1.0, true),
            asg(2, 0, 1.0, true),
        ];
        let p = Permutation::from_assignments(&assignments, 2);
        assert_eq!(p.counts, vec![2, 2]);
        assert_eq!(p.offsets, vec![0, 2]);
        // expert 0 segment: assignment idx 1 (token 0) then 3 (token 2).
        assert_eq!(p.order, vec![1, 3, 0, 2]);
    }

    #[test]
    fn dropped_copies_excluded() {
        let assignments = vec![asg(0, 0, 1.0, true), asg(1, 0, 1.0, false)];
        let p = Permutation::from_assignments(&assignments, 1);
        assert_eq!(p.total(), 1);
    }

    #[test]
    fn permute_unpermute_roundtrip_identity_expert() {
        // With an "identity expert" and probs summing to 1 per token, the
        // roundtrip returns the original tokens.
        let h = 4;
        let tokens: Vec<f32> = (0..3 * h).map(|x| x as f32).collect();
        let assignments = vec![
            asg(0, 0, 0.25, true),
            asg(0, 1, 0.75, true),
            asg(1, 1, 1.0, true),
            asg(2, 0, 1.0, true),
        ];
        let p = Permutation::from_assignments(&assignments, 2);
        let permuted = p.permute(&tokens, h, &assignments);
        let restored = p.unpermute_accumulate(&permuted, h, &assignments, 3);
        for (a, b) in tokens.iter().zip(&restored) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn unpermute_weights_by_prob() {
        let h = 1;
        let tokens = vec![2.0f32];
        let assignments = vec![asg(0, 0, 0.3, true), asg(0, 1, 0.7, true)];
        let p = Permutation::from_assignments(&assignments, 2);
        let permuted = p.permute(&tokens, h, &assignments);
        // expert 0 doubles, expert 1 triples.
        let expert_out = vec![permuted[0] * 2.0, permuted[1] * 3.0];
        let out = p.unpermute_accumulate(&expert_out, h, &assignments, 1);
        let expect = 0.3 * 4.0 + 0.7 * 6.0;
        assert!((out[0] - expect).abs() < 1e-6);
    }
}
