//! The flexible token-level MoE dispatcher (paper §3.3): router with
//! token-dropping (full/sub-sequence) and dropless modes plus pluggable
//! load balancing ([`router`]), expert-order permutation ([`permute`]),
//! deterministic skewed-workload generators ([`skewgen`]), and the
//! distributed EP×ETP dispatch workflow over the functional communicator
//! ([`workflow`]).

pub mod permute;
pub mod router;
pub mod skewgen;
pub mod workflow;

pub use permute::Permutation;
pub use router::{
    sinkhorn_plan, Assignment, Balancer, NodeLimit, RouteDecision, Router, RouterConfig,
};
pub use skewgen::{LoadStats, SkewGen, SkewProfile};
pub use workflow::{
    reference_moe_forward, DispatchScratch, DispatchStats, DistributedMoeLayer, MoePhaseCost,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DropPolicy, ParallelConfig};
    use crate::mapping::RuntimeTopology;
    use crate::simcomm::{run_ranks, Payload};
    use crate::train::math::SwigluExpert;
    use crate::util::Rng;

    const H: usize = 16;
    const F: usize = 32;
    const E: usize = 8;

    fn build_router(top_k: usize, policy: DropPolicy, seed: u64) -> Router {
        build_router_padded(top_k, policy, seed, false)
    }

    fn build_router_padded(
        top_k: usize,
        policy: DropPolicy,
        seed: u64,
        pad_to_capacity: bool,
    ) -> Router {
        let mut rng = Rng::seed_from_u64(seed);
        Router::init(
            RouterConfig {
                hidden: H,
                num_experts: E,
                top_k,
                capacity_factor: 1.0,
                drop_policy: policy,
                capacity_override: None,
                pad_to_capacity,
                node_limit: None,
                balancer: Balancer::AuxLoss,
            },
            &mut rng,
        )
    }

    fn build_experts(seed: u64) -> Vec<SwigluExpert> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..E).map(|_| SwigluExpert::init(H, F, &mut rng)).collect()
    }

    fn tokens(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from_u64(seed);
        let mut t = vec![0.0; n * H];
        rng.fill_normal(&mut t, 1.0);
        t
    }

    /// Core equivalence: distributed forward over (ep, etp) == single-rank
    /// reference, for every parallel decomposition of 4 ranks. Every rank's
    /// EP/ETP groups come from the folded runtime topology (MoE grid
    /// `(pp, edp, ep, etp)`, etp fastest), not hand-rolled arithmetic.
    fn check_equivalence(ep: usize, etp: usize, policy: DropPolicy) {
        let world = ep * etp;
        let n_per_rank = 12;
        let router = build_router(2, policy, 100);
        let experts = build_experts(200);
        let all_tokens = tokens(n_per_rank * world, 300);

        let topo =
            RuntimeTopology::folded(ParallelConfig::new(world, 1, 1, ep, etp, 1)).unwrap();
        let outs = run_ranks(world, |rank, comm| {
            let layer =
                DistributedMoeLayer::from_topology(topo.view(rank), router.clone(), &experts);
            let my_tokens =
                all_tokens[rank * n_per_rank * H..(rank + 1) * n_per_rank * H].to_vec();
            layer.forward(&comm, &my_tokens).0
        });

        // Reference applies the drop per rank-sized chunk (sub-sequence
        // scope == per-rank scope).
        let reference = reference_moe_forward(&router, &experts, &all_tokens, Some(n_per_rank));
        let distributed: Vec<f32> = outs.concat();
        assert_eq!(distributed.len(), reference.len());
        for (i, (a, b)) in distributed.iter().zip(&reference).enumerate() {
            assert!(
                (a - b).abs() < 2e-4 * (1.0 + b.abs()),
                "ep={ep} etp={etp} {policy:?}: idx {i}: {a} vs {b}"
            );
        }
    }

    #[test]
    fn equivalence_ep2() {
        check_equivalence(2, 1, DropPolicy::Dropless);
    }

    #[test]
    fn equivalence_ep4() {
        check_equivalence(4, 1, DropPolicy::Dropless);
    }

    #[test]
    fn equivalence_ep8() {
        check_equivalence(8, 1, DropPolicy::Dropless);
    }

    #[test]
    fn equivalence_etp2() {
        check_equivalence(1, 2, DropPolicy::Dropless);
    }

    #[test]
    fn equivalence_ep2_etp2() {
        check_equivalence(2, 2, DropPolicy::Dropless);
    }

    #[test]
    fn equivalence_ep4_etp2() {
        check_equivalence(4, 2, DropPolicy::Dropless);
    }

    #[test]
    fn equivalence_with_subsequence_drop() {
        check_equivalence(2, 1, DropPolicy::SubSequence);
        check_equivalence(4, 2, DropPolicy::SubSequence);
    }

    #[test]
    fn stats_are_populated() {
        let router = build_router(2, DropPolicy::Dropless, 1);
        let experts = build_experts(2);
        let outs = run_ranks(2, |rank, comm| {
            let epr = E / 2;
            let local: Vec<SwigluExpert> =
                experts[rank * epr..(rank + 1) * epr].to_vec();
            let layer = DistributedMoeLayer {
                router: router.clone(),
                local_experts: local,
                ep_group: vec![0, 1],
                etp_group: vec![rank],
                ep_index: rank,
                num_experts: E,
                seq_group: None,
                phase_cost: None,
                overlap_a2a: false,
                payload: Payload::F32,
            };
            layer.forward(&comm, &tokens(8, 40 + rank as u64)).1
        });
        for s in outs {
            assert!(s.a2a_send_bytes > 0);
            assert!(s.a2a_recv_bytes > 0);
            assert_eq!(s.tokens_routed, 16); // 8 tokens * top-2, dropless
            assert_eq!(s.etp_ag_bytes, 0); // etp=1
        }
    }

    #[test]
    fn full_sequence_drop_consistent_across_partitions() {
        // Full-sequence dropping must give the same result no matter how the
        // sequence is split across ranks — that's its defining property.
        let router = build_router(2, DropPolicy::FullSequence, 7);
        let experts = build_experts(8);
        let all_tokens = tokens(16, 9);

        // Reference: full-batch scope.
        let reference = reference_moe_forward(&router, &experts, &all_tokens, None);

        // TP2 attention on 2 ranks makes the topology's sequence block
        // {0, 1}, which is also the EP2 group of the MoE grid.
        let topo = RuntimeTopology::folded(ParallelConfig::new(2, 2, 1, 2, 1, 1)).unwrap();
        let outs = run_ranks(2, |rank, comm| {
            let layer =
                DistributedMoeLayer::from_topology(topo.view(rank), router.clone(), &experts);
            assert_eq!(layer.seq_group.as_deref(), Some(&[0usize, 1][..]));
            let mine = all_tokens[rank * 8 * H..(rank + 1) * 8 * H].to_vec();
            layer.forward(&comm, &mine).0
        });
        let distributed: Vec<f32> = outs.concat();
        for (a, b) in distributed.iter().zip(&reference) {
            assert!((a - b).abs() < 2e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    /// Pad-to-capacity (drop **with** padding): the dispatch a2a carries a
    /// constant per-expert bin of `capacity` rows, the outputs are
    /// bit-identical to the unpadded drop mode (padding is volume, not
    /// math), and the padded volume is exactly what the static-shape
    /// accounting predicts.
    #[test]
    fn pad_to_capacity_constant_volume_bit_identical() {
        let n_per_rank = 16;
        let experts = build_experts(501);
        let all_tokens = tokens(n_per_rank * 4, 502);
        let topo =
            RuntimeTopology::folded(ParallelConfig::new(4, 1, 1, 4, 1, 1)).unwrap();
        let run = |pad: bool| {
            run_ranks(4, |rank, comm| {
                let router = build_router_padded(2, DropPolicy::SubSequence, 500, pad);
                let layer = DistributedMoeLayer::from_topology(
                    topo.view(rank),
                    router,
                    &experts,
                );
                let mine =
                    all_tokens[rank * n_per_rank * H..(rank + 1) * n_per_rank * H].to_vec();
                layer.forward(&comm, &mine)
            })
        };
        let plain = run(false);
        let padded = run(true);
        let router = build_router_padded(2, DropPolicy::SubSequence, 500, true);
        let capacity = router.capacity_for(n_per_rank);
        let epr = E / 4;
        for rank in 0..4 {
            let (po, ps) = (&padded[rank].0, padded[rank].1);
            let (uo, us) = (&plain[rank].0, plain[rank].1);
            assert_eq!(po.len(), uo.len());
            for (i, (a, b)) in po.iter().zip(uo).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "rank {rank} idx {i}: {a} vs {b}");
            }
            // Static volume: 4 peers × (epr counts + epr·capacity·H rows).
            assert_eq!(ps.a2a_send_bytes, 4 * (epr + epr * capacity * H) * 4);
            assert_eq!(
                ps.tokens_padded,
                E * capacity - ps.tokens_routed,
                "rank {rank}: every bin padded to capacity"
            );
            assert!(ps.tokens_padded > 0, "rank {rank}: random gates must underfill");
            assert_eq!(us.tokens_routed, ps.tokens_routed);
        }
    }

    /// Padding composes with ETP sharding and full-sequence dropping.
    #[test]
    fn pad_to_capacity_with_etp_matches_unpadded() {
        let n_per_rank = 8;
        let experts = build_experts(601);
        let all_tokens = tokens(n_per_rank * 4, 602);
        let topo =
            RuntimeTopology::folded(ParallelConfig::new(4, 1, 1, 2, 2, 1)).unwrap();
        let run = |pad: bool| {
            run_ranks(4, |rank, comm| {
                let router = build_router_padded(2, DropPolicy::SubSequence, 600, pad);
                let layer = DistributedMoeLayer::from_topology(
                    topo.view(rank),
                    router,
                    &experts,
                );
                let mine =
                    all_tokens[rank * n_per_rank * H..(rank + 1) * n_per_rank * H].to_vec();
                layer.forward(&comm, &mine).0
            })
        };
        let plain = run(false);
        let padded = run(true);
        for (rank, (a, b)) in padded.iter().zip(&plain).enumerate() {
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "rank {rank} idx {i}");
            }
        }
    }

    #[test]
    fn dropping_caps_tokens_routed() {
        let router = build_router(2, DropPolicy::SubSequence, 11);
        let experts = build_experts(12);
        let outs = run_ranks(2, |rank, comm| {
            let epr = E / 2;
            let layer = DistributedMoeLayer {
                router: router.clone(),
                local_experts: experts[rank * epr..(rank + 1) * epr].to_vec(),
                ep_group: vec![0, 1],
                etp_group: vec![rank],
                ep_index: rank,
                num_experts: E,
                seq_group: None,
                phase_cost: None,
                overlap_a2a: false,
                payload: Payload::F32,
            };
            layer.forward(&comm, &tokens(32, 13 + rank as u64)).1
        });
        for s in outs {
            assert!(s.tokens_routed <= 64);
            assert_eq!(s.tokens_routed + s.tokens_dropped, 64);
        }
    }
}
