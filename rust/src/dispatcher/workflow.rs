//! The distributed MoE layer: the paper's token-dispatcher workflow
//! (§3.3, Figure 2) executed functionally over [`crate::simcomm`].
//!
//! Forward pipeline per rank:
//! 1. route local tokens (sub-sequence or full-sequence drop scope),
//! 2. permute copies into expert order,
//! 3. **All-to-All-V** over the EP group (dispatch),
//! 4. **AllGather-V** over the ETP group,
//! 5. expert FFN shard compute,
//! 6. **ReduceScatter-V** over the ETP group,
//! 7. **All-to-All-V** back (combine),
//! 8. un-permute + gate-weighted accumulate.
//!
//! Dropped tokens contribute zero (the transformer's residual path carries
//! them), exactly like Megatron-Core's `capacity_factor` behaviour.
//!
//! The communication steps run on whichever collective algorithms the
//! communicator selects ([`crate::simcomm::AlgoSelection`]); because every
//! algorithm reduces in rank order, the layer output is bit-identical
//! across selections. The hot path ([`DistributedMoeLayer::forward_with_scratch`])
//! stages all communication through a caller-owned [`DispatchScratch`], so
//! in steady state the collective calls perform **zero payload
//! allocations** (fabric pool + reused staging buffers).

use crate::cluster::GpuSpec;
use crate::config::{DropPolicy, ModelConfig};
use crate::mapping::RankView;
use crate::model::flops::ModelFlops;
use crate::simcomm::{fake_quantize_chunked, Communicator, Payload};
use crate::train::math::SwigluExpert;

use super::permute::Permutation;
use super::router::{Assignment, RouteDecision, Router};

/// Communication volume accounting for one forward (bytes, f32 payloads).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DispatchStats {
    pub a2a_send_bytes: usize,
    pub a2a_recv_bytes: usize,
    pub etp_ag_bytes: usize,
    pub etp_rs_bytes: usize,
    pub tokens_routed: usize,
    pub tokens_dropped: usize,
    /// Zero rows added to the dispatch All-to-All by pad-to-capacity mode
    /// ([`crate::dispatcher::RouterConfig::pad_to_capacity`]); 0 otherwise.
    pub tokens_padded: usize,
    /// Auxiliary load-balancing loss of this forward's routing decision.
    /// Under full-sequence dropping it is computed from the *gathered*
    /// full-sequence statistics, so every rank of the sequence group
    /// reports the bit-identical value.
    pub aux_loss: f32,
    /// On a clocked fabric with the chunk-pipelined dispatcher
    /// ([`DistributedMoeLayer::with_overlap`]): a2a time hidden under
    /// expert GEMM, µs. 0 on unclocked fabrics or the serialized path.
    pub a2a_hidden_us: f64,
    /// Overlapped-path a2a time the compute lane had to wait for, µs.
    pub a2a_exposed_us: f64,
}

/// Per-unit compute charges for the virtual clock's MoE phase tags
/// (µs per token/copy). Built from the model's FLOP accounting
/// ([`crate::model::flops::ModelFlops`]) so the executed timeline charges
/// the *model-scale* compute even when the functional payload is a
/// scaled-down stand-in. Attach with
/// [`DistributedMoeLayer::with_phase_cost`]; without it, clocked forwards
/// record communication time only.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MoePhaseCost {
    /// Router gating, µs per local token.
    pub router_us_per_token: f64,
    /// One permute *or* unpermute pass, µs per routed copy.
    pub permute_us_per_copy: f64,
    /// Expert FFN shard, µs per computed row (post-ETP-gather).
    pub expert_us_per_copy: f64,
}

impl MoePhaseCost {
    /// Charges for `model`'s MoE layer with the expert FFN sharded `etp`
    /// ways, on `gpu` (BF16; efficiency factors mirror the analytic layer
    /// coster's router/expert operating points).
    pub fn from_model(model: &ModelConfig, etp: usize, gpu: &GpuSpec) -> Self {
        let peak = gpu.peak_bf16_tflops * 1e12;
        let hbm = gpu.hbm_bw_gbs * 1e9;
        let router_us_per_token =
            ModelFlops::router_flops_per_token(model) / (peak * 0.2) * 1e6;
        // One gather pass: read + write of an h-wide bf16 row.
        let permute_us_per_copy =
            2.0 * 2.0 * model.hidden_size as f64 / hbm * 1e6;
        let expert_us_per_copy =
            ModelFlops::expert_flops_per_copy(model) / etp.max(1) as f64 / (peak * 0.5) * 1e6;
        Self { router_us_per_token, permute_us_per_copy, expert_us_per_copy }
    }
}

/// Reusable staging buffers for the dispatch hot path. Construct once per
/// rank (e.g. per training loop) and pass to
/// [`DistributedMoeLayer::forward_with_scratch`]; every buffer keeps its
/// capacity between calls, so steady-state dispatch performs no per-call
/// buffer allocation in the communication steps.
#[derive(Default)]
pub struct DispatchScratch {
    /// Per-EP-peer send staging (counts header + token rows).
    sends: Vec<Vec<f32>>,
    /// Per-EP-peer dispatch receive buffers.
    recvs: Vec<Vec<f32>>,
    /// Per-local-expert input rows regrouped from all peers.
    per_expert: Vec<Vec<f32>>,
    /// Per-local-expert outputs after the ETP combine.
    expert_outputs: Vec<Vec<f32>>,
    /// Per-EP-peer combine send staging.
    returns: Vec<Vec<f32>>,
    /// Per-EP-peer combine receive buffers.
    combined: Vec<Vec<f32>>,
    /// ETP row-count exchange buffer.
    lens: Vec<f32>,
    /// ETP element counts derived from `lens`.
    counts: Vec<usize>,
    /// ETP gathered token rows.
    gathered: Vec<f32>,
    /// Expert-sorted combine output rows.
    expert_sorted: Vec<f32>,
    /// Chunk-pipelined path: per-local-expert per-peer dispatch sends.
    chunk_sends: Vec<Vec<Vec<f32>>>,
    /// Chunk-pipelined path: per-local-expert per-peer dispatch receives.
    chunk_recvs: Vec<Vec<Vec<f32>>>,
    /// Chunk-pipelined path: per-local-expert per-peer combine sends.
    chunk_returns: Vec<Vec<Vec<f32>>>,
    /// Chunk-pipelined path: per-local-expert per-peer combine receives.
    chunk_combined: Vec<Vec<Vec<f32>>>,
}

/// One rank's slice of a distributed MoE layer.
pub struct DistributedMoeLayer {
    /// Replicated router (identical weights on every rank).
    pub router: Router,
    /// This rank's expert shards: `num_experts / ep` experts, each holding
    /// a `1/etp` column shard of the FFN.
    pub local_experts: Vec<SwigluExpert>,
    /// Global ranks of this rank's EP group (sorted).
    pub ep_group: Vec<usize>,
    /// Global ranks of this rank's ETP group (sorted).
    pub etp_group: Vec<usize>,
    /// This rank's index within `ep_group`.
    pub ep_index: usize,
    pub num_experts: usize,
    /// Optional sequence group for full-sequence dropping (global ranks that
    /// together hold one full sequence). `None` => sub-sequence scope.
    pub seq_group: Option<Vec<usize>>,
    /// Optional per-phase compute charges for the virtual clock; `None`
    /// leaves clocked forwards with communication time only.
    pub phase_cost: Option<MoePhaseCost>,
    /// Chunk-pipelined dispatch: issue the per-local-expert a2a chunks
    /// nonblocking so later chunks hide under earlier experts' GEMMs
    /// (paper's a2a ⟂ expert-GEMM overlap). Outputs are bit-identical to
    /// the serialized path — only the clock differs. Takes effect when
    /// `ep > 1`, `etp == 1` (the ETP gathers share the comm stream, so
    /// chunking would just queue ahead of them) and there are ≥ 2 local
    /// experts to pipeline.
    pub overlap_a2a: bool,
    /// Wire width of the dispatch/combine All-to-All payloads.
    /// [`Payload::Quantized`] fake-quantizes every token row (per-row
    /// symmetric 1-byte codes, [`crate::simcomm::quant`]) before the a2a
    /// and bills the transport at 1 B/el — count headers stay exact and
    /// f32-billed-as-width like the rows, so routing is untouched and the
    /// byte ratio vs a wider twin is exactly the width ratio. The ETP
    /// gather/scatter and all control traffic keep the ambient width.
    pub payload: Payload,
}

impl DistributedMoeLayer {
    /// Build this rank's layer slice from a runtime-topology view
    /// ([`crate::mapping::RuntimeTopology`]): the EP All-to-All group, the
    /// ETP AllGather/ReduceScatter group, and the sequence-drop scope all
    /// come from the mapping instead of ad-hoc rank arithmetic, and this
    /// rank's expert shards are cut from `global_experts` by its (EP, ETP)
    /// coordinates.
    pub fn from_topology(
        view: &RankView,
        router: Router,
        global_experts: &[SwigluExpert],
    ) -> Self {
        let ep = view.ep_group.len();
        let etp = view.etp_group.len();
        let num_experts = router.config.num_experts;
        assert_eq!(
            global_experts.len(),
            num_experts,
            "one global expert per router expert"
        );
        assert_eq!(num_experts % ep, 0, "num_experts must divide over EP");
        let epr = num_experts / ep;
        let local_experts: Vec<SwigluExpert> = (0..epr)
            .map(|le| {
                let global = view.ep_index * epr + le;
                if etp > 1 {
                    global_experts[global].shard(etp, view.etp_index)
                } else {
                    global_experts[global].clone()
                }
            })
            .collect();
        let seq_group = if view.seq_group.len() > 1 {
            Some(view.seq_group.clone())
        } else {
            None
        };
        Self {
            router,
            local_experts,
            ep_group: view.ep_group.clone(),
            etp_group: view.etp_group.clone(),
            ep_index: view.ep_index,
            num_experts,
            seq_group,
            phase_cost: None,
            overlap_a2a: false,
            payload: Payload::F32,
        }
    }

    /// Attach per-phase compute charges for clocked execution.
    pub fn with_phase_cost(mut self, pc: MoePhaseCost) -> Self {
        self.phase_cost = Some(pc);
        self
    }

    /// Enable the chunk-pipelined (overlapped) dispatch path.
    pub fn with_overlap(mut self, on: bool) -> Self {
        self.overlap_a2a = on;
        self
    }

    /// Select the dispatch/combine a2a wire width (see the `payload` field).
    pub fn with_payload(mut self, p: Payload) -> Self {
        self.payload = p;
        self
    }

    /// Fake-quantize the token rows of an a2a staging buffer in place
    /// (`header` leading f32-encoded count entries are left exact), one
    /// scale per h-wide row so padding zeros and row maxima survive
    /// bit-for-bit.
    fn quantize_rows(&self, buf: &mut [f32], header: usize) {
        if self.payload == Payload::Quantized {
            let h = self.router.config.hidden;
            fake_quantize_chunked(&mut buf[header..], h);
        }
    }

    /// Whether this forward runs the chunk-pipelined dispatch.
    fn overlapped(&self) -> bool {
        self.overlap_a2a
            && self.ep_group.len() > 1
            && self.etp_group.len() == 1
            && self.experts_per_rank() > 1
    }

    pub fn experts_per_rank(&self) -> usize {
        self.num_experts / self.ep_group.len()
    }

    /// Which EP-group index owns `expert`.
    pub fn owner_of(&self, expert: usize) -> usize {
        expert / self.experts_per_rank()
    }

    /// Routing with the configured drop scope.
    fn route(&self, comm: &Communicator, tokens: &[f32]) -> RouteDecision {
        let h = self.router.config.hidden;
        let n_local = tokens.len() / h;
        match (&self.seq_group, self.router.config.drop_policy) {
            (Some(group), DropPolicy::FullSequence) if group.len() > 1 => {
                // Gather gate probabilities across the sequence group so the
                // capacity decision sees the whole sequence. Ranks may hold
                // *uneven* chunks (non-divisible sequence lengths), so this
                // rank's slice offset is derived from the gathered per-rank
                // token counts — never from `my_idx * n_local`.
                let probs_local = self.router.gate_probs(tokens);
                let counts = comm.all_gather_v(group, &[n_local as f32]);
                let gathered = comm.all_gather_v(group, &probs_local);
                let e = self.router.config.num_experts;
                let n_total = gathered.len() / e;
                debug_assert_eq!(
                    counts.iter().map(|&c| c as usize).sum::<usize>(),
                    n_total,
                    "gathered counts must cover the sequence"
                );
                let mut assignments = self.router.topk(&gathered, n_total);
                let capacity = self.router.apply_capacity(&mut assignments, n_total);
                // Aux loss from the full-sequence statistics: every rank
                // folds the same gathered tensor, so the value is
                // bit-identical (replica-consistent) across the group —
                // never the local chunk's statistics.
                let aux_loss = self.router.aux_loss(&gathered, n_total);
                let my_idx = group.iter().position(|&r| r == comm.rank()).unwrap();
                let offset: usize = counts[..my_idx].iter().map(|&c| c as usize).sum();
                let k = self.router.config.top_k.min(e);
                let local: Vec<Assignment> = assignments[offset * k..(offset + n_local) * k]
                    .iter()
                    .map(|a| Assignment { token: a.token - offset, ..*a })
                    .collect();
                let mut expert_load = vec![0usize; e];
                for a in &local {
                    if a.kept {
                        expert_load[a.expert] += 1;
                    }
                }
                RouteDecision {
                    assignments: local,
                    num_tokens: n_local,
                    expert_load,
                    aux_loss,
                    capacity,
                }
            }
            _ => self.router.route(tokens),
        }
    }

    /// Full forward of the MoE layer for this rank's `tokens` [n × h].
    /// Returns (outputs [n × h], stats). Must be called collectively by all
    /// ranks of the EP×ETP block. Convenience wrapper that builds a fresh
    /// [`DispatchScratch`]; loops should hold their own and call
    /// [`Self::forward_with_scratch`].
    pub fn forward(&self, comm: &Communicator, tokens: &[f32]) -> (Vec<f32>, DispatchStats) {
        let mut scratch = DispatchScratch::default();
        self.forward_with_scratch(comm, tokens, &mut scratch)
    }

    /// [`Self::forward`] with caller-owned staging buffers — the zero
    /// per-call-allocation hot path.
    pub fn forward_with_scratch(
        &self,
        comm: &Communicator,
        tokens: &[f32],
        scratch: &mut DispatchScratch,
    ) -> (Vec<f32>, DispatchStats) {
        let h = self.router.config.hidden;
        let n_local = tokens.len() / h;
        let ep = self.ep_group.len();
        let epr = self.experts_per_rank();
        let mut stats = DispatchStats::default();

        // 1-2. Route + permute into expert-sorted order.
        comm.set_phase("moe/router");
        let decision = self.route(comm, tokens);
        if let Some(pc) = self.phase_cost {
            comm.advance("moe/router", pc.router_us_per_token * n_local as f64);
        }
        stats.tokens_routed = decision.assignments.iter().filter(|a| a.kept).count();
        stats.tokens_dropped = decision.assignments.len() - stats.tokens_routed;
        stats.aux_loss = decision.aux_loss;
        let perm = Permutation::from_assignments(&decision.assignments, self.num_experts);
        let permuted = perm.permute(tokens, h, &decision.assignments);
        if let Some(pc) = self.phase_cost {
            comm.advance("moe/permute", pc.permute_us_per_copy * perm.total() as f64);
        }

        // Pad-to-capacity: every expert bin in the dispatch is padded with
        // zero rows up to this rank's capacity (static shapes / constant
        // a2a volume — the paper's "drop with padding"). 0 disables.
        let pad = if self.router.config.pad_to_capacity {
            decision.capacity
        } else {
            0
        };

        // Chunk-pipelined dispatch: per-local-expert a2a chunks issued
        // nonblocking so chunk le+1's transfer hides under expert le's
        // GEMM. Bit-identical outputs; only the clock differs.
        if self.overlapped() {
            self.overlapped_dispatch(comm, scratch, &perm, &permuted, pad, &mut stats);
            let out = perm.unpermute_accumulate(
                &scratch.expert_sorted,
                h,
                &decision.assignments,
                n_local,
            );
            if let Some(pc) = self.phase_cost {
                comm.advance("moe/unpermute", pc.permute_us_per_copy * perm.total() as f64);
            }
            return (out, stats);
        }

        // 3. All-to-All-V dispatch. Send buffer for EP peer p:
        //    [counts for p's epr experts..., token rows...] — rows padded
        //    per expert to `pad` when padding is on.
        comm.set_phase("moe/a2a_dispatch");
        scratch.sends.truncate(ep);
        scratch.sends.resize_with(ep, Vec::new);
        for p in 0..ep {
            let first = p * epr;
            let buf = &mut scratch.sends[p];
            buf.clear();
            for le in 0..epr {
                buf.push(perm.counts[first + le] as f32);
            }
            if pad == 0 {
                let start_off = if first == 0 { 0 } else { perm.offsets[first] };
                let end_off = if first + epr < self.num_experts {
                    perm.offsets[first + epr]
                } else {
                    perm.total()
                };
                buf.extend_from_slice(&permuted[start_off * h..end_off * h]);
            } else {
                for le in 0..epr {
                    let e = first + le;
                    let rows = perm.counts[e];
                    debug_assert!(rows <= pad, "capacity must bound the bin");
                    let s = perm.offsets[e];
                    buf.extend_from_slice(&permuted[s * h..(s + rows) * h]);
                    buf.resize(buf.len() + (pad - rows) * h, 0.0);
                    stats.tokens_padded += pad - rows;
                }
            }
            stats.a2a_send_bytes += buf.len() * 4;
        }
        for buf in scratch.sends.iter_mut() {
            self.quantize_rows(buf, epr);
        }
        let prev = comm.set_payload(self.payload);
        comm.all_to_all_v_into(&self.ep_group, &scratch.sends, &mut scratch.recvs);
        comm.set_payload(prev);

        // Parse: per peer, counts per local expert + rows grouped by expert.
        // Regroup into per-local-expert buffers, preserving peer order so
        // the return path can undo the layout. Only real rows feed the
        // experts — padding is communication volume, not compute.
        scratch.per_expert.truncate(epr);
        scratch.per_expert.resize_with(epr, Vec::new);
        for buf in scratch.per_expert.iter_mut() {
            buf.clear();
        }
        // counts_from[p][le] = rows peer p sent for local expert le;
        // pad_from[p] = peer p's per-expert bin stride (its capacity).
        let mut counts_from = vec![vec![0usize; epr]; ep];
        let mut pad_from = vec![0usize; ep];
        for (p, buf) in scratch.recvs.iter().enumerate() {
            stats.a2a_recv_bytes += buf.len() * 4;
            let mut off = epr;
            for le in 0..epr {
                counts_from[p][le] = buf[le] as usize;
            }
            // Capacities may differ per peer (uneven local token counts);
            // the stride is recovered from the buffer length itself.
            pad_from[p] = if pad == 0 { 0 } else { (buf.len() - epr) / (epr * h) };
            for le in 0..epr {
                let rows = counts_from[p][le];
                scratch.per_expert[le].extend_from_slice(&buf[off..off + rows * h]);
                off += if pad == 0 { rows * h } else { pad_from[p] * h };
            }
        }

        // 4-6. ETP: AllGather-V tokens, compute the FFN shard, then
        // ReduceScatter-V back to each member's rows.
        comm.set_phase("moe/etp");
        let etp = self.etp_group.len();
        scratch.expert_outputs.truncate(epr);
        scratch.expert_outputs.resize_with(epr, Vec::new);
        for le in 0..epr {
            let mine = &scratch.per_expert[le];
            if etp > 1 {
                // Exchange lengths first (AllGather-V of [len]).
                comm.all_gather_v_into(&self.etp_group, &[mine.len() as f32], &mut scratch.lens);
                comm.all_gather_v_into(&self.etp_group, mine, &mut scratch.gathered);
                stats.etp_ag_bytes += scratch.gathered.len() * 4;
                let partial = self.local_experts[le].forward(&scratch.gathered);
                if let Some(pc) = self.phase_cost {
                    let rows = scratch.gathered.len() / h;
                    comm.advance("moe/expert", pc.expert_us_per_copy * rows as f64);
                }
                scratch.counts.clear();
                scratch.counts.extend(scratch.lens.iter().map(|&l| l as usize));
                comm.reduce_scatter_v_into(
                    &self.etp_group,
                    &partial,
                    &scratch.counts,
                    &mut scratch.expert_outputs[le],
                );
                stats.etp_rs_bytes += scratch.expert_outputs[le].len() * 4;
            } else {
                scratch.expert_outputs[le] = self.local_experts[le].forward(mine);
                if let Some(pc) = self.phase_cost {
                    let rows = mine.len() / h;
                    comm.advance("moe/expert", pc.expert_us_per_copy * rows as f64);
                }
            }
        }

        // 7. All-to-All-V combine: send each peer's rows back in the same
        // per-peer-per-expert layout it used (including its padding).
        comm.set_phase("moe/a2a_combine");
        scratch.returns.truncate(ep);
        scratch.returns.resize_with(ep, Vec::new);
        for buf in scratch.returns.iter_mut() {
            buf.clear();
        }
        let mut cursor = vec![0usize; epr];
        for p in 0..ep {
            for le in 0..epr {
                let rows = counts_from[p][le];
                let start = cursor[le];
                scratch.returns[p]
                    .extend_from_slice(&scratch.expert_outputs[le][start * h..(start + rows) * h]);
                cursor[le] += rows;
                if pad != 0 {
                    let r = &mut scratch.returns[p];
                    r.resize(r.len() + (pad_from[p] - rows) * h, 0.0);
                }
            }
        }
        for buf in scratch.returns.iter_mut() {
            self.quantize_rows(buf, 0);
        }
        let prev = comm.set_payload(self.payload);
        comm.all_to_all_v_into(&self.ep_group, &scratch.returns, &mut scratch.combined);
        comm.set_payload(prev);
        comm.clear_phase();

        // Reassemble into the original permuted order: peer p returned rows
        // for the experts it owns, in expert order — which is exactly the
        // contiguous segment we sent it (stride `pad` when padding is on).
        scratch.expert_sorted.clear();
        scratch.expert_sorted.resize(perm.total() * h, 0.0);
        for (p, buf) in scratch.combined.iter().enumerate() {
            let first = p * epr;
            if pad == 0 {
                let start_off = if first == 0 { 0 } else { perm.offsets[first] };
                scratch.expert_sorted[start_off * h..start_off * h + buf.len()]
                    .copy_from_slice(buf);
            } else {
                for le in 0..epr {
                    let e = first + le;
                    let rows = perm.counts[e];
                    let dst = perm.offsets[e];
                    scratch.expert_sorted[dst * h..(dst + rows) * h]
                        .copy_from_slice(&buf[le * pad * h..le * pad * h + rows * h]);
                }
            }
        }

        // 8. Un-permute with gate weighting.
        let out = perm.unpermute_accumulate(
            &scratch.expert_sorted,
            h,
            &decision.assignments,
            n_local,
        );
        if let Some(pc) = self.phase_cost {
            comm.advance("moe/unpermute", pc.permute_us_per_copy * perm.total() as f64);
        }
        (out, stats)
    }

    /// Steps 3–7 of the forward on the **chunk-pipelined** path: the
    /// dispatch a2a is split into one chunk per local expert (chunk `le`
    /// carries every peer's rows for its `le`-th local expert), all chunks
    /// are enqueued nonblocking on the comm lane up front, and expert `le`
    /// computes as soon as *its* chunk lands — later chunks' transfers run
    /// under earlier experts' GEMMs, and each combine chunk returns
    /// nonblocking under the remaining GEMMs. The rows each expert sees,
    /// their order, and the total a2a volume (`epr` count headers + rows)
    /// are identical to the serialized path, so outputs are bit-identical
    /// (property-tested in `prop_invariants.rs`); hidden vs exposed a2a
    /// time is measured per chunk into `stats`.
    fn overlapped_dispatch(
        &self,
        comm: &Communicator,
        scratch: &mut DispatchScratch,
        perm: &Permutation,
        permuted: &[f32],
        pad: usize,
        stats: &mut DispatchStats,
    ) {
        let h = self.router.config.hidden;
        let ep = self.ep_group.len();
        let epr = self.experts_per_rank();
        debug_assert!(self.etp_group.len() == 1, "overlapped path is ETP-1 only");
        let resize3 = |v: &mut Vec<Vec<Vec<f32>>>| {
            v.truncate(epr);
            v.resize_with(epr, Vec::new);
            for inner in v.iter_mut() {
                inner.truncate(ep);
                inner.resize_with(ep, Vec::new);
            }
        };
        resize3(&mut scratch.chunk_sends);
        resize3(&mut scratch.chunk_recvs);
        resize3(&mut scratch.chunk_returns);
        resize3(&mut scratch.chunk_combined);

        // Build every dispatch chunk up front (local staging, free on the
        // clock): [count, rows…, zero-pad to capacity when padding is on].
        for le in 0..epr {
            for p in 0..ep {
                let e = p * epr + le;
                let rows = perm.counts[e];
                let s = perm.offsets[e];
                let buf = &mut scratch.chunk_sends[le][p];
                buf.clear();
                buf.push(rows as f32);
                buf.extend_from_slice(&permuted[s * h..(s + rows) * h]);
                if pad != 0 {
                    debug_assert!(rows <= pad, "capacity must bound the bin");
                    buf.resize(buf.len() + (pad - rows) * h, 0.0);
                    stats.tokens_padded += pad - rows;
                }
                stats.a2a_send_bytes += buf.len() * 4;
                self.quantize_rows(buf, 1); // the count header stays exact
            }
        }

        // Enqueue all dispatch chunks (they queue on the serial comm lane;
        // the payloads move eagerly — only the clock is deferred). Every
        // collective in this region is a dispatch/combine a2a, so the
        // payload width can scope the whole pipelined section.
        let prev_payload = comm.set_payload(self.payload);
        comm.set_phase("moe/a2a_dispatch");
        let mut d_handles = Vec::with_capacity(epr);
        for le in 0..epr {
            d_handles.push(comm.all_to_all_v_into_i(
                &self.ep_group,
                &scratch.chunk_sends[le],
                &mut scratch.chunk_recvs[le],
            ));
        }

        scratch.per_expert.truncate(epr);
        scratch.per_expert.resize_with(epr, Vec::new);
        scratch.expert_outputs.truncate(epr);
        scratch.expert_outputs.resize_with(epr, Vec::new);
        let mut counts_from = vec![vec![0usize; epr]; ep];
        let mut pad_from = vec![vec![0usize; ep]; epr];
        let mut c_handles = Vec::with_capacity(epr);
        for (le, dh) in d_handles.into_iter().enumerate() {
            let (hid, exp) = comm.wait_split(dh);
            stats.a2a_hidden_us += hid;
            stats.a2a_exposed_us += exp;
            // Parse chunk le: one count header + rows per peer, appended
            // in peer order — the same row order the serialized path
            // feeds expert le.
            let mine = &mut scratch.per_expert[le];
            mine.clear();
            for p in 0..ep {
                let buf = &scratch.chunk_recvs[le][p];
                stats.a2a_recv_bytes += buf.len() * 4;
                let cnt = buf[0] as usize;
                counts_from[p][le] = cnt;
                pad_from[le][p] = if pad == 0 { 0 } else { (buf.len() - 1) / h };
                mine.extend_from_slice(&buf[1..1 + cnt * h]);
            }
            // Expert GEMM (ETP = 1 on this path) — the window the
            // remaining chunks' transfers hide under.
            scratch.expert_outputs[le] = self.local_experts[le].forward(&scratch.per_expert[le]);
            if let Some(pc) = self.phase_cost {
                let rows = scratch.per_expert[le].len() / h;
                comm.advance("moe/expert", pc.expert_us_per_copy * rows as f64);
            }
            // Combine chunk le: each peer's rows back in its own layout
            // (including its padding stride), issued nonblocking.
            let mut cursor = 0usize;
            for p in 0..ep {
                let rows = counts_from[p][le];
                let r = &mut scratch.chunk_returns[le][p];
                r.clear();
                r.extend_from_slice(
                    &scratch.expert_outputs[le][cursor * h..(cursor + rows) * h],
                );
                cursor += rows;
                if pad != 0 {
                    r.resize(r.len() + (pad_from[le][p] - rows) * h, 0.0);
                }
                self.quantize_rows(r, 0);
            }
            comm.set_phase("moe/a2a_combine");
            c_handles.push(comm.all_to_all_v_into_i(
                &self.ep_group,
                &scratch.chunk_returns[le],
                &mut scratch.chunk_combined[le],
            ));
            comm.set_phase("moe/a2a_dispatch");
        }

        // Settle the combine chunks and reassemble the permuted order:
        // peer p's chunk le holds this rank's rows for global expert
        // p·epr + le, padded to this rank's own capacity.
        comm.set_phase("moe/a2a_combine");
        scratch.expert_sorted.clear();
        scratch.expert_sorted.resize(perm.total() * h, 0.0);
        for (le, ch) in c_handles.into_iter().enumerate() {
            let (hid, exp) = comm.wait_split(ch);
            stats.a2a_hidden_us += hid;
            stats.a2a_exposed_us += exp;
            for p in 0..ep {
                let e = p * epr + le;
                let rows = perm.counts[e];
                let dst = perm.offsets[e];
                let buf = &scratch.chunk_combined[le][p];
                scratch.expert_sorted[dst * h..(dst + rows) * h]
                    .copy_from_slice(&buf[..rows * h]);
            }
        }
        comm.set_payload(prev_payload);
        comm.clear_phase();
    }
}

/// Single-process reference: the same MoE layer computed without any
/// parallelism (full-width experts). `chunk_tokens` emulates the drop scope:
/// `Some(c)` applies capacity per c-token chunk (sub-sequence semantics of a
/// c-token rank shard); `None` uses the full batch (full-sequence).
pub fn reference_moe_forward(
    router: &Router,
    experts: &[SwigluExpert],
    tokens: &[f32],
    chunk_tokens: Option<usize>,
) -> Vec<f32> {
    let h = router.config.hidden;
    let n = tokens.len() / h;
    let chunk = chunk_tokens.unwrap_or(n).max(1);
    let mut out = vec![0.0f32; n * h];
    for start in (0..n).step_by(chunk) {
        let end = (start + chunk).min(n);
        let slice = &tokens[start * h..end * h];
        let decision = router.route(slice);
        let perm = Permutation::from_assignments(&decision.assignments, router.config.num_experts);
        let permuted = perm.permute(slice, h, &decision.assignments);
        let mut expert_out = vec![0.0f32; perm.total() * h];
        for e in 0..router.config.num_experts {
            let s = perm.offsets[e];
            let cnt = perm.counts[e];
            if cnt == 0 {
                continue;
            }
            let y = experts[e].forward(&permuted[s * h..(s + cnt) * h]);
            expert_out[s * h..(s + cnt) * h].copy_from_slice(&y);
        }
        let o = perm.unpermute_accumulate(&expert_out, h, &decision.assignments, end - start);
        out[start * h..end * h].copy_from_slice(&o);
    }
    out
}
