//! Deterministic skewed-workload generators (ROADMAP item 5): Zipf-over-
//! experts gate skew, domain-shifted popularity phases, and bursty
//! per-step token counts. Every stream is seeded via [`crate::util::Rng`],
//! so the same `(profile, seed)` pair reproduces the same token bytes —
//! which is what lets the skew differential suites compare distributed
//! runs bit-for-bit against single-rank references.
//!
//! The trick that makes gate skew *controllable*: tokens are generated in
//! feature space, but routed through [`gate_weight`] — an identity block
//! embedded in the gating matrix — so a token's first `num_experts`
//! features **are** its gate logits. A boost of [`GATE_BOOST`] on the
//! preferred expert's feature over [`GATE_NOISE_STD`] background noise
//! yields a softmax sharply peaked on the Zipf-drawn expert, while
//! remaining an ordinary `[n × hidden]` f32 token batch any
//! `DistributedMoeLayer` can dispatch.

use super::router::{Router, RouterConfig};
use crate::util::Rng;

/// Logit boost applied to a token's preferred expert over the noise
/// floor: softmax(4 over N(0, 0.5)) puts ~95% of the mass on the
/// preferred expert without saturating f32.
pub const GATE_BOOST: f32 = 4.0;
/// Standard deviation of the background gate-feature noise.
pub const GATE_NOISE_STD: f32 = 0.5;

/// Which skew to impose on the expert-popularity distribution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SkewProfile {
    /// No skew: pure N(0, 1) features, the near-uniform regime every
    /// pre-existing differential suite routes.
    Uniform,
    /// Each token's preferred expert is drawn Zipf(`exponent`) over
    /// expert ids — expert 0 most popular, pmf ∝ 1/(id+1)^exponent.
    Zipf { exponent: f64 },
    /// Zipf popularity whose preferred expert rotates by one position
    /// every `period` emitted tokens — the mid-run domain shift that
    /// breaks any balancer tuned to a static distribution.
    DomainShift { exponent: f64, period: usize },
}

impl SkewProfile {
    /// Short profile name for tables, bench rows, and CLI echo.
    pub fn name(&self) -> &'static str {
        match self {
            SkewProfile::Uniform => "uniform",
            SkewProfile::Zipf { .. } => "zipf",
            SkewProfile::DomainShift { .. } => "shift",
        }
    }

    /// Parse a CLI profile string: `uniform`, `zipf` (exponent 1.2), or
    /// `shift` (exponent 1.2, period 256).
    pub fn parse(s: &str) -> Option<SkewProfile> {
        match s {
            "uniform" => Some(SkewProfile::Uniform),
            "zipf" => Some(SkewProfile::Zipf { exponent: 1.2 }),
            "shift" => Some(SkewProfile::DomainShift { exponent: 1.2, period: 256 }),
            _ => None,
        }
    }
}

/// A seeded stream of skew-gated tokens.
pub struct SkewGen {
    pub profile: SkewProfile,
    num_experts: usize,
    hidden: usize,
    rng: Rng,
    /// Cumulative Zipf distribution over expert ids (empty for Uniform).
    cdf: Vec<f64>,
    /// Tokens emitted so far — drives the DomainShift phase rotation, so
    /// a stream chunked into many `next_tokens` calls shifts exactly like
    /// one generated in a single call.
    emitted: usize,
}

impl SkewGen {
    pub fn new(profile: SkewProfile, num_experts: usize, hidden: usize, seed: u64) -> Self {
        assert!(
            hidden >= num_experts,
            "skewgen embeds gate logits in the first num_experts features"
        );
        let cdf = match profile {
            SkewProfile::Uniform => Vec::new(),
            SkewProfile::Zipf { exponent } | SkewProfile::DomainShift { exponent, .. } => {
                zipf_cdf(num_experts, exponent)
            }
        };
        Self { profile, num_experts, hidden, rng: Rng::seed_from_u64(seed), cdf, emitted: 0 }
    }

    /// The identity gating weight [hidden × num_experts]: expert `j`'s
    /// logit is exactly feature `j`, so the generator controls routing.
    pub fn gate_weight(hidden: usize, num_experts: usize) -> Vec<f32> {
        assert!(hidden >= num_experts);
        let mut w = vec![0.0f32; hidden * num_experts];
        for j in 0..num_experts {
            w[j * num_experts + j] = 1.0;
        }
        w
    }

    /// A router whose gate matrix is the identity embedding for this
    /// generator's dimensions.
    pub fn router(&self, config: RouterConfig) -> Router {
        assert_eq!(config.hidden, self.hidden);
        assert_eq!(config.num_experts, self.num_experts);
        Router::new(config, Self::gate_weight(self.hidden, self.num_experts))
    }

    /// Emit the next `n` tokens of the stream as an `[n × hidden]` batch.
    /// Deterministic in `(profile, seed, call history)`: the same total
    /// prefix of the stream is byte-identical however it is chunked.
    pub fn next_tokens(&mut self, n: usize) -> Vec<f32> {
        let (e, h) = (self.num_experts, self.hidden);
        let mut out = vec![0.0f32; n * h];
        for t in 0..n {
            let row = &mut out[t * h..(t + 1) * h];
            match self.profile {
                SkewProfile::Uniform => {
                    for x in row.iter_mut() {
                        *x = self.rng.next_normal_f32();
                    }
                }
                SkewProfile::Zipf { .. } | SkewProfile::DomainShift { .. } => {
                    for x in row.iter_mut() {
                        *x = GATE_NOISE_STD * self.rng.next_normal_f32();
                    }
                    let mut preferred = draw_cdf(&self.cdf, self.rng.next_f64());
                    if let SkewProfile::DomainShift { period, .. } = self.profile {
                        preferred = (preferred + self.emitted / period.max(1)) % e;
                    }
                    row[preferred] += GATE_BOOST;
                }
            }
            self.emitted += 1;
        }
        out
    }

    /// Deterministic bursty per-step token counts: a baseline of `base`
    /// tokens (± up to 1/8 jitter) with a burst to `peak` for the first
    /// quarter of every `period` steps. Every count is ≥ 1.
    pub fn burst_schedule(
        seed: u64,
        steps: usize,
        base: usize,
        peak: usize,
        period: usize,
    ) -> Vec<usize> {
        let mut rng = Rng::seed_from_u64(seed ^ 0xB0057);
        let period = period.max(1);
        (0..steps)
            .map(|s| {
                let level = if s % period < period.div_ceil(4) { peak } else { base };
                let jitter = level / 8;
                let n = if jitter > 0 {
                    level - jitter + rng.next_below(2 * jitter + 1)
                } else {
                    level
                };
                n.max(1)
            })
            .collect()
    }
}

/// Cumulative Zipf(`s`) distribution over `e` ranks: pmf ∝ 1/(i+1)^s.
fn zipf_cdf(e: usize, s: f64) -> Vec<f64> {
    let weights: Vec<f64> = (0..e).map(|i| 1.0 / ((i + 1) as f64).powf(s)).collect();
    let total: f64 = weights.iter().sum();
    let mut acc = 0.0;
    weights
        .iter()
        .map(|w| {
            acc += w / total;
            acc
        })
        .collect()
}

/// Inverse-CDF draw: first index whose cumulative mass covers `r`.
fn draw_cdf(cdf: &[f64], r: f64) -> usize {
    cdf.iter().position(|&c| r < c).unwrap_or(cdf.len() - 1)
}

/// Expert-load summary statistics shared by the sweep, the trainer probe,
/// and the imbalance pins: max/mean kept load and normalized entropy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadStats {
    /// max(load) / mean(load); 1.0 is perfectly balanced.
    pub imbalance: f64,
    /// Shannon entropy of the load distribution normalized by ln(E);
    /// 1.0 is perfectly balanced, 0.0 is all load on one expert.
    pub entropy: f64,
}

impl LoadStats {
    /// An all-zero (or empty) load carries no balance information: calling
    /// it "perfectly balanced" would let empty decode microsteps dilute
    /// probe/serving averages toward 1.0. Both fields are NaN for such
    /// loads; aggregation sites must skip NaN samples (see
    /// [`Self::is_empty`]).
    pub fn from_load(load: &[usize]) -> LoadStats {
        let e = load.len().max(1);
        let total: usize = load.iter().sum();
        if total == 0 {
            return LoadStats { imbalance: f64::NAN, entropy: f64::NAN };
        }
        if e == 1 {
            return LoadStats { imbalance: 1.0, entropy: 1.0 };
        }
        let mean = total as f64 / e as f64;
        let max = *load.iter().max().unwrap() as f64;
        let mut h = 0.0f64;
        for &l in load {
            if l > 0 {
                let p = l as f64 / total as f64;
                h -= p * p.ln();
            }
        }
        LoadStats { imbalance: max / mean, entropy: h / (e as f64).ln() }
    }

    /// True for the NaN sentinel of an all-zero load (no routed tokens).
    pub fn is_empty(&self) -> bool {
        self.imbalance.is_nan()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DropPolicy;
    use crate::dispatcher::Balancer;

    fn base_cfg(e: usize, h: usize) -> RouterConfig {
        RouterConfig {
            hidden: h,
            num_experts: e,
            top_k: 1,
            capacity_factor: 1.0,
            drop_policy: DropPolicy::Dropless,
            capacity_override: None,
            pad_to_capacity: false,
            node_limit: None,
            balancer: Balancer::AuxLoss,
        }
    }

    #[test]
    fn zipf_stream_is_seed_deterministic_and_chunk_invariant() {
        let profile = SkewProfile::Zipf { exponent: 1.2 };
        let mut a = SkewGen::new(profile, 8, 16, 77);
        let mut b = SkewGen::new(profile, 8, 16, 77);
        let whole = a.next_tokens(64);
        let mut chunked = b.next_tokens(20);
        chunked.extend(b.next_tokens(44));
        assert_eq!(whole, chunked, "chunking must not change the stream");
        let mut c = SkewGen::new(profile, 8, 16, 78);
        assert_ne!(whole, c.next_tokens(64), "different seed, different stream");
    }

    #[test]
    fn zipf_top1_concentrates_on_expert_zero() {
        let mut g = SkewGen::new(SkewProfile::Zipf { exponent: 1.2 }, 8, 16, 5);
        let router = g.router(base_cfg(8, 16));
        let d = router.route(&g.next_tokens(2048));
        let s = LoadStats::from_load(&d.expert_load);
        assert!(s.imbalance > 1.8, "zipf load should be skewed, got {}", s.imbalance);
        let top: usize = d.expert_load[0];
        assert!(
            top > d.expert_load[7] * 3,
            "expert 0 ({top}) should dwarf expert 7 ({})",
            d.expert_load[7]
        );
    }

    #[test]
    fn domain_shift_rotates_preferred_expert() {
        let profile = SkewProfile::DomainShift { exponent: 2.0, period: 128 };
        let mut g = SkewGen::new(profile, 8, 16, 9);
        let router = g.router(base_cfg(8, 16));
        // Phase 0: popularity peaks at expert 0; phase 1 (after `period`
        // tokens): the whole ranking rotates by one.
        let d0 = router.route(&g.next_tokens(128));
        let d1 = router.route(&g.next_tokens(128));
        let peak0 = d0.expert_load.iter().enumerate().max_by_key(|(_, &l)| l).unwrap().0;
        let peak1 = d1.expert_load.iter().enumerate().max_by_key(|(_, &l)| l).unwrap().0;
        assert_eq!(peak0, 0);
        assert_eq!(peak1, 1, "phase 1 must rotate the popular expert");
    }

    #[test]
    fn uniform_profile_stays_near_balanced() {
        let mut g = SkewGen::new(SkewProfile::Uniform, 8, 16, 13);
        let router = g.router(base_cfg(8, 16));
        let d = router.route(&g.next_tokens(4096));
        let s = LoadStats::from_load(&d.expert_load);
        assert!(s.imbalance < 1.5, "uniform stream imbalance {}", s.imbalance);
        assert!(s.entropy > 0.95, "uniform stream entropy {}", s.entropy);
    }

    #[test]
    fn burst_schedule_is_deterministic_and_bounded() {
        let a = SkewGen::burst_schedule(3, 64, 32, 128, 8);
        let b = SkewGen::burst_schedule(3, 64, 32, 128, 8);
        assert_eq!(a, b);
        assert_eq!(a.len(), 64);
        assert!(a.iter().all(|&n| n >= 1));
        let max = *a.iter().max().unwrap();
        let min = *a.iter().min().unwrap();
        assert!(max > 100, "burst steps should approach the peak, max {max}");
        assert!(min < 64, "baseline steps should stay near base, min {min}");
        // Bursts occupy the first quarter of each period.
        assert!(a[0] > a[4], "step 0 bursts, step 4 does not");
    }

    #[test]
    fn load_stats_extremes() {
        let balanced = LoadStats::from_load(&[10, 10, 10, 10]);
        assert!((balanced.imbalance - 1.0).abs() < 1e-12);
        assert!((balanced.entropy - 1.0).abs() < 1e-12);
        let collapsed = LoadStats::from_load(&[40, 0, 0, 0]);
        assert!((collapsed.imbalance - 4.0).abs() < 1e-12);
        assert!(collapsed.entropy.abs() < 1e-12);
    }

    /// Regression (ISSUE 10 satellite): an all-zero load used to report
    /// `{imbalance: 1.0, entropy: 1.0}` — "perfectly balanced" — so empty
    /// decode microsteps silently pulled stream averages toward 1.0. It
    /// must be the NaN sentinel, and a mixed empty/non-empty stream's
    /// NaN-skipping mean must equal the mean over the non-empty steps only.
    #[test]
    fn all_zero_load_is_nan_sentinel_not_balanced() {
        let empty = LoadStats::from_load(&[0, 0, 0, 0]);
        assert!(empty.imbalance.is_nan());
        assert!(empty.entropy.is_nan());
        assert!(empty.is_empty());
        assert!(LoadStats::from_load(&[]).is_empty());
        // Single-expert loads with actual tokens stay legitimately balanced.
        let single = LoadStats::from_load(&[17]);
        assert!((single.imbalance - 1.0).abs() < 1e-12);
        assert!(!single.is_empty());

        // Mixed stream: two skewed steps and two empty ones.
        let steps: [&[usize]; 4] = [&[30, 10, 0, 0], &[0, 0, 0, 0], &[10, 10, 10, 10], &[0; 4]];
        let stats: Vec<LoadStats> = steps.iter().map(|l| LoadStats::from_load(l)).collect();
        let valid: Vec<&LoadStats> = stats.iter().filter(|s| !s.is_empty()).collect();
        assert_eq!(valid.len(), 2, "the two empty steps must be skipped");
        let mean_imb: f64 =
            valid.iter().map(|s| s.imbalance).sum::<f64>() / valid.len() as f64;
        let expected = (3.0 + 1.0) / 2.0; // [30,10,0,0] -> 3.0, balanced -> 1.0
        assert!((mean_imb - expected).abs() < 1e-12, "got {mean_imb}");
        // The pre-fix behaviour would have produced (3 + 1 + 1 + 1) / 4 = 1.5.
        let diluted: f64 = stats
            .iter()
            .map(|s| if s.imbalance.is_nan() { 1.0 } else { s.imbalance })
            .sum::<f64>()
            / stats.len() as f64;
        assert!((diluted - 1.5).abs() < 1e-12, "sanity: the old bug diluted to 1.5");
    }
}
