//! `moe-folding` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   plan       auto-tune a parallel mapping for a model + GPU budget
//!   mapping    print the folded/legacy process groups for a config
//!   table1..5  regenerate the paper's tables
//!   fig5/fig6  MoE-layer breakdown ablations
//!   train      run the end-to-end trainer on AOT artifacts
//!   artifacts  list artifacts in the manifest

use moe_folding::autotune::{self, Constraints};
use moe_folding::cluster::ClusterSpec;
use moe_folding::config::{
    DropPolicy, EpPlacement, ModelConfig, ParallelConfig, Precision, TrainConfig,
};
use moe_folding::coordinator::{self, RoutingPolicy};
use moe_folding::dispatcher::{Balancer, SkewProfile};
use moe_folding::mapping::{ParallelMapping, RuntimeTopology};
use moe_folding::perfmodel::{execute_step_traced, PerfModel, Strategy};
use moe_folding::serving;
use moe_folding::simcomm::chrome_trace_json;
use moe_folding::train::{train, MoeProbe, TrainerConfig};
use moe_folding::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "moe-folding {} — MoE Parallel Folding reproduction

USAGE: moe-folding <command> [options]

COMMANDS:
  plan      --model <name> --gpus <n> [--strategy <s>] [--fp8]
            [--tp N --cp N --ep N --etp N --pp N --vpp N]
            [--hbm GIB]   per-rank HBM budget: candidates that don't fit are
                          rejected; the per-rank GiB estimate is printed
            [--executed [--top K]]   re-rank the analytic top-K (default 5,
                                     uncapped — pass the feasible-list size
                                     for a full re-rank) by executing each
                                     step (overlapped + serialized twin) on
                                     the event-driven clocked simulator
  timeline  --model <name> --gpus <n> --tp N --cp N --ep N --etp N --pp N
            [--vpp N] [--placement packed|strided] [--no-overlap]
            [--overlap-a2a] [--fp8] [--strategy <s>]
            [--seq N] [--gbs N] [--out trace.json]
            execute one step on the clocked simulator and dump a
            chrome-trace JSON (load at chrome://tracing or ui.perfetto.dev;
            rows per rank: main lane, comm lane, grad-sync lane; cp > 1
            shows each ring-attention KV step as an `attn/cp_ring` span
            hidden under the `attn/core` chunks; --placement strided lands
            EP groups across node boundaries to price the placement axis)
  mapping   --gpus <n> --tp N --cp N --ep N --etp N --pp N [--legacy] [--rank R]
  table1 | table2 | table3 | table4 | table5
  table1    [--executed [--max-gpus N]]   per-model MFU; --executed runs each
            folded winner on the clocked simulator (analytic vs sim MFU)
  table2    [--executed]   BF16 vs FP8 on Mixtral 8x22B @128; --executed
            measures the fp8 speedup on the clocked simulator (quantized
            a2a payloads, fp8 GEMM peaks, cast/amax passes — 1.26-1.30x)
  table4    [--executed [--max-gpus N]]   GPU scaling; --executed runs each
            tuned winner (and its strided-EP twin) on the clocked simulator
  table5    [--executed [--max-gpus N]]   context scaling, both models;
            --executed runs each tuned point on the clocked simulator
  fig3      [--model <name>] [--executed [--max-gpus N]]
            strong scaling over the paper's per-model GPU counts;
            --executed adds measured MFU/step plus the strided-EP twin
  fig5      [--model <name>] [--ep-etp 8|16]
            [--executed [--tokens N] [--overlap]
             [--skew uniform|zipf|shift] [--cf F]
             [--policy dropless|drop|pad] [--balancer aux|aux-free|sinkhorn]]
            --overlap runs the chunk-pipelined dispatcher and splits the
            measured a2a into hidden vs exposed; the policy knobs price
            drop/pad capacity policies under skewed gate streams (the
            trailing Drop % / A2A MB columns are the cost triangle)
  sweep-capacity  [--model <name>] [--ep N] [--tokens N]
            [--skew uniform|zipf|shift] [--cfs 1.0,1.5,2.0] [--seed S]
            executed capacity-factor × {dropless,drop,pad} × balancer
            sweep under one skew profile: drop rate, a2a MB, step µs,
            and load-balance quality per cell on the clocked fabric
            (--seed reseeds expert weights and gate streams; the default
            reproduces the historical sweep bit-for-bit)
  serve     [--model <name>] [--gpus <n>] [--seqs N] [--ctx N] [--fp8]
            [--hbm GIB]   serving autotuner: training candidate grids
            re-gated by weights + KV cache (no optimizer states) and
            ranked by analytic decode latency — prints the serving
            winner next to the training winner per strategy
            [--replay [--world N] [--requests N] [--prefill N] [--decode N]
             [--mean-gap-us F | --diurnal] [--skew uniform|zipf|shift]
             [--seed S] [--no-placement]]
            replays seeded arrivals through continuous batching on the
            clocked fabric (prefill step + single-token decode
            microsteps): p50/p99 token latency, tokens/s/GPU, and the
            metered IB bytes of packed vs histogram-optimized expert
            placement
  fig4      [--model <name>] [--executed [--max-gpus N]]
            context scaling (Figure 4 / Table 5, one model); --executed
            runs each tuned point on the clocked simulator and adds
            measured MFU + CP ring hidden/exposed columns
  fig6      [--model <name>] [--executed [--gpus N]]
            --executed runs the folded CP sweep on the clocked simulator:
            executed vs analytic step time and the measured hidden/exposed
            split of the ring-attention KV exchange
  train     [--preset test|e2e] [--steps N] [--dp N] [--lr F] [--artifacts DIR]
            [--clocked [--compute-us F] [--overlap]]  measured-in-sim step
            time; --overlap issues grad reduces nonblocking under backward
            [--moe-probe [--moe-skew uniform|zipf|shift] [--moe-tokens N]
             [--moe-experts N] [--cf F] [--policy dropless|drop|pad]
             [--balancer aux|aux-free|sinkhorn] [--bursty]]
            routes a skewed gate stream alongside each step and reports
            drop rate, capacity violations, and load-balance quality
  artifacts [--dir DIR]

MODELS: mixtral-8x22b, llama3-8x70b, qwen2-57b-a14b, mixtral-8x22b-g8t8, tiny
STRATEGIES: fsdp, fsdp-ep, tp-ep-dp, mcore, folding (default)",
        moe_folding::VERSION
    );
    std::process::exit(2);
}

fn parse_strategy(s: &str) -> Strategy {
    match s {
        "fsdp" => Strategy::Fsdp,
        "fsdp-ep" => Strategy::FsdpEp,
        "tp-ep-dp" => Strategy::TpEpDp,
        "mcore" => Strategy::MCore,
        "folding" | "mcore-folding" => Strategy::MCoreFolding,
        _ => {
            eprintln!("unknown strategy {s}");
            std::process::exit(2);
        }
    }
}

fn parse_balancer(s: &str) -> Balancer {
    match s {
        "aux" | "aux-loss" => Balancer::AuxLoss,
        "aux-free" => Balancer::AuxFree { update_rate: 0.05 },
        "sinkhorn" => Balancer::Sinkhorn { iters: 32 },
        _ => {
            eprintln!("unknown balancer {s} (want aux|aux-free|sinkhorn)");
            std::process::exit(2);
        }
    }
}

fn parse_policy(s: &str) -> (DropPolicy, bool) {
    match s {
        "dropless" => (DropPolicy::Dropless, false),
        "drop" => (DropPolicy::SubSequence, false),
        "pad" => (DropPolicy::SubSequence, true),
        _ => {
            eprintln!("unknown policy {s} (want dropless|drop|pad)");
            std::process::exit(2);
        }
    }
}

fn parse_skew(s: &str) -> SkewProfile {
    SkewProfile::parse(s).unwrap_or_else(|| {
        eprintln!("unknown skew profile {s} (want uniform|zipf|shift)");
        std::process::exit(2);
    })
}

fn model_arg(args: &Args, default: &str) -> ModelConfig {
    let name = args.get_or("model", default);
    ModelConfig::by_name(name).unwrap_or_else(|| {
        eprintln!("unknown model {name}");
        std::process::exit(2);
    })
}

fn main() -> moe_folding::util::error::Result<()> {
    let args = Args::parse();
    let Some(cmd) = args.positional.first().map(|s| s.as_str()) else {
        usage()
    };
    let pm = PerfModel::default();

    match cmd {
        "plan" => {
            let model = model_arg(&args, "mixtral-8x22b");
            let gpus = args.get_usize("gpus", 128);
            let strategy = parse_strategy(args.get_or("strategy", "folding"));
            let mut train_cfg = TrainConfig::paper_default(
                args.get_usize("seq", model.seq_len),
                args.get_usize("gbs", 256),
            );
            if args.flag("fp8") {
                train_cfg.precision = Precision::Fp8;
            }
            let cons = Constraints {
                tp: args.get("tp").map(|v| v.parse().unwrap()),
                cp: args.get("cp").map(|v| v.parse().unwrap()),
                ep: args.get("ep").map(|v| v.parse().unwrap()),
                etp: args.get("etp").map(|v| v.parse().unwrap()),
                pp: args.get("pp").map(|v| v.parse().unwrap()),
                vpp: args.get("vpp").map(|v| v.parse().unwrap()),
                hbm_gib: args.get("hbm").map(|v| v.parse().unwrap()),
            };
            let r = coordinator::plan(&pm, &model, gpus, &train_cfg, strategy, cons);
            println!(
                "# {} | {} | {} GPUs | {} candidates evaluated, {} OOM (budget {:.0} GiB/rank)",
                model.name,
                strategy.name(),
                gpus,
                r.evaluated,
                r.oom_count,
                cons.hbm_gib.unwrap_or(80.0)
            );
            if let Some(best) = &r.best {
                let gib = (1u64 << 30) as f64;
                println!(
                    "per-rank memory at the optimum: {:.1} GiB (params {:.1} + grads {:.1} \
                     + optimizer {:.1} + activations {:.1} + transient/overhead {:.1} GiB)",
                    best.memory.total_gib(),
                    best.memory.param_bytes / gib,
                    best.memory.grad_bytes / gib,
                    best.memory.optim_bytes / gib,
                    best.memory.activation_bytes / gib,
                    (best.memory.transient_bytes + best.memory.overhead_bytes) / gib,
                );
            }
            for e in r.feasible.iter().take(args.get_usize("top", 10)) {
                println!("{}", e.summary());
            }
            if r.feasible.is_empty() {
                println!("no feasible configuration (all OOM)");
            }
            if args.flag("executed") {
                // No cap on K: the event engine executes each candidate
                // single-threaded, so re-ranking the full feasible list at
                // paper scale is tier-1-cheap (ROADMAP item 2).
                let k = args.get_usize("top", 5);
                let ex = autotune::tune_executed(&pm, &model, gpus, &train_cfg, strategy, k);
                println!(
                    "\n# executed re-rank (top {k} analytic candidates, clocked simulator){}",
                    if ex.rank_changed { " — ORDER CHANGED" } else { "" }
                );
                for c in &ex.candidates {
                    println!(
                        "{}   (analytic {:8.1} ms, {}, {})",
                        c.executed.summary(),
                        c.analytic.step_ms,
                        if c.overlap { "overlapped" } else { "serialized" },
                        c.precision.name()
                    );
                }
            }
        }
        "timeline" => {
            let model = model_arg(&args, "mixtral-8x22b");
            let gpus = args.get_usize("gpus", 128);
            let cfg = ParallelConfig::new(
                gpus,
                args.get_usize("tp", 2),
                args.get_usize("cp", 1),
                args.get_usize("ep", 8),
                args.get_usize("etp", 1),
                args.get_usize("pp", 8),
            )
            .with_vpp(args.get_usize("vpp", 1));
            let cfg = match args.get_or("placement", "packed") {
                "packed" => cfg,
                "strided" => cfg.with_placement(EpPlacement::Strided),
                other => {
                    eprintln!("unknown placement {other} (want packed|strided)");
                    std::process::exit(2);
                }
            };
            let strategy = parse_strategy(args.get_or("strategy", "folding"));
            let mut train_cfg = TrainConfig::paper_default(
                args.get_usize("seq", model.seq_len),
                args.get_usize("gbs", 256),
            );
            if args.flag("no-overlap") {
                train_cfg.overlap_grad_reduce = false;
                train_cfg.overlap_param_gather = false;
            }
            train_cfg.overlap_a2a = args.flag("overlap-a2a");
            if args.flag("fp8") {
                train_cfg.precision = Precision::Fp8;
            }
            let (est, trace) =
                execute_step_traced(&pm, &model, cfg, &train_cfg, strategy)
                    .map_err(|e| moe_folding::anyhow!(e))?;
            println!("{}", est.summary());
            let analytic = pm
                .estimate(&model, cfg, &train_cfg, strategy)
                .map_err(|e| moe_folding::anyhow!(e))?;
            println!("analytic reference: {}", analytic.summary());
            let out = args.get_or("out", "timeline_trace.json");
            std::fs::write(out, chrome_trace_json(&trace))?;
            println!(
                "wrote {out} ({} events over {} ranks) — open at chrome://tracing",
                trace.len(),
                gpus
            );
        }
        "mapping" => {
            let gpus = args.get_usize("gpus", 16);
            let cfg = ParallelConfig::new(
                gpus,
                args.get_usize("tp", 2),
                args.get_usize("cp", 1),
                args.get_usize("ep", 4),
                args.get_usize("etp", 1),
                args.get_usize("pp", 1),
            );
            let mapping = if args.flag("legacy") {
                ParallelMapping::legacy(cfg)
            } else {
                ParallelMapping::folded(cfg)
            }
            .map_err(|e| moe_folding::anyhow!(e))?;
            println!("# {} ({})", cfg.tag(), if mapping.legacy { "legacy" } else { "folded" });
            for (name, set) in
                [("attention", &mapping.attention), ("moe", &mapping.moe)]
            {
                println!("[{name}]");
                for (axis, groups) in &set.groups {
                    println!("  {axis}: {groups:?}");
                }
            }
            let cluster = ClusterSpec::eos(gpus);
            println!("fold report: {:?}", mapping.fold_report(&cluster));
            // `--rank R`: the runtime-topology view one rank executes with
            // (the groups the dispatcher/trainer/pipeline actually use).
            if let Some(r) = args.get("rank") {
                let rank: usize = r.parse().map_err(|_| moe_folding::anyhow!("bad --rank"))?;
                if rank >= gpus {
                    return Err(moe_folding::anyhow!("--rank {rank} out of range (gpus {gpus})"));
                }
                let topo = RuntimeTopology::from_mapping(mapping)
                    .map_err(|e| moe_folding::anyhow!(e))?;
                println!("\n# runtime topology view");
                println!("{}", topo.view(rank).summary());
            }
        }
        "table1" => {
            if args.flag("executed") {
                let max_gpus = args.get_usize("max-gpus", 1024);
                print!("{}", coordinator::table1_executed(&pm, max_gpus).markdown());
            } else {
                print!("{}", coordinator::table1(&pm).markdown());
            }
        }
        "table2" => {
            if args.flag("executed") {
                print!("{}", coordinator::table2_executed(&pm).markdown());
            } else {
                print!("{}", coordinator::table2(&pm).markdown());
            }
        }
        "table3" => print!("{}", coordinator::table3(&pm).markdown()),
        "table4" => {
            let executed = args.flag("executed");
            let max_gpus = args.get_usize("max-gpus", 1024);
            for model in ModelConfig::paper_models() {
                println!("## {}", model.name);
                let t = if executed {
                    coordinator::strong_scaling_executed(
                        &pm,
                        &model,
                        &[128, 256, 512, 1024],
                        max_gpus,
                    )
                } else {
                    coordinator::strong_scaling(&pm, &model, &[128, 256, 512, 1024])
                };
                print!("{}", t.markdown());
            }
        }
        "fig3" => {
            let model = model_arg(&args, "mixtral-8x22b");
            // Figure 3 sweeps per-model GPU counts (the paper scales each
            // model from its Table-1 budget up to 1024).
            let counts: &[usize] = match model.name.as_str() {
                n if n.starts_with("Llama3") => &[256, 512, 1024],
                n if n.starts_with("Qwen2") => &[64, 128, 256, 512, 1024],
                _ => &[128, 256, 512, 1024],
            };
            let t = if args.flag("executed") {
                let max_gpus = args.get_usize("max-gpus", 1024);
                coordinator::strong_scaling_executed(&pm, &model, counts, max_gpus)
            } else {
                coordinator::strong_scaling(&pm, &model, counts)
            };
            print!("{}", t.markdown());
        }
        "table5" => {
            let executed = args.flag("executed");
            let max_gpus = args.get_usize("max-gpus", 1024);
            for name in ["mixtral-8x22b", "qwen2-57b-a14b"] {
                let model = ModelConfig::by_name(name).unwrap();
                println!("## {}", model.name);
                let t = if executed {
                    coordinator::context_scaling_executed(&pm, &model, max_gpus)
                } else {
                    coordinator::context_scaling(&pm, &model)
                };
                print!("{}", t.markdown());
            }
        }
        "fig5" => {
            let model = model_arg(&args, "mixtral-8x22b");
            let ep_etp = args.get_usize("ep-etp", 8);
            if args.flag("executed") {
                let tokens = args.get_usize("tokens", 256);
                let (drop_policy, pad_to_capacity) =
                    parse_policy(args.get_or("policy", "dropless"));
                let policy = RoutingPolicy {
                    capacity_factor: args.get_f64("cf", 1.0),
                    drop_policy,
                    pad_to_capacity,
                    balancer: parse_balancer(args.get_or("balancer", "aux")),
                    skew: args.get("skew").map(parse_skew),
                };
                print!(
                    "{}",
                    coordinator::fig5_breakdown_executed(
                        &model,
                        ep_etp,
                        tokens,
                        args.flag("overlap"),
                        &policy,
                    )
                    .markdown()
                );
            } else {
                print!("{}", coordinator::fig5_breakdown(&pm, &model, ep_etp).markdown());
            }
        }
        "sweep-capacity" => {
            let model = model_arg(&args, "mixtral-8x22b");
            let ep = args.get_usize("ep", 4);
            let tokens = args.get_usize("tokens", 64);
            let profile = parse_skew(args.get_or("skew", "zipf"));
            let cfs: Vec<f64> = args
                .get_or("cfs", "1.0,1.25,1.5,2.0")
                .split(',')
                .map(|s| {
                    s.trim().parse().unwrap_or_else(|_| {
                        eprintln!("bad --cfs entry {s} (want a comma list of floats)");
                        std::process::exit(2);
                    })
                })
                .collect();
            println!(
                "# {} | EP{ep} | {} tokens/rank | skew {}",
                model.name,
                tokens,
                profile.name()
            );
            let seed = args
                .get("seed")
                .map(|v| {
                    v.parse().unwrap_or_else(|_| {
                        eprintln!("bad --seed {v}");
                        std::process::exit(2);
                    })
                })
                .unwrap_or(coordinator::SWEEP_DEFAULT_SEED);
            print!(
                "{}",
                coordinator::sweep_capacity(&model, ep, tokens, profile, &cfs, seed).markdown()
            );
        }
        "serve" => {
            let model = model_arg(&args, "mixtral-8x22b");
            let gpus = args.get_usize("gpus", 128);
            let mut serve = serving::ServeConfig {
                concurrent_seqs: args.get_usize("seqs", 64),
                context_len: args.get_usize("ctx", 8192),
                ..serving::ServeConfig::default()
            };
            if args.flag("fp8") {
                serve.precision = Precision::Fp8;
            }
            serve.hbm_gib = args.get_f64("hbm", serve.hbm_gib);
            let gib = (1u64 << 30) as f64;
            println!(
                "# serving plan | {} | {} GPUs | {} seqs x {} ctx | {} | {:.0} GiB/rank",
                model.name,
                gpus,
                serve.concurrent_seqs,
                serve.context_len,
                serve.precision.name(),
                serve.hbm_gib
            );
            let t = TrainConfig::paper_default(model.seq_len, 256);
            for strategy in [Strategy::MCore, Strategy::MCoreFolding] {
                let train_best = autotune::tune(&pm, &model, gpus, &t, strategy).best;
                let r = serving::tune_serving(&pm, &model, gpus, &serve, strategy);
                match &r.best {
                    Some(b) => println!(
                        "{:<16} serve {:<30} {:>8.1} µs/tok | {:>6.1} GiB (kv {:>5.1}) | \
                         {} evaluated, {} KV-pruned | training best {}",
                        strategy.name(),
                        b.config.tag(),
                        b.decode_us,
                        b.memory.total_gib(),
                        b.memory.kv_cache_bytes / gib,
                        r.evaluated,
                        r.oom_count,
                        train_best
                            .as_ref()
                            .map_or_else(|| "n/a".to_string(), |e| e.config.tag()),
                    ),
                    None => println!(
                        "{:<16} n/a — no config fits {} seqs x {} ctx in {:.0} GiB \
                         ({} evaluated, {} KV-pruned)",
                        strategy.name(),
                        serve.concurrent_seqs,
                        serve.context_len,
                        serve.hbm_gib,
                        r.evaluated,
                        r.oom_count
                    ),
                }
            }
            if args.flag("replay") {
                let world = args.get_usize("world", 16);
                let seed = args.get_usize("seed", 42) as u64;
                let mut spec =
                    serving::ReplaySpec::small(world, args.get_usize("requests", 32), seed);
                spec.prefill_tokens = args.get_usize("prefill", spec.prefill_tokens);
                spec.decode_tokens = args.get_usize("decode", spec.decode_tokens);
                if let Some(s) = args.get("skew") {
                    spec.profile = parse_skew(s);
                }
                spec.arrivals = if args.flag("diurnal") {
                    serving::ArrivalProcess::Diurnal {
                        quiet_gap_us: 200.0,
                        busy_gap_us: 20.0,
                        period_us: 2000.0,
                    }
                } else {
                    serving::ArrivalProcess::Poisson {
                        mean_gap_us: args.get_f64("mean-gap-us", 50.0),
                    }
                };
                spec.bill_scale = model.hidden_size as f64 / spec.hidden as f64;
                let packed = serving::ExpertPlacement::packed(spec.num_experts);
                let base = serving::replay(&spec, &packed);
                let row = |tag: &str, r: &serving::ReplayReport| {
                    println!(
                        "{tag:<10} p50 {:>8.1} µs | p99 {:>8.1} µs | {:>8.1} tok/s/gpu | \
                         IB {:>10.0} B | {} steps, {} tokens",
                        r.p50_us,
                        r.p99_us,
                        r.tokens_per_sec_per_gpu,
                        r.ib_bytes,
                        r.steps,
                        r.generated_tokens
                    );
                };
                println!(
                    "\n# replay | {} ranks | {} requests | prefill {} + decode {} | skew {}",
                    world,
                    spec.requests,
                    spec.prefill_tokens,
                    spec.decode_tokens,
                    spec.profile.name()
                );
                row("packed", &base);
                if !args.flag("no-placement") {
                    let cluster = ClusterSpec::eos(world);
                    let placement = serving::optimize_placement(
                        &base.histogram,
                        &cluster,
                        world,
                        spec.num_experts,
                    );
                    let opt = serving::replay(&spec, &placement);
                    row("optimized", &opt);
                    if placement.is_identity() {
                        println!("placement: identity — traffic already node-aligned");
                    } else {
                        let moved = placement
                            .slot_to_expert
                            .iter()
                            .enumerate()
                            .filter(|&(s, &e)| s != e)
                            .count();
                        println!(
                            "placement: moved {} of {} experts, IB bytes {:+.1}%",
                            moved,
                            spec.num_experts,
                            (opt.ib_bytes / base.ib_bytes - 1.0) * 100.0
                        );
                    }
                }
            }
        }
        "fig4" => {
            let model = model_arg(&args, "mixtral-8x22b");
            if args.flag("executed") {
                let max_gpus = args.get_usize("max-gpus", 256);
                print!(
                    "{}",
                    coordinator::context_scaling_executed(&pm, &model, max_gpus).markdown()
                );
            } else {
                print!("{}", coordinator::context_scaling(&pm, &model).markdown());
            }
        }
        "fig6" => {
            let model = model_arg(&args, "mixtral-8x22b");
            if args.flag("executed") {
                let gpus = args.get_usize("gpus", 128);
                print!(
                    "{}",
                    coordinator::fig6_cp_folding_executed(&pm, &model, gpus).markdown()
                );
            } else {
                print!("{}", coordinator::fig6_cp_folding(&pm, &model).markdown());
            }
        }
        "train" => {
            let moe_probe = args.flag("moe-probe").then(|| {
                let (drop_policy, pad_to_capacity) = parse_policy(args.get_or("policy", "drop"));
                MoeProbe {
                    tokens_per_step: args.get_usize("moe-tokens", 64),
                    num_experts: args.get_usize("moe-experts", 8),
                    capacity_factor: args.get_f64("cf", 1.0),
                    drop_policy,
                    pad_to_capacity,
                    balancer: parse_balancer(args.get_or("balancer", "aux")),
                    skew: parse_skew(args.get_or("moe-skew", "zipf")),
                    bursty: args.flag("bursty"),
                    ..MoeProbe::default()
                }
            });
            let cfg = TrainerConfig {
                preset: args.get_or("preset", "test").to_string(),
                artifacts_dir: args.get_or("artifacts", "artifacts").to_string(),
                steps: args.get_usize("steps", 50),
                lr: args.get_f64("lr", 1e-3) as f32,
                dp: args.get_usize("dp", 1),
                seed: args.get_usize("seed", 42) as u64,
                log_every: args.get_usize("log-every", 10),
                clip_norm: args.get_f64("clip", 1.0) as f32,
                clocked: args.flag("clocked"),
                compute_us_per_step: args.get_f64("compute-us", 0.0),
                overlap_grad_reduce: args.flag("overlap"),
                moe_probe,
                ..TrainerConfig::default()
            };
            let report = train(&cfg)?;
            println!(
                "trained {} params for {} steps (dp={}): loss {:.4} -> {:.4}, {:.0} tokens/s, {:.1}s",
                report.num_params,
                cfg.steps,
                cfg.dp,
                report.initial_loss,
                report.final_loss,
                report.tokens_per_second,
                report.wall_seconds
            );
            if let Some(us) = report.sim_step_us {
                match report.sim_mfu {
                    Some(mfu) => println!(
                        "measured-in-sim: {us:.1} µs/step, MFU {:.1}%",
                        mfu * 100.0
                    ),
                    None => println!("measured-in-sim: {us:.1} µs/step"),
                }
                if let (Some(h), Some(e)) =
                    (report.sim_hidden_comm_us, report.sim_exposed_comm_us)
                {
                    println!(
                        "measured-in-sim grad comm: {h:.1} µs hidden, {e:.1} µs exposed per step"
                    );
                }
            }
            if let (Some(drop), Some(viol), Some(ent), Some(imb)) = (
                report.moe_drop_rate,
                report.moe_capacity_violations,
                report.moe_balance_entropy,
                report.moe_load_imbalance,
            ) {
                println!(
                    "moe probe: drop rate {:.1}%, {viol} capacity violations, \
                     load max/mean {imb:.2}, entropy {ent:.3}",
                    drop * 100.0
                );
            }
            if let Some(path) = args.get("loss-csv") {
                std::fs::write(path, report.loss_csv())?;
                println!("wrote {path}");
            }
        }
        "artifacts" => {
            let rt = moe_folding::runtime::Runtime::cpu(args.get_or("dir", "artifacts"))?;
            println!("platform: {}", rt.platform());
            for name in rt.artifact_names() {
                println!("  {name}");
            }
        }
        _ => usage(),
    }
    Ok(())
}
