//! Reporting: markdown/CSV table builders used by the CLI and the bench
//! harnesses to print paper-style tables.

/// A simple aligned markdown table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as github-flavoured markdown.
    pub fn markdown(&self) -> String {
        let w = self.widths();
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            s
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push('|');
        for wi in &w {
            out.push_str(&format!("{}|", "-".repeat(wi + 2)));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    /// Render as CSV.
    pub fn csv(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a fraction as "41.6%".
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_renders_aligned() {
        let mut t = Table::new(&["Model", "MFU"]);
        t.row_str(&["Mixtral-8x22B", "49.3%"]);
        t.row_str(&["Qwen2", "39.0%"]);
        let md = t.markdown();
        assert!(md.contains("| Model         | MFU   |"));
        assert!(md.lines().count() == 4);
    }

    #[test]
    fn csv_renders() {
        let mut t = Table::new(&["a", "b"]);
        t.row_str(&["1", "2"]);
        assert_eq!(t.csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn row_length_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row_str(&["only-one"]);
    }

    #[test]
    fn pct_formats() {
        assert_eq!(pct(0.493), "49.3%");
    }
}
