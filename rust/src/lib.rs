//! # moe-folding
//!
//! A reproduction of **"MoE Parallel Folding: Heterogeneous Parallelism
//! Mappings for Efficient Large-Scale MoE Model Training with Megatron
//! Core"** (NVIDIA, 2025) as a three-layer Rust + JAX + Pallas stack.
//!
//! * **Layer 3 (this crate)** — the coordination contribution: parallel
//!   group generation with MoE Parallel Folding ([`mapping`]), the flexible
//!   token dispatcher ([`dispatcher`]) and executed ring attention
//!   ([`attention`]) running over a functional in-process
//!   communicator ([`simcomm`]), a 1F1B pipeline scheduler ([`pipeline`]),
//!   an analytic cluster + collectives performance model
//!   ([`cluster`], [`collectives`], [`perfmodel`]) that regenerates every
//!   table and figure of the paper, a parallelism auto-tuner ([`autotune`]),
//!   and an end-to-end distributed trainer ([`train`]) that executes
//!   JAX/Pallas-authored compute via PJRT ([`runtime`]).
//! * **Layer 2** — `python/compile/model.py`: the MoE transformer fwd/bwd in
//!   JAX, AOT-lowered to HLO text artifacts.
//! * **Layer 1** — `python/compile/kernels/`: Pallas kernels for the MoE hot
//!   spot (grouped expert FFN, router top-k, token permute).
//!
//! See the top-level `README.md` for the architecture overview, quickstart,
//! the collectives-engine invariants, and the offline-build policy
//! (no external crates; see [`util`] for the in-crate stand-ins).

// Clippy runs as a CI gate (`cargo clippy -- -D warnings`); correctness
// lints are hard errors. The two style allowances below are deliberate:
// this crate's numerical kernels are index-heavy by design and read best
// as explicit loops, and a few simulation entry points take one scalar per
// parallel axis.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

pub mod attention;
pub mod autotune;
pub mod cluster;
pub mod dispatcher;
pub mod simcomm;
pub mod train;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod metrics;
pub mod mapping;
pub mod model;
pub mod perfmodel;
pub mod pipeline;
pub mod runtime;
pub mod serving;
pub mod util;

/// Crate version string for CLI banners.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
