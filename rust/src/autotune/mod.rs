//! Parallelism auto-tuner: sweep a strategy's legal configuration space and
//! return the best-MFU feasible mapping.
//!
//! The paper reports "the MFU achieved with the optimal parallelism
//! configuration found by tuning its supported parallelism dimensions" for
//! every baseline; this module is that tuning loop, and regenerates Table 3.

use std::cmp::Ordering;
use std::sync::mpsc;
use std::thread;

use crate::config::{EpPlacement, ModelConfig, ParallelConfig, Precision, TrainConfig};
use crate::perfmodel::{executed, ExecutedEstimate, PerfModel, StepEstimate, Strategy};

/// Descending comparator that sorts NaN last. A NaN estimate (e.g. a
/// degenerate flops denominator) must never win the tune, and the old
/// `partial_cmp(..).unwrap()` panicked outright on one. `f64::total_cmp`
/// alone is not enough either: reversed for descending order it puts +NaN
/// *first*, so NaN gets explicit arms.
fn desc_nan_last(a: f64, b: f64) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => b.total_cmp(&a),
    }
}

/// Ascending twin of [`desc_nan_last`]: smallest first, NaN still last.
fn asc_nan_last(a: f64, b: f64) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => a.total_cmp(&b),
    }
}

/// One tuning outcome.
#[derive(Debug, Clone)]
pub struct TuneResult {
    pub strategy: Strategy,
    pub best: Option<StepEstimate>,
    /// All feasible (non-OOM) estimates, sorted by descending MFU.
    pub feasible: Vec<StepEstimate>,
    pub evaluated: usize,
    pub oom_count: usize,
}

impl TuneResult {
    /// "OOM" or "41.6%" — the Table-1 cell for this (model, strategy).
    pub fn table_cell(&self) -> String {
        match &self.best {
            Some(e) => format!("{:.1}%", e.mfu * 100.0),
            None => "OOM".to_string(),
        }
    }
}

/// Sweep every candidate configuration of `strategy` for `model` on `gpus`
/// GPUs and keep the best non-OOM estimate. Unconstrained
/// [`tune_constrained`] — one evaluate loop, one memory gate.
pub fn tune(
    pm: &PerfModel,
    model: &ModelConfig,
    gpus: usize,
    train: &TrainConfig,
    strategy: Strategy,
) -> TuneResult {
    tune_constrained(pm, model, gpus, train, strategy, Constraints::default())
}

/// Tune all five strategies in parallel threads (they're independent).
pub fn tune_all(
    pm: &PerfModel,
    model: &ModelConfig,
    gpus: usize,
    train: &TrainConfig,
) -> Vec<TuneResult> {
    let (tx, rx) = mpsc::channel();
    thread::scope(|s| {
        for strategy in Strategy::ALL {
            let tx = tx.clone();
            let pm = pm.clone();
            let model = model.clone();
            let train = train.clone();
            s.spawn(move || {
                let r = tune(&pm, &model, gpus, &train, strategy);
                let _ = tx.send(r);
            });
        }
    });
    drop(tx);
    let mut results: Vec<TuneResult> = rx.into_iter().collect();
    results.sort_by_key(|r| Strategy::ALL.iter().position(|s| *s == r.strategy));
    results
}

/// One analytically-ranked candidate re-measured by executing its step on
/// the clocked simulator — once per overlap variant.
#[derive(Debug, Clone)]
pub struct ExecutedCandidate {
    pub analytic: StepEstimate,
    pub executed: ExecutedEstimate,
    /// Whether this variant ran with comm–compute overlap (the train
    /// config's overlap knobs) or as the fully serialized twin.
    pub overlap: bool,
    /// Precision this variant executed under. Every candidate also runs as
    /// its [`Precision::twin`], so the re-rank prices the precision axis
    /// the same way it prices EP placement.
    pub precision: Precision,
}

/// Outcome of [`tune_executed`]: the analytic top-k re-ranked by
/// measured-in-sim step time.
#[derive(Debug, Clone)]
pub struct ExecutedTune {
    pub strategy: Strategy,
    /// Candidates sorted by ascending executed step time.
    pub candidates: Vec<ExecutedCandidate>,
    /// True when executing changed the analytic ordering.
    pub rank_changed: bool,
}

impl ExecutedTune {
    pub fn best(&self) -> Option<&ExecutedCandidate> {
        self.candidates.first()
    }
}

/// `autotune --executed`: take the analytic sweep's top-`top_k` feasible
/// candidates and re-rank them by **executing** each step on the clocked
/// simulator at full world size ([`executed::execute_step`]). The analytic
/// model stays the pruner (sweeping hundreds of configs); execution is the
/// arbiter for the short list, where schedule composition, measured
/// bubbles, and measured comm–compute overlap can reorder near-ties.
///
/// Each candidate executes twice: with the train config's overlap knobs
/// and as its fully **serialized twin** (all overlap off) — both paired
/// with the matching analytic estimate — so the re-rank quantifies what
/// overlap is worth per mapping, not just which mapping wins.
///
/// Multi-rank-EP candidates additionally execute as their
/// [`EpPlacement::Strided`] twin (both overlap variants): same degrees,
/// EP peers strided across nodes instead of packed inside them, so the
/// re-rank prices the placement axis itself.
///
/// Every variant further executes at both the train config's precision and
/// its [`Precision::twin`] (ISSUE 8): fp8 vs bf16 becomes a ranked axis
/// like placement, with the speedup *measured* on the executed fabric
/// rather than assumed. A twin whose re-estimate is OOM at the flipped
/// precision (e.g. the bf16 twin of an fp8-only mapping) is dropped.
pub fn tune_executed(
    pm: &PerfModel,
    model: &ModelConfig,
    gpus: usize,
    train: &TrainConfig,
    strategy: Strategy,
    top_k: usize,
) -> ExecutedTune {
    let analytic = tune(pm, model, gpus, train, strategy);
    let mut serial_train = train.clone();
    serial_train.overlap_grad_reduce = false;
    serial_train.overlap_param_gather = false;
    serial_train.overlap_a2a = false;
    let mut candidates: Vec<ExecutedCandidate> = Vec::new();
    for e in analytic.feasible.iter().take(top_k) {
        let mut placements = vec![EpPlacement::Packed];
        if e.config.ep > 1 {
            placements.push(EpPlacement::Strided);
        }
        for placement in placements {
            let cfg = e.config.with_placement(placement);
            for (overlap, tc) in [(true, train), (false, &serial_train)] {
                for precision in [train.precision, train.precision.twin()] {
                    let native = precision == train.precision;
                    let mut tc = tc.clone();
                    tc.precision = precision;
                    // Pair each variant with its *matching* analytic
                    // estimate (the serialized twin drops the analytic
                    // overlap credit; the strided twin re-prices comm over
                    // strided groups; the precision twin re-prices GEMMs,
                    // payload bytes and activation memory).
                    let paired =
                        if overlap && placement == EpPlacement::Packed && native {
                            e.clone()
                        } else {
                            match pm.estimate(model, cfg, &tc, strategy) {
                                Ok(a) => a,
                                Err(err) => {
                                    eprintln!(
                                        "tune_executed: {} twin failed to estimate, \
                                         dropped from re-rank: {err}",
                                        cfg.tag()
                                    );
                                    continue;
                                }
                            }
                        };
                    if !native && paired.oom {
                        eprintln!(
                            "tune_executed: {} {} twin is OOM, dropped from re-rank",
                            cfg.tag(),
                            precision.name()
                        );
                        continue;
                    }
                    match executed::execute_step(pm, model, cfg, &tc, strategy) {
                        Ok(x) => candidates.push(ExecutedCandidate {
                            analytic: paired,
                            executed: x,
                            overlap,
                            precision,
                        }),
                        // Surface drops: a silently-shrunk survivor set
                        // would make an execution failure look like "no
                        // rank change".
                        Err(err) => eprintln!(
                            "tune_executed: {} failed to execute, dropped from re-rank: {err}",
                            cfg.tag()
                        ),
                    }
                }
            }
        }
    }
    let analytic_order: Vec<(ParallelConfig, bool, Precision)> = candidates
        .iter()
        .map(|c| (c.analytic.config, c.overlap, c.precision))
        .collect();
    candidates.sort_by(|a, b| asc_nan_last(a.executed.step_ms, b.executed.step_ms));
    let rank_changed = candidates
        .iter()
        .map(|c| (c.analytic.config, c.overlap, c.precision))
        .ne(analytic_order.into_iter());
    ExecutedTune { strategy, candidates, rank_changed }
}

/// Constrained tune: fix some dimensions (e.g. Figure 6 sweeps CP while
/// tuning the rest). `None` = free dimension.
#[derive(Debug, Clone, Copy, Default)]
pub struct Constraints {
    pub tp: Option<usize>,
    pub cp: Option<usize>,
    pub ep: Option<usize>,
    pub etp: Option<usize>,
    pub pp: Option<usize>,
    /// Pin the virtual-pipeline (interleaving) degree.
    pub vpp: Option<usize>,
    /// Per-rank HBM budget in GiB: candidates whose memory estimate fails
    /// [`crate::model::memory::MemoryEstimate::fits`] against it are
    /// rejected (counted as OOM). Tightens on top of the cluster default —
    /// a budget larger than the GPU's HBM cannot resurrect a config the
    /// estimator already flags as OOM.
    pub hbm_gib: Option<f64>,
}

impl Constraints {
    pub fn admits(&self, c: &ParallelConfig) -> bool {
        fn pinned(dim: Option<usize>, actual: usize) -> bool {
            match dim {
                Some(v) => actual == v,
                None => true,
            }
        }
        pinned(self.tp, c.tp)
            && pinned(self.cp, c.cp)
            && pinned(self.ep, c.ep)
            && pinned(self.etp, c.etp)
            && pinned(self.pp, c.pp)
            && pinned(self.vpp, c.vpp)
    }

    /// Memory feasibility of an estimate under this constraint set: the
    /// estimator's own OOM flag (cluster-default HBM), optionally
    /// tightened by the explicit `hbm_gib` budget.
    pub fn fits_memory(&self, est: &StepEstimate, pm: &PerfModel) -> bool {
        let within_budget = match self.hbm_gib {
            Some(gib) => est.memory.fits(gib, &pm.memory.knobs),
            None => true,
        };
        !est.oom && within_budget
    }
}

/// Tune under dimension constraints and the memory feasibility gate.
pub fn tune_constrained(
    pm: &PerfModel,
    model: &ModelConfig,
    gpus: usize,
    train: &TrainConfig,
    strategy: Strategy,
    cons: Constraints,
) -> TuneResult {
    let candidates: Vec<ParallelConfig> = strategy
        .candidates(model, gpus)
        .into_iter()
        .filter(|c| cons.admits(c))
        .collect();
    let evaluated = candidates.len();
    let mut feasible = Vec::new();
    let mut oom_count = 0;
    for cfg in candidates {
        match pm.estimate(model, cfg, train, strategy) {
            Ok(e) if !cons.fits_memory(&e, pm) => oom_count += 1,
            Ok(e) => feasible.push(e),
            Err(_) => {}
        }
    }
    feasible.sort_by(|a, b| desc_nan_last(a.mfu, b.mfu));
    TuneResult { strategy, best: feasible.first().cloned(), feasible, evaluated, oom_count }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tune_finds_feasible_configs() {
        let pm = PerfModel::default();
        let m = ModelConfig::mixtral_8x22b();
        let t = TrainConfig::paper_default(4096, 256);
        let r = tune(&pm, &m, 128, &t, Strategy::MCoreFolding);
        assert!(r.best.is_some());
        assert!(r.evaluated > 10);
        let best = r.best.unwrap();
        assert!(best.mfu > 0.2, "best {:.3}", best.mfu);
    }

    #[test]
    fn folding_never_worse_than_mcore() {
        // Folding's space is a superset, so the tuned optimum dominates.
        let pm = PerfModel::default();
        let t = TrainConfig::paper_default(4096, 256);
        for (m, gpus) in [
            (ModelConfig::mixtral_8x22b(), 128),
            (ModelConfig::qwen2_57b_a14b(), 64),
        ] {
            let mcore = tune(&pm, &m, gpus, &t, Strategy::MCore);
            let folded = tune(&pm, &m, gpus, &t, Strategy::MCoreFolding);
            // Infeasible is not "0.0 MFU": the superset claim is that
            // whenever MCore has a feasible optimum, folding has one at
            // least as good — `unwrap_or(0.0)` used to vacuously pass the
            // both-infeasible case and hide a feasible-MCore /
            // infeasible-folding regression behind `0 >= mfu` being false
            // only by luck (ISSUE 10 satellite).
            match (&mcore.best, &folded.best) {
                (Some(a), Some(b)) => {
                    assert!(b.mfu >= a.mfu, "{}: folded {:.3} < mcore {:.3}", m.name, b.mfu, a.mfu);
                }
                (Some(a), None) => {
                    panic!("{}: mcore feasible ({:.3} MFU) but folding infeasible", m.name, a.mfu);
                }
                (None, _) => panic!("{}: mcore must be feasible in this fixture", m.name),
            }
        }
    }

    /// `--executed` re-ranks the analytic top-k by simulated step time;
    /// executed and analytic step times agree within the pinned tolerance
    /// (the executed run shares the analytic per-phase prices, so residual
    /// differences are schedule composition only).
    #[test]
    fn executed_rerank_orders_by_sim_step_and_agrees() {
        let pm = PerfModel::default();
        let m = ModelConfig::qwen2_57b_a14b();
        let t = TrainConfig::paper_default(4096, 256);
        let r = tune_executed(&pm, &m, 64, &t, Strategy::MCoreFolding, 3);
        assert!(!r.candidates.is_empty(), "no executable candidates");
        for w in r.candidates.windows(2) {
            assert!(w[0].executed.step_ms <= w[1].executed.step_ms);
        }
        // Every config executes as an overlapped + serialized twin pair,
        // and measured overlap never slows a config down.
        for c in &r.candidates {
            let twin = r.candidates.iter().find(|d| {
                d.analytic.config == c.analytic.config
                    && d.precision == c.precision
                    && d.overlap != c.overlap
            });
            let Some(twin) = twin else { continue };
            let (ovl, ser) = if c.overlap { (c, twin) } else { (twin, c) };
            assert!(
                ovl.executed.step_ms <= ser.executed.step_ms + 1e-9,
                "{}: overlap {:.1} ms > serialized {:.1} ms",
                c.analytic.config.tag(),
                ovl.executed.step_ms,
                ser.executed.step_ms
            );
        }
        // Tolerance is looser than the Table-3 pin (tests/clocked_timing.rs):
        // for arbitrary tuned configs the executed run prices each actual
        // stage-boundary link (hops can mix NVLink and IB when the PP
        // stride is below the node size) while the analytic model prices
        // one representative hop.
        for c in &r.candidates {
            let rel =
                (c.executed.step_ms - c.analytic.step_ms).abs() / c.analytic.step_ms;
            assert!(
                rel < 0.10,
                "{}: executed {:.1} ms vs analytic {:.1} ms",
                c.analytic.config.tag(),
                c.executed.step_ms,
                c.analytic.step_ms
            );
        }
        // The precision axis (ISSUE 8): every variant pairs with its
        // precision twin, and the fp8 member of each pair wins its
        // measured step (the paper's Table-2 direction, executed).
        let mut pairs = 0;
        for c in r.candidates.iter().filter(|c| c.precision == Precision::Bf16) {
            let twin = r.candidates.iter().find(|d| {
                d.analytic.config == c.analytic.config
                    && d.overlap == c.overlap
                    && d.precision == Precision::Fp8
            });
            let Some(fp8) = twin else { continue };
            pairs += 1;
            assert!(
                fp8.executed.step_ms < c.executed.step_ms,
                "{}: fp8 {:.1} ms must beat bf16 {:.1} ms",
                c.analytic.config.tag(),
                fp8.executed.step_ms,
                c.executed.step_ms
            );
        }
        assert!(pairs > 0, "every candidate must execute a precision twin");
    }

    /// The EP-placement axis: every multi-rank-EP candidate is re-ranked
    /// against its strided twin, the twins' executed step times differ
    /// measurably, and packing EP inside nodes never loses — the token
    /// all-to-all rides NVLink instead of IB (the paper's placement
    /// argument, now *executed* rather than assumed).
    #[test]
    fn executed_rerank_ranks_ep_placements() {
        let pm = PerfModel::default();
        let m = ModelConfig::qwen2_57b_a14b();
        let t = TrainConfig::paper_default(4096, 256);
        let r = tune_executed(&pm, &m, 64, &t, Strategy::MCoreFolding, 2);
        let strided: Vec<&ExecutedCandidate> = r
            .candidates
            .iter()
            .filter(|c| c.analytic.config.placement == EpPlacement::Strided)
            .collect();
        assert!(!strided.is_empty(), "ep > 1 candidates must get strided twins");
        let mut strict_wins = 0;
        for s in strided {
            let packed = r
                .candidates
                .iter()
                .find(|c| {
                    c.analytic.config == s.analytic.config.with_placement(EpPlacement::Packed)
                        && c.overlap == s.overlap
                        && c.precision == s.precision
                })
                .expect("every strided twin pairs with a packed original");
            assert!(
                packed.executed.step_ms <= s.executed.step_ms + 1e-9,
                "{}: packed {:.2} ms must not lose to strided {:.2} ms",
                s.analytic.config.tag(),
                packed.executed.step_ms,
                s.executed.step_ms
            );
            if packed.executed.step_ms < s.executed.step_ms {
                strict_wins += 1;
            }
        }
        assert!(strict_wins > 0, "striding EP across nodes must cost executed step time");
    }

    /// Memory feasibility gate (ISSUE 5 satellite): the Table-3 folded
    /// optima fit an explicit 80 GiB budget, an oversized no-PP Mixtral
    /// mapping is pruned as OOM, and a tightened budget prunes configs the
    /// default HBM would admit.
    #[test]
    fn memory_gate_prunes_infeasible_candidates() {
        let pm = PerfModel::default();
        let t = TrainConfig::paper_default(4096, 256);
        // Table-3 folded optima fit under the explicit H100 budget.
        for (m, gpus, tp, ep, pp) in [
            (ModelConfig::mixtral_8x22b(), 128usize, 2usize, 8usize, 8usize),
            (ModelConfig::qwen2_57b_a14b(), 64, 2, 4, 4),
        ] {
            let cons = Constraints {
                tp: Some(tp),
                cp: Some(1),
                ep: Some(ep),
                etp: Some(1),
                pp: Some(pp),
                vpp: Some(1),
                hbm_gib: Some(80.0),
            };
            let r = tune_constrained(&pm, &m, gpus, &t, Strategy::MCoreFolding, cons);
            let best = r.best.unwrap_or_else(|| panic!("{}: optimum must fit 80 GiB", m.name));
            assert_eq!((best.config.tp, best.config.ep, best.config.pp), (tp, ep, pp));
            assert!(best.memory.fits(80.0, &pm.memory.knobs));
        }
        // No-PP Mixtral with unsharded experts: hundreds of GiB per rank —
        // every candidate is rejected by the gate.
        let m = ModelConfig::mixtral_8x22b();
        let cons =
            Constraints { pp: Some(1), ep: Some(1), etp: Some(1), ..Default::default() };
        let r = tune_constrained(&pm, &m, 128, &t, Strategy::MCoreFolding, cons);
        assert!(r.best.is_none(), "unsharded-expert no-PP Mixtral must be pruned");
        assert!(r.oom_count > 0);
        // A tightened budget prunes what the 80 GiB default admits.
        let pinned = Constraints {
            tp: Some(2),
            cp: Some(1),
            ep: Some(8),
            etp: Some(1),
            pp: Some(8),
            vpp: Some(1),
            hbm_gib: Some(20.0),
        };
        let r = tune_constrained(&pm, &m, 128, &t, Strategy::MCoreFolding, pinned);
        assert!(r.best.is_none(), "a 20 GiB budget must reject the optimum");
        assert_eq!(r.oom_count, r.evaluated);
    }

    /// Precision-aware memory gate (ISSUE 8): the Table-2 Mixtral optimum
    /// needs ~58 GiB under bf16 but ~47 GiB under fp8 (activations are
    /// half-width), so a 56 GiB budget prunes the bf16 run and admits the
    /// fp8 twin of the *same* mapping — fp8 is a feasibility axis, not
    /// just a speed axis.
    #[test]
    fn fp8_memory_gate_admits_what_bf16_prunes() {
        use crate::config::Precision;
        let pm = PerfModel::default();
        let m = ModelConfig::mixtral_8x22b();
        let cons = Constraints {
            tp: Some(2),
            cp: Some(1),
            ep: Some(8),
            etp: Some(1),
            pp: Some(8),
            vpp: Some(1),
            hbm_gib: Some(56.0),
        };
        let bf16 = TrainConfig::paper_default(4096, 256);
        let mut fp8 = bf16.clone();
        fp8.precision = Precision::Fp8;
        let r16 = tune_constrained(&pm, &m, 128, &bf16, Strategy::MCoreFolding, cons);
        assert!(r16.best.is_none(), "56 GiB must prune the bf16 optimum");
        assert!(r16.oom_count > 0);
        let r8 = tune_constrained(&pm, &m, 128, &fp8, Strategy::MCoreFolding, cons);
        let best = r8.best.expect("fp8 must fit the same mapping in 56 GiB");
        assert_eq!(
            (best.config.tp, best.config.ep, best.config.pp),
            (2, 8, 8),
            "the admitted fp8 config is the pinned Table-2 mapping"
        );
        assert!(best.memory.fits(56.0, &pm.memory.knobs));
    }

    /// Regression (ISSUE 6 satellite): a candidate whose estimate carries a
    /// NaN metric must sort *last*, not panic the tune. The old comparators
    /// used `partial_cmp(..).unwrap()` (panic) and `unwrap_or(Equal)`
    /// (NaN-position luck of the draw); these are the exact comparators the
    /// two sort sites now use.
    #[test]
    fn nan_candidates_sort_last_without_panicking() {
        let pm = PerfModel::default();
        let m = ModelConfig::qwen2_57b_a14b();
        let t = TrainConfig::paper_default(4096, 256);
        let cons = Constraints {
            tp: Some(2),
            cp: Some(1),
            ep: Some(4),
            etp: Some(1),
            pp: Some(4),
            vpp: Some(1),
            ..Default::default()
        };
        let r = tune_constrained(&pm, &m, 64, &t, Strategy::MCoreFolding, cons);
        let good = r.best.expect("pinned Table-3 optimum must be feasible");
        let mut poisoned = good.clone();
        poisoned.mfu = f64::NAN;
        poisoned.step_ms = f64::NAN;
        let mut slower = good.clone();
        slower.mfu = good.mfu / 2.0;
        slower.step_ms = good.step_ms * 2.0;

        // Descending-MFU site (tune_constrained): NaN sinks below every
        // finite value regardless of insertion order.
        let mut by_mfu = vec![poisoned.clone(), slower.clone(), good.clone()];
        by_mfu.sort_by(|a, b| desc_nan_last(a.mfu, b.mfu));
        assert_eq!(by_mfu[0].mfu.to_bits(), good.mfu.to_bits());
        assert_eq!(by_mfu[1].mfu.to_bits(), slower.mfu.to_bits());
        assert!(by_mfu[2].mfu.is_nan(), "NaN must sort last");

        // Ascending-step_ms site (tune_executed): same guarantee.
        let mut by_step = vec![poisoned, good.clone(), slower];
        by_step.sort_by(|a, b| asc_nan_last(a.step_ms, b.step_ms));
        assert_eq!(by_step[0].step_ms.to_bits(), good.step_ms.to_bits());
        assert!(by_step[2].step_ms.is_nan(), "NaN must sort last");
    }

    #[test]
    fn constraints_respected() {
        let pm = PerfModel::default();
        let m = ModelConfig::mixtral_8x22b();
        let t = TrainConfig::paper_default(4096, 256);
        let cons = Constraints { tp: Some(4), cp: Some(1), ..Default::default() };
        let r = tune_constrained(&pm, &m, 128, &t, Strategy::MCoreFolding, cons);
        for e in &r.feasible {
            assert_eq!(e.config.tp, 4);
            assert_eq!(e.config.cp, 1);
        }
    }
}

#[cfg(test)]
mod calibration {
    use super::*;

    /// Manual calibration dump: `cargo test --release calibration_table1 -- --ignored --nocapture`
    #[test]
    #[ignore]
    fn calibration_table1() {
        let pm = PerfModel::default();
        let t = TrainConfig::paper_default(4096, 256);
        for (m, gpus) in [
            (ModelConfig::mixtral_8x22b(), 128),
            (ModelConfig::llama3_8x70b(), 256),
            (ModelConfig::qwen2_57b_a14b(), 64),
            (ModelConfig::mixtral_8x22b_g8t8(), 128),
        ] {
            println!("=== {} ({} GPUs) ===", m.name, gpus);
            for r in tune_all(&pm, &m, gpus, &t) {
                let cfgs = r
                    .best
                    .as_ref()
                    .map(|e| e.config.tag())
                    .unwrap_or_else(|| "-".into());
                println!("  {:<18} {:>7}   {}", r.strategy.name(), r.table_cell(), cfgs);
            }
        }
    }
}
