//! Parallelism auto-tuner: sweep a strategy's legal configuration space and
//! return the best-MFU feasible mapping.
//!
//! The paper reports "the MFU achieved with the optimal parallelism
//! configuration found by tuning its supported parallelism dimensions" for
//! every baseline; this module is that tuning loop, and regenerates Table 3.

use std::sync::mpsc;
use std::thread;

use crate::config::{ModelConfig, ParallelConfig, TrainConfig};
use crate::perfmodel::{PerfModel, StepEstimate, Strategy};

/// One tuning outcome.
#[derive(Debug, Clone)]
pub struct TuneResult {
    pub strategy: Strategy,
    pub best: Option<StepEstimate>,
    /// All feasible (non-OOM) estimates, sorted by descending MFU.
    pub feasible: Vec<StepEstimate>,
    pub evaluated: usize,
    pub oom_count: usize,
}

impl TuneResult {
    /// "OOM" or "41.6%" — the Table-1 cell for this (model, strategy).
    pub fn table_cell(&self) -> String {
        match &self.best {
            Some(e) => format!("{:.1}%", e.mfu * 100.0),
            None => "OOM".to_string(),
        }
    }
}

/// Sweep every candidate configuration of `strategy` for `model` on `gpus`
/// GPUs and keep the best non-OOM estimate.
pub fn tune(
    pm: &PerfModel,
    model: &ModelConfig,
    gpus: usize,
    train: &TrainConfig,
    strategy: Strategy,
) -> TuneResult {
    let candidates = strategy.candidates(model, gpus);
    let evaluated = candidates.len();
    let mut feasible = Vec::new();
    let mut oom_count = 0usize;
    for cfg in candidates {
        match pm.estimate(model, cfg, train, strategy) {
            Ok(e) if e.oom => oom_count += 1,
            Ok(e) => feasible.push(e),
            Err(_) => {}
        }
    }
    feasible.sort_by(|a, b| b.mfu.partial_cmp(&a.mfu).unwrap());
    TuneResult {
        strategy,
        best: feasible.first().cloned(),
        feasible,
        evaluated,
        oom_count,
    }
}

/// Tune all five strategies in parallel threads (they're independent).
pub fn tune_all(
    pm: &PerfModel,
    model: &ModelConfig,
    gpus: usize,
    train: &TrainConfig,
) -> Vec<TuneResult> {
    let (tx, rx) = mpsc::channel();
    thread::scope(|s| {
        for strategy in Strategy::ALL {
            let tx = tx.clone();
            let pm = pm.clone();
            let model = model.clone();
            let train = train.clone();
            s.spawn(move || {
                let r = tune(&pm, &model, gpus, &train, strategy);
                let _ = tx.send(r);
            });
        }
    });
    drop(tx);
    let mut results: Vec<TuneResult> = rx.into_iter().collect();
    results.sort_by_key(|r| Strategy::ALL.iter().position(|s| *s == r.strategy));
    results
}

/// Constrained tune: fix some dimensions (e.g. Figure 6 sweeps CP while
/// tuning the rest). `None` = free dimension.
#[derive(Debug, Clone, Copy, Default)]
pub struct Constraints {
    pub tp: Option<usize>,
    pub cp: Option<usize>,
    pub ep: Option<usize>,
    pub etp: Option<usize>,
    pub pp: Option<usize>,
}

impl Constraints {
    pub fn admits(&self, c: &ParallelConfig) -> bool {
        fn pinned(dim: Option<usize>, actual: usize) -> bool {
            match dim {
                Some(v) => actual == v,
                None => true,
            }
        }
        pinned(self.tp, c.tp)
            && pinned(self.cp, c.cp)
            && pinned(self.ep, c.ep)
            && pinned(self.etp, c.etp)
            && pinned(self.pp, c.pp)
    }
}

/// Tune under dimension constraints.
pub fn tune_constrained(
    pm: &PerfModel,
    model: &ModelConfig,
    gpus: usize,
    train: &TrainConfig,
    strategy: Strategy,
    cons: Constraints,
) -> TuneResult {
    let candidates: Vec<ParallelConfig> = strategy
        .candidates(model, gpus)
        .into_iter()
        .filter(|c| cons.admits(c))
        .collect();
    let evaluated = candidates.len();
    let mut feasible = Vec::new();
    let mut oom_count = 0;
    for cfg in candidates {
        match pm.estimate(model, cfg, train, strategy) {
            Ok(e) if e.oom => oom_count += 1,
            Ok(e) => feasible.push(e),
            Err(_) => {}
        }
    }
    feasible.sort_by(|a, b| b.mfu.partial_cmp(&a.mfu).unwrap());
    TuneResult { strategy, best: feasible.first().cloned(), feasible, evaluated, oom_count }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tune_finds_feasible_configs() {
        let pm = PerfModel::default();
        let m = ModelConfig::mixtral_8x22b();
        let t = TrainConfig::paper_default(4096, 256);
        let r = tune(&pm, &m, 128, &t, Strategy::MCoreFolding);
        assert!(r.best.is_some());
        assert!(r.evaluated > 10);
        let best = r.best.unwrap();
        assert!(best.mfu > 0.2, "best {:.3}", best.mfu);
    }

    #[test]
    fn folding_never_worse_than_mcore() {
        // Folding's space is a superset, so the tuned optimum dominates.
        let pm = PerfModel::default();
        let t = TrainConfig::paper_default(4096, 256);
        for (m, gpus) in [
            (ModelConfig::mixtral_8x22b(), 128),
            (ModelConfig::qwen2_57b_a14b(), 64),
        ] {
            let mcore = tune(&pm, &m, gpus, &t, Strategy::MCore);
            let folded = tune(&pm, &m, gpus, &t, Strategy::MCoreFolding);
            let a = mcore.best.map(|e| e.mfu).unwrap_or(0.0);
            let b = folded.best.map(|e| e.mfu).unwrap_or(0.0);
            assert!(b >= a, "{}: folded {b:.3} < mcore {a:.3}", m.name);
        }
    }

    #[test]
    fn constraints_respected() {
        let pm = PerfModel::default();
        let m = ModelConfig::mixtral_8x22b();
        let t = TrainConfig::paper_default(4096, 256);
        let cons = Constraints { tp: Some(4), cp: Some(1), ..Default::default() };
        let r = tune_constrained(&pm, &m, 128, &t, Strategy::MCoreFolding, cons);
        for e in &r.feasible {
            assert_eq!(e.config.tp, 4);
            assert_eq!(e.config.cp, 1);
        }
    }
}

#[cfg(test)]
mod calibration {
    use super::*;

    /// Manual calibration dump: `cargo test --release calibration_table1 -- --ignored --nocapture`
    #[test]
    #[ignore]
    fn calibration_table1() {
        let pm = PerfModel::default();
        let t = TrainConfig::paper_default(4096, 256);
        for (m, gpus) in [
            (ModelConfig::mixtral_8x22b(), 128),
            (ModelConfig::llama3_8x70b(), 256),
            (ModelConfig::qwen2_57b_a14b(), 64),
            (ModelConfig::mixtral_8x22b_g8t8(), 128),
        ] {
            println!("=== {} ({} GPUs) ===", m.name, gpus);
            for r in tune_all(&pm, &m, gpus, &t) {
                let cfgs = r
                    .best
                    .as_ref()
                    .map(|e| e.config.tag())
                    .unwrap_or_else(|| "-".into());
                println!("  {:<18} {:>7}   {}", r.strategy.name(), r.table_cell(), cfgs);
            }
        }
    }
}
