//! Synthetic training corpus: a first-order Markov token stream with a
//! Zipfian unigram prior.
//!
//! The transition structure makes next-token prediction *learnable* (loss
//! drops well below the unigram entropy), which is what the e2e driver needs
//! to show a meaningful loss curve without shipping a dataset.

use crate::util::Rng;

/// Markov corpus generator.
pub struct SyntheticCorpus {
    vocab: usize,
    /// Per-state successor table: `branch` choices per token.
    successors: Vec<Vec<u32>>,
    rng: Rng,
}

impl SyntheticCorpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let branch = 4usize;
        // Zipfian successor selection: low token ids are common targets.
        let successors = (0..vocab)
            .map(|_| {
                (0..branch)
                    .map(|_| {
                        let u = rng.next_f64();
                        // Inverse-CDF of a truncated Zipf-ish distribution.
                        let z = ((vocab as f64).powf(u) - 1.0).max(0.0);
                        (z as u32).min(vocab as u32 - 1)
                    })
                    .collect()
            })
            .collect();
        Self { vocab, successors, rng: Rng::seed_from_u64(seed ^ 0x5EED) }
    }

    /// Sample a [batch, seq+1] id matrix; caller splits into inputs/targets.
    pub fn batch(&mut self, batch: usize, seq: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * (seq + 1));
        for _ in 0..batch {
            let mut state = self.rng.next_below(self.vocab) as u32;
            for _ in 0..=seq {
                out.push(state as i32);
                let succ = &self.successors[state as usize];
                state = succ[self.rng.next_below(succ.len())];
            }
        }
        out
    }

    /// Split a `[batch, seq+1]` buffer into (inputs, targets), both
    /// `[batch, seq]`.
    pub fn split(ids: &[i32], batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let mut inputs = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            let row = &ids[b * (seq + 1)..(b + 1) * (seq + 1)];
            inputs.extend_from_slice(&row[..seq]);
            targets.extend_from_slice(&row[1..]);
        }
        (inputs, targets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_in_vocab() {
        let mut c = SyntheticCorpus::new(256, 1);
        let ids = c.batch(4, 32);
        assert_eq!(ids.len(), 4 * 33);
        assert!(ids.iter().all(|&i| (0..256).contains(&i)));
    }

    #[test]
    fn split_shapes_and_shift() {
        let mut c = SyntheticCorpus::new(64, 2);
        let ids = c.batch(2, 8);
        let (inp, tgt) = SyntheticCorpus::split(&ids, 2, 8);
        assert_eq!(inp.len(), 16);
        assert_eq!(tgt.len(), 16);
        // targets are inputs shifted by one within each row.
        assert_eq!(inp[1], tgt[0]);
        assert_eq!(inp[9], tgt[8]);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = SyntheticCorpus::new(128, 7).batch(2, 16);
        let b = SyntheticCorpus::new(128, 7).batch(2, 16);
        assert_eq!(a, b);
    }

    #[test]
    fn markov_structure_is_predictable() {
        // Each state has at most 4 successors => conditional entropy is far
        // below the unigram entropy: check successor diversity is bounded.
        let c = SyntheticCorpus::new(512, 3);
        for s in c.successors.iter().take(32) {
            let mut u: Vec<u32> = s.clone();
            u.sort_unstable();
            u.dedup();
            assert!(u.len() <= 4);
        }
    }
}
