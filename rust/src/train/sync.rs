//! Gradient synchronization groups under MoE Parallel Folding.
//!
//! With folded mappings the attention and MoE grids have *different*
//! data-parallel axes: attention parameters replicate over the attention DP
//! group (`world / (tp·cp·pp)` ranks) while expert parameters replicate over
//! the expert-data-parallel (EDP) group (`world / (etp·ep·pp)` ranks) —
//! Megatron-Core's `get_data_parallel_group()` vs
//! `get_expert_data_parallel_group()` split. A single undifferentiated
//! all-reduce over the world is **wrong** whenever `dp != edp`: it would
//! average expert gradients with ranks that hold *other* experts' shards
//! and attention gradients with model-parallel peers.
//!
//! [`GradSync`] carries one rank's two reduction groups (taken from a
//! [`RuntimeTopology`] view, never hand-rolled) and applies the mean
//! all-reduce per [`ParamClass`].

use crate::mapping::RuntimeTopology;
use crate::simcomm::{CommHandle, Communicator};

/// Which replication axis a parameter tensor synchronizes over.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamClass {
    /// Attention / dense parameters: all-reduce over the attention DP group.
    Attention,
    /// Expert (MoE) parameters: all-reduce over the EDP group.
    Expert,
}

/// One rank's gradient-reduction groups.
#[derive(Debug, Clone, PartialEq)]
pub struct GradSync {
    dp_group: Vec<usize>,
    edp_group: Vec<usize>,
}

impl GradSync {
    /// Undifferentiated data parallelism: both classes reduce over the flat
    /// `0..world` group (the pre-folding trainer behaviour, and exactly
    /// right when `tp = cp = etp = ep = pp = 1`).
    pub fn flat(world: usize) -> Self {
        let group: Vec<usize> = (0..world).collect();
        Self { dp_group: group.clone(), edp_group: group }
    }

    /// Groups for `rank` from a runtime topology: attention params reduce
    /// over the rank's attention-DP group, expert params over its EDP group.
    pub fn from_topology(topo: &RuntimeTopology, rank: usize) -> Self {
        let view = topo.view(rank);
        Self {
            dp_group: view.dp_group.clone(),
            edp_group: view.edp_group.clone(),
        }
    }

    /// The reduction group for a parameter class.
    pub fn group_for(&self, class: ParamClass) -> &[usize] {
        match class {
            ParamClass::Attention => &self.dp_group,
            ParamClass::Expert => &self.edp_group,
        }
    }

    /// Mean all-reduce of `grad` over the class's group, in place. A
    /// singleton group is a no-op (no replication on that axis).
    pub fn reduce_mean(&self, comm: &Communicator, class: ParamClass, grad: &mut [f32]) {
        let h = self.reduce_mean_i(comm, class, grad);
        comm.wait(h);
    }

    /// Nonblocking [`Self::reduce_mean`]: the payload is reduced and
    /// rescaled eagerly (bit-identical to the blocking call), but the
    /// clock charge rides the returned handle — issue one per gradient
    /// bucket under the backward compute charge and
    /// [`Communicator::wait`] them afterwards, so the overlapped share is
    /// *measured* as hidden. A singleton group returns a completed handle.
    pub fn reduce_mean_i(
        &self,
        comm: &Communicator,
        class: ParamClass,
        grad: &mut [f32],
    ) -> CommHandle {
        let group = self.group_for(class);
        if group.len() <= 1 {
            return CommHandle::completed();
        }
        let h = comm.all_reduce_sum_into_i(group, grad);
        let n = group.len() as f32;
        for x in grad.iter_mut() {
            *x /= n;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ParallelConfig;
    use crate::simcomm::run_ranks;

    /// The folded dp≠edp case: attention grads average over the DP group,
    /// expert grads over the EDP group — and neither equals the flat world
    /// mean the pre-folding trainer produced.
    #[test]
    fn per_class_groups_differ_under_folding() {
        // TP2 attention vs ETP1·EP4 MoE on 8 ranks: dp = 4, edp = 2.
        let topo = RuntimeTopology::folded(ParallelConfig::new(8, 2, 1, 4, 1, 1)).unwrap();
        let outs = run_ranks(8, |rank, comm| {
            let sync = GradSync::from_topology(&topo, rank);
            let mut attn = vec![rank as f32; 3];
            let mut expert = vec![100.0 + rank as f32; 3];
            sync.reduce_mean(&comm, ParamClass::Attention, &mut attn);
            sync.reduce_mean(&comm, ParamClass::Expert, &mut expert);
            (attn[0], expert[0])
        });
        for (r, &(attn, expert)) in outs.iter().enumerate() {
            // DP group {r%2, r%2+2, r%2+4, r%2+6} -> mean = r%2 + 3.
            assert_eq!(attn, (r % 2) as f32 + 3.0, "rank {r} attention");
            // EDP group {r%4, r%4+4} -> mean = 100 + r%4 + 2.
            assert_eq!(expert, 100.0 + (r % 4) as f32 + 2.0, "rank {r} expert");
            // Both differ from the undifferentiated world means (3.5, 103.5).
            assert_ne!(attn, 3.5);
            assert_ne!(expert, 103.5);
        }
    }

    #[test]
    fn flat_sync_reduces_both_classes_over_world() {
        let outs = run_ranks(4, |rank, comm| {
            let sync = GradSync::flat(4);
            let mut g = vec![rank as f32];
            sync.reduce_mean(&comm, ParamClass::Attention, &mut g);
            let mut e = vec![rank as f32];
            sync.reduce_mean(&comm, ParamClass::Expert, &mut e);
            (g[0], e[0])
        });
        assert!(outs.iter().all(|&(a, e)| a == 1.5 && e == 1.5));
    }

    #[test]
    fn singleton_group_is_noop() {
        // pp = world: dp = edp = 1 on every rank.
        let topo = RuntimeTopology::folded(ParallelConfig::new(2, 1, 1, 1, 1, 2)).unwrap();
        let outs = run_ranks(2, |rank, comm| {
            let sync = GradSync::from_topology(&topo, rank);
            let mut g = vec![rank as f32];
            sync.reduce_mean(&comm, ParamClass::Attention, &mut g);
            g[0]
        });
        assert_eq!(outs, vec![0.0, 1.0]);
    }
}
