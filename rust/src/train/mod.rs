//! End-to-end distributed training: dense math helpers, Adam optimizer,
//! synthetic data, and the multi-rank trainer that executes AOT-compiled
//! JAX/Pallas artifacts through the PJRT runtime.

pub mod data;
pub mod math;
pub mod optimizer;
pub mod sync;
pub mod trainer;

pub use optimizer::Adam;
pub use sync::{GradSync, ParamClass};
pub use trainer::{train, CpAttnProbe, MoeCounters, MoeProbe, TrainerConfig, TrainReport};
