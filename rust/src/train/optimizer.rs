//! Adam optimizer over flat f32 parameter tensors (the Rust side of the
//! training loop: the AOT train-step artifact returns gradients, Rust owns
//! the optimizer state and update — mirroring Megatron's distributed
//! optimizer split).

/// Adam with bias correction (no weight decay by default).
#[derive(Debug, Clone)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub step: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    pub fn new(lr: f32, shapes: &[usize]) -> Self {
        Self {
            lr,
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            weight_decay: 0.0,
            step: 0,
            m: shapes.iter().map(|&n| vec![0.0; n]).collect(),
            v: shapes.iter().map(|&n| vec![0.0; n]).collect(),
        }
    }

    /// Apply one update step in place. `params[i].len()` must match the
    /// shapes given at construction.
    pub fn update(&mut self, params: &mut [Vec<f32>], grads: &[Vec<f32>]) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.step += 1;
        let b1t = 1.0 - self.beta1.powi(self.step as i32);
        let b2t = 1.0 - self.beta2.powi(self.step as i32);
        for ((p, g), (m, v)) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            assert_eq!(p.len(), g.len());
            for i in 0..p.len() {
                let gi = g[i] + self.weight_decay * p[i];
                m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * gi;
                v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * gi * gi;
                let mhat = m[i] / b1t;
                let vhat = v[i] / b2t;
                p[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
            }
        }
    }

    /// Global gradient-norm clipping; returns the pre-clip norm.
    pub fn clip_grads(grads: &mut [Vec<f32>], max_norm: f32) -> f32 {
        let norm: f32 = grads
            .iter()
            .map(|g| g.iter().map(|x| x * x).sum::<f32>())
            .sum::<f32>()
            .sqrt();
        if norm > max_norm && norm > 0.0 {
            let scale = max_norm / norm;
            for g in grads.iter_mut() {
                for x in g.iter_mut() {
                    *x *= scale;
                }
            }
        }
        norm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_minimizes_quadratic() {
        // f(x) = Σ (x - 3)^2, gradient 2(x-3).
        let mut params = vec![vec![0.0f32; 4]];
        let mut opt = Adam::new(0.1, &[4]);
        for _ in 0..200 {
            let grads = vec![params[0].iter().map(|x| 2.0 * (x - 3.0)).collect()];
            opt.update(&mut params, &grads);
        }
        for x in &params[0] {
            assert!((x - 3.0).abs() < 0.05, "{x}");
        }
    }

    #[test]
    fn clip_scales_to_max_norm() {
        let mut grads = vec![vec![3.0f32, 4.0]];
        let pre = Adam::clip_grads(&mut grads, 1.0);
        assert!((pre - 5.0).abs() < 1e-6);
        let post: f32 = grads[0].iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((post - 1.0).abs() < 1e-5);
    }

    #[test]
    fn clip_noop_when_small() {
        let mut grads = vec![vec![0.1f32, 0.1]];
        Adam::clip_grads(&mut grads, 1.0);
        assert_eq!(grads[0], vec![0.1, 0.1]);
    }

    #[test]
    fn deterministic_updates() {
        let mut p1 = vec![vec![1.0f32; 8]];
        let mut p2 = p1.clone();
        let mut o1 = Adam::new(0.01, &[8]);
        let mut o2 = Adam::new(0.01, &[8]);
        let g = vec![vec![0.5f32; 8]];
        for _ in 0..10 {
            o1.update(&mut p1, &g);
            o2.update(&mut p2, &g);
        }
        assert_eq!(p1, p2);
    }
}
