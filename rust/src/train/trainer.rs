//! The end-to-end trainer: Rust coordinator executing the AOT train-step
//! artifact via PJRT, with data-parallel ranks over the functional
//! communicator, gradient all-reduce, clipping and Adam — Python is never
//! on the step path.
//!
//! Gradient synchronization is **per parameter class**
//! ([`super::GradSync`]): with a folded [`ParallelConfig`] attached
//! ([`TrainerConfig::parallel`]), attention parameters all-reduce over the
//! rank's attention-DP group and expert parameters over its EDP group —
//! the Megatron-Core data-parallel vs expert-data-parallel split that a
//! single flat all-reduce gets wrong whenever `dp != edp`. Without a
//! topology the trainer degenerates to flat DP over `cfg.dp` ranks.

use std::sync::Arc;
use std::time::Instant;

use crate::anyhow;
use crate::attention::{zigzag, AttnConfig, AttnPhaseCost, AttnWeights, DistributedAttentionLayer};
use crate::cluster::ClusterSpec;
use crate::collectives::CommCost;
use crate::config::{DropPolicy, ParallelConfig};
use crate::dispatcher::{Balancer, LoadStats, RouterConfig, SkewGen, SkewProfile};
use crate::mapping::RuntimeTopology;
use crate::runtime::{InputBuf, InputRef, Runtime};
use crate::simcomm::{run_ranks_on, AlgoSelection, Fabric};
use crate::util::error::Result;
use crate::util::Rng;

use super::data::SyntheticCorpus;
use super::optimizer::Adam;
use super::sync::{GradSync, ParamClass};

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    /// Artifact preset name ("test", "e2e").
    pub preset: String,
    pub artifacts_dir: String,
    pub steps: usize,
    pub lr: f32,
    /// Data-parallel ranks (threads). Gradients are mean-all-reduced.
    pub dp: usize,
    pub seed: u64,
    pub log_every: usize,
    pub clip_norm: f32,
    /// Collective algorithms for the gradient all-reduce (ring by default;
    /// `AlgoSelection::naive()` reproduces the leader-based oracle bit-for-bit
    /// — every algorithm reduces in rank order, see [`crate::simcomm`]).
    pub algos: AlgoSelection,
    /// Optional folded parallel topology. When set, `world_size` rank
    /// threads run (ignoring `dp`), ranks sharing an attention-DP
    /// coordinate consume the same data (model-parallel peers replicate
    /// their microbatch), and gradients reduce per parameter class over the
    /// topology's DP/EDP groups. `None` keeps the flat-DP behaviour.
    pub parallel: Option<ParallelConfig>,
    /// Indices (into the artifact's parameter tensors) holding expert
    /// weights — these reduce over EDP instead of attention-DP. Only
    /// meaningful together with `parallel`.
    pub expert_param_indices: Vec<usize>,
    /// Run on a **clocked** fabric: gradient collectives advance per-rank
    /// simulated time (priced by the shared `CommCost`), and the report
    /// carries a measured-in-sim step time next to the wall-clock numbers.
    /// The clock never perturbs payloads — losses are bit-identical.
    pub clocked: bool,
    /// Simulated compute charged per rank per step, µs (the artifact's
    /// model-scale fwd+bwd time; 0 = comm-only clock).
    pub compute_us_per_step: f64,
    /// Model FLOPs per token for the measured-in-sim MFU (0 disables).
    pub flops_per_token: f64,
    /// Issue the per-parameter gradient reductions **nonblocking** under
    /// the backward share of `compute_us_per_step` (bucketed
    /// grad-reduce-under-backward). Payloads and losses are bit-identical
    /// to the serialized trainer — property-tested — and on a clocked run
    /// the report splits the measured hidden vs exposed comm.
    pub overlap_grad_reduce: bool,
    /// Run a **CP-sharded attention forward** each step (requires
    /// `parallel`): every rank executes its zig-zag shard of a real ring
    /// attention over its CP group ([`DistributedAttentionLayer`]) on a
    /// shared per-step token block. The ring's payload math never touches
    /// the artifact path (losses stay bit-identical across `cp`), the
    /// measured hidden/exposed KV transfer time lands in the report, and
    /// the step-0 full-sequence attention output
    /// ([`TrainReport::cp_attn_digest`]) is the bit-comparable witness the
    /// CP differential suite checks across `cp ∈ {1, 2, 4}`.
    pub cp_attention: Option<CpAttnProbe>,
    /// Run a **skew-routing probe** each step ([`MoeProbe`]): every rank
    /// routes a skewed token stream through a stand-in MoE router,
    /// all-reduces the expert loads so replicated balancer state stays
    /// identical, and the report carries the measured drop rate, capacity
    /// violations, and load-balance quality (ISSUE 9). Payload-disjoint
    /// from the artifact path — losses are bit-identical with and without
    /// the probe.
    pub moe_probe: Option<MoeProbe>,
}

/// Configuration of the trainer's skew-routing probe.
#[derive(Debug, Clone)]
pub struct MoeProbe {
    /// Tokens routed per rank per step (the bursty schedule peaks at 4×).
    pub tokens_per_step: usize,
    /// Stand-in hidden size (must be ≥ `num_experts`: the probe routes
    /// through the [`SkewGen`] identity gate).
    pub hidden: usize,
    pub num_experts: usize,
    pub top_k: usize,
    pub capacity_factor: f64,
    pub drop_policy: DropPolicy,
    pub pad_to_capacity: bool,
    pub balancer: Balancer,
    pub skew: SkewProfile,
    /// Vary the per-step token count with [`SkewGen::burst_schedule`]
    /// (base `tokens_per_step`, peak 4×, period 8 steps).
    pub bursty: bool,
}

impl Default for MoeProbe {
    fn default() -> Self {
        Self {
            tokens_per_step: 64,
            hidden: 32,
            num_experts: 8,
            top_k: 2,
            capacity_factor: 1.0,
            drop_policy: DropPolicy::SubSequence,
            pad_to_capacity: false,
            balancer: Balancer::AuxLoss,
            skew: SkewProfile::Zipf { exponent: 1.2 },
            bursty: false,
        }
    }
}

/// Per-rank accumulated counters of the skew-routing probe.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MoeCounters {
    pub tokens_routed: usize,
    pub tokens_dropped: usize,
    /// Expert-step events where the (globally reduced) kept load exceeded
    /// the CF-nominal capacity — only dropless can violate, that's the
    /// dynamic-shape overflow the capacity policies trade against.
    pub capacity_violations: usize,
    /// Sum over balance-carrying steps of the normalized global-load
    /// entropy. Steps whose global load was all-zero (every copy dropped,
    /// or an empty decode microstep) yield the [`LoadStats`] NaN sentinel
    /// and are excluded — they carry no balance information.
    pub entropy_sum: f64,
    /// Sum over balance-carrying steps of global max/mean load imbalance.
    pub imbalance_sum: f64,
    pub steps: usize,
    /// Steps that contributed to `entropy_sum`/`imbalance_sum` (non-empty
    /// global load). The balance means divide by this, not `steps`.
    pub balance_steps: usize,
}

/// Configuration of the trainer's CP-sharded attention forward.
#[derive(Debug, Clone)]
pub struct CpAttnProbe {
    /// Full sequence rows per step (must divide over `2·cp·tp` and
    /// `kv_chunks`).
    pub seq_len: usize,
    pub hidden: usize,
    pub num_heads: usize,
    /// Canonical LSE-combine grid; keep it fixed across the `cp` values
    /// being compared (see [`crate::attention`]).
    pub kv_chunks: usize,
    /// Zig-zag (balanced) vs contiguous sharding.
    pub zigzag: bool,
    /// Billed-bytes multiplier on the KV ring (model-scale billing for
    /// stand-in payloads); payload math unaffected.
    pub kv_bill_scale: f64,
    /// µs charged per allowed (query, key) pair on clocked runs (0 = no
    /// core charge — ring comm only).
    pub core_us_per_pair: f64,
}

impl Default for CpAttnProbe {
    fn default() -> Self {
        Self {
            seq_len: 64,
            hidden: 32,
            num_heads: 4,
            kv_chunks: 8,
            zigzag: true,
            kv_bill_scale: 1.0,
            core_us_per_pair: 0.0,
        }
    }
}

/// Share of `compute_us_per_step` charged as forward (the rest is the
/// backward window overlapped gradient reductions can hide under).
const FWD_COMPUTE_FRAC: f64 = 1.0 / 3.0;

impl Default for TrainerConfig {
    fn default() -> Self {
        Self {
            preset: "test".into(),
            artifacts_dir: "artifacts".into(),
            steps: 20,
            lr: 1e-3,
            dp: 1,
            seed: 42,
            log_every: 10,
            clip_norm: 1.0,
            algos: AlgoSelection::fast(),
            parallel: None,
            expert_param_indices: Vec::new(),
            clocked: false,
            compute_us_per_step: 0.0,
            flops_per_token: 0.0,
            overlap_grad_reduce: false,
            cp_attention: None,
            moe_probe: None,
        }
    }
}

/// Outcome of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub losses: Vec<(usize, f32)>,
    pub wall_seconds: f64,
    pub tokens_per_second: f64,
    pub num_params: usize,
    pub final_loss: f32,
    pub initial_loss: f32,
    /// Measured-in-sim step time (virtual clock, µs per step) when the
    /// trainer ran clocked (`TrainerConfig::clocked`).
    pub sim_step_us: Option<f64>,
    /// Measured-in-sim MFU vs the **BF16** peak (needs `flops_per_token`
    /// and a clocked run; the trainer has no precision knob).
    pub sim_mfu: Option<f64>,
    /// Gradient-reduce time hidden under backward compute (µs per step,
    /// rank 0, clocked runs with `overlap_grad_reduce`).
    pub sim_hidden_comm_us: Option<f64>,
    /// Gradient-reduce time the compute lane waited for (µs per step,
    /// rank 0, clocked runs).
    pub sim_exposed_comm_us: Option<f64>,
    /// CP ring KV transfer time hidden under the attention core (µs per
    /// step, rank 0, clocked runs with `cp_attention`).
    pub sim_cp_hidden_us: Option<f64>,
    /// CP ring time the compute lane waited for (µs per step, rank 0).
    pub sim_cp_exposed_us: Option<f64>,
    /// Step-0 full-sequence attention output of the CP-sharded forward
    /// (rank 0's TP × CP block, gathered + unsharded) — bit-identical
    /// across `cp` at a fixed TP, pinned by `tests/cp_equivalence.rs`.
    pub cp_attn_digest: Option<Vec<f32>>,
    /// Fraction of the probe's token-copies dropped (runs with
    /// [`TrainerConfig::moe_probe`]; rank 0's stream).
    pub moe_drop_rate: Option<f64>,
    /// Expert-step events where the global kept load exceeded the
    /// CF-nominal capacity (dropless overflow pressure).
    pub moe_capacity_violations: Option<usize>,
    /// Mean normalized entropy of the global expert load (1.0 = balanced).
    pub moe_balance_entropy: Option<f64>,
    /// Mean max/mean global expert-load imbalance (1.0 = balanced).
    pub moe_load_imbalance: Option<f64>,
}

impl TrainReport {
    pub fn loss_csv(&self) -> String {
        let mut s = String::from("step,loss\n");
        for (step, loss) in &self.losses {
            s.push_str(&format!("{step},{loss}\n"));
        }
        s
    }
}

/// Initialize parameters from the manifest's input specs (rank-based
/// heuristic: vectors → ones, matrices/tensors → scaled normal).
pub fn init_params_from_spec(
    specs: &[crate::runtime::TensorSpec],
    n_tensors: usize,
    seed: u64,
) -> (Vec<Vec<f32>>, Vec<Vec<usize>>) {
    let mut rng = Rng::seed_from_u64(seed);
    let mut params = Vec::with_capacity(n_tensors);
    let mut dims = Vec::with_capacity(n_tensors);
    for spec in specs.iter().take(n_tensors) {
        let n = spec.elements();
        let d = spec.dims.clone();
        let mut buf = vec![0.0f32; n];
        match d.len() {
            0 | 1 => buf.fill(1.0), // norm weights
            2 => {
                let fan = d[0].min(d[1]) as f32;
                rng.fill_normal(&mut buf, (1.0 / fan).sqrt());
            }
            _ => {
                let fan = d[d.len() - 2] as f32;
                rng.fill_normal(&mut buf, (1.0 / fan).sqrt());
            }
        }
        params.push(buf);
        dims.push(d);
    }
    (params, dims)
}

/// Run data-parallel training. `cfg.dp` rank threads each execute the
/// train-step artifact on their own microbatch; gradients are averaged over
/// the DP group (deterministic rank-ordered reduction); every rank applies
/// the identical Adam update, so parameters never diverge.
pub fn train(cfg: &TrainerConfig) -> Result<TrainReport> {
    if cfg.cp_attention.is_some() && cfg.parallel.is_none() {
        return Err(anyhow!(
            "cp_attention needs a parallel topology (TrainerConfig::parallel) \
             to derive CP/TP groups from"
        ));
    }
    let runtime = Arc::new(Runtime::cpu(&cfg.artifacts_dir)?);
    let step_name = format!("{}_train_step", cfg.preset);
    let exe = runtime.load(&step_name)?;
    let spec = exe
        .spec
        .clone()
        .ok_or_else(|| anyhow!("no manifest entry for {step_name}"))?;
    let n_tensors = runtime
        .meta_usize(&format!("{}.num_param_tensors", cfg.preset))
        .ok_or_else(|| anyhow!("missing num_param_tensors meta"))?;
    let num_params = runtime
        .meta_usize(&format!("{}.num_params", cfg.preset))
        .unwrap_or(0);
    let batch = runtime
        .meta_usize(&format!("{}.batch", cfg.preset))
        .ok_or_else(|| anyhow!("missing batch meta"))?;
    let seq = runtime
        .meta_usize(&format!("{}.seq", cfg.preset))
        .ok_or_else(|| anyhow!("missing seq meta"))?;
    let vocab = runtime
        .meta_usize(&format!("{}.vocab", cfg.preset))
        .ok_or_else(|| anyhow!("missing vocab meta"))?;

    let (init_params, param_dims) = init_params_from_spec(&spec.inputs, n_tensors, cfg.seed);
    let shapes: Vec<usize> = init_params.iter().map(|p| p.len()).collect();

    let t0 = Instant::now();
    let topo = match cfg.parallel {
        Some(p) => Some(RuntimeTopology::folded(p).map_err(|e| anyhow!(e))?),
        None => None,
    };
    let world = topo
        .as_ref()
        .map(|t| t.world())
        .unwrap_or(cfg.dp.max(1));
    // Data-parallel replica count for the sim-MFU token accounting (the
    // topology is moved into the rank closure below).
    let replicas = topo.as_ref().map(|t| t.config().dp()).unwrap_or(world);
    let cfg2 = cfg.clone();
    let runtime2 = runtime.clone();

    // Each rank runs the identical loop; rank 0's log is the report. A
    // clocked fabric advances simulated time alongside (never perturbing
    // payloads); the plain fabric is byte-for-byte the old behaviour.
    let cluster = ClusterSpec::eos(world);
    let fabric = if cfg.clocked {
        Fabric::new_clocked(world, cfg.algos, CommCost::new(cluster.clone()))
    } else {
        Fabric::new_with(world, cfg.algos)
    };
    type RankOut = (Vec<(usize, f32)>, f64, f64, f64, f64, Option<Vec<f32>>, Option<MoeCounters>);
    let reports = run_ranks_on(&fabric, move |rank, comm| -> Result<RankOut> {
        let exe = runtime2.load(&step_name)?;
        // Reduction groups per parameter class: topology DP/EDP groups
        // under folding, the flat world group otherwise.
        let sync = match &topo {
            Some(t) => GradSync::from_topology(t, rank),
            None => GradSync::flat(world),
        };
        // CP-sharded attention forward: this rank's slice of a ring
        // attention over its CP group, weights replicated from the seed.
        let cp_layer = topo.as_ref().zip(cfg2.cp_attention.as_ref()).map(|(t, probe)| {
            let mut wrng = Rng::seed_from_u64(cfg2.seed ^ 0xA77E);
            let weights = AttnWeights::init(probe.hidden, &mut wrng);
            let acfg = AttnConfig {
                hidden: probe.hidden,
                num_heads: probe.num_heads,
                kv_chunks: probe.kv_chunks,
                zigzag: probe.zigzag,
            };
            let mut layer = DistributedAttentionLayer::from_topology(t.view(rank), acfg, &weights)
                .with_kv_bill_scale(probe.kv_bill_scale);
            if probe.core_us_per_pair > 0.0 {
                layer = layer
                    .with_phase_cost(AttnPhaseCost { core_us_per_pair: probe.core_us_per_pair });
            }
            layer
        });
        // Model-parallel peers (same attention-DP coordinate) replicate
        // their microbatch stream; distinct DP replicas draw distinct data.
        let data_replica = topo.as_ref().map(|t| t.view(rank).dp_index).unwrap_or(rank);
        let mut params = init_params.clone();
        let mut opt = Adam::new(cfg2.lr, &shapes);
        let mut corpus =
            SyntheticCorpus::new(vocab, cfg2.seed.wrapping_add(1000 + data_replica as u64));
        let mut losses = Vec::new();
        let mut hidden_us = 0.0f64;
        let mut exposed_us = 0.0f64;
        let mut cp_hidden_us = 0.0f64;
        let mut cp_exposed_us = 0.0f64;
        let mut cp_digest: Option<Vec<f32>> = None;
        let overlap = cfg2.overlap_grad_reduce && world > 1;

        // Skew-routing probe: a per-rank skewed stream through a stand-in
        // router. Balancer state (the aux-loss-free bias) updates from the
        // *globally reduced* load, so every rank's router replica stays
        // bit-identical — the DeepSeek-V3 global-batch bias rule.
        let mut moe_state = cfg2.moe_probe.as_ref().map(|probe| {
            let gen = SkewGen::new(
                probe.skew,
                probe.num_experts,
                probe.hidden,
                cfg2.seed ^ 0x5EED ^ rank as u64,
            );
            let router = gen.router(RouterConfig {
                hidden: probe.hidden,
                num_experts: probe.num_experts,
                top_k: probe.top_k,
                capacity_factor: probe.capacity_factor,
                drop_policy: probe.drop_policy,
                capacity_override: None,
                pad_to_capacity: probe.pad_to_capacity,
                node_limit: None,
                balancer: probe.balancer,
            });
            let schedule = if probe.bursty {
                SkewGen::burst_schedule(
                    cfg2.seed,
                    cfg2.steps,
                    probe.tokens_per_step,
                    probe.tokens_per_step * 4,
                    8,
                )
            } else {
                vec![probe.tokens_per_step; cfg2.steps]
            };
            (gen, router, schedule, MoeCounters::default())
        });
        let world_group: Vec<usize> = (0..world).collect();

        for step in 0..cfg2.steps {
            let ids = corpus.batch(batch, seq);
            let (inputs, targets) = SyntheticCorpus::split(&ids, batch, seq);

            // CP-sharded attention forward on a shared per-step token
            // block: real zig-zag ring over the CP group, its KV transfer
            // measured on the clock. Separate RNG streams and message tags
            // keep it payload-disjoint from the artifact path, so losses
            // are bit-identical with and across `cp`.
            if let (Some(layer), Some(probe)) = (&cp_layer, &cfg2.cp_attention) {
                let mut trng = Rng::seed_from_u64(
                    cfg2.seed ^ 0xC0FFEE ^ (step as u64).wrapping_mul(0x9E37_79B9),
                );
                let mut toks = vec![0.0f32; probe.seq_len * probe.hidden];
                trng.fill_normal(&mut toks, 1.0);
                let slice = layer.input_slice(&toks);
                let (out, st) = layer.forward(&comm, &slice, probe.seq_len);
                cp_hidden_us += st.cp_hidden_us;
                cp_exposed_us += st.cp_exposed_us;
                if step == 0 {
                    // Full-sequence witness: gather over TP, then CP, then
                    // undo the zig-zag — pure row movement, bit-exact.
                    let shard_out = if layer.tp_group.len() > 1 {
                        comm.all_gather_v(&layer.tp_group, &out)
                    } else {
                        out
                    };
                    let all = comm.all_gather_v(&layer.cp_group, &shard_out);
                    let cpn = layer.cp_group.len();
                    let per = all.len() / cpn;
                    let shards: Vec<Vec<f32>> =
                        (0..cpn).map(|i| all[i * per..(i + 1) * per].to_vec()).collect();
                    cp_digest = Some(zigzag::unshard(&shards, probe.hidden, probe.zigzag));
                }
            }
            // Skew-routing probe: route this step's (possibly bursty)
            // token budget, reduce the loads globally, update balancer
            // state, and accumulate the report counters. Route-only — no
            // dispatch payload touches the artifact path.
            if let Some((gen, router, schedule, counters)) = &mut moe_state {
                let n = schedule[step];
                let d = router.route(&gen.next_tokens(n));
                let dropped = d.assignments.iter().filter(|a| !a.kept).count();
                counters.tokens_routed += d.assignments.len() - dropped;
                counters.tokens_dropped += dropped;
                let mut global: Vec<f32> = d.expert_load.iter().map(|&l| l as f32).collect();
                if world > 1 {
                    comm.all_reduce_sum_into(&world_group, &mut global);
                }
                let global: Vec<usize> = global.iter().map(|&l| l.round() as usize).collect();
                let nominal = router.capacity_for(n) * world;
                counters.capacity_violations += global.iter().filter(|&&l| l > nominal).count();
                let ls = LoadStats::from_load(&global);
                if !ls.is_empty() {
                    counters.entropy_sum += ls.entropy;
                    counters.imbalance_sum += ls.imbalance;
                    counters.balance_steps += 1;
                }
                counters.steps += 1;
                router.update_bias(&global);
            }

            // Model-scale compute charge for the artifact's fwd+bwd (the
            // clock's compute phase; no-op on unclocked fabrics). With
            // grad-reduce overlap the backward share is charged *after*
            // the nonblocking reductions are issued, so they can hide
            // under it.
            if overlap {
                comm.advance("fwd", cfg2.compute_us_per_step * FWD_COMPUTE_FRAC);
            } else {
                comm.advance("fwd_bwd", cfg2.compute_us_per_step);
            }

            // Borrowed views: no param clone per step (perf pass §Perf).
            let io_dims = [batch, seq];
            let mut bufs: Vec<InputRef> = params
                .iter()
                .zip(&param_dims)
                .map(|(p, d)| InputRef::F32(p, d))
                .collect();
            bufs.push(InputRef::I32(&inputs, &io_dims));
            bufs.push(InputRef::I32(&targets, &io_dims));

            let outs = exe.run_f32_refs(&bufs)?;
            let mut loss = outs[0][0];
            let mut grads: Vec<Vec<f32>> = outs[1..].to_vec();

            if world > 1 {
                // Average gradients per parameter class — attention params
                // over the attention-DP group, expert params over EDP — in
                // place, so steady-state steps allocate no gradient buffers
                // (the fabric's pooled scratch carries the chunks). The
                // payload work is identical on both paths (bitwise-equal
                // losses); overlap defers only the clock charge.
                let class_of = |i: usize| {
                    if cfg2.expert_param_indices.contains(&i) {
                        ParamClass::Expert
                    } else {
                        ParamClass::Attention
                    }
                };
                if overlap {
                    let mut handles = Vec::with_capacity(grads.len());
                    for (i, g) in grads.iter_mut().enumerate() {
                        handles.push(sync.reduce_mean_i(&comm, class_of(i), g));
                    }
                    // The backward window the bucketed reductions hide
                    // under.
                    comm.advance("bwd", cfg2.compute_us_per_step * (1.0 - FWD_COMPUTE_FRAC));
                    for h in handles {
                        let (hid, exp) = comm.wait_split(h);
                        hidden_us += hid;
                        exposed_us += exp;
                    }
                } else {
                    for (i, g) in grads.iter_mut().enumerate() {
                        sync.reduce_mean(&comm, class_of(i), g);
                    }
                }
                // The logged loss averages over this rank's DP group (the
                // whole world in the flat case).
                let dp_group = sync.group_for(ParamClass::Attention);
                if dp_group.len() > 1 {
                    let mut l = [loss];
                    comm.all_reduce_sum_into(dp_group, &mut l);
                    loss = l[0] / dp_group.len() as f32;
                }
            }

            Adam::clip_grads(&mut grads, cfg2.clip_norm);
            opt.update(&mut params, &grads);
            losses.push((step, loss));
            if rank == 0 && (step % cfg2.log_every == 0 || step + 1 == cfg2.steps) {
                eprintln!("step {step:>5}  loss {loss:.4}");
            }
        }
        let moe_counters = moe_state.map(|(_, _, _, counters)| counters);
        Ok((losses, hidden_us, exposed_us, cp_hidden_us, cp_exposed_us, cp_digest, moe_counters))
    });

    let (
        losses,
        hidden_total_us,
        exposed_total_us,
        cp_hid_us,
        cp_exp_us,
        cp_attn_digest,
        moe_counters,
    ) = reports
        .into_iter()
        .next()
        .ok_or_else(|| anyhow!("no rank output"))??;
    let wall = t0.elapsed().as_secs_f64();
    let tokens = cfg.steps * batch * seq * world;
    // Measured-in-sim step time: the slowest rank's virtual clock, per
    // optimizer step; MFU from it when the caller supplied a FLOP count.
    let (sim_step_us, sim_mfu) = if cfg.clocked && cfg.steps > 0 {
        let step_us = fabric.max_sim_time_us() / cfg.steps as f64;
        let mfu = if cfg.flops_per_token > 0.0 && step_us > 0.0 {
            let tokens_per_step = (batch * seq * replicas) as f64;
            // The trainer has no precision knob, so sim-MFU is always vs
            // the BF16 peak — stated in the TrainReport field docs (the
            // executed step estimator normalizes by the run's precision).
            let peak = cluster.gpu.peak_bf16_tflops * 1e12;
            // fwd+bwd model FLOPs / (step time × world × peak).
            Some(cfg.flops_per_token * tokens_per_step / (step_us / 1e6) / world as f64 / peak)
        } else {
            None
        };
        (Some(step_us), mfu)
    } else {
        (None, None)
    };
    let (sim_hidden_comm_us, sim_exposed_comm_us) = if cfg.clocked && cfg.steps > 0 {
        (
            Some(hidden_total_us / cfg.steps as f64),
            Some(exposed_total_us / cfg.steps as f64),
        )
    } else {
        (None, None)
    };
    let (sim_cp_hidden_us, sim_cp_exposed_us) =
        if cfg.clocked && cfg.steps > 0 && cfg.cp_attention.is_some() {
            (
                Some(cp_hid_us / cfg.steps as f64),
                Some(cp_exp_us / cfg.steps as f64),
            )
        } else {
            (None, None)
        };
    let (moe_drop_rate, moe_capacity_violations, moe_balance_entropy, moe_load_imbalance) =
        match moe_counters {
            Some(c) => {
                let total = (c.tokens_routed + c.tokens_dropped).max(1);
                // Balance means divide by the steps that actually carried
                // load — all-zero steps are NaN sentinels and were skipped.
                let steps = c.balance_steps.max(1) as f64;
                (
                    Some(c.tokens_dropped as f64 / total as f64),
                    Some(c.capacity_violations),
                    Some(c.entropy_sum / steps),
                    Some(c.imbalance_sum / steps),
                )
            }
            None => (None, None, None, None),
        };
    Ok(TrainReport {
        initial_loss: losses.first().map(|x| x.1).unwrap_or(f32::NAN),
        final_loss: losses.last().map(|x| x.1).unwrap_or(f32::NAN),
        losses,
        wall_seconds: wall,
        tokens_per_second: tokens as f64 / wall,
        num_params,
        sim_step_us,
        sim_mfu,
        sim_hidden_comm_us,
        sim_exposed_comm_us,
        sim_cp_hidden_us,
        sim_cp_exposed_us,
        cp_attn_digest,
        moe_drop_rate,
        moe_capacity_violations,
        moe_balance_entropy,
        moe_load_imbalance,
    })
}

/// Evaluate the eval-loss artifact on held-out synthetic data with the given
/// parameters (used by the loss-equivalence example).
pub fn eval_loss(
    runtime: &Runtime,
    preset: &str,
    params: &[Vec<f32>],
    param_dims: &[Vec<usize>],
    inputs: Vec<i32>,
    targets: Vec<i32>,
    batch: usize,
    seq: usize,
) -> Result<f32> {
    let exe = runtime.load(&format!("{preset}_eval_loss"))?;
    let mut bufs: Vec<InputBuf> = params
        .iter()
        .zip(param_dims)
        .map(|(p, d)| InputBuf::f32(p.clone(), d))
        .collect();
    bufs.push(InputBuf::i32(inputs, &[batch, seq]));
    bufs.push(InputBuf::i32(targets, &[batch, seq]));
    Ok(exe.run_f32(&bufs)?[0][0])
}
