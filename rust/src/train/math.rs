//! Minimal dense f32 math used by the functional dispatcher/trainer paths
//! (reference expert FFNs, router gating). Row-major layout throughout.

/// C[m×n] = A[m×k] · B[k×n].
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    c
}

/// C = A · B^T where B is [n×k].
pub fn matmul_bt(a: &[f32], b_t: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b_t.len(), n * k);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            let ar = &a[i * k..(i + 1) * k];
            let br = &b_t[j * k..(j + 1) * k];
            for (x, y) in ar.iter().zip(br) {
                acc += x * y;
            }
            c[i * n + j] = acc;
        }
    }
    c
}

/// SiLU activation x * sigmoid(x).
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Row-wise softmax over an [n × e] matrix, in place.
pub fn softmax_rows(x: &mut [f32], n: usize, e: usize) {
    for i in 0..n {
        let row = &mut x[i * e..(i + 1) * e];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// A SwiGLU expert FFN: y = W_down( silu(W_gate x) ⊙ (W_up x) ).
/// Weights are row-major: w_gate/w_up are [h × f], w_down is [f × h].
#[derive(Debug, Clone)]
pub struct SwigluExpert {
    pub h: usize,
    pub f: usize,
    pub w_gate: Vec<f32>,
    pub w_up: Vec<f32>,
    pub w_down: Vec<f32>,
}

impl SwigluExpert {
    /// Deterministic pseudo-random init.
    pub fn init(h: usize, f: usize, rng: &mut crate::util::Rng) -> Self {
        let std_in = (1.0 / h as f32).sqrt();
        let std_out = (1.0 / f as f32).sqrt();
        let mut w_gate = vec![0.0; h * f];
        let mut w_up = vec![0.0; h * f];
        let mut w_down = vec![0.0; f * h];
        rng.fill_normal(&mut w_gate, std_in);
        rng.fill_normal(&mut w_up, std_in);
        rng.fill_normal(&mut w_down, std_out);
        Self { h, f, w_gate, w_up, w_down }
    }

    /// Forward over `n` tokens [n × h] -> [n × h].
    pub fn forward(&self, tokens: &[f32]) -> Vec<f32> {
        let n = tokens.len() / self.h;
        let g = matmul(tokens, &self.w_gate, n, self.h, self.f);
        let u = matmul(tokens, &self.w_up, n, self.h, self.f);
        let mut a = vec![0.0f32; n * self.f];
        for i in 0..a.len() {
            a[i] = silu(g[i]) * u[i];
        }
        matmul(&a, &self.w_down, n, self.f, self.h)
    }

    /// Column shard of this expert for ETP: ranks split the FFN dimension.
    /// Summing the shard outputs over the ETP group reproduces `forward`.
    pub fn shard(&self, etp: usize, idx: usize) -> SwigluExpert {
        assert_eq!(self.f % etp, 0);
        let fs = self.f / etp;
        let mut w_gate = vec![0.0; self.h * fs];
        let mut w_up = vec![0.0; self.h * fs];
        for r in 0..self.h {
            let src = &self.w_gate[r * self.f + idx * fs..r * self.f + (idx + 1) * fs];
            w_gate[r * fs..(r + 1) * fs].copy_from_slice(src);
            let src = &self.w_up[r * self.f + idx * fs..r * self.f + (idx + 1) * fs];
            w_up[r * fs..(r + 1) * fs].copy_from_slice(src);
        }
        let w_down = self.w_down[idx * fs * self.h..(idx + 1) * fs * self.h].to_vec();
        SwigluExpert { h: self.h, f: fs, w_gate, w_up, w_down }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let i = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &i, 2, 2, 2), a);
    }

    #[test]
    fn matmul_known() {
        // [[1,2],[3,4]] x [[1,1],[1,1]] = [[3,3],[7,7]]
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![1.0, 1.0, 1.0, 1.0];
        assert_eq!(matmul(&a, &b, 2, 2, 2), vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_bt_matches() {
        let mut rng = Rng::seed_from_u64(1);
        let mut a = vec![0.0; 3 * 4];
        let mut b = vec![0.0; 4 * 5];
        rng.fill_normal(&mut a, 1.0);
        rng.fill_normal(&mut b, 1.0);
        // B^T is [5x4]
        let mut bt = vec![0.0; 5 * 4];
        for i in 0..4 {
            for j in 0..5 {
                bt[j * 4 + i] = b[i * 5 + j];
            }
        }
        let c1 = matmul(&a, &b, 3, 4, 5);
        let c2 = matmul_bt(&a, &bt, 3, 4, 5);
        for (x, y) in c1.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut x, 2, 3);
        for i in 0..2 {
            let s: f32 = x[i * 3..(i + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(x[2] > x[1] && x[1] > x[0]);
    }

    #[test]
    fn expert_shards_sum_to_full() {
        let mut rng = Rng::seed_from_u64(7);
        let e = SwigluExpert::init(8, 16, &mut rng);
        let mut tokens = vec![0.0; 3 * 8];
        rng.fill_normal(&mut tokens, 1.0);
        let full = e.forward(&tokens);
        for etp in [2usize, 4] {
            let mut sum = vec![0.0f32; full.len()];
            for idx in 0..etp {
                let part = e.shard(etp, idx).forward(&tokens);
                for (s, p) in sum.iter_mut().zip(&part) {
                    *s += p;
                }
            }
            for (a, b) in full.iter().zip(&sum) {
                assert!((a - b).abs() < 1e-4, "etp={etp}: {a} vs {b}");
            }
        }
    }
}
