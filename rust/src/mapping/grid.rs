//! Generic N-dimensional rank grid.
//!
//! A [`Grid`] reshapes the flat rank range `0..world` into named axes
//! (slowest first) and derives, for each axis, the partition of ranks into
//! process groups: two ranks are in the same group for axis `i` iff their
//! coordinates agree on every *other* axis.

use std::collections::BTreeMap;

use super::{GroupPartition, GroupSet};

/// An N-D reshape of `0..world` with named axes, slowest-varying first.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Grid {
    pub world: usize,
    /// (name, extent), slowest first.
    pub axes: Vec<(String, usize)>,
    /// stride of each axis in the flat rank id.
    strides: Vec<usize>,
}

impl Grid {
    pub fn new(world: usize, axes: &[(&str, usize)]) -> Result<Self, String> {
        let prod: usize = axes.iter().map(|(_, e)| e).product();
        if prod != world {
            return Err(format!(
                "grid axes {:?} product {prod} != world {world}",
                axes
            ));
        }
        let mut strides = vec![1usize; axes.len()];
        for i in (0..axes.len().saturating_sub(1)).rev() {
            strides[i] = strides[i + 1] * axes[i + 1].1;
        }
        Ok(Self {
            world,
            axes: axes.iter().map(|(n, e)| (n.to_string(), *e)).collect(),
            strides,
        })
    }

    /// Coordinates of a flat rank.
    pub fn coords(&self, rank: usize) -> Vec<usize> {
        self.axes
            .iter()
            .zip(&self.strides)
            .map(|((_, extent), stride)| (rank / stride) % extent)
            .collect()
    }

    /// Flat rank from coordinates.
    pub fn rank(&self, coords: &[usize]) -> usize {
        coords
            .iter()
            .zip(&self.strides)
            .map(|(c, s)| c * s)
            .sum()
    }

    /// Partition of ranks into groups along `axis`.
    pub fn groups(&self, axis: &str) -> GroupPartition {
        let ai = self
            .axes
            .iter()
            .position(|(n, _)| n == axis)
            .unwrap_or_else(|| panic!("no axis {axis}"));
        let extent = self.axes[ai].1;
        let stride = self.strides[ai];
        let num_groups = self.world / extent;
        let mut out = Vec::with_capacity(num_groups);
        // Enumerate base ranks: all ranks whose coordinate on `axis` is 0.
        for base in 0..self.world {
            if (base / stride) % extent != 0 {
                continue;
            }
            out.push((0..extent).map(|k| base + k * stride).collect());
        }
        out
    }

    /// All groups for all axes.
    pub fn group_set(&self) -> GroupSet {
        let mut groups = BTreeMap::new();
        for (name, _) in &self.axes {
            groups.insert(name.clone(), self.groups(name));
        }
        GroupSet { groups }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_and_coords_roundtrip() {
        let g = Grid::new(24, &[("A", 2), ("B", 3), ("C", 4)]).unwrap();
        for r in 0..24 {
            assert_eq!(g.rank(&g.coords(r)), r);
        }
        assert_eq!(g.coords(0), vec![0, 0, 0]);
        assert_eq!(g.coords(23), vec![1, 2, 3]);
        // C is fastest-varying.
        assert_eq!(g.coords(1), vec![0, 0, 1]);
    }

    #[test]
    fn innermost_axis_groups_are_consecutive() {
        let g = Grid::new(8, &[("PP", 2), ("TP", 4)]).unwrap();
        let tp = g.groups("TP");
        assert_eq!(tp, vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]]);
        let pp = g.groups("PP");
        assert_eq!(pp, vec![vec![0, 4], vec![1, 5], vec![2, 6], vec![3, 7]]);
    }

    #[test]
    fn groups_partition_world() {
        let g = Grid::new(64, &[("PP", 2), ("DP", 4), ("CP", 2), ("TP", 4)]).unwrap();
        for axis in ["PP", "DP", "CP", "TP"] {
            let part = g.groups(axis);
            let mut all: Vec<usize> = part.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..64).collect::<Vec<_>>(), "axis {axis}");
        }
    }

    #[test]
    fn rejects_bad_product() {
        assert!(Grid::new(10, &[("A", 3), ("B", 3)]).is_err());
    }
}
