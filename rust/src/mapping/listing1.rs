//! Faithful port of the paper's appendix Listing 1 (`generate_mappings`).
//!
//! The Python original reshapes ranks as `(attn_dp, pp, cp, tp)` for
//! attention and `(moe_dp, pp, ep, etp)` for MoE and derives groups via
//! einops rearranges. This layout is PP-consistent only when
//! `tp*cp == etp*ep` (the inner block below the `pp` axis must match);
//! the production layout in [`super::ParallelMapping::folded`] places `pp`
//! slowest instead, which is consistent for every legal configuration. This
//! module exists for fidelity with the paper text and is validated against
//! the appendix example `generate_mappings(64, 2, 2, 2, 2, 2)`.

use std::collections::BTreeMap;

use super::grid::Grid;
use super::GroupSet;

/// Port of Listing 1: returns (attention_groups, moe_groups).
///
/// Arguments mirror the Python signature:
/// `generate_mappings(world_size, tp, cp, ep, etp, pp)`.
pub fn generate_mappings_listing1(
    world_size: usize,
    tp: usize,
    cp: usize,
    ep: usize,
    etp: usize,
    pp: usize,
) -> Result<(GroupSet, GroupSet), String> {
    if world_size % (tp * cp * pp) != 0 {
        return Err("world_size % (tp*cp*pp) != 0".into());
    }
    if world_size % (etp * ep * pp) != 0 {
        return Err("world_size % (etp*ep*pp) != 0".into());
    }
    let attn_dp = world_size / tp / cp / pp;
    let moe_dp = world_size / etp / ep / pp;

    // attn_ranks = ranks.reshape(attn_dp, pp, cp, tp)
    let attn = Grid::new(world_size, &[("DP", attn_dp), ("PP", pp), ("CP", cp), ("TP", tp)])?;
    // moe_ranks = ranks.reshape(moe_dp, pp, ep, etp)
    let moe = Grid::new(world_size, &[("EDP", moe_dp), ("PP", pp), ("EP", ep), ("ETP", etp)])?;

    let mut a = BTreeMap::new();
    for ax in ["TP", "CP", "PP", "DP"] {
        a.insert(ax.to_string(), attn.groups(ax));
    }
    let mut m = BTreeMap::new();
    for ax in ["ETP", "EP", "PP", "EDP"] {
        m.insert(ax.to_string(), moe.groups(ax));
    }
    Ok((GroupSet { groups: a }, GroupSet { groups: m }))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The appendix example: generate_mappings(64, 2, 2, 2, 2, 2).
    #[test]
    fn appendix_example_shapes() {
        let (a, m) = generate_mappings_listing1(64, 2, 2, 2, 2, 2).unwrap();
        assert_eq!(a.groups["TP"].len(), 32);
        assert_eq!(a.groups["TP"][0], vec![0, 1]);
        assert_eq!(a.groups["CP"].len(), 32);
        assert_eq!(a.groups["CP"][0], vec![0, 2]);
        assert_eq!(a.groups["PP"].len(), 32);
        // pp stride = cp*tp = 4.
        assert_eq!(a.groups["PP"][0], vec![0, 4]);
        assert_eq!(a.groups["DP"].len(), 8);
        // dp stride = pp*cp*tp = 8.
        assert_eq!(a.groups["DP"][0], (0..64).step_by(8).collect::<Vec<_>>());

        // MoE grid has identical extents here, so group shapes coincide.
        assert_eq!(m.groups["ETP"][0], vec![0, 1]);
        assert_eq!(m.groups["EP"][0], vec![0, 2]);
        assert_eq!(m.groups["PP"][0], vec![0, 4]);
    }

    /// When tp*cp == etp*ep the listing layout's PP partitions agree.
    #[test]
    fn pp_consistent_when_inner_blocks_match() {
        let (a, m) = generate_mappings_listing1(64, 2, 2, 4, 1, 2).unwrap();
        let mut ap = a.groups["PP"].clone();
        let mut mp = m.groups["PP"].clone();
        ap.sort();
        mp.sort();
        assert_eq!(ap, mp);
    }

    /// When inner blocks differ (tp*cp != etp*ep) the listing layout's PP
    /// partitions diverge — documenting why the production layout puts PP
    /// slowest.
    #[test]
    fn pp_inconsistent_when_inner_blocks_differ() {
        let (a, m) = generate_mappings_listing1(32, 2, 1, 8, 1, 2).unwrap();
        let mut ap = a.groups["PP"].clone();
        let mut mp = m.groups["PP"].clone();
        ap.sort();
        mp.sort();
        assert_ne!(ap, mp);
    }
}
