//! Parallel-group generation: the paper's core mechanism (§3.2, Listing 1).
//!
//! With MoE Parallel Folding the attention layers use a 4-D grid
//! `TP × CP × DP × PP` while the MoE layers use an *independent* grid
//! `ETP × EP × EDP × PP`; the only consistency requirement is that both
//! grids induce the same pipeline-parallel partition of ranks.
//!
//! Two layouts are provided:
//!
//! * [`ParallelMapping::folded`] — the production layout (Megatron-Core
//!   order, `pp` slowest axis) which keeps PP partitions consistent for
//!   *every* legal `(tp, cp)` vs `(etp, ep)` combination, including the
//!   Table-3 optima where `tp·cp != etp·ep`.
//! * [`generate_mappings_listing1`] — a faithful port of the paper's
//!   appendix Listing 1 (grid order `(dp, pp, cp|ep, tp)`), which is only
//!   PP-consistent when `tp·cp == etp·ep`; kept for fidelity and tested
//!   against the appendix example.
//!
//! The legacy (pre-folding) MCore layout, where the EP group is a sub-group
//! of attention DP and `etp == tp`, is [`ParallelMapping::legacy`]; the
//! Figure-5/6 ablations compare group placements between the two.

pub mod grid;
pub mod listing1;
pub mod runtime;

pub use grid::Grid;
pub use listing1::generate_mappings_listing1;
pub use runtime::{RankView, RuntimeTopology};

use std::collections::BTreeMap;



use crate::cluster::ClusterSpec;
use crate::config::{EpPlacement, ParallelConfig};

/// Named axes of the attention grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AttnAxis {
    Tp,
    Cp,
    Dp,
    Pp,
}

/// Named axes of the MoE grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MoeAxis {
    Etp,
    Ep,
    Edp,
    Pp,
}

/// A partition of `0..world` into equally-sized groups for one axis.
pub type GroupPartition = Vec<Vec<usize>>;

/// All process groups for one layer type.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupSet {
    /// axis name -> list of groups (each group = sorted global ranks).
    pub groups: BTreeMap<String, GroupPartition>,
}

impl GroupSet {
    /// The group on `axis` containing `rank`.
    pub fn group_of(&self, axis: &str, rank: usize) -> Option<&[usize]> {
        self.groups
            .get(axis)?
            .iter()
            .find(|g| g.contains(&rank))
            .map(|g| g.as_slice())
    }

    /// Index of `rank` within its group on `axis` (its "coordinate").
    pub fn index_in_group(&self, axis: &str, rank: usize) -> Option<usize> {
        self.group_of(axis, rank)?.iter().position(|&r| r == rank)
    }
}

/// The complete dual mapping for one parallel configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ParallelMapping {
    pub config: ParallelConfig,
    pub attention: GroupSet,
    pub moe: GroupSet,
    /// True if built by the legacy (coupled) constructor.
    pub legacy: bool,
}

impl ParallelMapping {
    /// Folded mapping (Megatron-Core axis order, `pp` slowest).
    ///
    /// Attention grid: `(pp, dp, cp, tp)` — `tp` fastest-varying so TP groups
    /// are consecutive ranks (inside a node whenever `tp <= 8`).
    /// MoE grid: `(pp, edp, ep, etp)` — `etp` fastest, then `ep`, so the
    /// EP×ETP block *folds over* the same consecutive ranks the attention
    /// TP×CP(×DP) block occupies. Both grids place `pp` slowest, so the PP
    /// partition is `{r : r ≡ c (mod world/pp)}`-style slabs and always
    /// consistent between the two grids.
    pub fn folded(config: ParallelConfig) -> Result<Self, String> {
        config.validate_basic()?;
        let attn_grid = Grid::new(
            config.world_size,
            &[
                ("PP", config.pp),
                ("DP", config.dp()),
                ("CP", config.cp),
                ("TP", config.tp),
            ],
        )?;
        // Packed: `etp` then `ep` fastest, so an EP×ETP block is a
        // contiguous rank range (inside a node when it fits). Strided
        // (the [`EpPlacement`] twin): EP varies *slower* than EDP, so EP
        // peers sit `edp·etp` ranks apart and the dispatch a2a crosses
        // nodes — same group sizes, different wires.
        let moe_axes: [(&str, usize); 4] = match config.placement {
            EpPlacement::Packed => [
                ("PP", config.pp),
                ("EDP", config.edp()),
                ("EP", config.ep),
                ("ETP", config.etp),
            ],
            EpPlacement::Strided => [
                ("PP", config.pp),
                ("EP", config.ep),
                ("EDP", config.edp()),
                ("ETP", config.etp),
            ],
        };
        let moe_grid = Grid::new(config.world_size, &moe_axes)?;
        let mapping = Self {
            config,
            attention: attn_grid.group_set(),
            moe: moe_grid.group_set(),
            legacy: false,
        };
        mapping.validate_pp_consistency()?;
        Ok(mapping)
    }

    /// Legacy (pre-folding) MCore mapping: `etp` is forced equal to `tp`,
    /// `cp` is fused into the token batch for MoE, and the EP group is a
    /// sub-group of the *attention DP×CP* dimension: attention grid
    /// `(pp, dp, cp, tp)`, MoE grid `(pp, edp', ep, cp, tp)` where the EP
    /// group members stride by `cp·tp` ranks.
    ///
    /// This reproduces the pre-folding behaviour the ablations measure: with
    /// `tp·cp >= 8` the EP group members land on *different nodes*, pushing
    /// token All-to-All traffic onto InfiniBand (Figure 6).
    ///
    /// Ignores `config.placement`: the legacy layout predates the placement
    /// axis and already strides EP by construction.
    pub fn legacy(config: ParallelConfig) -> Result<Self, String> {
        if config.etp != config.tp {
            return Err(format!(
                "legacy MCore couples ETP to TP (got etp={} tp={})",
                config.etp, config.tp
            ));
        }
        if config.dp() % config.ep != 0 {
            return Err(format!(
                "legacy MCore requires ep | dp (ep={} dp={})",
                config.ep,
                config.dp()
            ));
        }
        config.validate_basic()?;
        let attn_grid = Grid::new(
            config.world_size,
            &[
                ("PP", config.pp),
                ("DP", config.dp()),
                ("CP", config.cp),
                ("TP", config.tp),
            ],
        )?;
        // EP takes the innermost `ep` slots of the DP axis, *outside* the
        // CP×TP block: members of one EP group stride by `cp·tp` ranks.
        // This is exactly the Figure-6 pathology — with cp·tp ≥ 8 the EP
        // All-to-All leaves the NVLink domain.
        let moe_grid = Grid::new(
            config.world_size,
            &[
                ("PP", config.pp),
                ("EDP", config.dp() / config.ep),
                ("EP", config.ep),
                ("CPTP", config.cp * config.tp),
            ],
        )?;
        // The MoE grid's "ETP" groups are the TP sub-blocks of CPTP, and
        // "EDP" fuses the leftover DP with CP. Rebuild those two axes from a
        // finer grid so group queries stay uniform.
        let moe_fine = Grid::new(
            config.world_size,
            &[
                ("PP", config.pp),
                ("EDPO", config.dp() / config.ep),
                ("EP", config.ep),
                ("CP", config.cp),
                ("ETP", config.tp),
            ],
        )?;
        let mut moe_groups = moe_grid.group_set();
        let fine = moe_fine.group_set();
        moe_groups.groups.insert("ETP".into(), fine.groups["ETP"].clone());
        // EDP for experts = outer DP remainder × CP (experts replicate over
        // both), i.e. ranks sharing (pp, ep, etp) coordinates.
        let edp = merged_axis_groups(&moe_fine, &["EDPO", "CP"]);
        moe_groups.groups.insert("EDP".into(), edp);
        moe_groups.groups.insert("EP".into(), fine.groups["EP"].clone());
        let mapping = Self {
            config,
            attention: attn_grid.group_set(),
            moe: moe_groups,
            legacy: true,
        };
        mapping.validate_pp_consistency()?;
        Ok(mapping)
    }

    /// The PP partitions of the two grids must be identical (paper §3.2:
    /// "the number of PP groups and members of each PP group for the
    /// Attention and MoE layer must be consistent").
    pub fn validate_pp_consistency(&self) -> Result<(), String> {
        let a = normalized(&self.attention.groups["PP"]);
        let m = normalized(&self.moe.groups["PP"]);
        if a == m {
            Ok(())
        } else {
            Err("PP partitions differ between attention and MoE grids".into())
        }
    }

    /// Summary of which groups fit inside one NVLink domain — the quantity
    /// MoE Parallel Folding optimizes.
    pub fn fold_report(&self, cluster: &ClusterSpec) -> FoldReport {
        let span = |set: &GroupSet, axis: &str| -> usize {
            set.groups[axis]
                .iter()
                .map(|g| cluster.nodes_spanned(g))
                .max()
                .unwrap_or(1)
        };
        FoldReport {
            tp_nodes: span(&self.attention, "TP"),
            cp_nodes: span(&self.attention, "CP"),
            dp_nodes: span(&self.attention, "DP"),
            ep_nodes: span(&self.moe, "EP"),
            etp_nodes: span(&self.moe, "ETP"),
            edp_nodes: span(&self.moe, "EDP"),
        }
    }

    /// Every rank belongs to exactly one group per axis; group sizes match
    /// the configured degrees. Used by property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        let w = self.config.world_size;
        let expect: &[(&GroupSet, &str, usize)] = &[
            (&self.attention, "TP", self.config.tp),
            (&self.attention, "CP", self.config.cp),
            (&self.attention, "DP", self.config.dp()),
            (&self.attention, "PP", self.config.pp),
            (&self.moe, "ETP", self.config.etp),
            (&self.moe, "EP", self.config.ep),
            (&self.moe, "EDP", self.config.edp()),
            (&self.moe, "PP", self.config.pp),
        ];
        for (set, axis, size) in expect {
            let part = &set.groups[*axis];
            let mut seen = vec![false; w];
            for g in part {
                if g.len() != *size {
                    return Err(format!("{axis} group size {} != {size}", g.len()));
                }
                for &r in g {
                    if r >= w || seen[r] {
                        return Err(format!("{axis}: rank {r} repeated/out of range"));
                    }
                    seen[r] = true;
                }
            }
            if !seen.iter().all(|&s| s) {
                return Err(format!("{axis}: not a partition of 0..{w}"));
            }
        }
        Ok(())
    }
}

/// Node-span summary per axis (max over groups).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FoldReport {
    pub tp_nodes: usize,
    pub cp_nodes: usize,
    pub dp_nodes: usize,
    pub ep_nodes: usize,
    pub etp_nodes: usize,
    pub edp_nodes: usize,
}

impl FoldReport {
    /// True when all MoE model-parallel communication (EP + ETP) stays on
    /// NVLink.
    pub fn moe_comm_intra_node(&self) -> bool {
        self.ep_nodes <= 1 && self.etp_nodes <= 1
    }
}

/// Partition of ranks into groups that share coordinates on every axis of
/// `grid` *except* the listed ones (the merged axes vary within a group).
fn merged_axis_groups(grid: &Grid, merged: &[&str]) -> GroupPartition {
    use std::collections::BTreeMap;
    let merged_idx: Vec<usize> = merged
        .iter()
        .map(|m| grid.axes.iter().position(|(n, _)| n == m).expect("axis"))
        .collect();
    let mut buckets: BTreeMap<Vec<usize>, Vec<usize>> = BTreeMap::new();
    for r in 0..grid.world {
        let mut key = grid.coords(r);
        for &i in &merged_idx {
            key[i] = 0;
        }
        buckets.entry(key).or_default().push(r);
    }
    buckets.into_values().collect()
}

fn normalized(p: &GroupPartition) -> Vec<Vec<usize>> {
    let mut v: Vec<Vec<usize>> = p
        .iter()
        .map(|g| {
            let mut g = g.clone();
            g.sort_unstable();
            g
        })
        .collect();
    v.sort();
    v
}

impl ParallelConfig {
    /// Divisibility checks that don't need model information.
    pub(crate) fn validate_basic(&self) -> Result<(), String> {
        if self.world_size % (self.tp * self.cp * self.pp) != 0 {
            return Err(format!(
                "world {} % tp*cp*pp {} != 0",
                self.world_size,
                self.tp * self.cp * self.pp
            ));
        }
        if self.world_size % (self.etp * self.ep * self.pp) != 0 {
            return Err(format!(
                "world {} % etp*ep*pp {} != 0",
                self.world_size,
                self.etp * self.ep * self.pp
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn folded_paper_optimum_is_valid() {
        // Table 3 Mixtral-8x22B folded optimum: 128 GPUs TP2 EP8 PP8 ETP1.
        let cfg = ParallelConfig::new(128, 2, 1, 8, 1, 8);
        let m = ParallelMapping::folded(cfg).unwrap();
        m.check_invariants().unwrap();
        // EP groups are 8 consecutive ranks -> inside one node.
        let cluster = ClusterSpec::eos(128);
        let rep = m.fold_report(&cluster);
        assert_eq!(rep.ep_nodes, 1, "folded EP must fit in a node: {rep:?}");
        assert!(rep.moe_comm_intra_node());
    }

    #[test]
    fn legacy_ep_spans_nodes_when_tp_large() {
        // Figure 6 scenario: attention TP8 -> legacy EP strides by 8 ranks,
        // crossing node boundaries.
        let cfg = ParallelConfig::new(128, 8, 1, 8, 8, 1);
        let m = ParallelMapping::legacy(cfg).unwrap();
        let cluster = ClusterSpec::eos(128);
        let rep = m.fold_report(&cluster);
        assert!(rep.ep_nodes > 1, "legacy EP should span nodes: {rep:?}");

        // Folding the same degrees keeps EP in-node (ETP=1, EP=8 innermost).
        let folded = ParallelMapping::folded(ParallelConfig::new(128, 8, 1, 8, 1, 1)).unwrap();
        let repf = folded.fold_report(&cluster);
        assert_eq!(repf.ep_nodes, 1, "{repf:?}");
    }

    #[test]
    fn pp_partitions_always_consistent_in_folded_layout() {
        for (w, tp, cp, ep, etp, pp) in [
            (128, 2, 1, 8, 1, 8),
            (64, 2, 2, 4, 1, 4),
            (256, 8, 1, 8, 1, 16),
            (64, 2, 2, 2, 2, 2),
            (1024, 8, 8, 8, 1, 8),
        ] {
            let cfg = ParallelConfig::new(w, tp, cp, ep, etp, pp);
            let m = ParallelMapping::folded(cfg).unwrap();
            m.validate_pp_consistency().unwrap();
            m.check_invariants().unwrap();
        }
    }

    #[test]
    fn group_lookup() {
        let cfg = ParallelConfig::new(16, 2, 2, 4, 1, 2);
        let m = ParallelMapping::folded(cfg).unwrap();
        for r in 0..16 {
            let tpg = m.attention.group_of("TP", r).unwrap();
            assert!(tpg.contains(&r));
            assert_eq!(tpg.len(), 2);
            let epg = m.moe.group_of("EP", r).unwrap();
            assert_eq!(epg.len(), 4);
        }
        // TP groups are consecutive pairs.
        assert_eq!(m.attention.group_of("TP", 0).unwrap(), &[0, 1]);
        assert_eq!(m.attention.group_of("TP", 5).unwrap(), &[4, 5]);
    }

    #[test]
    fn legacy_requires_coupling() {
        let cfg = ParallelConfig::new(128, 2, 1, 8, 1, 8); // etp != tp
        assert!(ParallelMapping::legacy(cfg).is_err());
    }

    /// The placement axis changes wires, not group sizes: strided EP peers
    /// sit `edp·etp` ranks apart, so the same degrees that pack EP into a
    /// node under [`EpPlacement::Packed`] span nodes under `Strided`.
    #[test]
    fn strided_placement_pushes_ep_across_nodes() {
        let cluster = ClusterSpec::eos(128);
        let packed = ParallelConfig::new(128, 2, 1, 8, 1, 8);
        let strided = packed.with_placement(EpPlacement::Strided);
        let mp = ParallelMapping::folded(packed).unwrap();
        let ms = ParallelMapping::folded(strided).unwrap();
        ms.check_invariants().unwrap();
        ms.validate_pp_consistency().unwrap();
        assert_eq!(mp.fold_report(&cluster).ep_nodes, 1);
        let rep = ms.fold_report(&cluster);
        assert!(rep.ep_nodes > 1, "strided EP should span nodes: {rep:?}");
    }

    #[test]
    fn fold_report_cp_folding() {
        // Figure 6: CP4 x EP4 = 16 > 8 spans nodes without folding, but the
        // folded MoE grid can still keep EP (8 innermost ranks) in-node.
        let cluster = ClusterSpec::eos(64);
        let cfg = ParallelConfig::new(64, 1, 4, 8, 1, 1);
        let folded = ParallelMapping::folded(cfg).unwrap();
        let rep = folded.fold_report(&cluster);
        assert_eq!(rep.ep_nodes, 1);
        assert!(rep.cp_nodes >= 1);
    }
}
