//! The **runtime topology** layer: per-rank group views, materialized from a
//! [`ParallelMapping`], that the *executed* path consumes.
//!
//! [`ParallelMapping::folded`] / [`ParallelMapping::legacy`] define every
//! process group of the dual `TP×CP×DP×PP` / `ETP×EP×EDP×PP` layout (paper
//! §3.2, Listing 1), but a group *partition* is the planner's view of the
//! world. The pieces that actually run collectives — the token dispatcher
//! ([`crate::dispatcher::DistributedMoeLayer`]), the trainer's gradient
//! synchronization ([`crate::train::GradSync`]), and the functional pipeline
//! ([`crate::pipeline::execute_1f1b_mapped`]) — each need *this rank's*
//! groups. [`RuntimeTopology`] bridges the two: it validates the mapping
//! (axis partitions tile the world, attention and MoE PP partitions agree)
//! and materializes one [`RankView`] per rank with every group membership
//! and coordinate resolved, so no executed component hand-rolls rank
//! arithmetic again.
//!
//! # Worked example (Table 3, Mixtral-8x22B folded optimum)
//!
//! `TP2 · CP1 · EP8 · ETP1 · PP8` on 128 GPUs (`DP8`, `EDP2`). For rank 5:
//!
//! * attention: TP group `[4, 5]`, DP group `[1, 3, 5, 7, 9, 11, 13, 15]`,
//!   PP group `[5, 21, 37, …, 117]` (stage 0 of 8);
//! * MoE: EP group `[0..8]` (eight *consecutive* ranks — one NVLink
//!   domain, the folding win), ETP group `[5]`, EDP group `[5, 13]`;
//! * sequence-drop scope: `[4, 5]` (the TP×CP block holding one sequence).
//!
//! Under the legacy (coupled) layout the same degrees are not even
//! expressible (`etp != tp`); the closest coupled config places EP group
//! members `tp` ranks apart, pushing token All-to-All onto InfiniBand.
//! `moe-folding mapping --gpus 128 --tp 2 --ep 8 --pp 8 --rank 5` prints
//! this view from the CLI.

use std::collections::BTreeMap;

use crate::config::ParallelConfig;

use super::{GroupSet, ParallelMapping};

/// One rank's complete view of the dual topology: group membership (sorted
/// global ranks) and this rank's coordinate on every axis of both grids.
#[derive(Debug, Clone, PartialEq)]
pub struct RankView {
    pub rank: usize,
    /// Attention tensor-parallel group and this rank's position in it.
    pub tp_group: Vec<usize>,
    pub tp_index: usize,
    /// Attention context-parallel group.
    pub cp_group: Vec<usize>,
    pub cp_index: usize,
    /// Attention data-parallel group (gradient all-reduce for attention
    /// parameters).
    pub dp_group: Vec<usize>,
    pub dp_index: usize,
    /// Pipeline group in **stage order** (`pp_group[pp_stage] == rank`);
    /// identical partition for the attention and MoE grids by construction.
    pub pp_group: Vec<usize>,
    pub pp_stage: usize,
    /// MoE expert-tensor-parallel group (AllGather-V / ReduceScatter-V).
    pub etp_group: Vec<usize>,
    pub etp_index: usize,
    /// MoE expert-parallel group (token All-to-All-V); `ep_index` selects
    /// which contiguous slice of global experts this rank hosts.
    pub ep_group: Vec<usize>,
    pub ep_index: usize,
    /// MoE expert-data-parallel group (gradient all-reduce for expert
    /// parameters) — **not** the attention DP group whenever `dp != edp`.
    pub edp_group: Vec<usize>,
    pub edp_index: usize,
    /// The attention TP×CP block that jointly holds one full sequence —
    /// the gather scope for full-sequence token dropping (paper §3.3).
    pub seq_group: Vec<usize>,
}

impl RankView {
    /// Human-readable one-rank summary (CLI `mapping --rank N`, docs).
    pub fn summary(&self) -> String {
        format!(
            "rank {r}\n  attention: TP {tp:?}[{tpi}]  CP {cp:?}[{cpi}]  DP {dp:?}[{dpi}]\n  \
             moe:       ETP {etp:?}[{etpi}]  EP {ep:?}[{epi}]  EDP {edp:?}[{edpi}]\n  \
             pipeline:  stage {st}/{nst} of {ppg:?}\n  \
             seq-drop scope: {seq:?}",
            r = self.rank,
            tp = self.tp_group,
            tpi = self.tp_index,
            cp = self.cp_group,
            cpi = self.cp_index,
            dp = self.dp_group,
            dpi = self.dp_index,
            etp = self.etp_group,
            etpi = self.etp_index,
            ep = self.ep_group,
            epi = self.ep_index,
            edp = self.edp_group,
            edpi = self.edp_index,
            st = self.pp_stage,
            nst = self.pp_group.len(),
            ppg = self.pp_group,
            seq = self.seq_group,
        )
    }
}

/// The executed-path topology: a validated [`ParallelMapping`] plus the
/// materialized per-rank views. This is the single source of truth for
/// every group the simulator runs a collective over.
#[derive(Debug, Clone, PartialEq)]
pub struct RuntimeTopology {
    pub mapping: ParallelMapping,
    views: Vec<RankView>,
}

/// `rank -> (group id, position within the group)` for one axis. Fails if
/// the axis is missing or its groups do not cover `0..world` exactly once.
fn axis_index(
    set: &GroupSet,
    axis: &str,
    world: usize,
) -> Result<Vec<(usize, usize)>, String> {
    let part = set
        .groups
        .get(axis)
        .ok_or_else(|| format!("mapping is missing axis {axis}"))?;
    let mut out = vec![(usize::MAX, usize::MAX); world];
    for (gid, g) in part.iter().enumerate() {
        for (pos, &r) in g.iter().enumerate() {
            if r >= world {
                return Err(format!("axis {axis}: rank {r} out of range"));
            }
            if out[r].0 != usize::MAX {
                return Err(format!("axis {axis}: rank {r} in two groups"));
            }
            out[r] = (gid, pos);
        }
    }
    if let Some(r) = out.iter().position(|&(g, _)| g == usize::MAX) {
        return Err(format!("axis {axis}: rank {r} in no group"));
    }
    Ok(out)
}

impl RuntimeTopology {
    /// Topology of the folded (production) layout.
    pub fn folded(config: ParallelConfig) -> Result<Self, String> {
        Self::from_mapping(ParallelMapping::folded(config)?)
    }

    /// Topology of the legacy (coupled) layout.
    pub fn legacy(config: ParallelConfig) -> Result<Self, String> {
        Self::from_mapping(ParallelMapping::legacy(config)?)
    }

    /// Materialize per-rank views from an existing mapping, re-validating
    /// the invariants the executed path relies on (each axis partitions
    /// `0..world` into equal groups; attention and MoE PP partitions agree;
    /// every sequence block has exactly `tp·cp` ranks).
    pub fn from_mapping(mapping: ParallelMapping) -> Result<Self, String> {
        mapping.check_invariants()?;
        mapping.validate_pp_consistency()?;
        let cfg = mapping.config;
        let world = cfg.world_size;
        let att = &mapping.attention;
        let moe = &mapping.moe;

        let tp = axis_index(att, "TP", world)?;
        let cp = axis_index(att, "CP", world)?;
        let dp = axis_index(att, "DP", world)?;
        let pp = axis_index(att, "PP", world)?;
        let etp = axis_index(moe, "ETP", world)?;
        let ep = axis_index(moe, "EP", world)?;
        let edp = axis_index(moe, "EDP", world)?;

        // Sequence blocks: ranks sharing the (pp, dp) attention coordinates
        // jointly hold one full sequence across their TP×CP block. Group
        // members are stored in ascending coordinate order, so positions
        // are coordinates and the (pp, dp) pair identifies the block.
        let mut blocks: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
        for r in 0..world {
            blocks.entry((pp[r].1, dp[r].1)).or_default().push(r);
        }
        for (key, b) in &blocks {
            if b.len() != cfg.tp * cfg.cp {
                return Err(format!(
                    "sequence block {key:?} has {} ranks, expected tp*cp = {}",
                    b.len(),
                    cfg.tp * cfg.cp
                ));
            }
        }

        let mut views = Vec::with_capacity(world);
        for r in 0..world {
            let view = RankView {
                rank: r,
                tp_group: att.groups["TP"][tp[r].0].clone(),
                tp_index: tp[r].1,
                cp_group: att.groups["CP"][cp[r].0].clone(),
                cp_index: cp[r].1,
                dp_group: att.groups["DP"][dp[r].0].clone(),
                dp_index: dp[r].1,
                pp_group: att.groups["PP"][pp[r].0].clone(),
                pp_stage: pp[r].1,
                etp_group: moe.groups["ETP"][etp[r].0].clone(),
                etp_index: etp[r].1,
                ep_group: moe.groups["EP"][ep[r].0].clone(),
                ep_index: ep[r].1,
                edp_group: moe.groups["EDP"][edp[r].0].clone(),
                edp_index: edp[r].1,
                seq_group: blocks[&(pp[r].1, dp[r].1)].clone(),
            };
            if view.pp_group[view.pp_stage] != r {
                return Err(format!(
                    "rank {r}: PP group {:?} not in stage order",
                    view.pp_group
                ));
            }
            views.push(view);
        }
        Ok(Self { mapping, views })
    }

    pub fn world(&self) -> usize {
        self.mapping.config.world_size
    }

    pub fn config(&self) -> &ParallelConfig {
        &self.mapping.config
    }

    /// True when built from the legacy (coupled) constructor.
    pub fn is_legacy(&self) -> bool {
        self.mapping.legacy
    }

    /// This rank's view of every group it belongs to.
    pub fn view(&self, rank: usize) -> &RankView {
        &self.views[rank]
    }

    pub fn views(&self) -> &[RankView] {
        &self.views
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn folded_views_match_grid_layout() {
        // World 16, TP2·CP2·DP4·PP1 attention vs ETP1·EP4·EDP4 MoE.
        let topo = RuntimeTopology::folded(ParallelConfig::new(16, 2, 2, 4, 1, 1)).unwrap();
        for r in 0..16 {
            let v = topo.view(r);
            assert_eq!(v.rank, r);
            // TP groups are consecutive pairs; EP groups consecutive fours.
            assert_eq!(v.tp_group, vec![r - r % 2, r - r % 2 + 1]);
            assert_eq!(v.tp_index, r % 2);
            let ep_base = r - r % 4;
            assert_eq!(v.ep_group, (ep_base..ep_base + 4).collect::<Vec<_>>());
            assert_eq!(v.ep_index, r % 4);
            // Sequence block = TP×CP block of 4 consecutive ranks.
            let blk = r - r % 4;
            assert_eq!(v.seq_group, (blk..blk + 4).collect::<Vec<_>>());
            // Membership + index coherence on every axis.
            assert_eq!(v.dp_group[v.dp_index], r);
            assert_eq!(v.edp_group[v.edp_index], r);
            assert_eq!(v.etp_group[v.etp_index], r);
            assert_eq!(v.pp_group[v.pp_stage], r);
            assert_eq!(v.cp_group[v.cp_index], r);
        }
    }

    #[test]
    fn folded_dp_and_edp_groups_differ_when_degrees_do() {
        // TP2 attention vs ETP1·EP4 MoE on 8 ranks: dp=4, edp=2.
        let topo = RuntimeTopology::folded(ParallelConfig::new(8, 2, 1, 4, 1, 1)).unwrap();
        assert_eq!(topo.config().dp(), 4);
        assert_eq!(topo.config().edp(), 2);
        for r in 0..8 {
            let v = topo.view(r);
            let want_dp: Vec<usize> = (0..4).map(|i| r % 2 + 2 * i).collect();
            let want_edp = vec![r % 4, r % 4 + 4];
            assert_eq!(v.dp_group, want_dp, "rank {r}");
            assert_eq!(v.edp_group, want_edp, "rank {r}");
            assert_ne!(v.dp_group, v.edp_group);
        }
    }

    #[test]
    fn table3_mixtral_optimum_rank5_worked_example() {
        // The module-doc example: TP2·EP8·ETP1·PP8 on 128 GPUs.
        let topo = RuntimeTopology::folded(ParallelConfig::new(128, 2, 1, 8, 1, 8)).unwrap();
        let v = topo.view(5);
        assert_eq!(v.tp_group, vec![4, 5]);
        assert_eq!(v.ep_group, (0..8).collect::<Vec<_>>());
        assert_eq!(v.etp_group, vec![5]);
        assert_eq!(v.edp_group, vec![5, 13]);
        assert_eq!(v.seq_group, vec![4, 5]);
        assert_eq!(v.pp_stage, 0);
        assert_eq!(v.pp_group.len(), 8);
        // EP stays inside one stage: all EP peers share the PP coordinate.
        for &peer in &v.ep_group {
            assert_eq!(topo.view(peer).pp_stage, v.pp_stage);
        }
        let s = v.summary();
        assert!(s.contains("EP [0, 1, 2, 3, 4, 5, 6, 7]"));
    }

    #[test]
    fn legacy_topology_couples_etp_to_tp() {
        let topo = RuntimeTopology::legacy(ParallelConfig::new(16, 2, 1, 4, 2, 1)).unwrap();
        assert!(topo.is_legacy());
        for r in 0..16 {
            let v = topo.view(r);
            // Legacy ETP groups are exactly the attention TP groups.
            assert_eq!(v.etp_group, v.tp_group, "rank {r}");
            // Legacy EP members stride by tp·cp ranks.
            let diffs: Vec<usize> =
                v.ep_group.windows(2).map(|w| w[1] - w[0]).collect();
            assert!(diffs.iter().all(|&d| d == 2), "rank {r}: {diffs:?}");
        }
    }

    #[test]
    fn pp_groups_are_stage_ordered_with_pp_gt_1() {
        let topo = RuntimeTopology::folded(ParallelConfig::new(16, 2, 1, 2, 1, 4)).unwrap();
        for r in 0..16 {
            let v = topo.view(r);
            assert_eq!(v.pp_group.len(), 4);
            assert_eq!(v.pp_group[v.pp_stage], r);
            // Stage order == ascending pp coordinate == ascending rank here.
            let mut sorted = v.pp_group.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, v.pp_group);
        }
    }
}
