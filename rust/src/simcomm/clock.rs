//! The virtual clock of the functional simulator.
//!
//! A clocked [`super::Fabric`] carries one [`SimClock`]: per-rank simulated
//! time (microseconds) plus a per-rank trace-event log. Time advances in
//! exactly two ways:
//!
//! * **compute** — [`super::Communicator::advance`] charges a labelled span
//!   to the calling rank;
//! * **communication** — every collective and point-to-point transfer
//!   charges the *same* [`CommCost`] primitive the analytic performance
//!   model prices (`collectives::cost`), after synchronizing the group on
//!   `max(entry times)`. One cost implementation means the executed clock
//!   and the analytic estimate can never drift on the price of a
//!   collective.
//!
//! Collective semantics: a collective entered by every group member at
//! times `t_i` exits on every member at `max_i(t_i) + cost`, where `cost`
//! comes from [`CommCost::price`] for the algorithm the communicator
//! actually ran. The max is established by a tiny leader exchange of
//! timestamps *after* the payload phase — control traffic that never
//! touches payload math, so clocked execution is bit-identical to
//! unclocked execution.
//!
//! The event log serializes to the Chrome trace-event format
//! ([`chrome_trace_json`]): load the file at `chrome://tracing` or
//! <https://ui.perfetto.dev> — one row per rank, compute and communication
//! spans color-coded by category, gaps = waiting (pipeline bubbles).

use std::sync::Mutex;

use crate::collectives::CommCost;

/// One timed span on one rank's simulated timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Global rank the span belongs to (chrome-trace `tid`).
    pub rank: usize,
    /// Phase label (e.g. `moe/a2a_dispatch`, `fwd`, `optimizer`).
    pub name: String,
    /// Category: `compute`, `comm`, or `p2p`.
    pub cat: &'static str,
    /// Start time, simulated microseconds.
    pub ts_us: f64,
    /// Duration, simulated microseconds.
    pub dur_us: f64,
}

/// Per-rank simulated time + trace log. Owned by a clocked fabric.
pub(crate) struct SimClock {
    pub(crate) cost: CommCost,
    times: Vec<Mutex<f64>>,
    events: Vec<Mutex<Vec<TraceEvent>>>,
}

impl SimClock {
    pub(crate) fn new(world: usize, cost: CommCost) -> Self {
        Self {
            cost,
            times: (0..world).map(|_| Mutex::new(0.0)).collect(),
            events: (0..world).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    /// Current simulated time of `rank`.
    pub(crate) fn now(&self, rank: usize) -> f64 {
        *self.times[rank].lock().unwrap()
    }

    /// Set `rank`'s clock (collective exit, p2p arrival).
    pub(crate) fn set(&self, rank: usize, t: f64) {
        *self.times[rank].lock().unwrap() = t;
    }

    /// Charge `us` of local work to `rank`; returns the span start.
    pub(crate) fn advance(&self, rank: usize, us: f64) -> f64 {
        let mut t = self.times[rank].lock().unwrap();
        let start = *t;
        *t += us.max(0.0);
        start
    }

    /// Append a span to `rank`'s trace.
    pub(crate) fn record(&self, rank: usize, name: &str, cat: &'static str, ts: f64, dur: f64) {
        self.events[rank].lock().unwrap().push(TraceEvent {
            rank,
            name: name.to_string(),
            cat,
            ts_us: ts,
            dur_us: dur,
        });
    }

    /// Snapshot of every rank's simulated time.
    pub(crate) fn times(&self) -> Vec<f64> {
        self.times.iter().map(|t| *t.lock().unwrap()).collect()
    }

    /// Drain all recorded events, ordered by (rank, start time).
    pub(crate) fn take_events(&self) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        for e in &self.events {
            out.append(&mut e.lock().unwrap());
        }
        out.sort_by(|a, b| {
            (a.rank, a.ts_us)
                .partial_cmp(&(b.rank, b.ts_us))
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        out
    }

    /// Reset every rank's clock to zero (events are kept).
    pub(crate) fn reset(&self) {
        for t in &self.times {
            *t.lock().unwrap() = 0.0;
        }
    }
}

/// Split an `f64` into two `f32`s that sum back to ~48-bit precision.
/// Timestamps and byte counts ride the `f32` message fabric this way —
/// plain arithmetic, no bit-pattern tricks (NaN payloads would be fragile).
pub(crate) fn split_f64(x: f64) -> [f32; 2] {
    let hi = x as f32;
    let lo = (x - hi as f64) as f32;
    [hi, lo]
}

/// Inverse of [`split_f64`].
pub(crate) fn join_f64(hi: f32, lo: f32) -> f64 {
    hi as f64 + lo as f64
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Serialize trace events to Chrome trace-event JSON (the
/// `{"traceEvents": [...]}` object form). Timestamps are microseconds —
/// the native unit of both the trace format and the simulated clock.
pub fn chrome_trace_json(events: &[TraceEvent]) -> String {
    let mut out = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n");
    for (i, e) in events.iter().enumerate() {
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"pid\":0,\"tid\":{},\
             \"ts\":{:.3},\"dur\":{:.3}}}",
            json_escape(&e.name),
            e.cat,
            e.rank,
            e.ts_us,
            e.dur_us
        ));
        if i + 1 < events.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_join_roundtrip_precision() {
        for x in [0.0, 1.0, 1e6 + 0.125, 9.87654321e8, 4.0e12] {
            let [hi, lo] = split_f64(x);
            let back = join_f64(hi, lo);
            assert!(
                (back - x).abs() <= x.abs() * 1e-12 + 1e-9,
                "{x} -> {back}"
            );
        }
    }

    #[test]
    fn chrome_trace_shape() {
        let events = vec![
            TraceEvent {
                rank: 0,
                name: "fwd".into(),
                cat: "compute",
                ts_us: 0.0,
                dur_us: 10.0,
            },
            TraceEvent {
                rank: 1,
                name: "moe/a2a \"x\"".into(),
                cat: "comm",
                ts_us: 10.0,
                dur_us: 2.5,
            },
        ];
        let j = chrome_trace_json(&events);
        assert!(j.starts_with("{\"displayTimeUnit\""));
        assert!(j.contains("\"traceEvents\":["));
        assert!(j.contains("\"tid\":1"));
        assert!(j.contains("\\\"x\\\""));
        assert!(j.trim_end().ends_with("]}"));
        // Exactly one JSON object per event line.
        assert_eq!(j.matches("\"ph\":\"X\"").count(), 2);
    }
}
