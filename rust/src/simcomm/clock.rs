//! The virtual clock of the functional simulator.
//!
//! A clocked [`super::Fabric`] carries one [`SimClock`]: per-rank simulated
//! time (microseconds) plus a per-rank trace-event log. Every rank owns
//! **three lanes** ([`Lane`]):
//!
//! * the **main lane** — the compute stream. Time advances via
//!   [`super::Communicator::advance`] (labelled compute spans), exposed
//!   p2p waits, and exposed waits on nonblocking communication.
//! * the **comm lane** — the layer-collective stream (the NCCL-comm-stream
//!   stand-in for a2a / TP / ETP collectives). Every collective occupies it
//!   for its priced duration; back-to-back collectives queue (the lane is
//!   a serial resource). A nonblocking collective
//!   ([`super::Communicator::all_reduce_sum_i`] &c.) runs here
//!   **concurrently with the main lane** — the makespan only pays the part
//!   not hidden under compute, which is what makes comm–compute overlap
//!   measurable instead of assumed.
//! * the **grad-sync lane** ([`Lane::Bg`]) — the dedicated
//!   gradient/param-sync stream
//!   ([`super::Communicator::charge_collective_bg`]), serial among its own
//!   charges but concurrent with both other lanes.
//!
//! Time advances in exactly two ways:
//!
//! * **compute** — [`super::Communicator::advance`] charges a labelled span
//!   to the calling rank's main lane;
//! * **communication** — every collective and point-to-point transfer
//!   charges the *same* [`CommCost`] primitive the analytic performance
//!   model prices (`collectives::cost`), after synchronizing the group on
//!   `max(issue times)`. One cost implementation means the executed clock
//!   and the analytic estimate can never drift on the price of a
//!   collective.
//!
//! Collective semantics: a collective entered by every group member at
//! times `t_i` (with comm-lane frontiers `c_i`) occupies each member's comm
//! lane over `[S, S + cost]` where `S = max_i(max(t_i, c_i))` and `cost`
//! comes from [`CommCost::price`] for the algorithm the communicator
//! actually ran. A *blocking* collective additionally advances the main
//! lane to `S + cost`; a *nonblocking* one returns a
//! [`super::CommHandle`] and the main lane catches up only at
//! [`super::Communicator::wait`]. The max is established by a tiny leader
//! exchange of timestamps *after* the payload phase — control traffic that
//! never touches payload math, so clocked execution is bit-identical to
//! unclocked execution.
//!
//! The event log serializes to the Chrome trace-event format
//! ([`chrome_trace_json`]): load the file at `chrome://tracing` or
//! <https://ui.perfetto.dev> — up to three rows per rank (main, comm and
//! grad-sync lanes), compute and communication spans color-coded by
//! category, gaps on the main lane = waiting (pipeline bubbles / exposed
//! communication).

use std::borrow::Cow;
use std::sync::Mutex;

use crate::collectives::CommCost;

/// Which per-rank timeline a span occupies. The two comm lanes model the
/// two NCCL streams a Megatron rank drives: layer collectives (a2a, TP/ETP
/// gathers) on one, gradient/param sync on the other — they proceed
/// concurrently with each other and with compute, but each lane is a
/// serial resource.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Lane {
    /// The compute stream: compute spans, exposed p2p waits, exposed
    /// nonblocking-comm waits.
    Main,
    /// The layer-collective communication stream.
    Comm,
    /// The background gradient/param-sync stream (bucketed DP/EDP
    /// grad-reduce issued under backward).
    Bg,
}

impl Lane {
    /// Stable name used in the chrome-trace thread labels.
    pub fn name(self) -> &'static str {
        match self {
            Lane::Main => "main",
            Lane::Comm => "comm",
            Lane::Bg => "grad-sync",
        }
    }
}

/// One timed span on one rank's simulated timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Global rank the span belongs to.
    pub rank: usize,
    /// Phase label (e.g. `moe/a2a_dispatch`, `fwd`, `optimizer`). Almost
    /// every span is labelled with a static string; `Cow` keeps the hot
    /// record path allocation-free so a 4096-rank step doesn't malloc
    /// per event.
    pub name: Cow<'static, str>,
    /// Category: `compute`, `comm`, `p2p`, or `wait`.
    pub cat: &'static str,
    /// Which of the rank's timelines the span occupies.
    pub lane: Lane,
    /// Start time, simulated microseconds.
    pub ts_us: f64,
    /// Duration, simulated microseconds.
    pub dur_us: f64,
}

/// Per-rank simulated time + trace log. Owned by a clocked fabric.
pub(crate) struct SimClock {
    pub(crate) cost: CommCost,
    /// Main-lane (compute) time per rank.
    times: Vec<Mutex<f64>>,
    /// Comm-lane frontier per rank: when the rank's layer-collective
    /// stream next becomes free.
    comm_free: Vec<Mutex<f64>>,
    /// Background (grad-sync) lane frontier per rank.
    bg_free: Vec<Mutex<f64>>,
    events: Vec<Mutex<Vec<TraceEvent>>>,
}

impl SimClock {
    pub(crate) fn new(world: usize, cost: CommCost) -> Self {
        Self {
            cost,
            times: (0..world).map(|_| Mutex::new(0.0)).collect(),
            comm_free: (0..world).map(|_| Mutex::new(0.0)).collect(),
            bg_free: (0..world).map(|_| Mutex::new(0.0)).collect(),
            events: (0..world).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    /// Current simulated main-lane time of `rank`.
    pub(crate) fn now(&self, rank: usize) -> f64 {
        *self.times[rank].lock().unwrap()
    }

    /// Set `rank`'s main-lane clock (collective exit, p2p arrival, wait).
    pub(crate) fn set(&self, rank: usize, t: f64) {
        *self.times[rank].lock().unwrap() = t;
    }

    fn lane_frontier(&self, lane: Lane) -> &[Mutex<f64>] {
        match lane {
            Lane::Main => unreachable!("main lane has no frontier"),
            Lane::Comm => &self.comm_free,
            Lane::Bg => &self.bg_free,
        }
    }

    /// When `rank`'s `lane` next becomes free.
    pub(crate) fn lane_free_at(&self, rank: usize, lane: Lane) -> f64 {
        *self.lane_frontier(lane)[rank].lock().unwrap()
    }

    /// Occupy `rank`'s `lane` over `[start, start + dur]`, recording the
    /// span. `start` must be ≥ the lane frontier (the caller synchronizes
    /// the group on `max(issue, frontier)` first), so lane spans never
    /// overlap.
    pub(crate) fn bill_lane(
        &self,
        rank: usize,
        lane: Lane,
        name: impl Into<Cow<'static, str>>,
        start: f64,
        dur: f64,
    ) {
        let mut free = self.lane_frontier(lane)[rank].lock().unwrap();
        debug_assert!(start + 1e-9 >= *free, "lane overlap: {start} < {free}");
        *free = start + dur;
        drop(free);
        self.record(rank, name, "comm", lane, start, dur);
    }

    /// Charge `us` of local work to `rank`'s main lane; returns the span
    /// start.
    pub(crate) fn advance(&self, rank: usize, us: f64) -> f64 {
        let mut t = self.times[rank].lock().unwrap();
        let start = *t;
        *t += us.max(0.0);
        start
    }

    /// Append a span to `rank`'s trace.
    pub(crate) fn record(
        &self,
        rank: usize,
        name: impl Into<Cow<'static, str>>,
        cat: &'static str,
        lane: Lane,
        ts: f64,
        dur: f64,
    ) {
        self.events[rank].lock().unwrap().push(TraceEvent {
            rank,
            name: name.into(),
            cat,
            lane,
            ts_us: ts,
            dur_us: dur,
        });
    }

    /// Snapshot of every rank's main-lane simulated time.
    pub(crate) fn times(&self) -> Vec<f64> {
        self.times.iter().map(|t| *t.lock().unwrap()).collect()
    }

    /// Snapshot of every rank's comm-lane frontier, folded with the
    /// background lane (the later of the two streams).
    pub(crate) fn comm_times(&self) -> Vec<f64> {
        self.comm_free
            .iter()
            .zip(&self.bg_free)
            .map(|(c, b)| (*c.lock().unwrap()).max(*b.lock().unwrap()))
            .collect()
    }

    /// Drain all recorded events, ordered by (rank, start time).
    pub(crate) fn take_events(&self) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        for e in &self.events {
            out.append(&mut e.lock().unwrap());
        }
        out.sort_by(|a, b| {
            (a.rank, a.ts_us)
                .partial_cmp(&(b.rank, b.ts_us))
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        out
    }

    /// Reset every rank's clock (all lanes) to zero (events are kept).
    pub(crate) fn reset(&self) {
        for t in self.times.iter().chain(&self.comm_free).chain(&self.bg_free) {
            *t.lock().unwrap() = 0.0;
        }
    }
}

/// Split an `f64` into two `f32`s that sum back to ~48-bit precision.
/// Timestamps and byte counts ride the `f32` message fabric this way —
/// plain arithmetic, no bit-pattern tricks (NaN payloads would be fragile).
pub(crate) fn split_f64(x: f64) -> [f32; 2] {
    let hi = x as f32;
    let lo = (x - hi as f64) as f32;
    [hi, lo]
}

/// Inverse of [`split_f64`].
pub(crate) fn join_f64(hi: f32, lo: f32) -> f64 {
    hi as f64 + lo as f64
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Chrome-trace thread id of a (rank, lane) timeline: the lanes of a rank
/// sit on adjacent tids so they group together in the viewer.
fn tid_of(rank: usize, lane: Lane) -> usize {
    let slot = match lane {
        Lane::Main => 0,
        Lane::Comm => 1,
        Lane::Bg => 2,
    };
    rank * 3 + slot
}

/// Serialize trace events to Chrome trace-event JSON (the
/// `{"traceEvents": [...]}` object form). Timestamps are microseconds —
/// the native unit of both the trace format and the simulated clock. Each
/// rank renders as one row per active lane: `rank N` (the main/compute
/// lane), `rank N comm` (the layer-collective lane) and `rank N grad-sync`
/// (the gradient-sync lane), named via thread-name metadata events.
pub fn chrome_trace_json(events: &[TraceEvent]) -> String {
    let mut out = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n");
    // Thread-name metadata for every (rank, lane) present.
    let mut seen: Vec<(usize, Lane)> = Vec::new();
    for e in events {
        if !seen.contains(&(e.rank, e.lane)) {
            seen.push((e.rank, e.lane));
        }
    }
    seen.sort_by_key(|&(r, l)| tid_of(r, l));
    let mut first = true;
    for (rank, lane) in seen {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        let label = match lane {
            Lane::Main => format!("rank {rank}"),
            Lane::Comm => format!("rank {rank} comm"),
            Lane::Bg => format!("rank {rank} grad-sync"),
        };
        out.push_str(&format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":{},\
             \"args\":{{\"name\":\"{}\"}}}}",
            tid_of(rank, lane),
            label
        ));
    }
    for e in events.iter() {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"pid\":0,\"tid\":{},\
             \"ts\":{:.3},\"dur\":{:.3}}}",
            json_escape(&e.name),
            e.cat,
            tid_of(e.rank, e.lane),
            e.ts_us,
            e.dur_us
        ));
    }
    out.push('\n');
    out.push_str("]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_join_roundtrip_precision() {
        for x in [0.0, 1.0, 1e6 + 0.125, 9.87654321e8, 4.0e12] {
            let [hi, lo] = split_f64(x);
            let back = join_f64(hi, lo);
            assert!(
                (back - x).abs() <= x.abs() * 1e-12 + 1e-9,
                "{x} -> {back}"
            );
        }
    }

    #[test]
    fn chrome_trace_shape() {
        let events = vec![
            TraceEvent {
                rank: 0,
                name: "fwd".into(),
                cat: "compute",
                lane: Lane::Main,
                ts_us: 0.0,
                dur_us: 10.0,
            },
            TraceEvent {
                rank: 1,
                name: "moe/a2a \"x\"".into(),
                cat: "comm",
                lane: Lane::Comm,
                ts_us: 10.0,
                dur_us: 2.5,
            },
        ];
        let j = chrome_trace_json(&events);
        assert!(j.starts_with("{\"displayTimeUnit\""));
        assert!(j.contains("\"traceEvents\":["));
        // rank 0 main lane = tid 0; rank 1 comm lane = tid 4.
        assert!(j.contains("\"tid\":0"));
        assert!(j.contains("\"tid\":4"));
        assert!(j.contains("rank 1 comm"));
        assert!(j.contains("\\\"x\\\""));
        assert!(j.trim_end().ends_with("]}"));
        // Exactly one JSON object per event line plus lane metadata.
        assert_eq!(j.matches("\"ph\":\"X\"").count(), 2);
        assert_eq!(j.matches("\"ph\":\"M\"").count(), 2);
    }

    #[test]
    fn comm_lane_bill_advances_frontier() {
        use crate::cluster::ClusterSpec;
        let c = SimClock::new(2, CommCost::new(ClusterSpec::eos(2)));
        assert_eq!(c.lane_free_at(0, Lane::Comm), 0.0);
        c.bill_lane(0, Lane::Comm, "x", 5.0, 10.0);
        assert_eq!(c.lane_free_at(0, Lane::Comm), 15.0);
        // Main lane and bg lane untouched by comm billing.
        assert_eq!(c.now(0), 0.0);
        assert_eq!(c.lane_free_at(0, Lane::Bg), 0.0);
        c.bill_lane(0, Lane::Comm, "y", 15.0, 2.0);
        assert_eq!(c.lane_free_at(0, Lane::Comm), 17.0);
        // The bg lane queues independently.
        c.bill_lane(0, Lane::Bg, "g", 1.0, 4.0);
        assert_eq!(c.lane_free_at(0, Lane::Bg), 5.0);
        let ev = c.take_events();
        assert_eq!(ev.len(), 3);
        assert!(ev.iter().all(|e| e.cat == "comm"));
        assert_eq!(ev.iter().filter(|e| e.lane == Lane::Bg).count(), 1);
    }
}
