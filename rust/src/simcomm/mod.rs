//! Functional in-process communicator: N rank threads exchanging real `f32`
//! buffers through per-rank mailboxes — the NCCL stand-in for
//! numerical-correctness work. The token dispatcher (paper §3.3) and the
//! distributed trainer run on it, and the appendix loss-equivalence
//! experiment (Figures 7/8) compares folded multi-rank runs against
//! single-rank references bit-for-bit.
//!
//! # Collective algorithms
//!
//! Every collective is implemented by *algorithmically real* communication
//! patterns selected via [`CollectiveAlgo`] / [`AlgoSelection`], mirroring
//! the algorithm families the analytic cost model
//! ([`crate::collectives::CommModel`]) prices:
//!
//! * [`CollectiveAlgo::NaiveLeader`] — leader gathers, computes, scatters.
//!   Serializes all traffic through one rank; kept as the **oracle** the
//!   differential suite (`tests/collectives_equivalence.rs`) checks every
//!   other algorithm against, bit-for-bit.
//! * [`CollectiveAlgo::Ring`] — chunk-pipelined ring/chain. Used for
//!   all-reduce (pipelined chain reduce in ascending rank order + pipelined
//!   ring broadcast), all-gather (segments circulate the ring), and
//!   broadcast (pipelined chain from the root).
//! * [`CollectiveAlgo::RecursiveHalving`] — log₂(n)-step halving exchange
//!   for reduce-scatter on power-of-two groups (falls back to
//!   [`CollectiveAlgo::PairwiseExchange`] otherwise). Summation is
//!   *deferred*: contributions travel unreduced and the shard owner folds
//!   them in rank order, so determinism is preserved.
//! * [`CollectiveAlgo::PairwiseExchange`] — n−1 deterministic rounds of
//!   direct exchange; the all-to-all(-v) workhorse and the variable-shard
//!   reduce-scatter used by the dispatcher's ETP combine.
//!
//! # Determinism invariant (load-bearing)
//!
//! **Every algorithm reduces in ascending group-index order**: for each
//! element, the produced sum is exactly `((x₀ + x₁) + x₂) + …` over the
//! group members — the same fold the naive leader performs. Algorithms that
//! cannot preserve this order for free (classic rotating-chunk ring
//! all-reduce, eager recursive halving) are implemented as order-preserving
//! variants (chain-pipelined reduce, deferred-summation halving) instead.
//! This is what lets the loss-equivalence experiments and the differential
//! suite compare algorithms **bit-for-bit**, not just within a tolerance.
//!
//! # Buffer pool
//!
//! Message payloads are pooled per rank ([`Fabric::pool_stats`]): once a
//! workload reaches steady state, collective calls perform **zero payload
//! allocations** — buffers cycle between rank pools and mailboxes. The
//! `*_into` variants additionally reuse caller-owned output buffers, which
//! is what the dispatcher hot path uses (`dispatcher/workflow.rs`).
//!
//! # Virtual clock (event-clocked execution)
//!
//! A fabric built with [`Fabric::new_clocked`] carries per-rank **simulated
//! time**: every collective and point-to-point transfer advances the clock
//! using the *same* [`CommCost`] primitives the analytic performance model
//! prices, and [`Communicator::advance`] charges labelled compute spans.
//! A collective entered at times `t_i` exits every member at
//! `max_i(t_i) + cost`; a p2p message sent at `t_s` becomes available to
//! the receiver at `t_s + p2p_cost`. Clock bookkeeping rides separate
//! control messages and never touches payload math, so clocked runs are
//! **bit-identical** to unclocked runs (enforced by
//! `tests/clocked_timing.rs`). Spans are logged per rank and export as a
//! chrome trace ([`Fabric::take_trace`] + [`chrome_trace_json`]).
//!
//! # Nonblocking communication (comm–compute overlap)
//!
//! Every rank's clock has three lanes ([`Lane`]): the **main lane**
//! (compute), the **comm lane** (the NCCL-comm-stream stand-in for layer
//! collectives), and the **grad-sync lane** (the dedicated DP stream,
//! [`Communicator::charge_collective_bg`]). The `*_i`
//! variants of the collectives ([`Communicator::all_reduce_sum_i`],
//! [`Communicator::charge_collective_i`], …) move the *same payload as
//! their blocking counterparts, bill the *same* [`CommCost`] price on the
//! comm lane — but return a [`CommHandle`] instead of advancing the main
//! lane. The main lane keeps computing; [`Communicator::wait`] charges only
//! the **exposed** remainder (`max(0, comm_end − now)`). An i-variant
//! followed by an immediate `wait` is bit-identical in payload and equal in
//! clock price to the blocking call (property-tested in
//! `tests/prop_invariants.rs`); a `wait` issued after compute genuinely
//! hides the overlapped communication in the makespan. The comm lane is a
//! serial resource: concurrent collectives on one rank queue.
//!
//! Point-to-point messages carry an optional **tag**
//! ([`Communicator::send_tagged`] / [`Communicator::recv_tagged`]) so
//! executors with interleaved message streams (e.g. the interleaved-1F1B
//! schedule, where forward activations and backward gradients of different
//! model chunks cross on the same rank pair) match payloads by
//! `(source, tag)` instead of arrival order.

mod algos;
mod clock;
pub(crate) mod engine;
pub mod quant;

pub use clock::{chrome_trace_json, Lane, TraceEvent};
pub use quant::{dequantize_chunked, fake_quantize_chunked, quantize_chunked, QuantChunks};

use std::borrow::Cow;
use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Condvar, Mutex};

use clock::SimClock;
use crate::cluster::{ClusterSpec, LinkKind};
use crate::collectives::{CommCost, CommPrimitive};

/// Wire width of collective payload elements — the dtype the fabric *bills*
/// per transported element. The functional engine always moves `f32`
/// stand-ins (determinism and reduction order are untouched); the payload
/// width scales what [`Fabric::link_traffic`] meters and what the virtual
/// clock prices per element, so a quantized dispatch is billed at 1 B/el
/// while a bf16 twin of the same routes is billed at 2 B/el — exactly half
/// the bytes on every wire, by construction (pinned in
/// `tests/prop_invariants.rs`). Per-chunk scales of the quantized codec
/// ([`quant`]) ride as unbilled metadata, mirroring NCCL's out-of-band
/// scale exchange.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Payload {
    /// Full f32 elements, 4 B each (the functional default).
    F32,
    /// bf16 activations, 2 B per element.
    Bf16,
    /// 1-byte quantized elements (fp8-class dispatch) with per-chunk scales.
    Quantized,
}

impl Payload {
    /// Billed bytes per transported element.
    pub fn bytes_per_el(self) -> f64 {
        match self {
            Payload::F32 => 4.0,
            Payload::Bf16 => 2.0,
            Payload::Quantized => 1.0,
        }
    }
}

/// Which algorithm a collective primitive runs. See module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollectiveAlgo {
    /// Leader gathers, computes, scatters — the correctness oracle.
    NaiveLeader,
    /// Chunk-pipelined ring/chain (all-reduce, all-gather, broadcast).
    Ring,
    /// log₂(n) halving exchange with deferred rank-order summation
    /// (reduce-scatter; power-of-two groups, else pairwise fallback).
    RecursiveHalving,
    /// n−1 deterministic direct-exchange rounds (all-to-all, reduce-scatter).
    PairwiseExchange,
    /// Node-grouped, topology-executed: intra-node gather to a node leader
    /// over NVLink, sequential inter-node exchange across the node leaders
    /// over IB, intra-node fan-out back. The inter-node reduction chains
    /// across leaders in ascending group order (node runs are contiguous in
    /// the sorted group), so every fold stays `((x₀+x₁)+x₂)+…` —
    /// bit-identical to the oracle.
    Hierarchical,
    /// Two-level all-to-all-v (DeepEP-style): payloads headed for a remote
    /// node are aggregated at the local node leader, cross IB as **one
    /// bundled message per node pair**, and are distributed intra-node on
    /// the far side. For the non-a2a primitives this is an alias of
    /// [`Self::Hierarchical`].
    HierarchicalA2A,
}

impl CollectiveAlgo {
    /// Stable name used in bench labels and the analytic cost model.
    pub fn name(self) -> &'static str {
        match self {
            CollectiveAlgo::NaiveLeader => "naive-leader",
            CollectiveAlgo::Ring => "ring",
            CollectiveAlgo::RecursiveHalving => "recursive-halving",
            CollectiveAlgo::PairwiseExchange => "pairwise",
            CollectiveAlgo::Hierarchical => "hierarchical",
            CollectiveAlgo::HierarchicalA2A => "hierarchical-a2a",
        }
    }
}

/// Per-primitive algorithm selection for a fabric/communicator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AlgoSelection {
    pub all_reduce: CollectiveAlgo,
    pub all_gather: CollectiveAlgo,
    pub reduce_scatter: CollectiveAlgo,
    pub all_to_all: CollectiveAlgo,
    pub broadcast: CollectiveAlgo,
}

impl AlgoSelection {
    /// The leader-based oracle for every primitive.
    pub fn naive() -> Self {
        Self {
            all_reduce: CollectiveAlgo::NaiveLeader,
            all_gather: CollectiveAlgo::NaiveLeader,
            reduce_scatter: CollectiveAlgo::NaiveLeader,
            all_to_all: CollectiveAlgo::NaiveLeader,
            broadcast: CollectiveAlgo::NaiveLeader,
        }
    }

    /// The production suite: ring all-reduce/all-gather/broadcast,
    /// recursive-halving reduce-scatter, pairwise all-to-all.
    pub fn fast() -> Self {
        Self {
            all_reduce: CollectiveAlgo::Ring,
            all_gather: CollectiveAlgo::Ring,
            reduce_scatter: CollectiveAlgo::RecursiveHalving,
            all_to_all: CollectiveAlgo::PairwiseExchange,
            broadcast: CollectiveAlgo::Ring,
        }
    }

    /// The topology-aware suite: node-grouped hierarchical algorithms for
    /// every primitive, with the two-level (node-aggregated) all-to-all.
    pub fn hierarchical() -> Self {
        Self {
            all_reduce: CollectiveAlgo::Hierarchical,
            all_gather: CollectiveAlgo::Hierarchical,
            reduce_scatter: CollectiveAlgo::Hierarchical,
            all_to_all: CollectiveAlgo::HierarchicalA2A,
            broadcast: CollectiveAlgo::Hierarchical,
        }
    }
}

impl Default for AlgoSelection {
    fn default() -> Self {
        Self::fast()
    }
}

/// Reserved tag for the engine's internal transport (collective algorithm
/// hops, clock-sync control traffic). Public p2p uses tag [`DEFAULT_TAG`];
/// executors that need stream separation pick their own tags.
const INTERNAL_TAG: u64 = u64::MAX;

/// Tag of untagged public p2p sends/receives.
pub const DEFAULT_TAG: u64 = 0;

/// A message between ranks: tagged payload (pool-backed) plus the clock
/// metadata the receiver needs to price the transfer.
#[derive(Debug)]
struct Msg {
    src: usize,
    /// Match key: receives pair on `(src, tag)`, FIFO within the pair.
    /// Internal engine traffic uses [`INTERNAL_TAG`] so collective hops
    /// and p2p payloads can never cross streams.
    tag: u64,
    /// Sender's simulated time when the message was posted (0 unclocked).
    sent_at: f64,
    /// Bytes billed to the clock for the transfer (defaults to the real
    /// payload size; [`Communicator::send_billed`] overrides it so skeleton
    /// executors can move tiny stand-in payloads billed at model scale).
    billed_bytes: f64,
    data: Vec<f32>,
}

/// Per-rank inbox: one deque guarded by a mutex/condvar pair. Receiving by
/// source scans front-to-back, so per-source FIFO order is preserved even
/// when a peer races ahead into its next collective. Steady state performs
/// no allocation: the deque's capacity persists.
struct Mailbox {
    q: Mutex<VecDeque<Msg>>,
    cv: Condvar,
}

impl Mailbox {
    fn new() -> Self {
        Self { q: Mutex::new(VecDeque::new()), cv: Condvar::new() }
    }

    fn push(&self, msg: Msg) {
        self.q.lock().unwrap().push_back(msg);
        self.cv.notify_all();
    }

    /// Earliest message from `src` with `tag` (blocking).
    fn take_from(&self, src: usize, tag: u64) -> Msg {
        let mut q = self.q.lock().unwrap();
        loop {
            if let Some(pos) = q.iter().position(|m| m.src == src && m.tag == tag) {
                return q.remove(pos).unwrap();
            }
            q = self.cv.wait(q).unwrap();
        }
    }
}

/// Per-rank free list of payload buffers. Buffers migrate between ranks
/// (sender takes from its own pool, receiver releases into its own), but
/// collectives move symmetric volume per call, so populations stabilize.
struct Pool {
    free: Mutex<Vec<Vec<f32>>>,
}

/// Cap on buffers retained per rank pool (excess is dropped on release).
const POOL_MAX: usize = 128;

impl Pool {
    fn new() -> Self {
        Self { free: Mutex::new(Vec::new()) }
    }
}

/// Cumulative traffic that crossed one link class of the fabric — see
/// [`Fabric::link_traffic`].
#[derive(Debug, Default, Clone, Copy)]
pub struct LinkTraffic {
    /// Messages posted over this link class.
    pub messages: u64,
    /// Billed bytes moved over it.
    pub bytes: f64,
}

/// Slot of a [`LinkKind`] in the fabric's traffic table.
fn link_index(kind: LinkKind) -> usize {
    match kind {
        LinkKind::Loopback => 0,
        LinkKind::NvLink => 1,
        LinkKind::InfiniBand => 2,
    }
}

/// Shared mailbox fabric connecting `world` ranks.
pub struct Fabric {
    world: usize,
    mailboxes: Vec<Mailbox>,
    pools: Vec<Pool>,
    barrier: Arc<Barrier>,
    algos: AlgoSelection,
    pool_hits: AtomicUsize,
    pool_misses: AtomicUsize,
    /// Node-grouped topology of this fabric: the grouping oracle of the
    /// hierarchical collective algorithms and the classifier behind the
    /// per-link traffic counters. Clocked fabrics share the cost model's
    /// cluster; plain fabrics default to the Eos shape for `world` GPUs.
    topology: ClusterSpec,
    /// Per-link-class traffic counters, indexed by [`link_index`].
    traffic: Mutex<[LinkTraffic; 3]>,
    /// Virtual clock (None on plain fabrics — zero overhead, no extra
    /// control messages).
    clock: Option<SimClock>,
}

impl Fabric {
    /// Fabric with the default (fast) algorithm suite.
    pub fn new(world: usize) -> Arc<Self> {
        Self::new_with(world, AlgoSelection::default())
    }

    /// Fabric with an explicit algorithm selection.
    pub fn new_with(world: usize, algos: AlgoSelection) -> Arc<Self> {
        Self::build(world, algos, None, ClusterSpec::eos(world.max(1)))
    }

    /// Clocked fabric: collectives, p2p transfers and
    /// [`Communicator::advance`] charges move per-rank simulated time priced
    /// by `cost` — the same [`CommCost`] the analytic model uses.
    pub fn new_clocked(world: usize, algos: AlgoSelection, cost: CommCost) -> Arc<Self> {
        let topology = cost.cluster.clone();
        Self::build(world, algos, Some(SimClock::new(world, cost)), topology)
    }

    fn build(
        world: usize,
        algos: AlgoSelection,
        clock: Option<SimClock>,
        topology: ClusterSpec,
    ) -> Arc<Self> {
        let mailboxes = (0..world).map(|_| Mailbox::new()).collect();
        let pools = (0..world).map(|_| Pool::new()).collect();
        Arc::new(Self {
            world,
            mailboxes,
            pools,
            barrier: Arc::new(Barrier::new(world)),
            algos,
            pool_hits: AtomicUsize::new(0),
            pool_misses: AtomicUsize::new(0),
            topology,
            traffic: Mutex::new([LinkTraffic::default(); 3]),
            clock,
        })
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// True when this fabric advances a virtual clock.
    pub fn clocked(&self) -> bool {
        self.clock.is_some()
    }

    /// Per-rank simulated main-lane (compute) times (µs); empty on
    /// unclocked fabrics.
    pub fn sim_times_us(&self) -> Vec<f64> {
        self.clock.as_ref().map(|c| c.times()).unwrap_or_default()
    }

    /// Per-rank comm-lane frontiers (µs); empty on unclocked fabrics.
    pub fn sim_comm_times_us(&self) -> Vec<f64> {
        self.clock.as_ref().map(|c| c.comm_times()).unwrap_or_default()
    }

    /// Maximum simulated time across ranks and lanes (the makespan so
    /// far). Un-waited nonblocking communication counts — the step is not
    /// over until the comm lane drains.
    pub fn max_sim_time_us(&self) -> f64 {
        self.sim_times_us()
            .into_iter()
            .chain(self.sim_comm_times_us())
            .fold(0.0, f64::max)
    }

    /// Drain the recorded trace events (ordered by rank, then start time).
    /// Serialize with [`chrome_trace_json`].
    pub fn take_trace(&self) -> Vec<TraceEvent> {
        self.clock.as_ref().map(|c| c.take_events()).unwrap_or_default()
    }

    /// Reset every rank's simulated clock to zero (trace is kept). The
    /// fabric must be idle.
    pub fn reset_clock(&self) {
        if let Some(c) = &self.clock {
            c.reset();
        }
    }

    /// The fabric-wide algorithm selection.
    pub fn algos(&self) -> AlgoSelection {
        self.algos
    }

    /// The node-grouped topology this fabric runs on.
    pub fn topology(&self) -> &ClusterSpec {
        &self.topology
    }

    /// Cumulative traffic that crossed `kind` links since the fabric was
    /// built. Every posted message counts — collective algorithm hops, p2p
    /// payloads, and clock control traffic — so the counters measure what
    /// an algorithm *actually* put on each wire. This is how the two-level
    /// a2a's cross-IB saving is pinned by test.
    pub fn link_traffic(&self, kind: LinkKind) -> LinkTraffic {
        self.traffic.lock().unwrap()[link_index(kind)]
    }

    /// `(hits, misses)` of the payload buffer pool. A workload is in steady
    /// state when `misses` stops growing — from then on collective calls
    /// allocate no payload buffers.
    pub fn pool_stats(&self) -> (usize, usize) {
        (
            self.pool_hits.load(Ordering::Relaxed),
            self.pool_misses.load(Ordering::Relaxed),
        )
    }

    /// Handle for one rank.
    pub fn communicator(self: &Arc<Self>, rank: usize) -> Communicator {
        assert!(rank < self.world);
        Communicator {
            fabric: Arc::clone(self),
            rank,
            algos: self.algos,
            phase: RefCell::new(String::new()),
            bill_scale: Cell::new(1.0),
            payload: Cell::new(Payload::F32),
            nonblocking: Cell::new(false),
            pending: RefCell::new(None),
        }
    }

    /// All rank communicators at once (for spawning workers).
    pub fn communicators(self: &Arc<Self>) -> Vec<Communicator> {
        (0..self.world).map(|r| self.communicator(r)).collect()
    }

    /// Take a pooled buffer with at least `cap` capacity. The caller's own
    /// pool is tried first; on a miss, peer pools are scanned (buffers
    /// migrate rank→rank inside messages, so global conservation — not
    /// per-rank balance — is what guarantees steady-state reuse). Only when
    /// no pool anywhere holds a fitting buffer does a real allocation
    /// happen, counted in [`Fabric::pool_stats`].
    fn take(&self, rank: usize, cap: usize) -> Vec<f32> {
        if cap == 0 {
            return Vec::new(); // zero-capacity vecs never allocate
        }
        for k in 0..self.world {
            let r = (rank + k) % self.world;
            let mut free = self.pools[r].free.lock().unwrap();
            // Best fit: the smallest buffer that is large enough, so small
            // requests don't waste big buffers (which would delay the
            // steady-state plateau).
            let best = (0..free.len())
                .filter(|&i| free[i].capacity() >= cap)
                .min_by_key(|&i| free[i].capacity());
            if let Some(pos) = best {
                let mut b = free.swap_remove(pos);
                drop(free);
                self.pool_hits.fetch_add(1, Ordering::Relaxed);
                b.clear();
                return b;
            }
        }
        // Reuse the largest retained allocation in the own pool (growing
        // it) before minting a new one; both count as a miss (a real
        // allocation happens).
        let mut free = self.pools[rank].free.lock().unwrap();
        let reuse = (0..free.len()).max_by_key(|&i| free[i].capacity());
        let out = match reuse {
            Some(i) => {
                let mut b = free.swap_remove(i);
                drop(free);
                b.clear();
                b.reserve(cap);
                b
            }
            None => {
                drop(free);
                Vec::with_capacity(cap)
            }
        };
        self.pool_misses.fetch_add(1, Ordering::Relaxed);
        out
    }

    /// Return a buffer to `rank`'s pool.
    fn give(&self, rank: usize, mut buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        buf.clear();
        let mut free = self.pools[rank].free.lock().unwrap();
        if free.len() < POOL_MAX {
            free.push(buf);
        }
    }
}

/// Completion handle of a nonblocking communication call. Carries the
/// simulated completion time of the comm-lane span; the payload itself is
/// already delivered when the call returns (the functional engine moves
/// payloads eagerly — only the *clock* is deferred). Settle it with
/// [`Communicator::wait`], which charges the exposed remainder to the main
/// lane. Dropping a handle without waiting leaves the comm lane billed but
/// the main lane un-synchronized (the fabric makespan still covers it).
#[must_use = "wait() the handle so the exposed communication time is charged"]
#[derive(Debug)]
pub struct CommHandle {
    /// Simulated completion time of the comm span, µs (0 unclocked).
    end_us: f64,
    /// Duration of the comm span, µs (0 unclocked).
    dur_us: f64,
    /// Label recorded on the main lane if the wait is exposed. `Cow` so
    /// the static-labelled hot paths (executed skeletons, grad buckets)
    /// never allocate per handle.
    label: Cow<'static, str>,
    /// Trace category of the exposed wait (`wait` or `p2p`).
    cat: &'static str,
}

impl CommHandle {
    /// An already-complete handle (unclocked fabrics, degenerate groups).
    pub fn completed() -> Self {
        Self { end_us: 0.0, dur_us: 0.0, label: Cow::Borrowed(""), cat: "wait" }
    }

    /// Simulated completion time of the communication, µs.
    pub fn end_us(&self) -> f64 {
        self.end_us
    }

    /// Priced duration of the communication, µs.
    pub fn dur_us(&self) -> f64 {
        self.dur_us
    }
}

/// Per-rank endpoint. Collective calls must be entered by *every* member of
/// `group` (a sorted list of global ranks including `self.rank()`).
pub struct Communicator {
    fabric: Arc<Fabric>,
    rank: usize,
    algos: AlgoSelection,
    /// Current phase label; clocked collectives record their trace span
    /// under it (see [`Self::set_phase`]).
    phase: RefCell<String>,
    /// Multiplier applied to real payload bytes when billing the clock —
    /// lets scaled-down functional runs charge model-scale volumes.
    bill_scale: Cell<f64>,
    /// Billed wire width per transported element (see [`Payload`]). Applies
    /// to collective transport hops and the per-collective clock charge;
    /// explicit-volume calls (`send_billed`, `charge_collective`) are
    /// unaffected.
    payload: Cell<Payload>,
    /// When set, the next collective's clock charge is deferred into
    /// `pending` instead of advancing the main lane (the `*_i` variants).
    nonblocking: Cell<bool>,
    /// Handle parked by the collective tail while `nonblocking` is set.
    pending: RefCell<Option<CommHandle>>,
}

impl Communicator {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.fabric.world
    }

    /// The algorithm selection this communicator dispatches on.
    pub fn algos(&self) -> AlgoSelection {
        self.algos
    }

    /// Same endpoint with a different algorithm selection (used by the
    /// differential tests to pit algorithms against the oracle on one
    /// fabric).
    pub fn with_algos(&self, algos: AlgoSelection) -> Communicator {
        Communicator {
            fabric: Arc::clone(&self.fabric),
            rank: self.rank,
            algos,
            phase: RefCell::new(String::new()),
            bill_scale: Cell::new(self.bill_scale.get()),
            payload: Cell::new(self.payload.get()),
            nonblocking: Cell::new(false),
            pending: RefCell::new(None),
        }
    }

    /// Global barrier over the whole fabric.
    pub fn barrier(&self) {
        self.fabric.barrier.wait();
    }

    // ---- internal transport -------------------------------------------

    /// Take a pooled scratch buffer (returned via [`Self::release`] or
    /// moved into a message).
    pub(crate) fn take_buf(&self, cap: usize) -> Vec<f32> {
        self.fabric.take(self.rank, cap)
    }

    /// Return a pooled buffer to this rank's pool.
    pub(crate) fn release(&self, buf: Vec<f32>) {
        self.fabric.give(self.rank, buf);
    }

    /// Move an owned (pooled) buffer to `dst` as an internal-transport
    /// message (collective hop / control traffic).
    pub(crate) fn send_vec(&self, dst: usize, data: Vec<f32>) {
        let billed = data.len() as f64 * self.payload.get().bytes_per_el();
        self.push_msg(dst, INTERNAL_TAG, data, billed);
    }

    /// Post a message with an explicit tag and billed volume. Every message
    /// is classified against the fabric topology and counted into the
    /// per-link traffic table — this is the single choke point all traffic
    /// (collective hops, p2p, control) flows through.
    fn push_msg(&self, dst: usize, tag: u64, data: Vec<f32>, billed_bytes: f64) {
        let sent_at = match &self.fabric.clock {
            Some(c) => c.now(self.rank),
            None => 0.0,
        };
        {
            let kind = self.fabric.topology.link_of(self.rank, dst);
            let mut table = self.fabric.traffic.lock().unwrap();
            let slot = &mut table[link_index(kind)];
            slot.messages += 1;
            slot.bytes += billed_bytes;
        }
        self.fabric.mailboxes[dst].push(Msg { src: self.rank, tag, sent_at, billed_bytes, data });
    }

    /// Copy `data` into a pooled buffer and send it to `dst` on the
    /// internal-transport stream.
    pub(crate) fn send_slice(&self, dst: usize, data: &[f32]) {
        let mut buf = self.take_buf(data.len());
        buf.extend_from_slice(data);
        self.send_vec(dst, buf);
    }

    /// Receive the earliest message from `src` with `tag`, with its clock
    /// metadata.
    fn take_msg(&self, src: usize, tag: u64) -> Msg {
        self.fabric.mailboxes[self.rank].take_from(src, tag)
    }

    /// Receive the earliest internal-transport message from `src`, taking
    /// ownership of the pooled payload (pair with [`Self::release`] or
    /// forward it). Does **not** touch the clock — collective algorithms
    /// account time once per collective, not per hop.
    pub(crate) fn recv_take(&self, src: usize) -> Vec<f32> {
        self.take_msg(src, INTERNAL_TAG).data
    }

    /// Receive from `src` into a caller buffer (cleared first); the pooled
    /// payload is recycled. Internal transport.
    pub(crate) fn recv_into_vec(&self, src: usize, out: &mut Vec<f32>) {
        let buf = self.recv_take(src);
        out.clear();
        out.extend_from_slice(&buf);
        self.release(buf);
    }

    /// The fabric's node-grouped topology (the hierarchical algorithms'
    /// grouping oracle).
    pub(crate) fn topology(&self) -> &ClusterSpec {
        &self.fabric.topology
    }

    /// This rank's index within `group`.
    pub(crate) fn my_index(&self, group: &[usize]) -> usize {
        group
            .iter()
            .position(|&r| r == self.rank)
            .expect("rank must be a member of the group")
    }

    // ---- point-to-point ------------------------------------------------

    /// Point-to-point send (asynchronous: the sender's clock does not
    /// advance; the receiver pays the transfer, priced from `sent_at`).
    pub fn send(&self, dst: usize, data: &[f32]) {
        self.send_tagged(dst, DEFAULT_TAG, data);
    }

    /// [`Self::send`] with an explicit message tag: the receiver matches
    /// on `(src, tag)`, FIFO within the pair. Executors whose message
    /// streams interleave on one rank pair (interleaved-1F1B chunks) tag by
    /// `(direction, chunk, microbatch)` so payloads can never cross.
    pub fn send_tagged(&self, dst: usize, tag: u64, data: &[f32]) {
        let billed = data.len() as f64 * 4.0;
        let mut buf = self.take_buf(data.len());
        buf.extend_from_slice(data);
        self.push_msg(dst, tag, buf, billed);
    }

    /// [`Self::send`] with an explicit billed volume: the clock prices the
    /// transfer as `billed_bytes` regardless of the real payload size. This
    /// is how the executed step estimator moves tiny stand-in activations
    /// billed at model scale.
    pub fn send_billed(&self, dst: usize, data: &[f32], billed_bytes: f64) {
        self.send_tagged_billed(dst, DEFAULT_TAG, data, billed_bytes);
    }

    /// Tagged send with an explicit billed volume.
    pub fn send_tagged_billed(&self, dst: usize, tag: u64, data: &[f32], billed_bytes: f64) {
        let mut buf = self.take_buf(data.len());
        buf.extend_from_slice(data);
        self.push_msg(dst, tag, buf, billed_bytes);
    }

    /// Point-to-point receive. Hands the message buffer to the caller
    /// directly (no copy); the pool mints a replacement on a later send.
    /// Use [`Self::recv_into`] to keep the buffer cycling instead.
    pub fn recv(&self, src: usize) -> Vec<f32> {
        self.recv_tagged(src, DEFAULT_TAG)
    }

    /// Blocking receive of the earliest `(src, tag)` message.
    pub fn recv_tagged(&self, src: usize, tag: u64) -> Vec<f32> {
        let (data, h) = self.irecv_tagged(src, tag);
        self.wait(h);
        data
    }

    /// Point-to-point receive into a reusable buffer.
    pub fn recv_into(&self, src: usize, out: &mut Vec<f32>) {
        let msg = self.take_msg(src, DEFAULT_TAG);
        let h = self.p2p_handle(&msg);
        self.wait(h);
        out.clear();
        out.extend_from_slice(&msg.data);
        self.release(msg.data);
    }

    /// Nonblocking receive: takes the earliest `(src, DEFAULT_TAG)` payload
    /// off the mailbox (blocking the *thread* until one is posted — the
    /// functional engine has no background progress) without advancing the
    /// virtual clock. The returned handle completes at the message's
    /// arrival time; [`Self::wait`] charges the exposed remainder. An
    /// `irecv` + immediate `wait` is exactly [`Self::recv`].
    pub fn irecv(&self, src: usize) -> (Vec<f32>, CommHandle) {
        self.irecv_tagged(src, DEFAULT_TAG)
    }

    /// Tagged [`Self::irecv`].
    pub fn irecv_tagged(&self, src: usize, tag: u64) -> (Vec<f32>, CommHandle) {
        let msg = self.take_msg(src, tag);
        let h = self.p2p_handle(&msg);
        (msg.data, h)
    }

    /// Handle completing at the message's arrival time
    /// (`sent_at + p2p cost`).
    fn p2p_handle(&self, msg: &Msg) -> CommHandle {
        match &self.fabric.clock {
            Some(clock) => {
                let cost = clock.cost.p2p(msg.src, self.rank, msg.billed_bytes);
                CommHandle {
                    end_us: msg.sent_at + cost,
                    dur_us: cost,
                    label: Cow::Owned(format!("recv<-{}", msg.src)),
                    cat: "p2p",
                }
            }
            None => CommHandle::completed(),
        }
    }

    /// Settle a nonblocking communication: advance the main lane to the
    /// comm span's end, recording the **exposed** portion (`end − now`) as
    /// a main-lane span. Returns the exposed time in µs — 0 when the
    /// communication was fully hidden under compute (or the fabric is
    /// unclocked).
    pub fn wait(&self, h: CommHandle) -> f64 {
        let Some(clock) = &self.fabric.clock else {
            return 0.0;
        };
        let now = clock.now(self.rank);
        if h.end_us > now {
            let exposed = h.end_us - now;
            clock.set(self.rank, h.end_us);
            if !h.label.is_empty() {
                clock.record(self.rank, h.label, h.cat, clock::Lane::Main, now, exposed);
            }
            exposed
        } else {
            0.0
        }
    }

    /// [`Self::wait`] splitting the span into `(hidden_us, exposed_us)`:
    /// hidden = the priced duration the main lane did *not* have to wait
    /// for, exposed = the wait actually charged (which can exceed the
    /// duration when the span queued behind earlier lane traffic).
    pub fn wait_split(&self, h: CommHandle) -> (f64, f64) {
        let dur = h.dur_us();
        let exposed = self.wait(h);
        ((dur - exposed.min(dur)).max(0.0), exposed)
    }

    // ---- nonblocking collectives (i-variants) --------------------------
    //
    // Payload semantics are bit-identical to the blocking calls (the same
    // algorithm code runs, eagerly); only the clock charge is deferred into
    // the returned handle. An i-variant + immediate `wait` equals the
    // blocking call in both payload bits and clock price — property-tested
    // in `tests/prop_invariants.rs` for every `CollectiveAlgo`.

    /// Nonblocking [`Self::all_reduce_sum`].
    pub fn all_reduce_sum_i(&self, group: &[usize], local: &[f32]) -> (Vec<f32>, CommHandle) {
        self.nonblocking.set(true);
        let out = self.all_reduce_sum(group, local);
        (out, self.take_pending())
    }

    /// Nonblocking in-place [`Self::all_reduce_sum_into`].
    pub fn all_reduce_sum_into_i(&self, group: &[usize], buf: &mut [f32]) -> CommHandle {
        self.nonblocking.set(true);
        self.all_reduce_sum_into(group, buf);
        self.take_pending()
    }

    /// Nonblocking [`Self::all_gather_v`].
    pub fn all_gather_v_i(&self, group: &[usize], local: &[f32]) -> (Vec<f32>, CommHandle) {
        self.nonblocking.set(true);
        let out = self.all_gather_v(group, local);
        (out, self.take_pending())
    }

    /// Nonblocking [`Self::reduce_scatter_v`].
    pub fn reduce_scatter_v_i(
        &self,
        group: &[usize],
        local: &[f32],
        counts: &[usize],
    ) -> (Vec<f32>, CommHandle) {
        self.nonblocking.set(true);
        let out = self.reduce_scatter_v(group, local, counts);
        (out, self.take_pending())
    }

    /// Nonblocking [`Self::all_to_all_v`].
    pub fn all_to_all_v_i(
        &self,
        group: &[usize],
        sends: Vec<Vec<f32>>,
    ) -> (Vec<Vec<f32>>, CommHandle) {
        self.nonblocking.set(true);
        let out = self.all_to_all_v(group, sends);
        (out, self.take_pending())
    }

    /// Nonblocking `_into` [`Self::all_to_all_v_into`] — the dispatcher hot
    /// path's overlapped a2a.
    pub fn all_to_all_v_into_i(
        &self,
        group: &[usize],
        sends: &[Vec<f32>],
        out: &mut Vec<Vec<f32>>,
    ) -> CommHandle {
        self.nonblocking.set(true);
        self.all_to_all_v_into(group, sends, out);
        self.take_pending()
    }

    /// Nonblocking [`Self::broadcast`].
    pub fn broadcast_i(
        &self,
        group: &[usize],
        root: usize,
        data: &[f32],
    ) -> (Vec<f32>, CommHandle) {
        self.nonblocking.set(true);
        let out = self.broadcast(group, root, data);
        (out, self.take_pending())
    }

    // ---- virtual clock -------------------------------------------------

    /// True when this communicator's fabric advances a virtual clock.
    pub fn clocked(&self) -> bool {
        self.fabric.clock.is_some()
    }

    /// This rank's simulated time in microseconds (0 on plain fabrics).
    pub fn now_us(&self) -> f64 {
        match &self.fabric.clock {
            Some(c) => c.now(self.rank),
            None => 0.0,
        }
    }

    /// Charge `us` microseconds of local compute under `label`. No-op on
    /// unclocked fabrics. The label is `&'static` so the per-span record
    /// is allocation-free (every call site labels with a literal).
    pub fn advance(&self, label: &'static str, us: f64) {
        if let Some(clock) = &self.fabric.clock {
            if us > 0.0 {
                let start = clock.advance(self.rank, us);
                clock.record(self.rank, label, "compute", clock::Lane::Main, start, us);
            }
        }
    }

    /// Set the phase label under which subsequent auto-charged collectives
    /// record their trace spans (e.g. `moe/a2a_dispatch`). Cleared with
    /// [`Self::clear_phase`]; when empty, spans use the primitive name.
    pub fn set_phase(&self, label: &str) {
        let mut p = self.phase.borrow_mut();
        p.clear();
        p.push_str(label);
    }

    /// Clear the phase label.
    pub fn clear_phase(&self) {
        self.phase.borrow_mut().clear();
    }

    /// Multiply real payload bytes by `scale` when billing auto-charged
    /// collectives (scaled-down functional runs billing model-scale
    /// volumes). Does not affect [`Self::charge_collective`] or p2p.
    pub fn set_bill_scale(&self, scale: f64) {
        self.bill_scale.set(scale.max(0.0));
    }

    /// Set the billed wire width per transported element for subsequent
    /// collective calls (see [`Payload`]). Returns the previous width so
    /// callers can scope the change (`let prev = set_payload(..); …;
    /// set_payload(prev)`). The functional payload stays f32 — only the
    /// traffic meters and the clock price change.
    pub fn set_payload(&self, p: Payload) -> Payload {
        self.payload.replace(p)
    }

    /// The billed wire width currently in effect.
    pub fn payload(&self) -> Payload {
        self.payload.get()
    }

    /// Executed collective with **virtual volume**: synchronizes the group
    /// on `max(issue times)` (a real cross-thread rendezvous — ordering and
    /// deadlock semantics of a collective) and advances every member's
    /// clock by the [`CommCost`] price of `prim` at `my_bytes` per rank.
    /// Must be entered by every member of `group`. No payload moves. No-op
    /// on unclocked fabrics.
    pub fn charge_collective(
        &self,
        label: &'static str,
        prim: CommPrimitive,
        group: &[usize],
        my_bytes: f64,
    ) {
        if self.fabric.clock.is_none() || group.len() <= 1 {
            return;
        }
        self.finish_collective(Some(label), prim, group, my_bytes);
    }

    /// Nonblocking [`Self::charge_collective`]: bills the comm lane and
    /// returns the handle instead of advancing the main lane. Must be
    /// entered by every member of `group` (the issue rendezvous is a
    /// collective).
    pub fn charge_collective_i(
        &self,
        label: &'static str,
        prim: CommPrimitive,
        group: &[usize],
        my_bytes: f64,
    ) -> CommHandle {
        if self.fabric.clock.is_none() || group.len() <= 1 {
            return CommHandle::completed();
        }
        self.nonblocking.set(true);
        self.finish_collective_on(Lane::Comm, Some(label), prim, group, my_bytes);
        self.take_pending()
    }

    /// Nonblocking virtual-volume collective on the **background
    /// grad-sync lane** ([`Lane::Bg`]) — the stand-in for the dedicated
    /// NCCL stream Megatron's distributed optimizer reduces gradients on.
    /// Background charges queue among themselves but run concurrently with
    /// the layer-collective lane and with compute; this is what the
    /// executed step estimator issues its bucketed DP/EDP grad-reduce on.
    pub fn charge_collective_bg(
        &self,
        label: &'static str,
        prim: CommPrimitive,
        group: &[usize],
        my_bytes: f64,
    ) -> CommHandle {
        if self.fabric.clock.is_none() || group.len() <= 1 {
            return CommHandle::completed();
        }
        self.nonblocking.set(true);
        self.finish_collective_on(Lane::Bg, Some(label), prim, group, my_bytes);
        self.take_pending()
    }

    /// Nonblocking comm-lane charge of an explicit duration: synchronizes
    /// `group` on `max(issue times, comm frontiers)` and occupies every
    /// member's comm lane for `max(us over the group)` microseconds. This
    /// is the raw-duration escape hatch for executed skeletons whose comm
    /// phases are priced upstream (the layer coster's a2a time) rather than
    /// re-priced from bytes. Returns a completed handle when `us <= 0` or
    /// the fabric is unclocked.
    pub fn charge_comm_i(&self, label: &'static str, group: &[usize], us: f64) -> CommHandle {
        let Some(clock) = &self.fabric.clock else {
            return CommHandle::completed();
        };
        if us <= 0.0 {
            return CommHandle::completed();
        }
        let (t_start, _, dur) = self.clock_sync(Lane::Comm, group, us);
        clock.bill_lane(self.rank, Lane::Comm, label, t_start, dur);
        CommHandle { end_us: t_start + dur, dur_us: dur, label: Cow::Borrowed(label), cat: "wait" }
    }

    /// Clock accounting for a collective that just moved real payloads:
    /// called at the end of every public collective in `algos.rs` with this
    /// rank's payload element count.
    pub(crate) fn clock_collective(&self, prim: CommPrimitive, group: &[usize], my_elems: f64) {
        if self.fabric.clock.is_none() || group.len() <= 1 {
            return;
        }
        let my_bytes = my_elems * self.payload.get().bytes_per_el() * self.bill_scale.get();
        self.finish_collective(None, prim, group, my_bytes);
    }

    /// Clear the nonblocking flag and take the parked handle (completed
    /// when the collective never reached the clock tail — unclocked fabric
    /// or singleton group).
    fn take_pending(&self) -> CommHandle {
        self.nonblocking.set(false);
        self.pending.borrow_mut().take().unwrap_or_else(CommHandle::completed)
    }

    /// Shared tail: issue-time sync + price + comm-lane billing. Blocking
    /// calls advance the main lane to the span end; nonblocking calls park
    /// a [`CommHandle`] in `pending` instead.
    fn finish_collective(
        &self,
        label: Option<&'static str>,
        prim: CommPrimitive,
        group: &[usize],
        my_bytes: f64,
    ) {
        self.finish_collective_on(Lane::Comm, label, prim, group, my_bytes)
    }

    /// [`Self::finish_collective`] on an explicit lane.
    fn finish_collective_on(
        &self,
        lane: Lane,
        label: Option<&'static str>,
        prim: CommPrimitive,
        group: &[usize],
        my_bytes: f64,
    ) {
        let clock = self.fabric.clock.as_ref().expect("clocked fabric");
        let (t_start, sum, max) = self.clock_sync(lane, group, my_bytes);
        // Uniform primitives price the mean contribution; AllToAll(-V) and
        // Broadcast pace on the busiest/root payload — matching the
        // analytic model's `all_to_all_v(mean, imbalance)` convention.
        let bytes = match prim {
            CommPrimitive::AllToAll | CommPrimitive::Broadcast => max,
            _ => sum / group.len() as f64,
        };
        let algo = match prim {
            CommPrimitive::AllReduce => self.algos.all_reduce,
            CommPrimitive::AllGather => self.algos.all_gather,
            CommPrimitive::ReduceScatter => self.algos.reduce_scatter,
            CommPrimitive::AllToAll => self.algos.all_to_all,
            CommPrimitive::Broadcast => self.algos.broadcast,
        };
        let name: Cow<'static, str> = match label {
            Some(l) => Cow::Borrowed(l),
            None => {
                let phase = self.phase.borrow();
                if phase.is_empty() {
                    Cow::Borrowed(prim.name())
                } else {
                    Cow::Owned(phase.clone())
                }
            }
        };
        let end = match algo {
            // Hierarchical algorithms bill one back-to-back span per fabric
            // tier they cross, so the trace shows which wire each slice
            // occupied. The phase sum is exactly `price()` for these algos
            // (pinned in `collectives/cost.rs`), so totals are unchanged.
            CollectiveAlgo::Hierarchical | CollectiveAlgo::HierarchicalA2A => {
                let mut t = t_start;
                for (suffix, dur) in clock.cost.hierarchical_phases(prim, group, bytes) {
                    let span = Cow::Owned(format!("{name}/{suffix}"));
                    clock.bill_lane(self.rank, lane, span, t, dur);
                    t += dur;
                }
                t
            }
            _ => {
                let cost = clock.cost.price(prim, algo, group, bytes);
                clock.bill_lane(self.rank, lane, name.clone(), t_start, cost);
                t_start + cost
            }
        };
        if self.nonblocking.get() {
            *self.pending.borrow_mut() =
                Some(CommHandle { end_us: end, dur_us: end - t_start, label: name, cat: "wait" });
        } else if end > clock.now(self.rank) {
            clock.set(self.rank, end);
        }
    }

    /// Group rendezvous for the clock: leader folds `(issue time, value)`
    /// pairs in group order and replies `(max time, sum value, max value)`.
    /// The issue time is `max(main lane, lane frontier)` — a new collective
    /// queues behind communication still occupying its lane. Control
    /// traffic only — payloads are untouched.
    fn clock_sync(&self, lane: Lane, group: &[usize], my_val: f64) -> (f64, f64, f64) {
        let clock = self.fabric.clock.as_ref().expect("clocked fabric");
        let t = clock.now(self.rank).max(clock.lane_free_at(self.rank, lane));
        if group.len() <= 1 {
            return (t, my_val, my_val);
        }
        let me = self.my_index(group);
        let leader = group[0];
        if me == 0 {
            let mut t_max = t;
            let mut sum = my_val;
            let mut max = my_val;
            for &src in &group[1..] {
                let m = self.recv_take(src);
                let pt = clock::join_f64(m[0], m[1]);
                let pv = clock::join_f64(m[2], m[3]);
                self.release(m);
                if pt > t_max {
                    t_max = pt;
                }
                sum += pv;
                if pv > max {
                    max = pv;
                }
            }
            let th = clock::split_f64(t_max);
            let sh = clock::split_f64(sum);
            let mh = clock::split_f64(max);
            let reply = [th[0], th[1], sh[0], sh[1], mh[0], mh[1]];
            for &dst in &group[1..] {
                self.send_slice(dst, &reply);
            }
            (t_max, sum, max)
        } else {
            let th = clock::split_f64(t);
            let vh = clock::split_f64(my_val);
            self.send_slice(leader, &[th[0], th[1], vh[0], vh[1]]);
            let m = self.recv_take(leader);
            let out = (
                clock::join_f64(m[0], m[1]),
                clock::join_f64(m[2], m[3]),
                clock::join_f64(m[4], m[5]),
            );
            self.release(m);
            out
        }
    }
}

/// Run `f(rank, comm)` on `world` threads, one per rank, with the default
/// (fast) algorithm suite; returns the outputs in rank order. Panics in any
/// rank propagate.
pub fn run_ranks<T, F>(world: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, Communicator) -> T + Sync,
{
    run_ranks_with(world, AlgoSelection::default(), f)
}

/// [`run_ranks`] with an explicit algorithm selection.
pub fn run_ranks_with<T, F>(world: usize, algos: AlgoSelection, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, Communicator) -> T + Sync,
{
    let fabric = Fabric::new_with(world, algos);
    run_ranks_on(&fabric, f)
}

/// Run one collective program over an existing fabric (reusing its buffer
/// pool across calls — this is what keeps repeated dispatch steps
/// allocation-free). The fabric must be idle (no messages in flight).
pub fn run_ranks_on<T, F>(fabric: &Arc<Fabric>, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, Communicator) -> T + Sync,
{
    let world = fabric.world();
    let mut out: Vec<Option<T>> = (0..world).map(|_| None).collect();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (rank, slot) in out.iter_mut().enumerate() {
            let comm = fabric.communicator(rank);
            let f = &f;
            handles.push(s.spawn(move || {
                *slot = Some(f(rank, comm));
            }));
        }
        for h in handles {
            h.join().expect("rank thread panicked");
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_suites() -> [AlgoSelection; 3] {
        [AlgoSelection::naive(), AlgoSelection::fast(), AlgoSelection::hierarchical()]
    }

    #[test]
    fn all_gather_v_concatenates_in_order() {
        for algos in all_suites() {
            let outs = run_ranks_with(4, algos, |rank, comm| {
                let local = vec![rank as f32; rank + 1]; // variable lengths
                comm.all_gather_v(&[0, 1, 2, 3], &local)
            });
            let expect = vec![0.0, 1.0, 1.0, 2.0, 2.0, 2.0, 3.0, 3.0, 3.0, 3.0];
            for o in outs {
                assert_eq!(o, expect);
            }
        }
    }

    #[test]
    fn all_reduce_sums() {
        for algos in all_suites() {
            let outs = run_ranks_with(4, algos, |rank, comm| {
                comm.all_reduce_sum(&[0, 1, 2, 3], &[rank as f32, 1.0])
            });
            for o in outs {
                assert_eq!(o, vec![6.0, 4.0]);
            }
        }
    }

    #[test]
    fn all_reduce_large_buffer_chunking() {
        // Exercises the pipelined chain with chunk boundaries that don't
        // divide evenly.
        let n = 1037usize;
        for algos in all_suites() {
            let outs = run_ranks_with(5, algos, |rank, comm| {
                let local: Vec<f32> = (0..n).map(|i| (rank * n + i) as f32).collect();
                comm.all_reduce_sum(&[0, 1, 2, 3, 4], &local)
            });
            for o in &outs {
                for (i, v) in o.iter().enumerate() {
                    let expect: f32 = (0..5).map(|r| (r * n + i) as f32).sum();
                    assert_eq!(*v, expect, "idx {i}");
                }
            }
        }
    }

    #[test]
    fn subgroup_collectives() {
        // Two disjoint groups of 2 run independently.
        for algos in all_suites() {
            let outs = run_ranks_with(4, algos, |rank, comm| {
                let group: Vec<usize> = if rank < 2 { vec![0, 1] } else { vec![2, 3] };
                comm.all_reduce_sum(&group, &[1.0])
            });
            assert_eq!(outs, vec![vec![2.0]; 4]);
        }
    }

    #[test]
    fn reduce_scatter_shards() {
        for algos in all_suites() {
            let outs = run_ranks_with(2, algos, |_, comm| {
                comm.reduce_scatter_sum(&[0, 1], &[1.0, 2.0, 3.0, 4.0])
            });
            assert_eq!(outs[0], vec![2.0, 4.0]);
            assert_eq!(outs[1], vec![6.0, 8.0]);
        }
    }

    #[test]
    fn reduce_scatter_non_power_of_two_falls_back() {
        // 3-rank group: recursive halving must fall back to pairwise.
        let outs = run_ranks_with(3, AlgoSelection::fast(), |rank, comm| {
            let local: Vec<f32> = (0..6).map(|i| (rank * 6 + i) as f32).collect();
            comm.reduce_scatter_sum(&[0, 1, 2], &local)
        });
        for (me, o) in outs.iter().enumerate() {
            for (j, v) in o.iter().enumerate() {
                let i = me * 2 + j;
                let expect: f32 = (0..3).map(|r| (r * 6 + i) as f32).sum();
                assert_eq!(*v, expect);
            }
        }
    }

    #[test]
    fn reduce_scatter_v_variable_shards() {
        for algos in all_suites() {
            let counts = [1usize, 3, 2];
            let outs = run_ranks_with(3, algos, |rank, comm| {
                let local: Vec<f32> = (0..6).map(|i| (rank * 6 + i) as f32).collect();
                comm.reduce_scatter_v(&[0, 1, 2], &local, &counts)
            });
            let offsets = [0usize, 1, 4];
            for (me, o) in outs.iter().enumerate() {
                assert_eq!(o.len(), counts[me]);
                for (j, v) in o.iter().enumerate() {
                    let i = offsets[me] + j;
                    let expect: f32 = (0..3).map(|r| (r * 6 + i) as f32).sum();
                    assert_eq!(*v, expect);
                }
            }
        }
    }

    #[test]
    fn all_to_all_v_exchanges() {
        for algos in all_suites() {
            let outs = run_ranks_with(3, algos, |rank, comm| {
                // rank r sends [r*10 + i] to member i.
                let sends: Vec<Vec<f32>> =
                    (0..3).map(|i| vec![(rank * 10 + i) as f32]).collect();
                comm.all_to_all_v(&[0, 1, 2], sends)
            });
            // rank 0 receives [0] from self, [10] from 1, [20] from 2.
            assert_eq!(outs[0], vec![vec![0.0], vec![10.0], vec![20.0]]);
            assert_eq!(outs[1], vec![vec![1.0], vec![11.0], vec![21.0]]);
            assert_eq!(outs[2], vec![vec![2.0], vec![12.0], vec![22.0]]);
        }
    }

    #[test]
    fn all_to_all_v_variable_sizes() {
        for algos in all_suites() {
            let outs = run_ranks_with(2, algos, |rank, comm| {
                let sends = if rank == 0 {
                    vec![vec![], vec![1.0, 2.0, 3.0]]
                } else {
                    vec![vec![9.0], vec![]]
                };
                comm.all_to_all_v(&[0, 1], sends)
            });
            assert_eq!(outs[0], vec![Vec::<f32>::new(), vec![9.0]]);
            assert_eq!(outs[1], vec![vec![1.0, 2.0, 3.0], Vec::<f32>::new()]);
        }
    }

    #[test]
    fn broadcast_from_root() {
        for algos in all_suites() {
            let outs =
                run_ranks_with(3, algos, |_, comm| comm.broadcast(&[0, 1, 2], 1, &[7.0, 8.0]));
            assert_eq!(outs, vec![vec![7.0, 8.0]; 3]);
        }
    }

    /// Every posted message lands in the per-link traffic table classified
    /// by the fabric topology (eos(16): ranks 0–7 node 0, 8–15 node 1).
    #[test]
    fn link_traffic_classifies_by_node() {
        let fabric = Fabric::new(16);
        run_ranks_on(&fabric, |rank, comm| {
            if rank == 0 {
                comm.send(1, &[1.0; 8]);
                comm.send(8, &[1.0; 4]);
            } else if rank == 1 {
                comm.recv(0);
            } else if rank == 8 {
                comm.recv(0);
            }
        });
        let nv = fabric.link_traffic(LinkKind::NvLink);
        let ib = fabric.link_traffic(LinkKind::InfiniBand);
        assert_eq!(nv.messages, 1);
        assert_eq!(nv.bytes, 32.0);
        assert_eq!(ib.messages, 1);
        assert_eq!(ib.bytes, 16.0);
        assert_eq!(fabric.link_traffic(LinkKind::Loopback).messages, 0);
    }

    #[test]
    fn p2p_send_recv() {
        let outs = run_ranks(2, |rank, comm| {
            if rank == 0 {
                comm.send(1, &[3.5]);
                vec![]
            } else {
                comm.recv(0)
            }
        });
        assert_eq!(outs[1], vec![3.5]);
    }

    #[test]
    fn concurrent_disjoint_a2a() {
        // Simulates EP groups folded inside a larger world: {0,2} and {1,3}.
        for algos in all_suites() {
            let outs = run_ranks_with(4, algos, |rank, comm| {
                let group = if rank % 2 == 0 { vec![0, 2] } else { vec![1, 3] };
                let sends: Vec<Vec<f32>> =
                    (0..2).map(|i| vec![(rank * 2 + i) as f32]).collect();
                comm.all_to_all_v(&group, sends)
            });
            assert_eq!(outs[0], vec![vec![0.0], vec![4.0]]);
            assert_eq!(outs[2], vec![vec![1.0], vec![5.0]]);
        }
    }

    #[test]
    fn non_contiguous_group_ring() {
        // Group {0, 2, 5} inside a 6-rank world; other ranks idle.
        let outs = run_ranks(6, |rank, comm| {
            let group = [0usize, 2, 5];
            if group.contains(&rank) {
                comm.all_reduce_sum(&group, &[rank as f32, 1.0])
            } else {
                vec![]
            }
        });
        for r in [0usize, 2, 5] {
            assert_eq!(outs[r], vec![7.0, 3.0]);
        }
    }

    /// The determinism invariant, observable: the fast suite produces sums
    /// bit-for-bit identical to the naive leader's rank-order fold even
    /// when addition order changes the f32 result.
    #[test]
    fn rank_order_reduction_is_bit_exact() {
        // (1e8 + 1) + (-1e8) = 0.0 in f32 (the 1 is absorbed); any other
        // association yields 1.0.
        let vals = [1e8f32, 1.0, -1e8];
        let expect = ((vals[0] + vals[1]) + vals[2]).to_bits();
        for algos in all_suites() {
            let outs = run_ranks_with(3, algos, |rank, comm| {
                comm.all_reduce_sum(&[0, 1, 2], &[vals[rank]])
            });
            for o in outs {
                assert_eq!(o[0].to_bits(), expect, "algos {algos:?}");
            }
        }
    }

    /// A clocked collective exits every member at `max(entry) + cost`,
    /// with the cost priced by the same `CommCost` the analytic model uses.
    #[test]
    fn clocked_collective_exits_at_group_max_plus_cost() {
        use crate::cluster::ClusterSpec;
        let group = [0usize, 1, 2, 3];
        let elems = 1024usize;
        let cost = CommCost::new(ClusterSpec::eos(4));
        let expect_cost = cost.all_reduce(&group, elems as f64 * 4.0);
        let fabric = Fabric::new_clocked(4, AlgoSelection::fast(), cost);
        let outs = run_ranks_on(&fabric, |rank, comm| {
            // Skewed entry: rank r has done 10·r µs of local work.
            comm.advance("local", 10.0 * rank as f64);
            let out = comm.all_reduce_sum(&group, &vec![rank as f32; elems]);
            (out[0], comm.now_us())
        });
        let t_max_entry = 30.0;
        for (rank, &(sum, t)) in outs.iter().enumerate() {
            assert_eq!(sum, 6.0, "payload must be unperturbed");
            assert!(
                (t - (t_max_entry + expect_cost)).abs() < 1e-6,
                "rank {rank}: clock {t} vs {}",
                t_max_entry + expect_cost
            );
        }
        // The trace recorded one compute span per busy rank + one comm span
        // per rank.
        let trace = fabric.take_trace();
        assert_eq!(trace.iter().filter(|e| e.cat == "comm").count(), 4);
        assert_eq!(trace.iter().filter(|e| e.cat == "compute").count(), 3);
    }

    /// P2p transfers are clocked on the receiver: arrival = sent_at + cost,
    /// with `send_billed` overriding the billed volume.
    #[test]
    fn clocked_p2p_prices_billed_volume() {
        use crate::cluster::ClusterSpec;
        let cost = CommCost::new(ClusterSpec::eos(2));
        let expect = cost.p2p(0, 1, 1e6);
        let fabric = Fabric::new_clocked(2, AlgoSelection::fast(), cost);
        let outs = run_ranks_on(&fabric, |rank, comm| {
            if rank == 0 {
                comm.advance("work", 50.0);
                comm.send_billed(1, &[1.0, 2.0], 1e6);
                comm.now_us()
            } else {
                let x = comm.recv(0);
                assert_eq!(x, vec![1.0, 2.0]);
                comm.now_us()
            }
        });
        assert_eq!(outs[0], 50.0, "send is asynchronous");
        assert!(
            (outs[1] - (50.0 + expect)).abs() < 1e-6,
            "receiver {} vs {}",
            outs[1],
            50.0 + expect
        );
    }

    /// `charge_collective` synchronizes the group and advances by the
    /// priced cost without moving payload.
    #[test]
    fn charge_collective_virtual_volume() {
        use crate::cluster::ClusterSpec;
        use crate::collectives::CommPrimitive;
        let group = [0usize, 1, 2, 3];
        let cost = CommCost::new(ClusterSpec::eos(4));
        let expect = cost.all_to_all(&group, 2e6);
        let fabric = Fabric::new_clocked(4, AlgoSelection::fast(), cost);
        let outs = run_ranks_on(&fabric, |_rank, comm| {
            comm.charge_collective("a2a", CommPrimitive::AllToAll, &group, 2e6);
            comm.now_us()
        });
        for t in outs {
            assert!((t - expect).abs() < 1e-6, "{t} vs {expect}");
        }
    }

    /// A nonblocking collective issued before compute is genuinely hidden:
    /// the main lane pays only the exposed remainder at `wait`, and the
    /// payload is identical to the blocking call.
    #[test]
    fn nonblocking_collective_hides_under_compute() {
        use crate::cluster::ClusterSpec;
        let group = [0usize, 1, 2, 3];
        let elems = 4096usize;
        let cost = CommCost::new(ClusterSpec::eos(4));
        let comm_us = cost.all_reduce(&group, elems as f64 * 4.0);
        assert!(comm_us > 1.0);
        for compute_us in [comm_us * 2.0, comm_us * 0.25] {
            let fabric = Fabric::new_clocked(4, AlgoSelection::fast(), cost.clone());
            let outs = run_ranks_on(&fabric, |rank, comm| {
                let (out, h) = comm.all_reduce_sum_i(&group, &vec![rank as f32; elems]);
                comm.advance("work", compute_us);
                let exposed = comm.wait(h);
                (out[0], comm.now_us(), exposed)
            });
            let expect_t = compute_us.max(comm_us);
            let expect_exposed = (comm_us - compute_us).max(0.0);
            for (rank, &(sum, t, exposed)) in outs.iter().enumerate() {
                assert_eq!(sum, 6.0, "payload must be unperturbed");
                assert!((t - expect_t).abs() < 1e-9, "rank {rank}: {t} vs {expect_t}");
                assert!(
                    (exposed - expect_exposed).abs() < 1e-9,
                    "rank {rank}: exposed {exposed} vs {expect_exposed}"
                );
            }
        }
    }

    /// Back-to-back nonblocking collectives queue on the serial comm lane.
    #[test]
    fn comm_lane_serializes_inflight_collectives() {
        use crate::cluster::ClusterSpec;
        let group = [0usize, 1];
        let elems = 2048usize;
        let cost = CommCost::new(ClusterSpec::eos(2));
        let one = cost.all_reduce(&group, elems as f64 * 4.0);
        let fabric = Fabric::new_clocked(2, AlgoSelection::fast(), cost);
        let outs = run_ranks_on(&fabric, |rank, comm| {
            let (_, h1) = comm.all_reduce_sum_i(&group, &vec![rank as f32; elems]);
            let (_, h2) = comm.all_reduce_sum_i(&group, &vec![rank as f32; elems]);
            (h1.end_us(), h2.end_us(), comm.wait(h1), comm.wait(h2))
        });
        for &(e1, e2, _, _) in &outs {
            assert!((e1 - one).abs() < 1e-9, "{e1} vs {one}");
            assert!((e2 - 2.0 * one).abs() < 1e-9, "{e2} vs {}", 2.0 * one);
        }
    }

    /// Tagged p2p: payloads match on (src, tag) even when posted out of the
    /// receiver's consumption order.
    #[test]
    fn tagged_p2p_matches_out_of_order() {
        let outs = run_ranks(2, |rank, comm| {
            if rank == 0 {
                comm.send_tagged(1, 7, &[7.0]);
                comm.send_tagged(1, 3, &[3.0]);
                comm.send(1, &[0.5]);
                vec![]
            } else {
                // Consume in the reverse of the posted order.
                let a = comm.recv(0);
                let b = comm.recv_tagged(0, 3);
                let c = comm.recv_tagged(0, 7);
                vec![a[0], b[0], c[0]]
            }
        });
        assert_eq!(outs[1], vec![0.5, 3.0, 7.0]);
    }

    /// `charge_comm_i` occupies the comm lane for the explicit duration and
    /// synchronizes the group on issue.
    #[test]
    fn charge_comm_i_raw_duration() {
        use crate::cluster::ClusterSpec;
        let group = [0usize, 1];
        let fabric =
            Fabric::new_clocked(2, AlgoSelection::fast(), CommCost::new(ClusterSpec::eos(2)));
        let outs = run_ranks_on(&fabric, |rank, comm| {
            comm.advance("skew", 5.0 * rank as f64);
            let h = comm.charge_comm_i("x", &group, 40.0);
            comm.advance("work", 100.0);
            let exposed = comm.wait(h);
            (comm.now_us(), exposed)
        });
        // Issue at max(0, 5) = 5; span [5, 45]; both hidden under work.
        assert!((outs[0].0 - 100.0).abs() < 1e-9);
        assert!((outs[1].0 - 105.0).abs() < 1e-9);
        assert_eq!(outs[0].1, 0.0);
        assert_eq!(outs[1].1, 0.0);
        let trace = fabric.take_trace();
        let comm_spans: Vec<_> = trace.iter().filter(|e| e.lane == Lane::Comm).collect();
        assert_eq!(comm_spans.len(), 2);
        for e in comm_spans {
            assert!((e.ts_us - 5.0).abs() < 1e-9 && (e.dur_us - 40.0).abs() < 1e-9);
        }
    }

    /// Steady state performs zero payload allocations: pool misses plateau
    /// after warmup while hits keep climbing.
    #[test]
    fn steady_state_collectives_allocate_nothing() {
        let fabric = Fabric::new(4);
        let group = [0usize, 1, 2, 3];
        let step = |fabric: &Arc<Fabric>| {
            run_ranks_on(fabric, |rank, comm| {
                let mut buf: Vec<f32> = (0..257).map(|i| (rank + i) as f32).collect();
                comm.all_reduce_sum_into(&group, &mut buf);
                let sends: Vec<Vec<f32>> =
                    (0..4).map(|i| vec![(rank * 4 + i) as f32; 33]).collect();
                let mut recvs: Vec<Vec<f32>> = Vec::new();
                comm.all_to_all_v_into(&group, &sends, &mut recvs);
                let mut gathered = Vec::new();
                comm.all_gather_v_into(&group, &buf[..7 + rank], &mut gathered);
                gathered[0]
            });
        };
        // Warm up until the pool plateaus (three consecutive steps minting
        // nothing). The exact mint count depends on thread interleaving, so
        // a fixed warmup length would flake on loaded machines.
        let mut last_misses = fabric.pool_stats().1;
        let mut stable = 0usize;
        for _ in 0..200 {
            step(&fabric);
            let misses = fabric.pool_stats().1;
            if misses == last_misses {
                stable += 1;
                if stable >= 3 {
                    break;
                }
            } else {
                stable = 0;
                last_misses = misses;
            }
        }
        assert!(stable >= 3, "pool never reached steady state");
        let (_, misses_warm) = fabric.pool_stats();
        for _ in 0..8 {
            step(&fabric);
        }
        let (hits_after, misses_after) = fabric.pool_stats();
        assert_eq!(
            misses_warm, misses_after,
            "steady-state collective calls must not allocate payload buffers"
        );
        assert!(hits_after > 0);
    }
}
