//! Functional in-process communicator: N rank threads exchanging real `f32`
//! buffers through per-rank mailboxes — the NCCL stand-in for
//! numerical-correctness work. The token dispatcher (paper §3.3) and the
//! distributed trainer run on it, and the appendix loss-equivalence
//! experiment (Figures 7/8) compares folded multi-rank runs against
//! single-rank references bit-for-bit.
//!
//! # Collective algorithms
//!
//! Every collective is implemented by *algorithmically real* communication
//! patterns selected via [`CollectiveAlgo`] / [`AlgoSelection`], mirroring
//! the algorithm families the analytic cost model
//! ([`crate::collectives::CommModel`]) prices:
//!
//! * [`CollectiveAlgo::NaiveLeader`] — leader gathers, computes, scatters.
//!   Serializes all traffic through one rank; kept as the **oracle** the
//!   differential suite (`tests/collectives_equivalence.rs`) checks every
//!   other algorithm against, bit-for-bit.
//! * [`CollectiveAlgo::Ring`] — chunk-pipelined ring/chain. Used for
//!   all-reduce (pipelined chain reduce in ascending rank order + pipelined
//!   ring broadcast), all-gather (segments circulate the ring), and
//!   broadcast (pipelined chain from the root).
//! * [`CollectiveAlgo::RecursiveHalving`] — log₂(n)-step halving exchange
//!   for reduce-scatter on power-of-two groups (falls back to
//!   [`CollectiveAlgo::PairwiseExchange`] otherwise). Summation is
//!   *deferred*: contributions travel unreduced and the shard owner folds
//!   them in rank order, so determinism is preserved.
//! * [`CollectiveAlgo::PairwiseExchange`] — n−1 deterministic rounds of
//!   direct exchange; the all-to-all(-v) workhorse and the variable-shard
//!   reduce-scatter used by the dispatcher's ETP combine.
//!
//! # Determinism invariant (load-bearing)
//!
//! **Every algorithm reduces in ascending group-index order**: for each
//! element, the produced sum is exactly `((x₀ + x₁) + x₂) + …` over the
//! group members — the same fold the naive leader performs. Algorithms that
//! cannot preserve this order for free (classic rotating-chunk ring
//! all-reduce, eager recursive halving) are implemented as order-preserving
//! variants (chain-pipelined reduce, deferred-summation halving) instead.
//! This is what lets the loss-equivalence experiments and the differential
//! suite compare algorithms **bit-for-bit**, not just within a tolerance.
//!
//! # Buffer pool
//!
//! Message payloads are pooled per rank ([`Fabric::pool_stats`]): once a
//! workload reaches steady state, collective calls perform **zero payload
//! allocations** — buffers cycle between rank pools and mailboxes. The
//! `*_into` variants additionally reuse caller-owned output buffers, which
//! is what the dispatcher hot path uses (`dispatcher/workflow.rs`).

mod algos;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Condvar, Mutex};

/// Which algorithm a collective primitive runs. See module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollectiveAlgo {
    /// Leader gathers, computes, scatters — the correctness oracle.
    NaiveLeader,
    /// Chunk-pipelined ring/chain (all-reduce, all-gather, broadcast).
    Ring,
    /// log₂(n) halving exchange with deferred rank-order summation
    /// (reduce-scatter; power-of-two groups, else pairwise fallback).
    RecursiveHalving,
    /// n−1 deterministic direct-exchange rounds (all-to-all, reduce-scatter).
    PairwiseExchange,
}

impl CollectiveAlgo {
    /// Stable name used in bench labels and the analytic cost model.
    pub fn name(self) -> &'static str {
        match self {
            CollectiveAlgo::NaiveLeader => "naive-leader",
            CollectiveAlgo::Ring => "ring",
            CollectiveAlgo::RecursiveHalving => "recursive-halving",
            CollectiveAlgo::PairwiseExchange => "pairwise",
        }
    }
}

/// Per-primitive algorithm selection for a fabric/communicator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AlgoSelection {
    pub all_reduce: CollectiveAlgo,
    pub all_gather: CollectiveAlgo,
    pub reduce_scatter: CollectiveAlgo,
    pub all_to_all: CollectiveAlgo,
    pub broadcast: CollectiveAlgo,
}

impl AlgoSelection {
    /// The leader-based oracle for every primitive.
    pub fn naive() -> Self {
        Self {
            all_reduce: CollectiveAlgo::NaiveLeader,
            all_gather: CollectiveAlgo::NaiveLeader,
            reduce_scatter: CollectiveAlgo::NaiveLeader,
            all_to_all: CollectiveAlgo::NaiveLeader,
            broadcast: CollectiveAlgo::NaiveLeader,
        }
    }

    /// The production suite: ring all-reduce/all-gather/broadcast,
    /// recursive-halving reduce-scatter, pairwise all-to-all.
    pub fn fast() -> Self {
        Self {
            all_reduce: CollectiveAlgo::Ring,
            all_gather: CollectiveAlgo::Ring,
            reduce_scatter: CollectiveAlgo::RecursiveHalving,
            all_to_all: CollectiveAlgo::PairwiseExchange,
            broadcast: CollectiveAlgo::Ring,
        }
    }
}

impl Default for AlgoSelection {
    fn default() -> Self {
        Self::fast()
    }
}

/// A message between ranks: tagged payload (pool-backed).
#[derive(Debug)]
struct Msg {
    src: usize,
    data: Vec<f32>,
}

/// Per-rank inbox: one deque guarded by a mutex/condvar pair. Receiving by
/// source scans front-to-back, so per-source FIFO order is preserved even
/// when a peer races ahead into its next collective. Steady state performs
/// no allocation: the deque's capacity persists.
struct Mailbox {
    q: Mutex<VecDeque<Msg>>,
    cv: Condvar,
}

impl Mailbox {
    fn new() -> Self {
        Self { q: Mutex::new(VecDeque::new()), cv: Condvar::new() }
    }

    fn push(&self, msg: Msg) {
        self.q.lock().unwrap().push_back(msg);
        self.cv.notify_all();
    }

    /// Earliest message from `src` (blocking).
    fn take_from(&self, src: usize) -> Vec<f32> {
        let mut q = self.q.lock().unwrap();
        loop {
            if let Some(pos) = q.iter().position(|m| m.src == src) {
                return q.remove(pos).unwrap().data;
            }
            q = self.cv.wait(q).unwrap();
        }
    }
}

/// Per-rank free list of payload buffers. Buffers migrate between ranks
/// (sender takes from its own pool, receiver releases into its own), but
/// collectives move symmetric volume per call, so populations stabilize.
struct Pool {
    free: Mutex<Vec<Vec<f32>>>,
}

/// Cap on buffers retained per rank pool (excess is dropped on release).
const POOL_MAX: usize = 128;

impl Pool {
    fn new() -> Self {
        Self { free: Mutex::new(Vec::new()) }
    }
}

/// Shared mailbox fabric connecting `world` ranks.
pub struct Fabric {
    world: usize,
    mailboxes: Vec<Mailbox>,
    pools: Vec<Pool>,
    barrier: Arc<Barrier>,
    algos: AlgoSelection,
    pool_hits: AtomicUsize,
    pool_misses: AtomicUsize,
}

impl Fabric {
    /// Fabric with the default (fast) algorithm suite.
    pub fn new(world: usize) -> Arc<Self> {
        Self::new_with(world, AlgoSelection::default())
    }

    /// Fabric with an explicit algorithm selection.
    pub fn new_with(world: usize, algos: AlgoSelection) -> Arc<Self> {
        let mailboxes = (0..world).map(|_| Mailbox::new()).collect();
        let pools = (0..world).map(|_| Pool::new()).collect();
        Arc::new(Self {
            world,
            mailboxes,
            pools,
            barrier: Arc::new(Barrier::new(world)),
            algos,
            pool_hits: AtomicUsize::new(0),
            pool_misses: AtomicUsize::new(0),
        })
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// The fabric-wide algorithm selection.
    pub fn algos(&self) -> AlgoSelection {
        self.algos
    }

    /// `(hits, misses)` of the payload buffer pool. A workload is in steady
    /// state when `misses` stops growing — from then on collective calls
    /// allocate no payload buffers.
    pub fn pool_stats(&self) -> (usize, usize) {
        (
            self.pool_hits.load(Ordering::Relaxed),
            self.pool_misses.load(Ordering::Relaxed),
        )
    }

    /// Handle for one rank.
    pub fn communicator(self: &Arc<Self>, rank: usize) -> Communicator {
        assert!(rank < self.world);
        Communicator { fabric: Arc::clone(self), rank, algos: self.algos }
    }

    /// All rank communicators at once (for spawning workers).
    pub fn communicators(self: &Arc<Self>) -> Vec<Communicator> {
        (0..self.world).map(|r| self.communicator(r)).collect()
    }

    /// Take a pooled buffer with at least `cap` capacity. The caller's own
    /// pool is tried first; on a miss, peer pools are scanned (buffers
    /// migrate rank→rank inside messages, so global conservation — not
    /// per-rank balance — is what guarantees steady-state reuse). Only when
    /// no pool anywhere holds a fitting buffer does a real allocation
    /// happen, counted in [`Fabric::pool_stats`].
    fn take(&self, rank: usize, cap: usize) -> Vec<f32> {
        if cap == 0 {
            return Vec::new(); // zero-capacity vecs never allocate
        }
        for k in 0..self.world {
            let r = (rank + k) % self.world;
            let mut free = self.pools[r].free.lock().unwrap();
            // Best fit: the smallest buffer that is large enough, so small
            // requests don't waste big buffers (which would delay the
            // steady-state plateau).
            let best = (0..free.len())
                .filter(|&i| free[i].capacity() >= cap)
                .min_by_key(|&i| free[i].capacity());
            if let Some(pos) = best {
                let mut b = free.swap_remove(pos);
                drop(free);
                self.pool_hits.fetch_add(1, Ordering::Relaxed);
                b.clear();
                return b;
            }
        }
        // Reuse the largest retained allocation in the own pool (growing
        // it) before minting a new one; both count as a miss (a real
        // allocation happens).
        let mut free = self.pools[rank].free.lock().unwrap();
        let reuse = (0..free.len()).max_by_key(|&i| free[i].capacity());
        let out = match reuse {
            Some(i) => {
                let mut b = free.swap_remove(i);
                drop(free);
                b.clear();
                b.reserve(cap);
                b
            }
            None => {
                drop(free);
                Vec::with_capacity(cap)
            }
        };
        self.pool_misses.fetch_add(1, Ordering::Relaxed);
        out
    }

    /// Return a buffer to `rank`'s pool.
    fn give(&self, rank: usize, mut buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        buf.clear();
        let mut free = self.pools[rank].free.lock().unwrap();
        if free.len() < POOL_MAX {
            free.push(buf);
        }
    }
}

/// Per-rank endpoint. Collective calls must be entered by *every* member of
/// `group` (a sorted list of global ranks including `self.rank()`).
pub struct Communicator {
    fabric: Arc<Fabric>,
    rank: usize,
    algos: AlgoSelection,
}

impl Communicator {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.fabric.world
    }

    /// The algorithm selection this communicator dispatches on.
    pub fn algos(&self) -> AlgoSelection {
        self.algos
    }

    /// Same endpoint with a different algorithm selection (used by the
    /// differential tests to pit algorithms against the oracle on one
    /// fabric).
    pub fn with_algos(&self, algos: AlgoSelection) -> Communicator {
        Communicator { fabric: Arc::clone(&self.fabric), rank: self.rank, algos }
    }

    /// Global barrier over the whole fabric.
    pub fn barrier(&self) {
        self.fabric.barrier.wait();
    }

    // ---- internal transport -------------------------------------------

    /// Take a pooled scratch buffer (returned via [`Self::release`] or
    /// moved into a message).
    pub(crate) fn take_buf(&self, cap: usize) -> Vec<f32> {
        self.fabric.take(self.rank, cap)
    }

    /// Return a pooled buffer to this rank's pool.
    pub(crate) fn release(&self, buf: Vec<f32>) {
        self.fabric.give(self.rank, buf);
    }

    /// Move an owned (pooled) buffer to `dst` as a message.
    pub(crate) fn send_vec(&self, dst: usize, data: Vec<f32>) {
        self.fabric.mailboxes[dst].push(Msg { src: self.rank, data });
    }

    /// Copy `data` into a pooled buffer and send it to `dst`.
    pub(crate) fn send_slice(&self, dst: usize, data: &[f32]) {
        let mut buf = self.take_buf(data.len());
        buf.extend_from_slice(data);
        self.send_vec(dst, buf);
    }

    /// Receive the earliest message from `src`, taking ownership of the
    /// pooled payload (pair with [`Self::release`] or forward it).
    pub(crate) fn recv_take(&self, src: usize) -> Vec<f32> {
        self.fabric.mailboxes[self.rank].take_from(src)
    }

    /// Receive from `src` into a caller buffer (cleared first); the pooled
    /// payload is recycled.
    pub(crate) fn recv_into_vec(&self, src: usize, out: &mut Vec<f32>) {
        let buf = self.recv_take(src);
        out.clear();
        out.extend_from_slice(&buf);
        self.release(buf);
    }

    /// This rank's index within `group`.
    pub(crate) fn my_index(&self, group: &[usize]) -> usize {
        group
            .iter()
            .position(|&r| r == self.rank)
            .expect("rank must be a member of the group")
    }

    // ---- point-to-point ------------------------------------------------

    /// Point-to-point send.
    pub fn send(&self, dst: usize, data: &[f32]) {
        self.send_slice(dst, data);
    }

    /// Point-to-point receive. Hands the message buffer to the caller
    /// directly (no copy); the pool mints a replacement on a later send.
    /// Use [`Self::recv_into`] to keep the buffer cycling instead.
    pub fn recv(&self, src: usize) -> Vec<f32> {
        self.recv_take(src)
    }

    /// Point-to-point receive into a reusable buffer.
    pub fn recv_into(&self, src: usize, out: &mut Vec<f32>) {
        self.recv_into_vec(src, out);
    }
}

/// Run `f(rank, comm)` on `world` threads, one per rank, with the default
/// (fast) algorithm suite; returns the outputs in rank order. Panics in any
/// rank propagate.
pub fn run_ranks<T, F>(world: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, Communicator) -> T + Sync,
{
    run_ranks_with(world, AlgoSelection::default(), f)
}

/// [`run_ranks`] with an explicit algorithm selection.
pub fn run_ranks_with<T, F>(world: usize, algos: AlgoSelection, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, Communicator) -> T + Sync,
{
    let fabric = Fabric::new_with(world, algos);
    run_ranks_on(&fabric, f)
}

/// Run one collective program over an existing fabric (reusing its buffer
/// pool across calls — this is what keeps repeated dispatch steps
/// allocation-free). The fabric must be idle (no messages in flight).
pub fn run_ranks_on<T, F>(fabric: &Arc<Fabric>, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, Communicator) -> T + Sync,
{
    let world = fabric.world();
    let mut out: Vec<Option<T>> = (0..world).map(|_| None).collect();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (rank, slot) in out.iter_mut().enumerate() {
            let comm = fabric.communicator(rank);
            let f = &f;
            handles.push(s.spawn(move || {
                *slot = Some(f(rank, comm));
            }));
        }
        for h in handles {
            h.join().expect("rank thread panicked");
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn both_suites() -> [AlgoSelection; 2] {
        [AlgoSelection::naive(), AlgoSelection::fast()]
    }

    #[test]
    fn all_gather_v_concatenates_in_order() {
        for algos in both_suites() {
            let outs = run_ranks_with(4, algos, |rank, comm| {
                let local = vec![rank as f32; rank + 1]; // variable lengths
                comm.all_gather_v(&[0, 1, 2, 3], &local)
            });
            let expect = vec![0.0, 1.0, 1.0, 2.0, 2.0, 2.0, 3.0, 3.0, 3.0, 3.0];
            for o in outs {
                assert_eq!(o, expect);
            }
        }
    }

    #[test]
    fn all_reduce_sums() {
        for algos in both_suites() {
            let outs = run_ranks_with(4, algos, |rank, comm| {
                comm.all_reduce_sum(&[0, 1, 2, 3], &[rank as f32, 1.0])
            });
            for o in outs {
                assert_eq!(o, vec![6.0, 4.0]);
            }
        }
    }

    #[test]
    fn all_reduce_large_buffer_chunking() {
        // Exercises the pipelined chain with chunk boundaries that don't
        // divide evenly.
        let n = 1037usize;
        for algos in both_suites() {
            let outs = run_ranks_with(5, algos, |rank, comm| {
                let local: Vec<f32> = (0..n).map(|i| (rank * n + i) as f32).collect();
                comm.all_reduce_sum(&[0, 1, 2, 3, 4], &local)
            });
            for o in &outs {
                for (i, v) in o.iter().enumerate() {
                    let expect: f32 = (0..5).map(|r| (r * n + i) as f32).sum();
                    assert_eq!(*v, expect, "idx {i}");
                }
            }
        }
    }

    #[test]
    fn subgroup_collectives() {
        // Two disjoint groups of 2 run independently.
        for algos in both_suites() {
            let outs = run_ranks_with(4, algos, |rank, comm| {
                let group: Vec<usize> = if rank < 2 { vec![0, 1] } else { vec![2, 3] };
                comm.all_reduce_sum(&group, &[1.0])
            });
            assert_eq!(outs, vec![vec![2.0]; 4]);
        }
    }

    #[test]
    fn reduce_scatter_shards() {
        for algos in both_suites() {
            let outs = run_ranks_with(2, algos, |_, comm| {
                comm.reduce_scatter_sum(&[0, 1], &[1.0, 2.0, 3.0, 4.0])
            });
            assert_eq!(outs[0], vec![2.0, 4.0]);
            assert_eq!(outs[1], vec![6.0, 8.0]);
        }
    }

    #[test]
    fn reduce_scatter_non_power_of_two_falls_back() {
        // 3-rank group: recursive halving must fall back to pairwise.
        let outs = run_ranks_with(3, AlgoSelection::fast(), |rank, comm| {
            let local: Vec<f32> = (0..6).map(|i| (rank * 6 + i) as f32).collect();
            comm.reduce_scatter_sum(&[0, 1, 2], &local)
        });
        for (me, o) in outs.iter().enumerate() {
            for (j, v) in o.iter().enumerate() {
                let i = me * 2 + j;
                let expect: f32 = (0..3).map(|r| (r * 6 + i) as f32).sum();
                assert_eq!(*v, expect);
            }
        }
    }

    #[test]
    fn reduce_scatter_v_variable_shards() {
        for algos in both_suites() {
            let counts = [1usize, 3, 2];
            let outs = run_ranks_with(3, algos, |rank, comm| {
                let local: Vec<f32> = (0..6).map(|i| (rank * 6 + i) as f32).collect();
                comm.reduce_scatter_v(&[0, 1, 2], &local, &counts)
            });
            let offsets = [0usize, 1, 4];
            for (me, o) in outs.iter().enumerate() {
                assert_eq!(o.len(), counts[me]);
                for (j, v) in o.iter().enumerate() {
                    let i = offsets[me] + j;
                    let expect: f32 = (0..3).map(|r| (r * 6 + i) as f32).sum();
                    assert_eq!(*v, expect);
                }
            }
        }
    }

    #[test]
    fn all_to_all_v_exchanges() {
        for algos in both_suites() {
            let outs = run_ranks_with(3, algos, |rank, comm| {
                // rank r sends [r*10 + i] to member i.
                let sends: Vec<Vec<f32>> =
                    (0..3).map(|i| vec![(rank * 10 + i) as f32]).collect();
                comm.all_to_all_v(&[0, 1, 2], sends)
            });
            // rank 0 receives [0] from self, [10] from 1, [20] from 2.
            assert_eq!(outs[0], vec![vec![0.0], vec![10.0], vec![20.0]]);
            assert_eq!(outs[1], vec![vec![1.0], vec![11.0], vec![21.0]]);
            assert_eq!(outs[2], vec![vec![2.0], vec![12.0], vec![22.0]]);
        }
    }

    #[test]
    fn all_to_all_v_variable_sizes() {
        for algos in both_suites() {
            let outs = run_ranks_with(2, algos, |rank, comm| {
                let sends = if rank == 0 {
                    vec![vec![], vec![1.0, 2.0, 3.0]]
                } else {
                    vec![vec![9.0], vec![]]
                };
                comm.all_to_all_v(&[0, 1], sends)
            });
            assert_eq!(outs[0], vec![Vec::<f32>::new(), vec![9.0]]);
            assert_eq!(outs[1], vec![vec![1.0, 2.0, 3.0], Vec::<f32>::new()]);
        }
    }

    #[test]
    fn broadcast_from_root() {
        for algos in both_suites() {
            let outs =
                run_ranks_with(3, algos, |_, comm| comm.broadcast(&[0, 1, 2], 1, &[7.0, 8.0]));
            assert_eq!(outs, vec![vec![7.0, 8.0]; 3]);
        }
    }

    #[test]
    fn p2p_send_recv() {
        let outs = run_ranks(2, |rank, comm| {
            if rank == 0 {
                comm.send(1, &[3.5]);
                vec![]
            } else {
                comm.recv(0)
            }
        });
        assert_eq!(outs[1], vec![3.5]);
    }

    #[test]
    fn concurrent_disjoint_a2a() {
        // Simulates EP groups folded inside a larger world: {0,2} and {1,3}.
        for algos in both_suites() {
            let outs = run_ranks_with(4, algos, |rank, comm| {
                let group = if rank % 2 == 0 { vec![0, 2] } else { vec![1, 3] };
                let sends: Vec<Vec<f32>> =
                    (0..2).map(|i| vec![(rank * 2 + i) as f32]).collect();
                comm.all_to_all_v(&group, sends)
            });
            assert_eq!(outs[0], vec![vec![0.0], vec![4.0]]);
            assert_eq!(outs[2], vec![vec![1.0], vec![5.0]]);
        }
    }

    #[test]
    fn non_contiguous_group_ring() {
        // Group {0, 2, 5} inside a 6-rank world; other ranks idle.
        let outs = run_ranks(6, |rank, comm| {
            let group = [0usize, 2, 5];
            if group.contains(&rank) {
                comm.all_reduce_sum(&group, &[rank as f32, 1.0])
            } else {
                vec![]
            }
        });
        for r in [0usize, 2, 5] {
            assert_eq!(outs[r], vec![7.0, 3.0]);
        }
    }

    /// The determinism invariant, observable: the fast suite produces sums
    /// bit-for-bit identical to the naive leader's rank-order fold even
    /// when addition order changes the f32 result.
    #[test]
    fn rank_order_reduction_is_bit_exact() {
        // (1e8 + 1) + (-1e8) = 0.0 in f32 (the 1 is absorbed); any other
        // association yields 1.0.
        let vals = [1e8f32, 1.0, -1e8];
        let expect = ((vals[0] + vals[1]) + vals[2]).to_bits();
        for algos in both_suites() {
            let outs = run_ranks_with(3, algos, |rank, comm| {
                comm.all_reduce_sum(&[0, 1, 2], &[vals[rank]])
            });
            for o in outs {
                assert_eq!(o[0].to_bits(), expect, "algos {algos:?}");
            }
        }
    }

    /// Steady state performs zero payload allocations: pool misses plateau
    /// after warmup while hits keep climbing.
    #[test]
    fn steady_state_collectives_allocate_nothing() {
        let fabric = Fabric::new(4);
        let group = [0usize, 1, 2, 3];
        let step = |fabric: &Arc<Fabric>| {
            run_ranks_on(fabric, |rank, comm| {
                let mut buf: Vec<f32> = (0..257).map(|i| (rank + i) as f32).collect();
                comm.all_reduce_sum_into(&group, &mut buf);
                let sends: Vec<Vec<f32>> =
                    (0..4).map(|i| vec![(rank * 4 + i) as f32; 33]).collect();
                let mut recvs: Vec<Vec<f32>> = Vec::new();
                comm.all_to_all_v_into(&group, &sends, &mut recvs);
                let mut gathered = Vec::new();
                comm.all_gather_v_into(&group, &buf[..7 + rank], &mut gathered);
                gathered[0]
            });
        };
        // Warm up until the pool plateaus (three consecutive steps minting
        // nothing). The exact mint count depends on thread interleaving, so
        // a fixed warmup length would flake on loaded machines.
        let mut last_misses = fabric.pool_stats().1;
        let mut stable = 0usize;
        for _ in 0..200 {
            step(&fabric);
            let misses = fabric.pool_stats().1;
            if misses == last_misses {
                stable += 1;
                if stable >= 3 {
                    break;
                }
            } else {
                stable = 0;
                last_misses = misses;
            }
        }
        assert!(stable >= 3, "pool never reached steady state");
        let (_, misses_warm) = fabric.pool_stats();
        for _ in 0..8 {
            step(&fabric);
        }
        let (hits_after, misses_after) = fabric.pool_stats();
        assert_eq!(
            misses_warm, misses_after,
            "steady-state collective calls must not allocate payload buffers"
        );
        assert!(hits_after > 0);
    }
}
