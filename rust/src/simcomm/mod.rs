//! Functional in-process communicator: N rank threads exchanging real `f32`
//! buffers through channels.
//!
//! This is the NCCL stand-in for numerical-correctness work: the token
//! dispatcher (paper §3.3) and the distributed trainer run on it, and the
//! appendix loss-equivalence experiment (Figures 7/8) compares folded
//! multi-rank runs against single-rank references bit-for-bit (modulo f32
//! reduction order, which we keep deterministic by always reducing in rank
//! order).
//!
//! Collectives are implemented naively (leader gathers, computes, scatters)
//! — correctness and determinism matter here, not wall-clock; the *cost* of
//! collectives is modeled analytically in [`crate::collectives`].

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier, Mutex};

/// A message between ranks: tagged payload.
#[derive(Debug, Clone)]
struct Msg {
    src: usize,
    data: Vec<f32>,
}

/// Per-rank inbox: the channel receiver plus a stash that preserves
/// per-source FIFO order when messages are consumed out of arrival order
/// (e.g. AllToAll-V receives in group order while peers race ahead).
struct Inbox {
    rx: Receiver<Msg>,
    stash: std::collections::VecDeque<Msg>,
}

/// Shared mailbox fabric connecting `world` ranks.
pub struct Fabric {
    world: usize,
    senders: Vec<Sender<Msg>>,
    inboxes: Vec<Mutex<Inbox>>,
    barrier: Arc<Barrier>,
}

impl Fabric {
    pub fn new(world: usize) -> Arc<Self> {
        let mut senders = Vec::with_capacity(world);
        let mut inboxes = Vec::with_capacity(world);
        for _ in 0..world {
            let (tx, rx) = channel();
            senders.push(tx);
            inboxes.push(Mutex::new(Inbox { rx, stash: std::collections::VecDeque::new() }));
        }
        Arc::new(Self { world, senders, inboxes, barrier: Arc::new(Barrier::new(world)) })
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// Handle for one rank.
    pub fn communicator(self: &Arc<Self>, rank: usize) -> Communicator {
        assert!(rank < self.world);
        Communicator { fabric: Arc::clone(self), rank }
    }

    /// All rank communicators at once (for spawning workers).
    pub fn communicators(self: &Arc<Self>) -> Vec<Communicator> {
        (0..self.world).map(|r| self.communicator(r)).collect()
    }
}

/// Per-rank endpoint. Collective calls must be entered by *every* member of
/// `group` (a sorted list of ranks including `self.rank`).
pub struct Communicator {
    fabric: Arc<Fabric>,
    rank: usize,
}

impl Communicator {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.fabric.world
    }

    fn send_to(&self, dst: usize, data: Vec<f32>) {
        self.fabric.senders[dst]
            .send(Msg { src: self.rank, data })
            .expect("fabric send");
    }

    /// Receive the earliest message from a specific source. Messages from
    /// other sources are stashed in arrival order, so per-source FIFO is
    /// preserved even when a peer races ahead into its next collective.
    fn recv_from(&self, src: usize) -> Vec<f32> {
        let mut inbox = self.fabric.inboxes[self.rank].lock().unwrap();
        // Earliest stashed message from `src` wins.
        if let Some(pos) = inbox.stash.iter().position(|m| m.src == src) {
            return inbox.stash.remove(pos).unwrap().data;
        }
        loop {
            let m = inbox.rx.recv().expect("fabric recv");
            if m.src == src {
                return m.data;
            }
            inbox.stash.push_back(m);
        }
    }

    /// Global barrier over the whole fabric.
    pub fn barrier(&self) {
        self.fabric.barrier.wait();
    }

    fn my_index(&self, group: &[usize]) -> usize {
        group
            .iter()
            .position(|&r| r == self.rank)
            .expect("rank must be a member of the group")
    }

    /// Point-to-point send.
    pub fn send(&self, dst: usize, data: &[f32]) {
        self.send_to(dst, data.to_vec());
    }

    /// Point-to-point receive.
    pub fn recv(&self, src: usize) -> Vec<f32> {
        self.recv_from(src)
    }

    /// AllGather-V: concatenation of every member's buffer, in group order.
    pub fn all_gather_v(&self, group: &[usize], local: &[f32]) -> Vec<f32> {
        if group.len() <= 1 {
            return local.to_vec();
        }
        let me = self.my_index(group);
        // Everyone sends to the leader; leader broadcasts concatenation.
        let leader = group[0];
        if self.rank == leader {
            let mut parts: Vec<Vec<f32>> = vec![Vec::new(); group.len()];
            parts[0] = local.to_vec();
            for (i, &src) in group.iter().enumerate().skip(1) {
                parts[i] = self.recv_from(src);
            }
            let cat: Vec<f32> = parts.concat();
            for &dst in &group[1..] {
                self.send_to(dst, cat.clone());
            }
            cat
        } else {
            let _ = me;
            self.send_to(leader, local.to_vec());
            self.recv_from(leader)
        }
    }

    /// AllReduce (sum), reducing in group-rank order for determinism.
    pub fn all_reduce_sum(&self, group: &[usize], local: &[f32]) -> Vec<f32> {
        if group.len() <= 1 {
            return local.to_vec();
        }
        let leader = group[0];
        if self.rank == leader {
            let mut acc = local.to_vec();
            for &src in &group[1..] {
                let part = self.recv_from(src);
                assert_eq!(part.len(), acc.len(), "allreduce length mismatch");
                for (a, b) in acc.iter_mut().zip(&part) {
                    *a += b;
                }
            }
            for &dst in &group[1..] {
                self.send_to(dst, acc.clone());
            }
            acc
        } else {
            self.send_to(leader, local.to_vec());
            self.recv_from(leader)
        }
    }

    /// ReduceScatter (sum): every rank contributes `local` (length divisible
    /// by group size), receives its reduced shard.
    pub fn reduce_scatter_sum(&self, group: &[usize], local: &[f32]) -> Vec<f32> {
        let n = group.len();
        if n <= 1 {
            return local.to_vec();
        }
        assert_eq!(local.len() % n, 0, "reduce_scatter length must divide");
        let reduced = self.all_reduce_sum(group, local);
        let shard = reduced.len() / n;
        let me = self.my_index(group);
        reduced[me * shard..(me + 1) * shard].to_vec()
    }

    /// AllToAll-V: `sends[i]` goes to group member `i`; returns the buffers
    /// received from each member, in group order.
    pub fn all_to_all_v(&self, group: &[usize], sends: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
        assert_eq!(sends.len(), group.len(), "one send buffer per group member");
        let me = self.my_index(group);
        let mut out: Vec<Vec<f32>> = vec![Vec::new(); group.len()];
        // Self-exchange without the fabric.
        out[me] = sends[me].clone();
        // Deterministic pairwise exchange: for each round r, exchange with
        // partner (me ^ r) when valid — but groups may be non-power-of-two,
        // so use simple ordered push/pull: everyone sends everything first
        // (channels are buffered), then receives.
        for (i, &dst) in group.iter().enumerate() {
            if i != me {
                self.send_to(dst, sends[i].clone());
            }
        }
        for (i, &src) in group.iter().enumerate() {
            if i != me {
                out[i] = self.recv_from(src);
            }
        }
        out
    }

    /// Broadcast from `root` (a global rank in `group`).
    pub fn broadcast(&self, group: &[usize], root: usize, data: &[f32]) -> Vec<f32> {
        if group.len() <= 1 {
            return data.to_vec();
        }
        if self.rank == root {
            for &dst in group {
                if dst != root {
                    self.send_to(dst, data.to_vec());
                }
            }
            data.to_vec()
        } else {
            self.recv_from(root)
        }
    }
}

/// Run `f(rank, comm)` on `world` threads, one per rank; returns the outputs
/// in rank order. Panics in any rank propagate.
pub fn run_ranks<T, F>(world: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, Communicator) -> T + Sync,
{
    let fabric = Fabric::new(world);
    let mut out: Vec<Option<T>> = (0..world).map(|_| None).collect();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (rank, slot) in out.iter_mut().enumerate() {
            let comm = fabric.communicator(rank);
            let f = &f;
            handles.push(s.spawn(move || {
                *slot = Some(f(rank, comm));
            }));
        }
        for h in handles {
            h.join().expect("rank thread panicked");
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_gather_v_concatenates_in_order() {
        let outs = run_ranks(4, |rank, comm| {
            let local = vec![rank as f32; rank + 1]; // variable lengths
            comm.all_gather_v(&[0, 1, 2, 3], &local)
        });
        let expect = vec![0.0, 1.0, 1.0, 2.0, 2.0, 2.0, 3.0, 3.0, 3.0, 3.0];
        for o in outs {
            assert_eq!(o, expect);
        }
    }

    #[test]
    fn all_reduce_sums() {
        let outs = run_ranks(4, |rank, comm| {
            comm.all_reduce_sum(&[0, 1, 2, 3], &[rank as f32, 1.0])
        });
        for o in outs {
            assert_eq!(o, vec![6.0, 4.0]);
        }
    }

    #[test]
    fn subgroup_collectives() {
        // Two disjoint groups of 2 run independently.
        let outs = run_ranks(4, |rank, comm| {
            let group: Vec<usize> = if rank < 2 { vec![0, 1] } else { vec![2, 3] };
            comm.all_reduce_sum(&group, &[1.0])
        });
        assert_eq!(outs, vec![vec![2.0]; 4]);
    }

    #[test]
    fn reduce_scatter_shards() {
        let outs = run_ranks(2, |_, comm| {
            comm.reduce_scatter_sum(&[0, 1], &[1.0, 2.0, 3.0, 4.0])
        });
        assert_eq!(outs[0], vec![2.0, 4.0]);
        assert_eq!(outs[1], vec![6.0, 8.0]);
    }

    #[test]
    fn all_to_all_v_exchanges() {
        let outs = run_ranks(3, |rank, comm| {
            // rank r sends [r*10 + i] to member i.
            let sends: Vec<Vec<f32>> =
                (0..3).map(|i| vec![(rank * 10 + i) as f32]).collect();
            comm.all_to_all_v(&[0, 1, 2], sends)
        });
        // rank 0 receives [0] from self, [10] from 1, [20] from 2.
        assert_eq!(outs[0], vec![vec![0.0], vec![10.0], vec![20.0]]);
        assert_eq!(outs[1], vec![vec![1.0], vec![11.0], vec![21.0]]);
        assert_eq!(outs[2], vec![vec![2.0], vec![12.0], vec![22.0]]);
    }

    #[test]
    fn all_to_all_v_variable_sizes() {
        let outs = run_ranks(2, |rank, comm| {
            let sends = if rank == 0 {
                vec![vec![], vec![1.0, 2.0, 3.0]]
            } else {
                vec![vec![9.0], vec![]]
            };
            comm.all_to_all_v(&[0, 1], sends)
        });
        assert_eq!(outs[0], vec![Vec::<f32>::new(), vec![9.0]]);
        assert_eq!(outs[1], vec![vec![1.0, 2.0, 3.0], Vec::<f32>::new()]);
    }

    #[test]
    fn broadcast_from_root() {
        let outs = run_ranks(3, |_, comm| comm.broadcast(&[0, 1, 2], 1, &[7.0, 8.0]));
        assert_eq!(outs, vec![vec![7.0, 8.0]; 3]);
    }

    #[test]
    fn p2p_send_recv() {
        let outs = run_ranks(2, |rank, comm| {
            if rank == 0 {
                comm.send(1, &[3.5]);
                vec![]
            } else {
                comm.recv(0)
            }
        });
        assert_eq!(outs[1], vec![3.5]);
    }

    #[test]
    fn concurrent_disjoint_a2a() {
        // Simulates EP groups folded inside a larger world: {0,2} and {1,3}.
        let outs = run_ranks(4, |rank, comm| {
            let group = if rank % 2 == 0 { vec![0, 2] } else { vec![1, 3] };
            let sends: Vec<Vec<f32>> = (0..2).map(|i| vec![(rank * 2 + i) as f32]).collect();
            comm.all_to_all_v(&group, sends)
        });
        assert_eq!(outs[0], vec![vec![0.0], vec![4.0]]);
        assert_eq!(outs[2], vec![vec![1.0], vec![5.0]]);
    }
}
