//! Chunked symmetric 1-byte quantization codec for reduced-precision
//! dispatch payloads (ISSUE 8).
//!
//! The paper's Table 2 and the Megatron-Core FP8 path move activation-class
//! traffic at 1 byte per element; this codec is the functional stand-in.
//! Each `chunk`-element block gets one f32 scale `s = max|x| / 127` and
//! 1-byte codes `q = round(x / s) ∈ [-127, 127]`, so the worst-case
//! round-trip error of any element is **`s / 2 = max|x| / 254` per chunk**
//! — the pinned envelope. Two exact cases fall out of the symmetric scheme:
//! zeros stay exactly zero (padding rows survive bit-for-bit) and the
//! chunk's own ±max round-trips exactly (`±max / s = ±127`, an integer).
//!
//! The fabric transports dequantized f32 stand-ins (fake quantization), so
//! reduction order and determinism are untouched; [`super::Payload`] is
//! what makes the *billing* 1 byte per element. Scales are out-of-band
//! metadata, unbilled — mirroring how scale tensors ride the NCCL header
//! stream rather than the payload allocation.

/// Quantized representation of a buffer: 1-byte codes plus one f32 scale
/// per `chunk` elements (the last chunk may be short).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantChunks {
    /// Symmetric signed codes in `[-127, 127]`, one per input element.
    pub codes: Vec<i8>,
    /// Per-chunk dequantization scales (`codes[i] as f32 * scales[i / chunk]`).
    pub scales: Vec<f32>,
    /// Elements per scale.
    pub chunk: usize,
}

impl QuantChunks {
    /// Worst-case absolute round-trip error any element of this buffer can
    /// carry: `max(scales) / 2` (each chunk's bound is `scale / 2`).
    pub fn error_bound(&self) -> f32 {
        self.scales.iter().fold(0.0f32, |m, &s| m.max(s)) / 2.0
    }
}

/// Quantize `data` with one symmetric scale per `chunk` elements.
pub fn quantize_chunked(data: &[f32], chunk: usize) -> QuantChunks {
    let chunk = chunk.max(1);
    let mut codes = Vec::with_capacity(data.len());
    let mut scales = Vec::with_capacity(data.len().div_ceil(chunk));
    for block in data.chunks(chunk) {
        let max_abs = block.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let scale = max_abs / 127.0;
        scales.push(scale);
        if scale == 0.0 {
            codes.extend(std::iter::repeat(0i8).take(block.len()));
        } else {
            codes.extend(
                block
                    .iter()
                    .map(|&x| (x / scale).round().clamp(-127.0, 127.0) as i8),
            );
        }
    }
    QuantChunks { codes, scales, chunk }
}

/// Reconstruct f32 values from a [`QuantChunks`].
pub fn dequantize_chunked(q: &QuantChunks) -> Vec<f32> {
    q.codes
        .iter()
        .enumerate()
        .map(|(i, &c)| c as f32 * q.scales[i / q.chunk])
        .collect()
}

/// Dequantize∘quantize in place: `data` becomes exactly what a receiver of
/// the quantized payload would observe. Idempotent (a second pass is a
/// no-op: the reconstruction points are fixed points of the codec).
pub fn fake_quantize_chunked(data: &mut [f32], chunk: usize) {
    let chunk = chunk.max(1);
    for block in data.chunks_mut(chunk) {
        let max_abs = block.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let scale = max_abs / 127.0;
        if scale == 0.0 {
            continue; // all-zero chunk is already exact
        }
        for x in block.iter_mut() {
            *x = (*x / scale).round().clamp(-127.0, 127.0) * scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// The pinned envelope: every element round-trips within `scale / 2 =
    /// chunk_max_abs / 254`, across chunks whose magnitudes span six orders
    /// (per-chunk scaling is the whole point — one global scale would
    /// crush the small chunks to zero).
    #[test]
    fn round_trip_error_within_envelope_across_skewed_magnitudes() {
        let mut rng = Rng::seed_from_u64(88);
        let chunk = 64usize;
        let mut data = vec![0.0f32; chunk * 4];
        rng.fill_normal(&mut data, 1.0);
        for (i, block_scale) in [1e-3f32, 1.0, 40.0, 1e3].into_iter().enumerate() {
            for x in &mut data[i * chunk..(i + 1) * chunk] {
                *x *= block_scale;
            }
        }
        let q = quantize_chunked(&data, chunk);
        let back = dequantize_chunked(&q);
        for (b, block) in data.chunks(chunk).enumerate() {
            let max_abs = block.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let bound = max_abs / 254.0 + f32::EPSILON * max_abs;
            for (i, &x) in block.iter().enumerate() {
                let err = (back[b * chunk + i] - x).abs();
                assert!(
                    err <= bound,
                    "chunk {b} el {i}: err {err} > bound {bound} (x = {x})"
                );
            }
        }
        assert!(q.error_bound() > 0.0);
        // The codec is lossy for generic values — the twin must differ.
        assert!(back.iter().zip(&data).any(|(a, b)| a != b));
    }

    /// Zeros and the chunk's own ±max are exact; fake-quantize is
    /// idempotent (reconstruction points are codec fixed points).
    #[test]
    fn exact_cases_and_idempotence() {
        let mut data = vec![0.0f32, 0.5, -3.25, 3.25, 1.0, 0.0, -0.125, 2.0];
        let q = quantize_chunked(&data, 4);
        let back = dequantize_chunked(&q);
        assert_eq!(back[0], 0.0);
        assert_eq!(back[5], 0.0);
        assert_eq!(back[2], -3.25, "chunk -max is exact");
        assert_eq!(back[3], 3.25, "chunk +max is exact");
        assert_eq!(back[7], 2.0, "second chunk's max is exact too");
        assert_ne!(back[4], 1.0, "non-max elements are lossy (1.0 → 64·2/127)");
        fake_quantize_chunked(&mut data, 4);
        assert_eq!(data, back, "fake quantization = dequantize∘quantize");
        let mut twice = data.clone();
        fake_quantize_chunked(&mut twice, 4);
        assert_eq!(twice, data, "idempotent");
        // All-zero buffers survive untouched (padding rows).
        let mut zeros = vec![0.0f32; 16];
        fake_quantize_chunked(&mut zeros, 4);
        assert!(zeros.iter().all(|&z| z == 0.0));
    }
}
