//! Collective algorithm implementations for [`Communicator`].
//!
//! Every algorithm here upholds the module-level determinism invariant:
//! sums are folded in **ascending group-index order**, bit-for-bit equal to
//! the [`CollectiveAlgo::NaiveLeader`] oracle. See `simcomm` module docs for
//! the rationale and the algorithm catalogue.
//!
//! Payload framing note: variable-length primitives (all-gather-v,
//! broadcast) circulate lengths as `f32` control messages, exact for
//! buffers under 2²⁴ elements — far beyond anything the functional
//! simulator moves.

use crate::collectives::CommPrimitive;

use super::{CollectiveAlgo, Communicator};

impl Communicator {
    // =====================================================================
    // AllGather-V
    // =====================================================================

    /// AllGather-V: concatenation of every member's buffer, in group order.
    pub fn all_gather_v(&self, group: &[usize], local: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.all_gather_v_into(group, local, &mut out);
        out
    }

    /// [`Self::all_gather_v`] into a reusable output buffer.
    pub fn all_gather_v_into(&self, group: &[usize], local: &[f32], out: &mut Vec<f32>) {
        if group.len() <= 1 {
            out.clear();
            out.extend_from_slice(local);
            return;
        }
        match self.algos().all_gather {
            CollectiveAlgo::NaiveLeader => self.naive_all_gather_v(group, local, out),
            _ => self.ring_all_gather_v(group, local, out),
        }
        self.clock_collective(CommPrimitive::AllGather, group, local.len() as f64);
    }

    /// Oracle: everyone sends to the leader; leader broadcasts the
    /// concatenation.
    fn naive_all_gather_v(&self, group: &[usize], local: &[f32], out: &mut Vec<f32>) {
        let leader = group[0];
        if self.rank() == leader {
            out.clear();
            out.extend_from_slice(local);
            for &src in &group[1..] {
                let buf = self.recv_take(src);
                out.extend_from_slice(&buf);
                self.release(buf);
            }
            for &dst in &group[1..] {
                self.send_slice(dst, out);
            }
        } else {
            self.send_slice(leader, local);
            self.recv_into_vec(leader, out);
        }
    }

    /// Ring: a length pass then a data pass; each segment travels n−1 hops
    /// around the ring, every link carrying disjoint traffic concurrently.
    fn ring_all_gather_v(&self, group: &[usize], local: &[f32], out: &mut Vec<f32>) {
        let n = group.len();
        let me = self.my_index(group);
        let next = group[(me + 1) % n];
        let prev = group[(me + n - 1) % n];

        // Pass 1: circulate segment lengths.
        let mut lens = vec![0usize; n];
        lens[me] = local.len();
        self.send_slice(next, &[local.len() as f32]);
        for s in 1..n {
            let idx = (me + n - s) % n;
            let buf = self.recv_take(prev);
            lens[idx] = buf[0] as usize;
            if s < n - 1 {
                self.send_vec(next, buf);
            } else {
                self.release(buf);
            }
        }
        let mut offsets = vec![0usize; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + lens[i];
        }
        out.clear();
        out.resize(offsets[n], 0.0);
        out[offsets[me]..offsets[me] + local.len()].copy_from_slice(local);

        // Pass 2: circulate segment data, writing at the known offsets.
        self.send_slice(next, local);
        for s in 1..n {
            let idx = (me + n - s) % n;
            let buf = self.recv_take(prev);
            debug_assert_eq!(buf.len(), lens[idx], "ring all-gather framing");
            out[offsets[idx]..offsets[idx] + buf.len()].copy_from_slice(&buf);
            if s < n - 1 {
                self.send_vec(next, buf);
            } else {
                self.release(buf);
            }
        }
    }

    // =====================================================================
    // AllReduce (sum)
    // =====================================================================

    /// AllReduce (sum), reducing in group-index order for determinism.
    pub fn all_reduce_sum(&self, group: &[usize], local: &[f32]) -> Vec<f32> {
        let mut out = local.to_vec();
        self.all_reduce_sum_into(group, &mut out);
        out
    }

    /// In-place AllReduce (sum): `buf` holds this rank's contribution on
    /// entry and the rank-order sum on exit. Zero payload allocations in
    /// steady state (pool-backed chunks).
    pub fn all_reduce_sum_into(&self, group: &[usize], buf: &mut [f32]) {
        if group.len() <= 1 {
            return;
        }
        match self.algos().all_reduce {
            CollectiveAlgo::NaiveLeader => self.naive_all_reduce_into(group, buf),
            _ => self.chain_all_reduce_into(group, buf),
        }
        self.clock_collective(CommPrimitive::AllReduce, group, buf.len() as f64);
    }

    /// Oracle: leader folds contributions in group order, then scatters the
    /// full result.
    fn naive_all_reduce_into(&self, group: &[usize], buf: &mut [f32]) {
        let leader = group[0];
        if self.rank() == leader {
            for &src in &group[1..] {
                let part = self.recv_take(src);
                assert_eq!(part.len(), buf.len(), "allreduce length mismatch");
                for (a, b) in buf.iter_mut().zip(&part) {
                    *a += *b;
                }
                self.release(part);
            }
            for &dst in &group[1..] {
                self.send_slice(dst, buf);
            }
        } else {
            self.send_slice(leader, buf);
            let full = self.recv_take(leader);
            buf.copy_from_slice(&full);
            self.release(full);
        }
    }

    /// Ring: chunk-pipelined chain reduce `0 → 1 → … → n−1` (each chunk's
    /// partial sum grows strictly in ascending rank order — the classic
    /// rotating-chunk ring is rejected because it breaks that invariant),
    /// followed by a chunk-pipelined ring broadcast `n−1 → 0 → … → n−2`.
    /// Per-link volume is ~2× the buffer, like a bandwidth-optimal ring,
    /// and all links run concurrently — no leader bottleneck.
    fn chain_all_reduce_into(&self, group: &[usize], buf: &mut [f32]) {
        let n = group.len();
        let me = self.my_index(group);
        let len = buf.len();
        let chunks = n.min(len.max(1));
        let bounds = |c: usize| (c * len / chunks, (c + 1) * len / chunks);

        // Phase 1: pipelined chain reduce.
        if me == 0 {
            for c in 0..chunks {
                let (lo, hi) = bounds(c);
                self.send_slice(group[1], &buf[lo..hi]);
            }
        } else {
            let prev = group[me - 1];
            for c in 0..chunks {
                let (lo, hi) = bounds(c);
                let mut part = self.recv_take(prev);
                debug_assert_eq!(part.len(), hi - lo, "chain reduce framing");
                // part = Σ ranks 0..me; adding mine keeps the left fold.
                for (p, x) in part.iter_mut().zip(&buf[lo..hi]) {
                    *p += *x;
                }
                if me < n - 1 {
                    self.send_vec(group[me + 1], part);
                } else {
                    buf[lo..hi].copy_from_slice(&part);
                    self.release(part);
                }
            }
        }

        // Phase 2: pipelined ring broadcast of the finished chunks, rooted
        // at the chain's end (group index n−1).
        self.ring_chain_broadcast(group, n - 1, buf);
    }

    /// Chunk-pipelined ring broadcast where every member already knows the
    /// buffer length: the member at group index `root_idx` sends its `buf`
    /// around the ring; the member just before it terminates the chain.
    /// Shared by the all-reduce distribution phase and [`Self::broadcast`].
    fn ring_chain_broadcast(&self, group: &[usize], root_idx: usize, buf: &mut [f32]) {
        let n = group.len();
        let me = self.my_index(group);
        let chain_pos = (me + n - root_idx) % n;
        let next = group[(me + 1) % n];
        let prev = group[(me + n - 1) % n];
        let is_last = chain_pos == n - 1;
        let len = buf.len();
        let chunks = n.min(len.max(1));
        let bounds = |c: usize| (c * len / chunks, (c + 1) * len / chunks);
        if chain_pos == 0 {
            for c in 0..chunks {
                let (lo, hi) = bounds(c);
                self.send_slice(next, &buf[lo..hi]);
            }
        } else {
            for c in 0..chunks {
                let (lo, hi) = bounds(c);
                let part = self.recv_take(prev);
                debug_assert_eq!(part.len(), hi - lo, "ring broadcast framing");
                buf[lo..hi].copy_from_slice(&part);
                if !is_last {
                    self.send_vec(next, part);
                } else {
                    self.release(part);
                }
            }
        }
    }

    // =====================================================================
    // ReduceScatter (sum)
    // =====================================================================

    /// ReduceScatter (sum): every rank contributes `local` (length divisible
    /// by group size), receives its reduced shard.
    pub fn reduce_scatter_sum(&self, group: &[usize], local: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.reduce_scatter_sum_into(group, local, &mut out);
        out
    }

    /// [`Self::reduce_scatter_sum`] into a reusable output buffer.
    pub fn reduce_scatter_sum_into(&self, group: &[usize], local: &[f32], out: &mut Vec<f32>) {
        let n = group.len();
        if n <= 1 {
            out.clear();
            out.extend_from_slice(local);
            return;
        }
        assert_eq!(local.len() % n, 0, "reduce_scatter length must divide");
        let shard = local.len() / n;
        let counts = vec![shard; n];
        match self.algos().reduce_scatter {
            CollectiveAlgo::NaiveLeader => self.naive_reduce_scatter_v(group, local, &counts, out),
            CollectiveAlgo::RecursiveHalving if n.is_power_of_two() => {
                self.halving_reduce_scatter(group, local, out)
            }
            // Recursive halving needs a power-of-two group; everything else
            // (and the explicit Pairwise/Ring selections) uses the direct
            // pairwise exchange.
            _ => self.pairwise_reduce_scatter_v(group, local, &counts, out),
        }
        self.clock_collective(CommPrimitive::ReduceScatter, group, local.len() as f64);
    }

    /// ReduceScatter-V (sum): `counts[i]` elements of `local` belong to
    /// group member `i` (`Σ counts == local.len()`, identical on every
    /// member); returns this rank's reduced segment. This is the
    /// dispatcher's ETP combine primitive.
    pub fn reduce_scatter_v(&self, group: &[usize], local: &[f32], counts: &[usize]) -> Vec<f32> {
        let mut out = Vec::new();
        self.reduce_scatter_v_into(group, local, counts, &mut out);
        out
    }

    /// [`Self::reduce_scatter_v`] into a reusable output buffer.
    pub fn reduce_scatter_v_into(
        &self,
        group: &[usize],
        local: &[f32],
        counts: &[usize],
        out: &mut Vec<f32>,
    ) {
        let n = group.len();
        assert_eq!(counts.len(), n, "one count per group member");
        debug_assert_eq!(counts.iter().sum::<usize>(), local.len(), "counts must cover local");
        if n <= 1 {
            out.clear();
            out.extend_from_slice(local);
            return;
        }
        match self.algos().reduce_scatter {
            CollectiveAlgo::NaiveLeader => self.naive_reduce_scatter_v(group, local, counts, out),
            // Variable shards break the halving size symmetry; pairwise
            // exchange is the variable-count workhorse for every fast suite.
            _ => self.pairwise_reduce_scatter_v(group, local, counts, out),
        }
        self.clock_collective(CommPrimitive::ReduceScatter, group, local.len() as f64);
    }

    /// Oracle: leader folds the full buffers in group order, then scatters
    /// each member's segment.
    fn naive_reduce_scatter_v(
        &self,
        group: &[usize],
        local: &[f32],
        counts: &[usize],
        out: &mut Vec<f32>,
    ) {
        let n = group.len();
        let me = self.my_index(group);
        let leader = group[0];
        let mut offsets = vec![0usize; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + counts[i];
        }
        if self.rank() == leader {
            let mut acc = self.take_buf(local.len());
            acc.extend_from_slice(local);
            for &src in &group[1..] {
                let part = self.recv_take(src);
                assert_eq!(part.len(), acc.len(), "reduce_scatter length mismatch");
                for (a, b) in acc.iter_mut().zip(&part) {
                    *a += *b;
                }
                self.release(part);
            }
            for (i, &dst) in group.iter().enumerate().skip(1) {
                self.send_slice(dst, &acc[offsets[i]..offsets[i + 1]]);
            }
            out.clear();
            out.extend_from_slice(&acc[offsets[0]..offsets[1]]);
            self.release(acc);
        } else {
            self.send_slice(leader, local);
            self.recv_into_vec(leader, out);
            debug_assert_eq!(out.len(), counts[me]);
        }
    }

    /// Direct pairwise exchange: round `r` sends member `(me+r) mod n` its
    /// segment; contributions for my segment are folded in ascending group
    /// order (mine spliced in at position `me`), preserving the invariant.
    fn pairwise_reduce_scatter_v(
        &self,
        group: &[usize],
        local: &[f32],
        counts: &[usize],
        out: &mut Vec<f32>,
    ) {
        let n = group.len();
        let me = self.my_index(group);
        let mut offsets = vec![0usize; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + counts[i];
        }
        for r in 1..n {
            let di = (me + r) % n;
            self.send_slice(group[di], &local[offsets[di]..offsets[di + 1]]);
        }
        out.clear();
        out.resize(counts[me], 0.0);
        let my_seg = &local[offsets[me]..offsets[me + 1]];
        for i in 0..n {
            if i == me {
                if i == 0 {
                    out.copy_from_slice(my_seg);
                } else {
                    for (o, x) in out.iter_mut().zip(my_seg) {
                        *o += *x;
                    }
                }
            } else {
                let part = self.recv_take(group[i]);
                debug_assert_eq!(part.len(), counts[me], "reduce_scatter_v framing");
                if i == 0 {
                    out.copy_from_slice(&part);
                } else {
                    for (o, x) in out.iter_mut().zip(&part) {
                        *o += *x;
                    }
                }
                self.release(part);
            }
        }
    }

    /// Recursive halving with **deferred summation** (power-of-two groups):
    /// log₂(n) rounds, each exchanging half the remaining range with the
    /// partner `me ⊕ half`. Contributions travel unreduced (each round moves
    /// the same `len/2` elements a classic halving round would), and the
    /// shard owner folds all n contributions in ascending rank order at the
    /// end — eager halving would sum in tree order and break bit-exactness.
    fn halving_reduce_scatter(&self, group: &[usize], local: &[f32], out: &mut Vec<f32>) {
        let n = group.len();
        debug_assert!(n.is_power_of_two());
        let me = self.my_index(group);
        let shard = local.len() / n;

        // Contributions held, sorted by source group-index; each covers the
        // current shard range [lo, hi).
        let mut lo = 0usize;
        let mut hi = n;
        let mut sources: Vec<usize> = vec![me];
        let mut held: Vec<Vec<f32>> = {
            let mut b = self.take_buf(local.len());
            b.extend_from_slice(local);
            vec![b]
        };

        while hi - lo > 1 {
            let m = hi - lo;
            let half = m / 2;
            // [lo, hi) is always aligned to m, so the partner is me ⊕ half.
            let keep_low = (me - lo) < half;
            let partner_idx = me ^ half;
            let send_elems = half * shard;

            // Send the half the partner's subgroup owns, contributions
            // concatenated in my sorted-source order.
            let mut sbuf = self.take_buf(sources.len() * send_elems);
            for b in &held {
                let slice = if keep_low { &b[send_elems..] } else { &b[..send_elems] };
                sbuf.extend_from_slice(slice);
            }
            self.send_vec(group[partner_idx], sbuf);

            // Keep my half of each held contribution.
            for b in held.iter_mut() {
                if keep_low {
                    b.truncate(send_elems);
                } else {
                    b.drain(..send_elems);
                }
            }

            // Receive the partner's block: its sources are mine ⊕ half, and
            // its concatenation order is by *its* sorted source values.
            let rbuf = self.recv_take(group[partner_idx]);
            debug_assert_eq!(rbuf.len(), sources.len() * send_elems, "halving framing");
            let mut psources: Vec<usize> = sources.iter().map(|&s| s ^ half).collect();
            psources.sort_unstable();
            let mut merged: Vec<(usize, Vec<f32>)> =
                Vec::with_capacity(sources.len() + psources.len());
            for (s, b) in sources.drain(..).zip(held.drain(..)) {
                merged.push((s, b));
            }
            for (i, &ps) in psources.iter().enumerate() {
                let mut b = self.take_buf(send_elems);
                b.extend_from_slice(&rbuf[i * send_elems..(i + 1) * send_elems]);
                merged.push((ps, b));
            }
            self.release(rbuf);
            merged.sort_by_key(|(s, _)| *s);
            for (s, b) in merged {
                sources.push(s);
                held.push(b);
            }

            if keep_low {
                hi = lo + half;
            } else {
                lo += half;
            }
        }
        debug_assert_eq!(lo, me, "halving recursion must land on my shard");
        debug_assert_eq!(sources.len(), n);

        // Fold all contributions in ascending rank order.
        out.clear();
        out.resize(shard, 0.0);
        for (i, b) in held.iter().enumerate() {
            debug_assert_eq!(b.len(), shard);
            if i == 0 {
                out.copy_from_slice(b);
            } else {
                for (o, x) in out.iter_mut().zip(b) {
                    *o += *x;
                }
            }
        }
        for b in held {
            self.release(b);
        }
    }

    // =====================================================================
    // AllToAll-V
    // =====================================================================

    /// AllToAll-V: `sends[i]` goes to group member `i`; returns the buffers
    /// received from each member, in group order.
    pub fn all_to_all_v(&self, group: &[usize], sends: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        self.all_to_all_v_into(group, &sends, &mut out);
        out
    }

    /// [`Self::all_to_all_v`] into reusable per-peer output buffers
    /// (`out` is resized to the group size; inner buffers keep capacity).
    pub fn all_to_all_v_into(&self, group: &[usize], sends: &[Vec<f32>], out: &mut Vec<Vec<f32>>) {
        let n = group.len();
        assert_eq!(sends.len(), n, "one send buffer per group member");
        out.truncate(n);
        out.resize_with(n, Vec::new);
        match self.algos().all_to_all {
            CollectiveAlgo::NaiveLeader => self.naive_all_to_all_v(group, sends, out),
            _ => self.pairwise_all_to_all_v(group, sends, out),
        }
        let total: usize = sends.iter().map(|s| s.len()).sum();
        self.clock_collective(CommPrimitive::AllToAll, group, total as f64);
    }

    /// Oracle: every buffer (including self-destined ones) is relayed
    /// through the leader, which serializes the entire exchange.
    fn naive_all_to_all_v(&self, group: &[usize], sends: &[Vec<f32>], out: &mut [Vec<f32>]) {
        let n = group.len();
        let leader = group[0];
        for dst_buf in sends {
            self.send_slice(leader, dst_buf);
        }
        if self.rank() == leader {
            // blocks[src][dst], collected in source order.
            let mut blocks: Vec<Vec<Vec<f32>>> = Vec::with_capacity(n);
            for i in 0..n {
                let mut per_dst = Vec::with_capacity(n);
                for _ in 0..n {
                    per_dst.push(self.recv_take(group[i]));
                }
                blocks.push(per_dst);
            }
            for (j, &dst) in group.iter().enumerate() {
                for src_blocks in blocks.iter_mut() {
                    let b = std::mem::take(&mut src_blocks[j]);
                    self.send_vec(dst, b);
                }
            }
        }
        for slot in out.iter_mut() {
            self.recv_into_vec(leader, slot);
        }
    }

    /// Deterministic pairwise rounds: round `r` sends to `(me+r) mod n` and
    /// receives from `(me−r) mod n` — the schedule every link is busy on
    /// simultaneously.
    fn pairwise_all_to_all_v(&self, group: &[usize], sends: &[Vec<f32>], out: &mut [Vec<f32>]) {
        let n = group.len();
        let me = self.my_index(group);
        out[me].clear();
        out[me].extend_from_slice(&sends[me]);
        for r in 1..n {
            let di = (me + r) % n;
            self.send_slice(group[di], &sends[di]);
        }
        for r in 1..n {
            let si = (me + n - r) % n;
            self.recv_into_vec(group[si], &mut out[si]);
        }
    }

    // =====================================================================
    // Broadcast
    // =====================================================================

    /// Broadcast from `root` (a global rank in `group`).
    pub fn broadcast(&self, group: &[usize], root: usize, data: &[f32]) -> Vec<f32> {
        let mut out = data.to_vec();
        self.broadcast_into(group, root, &mut out);
        out
    }

    /// [`Self::broadcast`] into a reusable buffer (`buf` holds the payload
    /// on the root; other ranks have it overwritten/resized).
    pub fn broadcast_into(&self, group: &[usize], root: usize, buf: &mut Vec<f32>) {
        if group.len() <= 1 {
            return;
        }
        match self.algos().broadcast {
            CollectiveAlgo::NaiveLeader => self.naive_broadcast_into(group, root, buf),
            _ => self.ring_broadcast_into(group, root, buf),
        }
        self.clock_collective(CommPrimitive::Broadcast, group, buf.len() as f64);
    }

    /// Oracle: root sends the full payload to every member, serially.
    fn naive_broadcast_into(&self, group: &[usize], root: usize, buf: &mut Vec<f32>) {
        debug_assert!(group.contains(&root), "root must be in group");
        if self.rank() == root {
            for &dst in group {
                if dst != root {
                    self.send_slice(dst, buf);
                }
            }
        } else {
            self.recv_into_vec(root, buf);
        }
    }

    /// Ring: a length message down the chain so non-roots can size their
    /// buffers, then the shared chunk-pipelined chain broadcast.
    fn ring_broadcast_into(&self, group: &[usize], root: usize, buf: &mut Vec<f32>) {
        let n = group.len();
        let me = self.my_index(group);
        let root_idx = group.iter().position(|&r| r == root).expect("root must be in group");
        let chain_pos = (me + n - root_idx) % n;
        let next = group[(me + 1) % n];
        let prev = group[(me + n - 1) % n];
        let is_last = chain_pos == n - 1;

        if chain_pos == 0 {
            self.send_slice(next, &[buf.len() as f32]);
        } else {
            let lbuf = self.recv_take(prev);
            let len = lbuf[0] as usize;
            if !is_last {
                self.send_vec(next, lbuf);
            } else {
                self.release(lbuf);
            }
            buf.clear();
            buf.resize(len, 0.0);
        }
        self.ring_chain_broadcast(group, root_idx, buf);
    }
}
