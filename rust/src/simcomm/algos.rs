//! Collective algorithm implementations for [`Communicator`].
//!
//! Every algorithm here upholds the module-level determinism invariant:
//! sums are folded in **ascending group-index order**, bit-for-bit equal to
//! the [`CollectiveAlgo::NaiveLeader`] oracle. See `simcomm` module docs for
//! the rationale and the algorithm catalogue.
//!
//! Payload framing note: variable-length primitives (all-gather-v,
//! broadcast) circulate lengths as `f32` control messages, exact for
//! buffers under 2²⁴ elements — far beyond anything the functional
//! simulator moves.

use crate::collectives::CommPrimitive;

use super::{CollectiveAlgo, Communicator};

impl Communicator {
    // =====================================================================
    // AllGather-V
    // =====================================================================

    /// AllGather-V: concatenation of every member's buffer, in group order.
    pub fn all_gather_v(&self, group: &[usize], local: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.all_gather_v_into(group, local, &mut out);
        out
    }

    /// [`Self::all_gather_v`] into a reusable output buffer.
    pub fn all_gather_v_into(&self, group: &[usize], local: &[f32], out: &mut Vec<f32>) {
        if group.len() <= 1 {
            out.clear();
            out.extend_from_slice(local);
            return;
        }
        match self.algos().all_gather {
            CollectiveAlgo::NaiveLeader => self.naive_all_gather_v(group, local, out),
            CollectiveAlgo::Hierarchical | CollectiveAlgo::HierarchicalA2A => {
                self.hierarchical_all_gather_v(group, local, out)
            }
            _ => self.ring_all_gather_v(group, local, out),
        }
        self.clock_collective(CommPrimitive::AllGather, group, local.len() as f64);
    }

    /// Oracle: everyone sends to the leader; leader broadcasts the
    /// concatenation.
    fn naive_all_gather_v(&self, group: &[usize], local: &[f32], out: &mut Vec<f32>) {
        let leader = group[0];
        if self.rank() == leader {
            out.clear();
            out.extend_from_slice(local);
            for &src in &group[1..] {
                let buf = self.recv_take(src);
                out.extend_from_slice(&buf);
                self.release(buf);
            }
            for &dst in &group[1..] {
                self.send_slice(dst, out);
            }
        } else {
            self.send_slice(leader, local);
            self.recv_into_vec(leader, out);
        }
    }

    /// Ring: a length pass then a data pass; each segment travels n−1 hops
    /// around the ring, every link carrying disjoint traffic concurrently.
    fn ring_all_gather_v(&self, group: &[usize], local: &[f32], out: &mut Vec<f32>) {
        let n = group.len();
        let me = self.my_index(group);
        let next = group[(me + 1) % n];
        let prev = group[(me + n - 1) % n];

        // Pass 1: circulate segment lengths.
        let mut lens = vec![0usize; n];
        lens[me] = local.len();
        self.send_slice(next, &[local.len() as f32]);
        for s in 1..n {
            let idx = (me + n - s) % n;
            let buf = self.recv_take(prev);
            lens[idx] = buf[0] as usize;
            if s < n - 1 {
                self.send_vec(next, buf);
            } else {
                self.release(buf);
            }
        }
        let mut offsets = vec![0usize; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + lens[i];
        }
        out.clear();
        out.resize(offsets[n], 0.0);
        out[offsets[me]..offsets[me] + local.len()].copy_from_slice(local);

        // Pass 2: circulate segment data, writing at the known offsets.
        self.send_slice(next, local);
        for s in 1..n {
            let idx = (me + n - s) % n;
            let buf = self.recv_take(prev);
            debug_assert_eq!(buf.len(), lens[idx], "ring all-gather framing");
            out[offsets[idx]..offsets[idx] + buf.len()].copy_from_slice(&buf);
            if s < n - 1 {
                self.send_vec(next, buf);
            } else {
                self.release(buf);
            }
        }
    }

    // =====================================================================
    // AllReduce (sum)
    // =====================================================================

    /// AllReduce (sum), reducing in group-index order for determinism.
    pub fn all_reduce_sum(&self, group: &[usize], local: &[f32]) -> Vec<f32> {
        let mut out = local.to_vec();
        self.all_reduce_sum_into(group, &mut out);
        out
    }

    /// In-place AllReduce (sum): `buf` holds this rank's contribution on
    /// entry and the rank-order sum on exit. Zero payload allocations in
    /// steady state (pool-backed chunks).
    pub fn all_reduce_sum_into(&self, group: &[usize], buf: &mut [f32]) {
        if group.len() <= 1 {
            return;
        }
        match self.algos().all_reduce {
            CollectiveAlgo::NaiveLeader => self.naive_all_reduce_into(group, buf),
            CollectiveAlgo::Hierarchical | CollectiveAlgo::HierarchicalA2A => {
                self.hierarchical_all_reduce_into(group, buf)
            }
            _ => self.chain_all_reduce_into(group, buf),
        }
        self.clock_collective(CommPrimitive::AllReduce, group, buf.len() as f64);
    }

    /// Oracle: leader folds contributions in group order, then scatters the
    /// full result.
    fn naive_all_reduce_into(&self, group: &[usize], buf: &mut [f32]) {
        let leader = group[0];
        if self.rank() == leader {
            for &src in &group[1..] {
                let part = self.recv_take(src);
                assert_eq!(part.len(), buf.len(), "allreduce length mismatch");
                for (a, b) in buf.iter_mut().zip(&part) {
                    *a += *b;
                }
                self.release(part);
            }
            for &dst in &group[1..] {
                self.send_slice(dst, buf);
            }
        } else {
            self.send_slice(leader, buf);
            let full = self.recv_take(leader);
            buf.copy_from_slice(&full);
            self.release(full);
        }
    }

    /// Ring: chunk-pipelined chain reduce `0 → 1 → … → n−1` (each chunk's
    /// partial sum grows strictly in ascending rank order — the classic
    /// rotating-chunk ring is rejected because it breaks that invariant),
    /// followed by a chunk-pipelined ring broadcast `n−1 → 0 → … → n−2`.
    /// Per-link volume is ~2× the buffer, like a bandwidth-optimal ring,
    /// and all links run concurrently — no leader bottleneck.
    fn chain_all_reduce_into(&self, group: &[usize], buf: &mut [f32]) {
        let n = group.len();
        let me = self.my_index(group);
        let len = buf.len();
        let chunks = n.min(len.max(1));
        let bounds = |c: usize| (c * len / chunks, (c + 1) * len / chunks);

        // Phase 1: pipelined chain reduce.
        if me == 0 {
            for c in 0..chunks {
                let (lo, hi) = bounds(c);
                self.send_slice(group[1], &buf[lo..hi]);
            }
        } else {
            let prev = group[me - 1];
            for c in 0..chunks {
                let (lo, hi) = bounds(c);
                let mut part = self.recv_take(prev);
                debug_assert_eq!(part.len(), hi - lo, "chain reduce framing");
                // part = Σ ranks 0..me; adding mine keeps the left fold.
                for (p, x) in part.iter_mut().zip(&buf[lo..hi]) {
                    *p += *x;
                }
                if me < n - 1 {
                    self.send_vec(group[me + 1], part);
                } else {
                    buf[lo..hi].copy_from_slice(&part);
                    self.release(part);
                }
            }
        }

        // Phase 2: pipelined ring broadcast of the finished chunks, rooted
        // at the chain's end (group index n−1).
        self.ring_chain_broadcast(group, n - 1, buf);
    }

    /// Chunk-pipelined ring broadcast where every member already knows the
    /// buffer length: the member at group index `root_idx` sends its `buf`
    /// around the ring; the member just before it terminates the chain.
    /// Shared by the all-reduce distribution phase and [`Self::broadcast`].
    fn ring_chain_broadcast(&self, group: &[usize], root_idx: usize, buf: &mut [f32]) {
        let n = group.len();
        let me = self.my_index(group);
        let chain_pos = (me + n - root_idx) % n;
        let next = group[(me + 1) % n];
        let prev = group[(me + n - 1) % n];
        let is_last = chain_pos == n - 1;
        let len = buf.len();
        let chunks = n.min(len.max(1));
        let bounds = |c: usize| (c * len / chunks, (c + 1) * len / chunks);
        if chain_pos == 0 {
            for c in 0..chunks {
                let (lo, hi) = bounds(c);
                self.send_slice(next, &buf[lo..hi]);
            }
        } else {
            for c in 0..chunks {
                let (lo, hi) = bounds(c);
                let part = self.recv_take(prev);
                debug_assert_eq!(part.len(), hi - lo, "ring broadcast framing");
                buf[lo..hi].copy_from_slice(&part);
                if !is_last {
                    self.send_vec(next, part);
                } else {
                    self.release(part);
                }
            }
        }
    }

    // =====================================================================
    // ReduceScatter (sum)
    // =====================================================================

    /// ReduceScatter (sum): every rank contributes `local` (length divisible
    /// by group size), receives its reduced shard.
    pub fn reduce_scatter_sum(&self, group: &[usize], local: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.reduce_scatter_sum_into(group, local, &mut out);
        out
    }

    /// [`Self::reduce_scatter_sum`] into a reusable output buffer.
    pub fn reduce_scatter_sum_into(&self, group: &[usize], local: &[f32], out: &mut Vec<f32>) {
        let n = group.len();
        if n <= 1 {
            out.clear();
            out.extend_from_slice(local);
            return;
        }
        assert_eq!(local.len() % n, 0, "reduce_scatter length must divide");
        let shard = local.len() / n;
        let counts = vec![shard; n];
        match self.algos().reduce_scatter {
            CollectiveAlgo::NaiveLeader => self.naive_reduce_scatter_v(group, local, &counts, out),
            CollectiveAlgo::Hierarchical | CollectiveAlgo::HierarchicalA2A => {
                self.hierarchical_reduce_scatter_v(group, local, &counts, out)
            }
            CollectiveAlgo::RecursiveHalving if n.is_power_of_two() => {
                self.halving_reduce_scatter(group, local, out)
            }
            // Recursive halving needs a power-of-two group; everything else
            // (and the explicit Pairwise/Ring selections) uses the direct
            // pairwise exchange.
            _ => self.pairwise_reduce_scatter_v(group, local, &counts, out),
        }
        self.clock_collective(CommPrimitive::ReduceScatter, group, local.len() as f64);
    }

    /// ReduceScatter-V (sum): `counts[i]` elements of `local` belong to
    /// group member `i` (`Σ counts == local.len()`, identical on every
    /// member); returns this rank's reduced segment. This is the
    /// dispatcher's ETP combine primitive.
    pub fn reduce_scatter_v(&self, group: &[usize], local: &[f32], counts: &[usize]) -> Vec<f32> {
        let mut out = Vec::new();
        self.reduce_scatter_v_into(group, local, counts, &mut out);
        out
    }

    /// [`Self::reduce_scatter_v`] into a reusable output buffer.
    pub fn reduce_scatter_v_into(
        &self,
        group: &[usize],
        local: &[f32],
        counts: &[usize],
        out: &mut Vec<f32>,
    ) {
        let n = group.len();
        assert_eq!(counts.len(), n, "one count per group member");
        debug_assert_eq!(counts.iter().sum::<usize>(), local.len(), "counts must cover local");
        if n <= 1 {
            out.clear();
            out.extend_from_slice(local);
            return;
        }
        match self.algos().reduce_scatter {
            CollectiveAlgo::NaiveLeader => self.naive_reduce_scatter_v(group, local, counts, out),
            CollectiveAlgo::Hierarchical | CollectiveAlgo::HierarchicalA2A => {
                self.hierarchical_reduce_scatter_v(group, local, counts, out)
            }
            // Variable shards break the halving size symmetry; pairwise
            // exchange is the variable-count workhorse for every fast suite.
            _ => self.pairwise_reduce_scatter_v(group, local, counts, out),
        }
        self.clock_collective(CommPrimitive::ReduceScatter, group, local.len() as f64);
    }

    /// Oracle: leader folds the full buffers in group order, then scatters
    /// each member's segment.
    fn naive_reduce_scatter_v(
        &self,
        group: &[usize],
        local: &[f32],
        counts: &[usize],
        out: &mut Vec<f32>,
    ) {
        let n = group.len();
        let me = self.my_index(group);
        let leader = group[0];
        let mut offsets = vec![0usize; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + counts[i];
        }
        if self.rank() == leader {
            let mut acc = self.take_buf(local.len());
            acc.extend_from_slice(local);
            for &src in &group[1..] {
                let part = self.recv_take(src);
                assert_eq!(part.len(), acc.len(), "reduce_scatter length mismatch");
                for (a, b) in acc.iter_mut().zip(&part) {
                    *a += *b;
                }
                self.release(part);
            }
            for (i, &dst) in group.iter().enumerate().skip(1) {
                self.send_slice(dst, &acc[offsets[i]..offsets[i + 1]]);
            }
            out.clear();
            out.extend_from_slice(&acc[offsets[0]..offsets[1]]);
            self.release(acc);
        } else {
            self.send_slice(leader, local);
            self.recv_into_vec(leader, out);
            debug_assert_eq!(out.len(), counts[me]);
        }
    }

    /// Direct pairwise exchange: round `r` sends member `(me+r) mod n` its
    /// segment; contributions for my segment are folded in ascending group
    /// order (mine spliced in at position `me`), preserving the invariant.
    fn pairwise_reduce_scatter_v(
        &self,
        group: &[usize],
        local: &[f32],
        counts: &[usize],
        out: &mut Vec<f32>,
    ) {
        let n = group.len();
        let me = self.my_index(group);
        let mut offsets = vec![0usize; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + counts[i];
        }
        for r in 1..n {
            let di = (me + r) % n;
            self.send_slice(group[di], &local[offsets[di]..offsets[di + 1]]);
        }
        out.clear();
        out.resize(counts[me], 0.0);
        let my_seg = &local[offsets[me]..offsets[me + 1]];
        for i in 0..n {
            if i == me {
                if i == 0 {
                    out.copy_from_slice(my_seg);
                } else {
                    for (o, x) in out.iter_mut().zip(my_seg) {
                        *o += *x;
                    }
                }
            } else {
                let part = self.recv_take(group[i]);
                debug_assert_eq!(part.len(), counts[me], "reduce_scatter_v framing");
                if i == 0 {
                    out.copy_from_slice(&part);
                } else {
                    for (o, x) in out.iter_mut().zip(&part) {
                        *o += *x;
                    }
                }
                self.release(part);
            }
        }
    }

    /// Recursive halving with **deferred summation** (power-of-two groups):
    /// log₂(n) rounds, each exchanging half the remaining range with the
    /// partner `me ⊕ half`. Contributions travel unreduced (each round moves
    /// the same `len/2` elements a classic halving round would), and the
    /// shard owner folds all n contributions in ascending rank order at the
    /// end — eager halving would sum in tree order and break bit-exactness.
    fn halving_reduce_scatter(&self, group: &[usize], local: &[f32], out: &mut Vec<f32>) {
        let n = group.len();
        debug_assert!(n.is_power_of_two());
        let me = self.my_index(group);
        let shard = local.len() / n;

        // Contributions held, sorted by source group-index; each covers the
        // current shard range [lo, hi).
        let mut lo = 0usize;
        let mut hi = n;
        let mut sources: Vec<usize> = vec![me];
        let mut held: Vec<Vec<f32>> = {
            let mut b = self.take_buf(local.len());
            b.extend_from_slice(local);
            vec![b]
        };

        while hi - lo > 1 {
            let m = hi - lo;
            let half = m / 2;
            // [lo, hi) is always aligned to m, so the partner is me ⊕ half.
            let keep_low = (me - lo) < half;
            let partner_idx = me ^ half;
            let send_elems = half * shard;

            // Send the half the partner's subgroup owns, contributions
            // concatenated in my sorted-source order.
            let mut sbuf = self.take_buf(sources.len() * send_elems);
            for b in &held {
                let slice = if keep_low { &b[send_elems..] } else { &b[..send_elems] };
                sbuf.extend_from_slice(slice);
            }
            self.send_vec(group[partner_idx], sbuf);

            // Keep my half of each held contribution.
            for b in held.iter_mut() {
                if keep_low {
                    b.truncate(send_elems);
                } else {
                    b.drain(..send_elems);
                }
            }

            // Receive the partner's block: its sources are mine ⊕ half, and
            // its concatenation order is by *its* sorted source values.
            let rbuf = self.recv_take(group[partner_idx]);
            debug_assert_eq!(rbuf.len(), sources.len() * send_elems, "halving framing");
            let mut psources: Vec<usize> = sources.iter().map(|&s| s ^ half).collect();
            psources.sort_unstable();
            let mut merged: Vec<(usize, Vec<f32>)> =
                Vec::with_capacity(sources.len() + psources.len());
            for (s, b) in sources.drain(..).zip(held.drain(..)) {
                merged.push((s, b));
            }
            for (i, &ps) in psources.iter().enumerate() {
                let mut b = self.take_buf(send_elems);
                b.extend_from_slice(&rbuf[i * send_elems..(i + 1) * send_elems]);
                merged.push((ps, b));
            }
            self.release(rbuf);
            merged.sort_by_key(|(s, _)| *s);
            for (s, b) in merged {
                sources.push(s);
                held.push(b);
            }

            if keep_low {
                hi = lo + half;
            } else {
                lo += half;
            }
        }
        debug_assert_eq!(lo, me, "halving recursion must land on my shard");
        debug_assert_eq!(sources.len(), n);

        // Fold all contributions in ascending rank order.
        out.clear();
        out.resize(shard, 0.0);
        for (i, b) in held.iter().enumerate() {
            debug_assert_eq!(b.len(), shard);
            if i == 0 {
                out.copy_from_slice(b);
            } else {
                for (o, x) in out.iter_mut().zip(b) {
                    *o += *x;
                }
            }
        }
        for b in held {
            self.release(b);
        }
    }

    // =====================================================================
    // AllToAll-V
    // =====================================================================

    /// AllToAll-V: `sends[i]` goes to group member `i`; returns the buffers
    /// received from each member, in group order.
    pub fn all_to_all_v(&self, group: &[usize], sends: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        self.all_to_all_v_into(group, &sends, &mut out);
        out
    }

    /// [`Self::all_to_all_v`] into reusable per-peer output buffers
    /// (`out` is resized to the group size; inner buffers keep capacity).
    pub fn all_to_all_v_into(&self, group: &[usize], sends: &[Vec<f32>], out: &mut Vec<Vec<f32>>) {
        let n = group.len();
        assert_eq!(sends.len(), n, "one send buffer per group member");
        out.truncate(n);
        out.resize_with(n, Vec::new);
        match self.algos().all_to_all {
            CollectiveAlgo::NaiveLeader => self.naive_all_to_all_v(group, sends, out),
            CollectiveAlgo::Hierarchical | CollectiveAlgo::HierarchicalA2A => {
                self.two_level_all_to_all_v(group, sends, out)
            }
            _ => self.pairwise_all_to_all_v(group, sends, out),
        }
        let total: usize = sends.iter().map(|s| s.len()).sum();
        self.clock_collective(CommPrimitive::AllToAll, group, total as f64);
    }

    /// Oracle: every buffer (including self-destined ones) is relayed
    /// through the leader, which serializes the entire exchange.
    fn naive_all_to_all_v(&self, group: &[usize], sends: &[Vec<f32>], out: &mut [Vec<f32>]) {
        let n = group.len();
        let leader = group[0];
        for dst_buf in sends {
            self.send_slice(leader, dst_buf);
        }
        if self.rank() == leader {
            // blocks[src][dst], collected in source order.
            let mut blocks: Vec<Vec<Vec<f32>>> = Vec::with_capacity(n);
            for i in 0..n {
                let mut per_dst = Vec::with_capacity(n);
                for _ in 0..n {
                    per_dst.push(self.recv_take(group[i]));
                }
                blocks.push(per_dst);
            }
            for (j, &dst) in group.iter().enumerate() {
                for src_blocks in blocks.iter_mut() {
                    let b = std::mem::take(&mut src_blocks[j]);
                    self.send_vec(dst, b);
                }
            }
        }
        for slot in out.iter_mut() {
            self.recv_into_vec(leader, slot);
        }
    }

    /// Deterministic pairwise rounds: round `r` sends to `(me+r) mod n` and
    /// receives from `(me−r) mod n` — the schedule every link is busy on
    /// simultaneously.
    fn pairwise_all_to_all_v(&self, group: &[usize], sends: &[Vec<f32>], out: &mut [Vec<f32>]) {
        let n = group.len();
        let me = self.my_index(group);
        out[me].clear();
        out[me].extend_from_slice(&sends[me]);
        for r in 1..n {
            let di = (me + r) % n;
            self.send_slice(group[di], &sends[di]);
        }
        for r in 1..n {
            let si = (me + n - r) % n;
            self.recv_into_vec(group[si], &mut out[si]);
        }
    }

    // =====================================================================
    // Broadcast
    // =====================================================================

    /// Broadcast from `root` (a global rank in `group`).
    pub fn broadcast(&self, group: &[usize], root: usize, data: &[f32]) -> Vec<f32> {
        let mut out = data.to_vec();
        self.broadcast_into(group, root, &mut out);
        out
    }

    /// [`Self::broadcast`] into a reusable buffer (`buf` holds the payload
    /// on the root; other ranks have it overwritten/resized).
    pub fn broadcast_into(&self, group: &[usize], root: usize, buf: &mut Vec<f32>) {
        if group.len() <= 1 {
            return;
        }
        match self.algos().broadcast {
            CollectiveAlgo::NaiveLeader => self.naive_broadcast_into(group, root, buf),
            CollectiveAlgo::Hierarchical | CollectiveAlgo::HierarchicalA2A => {
                self.hierarchical_broadcast_into(group, root, buf)
            }
            _ => self.ring_broadcast_into(group, root, buf),
        }
        self.clock_collective(CommPrimitive::Broadcast, group, buf.len() as f64);
    }

    /// Oracle: root sends the full payload to every member, serially.
    fn naive_broadcast_into(&self, group: &[usize], root: usize, buf: &mut Vec<f32>) {
        debug_assert!(group.contains(&root), "root must be in group");
        if self.rank() == root {
            for &dst in group {
                if dst != root {
                    self.send_slice(dst, buf);
                }
            }
        } else {
            self.recv_into_vec(root, buf);
        }
    }

    /// Ring: a length message down the chain so non-roots can size their
    /// buffers, then the shared chunk-pipelined chain broadcast.
    fn ring_broadcast_into(&self, group: &[usize], root: usize, buf: &mut Vec<f32>) {
        let n = group.len();
        let me = self.my_index(group);
        let root_idx = group.iter().position(|&r| r == root).expect("root must be in group");
        let chain_pos = (me + n - root_idx) % n;
        let next = group[(me + 1) % n];
        let prev = group[(me + n - 1) % n];
        let is_last = chain_pos == n - 1;

        if chain_pos == 0 {
            self.send_slice(next, &[buf.len() as f32]);
        } else {
            let lbuf = self.recv_take(prev);
            let len = lbuf[0] as usize;
            if !is_last {
                self.send_vec(next, lbuf);
            } else {
                self.release(lbuf);
            }
            buf.clear();
            buf.resize(len, 0.0);
        }
        self.ring_chain_broadcast(group, root_idx, buf);
    }

    // =====================================================================
    // Hierarchical (node-grouped) algorithms
    // =====================================================================

    /// Maximal runs of consecutive group members on the same node, as
    /// `(start, end)` index ranges into `group` (ascending order). Groups
    /// are sorted and `node_of` is monotone in rank, so each run is
    /// exactly the slice of the group living in one NVLink domain; the
    /// first member of each run acts as its node leader.
    fn node_runs(&self, group: &[usize]) -> Vec<(usize, usize)> {
        let topo = self.topology();
        let mut runs = Vec::new();
        let mut start = 0usize;
        for i in 1..group.len() {
            if topo.node_of(group[i]) != topo.node_of(group[start]) {
                runs.push((start, i));
                start = i;
            }
        }
        runs.push((start, group.len()));
        runs
    }

    /// Index of the run containing group index `me`.
    fn run_of(runs: &[(usize, usize)], me: usize) -> usize {
        runs.iter().position(|&(s, e)| me >= s && me < e).expect("index in some run")
    }

    /// Hierarchical AllReduce: members ship raw buffers to their node
    /// leader over NVLink; leaders chain the partial sum across nodes in
    /// ascending run order (run 0's left fold travels to run 1's leader,
    /// which folds its run on top, …) so the total is the exact ascending
    /// group-order fold the `NaiveLeader` oracle produces; the last leader
    /// fans the result back out through the other leaders. Only the
    /// leader chain and the fan-out cross IB.
    fn hierarchical_all_reduce_into(&self, group: &[usize], buf: &mut [f32]) {
        let runs = self.node_runs(group);
        let me = self.my_index(group);
        let ri = Self::run_of(&runs, me);
        let (start, end) = runs[ri];
        let leader = group[start];
        if me != start {
            self.send_slice(leader, buf);
            let full = self.recv_take(leader);
            buf.copy_from_slice(&full);
            self.release(full);
            return;
        }
        let mut acc = if ri == 0 {
            let mut a = self.take_buf(buf.len());
            a.extend_from_slice(buf);
            a
        } else {
            let mut a = self.recv_take(group[runs[ri - 1].0]);
            debug_assert_eq!(a.len(), buf.len(), "hierarchical allreduce framing");
            for (x, y) in a.iter_mut().zip(buf.iter()) {
                *x += *y;
            }
            a
        };
        for i in start + 1..end {
            let part = self.recv_take(group[i]);
            debug_assert_eq!(part.len(), buf.len(), "hierarchical allreduce framing");
            for (x, y) in acc.iter_mut().zip(part.iter()) {
                *x += *y;
            }
            self.release(part);
        }
        let last = runs.len() - 1;
        if ri < last {
            self.send_vec(group[runs[ri + 1].0], acc);
            let total = self.recv_take(group[runs[last].0]);
            buf.copy_from_slice(&total);
            self.release(total);
        } else {
            buf.copy_from_slice(&acc);
            for &(s, _) in runs.iter().take(last) {
                self.send_slice(group[s], &acc);
            }
            self.release(acc);
        }
        for i in start + 1..end {
            self.send_slice(group[i], buf);
        }
    }

    /// Hierarchical ReduceScatter-V: the same ascending leader chain as
    /// [`Self::hierarchical_all_reduce_into`] over the full vector, after
    /// which the last leader scatters each run's concatenated shard block
    /// to that run's leader (one IB message per node) and leaders split
    /// shards out to their members over NVLink.
    fn hierarchical_reduce_scatter_v(
        &self,
        group: &[usize],
        local: &[f32],
        counts: &[usize],
        out: &mut Vec<f32>,
    ) {
        let runs = self.node_runs(group);
        let me = self.my_index(group);
        let ri = Self::run_of(&runs, me);
        let (start, end) = runs[ri];
        let leader = group[start];
        if me != start {
            self.send_slice(leader, local);
            self.recv_into_vec(leader, out);
            debug_assert_eq!(out.len(), counts[me], "hierarchical rs framing");
            return;
        }
        let mut acc = if ri == 0 {
            let mut a = self.take_buf(local.len());
            a.extend_from_slice(local);
            a
        } else {
            let mut a = self.recv_take(group[runs[ri - 1].0]);
            debug_assert_eq!(a.len(), local.len(), "hierarchical rs framing");
            for (x, y) in a.iter_mut().zip(local.iter()) {
                *x += *y;
            }
            a
        };
        for i in start + 1..end {
            let part = self.recv_take(group[i]);
            debug_assert_eq!(part.len(), local.len(), "hierarchical rs framing");
            for (x, y) in acc.iter_mut().zip(part.iter()) {
                *x += *y;
            }
            self.release(part);
        }
        let mut offsets = vec![0usize; group.len() + 1];
        for (i, &c) in counts.iter().enumerate() {
            offsets[i + 1] = offsets[i] + c;
        }
        let last = runs.len() - 1;
        let my_block = if ri < last {
            self.send_vec(group[runs[ri + 1].0], acc);
            let block = self.recv_take(group[runs[last].0]);
            debug_assert_eq!(block.len(), offsets[end] - offsets[start], "hierarchical rs block");
            block
        } else {
            for &(s, e) in runs.iter().take(last) {
                self.send_slice(group[s], &acc[offsets[s]..offsets[e]]);
            }
            let mut block = self.take_buf(offsets[end] - offsets[start]);
            block.extend_from_slice(&acc[offsets[start]..offsets[end]]);
            self.release(acc);
            block
        };
        out.clear();
        out.extend_from_slice(&my_block[..counts[start]]);
        let mut off = counts[start];
        for i in start + 1..end {
            self.send_slice(group[i], &my_block[off..off + counts[i]]);
            off += counts[i];
        }
        self.release(my_block);
    }

    /// Hierarchical AllGather-V: members gather their shards to the node
    /// leader, leaders exchange per-run concatenations (one IB message per
    /// ordered leader pair), and each leader rebroadcasts the full
    /// group-order concatenation to its members over NVLink.
    fn hierarchical_all_gather_v(&self, group: &[usize], local: &[f32], out: &mut Vec<f32>) {
        let runs = self.node_runs(group);
        let me = self.my_index(group);
        let ri = Self::run_of(&runs, me);
        let (start, end) = runs[ri];
        let leader = group[start];
        if me != start {
            self.send_slice(leader, local);
            self.recv_into_vec(leader, out);
            return;
        }
        let mut mine = self.take_buf(local.len());
        mine.extend_from_slice(local);
        for i in start + 1..end {
            let part = self.recv_take(group[i]);
            mine.extend_from_slice(&part);
            self.release(part);
        }
        for (r, &(s, _)) in runs.iter().enumerate() {
            if r != ri {
                self.send_slice(group[s], &mine);
            }
        }
        out.clear();
        for (r, &(s, _)) in runs.iter().enumerate() {
            if r == ri {
                out.extend_from_slice(&mine);
            } else {
                let part = self.recv_take(group[s]);
                out.extend_from_slice(&part);
                self.release(part);
            }
        }
        self.release(mine);
        for i in start + 1..end {
            self.send_slice(group[i], out);
        }
    }

    /// Hierarchical broadcast: the root sends one copy per remote node to
    /// that node's leader, which re-distributes over NVLink; the root's
    /// own run is fed directly.
    fn hierarchical_broadcast_into(&self, group: &[usize], root: usize, buf: &mut Vec<f32>) {
        let runs = self.node_runs(group);
        let me = self.my_index(group);
        let ri = Self::run_of(&runs, me);
        let (start, end) = runs[ri];
        let leader = group[start];
        let root_idx = group.iter().position(|&r| r == root).expect("root must be in group");
        let root_run = Self::run_of(&runs, root_idx);
        if me == root_idx {
            for (r, &(s, _)) in runs.iter().enumerate() {
                if r != root_run {
                    self.send_slice(group[s], buf);
                }
            }
            for i in start..end {
                if i != root_idx {
                    self.send_slice(group[i], buf);
                }
            }
        } else if ri == root_run {
            self.recv_into_vec(root, buf);
        } else if me == start {
            self.recv_into_vec(root, buf);
            for i in start + 1..end {
                self.send_slice(group[i], buf);
            }
        } else {
            self.recv_into_vec(leader, buf);
        }
    }

    /// Two-level AllToAll-V (DeepEP-style): intra-node payloads travel
    /// directly over NVLink; payloads bound for each remote node are
    /// bundled at the sender's node leader and cross IB as **one message
    /// per ordered node pair** before fanning out on the far side. Output
    /// buffers are bit-identical to the pairwise/naive exchange — only the
    /// wires the bytes ride (and the per-link message counts) differ.
    ///
    /// Framing: a member's per-remote-run bundle is `[len(dst) as f32 for
    /// each dst in the run, then the payloads in ascending dst order]`;
    /// the leader's cross-IB mega-bundle concatenates member bundles in
    /// ascending member order. FIFO mailbox order per (src, dst) channel
    /// makes every take below unambiguous: members send leader bundles in
    /// ascending remote-run order *before* their direct intra-run pieces,
    /// and leaders forward remote pieces in (run, source) ascending order.
    fn two_level_all_to_all_v(&self, group: &[usize], sends: &[Vec<f32>], out: &mut [Vec<f32>]) {
        let runs = self.node_runs(group);
        let me = self.my_index(group);
        let ri = Self::run_of(&runs, me);
        let (start, end) = runs[ri];
        let leader = group[start];

        out[me].clear();
        out[me].extend_from_slice(&sends[me]);
        // Bundles for remote runs go to my leader first (leaders keep
        // their own contribution local and splice it in below).
        if me != start {
            for (r, &(rs, re)) in runs.iter().enumerate() {
                if r == ri {
                    continue;
                }
                let payload: usize = (rs..re).map(|di| sends[di].len()).sum();
                let mut bundle = self.take_buf(re - rs + payload);
                for di in rs..re {
                    bundle.push(sends[di].len() as f32);
                }
                for di in rs..re {
                    bundle.extend_from_slice(&sends[di]);
                }
                self.send_vec(leader, bundle);
            }
        }
        // Direct intra-run pieces (ascending destination order).
        for di in start..end {
            if di != me {
                self.send_slice(group[di], &sends[di]);
            }
        }

        if me == start {
            // Aggregate member bundles per remote run and cross IB once
            // per destination node.
            for (r, &(rs, re)) in runs.iter().enumerate() {
                if r == ri {
                    continue;
                }
                let mut mega = self.take_buf(0);
                for m in start..end {
                    if m == me {
                        for di in rs..re {
                            mega.push(sends[di].len() as f32);
                        }
                        for di in rs..re {
                            mega.extend_from_slice(&sends[di]);
                        }
                    } else {
                        let bundle = self.recv_take(group[m]);
                        mega.extend_from_slice(&bundle);
                        self.release(bundle);
                    }
                }
                self.send_vec(group[rs], mega);
            }
            // Unpack each remote leader's mega-bundle and fan the pieces
            // out to their destinations, keeping my own.
            for (r, &(rs, re)) in runs.iter().enumerate() {
                if r == ri {
                    continue;
                }
                let mega = self.recv_take(group[rs]);
                let mut off = 0usize;
                for src in rs..re {
                    let lens_at = off;
                    off += end - start;
                    for j in 0..end - start {
                        let len = mega[lens_at + j] as usize;
                        let piece = &mega[off..off + len];
                        off += len;
                        if start + j == me {
                            out[src].clear();
                            out[src].extend_from_slice(piece);
                        } else {
                            self.send_slice(group[start + j], piece);
                        }
                    }
                }
                debug_assert_eq!(off, mega.len(), "two-level a2a framing");
                self.release(mega);
            }
        }

        // Collect direct intra-run pieces (ascending source order)…
        for si in start..end {
            if si != me {
                self.recv_into_vec(group[si], &mut out[si]);
            }
        }
        // …then remote pieces forwarded by my leader in (run, source)
        // ascending order. The leader filled its own slots while
        // unpacking.
        if me != start {
            for (r, &(rs, re)) in runs.iter().enumerate() {
                if r != ri {
                    for si in rs..re {
                        self.recv_into_vec(leader, &mut out[si]);
                    }
                }
            }
        }
    }
}
